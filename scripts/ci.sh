#!/usr/bin/env bash
# The single CI entry point — humans and automation invoke the same
# command (ROADMAP.md "Tier-1 verify"). Runs the full offline test
# suite; add BENCH=1 to also run the benchmark harness's assertions;
# QUICK=1 skips the @pytest.mark.slow tests (exact-TSP and multidevice
# oracle suites) for a fast inner loop — the default run keeps them.
# QUICK=1 BENCH=1 keeps the fast lane honest about wire bytes: it runs
# the self-contained bench_collectives subprocess (the ChainProgram
# byte-prediction assertions for every collective × K), bench_serve
# (the serving-traffic + KV-multicast self-consistency assertions) and
# bench_train (the bucketed-overlap reduce: modeled wire bytes ==
# bucketed-path HLO bytes EXACTLY, modeled overlap < serial) instead
# of the full harness. Either BENCH path rewrites
# BENCH_collectives.json, BENCH_serve.json and BENCH_train.json — the
# per-benchmark modeled-vs-actual bytes/latency records tracked
# across PRs.
set -euo pipefail

cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

if [[ "${QUICK:-0}" == "1" ]]; then
    python -m pytest -x -q -m "not slow" "$@"
else
    python -m pytest -x -q "$@"
fi

if [[ "${BENCH:-0}" == "1" ]]; then
    if [[ "${QUICK:-0}" == "1" ]]; then
        python -m benchmarks.bench_collectives
        python -m benchmarks.bench_serve
        python -m benchmarks.bench_train
    else
        python -m benchmarks.run
    fi
fi
