#!/usr/bin/env bash
# The single CI entry point — humans and automation invoke the same
# command (ROADMAP.md "Tier-1 verify"). Runs the full offline test
# suite; add BENCH=1 to also run the benchmark harness's assertions.
set -euo pipefail

cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

python -m pytest -x -q "$@"

if [[ "${BENCH:-0}" == "1" ]]; then
    python -m benchmarks.run
fi
