"""Paper Fig. 5 — η_P2MP for unicast (iDMA), multicast (ESP) and
Chainwrite (Torrent) over 1–128 KB × 2–16 destinations (192 points).

Validation targets (paper §IV-B):
  * unicast η ≤ 1 everywhere, approaching 1 for ≥ 8 KB;
  * multicast > chainwrite at N_dst 2–4 (lower link-setup cost);
  * chainwrite ≥ multicast at N_dst ≥ 8 (linear vs superlinear config);
  * both approach the ideal η = N_dst as size grows.
"""

from __future__ import annotations

import time

from repro.core.simulator import p2mp_efficiency_point
from repro.core.topology import MeshTopology

SIZES_KB = (1, 2, 4, 8, 16, 32, 64, 128)
N_DSTS = tuple(range(2, 17))  # 2..16
TOPO = MeshTopology(4, 5)  # the paper's 20-cluster SoC


def sweep() -> list[dict]:
    rows = []
    for n in N_DSTS:
        dsts = list(range(1, 1 + n))
        for kb in SIZES_KB:
            pt = p2mp_efficiency_point(TOPO, 0, dsts, kb * 1024, scheduler="greedy")
            rows.append(pt)
    return rows


def validate(rows: list[dict]) -> dict:
    by = {(r["n_dst"], r["size_bytes"] // 1024): r for r in rows}
    uni_max = max(r["eta_unicast"] for r in rows)
    big_uni = min(
        r["eta_unicast"] for r in rows if r["size_bytes"] >= 8 * 1024
    )
    few = [by[(n, 8)] for n in (2, 3, 4)]
    # ESP's config complexity grows superlinearly -> Torrent overtakes
    # at the top of the paper's swept range (N_dst = 16).
    many = [by[(16, kb)] for kb in (64, 128)]
    mid = [by[(n, 64)] for n in (8, 12)]
    ideal_frac = by[(16, 128)]["eta_chainwrite"] / 16
    return {
        "unicast_eta_max": round(uni_max, 4),  # must be <= 1
        "unicast_eta_min_large": round(big_uni, 4),  # ~1 at >= 8 KB
        "multicast_wins_few_dsts": all(
            r["eta_multicast"] > r["eta_chainwrite"] for r in few
        ),
        "chainwrite_wins_many_dsts": all(
            r["eta_chainwrite"] >= r["eta_multicast"] for r in many
        ),
        "chainwrite_competitive_mid": all(
            r["eta_chainwrite"] >= 0.8 * r["eta_multicast"] for r in mid
        ),
        "chainwrite_ideal_fraction_16dst_128kb": round(ideal_frac, 4),
        "points": len(rows),
    }


def main() -> list[tuple[str, float, str]]:
    t0 = time.perf_counter()
    rows = sweep()
    us = (time.perf_counter() - t0) * 1e6 / len(rows)
    v = validate(rows)
    assert v["unicast_eta_max"] <= 1.0 + 1e-9
    assert v["multicast_wins_few_dsts"] and v["chainwrite_wins_many_dsts"]
    assert v["chainwrite_competitive_mid"]
    out = [
        ("fig5.points", us, str(v["points"])),
        ("fig5.unicast_eta_max", us, f"{v['unicast_eta_max']}"),
        ("fig5.chainwrite_ideal_frac@16dst128KB", us,
         f"{v['chainwrite_ideal_fraction_16dst_128kb']}"),
        ("fig5.multicast_wins_2-4dst", us, str(v["multicast_wins_few_dsts"])),
        ("fig5.chainwrite_wins_8-16dst", us, str(v["chainwrite_wins_many_dsts"])),
    ]
    return out


if __name__ == "__main__":
    for name, us, derived in main():
        print(f"{name},{us:.2f},{derived}")
