"""Paper Fig. 7 — Chainwrite configuration overhead: 64 KB copy to
1–8 destinations; linear fit must give the paper's 82 CC/destination."""

from __future__ import annotations

import time

from repro.core.simulator import config_overhead_per_destination
from repro.core.topology import MeshTopology

TOPO = MeshTopology(4, 5)


def main() -> list[tuple[str, float, str]]:
    t0 = time.perf_counter()
    res = config_overhead_per_destination(TOPO, src=0, size_bytes=64 * 1024,
                                          max_dsts=8)
    us = (time.perf_counter() - t0) * 1e6
    slope = res["slope_cc_per_dst"]
    assert abs(slope - 82.0) <= 3.0, slope
    lats = res["latencies_cc"]
    return [
        ("fig7.slope_cc_per_dst", us, f"{slope:.1f}"),
        ("fig7.latency_1dst_cc", us, str(lats[0])),
        ("fig7.latency_8dst_cc", us, str(lats[-1])),
        ("fig7.linear", us, str(all(b > a for a, b in zip(lats, lats[1:])))),
    ]


if __name__ == "__main__":
    for name, us, derived in main():
        print(f"{name},{us:.2f},{derived}")
