"""Benchmark harness entry: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV. Individual benches also run
standalone: ``python -m benchmarks.bench_fig5_eta_p2mp`` etc.
"""

from __future__ import annotations

import sys
import traceback

from . import (
    bench_area_power,
    bench_collectives,
    bench_fig5_eta_p2mp,
    bench_fig6_hops,
    bench_fig7_config_overhead,
    bench_fig9_deepseek,
    bench_roofline,
    bench_serve,
    bench_train,
)

BENCHES = [
    ("fig5 (eta_P2MP sweep)", bench_fig5_eta_p2mp),
    ("fig6 (avg hops/dst)", bench_fig6_hops),
    ("fig7 (config overhead)", bench_fig7_config_overhead),
    ("fig9 (DeepSeek-V3 workloads)", bench_fig9_deepseek),
    ("fig11 (area/power model)", bench_area_power),
    ("collectives (chain vs xla)", bench_collectives),
    ("serve (traffic + KV multicast)", bench_serve),
    ("train (bucketed overlap reduce)", bench_train),
    ("roofline (dry-run table)", bench_roofline),
]


def main() -> None:
    print("name,us_per_call,derived")
    failed = []
    for title, mod in BENCHES:
        try:
            for name, us, derived in mod.main():
                print(f"{name},{us:.2f},{derived}")
        except Exception:
            failed.append(title)
            traceback.print_exc()
    if failed:
        print(f"FAILED: {failed}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
