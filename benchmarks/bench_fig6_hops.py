"""Paper Fig. 6 — average hops per destination on an 8×8 mesh,
N_dst ∈ {4, 8, 16, 24, 32, 40, 48, 63} × 128 random destination sets
(1024 points), for unicast / multicast / naive / greedy / TSP chains.

Validation targets (paper §IV-C):
  * naive chain ≫ multicast (redundant paths);
  * greedy ≈ multicast;
  * TSP ≤ multicast at scale; both → ~1 hop/dst at N_dst = 63;
  * unicast converges to the mesh's average Manhattan distance.
"""

from __future__ import annotations

import random
import time

from repro.core.scheduling import (
    SCHEDULERS,
    chain_total_hops,
    multicast_total_hops,
    unicast_total_hops,
)
from repro.core.topology import MeshTopology

TOPO = MeshTopology(8, 8)
GROUPS = (4, 8, 16, 24, 32, 40, 48, 63)
REPEATS = 128


def sweep(repeats: int = REPEATS) -> dict[int, dict[str, float]]:
    rng = random.Random(42)
    out: dict[int, dict[str, float]] = {}
    for n in GROUPS:
        acc = {"unicast": 0.0, "multicast": 0.0, "naive": 0.0,
               "greedy": 0.0, "tsp": 0.0}
        for _ in range(repeats):
            dsts = rng.sample(range(1, 64), n)
            acc["unicast"] += unicast_total_hops(TOPO, dsts, 0) / n
            acc["multicast"] += multicast_total_hops(TOPO, dsts, 0) / n
            for s in ("naive", "greedy", "tsp"):
                order = SCHEDULERS[s](TOPO, dsts, 0)
                acc[s] += chain_total_hops(TOPO, order, 0) / n
        out[n] = {k: v / repeats for k, v in acc.items()}
    return out


def main() -> list[tuple[str, float, str]]:
    t0 = time.perf_counter()
    table = sweep()
    us = (time.perf_counter() - t0) * 1e6 / (len(GROUPS) * REPEATS)

    big = table[63]
    assert table[16]["naive"] > table[16]["multicast"]
    assert table[48]["tsp"] <= table[48]["multicast"] * 1.02
    assert big["tsp"] <= 1.15  # → ~1 hop/dst (paper's theoretical limit)
    assert big["multicast"] <= 1.15

    rows = []
    for n, r in table.items():
        rows.append((
            f"fig6.avg_hops@n{n}", us,
            "uni={unicast:.2f} mc={multicast:.2f} naive={naive:.2f} "
            "greedy={greedy:.2f} tsp={tsp:.2f}".format(**r),
        ))
    rows.append(("fig6.tsp_beats_multicast@48", us,
                 str(table[48]["tsp"] <= table[48]["multicast"])))
    return rows


if __name__ == "__main__":
    for name, us, derived in main():
        print(f"{name},{us:.2f},{derived}")
