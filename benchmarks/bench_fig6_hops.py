"""Paper Fig. 6 — average hops per destination on an 8×8 mesh,
N_dst ∈ {4, 8, 16, 24, 32, 40, 48, 63} × 128 random destination sets
(1024 points), for unicast / multicast / naive / greedy / TSP chains.

Validation targets (paper §IV-C):
  * naive chain ≫ multicast (redundant paths);
  * greedy ≈ multicast;
  * TSP ≤ multicast at scale; both → ~1 hop/dst at N_dst = 63;
  * unicast converges to the mesh's average Manhattan distance.

Beyond the paper — multi-chain Chainwrite completion latency: the same
destination sets scheduled as K partitioned concurrent chains
(``partition_schedule`` + ``multi_chain_latency``). Validation: for
every ≥16-destination set, K≥2 completion latency is *strictly below*
the single-chain schedule, and auto-K is never worse than any fixed K.
"""

from __future__ import annotations

import random
import time

from repro.core.scheduling import (
    SCHEDULERS,
    chain_total_hops,
    multicast_total_hops,
    partition_schedule,
    unicast_total_hops,
)
from repro.core.simulator import (
    chainwrite_latency,
    choose_num_chains,
    multi_chain_latency,
)
from repro.core.topology import MeshTopology

TOPO = MeshTopology(8, 8)
GROUPS = (4, 8, 16, 24, 32, 40, 48, 63)
REPEATS = 128
MC_GROUPS = (16, 24, 32, 48)  # multi-chain latency sweep (>= 16 dsts)
MC_REPEATS = 24
MC_SIZE = 64 * 1024  # Fig. 7's 64 KB working payload


def sweep(repeats: int = REPEATS) -> dict[int, dict[str, float]]:
    rng = random.Random(42)
    out: dict[int, dict[str, float]] = {}
    for n in GROUPS:
        acc = {"unicast": 0.0, "multicast": 0.0, "naive": 0.0,
               "greedy": 0.0, "tsp": 0.0}
        for _ in range(repeats):
            dsts = rng.sample(range(1, 64), n)
            acc["unicast"] += unicast_total_hops(TOPO, dsts, 0) / n
            acc["multicast"] += multicast_total_hops(TOPO, dsts, 0) / n
            for s in ("naive", "greedy", "tsp"):
                order = SCHEDULERS[s](TOPO, dsts, 0)
                acc[s] += chain_total_hops(TOPO, order, 0) / n
        out[n] = {k: v / repeats for k, v in acc.items()}
    return out


def multichain_sweep(
    repeats: int = MC_REPEATS,
) -> dict[int, dict[str, float]]:
    """Completion latency (CC) of K-chain vs single-chain schedules."""
    rng = random.Random(7)
    out: dict[int, dict[str, float]] = {}
    for n in MC_GROUPS:
        acc = {"k1": 0.0, "k2": 0.0, "k3": 0.0, "auto": 0.0, "auto_k": 0.0}
        k2_always_below = True
        for _ in range(repeats):
            dsts = rng.sample(range(1, 64), n)
            single = SCHEDULERS["tsp"](TOPO, dsts, 0)
            lat1 = chainwrite_latency(TOPO, 0, single, MC_SIZE)
            lat_k = {}
            for k in (2, 3):
                chains = partition_schedule(TOPO, dsts, 0, num_chains=k)
                lat_k[k] = multi_chain_latency(TOPO, 0, chains, MC_SIZE)
            auto_k, auto_chains = choose_num_chains(TOPO, 0, dsts, MC_SIZE)
            lat_auto = multi_chain_latency(TOPO, 0, auto_chains, MC_SIZE)
            if lat_k[2] >= lat1:
                k2_always_below = False
            assert lat_auto <= lat1  # K=1 is an auto-K candidate
            acc["k1"] += lat1
            acc["k2"] += lat_k[2]
            acc["k3"] += lat_k[3]
            acc["auto"] += lat_auto
            acc["auto_k"] += auto_k
        out[n] = {key: v / repeats for key, v in acc.items()}
        out[n]["k2_always_below_k1"] = float(k2_always_below)
    return out


def main() -> list[tuple[str, float, str]]:
    t0 = time.perf_counter()
    table = sweep()
    us = (time.perf_counter() - t0) * 1e6 / (len(GROUPS) * REPEATS)

    big = table[63]
    assert table[16]["naive"] > table[16]["multicast"]
    assert table[48]["tsp"] <= table[48]["multicast"] * 1.02
    assert big["tsp"] <= 1.15  # → ~1 hop/dst (paper's theoretical limit)
    assert big["multicast"] <= 1.15

    rows = []
    for n, r in table.items():
        rows.append((
            f"fig6.avg_hops@n{n}", us,
            "uni={unicast:.2f} mc={multicast:.2f} naive={naive:.2f} "
            "greedy={greedy:.2f} tsp={tsp:.2f}".format(**r),
        ))
    rows.append(("fig6.tsp_beats_multicast@48", us,
                 str(table[48]["tsp"] <= table[48]["multicast"])))

    t1 = time.perf_counter()
    mc = multichain_sweep()
    mc_us = (time.perf_counter() - t1) * 1e6 / (len(MC_GROUPS) * MC_REPEATS)
    for n, r in mc.items():
        # K>=2 must beat the single chain on EVERY >=16-dst set.
        assert r["k2_always_below_k1"] == 1.0, (n, r)
        rows.append((
            f"fig6.multichain_latency_cc@n{n}", mc_us,
            "k1={k1:.0f} k2={k2:.0f} k3={k3:.0f} auto={auto:.0f} "
            "(avg auto K={auto_k:.1f}, speedup k2 {sp:.2f}x)".format(
                sp=r["k1"] / r["k2"], **r
            ),
        ))
    return rows


if __name__ == "__main__":
    for name, us, derived in main():
        print(f"{name},{us:.2f},{derived}")
