"""Bucketed, backward-overlapped gradient reduction benchmark on the
DeepSeek configs: modeled overlap timeline vs the executed step.

Three claims, each asserted (BENCH=1 ci.sh runs this):

* **EXACT wire bytes** — the bucketed ``torrent_grad_reduce`` path's
  HLO collective bytes (trip-count-aware parse, 8 virtual devices) must
  equal ``roofline.modeled_train_overlap``'s ``total_wire_bytes`` to
  the byte: the model prices the very same per-bucket
  ``plan_all_reduce`` programs (chunk-aligned padded payloads, the same
  ``resolve_ring_chains`` auto-K) the executor runs. Checked for both
  DeepSeek archs at the f32 wire and for the int8 wire.
* **Modeled overlap wins** — on the FULL (non-smoke) DeepSeek configs
  at production ring size, the overlapped step time
  (``overlap_timeline``: bucket i's reduction starts at
  max(backward-ready_i, NoC-free)) is strictly below the serial step
  time (all comm after backward), with efficiency = hidden/total comm
  in (0, 1].
* **HLO overlap evidence** — the bucketed train step's HLO shows the
  dispatch interleaving: collective -> compute -> collective patterns
  (and any async start/done pairs XLA emits) counted by
  ``hlo_breakdown.overlap_stats``; the bucketed step must interleave
  at least as much as it has buckets.

``main()`` writes ``BENCH_train.json`` at the repo root — measured
step wall time (serial vs bucketed, CPU-portable only as a smoke
number), the modeled timelines, bucket count/bytes, and the HLO
async/interleaving counts — so training perf is tracked across PRs
like the collectives and serving lanes. Run standalone:

    PYTHONPATH=src python -m benchmarks.bench_train
"""

from __future__ import annotations

import json
import os
import subprocess
import sys
import time

ARCHS = ("deepseek-v2-lite-16b", "deepseek-moe-16b")
STEP_ARCH = "deepseek-v2-lite-16b"  # full-step timing twin
L = 8  # virtual devices (smoke execution ring)
BB_SMOKE = 1 << 18  # 256 KiB buckets over the ~1 MB smoke grad tree
TOKENS_SMOKE = 32  # per-device tokens of the smoke step (8*32/8)
FULL_RING = 16  # production "data" axis (launch.mesh single pod)
BB_FULL = 128 << 20
TOKENS_FULL = 65536  # per-device tokens/step at seq 4k

_SNIPPET = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json, tempfile, time
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro import configs as C
from repro.launch import hlo_cost
from repro.launch.hlo_breakdown import overlap_stats
from repro.launch.mesh import make_host_mesh
from repro.launch.train import TrainConfig, Trainer
from repro.models import transformer as T
from repro.parallel.collectives import torrent_grad_reduce

ARCHS = ("deepseek-v2-lite-16b", "deepseek-moe-16b")
STEP_ARCH = "deepseek-v2-lite-16b"
BB = 1 << 18
ITERS = 3

out = {"reduce": {}, "step": {}}
mesh = make_host_mesh(model=1)
batch_specs = {"d": P("data", None)}
dummy = {"d": jnp.zeros((8, 1), jnp.float32)}


def reduce_case(arch, wire):
    # The bucketed DP reduction in isolation: its HLO holds ONLY the
    # chain ppermutes, so the trip-count-aware collective-byte parse is
    # the exact wire of the bucketed path (metrics dict empty -> no
    # psum; params replicated -> no resharding collectives).
    cfg = C.get_smoke_config(arch)
    shapes = jax.eval_shape(lambda: T.model_init(jax.random.PRNGKey(0), cfg))
    red = torrent_grad_reduce(
        lambda p, b: (p, {}), mesh, batch_specs,
        num_chains="auto", wire_dtype=wire, bucket_bytes=BB,
    )
    jitted = jax.jit(lambda p, b: red(p, b)[0])
    ones = jax.tree.map(lambda s: jnp.ones(s.shape, s.dtype), shapes)
    with jax.set_mesh(mesh):
        text = jitted.lower(ones, dummy).compile().as_text()
        if wire is None:
            # exact wire: 8 local all-ones grads -> sum 8 / dp 8 == 1.0
            got = jitted(ones, dummy)
            for leaf in jax.tree.leaves(got):
                np.testing.assert_array_equal(np.asarray(leaf), 1.0)
    cost = hlo_cost.analyze(text)
    return {
        "hlo_bytes": int(cost.coll_bytes),
        "coll": {k: int(v) for k, v in cost.coll.items() if v},
    }


for arch in ARCHS:
    out["reduce"][arch] = reduce_case(arch, None)
out["reduce"][STEP_ARCH + "__int8"] = reduce_case(STEP_ARCH, "int8")


def step_case(bb):
    tc = TrainConfig(
        arch=STEP_ARCH, smoke=True, steps=1, global_batch=8, seq_len=32,
        collectives="torrent", bucket_bytes=bb, loss_chunks=2,
        ckpt_dir=tempfile.mkdtemp(),
    )
    tr = Trainer(tc)
    batch = tr._device_batch(0)
    with jax.set_mesh(tr.mesh):
        compiled = tr.step_fn.lower(
            tr.state["params"], tr.state["opt"], batch
        ).compile()
        text = compiled.as_text()
        p, o, m = compiled(tr.state["params"], tr.state["opt"], batch)
        jax.block_until_ready(m)
        t0 = time.perf_counter()
        for _ in range(ITERS):
            p, o, m = compiled(p, o, batch)
        jax.block_until_ready(m)
        us = (time.perf_counter() - t0) / ITERS * 1e6
    cost = hlo_cost.analyze(text)
    return {
        "us": us,
        "loss": float(m["loss"]),
        "coll": {k: int(v) for k, v in cost.coll.items() if v},
        "overlap_stats": overlap_stats(text),
    }


out["step"]["serial"] = step_case(None)
out["step"]["bucketed"] = step_case(BB)
print(json.dumps(out))
"""


def _modeled_smoke(arch: str, wire: str | None) -> dict:
    """The modeled twin of the subprocess's reduce_case — same leaves,
    same ring, same bucket size, same auto-K resolution."""
    import jax

    from repro import configs as C
    from repro.launch.roofline import modeled_train_overlap
    from repro.models import transformer as T

    cfg = C.get_smoke_config(arch)
    leaves = jax.tree.leaves(
        jax.eval_shape(lambda: T.model_init(jax.random.PRNGKey(0), cfg))
    )
    return modeled_train_overlap(
        leaves, L, TOKENS_SMOKE, bucket_bytes=BB_SMOKE,
        num_chains="auto", wire_dtype=wire,
    )


def _modeled_full(arch: str) -> dict:
    """Production-scale modeled timeline: FULL config leaves on the
    16-ring, where backward compute is long enough that overlapping
    the bucket reductions visibly shortens the modeled step."""
    import jax

    from repro import configs as C
    from repro.launch.roofline import modeled_train_overlap
    from repro.models import transformer as T

    cfg = C.get_config(arch)
    leaves = jax.tree.leaves(
        jax.eval_shape(lambda: T.model_init(jax.random.PRNGKey(0), cfg))
    )
    m = modeled_train_overlap(
        leaves, FULL_RING, TOKENS_FULL, bucket_bytes=BB_FULL,
        num_chains="auto",
    )
    # keep the JSON tractable: summarize the (many) bucket records
    buckets = m.pop("buckets")
    m["num_buckets"] = len(buckets)
    m["bucket_bytes"] = BB_FULL
    m["tokens_per_device"] = TOKENS_FULL
    m["ring"] = FULL_RING
    m.pop("timeline", None)
    return m


def main() -> list[tuple[str, float, str]]:
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["PYTHONPATH"] = (
        os.path.join(repo, "src") + os.pathsep + env.get("PYTHONPATH", "")
    )
    t0 = time.perf_counter()
    proc = subprocess.run(
        [sys.executable, "-c", _SNIPPET], capture_output=True, text=True,
        env=env, timeout=1800,
    )
    if proc.returncode != 0:
        raise RuntimeError(proc.stderr[-2000:])
    sub = json.loads(proc.stdout.strip().splitlines()[-1])

    rows: list[tuple[str, float, str]] = []
    metrics: dict = {"reduce": {}, "step": sub["step"], "modeled_full": {}}

    # -- EXACT: modeled wire bytes == the bucketed path's HLO bytes ----
    for key, wire in [(a, None) for a in ARCHS] + [
        (STEP_ARCH + "__int8", "int8")
    ]:
        arch = key.split("__")[0]
        m = _modeled_smoke(arch, wire)
        hlo = sub["reduce"][key]
        assert m["total_wire_bytes"] == hlo["hlo_bytes"], (key, m, hlo)
        metrics["reduce"][key] = {
            "hlo_bytes": hlo["hlo_bytes"],
            "modeled_bytes": m["total_wire_bytes"],
            "num_buckets": len(m["buckets"]),
            "buckets": m["buckets"],
        }
        rows.append((
            f"train.reduce_exact.{key}", 0.0,
            f"wire_bytes={hlo['hlo_bytes']} buckets={len(m['buckets'])}",
        ))

    # -- modeled overlap beats modeled serial on the full configs ------
    for arch in ARCHS:
        m = _modeled_full(arch)
        assert m["overlap_cc"] < m["serial_cc"], (arch, m)
        assert 0.0 < m["efficiency"] <= 1.0, (arch, m)
        assert m["num_buckets"] > 1, (arch, m)
        metrics["modeled_full"][arch] = m
        rows.append((
            f"train.modeled_overlap.{arch}", float(m["overlap_cc"]),
            f"serial_cc={m['serial_cc']} eff={m['efficiency']:.3f} "
            f"buckets={m['num_buckets']}",
        ))

    # -- HLO overlap evidence in the executed bucketed step ------------
    ov = sub["step"]["bucketed"]["overlap_stats"]
    n_buckets = len(metrics["reduce"][STEP_ARCH]["buckets"])
    assert ov["collectives"] > 0, ov
    assert ov["interleavings"] >= n_buckets, (ov, n_buckets)
    for kind in ("serial", "bucketed"):
        s = sub["step"][kind]
        rows.append((
            f"train.step_{kind}", s["us"],
            f"interleavings={s['overlap_stats']['interleavings']} "
            f"async_pairs={s['overlap_stats']['async_done']}",
        ))
    # both steps train: finite loss from the same data pipeline
    import math

    assert math.isfinite(sub["step"]["serial"]["loss"])
    assert math.isfinite(sub["step"]["bucketed"]["loss"])

    with open(os.path.join(repo, "BENCH_train.json"), "w") as f:
        json.dump(metrics, f, indent=2, sort_keys=True)
        f.write("\n")
    rows.append((
        "train.subprocess_s", (time.perf_counter() - t0) * 1e6,
        "8 virtual devices",
    ))
    return rows


if __name__ == "__main__":
    for name, us, derived in main():
        print(f"{name},{us:.2f},{derived}")
