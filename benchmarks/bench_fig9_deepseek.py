"""Paper §IV-E / Fig. 9–10 — DeepSeek-V3 self-attention data-movement
workloads on the 3×3-cluster FPGA SoC (Table II), Torrent vs XDMA.

Workloads (Table II): shape, src/dst blocked layouts, multicast flag.
The prefill workloads multicast to all 8 other clusters; the decode
QKT/SV workloads are single-destination layout transforms.

Model (documented; calibrated to the paper's system):
  * XDMA baseline — software P2MP: one sequential P2P copy per
    destination, no replication (Torrent's Frontend is *built on*
    XDMA, so both do ND-affine layout transforms on the fly; the
    speedup is pure Chainwrite, paper: "up to 7.88×").
  * Torrent — one Chainwrite stream through the scheduled chain; the
    stream duplicator forwards while the local DSE writes, so all
    destinations are served by a single source read.

The relayout itself is executed for real through the Pallas kernel
(interpret mode on CPU) and verified against the oracle, so the
"derived" column also certifies correctness of the moved bytes.
"""

from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.scheduling import SCHEDULERS
from repro.core.simulator import (
    DEFAULT_PARAMS,
    chainwrite_latency,
    p2p_latency,
    unicast_latency,
)
from repro.core.topology import MeshTopology
from repro.kernels.relayout import ops as relayout_ops

TOPO = MeshTopology(3, 3)  # the paper's 9-cluster FPGA SoC
ALL_DSTS = list(range(1, 9))


@dataclasses.dataclass(frozen=True)
class Workload:
    name: str
    rows: int
    cols: int
    src_layout: str
    dst_layout: str
    multicast: bool


WORKLOADS = [
    Workload("P1:QKT_Single_Head", 2048, 192, "MNM16N8", "MNM8N8", True),
    Workload("P2:SV_Single_Head", 2048, 128, "MNM16N8", "MNM8N8", True),
    Workload("P3:KV_Matrix_MLA_Recovery", 2048, 512, "MNM16N8", "MNM16N8", True),
    Workload("D1:QKT_Single_Head", 4096, 192, "MNM16N8", "MNM64N16", False),
    Workload("D2:SV_Single_Head", 4096, 128, "MNM16N8", "MNM64N16", False),
    Workload("D3:KV_Matrix_MLA_Recovery", 4096, 512, "MNM16N8", "MNM16N8", True),
]

BYTES_PER_EL = 1  # the paper's GeMM is 8-bit


def xdma_latency(w: Workload) -> int:
    """Baseline: per-destination sequential P2P copies (layout
    transform is on-the-fly in XDMA's DSE, same as Torrent's)."""
    size = w.rows * w.cols * BYTES_PER_EL
    dsts = ALL_DSTS if w.multicast else [1]
    return unicast_latency(TOPO, 0, dsts, size)


def torrent_latency(w: Workload) -> int:
    """Chainwrite with on-the-fly DSE relayout (transform is free)."""
    size = w.rows * w.cols * BYTES_PER_EL
    dsts = ALL_DSTS if w.multicast else [1]
    if len(dsts) == 1:
        return p2p_latency(TOPO, 0, 1, size)
    order = SCHEDULERS["tsp"](TOPO, dsts, 0)
    return chainwrite_latency(TOPO, 0, order, size)


def run_relayout(w: Workload) -> bool:
    """Execute the actual layout transform through the Pallas kernel."""
    shape = (w.rows, w.cols)
    src = relayout_ops.parse_layout(w.src_layout)
    dst = relayout_ops.parse_layout(w.dst_layout)
    dense = jnp.arange(w.rows * w.cols, dtype=jnp.int8).reshape(shape)
    x = relayout_ops.dense_to_blocked(dense, src)
    got = relayout_ops.relayout(x, shape, src, dst)
    want = relayout_ops.relayout_ref(x, shape, src, dst)
    return bool((np.asarray(got) == np.asarray(want)).all())


def main() -> list[tuple[str, float, str]]:
    rows = []
    speedups = []
    for w in WORKLOADS:
        t0 = time.perf_counter()
        ok = run_relayout(w)
        us = (time.perf_counter() - t0) * 1e6
        base = xdma_latency(w)
        torr = torrent_latency(w)
        s = base / torr
        speedups.append(s)
        rows.append((
            f"fig9.{w.name}", us,
            f"xdma={base}cc torrent={torr}cc speedup={s:.2f}x "
            f"relayout_ok={ok} ndst={8 if w.multicast else 1}",
        ))
        assert ok
    best = max(speedups)
    # paper: up to 7.88x over the XDMA unicast baseline (8 destinations)
    assert 6.5 <= best <= 8.0, best
    # single-destination decode transforms see no chainwrite win
    singles = [s for w, s in zip(WORKLOADS, speedups) if not w.multicast]
    assert all(0.9 <= s <= 1.1 for s in singles), singles
    rows.append(("fig9.best_speedup", 0.0, f"{best:.2f}x (paper: 7.88x)"))
    return rows


if __name__ == "__main__":
    for name, us, derived in main():
        print(f"{name},{us:.2f},{derived}")
