"""Paper §IV-F / Fig. 11 — ASIC area & power, as an analytic model.

We cannot synthesize RTL here; this module encodes the paper's measured
constants and reproduces the derived claims from them (clearly labeled
as a calibrated model, DESIGN.md §2):

  * 4-cluster SoC total area 2.8 mm²; CVA6 5.9 %, cluster-0 23.3 %,
    global SRAM 16.6 %;
  * Torrent = 5.3 % of a cluster (~1/5 of the GeMM accelerator);
  * Torrent attached to global memory: 0.6 % of SoC;
  * area vs N_dst_max slope: 207 µm² per destination
    (≈ 0.65 % additional Torrent area per destination);
  * total Torrent share ≈ 1.2 % of SoC area, 2.3 % of system power;
  * initiator-cluster power 175.7 mW; energy 4.68 pJ/B/hop.

The model's *checkable* content: the per-destination slope is O(1)
(Chainwrite's area does not scale with the NoC), total shares stay
within the paper's reported envelope, and middle-of-chain followers
burn more power than the tail (they forward AND write).
"""

from __future__ import annotations

import time

# --- calibrated constants (paper §IV-F) -------------------------------------
SOC_AREA_UM2 = 2.8e6  # 2.8 mm²
TORRENT_BASE_UM2 = 0.006 * SOC_AREA_UM2  # global-memory Torrent: 0.6 %
AREA_PER_DST_UM2 = 207.0
TORRENT_SOC_SHARE = 0.012
POWER_SHARE = 0.023
INITIATOR_POWER_MW = 175.7
ENERGY_PJ_PER_B_HOP = 4.68
# follower power split: middle forwards + writes; tail only writes.
MID_FOLLOWER_FWD_FRACTION = 0.35


def torrent_area(n_dst_max: int) -> float:
    """Initiator Torrent area as a function of N_dst,max (Fig. 11g)."""
    return TORRENT_BASE_UM2 + AREA_PER_DST_UM2 * n_dst_max


def chain_energy_pj(size_bytes: int, total_hops: int) -> float:
    return ENERGY_PJ_PER_B_HOP * size_bytes * total_hops


def follower_power_mw(position: str) -> float:
    """Middle followers forward data to the next hop (paper Fig. 11e/f)."""
    base = INITIATOR_POWER_MW * 0.8
    if position == "middle":
        return base * (1 + MID_FOLLOWER_FWD_FRACTION)
    return base


def main() -> list[tuple[str, float, str]]:
    t0 = time.perf_counter()
    a4, a16, a64 = torrent_area(4), torrent_area(16), torrent_area(64)
    # O(1)-ish scaling claim: slope is constant, independent of N
    slope_small = (a16 - a4) / 12
    slope_large = (a64 - a16) / 48
    assert slope_small == slope_large == AREA_PER_DST_UM2
    # +64 destinations adds < 1 % of the SoC
    assert (a64 - a4) / SOC_AREA_UM2 < 0.01
    assert follower_power_mw("middle") > follower_power_mw("tail")
    # energy model: 64 KB through a 8-dst snake chain (8 hops)
    e = chain_energy_pj(64 * 1024, 8)
    us = (time.perf_counter() - t0) * 1e6
    return [
        ("fig11.area_per_dst_um2", us, f"{AREA_PER_DST_UM2}"),
        ("fig11.torrent_area@dst4_um2", us, f"{a4:.0f}"),
        ("fig11.torrent_area@dst64_um2", us, f"{a64:.0f}"),
        ("fig11.soc_area_share", us, f"{TORRENT_SOC_SHARE:.3f}"),
        ("fig11.power_share", us, f"{POWER_SHARE:.3f}"),
        ("fig11.energy_64KB_8hop_uJ", us, f"{e/1e6:.2f}"),
        ("fig11.mid_follower_gt_tail", us, "True"),
    ]


if __name__ == "__main__":
    for name, us, derived in main():
        print(f"{name},{us:.2f},{derived}")
