"""Production-traffic serving benchmark: continuous batching under
Poisson and heavy-tailed arrivals with KV-block multicast prefix reuse.

The serving twin of ``bench_collectives``: where that file pins the
collective wire bytes against the ChainProgram IR, this one pins the
*serving* data plane —

* **KV broadcast self-consistency** — the bytes the ``MultiChainTask``
  actually delivered to the replica set must equal
  ``program_wire_bytes(plan_broadcast(...), dense_kv_bytes)`` EXACTLY,
  and every replica's paged blocks must be bit-identical to the
  ``relayout_ref`` numpy oracle of the prefilling replica's dense rows.
* **Traffic stats** — two arrival processes (Poisson and Pareto
  heavy-tail) drive the continuous-batching loop; we report p50/p99
  request latency (in decode ticks, the simulator's time base), the
  prefix-cache hit rate (asserted against the workload's ground-truth
  share of prefix-bearing prompts), and the multicast-vs-unicast
  KV-refresh cycle ratio from the calibrated latency model.

``main()`` returns the harness rows and writes ``BENCH_serve.json`` at
the repo root so serving gets the same cross-PR perf trajectory the
collectives have. Run standalone:

    PYTHONPATH=src python -m benchmarks.bench_serve
"""

from __future__ import annotations

import json
import os
import time

import numpy as np

PAGE = 8
PREFIX_LENS = (16, 24)  # registered system prompts (multiples of PAGE)
SUFFIX_LENS = (4, 8)  # few distinct prompt lengths -> few prefill traces
N_REQUESTS = 12
MAX_NEW = 8
HIT_SHARE = 0.75  # fraction of prompts that start with a registered prefix


def _workload(kind: str, rng: np.random.Generator, vocab: int):
    """(prompt, arrival_tick, is_hit) triples under the named process."""
    if kind == "poisson":
        gaps = rng.exponential(scale=2.0, size=N_REQUESTS)
    elif kind == "heavy_tail":
        # Pareto(a=1.5): infinite-variance inter-arrivals — bursts and
        # long silences, the p99-stressing regime.
        gaps = rng.pareto(1.5, size=N_REQUESTS) * 1.5
    else:
        raise ValueError(kind)
    arrivals = np.floor(np.cumsum(gaps)).astype(int)
    prefixes = [
        rng.integers(0, vocab, size=n).astype(np.int32) for n in PREFIX_LENS
    ]
    reqs = []
    for i in range(N_REQUESTS):
        hit = rng.random() < HIT_SHARE
        suffix = rng.integers(
            0, vocab, size=int(rng.choice(SUFFIX_LENS))
        ).astype(np.int32)
        if hit:
            prefix = prefixes[int(rng.integers(len(prefixes)))]
            prompt = np.concatenate([prefix, suffix])
        else:
            # same length population as the shortest hit prompts, but
            # guaranteed not to match any registered prefix: pick a
            # first token none of the prefixes start with
            prompt = rng.integers(0, vocab, size=PREFIX_LENS[0] + 4).astype(
                np.int32
            )
            starts = {int(p[0]) for p in prefixes}
            prompt[0] = next(t for t in range(vocab) if t not in starts)
        reqs.append((prompt, int(arrivals[i]), hit))
    return prefixes, reqs


def _run_workload(kind: str) -> dict:
    from repro.core.program import plan_broadcast, program_wire_bytes
    from repro.launch.paged_kv import paged_ref
    from repro.launch.serve import ServeConfig, Server

    rng = np.random.default_rng({"poisson": 11, "heavy_tail": 23}[kind])
    sc = ServeConfig(
        arch="yi-6b", smoke=True, batch=4,
        prompt_len=max(PREFIX_LENS) + max(SUFFIX_LENS),
        max_seq=64, replicas=4, page_size=PAGE,
    )
    server = Server(sc)
    prefixes, spec = _workload(kind, rng, server.cfg.vocab_size)
    entries = [server.register_prefix(p) for p in prefixes]

    reqs = [
        server.submit(prompt, MAX_NEW, arrival=arr)
        for prompt, arr, _ in spec
    ]
    t0 = time.perf_counter()
    out = server.run(reqs)
    wall_us = (time.perf_counter() - t0) * 1e6

    # -- self-consistency: every request served, full length, hit flags
    assert out["served"] == N_REQUESTS, out
    assert all(r.done and len(r.out) == MAX_NEW for r in reqs)
    truth_hits = sum(1 for _, _, h in spec if h)
    got_hits = sum(1 for r in reqs if r.prefix_hit)
    assert got_hits == truth_hits, (got_hits, truth_hits)
    assert out["prefix_hit_rate"] == truth_hits / N_REQUESTS
    assert out["latency_ticks_p99"] >= out["latency_ticks_p50"] >= 0

    # -- KV broadcast: modeled == delivered, replicas bit-exact
    chains = tuple(tuple(c) for c in server.plan.chains)
    program = plan_broadcast(server.topo.num_nodes, 0, chains)
    kv_wire = 0
    for e in entries:
        rec = e.broadcast
        modeled = program_wire_bytes(program, int(e.dense.nbytes))
        assert rec["wire_bytes"] == rec["delivered_bytes"] == modeled, rec
        assert rec["speedup_vs_unicast"] >= 1.0, rec
        oracle = paged_ref(e.dense, e.page)
        assert sorted(e.replica_paged) == sorted([0] + list(server.plan.survivors))
        for d, blocks in e.replica_paged.items():
            np.testing.assert_array_equal(
                blocks.view(np.uint8), oracle.view(np.uint8)
            )
        kv_wire += rec["wire_bytes"]

    return {
        "wall_us": wall_us,
        "requests": N_REQUESTS,
        "generated_tokens": out["generated_tokens"],
        "decode_steps": out["decode_steps"],
        "latency_ticks_p50": out["latency_ticks_p50"],
        "latency_ticks_p99": out["latency_ticks_p99"],
        "prefix_hit_rate": out["prefix_hit_rate"],
        "kv_wire_bytes": kv_wire,
        "kv_multicast_cycles": sum(e.broadcast["cycles"] for e in entries),
        "kv_unicast_cycles": sum(
            e.broadcast["unicast_cycles"] for e in entries
        ),
        "kv_speedup_vs_unicast": min(
            e.broadcast["speedup_vs_unicast"] for e in entries
        ),
        "weight_refresh_bytes": out["weight_multicast"]["bytes"],
    }


def main() -> list[tuple[str, float, str]]:
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    rows: list[tuple[str, float, str]] = []
    metrics: dict[str, dict] = {}
    for kind in ("poisson", "heavy_tail"):
        m = _run_workload(kind)
        metrics[kind] = m
        rows.append((
            f"serve.{kind}", m["wall_us"],
            f"p50={m['latency_ticks_p50']:.0f}t "
            f"p99={m['latency_ticks_p99']:.0f}t "
            f"hit_rate={m['prefix_hit_rate']:.2f} "
            f"kv_wire_bytes={m['kv_wire_bytes']}",
        ))
    with open(os.path.join(repo, "BENCH_serve.json"), "w") as f:
        json.dump(metrics, f, indent=2, sort_keys=True)
        f.write("\n")
    return rows


if __name__ == "__main__":
    for name, us, derived in main():
        print(f"{name},{us:.2f},{derived}")
