"""Chainwrite vs XLA-native collectives on the TPU-analogue mesh:
wall-clock on 8 virtual CPU devices (subprocess) + HLO wire bytes.

This is the JAX-side counterpart of Fig. 5: the "network-layer
multicast" baseline is XLA's built-in all-reduce/all-gather; "Torrent"
is the scheduled ppermute chain. On CPU the wall-clock ratio is not
meaningful for TPU — the *collective wire bytes* (trip-count-aware HLO
parse) are the portable metric and must match the ChainProgram IR's
``program_wire_bytes`` prediction for every collective × K:

* all-reduce — ``rotation`` must match the (S+K-2)-payload/device
  prediction and ``rs_ag`` (fused per-ring reduce-scatter/all-gather +
  cross-ring shard rotation) must match (2·(S-1)+(K-1))/S·payload and
  land strictly below its rotation twin;
* reduce-scatter / all-gather / all-to-all — the K-ring schedules must
  match the single ring's bytes exactly (the planner redistributes
  hops, not bytes);
* int8-wire all-reduce (``ar_int8_k{1,2,4}``) — the same rs_ag
  schedules with ``wire_dtype="int8"``: int8 frames plus one f32 scale
  per hop (~4x fewer payload bytes), matched exactly by the IR's
  int8-aware ``Step.bytes`` model;
* multi-chain broadcast (K=2) is timed against the single chain.

Besides the CSV rows, ``main()`` writes ``BENCH_collectives.json`` at
the repo root — per-benchmark ``{us, hlo_bytes, modeled_bytes,
modeled_latency_cc}`` from the very same IR the executors run — so the
perf trajectory is tracked across PRs. Model-only ``recovery_k{K}_f{N}``
entries (no HLO twin) record the ``plan_recovery`` program's wire bytes
and ``chain_recovery_latency`` completion for K ∈ {2, 4} partitions
with one and two concurrent failures, asserted self-consistent against
the failure-free model.

Model-only ``plan_L{64,256,1024}`` entries track the symbolic-addressing
scaling pin: cold plan+validate wall time and pickled program bytes for
the K=8 all-to-all at each ring length, plus ``plan_hlo_const_bytes`` —
the executor's compiled-HLO literal-constant footprint measured at L=8
and L=16 virtual devices and hard-asserted EQUAL (addresses are
computed in-kernel from the device index, so the constant footprint is
ring-length-independent).
"""

from __future__ import annotations

import json
import os
import pickle
import subprocess
import sys
import time

L = 8
N = 1 << 18  # 256k f32 per device = 1 MiB
RINGS = {
    1: ((0, 1, 2, 3, 4, 5, 6, 7),),
    2: ((0, 1, 2, 3), (4, 5, 6, 7)),
    4: ((0, 1), (2, 3), (4, 5), (6, 7)),
}
BCAST_CHAINS = ((1, 2, 3), (4, 5, 6, 7))

_SNIPPET = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import time
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P
from repro.core import chainwrite as cw
from repro.launch import hlo_cost

L = 8
mesh = jax.make_mesh((L,), ("x",), axis_types=(jax.sharding.AxisType.Auto,))
N = 1 << 18  # 256k f32 per device = 1 MiB

def time_fn(f, *args):
    f(*args)  # compile+warm
    t0 = time.perf_counter()
    for _ in range(5):
        out = f(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / 5 * 1e6

x = jnp.ones((L, N), jnp.float32)

def chain_ar(x):
    return cw.chain_all_reduce(x[0], "x")[None]

def xla_ar(x):
    return jax.lax.psum(x[0], "x")[None]

RINGS = {2: [(0,1,2,3), (4,5,6,7)], 4: [(0,1), (2,3), (4,5), (6,7)]}

def multi_ar(k, algo):
    def fn(x):
        return cw.multi_chain_all_reduce(x[0], "x", RINGS[k], algo=algo)[None]
    return fn

def multi_rs(k):
    def fn(x):
        orders = RINGS[k] if k > 1 else None
        v = x[0].reshape(L, N // L)
        out = (cw.multi_chain_reduce_scatter(v, "x", orders) if k > 1
               else cw.chain_reduce_scatter(v, "x"))
        return jnp.tile(out, L)[None]
    return fn

def multi_ag(k):
    def fn(x):
        v = x[0, : N // L]
        out = (cw.multi_chain_all_gather(v, "x", RINGS[k], tiled=True) if k > 1
               else cw.chain_all_gather(v, "x", tiled=True))
        return out[None]
    return fn

def multi_a2a(k):
    def fn(x):
        v = x[0].reshape(L, N // L)
        out = (cw.multi_chain_all_to_all(v, "x", RINGS[k]) if k > 1
               else cw.chain_all_to_all(v, "x"))
        return out.reshape(N)[None]
    return fn

def int8_ar(k):
    def fn(x):
        out = (cw.multi_chain_all_reduce(
                   x[0], "x", RINGS[k], algo="rs_ag", wire_dtype="int8")
               if k > 1
               else cw.chain_all_reduce(x[0], "x", wire_dtype="int8"))
        return out[None]
    return fn

results = {}
cases = [
    ("chain_all_reduce", chain_ar),
    ("multi_chain_all_reduce_k2_rotation", multi_ar(2, "rotation")),
    ("multi_chain_all_reduce_k2_rs_ag", multi_ar(2, "rs_ag")),
    ("multi_chain_all_reduce_k4_rotation", multi_ar(4, "rotation")),
    ("multi_chain_all_reduce_k4_rs_ag", multi_ar(4, "rs_ag")),
    ("xla_all_reduce", xla_ar),
]
for k in (1, 2, 4):
    cases += [
        (f"multi_chain_reduce_scatter_k{k}", multi_rs(k)),
        (f"multi_chain_all_gather_k{k}", multi_ag(k)),
        (f"multi_chain_all_to_all_k{k}", multi_a2a(k)),
    ]
for k in (1, 2, 4):
    # name deliberately avoids the "all_reduce" substring: the int8 wire
    # is lossy, so the exact sums-to-L check below must not apply.
    cases.append((f"ar_int8_k{k}", int8_ar(k)))
for name, fn in cases:
    sm = jax.shard_map(fn, mesh=mesh, in_specs=P("x"), out_specs=P("x"))
    jitted = jax.jit(sm)
    us = time_fn(jitted, x)
    cost = hlo_cost.analyze(jitted.lower(x).compile().as_text())
    results[name] = (us, cost.coll_bytes)
    if "all_reduce" in name:  # correctness: every element sums to L
        np.testing.assert_allclose(
            np.asarray(jitted(x))[0], np.full((N,), L, np.float32))
    elif name.startswith("ar_int8"):
        # lossy wire: per-hop requantization bounds the error relative
        # to the tensor max, not element-wise
        got = np.asarray(jitted(x))[0]
        err = float(np.max(np.abs(got - L)) / L)
        assert err < 0.08, (name, err)

payload = N * 4
ring_pred = 2 * (L - 1) / L * payload
chain_bytes = results["chain_all_reduce"][1]
assert 0.9 * ring_pred <= chain_bytes <= 1.35 * ring_pred, (chain_bytes, ring_pred)
# Rotation trades wire bytes for chain length: (S-1)+(K-1) full-payload
# sends/device. RS+AG keeps the short rings but moves 1/S shards:
# (2*(S-1)+(K-1))/S payloads/device — strictly below its rotation twin.
for K in (2, 4):
    S = L // K
    rot_pred = (S + K - 2) * payload
    rot_bytes = results[f"multi_chain_all_reduce_k{K}_rotation"][1]
    assert 0.9 * rot_pred <= rot_bytes <= 1.35 * rot_pred, (K, rot_bytes, rot_pred)
    rsag_pred = (2 * (S - 1) + (K - 1)) / S * payload
    rsag_bytes = results[f"multi_chain_all_reduce_k{K}_rs_ag"][1]
    assert 0.9 * rsag_pred <= rsag_bytes <= 1.35 * rsag_pred, (K, rsag_bytes, rsag_pred)
    assert rsag_bytes < rot_bytes, (K, rsag_bytes, rot_bytes)

# int8 wire: each rs_ag step ships its f32 shard as int8 plus one f32
# scale, so per-device bytes = steps * (shard_elems + 4) exactly —
# ~4x below the f32 twin (which ships steps * shard_elems * 4).
SHARDS = {1: N // 8, 2: N // 4, 4: N // 2}
for K in (1, 2, 4):
    S = L // K
    steps = 2 * (S - 1) + (K - 1)
    pred = steps * (SHARDS[K] + 4)
    got = results[f"ar_int8_k{K}"][1]
    assert got == pred, (K, got, pred)
    f32_twin = results["chain_all_reduce" if K == 1
                       else f"multi_chain_all_reduce_k{K}_rs_ag"][1]
    assert got < f32_twin / 3.5, (K, got, f32_twin)

# The K-ring reduce-scatter / all-gather / all-to-all redistribute hops,
# not bytes: every K must land on the single ring's byte count.
ring_bytes = {
    "multi_chain_reduce_scatter": (L - 1) / L * payload,
    "multi_chain_all_gather": (L - 1) / L * payload,
    "multi_chain_all_to_all": (L - 1) * payload,
}
for stem, pred in ring_bytes.items():
    for k in (1, 2, 4):
        got = results[f"{stem}_k{k}"][1]
        assert 0.9 * pred <= got <= 1.35 * pred, (stem, k, got, pred)

# P2MP broadcast: single chain vs 2 partitioned chains. The K=2 split
# buys LATENCY (10 -> 7 pipeline slots: the longest chain halves), not
# bytes — the head's per-slot fan-out costs a second ppermute, so HLO
# wire bytes RISE from 10 to 7x2 frame-payloads (both recorded in
# BENCH_collectives.json and matched exactly by pipelined_wire_bytes).
def chain_bc(x):
    return cw.chain_broadcast(x[0], "x", tuple(range(8)), num_frames=4)[None]

def multi_bc(x):
    return cw.multi_chain_broadcast(
        x[0], "x", 0, [(1, 2, 3), (4, 5, 6, 7)], num_frames=4)[None]

for name, fn in [("chain_broadcast", chain_bc), ("multi_chain_broadcast_k2", multi_bc)]:
    sm = jax.shard_map(fn, mesh=mesh, in_specs=P("x"), out_specs=P("x"))
    jitted = jax.jit(sm)
    us = time_fn(jitted, x)
    cost = hlo_cost.analyze(jitted.lower(x).compile().as_text())
    results[name] = (us, cost.coll_bytes)
    np.testing.assert_allclose(np.asarray(jitted(x)), np.ones((L, N), np.float32))

for name, (us, cb) in results.items():
    print(f"{name},{us:.1f},{cb:.0f}")
"""


def _modeled(name: str) -> dict:
    """Modeled bytes/latency for a benchmark entry from the very same
    ChainProgram the subprocess executed (host-side: no jax needed)."""
    from repro.core import program as prg
    from repro.core.simulator import program_latency
    from repro.core.topology import MeshTopology

    topo = MeshTopology(L, 1)  # the snake-ring analogue topology
    payload = N * 4
    prog = None
    size = payload
    if name in ("chain_broadcast", "multi_chain_broadcast_k2"):
        chains = (
            (tuple(range(1, L)),) if name == "chain_broadcast" else BCAST_CHAINS
        )
        prog = prg.plan_broadcast(L, 0, chains)
        return {
            # the bench runs the frame-pipelined path (num_frames=4)
            "modeled_bytes": prg.pipelined_wire_bytes(prog, payload, 4),
            "modeled_latency_cc": program_latency(topo, 0, prog, payload),
        }
    if name.startswith("ar_int8_k"):
        k = int(name[len("ar_int8_k"):])
        prog = prg.plan_all_reduce(L, RINGS[k], "rs_ag", wire_dtype="int8")
    elif name.startswith("multi_chain_all_reduce") or name == "chain_all_reduce":
        if name == "chain_all_reduce":
            k, algo = 1, "rs_ag"
        else:
            parts = name.split("_k")[1].split("_", 1)
            k, algo = int(parts[0]), parts[1]
        prog = prg.plan_all_reduce(L, RINGS[k], "rs_ag" if k == 1 else algo)
    elif name.startswith("multi_chain_reduce_scatter"):
        prog = prg.plan_reduce_scatter(L, RINGS[int(name[-1])])
    elif name.startswith("multi_chain_all_gather"):
        prog = prg.plan_all_gather(L, RINGS[int(name[-1])])
        size = payload // L  # per-device input is one shard
    elif name.startswith("multi_chain_all_to_all"):
        prog = prg.plan_all_to_all(L, RINGS[int(name[-1])])
    if prog is None:
        return {}
    return {
        "modeled_bytes": prog.wire_bytes(size),
        "modeled_latency_cc": program_latency(topo, 0, prog, size),
    }


# Executor HLO constant footprint at a given virtual-device count: the
# K=2 all-to-all (the heaviest table user) with a fixed 32-element
# chunk, compiled and parsed for literal ``constant`` bytes.
_CONST_SNIPPET = r"""
import os, sys
L = int(sys.argv[1])
os.environ["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={L}"
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.core import chainwrite as cw
from repro.launch import hlo_cost

mesh = jax.make_mesh((L,), ("x",), axis_types=(jax.sharding.AxisType.Auto,))
rings = (tuple(range(L // 2)), tuple(range(L // 2, L)))
C = 32  # elems per chunk, fixed across L

def a2a(x):
    v = x[0].reshape(L, C)
    return cw.multi_chain_all_to_all(v, "x", rings).reshape(L * C)[None]

x = jnp.ones((L, L * C), jnp.float32)
jitted = jax.jit(jax.shard_map(a2a, mesh=mesh, in_specs=P("x"), out_specs=P("x")))
print(hlo_cost.constant_bytes(jitted.lower(x).compile().as_text()))
"""


def _plan_scaling_metrics(env: dict) -> dict[str, dict]:
    """Model-only symbolic-addressing scaling entries: cold
    plan+validate wall time and program pickle size for the K=8
    all-to-all at L ∈ {64, 256, 1024} (host-side, no jax), plus the
    executor's HLO constant bytes at L ∈ {8, 16} virtual devices —
    hard-asserted ring-length-independent."""
    from repro.core import program as prg

    out: dict[str, dict] = {}
    for ring_len in (64, 256, 1024):
        K = 8
        S = ring_len // K
        rings = tuple(
            tuple(range(i * S, (i + 1) * S)) for i in range(K)
        )
        prg.clear_planner_caches()
        t0 = time.perf_counter()
        prog = prg.plan_all_to_all(ring_len, rings)
        plan_s = time.perf_counter() - t0
        out[f"plan_L{ring_len}"] = {
            "plan_validate_s": plan_s,
            "program_bytes": len(pickle.dumps(prog)),
            "steps": len(prog.steps),
        }
        # "seconds, not minutes" is the acceptance bar; 30s is generous
        assert plan_s < 30.0, (ring_len, plan_s)
    const: dict[str, int] = {}
    for dev in (8, 16):
        proc = subprocess.run(
            [sys.executable, "-c", _CONST_SNIPPET, str(dev)],
            capture_output=True, text=True, env=env, timeout=600,
        )
        if proc.returncode != 0:
            raise RuntimeError(proc.stderr[-2000:])
        const[f"L{dev}"] = int(proc.stdout.strip())
    # THE pin: symbolic addressing keeps the executor's embedded-table
    # footprint independent of ring length (a dense-table regression
    # would scale these O(L^2) per step).
    assert const["L8"] == const["L16"], const
    out["plan_hlo_const_bytes"] = const
    return out


def _recovery_metrics() -> dict[str, dict]:
    """Modeled recovery cost (no HLO twin — recovery never executes as
    one SPMD collective): for K ∈ {2, 4} partitions of 16 destinations
    on the 8x8 NoC, one and two concurrent mid-chain failures, the
    ``plan_recovery`` program's wire bytes and the
    ``chain_recovery_latency`` completion — asserted self-consistent
    against the failure-free model (BENCH=1 ci.sh runs this)."""
    from repro.core import program as prg
    from repro.core.scheduling import partition_schedule
    from repro.core.simulator import (
        DEFAULT_PARAMS,
        chain_recovery_latency,
        multi_chain_latency,
    )
    from repro.core.topology import MeshTopology

    topo = MeshTopology(8, 8)
    payload = N * 4
    out: dict[str, dict] = {}
    for k in (2, 4):
        chains = partition_schedule(topo, list(range(1, 17)), 0, num_chains=k)
        base = multi_chain_latency(topo, 0, chains, payload)
        mid = [c[len(c) // 2] for c in chains]  # one mid-chain member each
        for nf, failed in (("f1", {mid[0]}), ("f2", {mid[0], mid[1]})):
            program = prg.plan_recovery(topo, 0, chains, frozenset(failed))
            lat = chain_recovery_latency(topo, 0, chains, frozenset(failed), payload)
            entry = {
                "modeled_bytes": program.wire_bytes(payload),
                "modeled_latency_cc": lat,
                "failures": len(failed),
                "num_chains": k,
            }
            out[f"recovery_k{k}_{nf}"] = entry
        # the modeled invariants the JSON record is trusted for:
        f1, f2 = out[f"recovery_k{k}_f1"], out[f"recovery_k{k}_f2"]
        assert f1["modeled_bytes"] > 0, f1
        assert f2["modeled_bytes"] >= f1["modeled_bytes"], (f1, f2)
        for e in (f1, f2):
            # recovery = detection timeout + a real re-send on top of
            # the failure-free completion
            assert e["modeled_latency_cc"] > base + DEFAULT_PARAMS.fail_timeout_cc, (
                e, base)
    return out


def _tiered_metrics() -> dict[str, dict]:
    """Model-only tier-aware planning entries (no HLO twin — the SPMD
    executor is topology-agnostic): on two 2-tier pod topologies, the
    K-swept all-reduce chosen on the weighted link graph vs the
    tier-blind twin (chosen on the uniform mesh of the same shape,
    then priced on the tiered graph), plus the pod-partitioned
    broadcast chains. Self-consistency: the tier-aware plan is never
    slower than the tier-blind one on ANY entry, STRICTLY faster on
    the 4-pod auto all-reduce entry (where one sub-ring per pod — the
    hierarchical schedule — emerges, K=4), and every broadcast chain
    crosses the inter-pod boundary at most once (exactly once for
    every remote-pod chain). BENCH=1 ci.sh runs this."""
    from repro.core.program import tier_crossing_stats
    from repro.core.scheduling import (
        partition_schedule,
        partition_tier_crossings,
    )
    from repro.core.simulator import (
        all_reduce_latency,
        choose_num_chains,
        multi_chain_latency,
        plan_ring_collective,
    )
    from repro.core.topology import MeshTopology, parse_topology_spec

    payload = N * 4
    out: dict[str, dict] = {}
    # the same spec grammar dryrun --topology / train --topology take
    topos = {
        "p4": parse_topology_spec("pods=4x(4x4):interpod_bw=0.25"),
        "p2": parse_topology_spec(
            "pods=2x(4x4):interpod_bw=0.5:interpod_lat=2"),
    }
    for tag, topo in topos.items():
        uniform = MeshTopology(topo.nx, topo.ny, topo.torus)
        dests = list(range(1, topo.num_nodes))
        for mk in (2, 4):
            aware = choose_num_chains(
                topo, 0, dests, payload, max_chains=mk,
                collective="all_reduce", algo="rs_ag", detail=True,
            )
            blind = choose_num_chains(
                uniform, 0, dests, payload, max_chains=mk,
                collective="all_reduce", algo="rs_ag", detail=True,
            )
            blind_cc = all_reduce_latency(
                topo, 0, blind["rings"], payload, algo="rs_ag")
            program = plan_ring_collective(
                "all_reduce", topo.num_nodes, aware["rings"])
            stats = tier_crossing_stats(program, topo)
            out[f"tiered_{tag}_ar_k{mk}"] = {
                "topology": topo.spec(),
                "max_chains": mk,
                "num_chains": aware["num_chains"],
                "modeled_latency_cc": int(aware["latency_cc"]),
                "blind_num_chains": blind["num_chains"],
                "blind_latency_cc": int(blind_cc),
                "modeled_bytes": program.wire_bytes(payload),
                "interpod_crossings": stats["total"],
                "crossing_steps": stats["crossing_steps"],
            }
        # pod-partitioned broadcast: one chain per pod, remote chains
        # entering their pod once and staying there
        k = topo.num_pods
        chains = partition_schedule(topo, dests, 0, num_chains=k)
        blind_chains = partition_schedule(uniform, dests, 0, num_chains=k)
        out[f"tiered_{tag}_bcast_k{k}"] = {
            "topology": topo.spec(),
            "num_chains": k,
            "modeled_latency_cc": int(
                multi_chain_latency(topo, 0, chains, payload)),
            "blind_latency_cc": int(
                multi_chain_latency(topo, 0, blind_chains, payload)),
            "chain_tier_crossings": partition_tier_crossings(
                topo, chains, 0),
        }
    for name, e in out.items():
        assert e["modeled_latency_cc"] <= e["blind_latency_cc"], (name, e)
    # THE hierarchical pin: on the 4-pod topology the weighted planner
    # picks one sub-ring per pod and beats the tier-blind plan
    # STRICTLY on the same links.
    p4 = out["tiered_p4_ar_k4"]
    assert p4["num_chains"] == 4, p4
    assert p4["modeled_latency_cc"] < p4["blind_latency_cc"], p4
    for name, k in (("tiered_p4_bcast_k4", 4), ("tiered_p2_bcast_k2", 2)):
        cr = out[name]["chain_tier_crossings"]
        assert sorted(cr) == [0] + [1] * (k - 1), (name, cr)
    return out


def main() -> list[tuple[str, float, str]]:
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(repo, "src") + os.pathsep + env.get("PYTHONPATH", "")
    t0 = time.perf_counter()
    proc = subprocess.run(
        [sys.executable, "-c", _SNIPPET], capture_output=True, text=True,
        env=env, timeout=1800,
    )
    if proc.returncode != 0:
        raise RuntimeError(proc.stderr[-2000:])
    rows = []
    metrics: dict[str, dict] = {}
    for line in proc.stdout.strip().splitlines():
        name, us, cb = line.split(",")
        rows.append((f"collectives.{name}", float(us), f"wire_bytes={cb}"))
        metrics[name] = {
            "us": float(us), "hlo_bytes": float(cb), **_modeled(name),
        }
    for name, m in metrics.items():
        # The IR's byte model must match the HLO parse EXACTLY — this
        # also keeps the module-level L/N/RINGS constants honest
        # against their copies inside the subprocess snippet.
        assert m.get("modeled_bytes", m["hlo_bytes"]) == m["hlo_bytes"], (
            name, m)
    # Model-only entries (no HLO twin): the recovery program's cost.
    recovery = _recovery_metrics()
    metrics.update(recovery)
    for name, m in recovery.items():
        rows.append((
            f"collectives.{name}", float(m["modeled_latency_cc"]),
            f"modeled_bytes={m['modeled_bytes']}",
        ))
    # Model-only entries: tier-aware planning on 2-tier pod topologies
    # vs the tier-blind twin priced on the same links.
    tiered = _tiered_metrics()
    metrics.update(tiered)
    for name, m in tiered.items():
        rows.append((
            f"collectives.{name}", float(m["modeled_latency_cc"]),
            f"blind={m['blind_latency_cc']} k={m['num_chains']}",
        ))
    # Model-only entries: symbolic-addressing plan scaling + the HLO
    # constant-footprint independence pin.
    scaling = _plan_scaling_metrics(env)
    metrics.update(scaling)
    for name, m in scaling.items():
        if name.startswith("plan_L"):
            rows.append((
                f"collectives.{name}", m["plan_validate_s"] * 1e6,
                f"program_bytes={m['program_bytes']}",
            ))
    rows.append((
        "collectives.plan_hlo_const_bytes", float(scaling["plan_hlo_const_bytes"]["L8"]),
        "asserted equal at L=8 and L=16",
    ))
    with open(os.path.join(repo, "BENCH_collectives.json"), "w") as f:
        json.dump(metrics, f, indent=2, sort_keys=True)
        f.write("\n")
    rows.append((
        "collectives.subprocess_s",
        (time.perf_counter() - t0) * 1e6, "8 virtual devices",
    ))
    return rows


if __name__ == "__main__":
    for name, us, derived in main():
        print(f"{name},{us:.2f},{derived}")
