"""Chainwrite vs XLA-native collectives on the TPU-analogue mesh:
wall-clock on 8 virtual CPU devices (subprocess) + HLO wire bytes.

This is the JAX-side counterpart of Fig. 5: the "network-layer
multicast" baseline is XLA's built-in all-reduce/all-gather; "Torrent"
is the scheduled ppermute chain. On CPU the wall-clock ratio is not
meaningful for TPU — the *collective wire bytes* (trip-count-aware HLO
parse) are the portable metric and must match the ring-algorithm
prediction 2·(L-1)/L · payload per device.

The ``num_chains``/``algo`` knobs are surfaced here too: multi-chain
all-reduce (K=2/K=4 partitioned sub-rings, the hierarchical
generalization) is emitted for BOTH schedules and byte-pinned —
``rotation`` must match the (S+K-2)-payload/device prediction and
``rs_ag`` (fused per-ring reduce-scatter/all-gather + cross-ring shard
rotation) must match (2·(S-1)+(K-1))/S·payload and land strictly below
its rotation twin; multi-chain broadcast (K=2) is timed against the
single chain.
"""

from __future__ import annotations

import os
import subprocess
import sys
import time

_SNIPPET = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import time
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P
from repro.core import chainwrite as cw
from repro.launch import hlo_cost

L = 8
mesh = jax.make_mesh((L,), ("x",), axis_types=(jax.sharding.AxisType.Auto,))
N = 1 << 18  # 256k f32 per device = 1 MiB

def time_fn(f, *args):
    f(*args)  # compile+warm
    t0 = time.perf_counter()
    for _ in range(5):
        out = f(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / 5 * 1e6

x = jnp.ones((L, N), jnp.float32)

def chain_ar(x):
    return cw.chain_all_reduce(x[0], "x")[None]

def xla_ar(x):
    return jax.lax.psum(x[0], "x")[None]

RINGS = {2: [(0,1,2,3), (4,5,6,7)], 4: [(0,1), (2,3), (4,5), (6,7)]}

def multi_ar(k, algo):
    def fn(x):
        return cw.multi_chain_all_reduce(x[0], "x", RINGS[k], algo=algo)[None]
    return fn

results = {}
for name, fn in [
    ("chain_all_reduce", chain_ar),
    ("multi_chain_all_reduce_k2_rotation", multi_ar(2, "rotation")),
    ("multi_chain_all_reduce_k2_rs_ag", multi_ar(2, "rs_ag")),
    ("multi_chain_all_reduce_k4_rotation", multi_ar(4, "rotation")),
    ("multi_chain_all_reduce_k4_rs_ag", multi_ar(4, "rs_ag")),
    ("xla_all_reduce", xla_ar),
]:
    sm = jax.shard_map(fn, mesh=mesh, in_specs=P("x"), out_specs=P("x"))
    jitted = jax.jit(sm)
    us = time_fn(jitted, x)
    cost = hlo_cost.analyze(jitted.lower(x).compile().as_text())
    results[name] = (us, cost.coll_bytes)
    # correctness
    np.testing.assert_allclose(np.asarray(jitted(x))[0], np.full((N,), L, np.float32))

payload = N * 4
ring_pred = 2 * (L - 1) / L * payload
chain_bytes = results["chain_all_reduce"][1]
assert 0.9 * ring_pred <= chain_bytes <= 1.35 * ring_pred, (chain_bytes, ring_pred)
# Rotation trades wire bytes for chain length: (S-1)+(K-1) full-payload
# sends/device. RS+AG keeps the short rings but moves 1/S shards:
# (2*(S-1)+(K-1))/S payloads/device — strictly below its rotation twin.
for K in (2, 4):
    S = L // K
    rot_pred = (S + K - 2) * payload
    rot_bytes = results[f"multi_chain_all_reduce_k{K}_rotation"][1]
    assert 0.9 * rot_pred <= rot_bytes <= 1.35 * rot_pred, (K, rot_bytes, rot_pred)
    rsag_pred = (2 * (S - 1) + (K - 1)) / S * payload
    rsag_bytes = results[f"multi_chain_all_reduce_k{K}_rs_ag"][1]
    assert 0.9 * rsag_pred <= rsag_bytes <= 1.35 * rsag_pred, (K, rsag_bytes, rsag_pred)
    assert rsag_bytes < rot_bytes, (K, rsag_bytes, rot_bytes)

# P2MP broadcast: single chain vs 2 partitioned chains (wire bytes drop
# because the longest chain halves: 7 sequential hops -> 2x3+1 concurrent).
def chain_bc(x):
    return cw.chain_broadcast(x[0], "x", tuple(range(8)), num_frames=4)[None]

def multi_bc(x):
    return cw.multi_chain_broadcast(
        x[0], "x", 0, [(1, 2, 3), (4, 5, 6, 7)], num_frames=4)[None]

for name, fn in [("chain_broadcast", chain_bc), ("multi_chain_broadcast_k2", multi_bc)]:
    sm = jax.shard_map(fn, mesh=mesh, in_specs=P("x"), out_specs=P("x"))
    jitted = jax.jit(sm)
    us = time_fn(jitted, x)
    cost = hlo_cost.analyze(jitted.lower(x).compile().as_text())
    results[name] = (us, cost.coll_bytes)
    np.testing.assert_allclose(np.asarray(jitted(x)), np.ones((L, N), np.float32))

for name, (us, cb) in results.items():
    print(f"{name},{us:.1f},{cb:.0f}")
"""


def main() -> list[tuple[str, float, str]]:
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(repo, "src") + os.pathsep + env.get("PYTHONPATH", "")
    t0 = time.perf_counter()
    proc = subprocess.run(
        [sys.executable, "-c", _SNIPPET], capture_output=True, text=True,
        env=env, timeout=900,
    )
    if proc.returncode != 0:
        raise RuntimeError(proc.stderr[-2000:])
    rows = []
    for line in proc.stdout.strip().splitlines():
        name, us, cb = line.split(",")
        rows.append((f"collectives.{name}", float(us), f"wire_bytes={cb}"))
    rows.append((
        "collectives.subprocess_s",
        (time.perf_counter() - t0) * 1e6, "8 virtual devices",
    ))
    return rows


if __name__ == "__main__":
    for name, us, derived in main():
        print(f"{name},{us:.2f},{derived}")
