"""§Roofline table generator — reads the dry-run artifacts under
``experiments/dryrun/`` and emits the per-(arch × shape × mesh) roofline
terms, dominant bottleneck and MODEL/HLO flops ratio.

Run the sweep first:  PYTHONPATH=src python -m repro.launch.dryrun --all
Then:                 PYTHONPATH=src python -m benchmarks.bench_roofline
"""

from __future__ import annotations

import json
import os
import time

DRYRUN_DIR = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    "experiments", "dryrun",
)


def load(mesh: str = "single") -> list[dict]:
    d = os.path.join(DRYRUN_DIR, mesh)
    rows = []
    if not os.path.isdir(d):
        return rows
    for f in sorted(os.listdir(d)):
        if f.endswith(".json") and "__" in f and not f.count("__") > 1:
            with open(os.path.join(d, f)) as fh:
                rows.append(json.load(fh))
    return rows


def table(mesh: str = "single") -> list[dict]:
    out = []
    for r in load(mesh):
        if r.get("status") == "skipped":
            out.append({
                "cell": f'{r["arch"]} × {r["shape"]}',
                "status": "skipped", "reason": r.get("reason", ""),
            })
            continue
        if r.get("status") != "ok":
            out.append({"cell": f'{r["arch"]} × {r["shape"]}',
                        "status": r.get("status", "?")})
            continue
        roof = r["roofline"]
        out.append({
            "cell": f'{r["arch"]} × {r["shape"]}',
            "status": "ok",
            "compute_s": roof["compute_s"],
            "memory_s": roof["memory_s"],
            "collective_s": roof["collective_s"],
            "dominant": roof["dominant"],
            "bound_s": max(roof["compute_s"], roof["memory_s"],
                           roof["collective_s"]),
            "roofline_fraction": (
                roof["compute_s"]
                / max(roof["compute_s"], roof["memory_s"], roof["collective_s"])
            ),
            "useful_flops_ratio": r.get("useful_flops_ratio"),
            "hbm_gb_per_dev": r["memory_analysis"].get(
                "peak_memory_in_bytes", 0) / 2**30,
        })
    return out


def markdown(mesh: str = "single") -> str:
    rows = table(mesh)
    lines = [
        "| cell | compute_s | memory_s | collective_s | dominant | "
        "roofline-frac | MODEL/HLO flops |",
        "|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        if r["status"] != "ok":
            lines.append(
                f"| {r['cell']} | — | — | — | {r['status']} | — | — |")
            continue
        ufr = r["useful_flops_ratio"]
        ufr_s = f"{ufr:.2f}" if ufr else "?"
        lines.append(
            f"| {r['cell']} | {r['compute_s']:.4f} | {r['memory_s']:.4f} | "
            f"{r['collective_s']:.4f} | **{r['dominant']}** | "
            f"{r['roofline_fraction']:.2f} | {ufr_s} |"
        )
    return "\n".join(lines)


def main() -> list[tuple[str, float, str]]:
    t0 = time.perf_counter()
    rows = [r for r in table("single") if r["status"] == "ok"]
    us = (time.perf_counter() - t0) * 1e6
    out = []
    for r in rows:
        out.append((
            f"roofline.{r['cell'].replace(' × ', '__')}", us,
            f"dom={r['dominant']} frac={r['roofline_fraction']:.2f} "
            f"c={r['compute_s']:.4f} m={r['memory_s']:.4f} "
            f"n={r['collective_s']:.4f}",
        ))
    if rows:
        worst = min(rows, key=lambda r: r["roofline_fraction"])
        out.append(("roofline.worst_cell", us,
                    f"{worst['cell']} frac={worst['roofline_fraction']:.2f}"))
    return out


if __name__ == "__main__":
    for name, us, derived in main():
        print(f"{name},{us:.2f},{derived}")
