"""Quickstart: the Torrent library in five minutes.

1. Schedule a Chainwrite over a mesh NoC and compare against unicast /
   network-layer multicast (the paper's core contribution).
2. Run the four-phase ChainTask orchestration with a real payload.
3. Train a tiny LM for a handful of steps with the full framework stack.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core import (
    ChainTask,
    MeshTopology,
    chain_total_hops,
    greedy_schedule,
    multicast_total_hops,
    tsp_schedule,
    unicast_total_hops,
)


def scheduling_demo():
    print("=== 1. Chainwrite scheduling (paper Alg. 1 + TSP) ===")
    topo = MeshTopology(8, 8)  # 64-node mesh NoC
    rng = np.random.default_rng(0)
    dests = sorted(rng.choice(np.arange(1, 64), size=12, replace=False).tolist())
    print(f"source C0 -> {len(dests)} destinations: {dests}")
    print(f"  unicast   total hops: {unicast_total_hops(topo, dests)}")
    print(f"  multicast total hops: {multicast_total_hops(topo, dests)}")
    for name, sched in [("greedy", greedy_schedule), ("tsp", tsp_schedule)]:
        order = sched(topo, dests)
        print(f"  chainwrite[{name}] hops: {chain_total_hops(topo, order)}"
              f"  (order {order})")


def chaintask_demo():
    print("\n=== 2. Four-phase ChainTask (paper Fig. 4) ===")
    topo = MeshTopology(4, 5)  # the paper's 20-cluster SoC
    payload = np.arange(64 * 1024, dtype=np.uint8)
    task = ChainTask(topo, source=0, destinations=[3, 7, 12, 18], payload=payload,
                     scheduler="tsp")
    buffers = task.run()
    ok = all(np.array_equal(buf, payload) for buf in buffers.values())
    print(f"  delivered to {sorted(buffers)} intact={ok}")
    print(f"  cycles: {task.cycle_ledger}")
    print(f"  speedup vs unicast: {task.speedup_vs_unicast():.2f}x")


def training_demo():
    print("\n=== 3. Tiny LM training through the framework ===")
    from repro.launch.train import TrainConfig, Trainer

    tc = TrainConfig(arch="yi-6b", smoke=True, steps=20, global_batch=4,
                     seq_len=32, peak_lr=2e-3, warmup_steps=4,
                     ckpt_dir="/tmp/quickstart_ckpt", ckpt_every=10,
                     loss_chunks=2, log_every=5)
    out = Trainer(tc).run()
    print(f"  loss {out['first_loss']:.3f} -> {out['last_loss']:.3f} "
          f"in {out['final_step']} steps ({out['tokens_per_s']:.0f} tok/s)")


if __name__ == "__main__":
    scheduling_demo()
    chaintask_demo()
    training_demo()
