"""Chainwrite as JAX collectives on a (virtual) 8-device mesh.

Shows the TPU-side of the paper's contribution: P2MP broadcast to a
device *subset*, scheduled ring all-reduce, and the backend seam that
swaps XLA collectives for Torrent chains — plus the ChainProgram IR
behind all of them: every collective is planned ONCE (``core.program``)
and the same step/edge/byte table drives the SPMD executor, the numpy
oracle and the cycle model (section 0 prints the planned tables).

This script needs 8 devices, so it sets the host-platform flag itself —
run it standalone, not inside other JAX code:

    PYTHONPATH=src python examples/chainwrite_collectives.py
"""

import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=8 "
    + os.environ.get("XLA_FLAGS", "")
)

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core import chainwrite as cw
from repro.core.scheduling import tsp_schedule
from repro.core.topology import MeshTopology


def show_programs():
    """--- 0. The schedule IR: one planner, three backends ------------

    Prints each collective's planned step/edge/byte table straight
    from the ChainProgram — the same object `chainwrite` executes,
    `chainwrite_ref` replays and `simulator.program_latency` prices.
    """
    from repro.core import program as prg
    from repro.core.simulator import program_latency
    from repro.core.topology import TieredMeshTopology

    L, payload = 8, 64 * 1024
    topo = MeshTopology(L, 1)
    # a tiered twin of the same 8-ring: two 4-node pods joined by one
    # 2x-slower link — the crossing counts below price against it
    tiered = TieredMeshTopology(L, 1, pods_x=2, interpod_bw=0.5,
                                interpod_latency=2)
    rings2 = ((0, 1, 2, 3), (4, 5, 6, 7))
    programs = [
        prg.plan_broadcast(L, 0, ((1, 2, 3), (4, 5, 6, 7))),
        prg.plan_all_reduce(L, rings2, "rs_ag"),
        prg.plan_all_reduce(L, rings2, "rotation"),
        prg.plan_reduce_scatter(L, rings2),
        prg.plan_all_gather(L, rings2),
        prg.plan_all_to_all(L, rings2),
    ]
    for prog in programs:
        for line in prog.describe(payload):
            print(line)
        stats = prg.tier_crossing_stats(prog, tiered)
        print(f"  modeled latency: "
              f"{program_latency(topo, 0, prog, payload)} CC")
        print(f"  inter-pod crossings on {tiered.spec()}: "
              f"{stats['total']} link(s), per-chain {stats['per_group']}, "
              f"{stats['crossing_steps']} crossing step(s)\n")

    # Recovery is a program too: two concurrent mid-chain failures of
    # the K=2 broadcast — the detection window plus each re-formed
    # suffix streaming from the member that banked the payload.
    rec = prg.plan_recovery(topo, 0, ((1, 2, 3), (4, 5, 6, 7)), {2, 6})
    for line in rec.describe(payload):
        print(line)
    print(f"  streams from banked members: {rec.group_heads}")
    print(f"  modeled latency (incl. detection): "
          f"{program_latency(topo, 0, rec, payload)} CC\n")


def main():
    show_programs()
    mesh = jax.make_mesh((8,), ("x",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    print(f"devices: {jax.device_count()}")

    # --- 1. P2MP broadcast to a subset, frame-pipelined -----------------
    # Schedule the chain over the physical 4x2 torus the 8 devices form.
    topo = MeshTopology(4, 2)
    dests = [3, 5, 6]
    order = (1, *tsp_schedule(topo, dests, source=1))
    print(f"chain order from device 1 over 4x2 torus: {order}")

    x = jnp.stack([jnp.full((16, 4), i, jnp.float32) for i in range(8)])

    def bcast(x):
        return cw.chain_broadcast(x[0], "x", order, num_frames=4)[None]

    y = jax.jit(jax.shard_map(bcast, mesh=mesh, in_specs=P("x"),
                              out_specs=P("x")))(x)
    got = {d: float(np.asarray(y)[d].mean()) for d in range(8)}
    print(f"after chain_broadcast(head=1): per-device mean {got}")
    assert all(got[d] == 1.0 for d in order)
    assert all(got[d] == 0.0 for d in range(8) if d not in order)

    # --- 2. Scheduled ring all-reduce (the DP gradient path) ------------
    ring = (0, *tsp_schedule(MeshTopology(8, 1), list(range(1, 8)), 0))

    def allreduce(x):
        return cw.chain_all_reduce(x[0], "x", ring)[None]

    z = jax.jit(jax.shard_map(allreduce, mesh=mesh, in_specs=P("x"),
                              out_specs=P("x")))(x)
    expect = float(np.asarray(x).sum(0).mean())
    print(f"chain_all_reduce: every device holds mean {np.asarray(z)[0].mean()} "
          f"(expected {expect})")
    np.testing.assert_allclose(np.asarray(z), np.broadcast_to(
        np.asarray(x).sum(0), (8, 16, 4)))

    # --- 3. Wire-byte accounting: chain vs native all-reduce ------------
    from repro.launch import hlo_cost

    jitted = jax.jit(jax.shard_map(allreduce, mesh=mesh, in_specs=P("x"),
                                   out_specs=P("x")))
    cost = hlo_cost.analyze(jitted.lower(x).compile().as_text())
    payload = 16 * 4 * 4
    print(f"chain all-reduce wire bytes/device: {cost.coll_bytes:.0f} "
          f"(ring optimum 2*(L-1)/L*payload = {2 * 7 / 8 * payload:.0f})")


if __name__ == "__main__":
    main()
