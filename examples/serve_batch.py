"""End-to-end driver: serve a small LM with batched requests.

This is the paper-appropriate end-to-end scenario (Torrent is an
inference-SoC data-movement architecture evaluated on DeepSeek-V3
attention): a slot-based continuous-batching server whose weight
distribution to the replica set runs as a four-phase Torrent ChainTask
(cfg → grant → data → finish), with predicted-cycle accounting from the
NoC model.

Run:  PYTHONPATH=src python examples/serve_batch.py [--requests 16]
"""

import argparse

import numpy as np

from repro.launch.serve import ServeConfig, Server


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--requests", type=int, default=12)
    p.add_argument("--max-new", type=int, default=16)
    p.add_argument("--batch", type=int, default=4)
    p.add_argument("--arch", default="yi-6b")
    args = p.parse_args()

    sc = ServeConfig(
        arch=args.arch, smoke=True, batch=args.batch, prompt_len=16,
        max_seq=16 + args.max_new + 2, replicas=8,
    )
    server = Server(sc)
    rng = np.random.default_rng(0)

    # register a shared system prompt: prefilled once, its KV rows are
    # chained to every replica and paged via the relayout kernel
    system_prompt = rng.integers(0, server.cfg.vocab_size, size=8).astype(
        np.int32
    )
    entry = server.register_prefix(system_prompt)
    kv = entry.broadcast
    print(f"KV multicast of a {entry.plen}-token prefix to "
          f"{kv['replicas'] - 1} replicas: {kv['wire_bytes']} wire bytes "
          f"({kv['speedup_vs_unicast']:.2f}x vs unicast), "
          f"{entry.paged.shape[0]} pages/replica")

    print(f"submitting {args.requests} requests "
          f"({sc.batch} decode slots, greedy sampling, every other "
          f"request reusing the system prompt)...")
    reqs = [
        server.submit(
            np.concatenate(
                [system_prompt,
                 rng.integers(0, server.cfg.vocab_size, size=8)]
            ).astype(np.int32)
            if i % 2 == 0
            else rng.integers(0, server.cfg.vocab_size, size=16),
            args.max_new,
        )
        for i in range(args.requests)
    ]
    out = server.run(reqs)
    print(f"generated {out['generated_tokens']} tokens over "
          f"{out['decode_steps']} decode steps "
          f"({out['tokens_per_s']:.1f} tok/s on CPU); "
          f"prefix-cache hit rate {out['prefix_hit_rate']:.0%}, "
          f"p50/p99 latency {out['latency_ticks_p50']:.0f}/"
          f"{out['latency_ticks_p99']:.0f} ticks")
    wm = out["weight_multicast"]
    print(f"weight multicast to {sc.replicas - 1} replicas: "
          f"{wm['bytes']} bytes, {wm['cycles']} predicted cycles, "
          f"{wm['speedup_vs_unicast']:.2f}x vs unicast")
    for r in reqs[:3]:
        print(f"  request {r.rid}{' (hit)' if r.prefix_hit else ''}: {r.out}")


if __name__ == "__main__":
    main()
