"""Train an LM end to end with the full framework stack: Markov data
pipeline, AdamW, async checkpointing, straggler monitor, fault-tolerant
restart loop, and (optionally) Torrent chain collectives for the
data-parallel gradient reduction.

Defaults are laptop-sized; ``--dim/--layers/--steps`` scale it up (e.g.
``--dim 640 --layers 10 --vocab 32000`` is a ~100M-param model — on a
TPU slice the same script is what launch/train.py drives per host).

Run:  PYTHONPATH=src python examples/train_lm.py --steps 60
"""

import argparse
import dataclasses

from repro import configs as C
from repro.launch.train import TrainConfig, Trainer


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="yi-6b", choices=C.ARCHS)
    p.add_argument("--steps", type=int, default=60)
    p.add_argument("--batch", type=int, default=8)
    p.add_argument("--seq", type=int, default=64)
    p.add_argument("--dim", type=int, default=0, help="override d_model")
    p.add_argument("--layers", type=int, default=0)
    p.add_argument("--vocab", type=int, default=0)
    p.add_argument("--collectives", choices=("xla", "torrent"), default="xla")
    p.add_argument("--fail-at", default="", help="e.g. 25,40 to demo restart")
    p.add_argument("--ckpt-dir", default="/tmp/train_lm_ckpt")
    args = p.parse_args()

    tc = TrainConfig(
        arch=args.arch, smoke=True, steps=args.steps,
        global_batch=args.batch, seq_len=args.seq,
        collectives=args.collectives, ckpt_dir=args.ckpt_dir,
        ckpt_every=max(10, args.steps // 4), log_every=5,
        fail_at=tuple(int(s) for s in args.fail_at.split(",") if s),
    )
    trainer = Trainer(tc)
    if args.dim or args.layers or args.vocab:
        overrides = {}
        if args.dim:
            overrides.update(d_model=args.dim, d_ff=4 * args.dim,
                             head_dim=args.dim // trainer.cfg.num_heads)
        if args.layers:
            overrides["num_layers"] = args.layers
        if args.vocab:
            overrides["vocab_size"] = args.vocab
        trainer.cfg = dataclasses.replace(trainer.cfg, **overrides)
        trainer.source.vocab = trainer.cfg.vocab_size
        trainer._build()

    import jax

    n = sum(x.size for x in jax.tree.leaves(trainer.state["params"]))
    print(f"training {trainer.cfg.name}: {n/1e6:.1f}M params, "
          f"{args.steps} steps, collectives={args.collectives}")
    out = trainer.run()
    print(
        f"done: loss {out['first_loss']:.3f} -> {out['last_loss']:.3f}, "
        f"{out['restarts']} restarts, {out['straggler_events']} stragglers, "
        f"{out['tokens_per_s']:.0f} tok/s"
    )


if __name__ == "__main__":
    main()
