"""The paper's §IV-E workload, end to end: DeepSeek-V3 self-attention
data movement (Table II, P1–P3 / D1–D3) through the Torrent stack.

For each workload this script
  * runs the DSE layout transform through the Pallas relayout kernel
    (interpret mode on CPU) and checks it against the oracle,
  * multicasts the transformed operand to the 8 follower clusters with
    a four-phase ChainTask over the 3×3 FPGA-SoC topology,
  * reports predicted cycles vs the XDMA unicast baseline.

Run:  PYTHONPATH=src python examples/deepseek_attention_demo.py
"""

import os
import sys

import numpy as np
import jax.numpy as jnp

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
from benchmarks.bench_fig9_deepseek import WORKLOADS, xdma_latency  # noqa: E402
from repro.core import ChainTask, MeshTopology  # noqa: E402
from repro.kernels.relayout import ops as relayout  # noqa: E402


def main():
    topo = MeshTopology(3, 3)  # the paper's 9-cluster VPK180 FPGA SoC
    for w in WORKLOADS:
        shape = (w.rows, w.cols)
        src = relayout.parse_layout(w.src_layout)
        dst = relayout.parse_layout(w.dst_layout)

        # 1. DSE layout transform (Pallas kernel vs oracle)
        dense = jnp.arange(w.rows * w.cols, dtype=jnp.int8).reshape(shape)
        blocked = relayout.dense_to_blocked(dense, src)
        out = relayout.relayout(blocked, shape, src, dst)
        ok = bool(
            (np.asarray(out) == np.asarray(
                relayout.relayout_ref(blocked, shape, src, dst))).all()
        )

        # 2. P2MP movement: Chainwrite vs XDMA unicast
        dests = list(range(1, 9)) if w.multicast else [1]
        payload = np.asarray(out).reshape(-1)
        task = ChainTask(topo, 0, dests, payload, scheduler="tsp")
        task.run()
        cw = task.cycle_ledger["total"]
        base = xdma_latency(w)
        print(
            f"{w.name:28s} {w.rows}x{w.cols} {w.src_layout}->{w.dst_layout} "
            f"relayout_ok={ok} ndst={len(dests)} "
            f"xdma={base}cc chainwrite={cw}cc speedup={base / cw:.2f}x"
        )


if __name__ == "__main__":
    main()
