"""KV-block multicast serving: paged KV packing, prefix-cache seeding,
ChainProgram-priced broadcast delivery, and the relayout-oracle pins."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.program import plan_broadcast, program_wire_bytes
from repro.launch.paged_kv import (
    BF16,
    PrefixCache,
    PrefixEntry,
    dense_from_bytes,
    extract_dense_kv,
    kv_feature_width,
    paged_ref,
    seed_cache_row,
    to_paged,
)
from repro.launch.serve import ServeConfig, Server
from repro.launch.steps import make_slot_prefill_step
from repro.models import transformer as T

from repro import configs as C

MAX_SEQ = 48


@pytest.fixture(scope="module")
def cfg():
    return C.get_smoke_config("yi-6b")


def test_pack_seed_roundtrip_is_bit_exact(cfg):
    """extract_dense_kv ∘ seed_cache_row reproduces a full prefill's
    cache row bit-for-bit — the property that makes prefix seeding exact."""
    plen = 16
    params = T.model_init(jax.random.PRNGKey(0), cfg)
    prefill = jax.jit(make_slot_prefill_step(cfg, MAX_SEQ))
    tokens = np.arange(plen, dtype=np.int32) % cfg.vocab_size
    _, one_cache = prefill(params, jnp.asarray(tokens)[None])
    dense = extract_dense_kv(one_cache, 0, plen, MAX_SEQ)
    assert dense.dtype == BF16
    assert dense.shape == (plen, kv_feature_width(one_cache, MAX_SEQ))

    fresh = T.init_cache(cfg, 2, MAX_SEQ)
    seeded = seed_cache_row(fresh, 1, dense, plen)
    # the seeded row's first plen positions == the prefilled row's
    for leaf_s, leaf_p in zip(
        jax.tree.leaves(seeded["layers"]), jax.tree.leaves(one_cache["layers"])
    ):
        np.testing.assert_array_equal(
            np.asarray(leaf_s)[:, 1, :plen].view(np.uint8),
            np.asarray(leaf_p)[:, 0, :plen].view(np.uint8),
        )
    # row 0 untouched
    for leaf_s, leaf_f in zip(
        jax.tree.leaves(seeded["layers"]), jax.tree.leaves(fresh["layers"])
    ):
        np.testing.assert_array_equal(
            np.asarray(leaf_s)[:, 0].view(np.uint8),
            np.asarray(leaf_f)[:, 0].view(np.uint8),
        )


def test_to_paged_matches_relayout_ref():
    rng = np.random.default_rng(3)
    dense = rng.standard_normal((32, 24), np.float32).astype(BF16)
    paged = to_paged(dense, 8)
    assert paged.shape == (4, 8, 24)
    np.testing.assert_array_equal(
        paged.view(np.uint8), paged_ref(dense, 8).view(np.uint8)
    )
    # pages tile the dense rows in order
    np.testing.assert_array_equal(
        paged.reshape(32, 24).view(np.uint8), dense.view(np.uint8)
    )
    # wire roundtrip: uint8 view -> dense_from_bytes is the identity
    wire = np.ascontiguousarray(dense).reshape(-1).view(np.uint8)
    np.testing.assert_array_equal(
        dense_from_bytes(wire, 32, 24).view(np.uint8), dense.view(np.uint8)
    )


def test_prefix_cache_longest_match():
    pc = PrefixCache()
    t8 = np.arange(8, dtype=np.int32)
    t16 = np.arange(16, dtype=np.int32)
    d = np.zeros((16, 4), BF16)
    pc.add(PrefixEntry(tokens=t8, page=8, dense=d[:8], paged=d[:8][None]))
    pc.add(PrefixEntry(tokens=t16, page=8, dense=d, paged=d[None]))
    hit = pc.lookup(np.arange(20, dtype=np.int32))
    assert hit is not None and hit.plen == 16  # longest wins
    assert pc.lookup(np.arange(10, dtype=np.int32)).plen == 8
    assert pc.lookup(np.array([99, 1, 2], np.int32)) is None
    assert pc.hits == 2 and pc.misses == 1
    assert pc.hit_rate == pytest.approx(2 / 3)


def test_register_prefix_broadcast_is_exact_and_priced():
    """The tentpole invariant: KV bytes delivered == program_wire_bytes
    of the planned broadcast EXACTLY, and every replica's paged blocks
    are bit-identical to the relayout_ref oracle of the source rows."""
    sc = ServeConfig(arch="yi-6b", smoke=True, batch=2, prompt_len=24,
                     max_seq=MAX_SEQ, replicas=5, page_size=8)
    server = Server(sc)
    rng = np.random.default_rng(0)
    prefix = rng.integers(0, server.cfg.vocab_size, size=16).astype(np.int32)
    entry = server.register_prefix(prefix)

    rec = entry.broadcast
    program = plan_broadcast(
        server.topo.num_nodes, 0, tuple(tuple(c) for c in server.plan.chains)
    )
    modeled = program_wire_bytes(program, int(entry.dense.nbytes))
    assert rec["wire_bytes"] == rec["delivered_bytes"] == modeled
    assert rec["bytes"] == entry.dense.nbytes
    assert rec["replicas"] == 5
    assert rec["speedup_vs_unicast"] >= 1.0
    oracle = paged_ref(entry.dense, sc.page_size)
    assert sorted(entry.replica_paged) == [0, 1, 2, 3, 4]
    for blocks in entry.replica_paged.values():
        np.testing.assert_array_equal(
            blocks.view(np.uint8), oracle.view(np.uint8)
        )
    assert server.kv_multicast_log == [rec]


def test_register_prefix_single_replica_is_noop_record():
    sc = ServeConfig(arch="yi-6b", smoke=True, batch=2, prompt_len=24,
                     max_seq=MAX_SEQ, replicas=1, page_size=8)
    server = Server(sc)
    entry = server.register_prefix(np.arange(8, dtype=np.int32))
    rec = entry.broadcast
    assert rec["noop"] and rec["delivered_bytes"] == rec["wire_bytes"] == 0
    assert list(entry.replica_paged) == [0]  # source still has its pages


def test_register_prefix_rejects_bad_lengths():
    sc = ServeConfig(arch="yi-6b", smoke=True, batch=2, prompt_len=24,
                     max_seq=MAX_SEQ, replicas=2, page_size=8)
    server = Server(sc)
    with pytest.raises(ValueError):  # not a multiple of the page
        server.register_prefix(np.arange(12, dtype=np.int32))
    with pytest.raises(ValueError):  # empty
        server.register_prefix(np.zeros(0, np.int32))
    with pytest.raises(ValueError):  # no decode headroom
        server.register_prefix(np.arange(MAX_SEQ, dtype=np.int32))


def test_prefix_hit_seeds_aligned_cache_rows():
    """After a hit admission the slot's cache row equals a full prefill
    of the same prompt: the prefix positions BIT-exactly (they are the
    seeded multicast payload), the suffix positions to within a bf16
    projection ulp (the suffix runs through the decode path — same math,
    chunked differently). A position-misalignment bug would blow the
    ulp-scale tolerance by orders of magnitude."""
    import jax.numpy as jnp

    rng = np.random.default_rng(5)
    sc = ServeConfig(arch="yi-6b", smoke=True, batch=2, prompt_len=24,
                     max_seq=MAX_SEQ, replicas=3, page_size=8)
    prefix = rng.integers(0, 256, size=16).astype(np.int32)
    suffix = rng.integers(0, 256, size=5).astype(np.int32)
    prompt = np.concatenate([prefix, suffix])
    plen = int(prompt.size)

    server = Server(sc)
    server.register_prefix(prefix)
    req = server.submit(prompt, 6)
    server._admit()  # hit-path admission: seed prefix rows, decode suffix
    assert req.prefix_hit and len(req.out) == 1

    _, ref = server.slot_prefill(server.params, jnp.asarray(prompt)[None])
    for got, want in zip(
        jax.tree.leaves(server.cache["layers"]), jax.tree.leaves(ref["layers"])
    ):
        g = np.asarray(jax.device_get(got))[:, 0, :plen]
        w = np.asarray(jax.device_get(want))[:, 0, :plen]
        np.testing.assert_array_equal(  # seeded prefix rows: bit-exact
            g[:, :16].view(np.uint8), w[:, :16].view(np.uint8)
        )
        np.testing.assert_allclose(  # decode-path suffix rows: ulp-close
            g[:, 16:].astype(np.float32), w[:, 16:].astype(np.float32),
            atol=0.05, rtol=0.05,
        )


def test_prefix_hit_serving_is_deterministic():
    """The hit path (seed + suffix decode) is a fixed numeric program:
    identical runs produce identical tokens, for both a strict-suffix
    prompt and prompt == prefix (where the last prefix token re-feeds
    through decode to produce the first output)."""
    rng = np.random.default_rng(5)
    sc = ServeConfig(arch="yi-6b", smoke=True, batch=2, prompt_len=24,
                     max_seq=MAX_SEQ, replicas=3, page_size=8)
    prefix = rng.integers(0, 256, size=16).astype(np.int32)
    suffix = rng.integers(0, 256, size=5).astype(np.int32)
    for prompt in (np.concatenate([prefix, suffix]), prefix.copy()):
        outs = []
        for _ in range(2):
            server = Server(sc)
            server.register_prefix(prefix)
            req = server.submit(prompt, 6)
            server.run([req])
            assert req.prefix_hit and len(req.out) == 6
            outs.append(list(req.out))
        assert outs[0] == outs[1], (prompt.size, outs)


def test_serve_hit_rate_and_mixed_traffic():
    rng = np.random.default_rng(9)
    sc = ServeConfig(arch="yi-6b", smoke=True, batch=3, prompt_len=24,
                     max_seq=MAX_SEQ, replicas=3, page_size=8)
    server = Server(sc)
    prefix = rng.integers(0, 256, size=16).astype(np.int32)
    server.register_prefix(prefix)
    reqs = []
    for i in range(6):
        if i % 2 == 0:
            prompt = np.concatenate(
                [prefix, rng.integers(0, 256, size=4).astype(np.int32)]
            )
        else:
            prompt = rng.integers(0, 256, size=20).astype(np.int32)
            prompt[0] = (prefix[0] + 1) % 256
        reqs.append(server.submit(prompt, 4, arrival=i))
    out = server.run(reqs)
    assert out["served"] == 6
    assert all(len(r.out) == 4 for r in reqs)
    assert [r.prefix_hit for r in reqs] == [True, False] * 3
    assert out["prefix_hit_rate"] == pytest.approx(0.5)
    assert out["latency_ticks_p99"] >= out["latency_ticks_p50"] > 0


def _entry(tokens: np.ndarray, rows: int = 16, width: int = 4) -> PrefixEntry:
    d = np.zeros((rows, width), BF16)
    return PrefixEntry(tokens=tokens, page=8, dense=d, paged=d[None])


def test_prefix_cache_lru_eviction():
    """Capacity-bound cache: adds evict the least-recently-used entry,
    and a lookup hit refreshes recency (the classic LRU contract)."""
    one = _entry(np.arange(8, dtype=np.int32)).nbytes
    pc = PrefixCache(capacity_bytes=2 * one)
    a = _entry(np.arange(8, dtype=np.int32))
    b = _entry(np.arange(100, 108, dtype=np.int32))
    pc.add(a)
    pc.add(b)
    assert pc.total_bytes == 2 * one and pc.evictions == 0
    # touch a: it becomes most-recent, so the third add evicts b
    assert pc.lookup(np.arange(10, dtype=np.int32)) is a
    pc.add(_entry(np.arange(200, 208, dtype=np.int32)))
    assert pc.evictions == 1
    assert a in pc.entries and b not in pc.entries
    assert pc.total_bytes <= pc.capacity_bytes
    # an entry bigger than the whole bound cannot be cached
    pc2 = PrefixCache(capacity_bytes=one // 2)
    pc2.add(_entry(np.arange(8, dtype=np.int32)))
    assert pc2.entries == [] and pc2.evictions == 1
    with pytest.raises(ValueError):
        PrefixCache(capacity_bytes=0)


def test_prefix_cache_version_invalidation():
    """A weight refresh makes every cached KV stale: entries carry the
    weights version they were prefilled under and are dropped (and
    counted) when it bumps; new adds stamp the new version."""
    pc = PrefixCache()
    pc.add(_entry(np.arange(8, dtype=np.int32)))
    pc.add(_entry(np.arange(16, dtype=np.int32)))
    assert [e.version for e in pc.entries] == [0, 0]
    assert pc.on_weights_update() == 2
    assert pc.entries == [] and pc.invalidations == 2
    assert pc.lookup(np.arange(10, dtype=np.int32)) is None
    fresh = _entry(np.arange(8, dtype=np.int32))
    pc.add(fresh)
    assert fresh.version == pc.weights_version == 1
    assert pc.lookup(np.arange(10, dtype=np.int32)) is fresh
    assert pc.on_weights_update() == 1 and pc.invalidations == 3


def test_server_weight_refresh_invalidates_prefix_cache():
    """End to end: re-broadcasting UNCHANGED weights keeps registered
    prefixes valid (the run()-start refresh must not wipe them), while
    a refresh with NEW params version-invalidates the cache and the
    serving stats surface the counters."""
    rng = np.random.default_rng(5)
    sc = ServeConfig(arch="yi-6b", smoke=True, batch=2, prompt_len=24,
                     max_seq=MAX_SEQ, replicas=3, page_size=8)
    server = Server(sc)
    prefix = rng.integers(0, 256, size=16).astype(np.int32)
    server.register_prefix(prefix)

    rec = server.broadcast_weights()  # same weights: entries stay valid
    assert rec["prefix_invalidated"] == 0
    assert server.prefix_cache.lookup(prefix) is not None

    new_params = jax.tree.map(lambda x: x * 1.5, server.params)
    rec = server.broadcast_weights(new_params=new_params)
    assert rec["prefix_invalidated"] == 1
    assert server.prefix_cache.entries == []
    assert server.prefix_cache.lookup(prefix) is None
    # the served weights really were replaced before streaming
    got = jax.tree.leaves(server.params)[0]
    assert np.allclose(np.asarray(got), np.asarray(jax.tree.leaves(new_params)[0]))

    # a prefix registered AFTER the refresh is valid under the new
    # version, and the run stats expose the cache counters
    server.register_prefix(prefix)
    req = server.submit(
        np.concatenate([prefix, rng.integers(0, 256, size=4).astype(np.int32)]),
        2, arrival=0,
    )
    out = server.run([req])
    assert req.prefix_hit
    assert out["prefix_entries"] == 1
    assert out["prefix_bytes"] > 0
    assert out["prefix_evictions"] == 0
    assert out["prefix_invalidations"] == 1
