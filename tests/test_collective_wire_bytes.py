"""program_wire_bytes vs trip-count-aware HLO parses, collective × K.

The ChainProgram byte model claims to predict the HLO
``collective-permute`` wire attribution of the SPMD executor for EVERY
collective and ring partition. This promotes the ``bench_collectives``
byte assertions into the pytest suite: one 8-virtual-device subprocess
compiles each collective × K ∈ {1, 2, 4}, parses the compiled HLO with
``launch.hlo_cost`` and pins the parsed collective bytes against
``ChainProgram.wire_bytes`` (and, for all-reduce, against
``simulator.all_reduce_wire_bytes`` — the same number by construction).
"""

from __future__ import annotations

import pytest

pytestmark = pytest.mark.slow

SNIPPET = """
from repro.core import chainwrite as cw
from repro.core import program as prg
from repro.launch import hlo_cost

L = 8
mesh = jax.make_mesh((L,), ('x',), axis_types=(jax.sharding.AxisType.Auto,))
N = 1 << 12  # 4k f32 per device
RINGS = {
    1: ((0, 1, 2, 3, 4, 5, 6, 7),),
    2: ((3, 1, 0, 2), (7, 5, 6, 4)),
    4: ((0, 2), (4, 6), (1, 3), (5, 7)),
}

def coll_bytes(fn, x):
    sm = jax.shard_map(fn, mesh=mesh, in_specs=P('x'), out_specs=P('x'))
    jitted = jax.jit(sm)
    return hlo_cost.analyze(jitted.lower(x).compile().as_text()).coll_bytes

def pin(name, got, want):
    assert want == 0 or 0.9 * want <= got <= 1.35 * want, (name, got, want)
    print(f"{name}: hlo={got:.0f} modeled={want}")

x1 = jnp.ones((L, N), jnp.float32)           # per-device (N,) payload
x2 = jnp.ones((L, L, N // 8), jnp.float32)   # per-device (L, N/8) train

for K, orders in RINGS.items():
    S = L // K
    for algo in ('rs_ag', 'rotation'):
        prog = prg.plan_all_reduce(L, orders, 'rs_ag' if K == 1 else algo)
        got = coll_bytes(
            lambda v, o=orders, a=algo: cw.multi_chain_all_reduce(
                v[0], 'x', o, algo=a)[None], x1)
        pin(f"all_reduce k{K} {algo}", got, prog.wire_bytes(N * 4))
        from repro.core.simulator import all_reduce_wire_bytes
        assert prog.wire_bytes(N * 4) == all_reduce_wire_bytes(S, K, N * 4, algo)

    prog = prg.plan_reduce_scatter(L, orders)
    got = coll_bytes(
        lambda v, o=orders: cw.multi_chain_reduce_scatter(v[0], 'x', o)[None],
        x2)
    pin(f"reduce_scatter k{K}", got, prog.wire_bytes(L * (N // 8) * 4))

    prog = prg.plan_all_gather(L, orders)
    got = coll_bytes(
        lambda v, o=orders: cw.multi_chain_all_gather(
            v[0], 'x', o, tiled=True)[None], x1)
    pin(f"all_gather k{K}", got, prog.wire_bytes(N * 4))

    prog = prg.plan_all_to_all(L, orders)
    got = coll_bytes(
        lambda v, o=orders: cw.multi_chain_all_to_all(v[0], 'x', o)[None], x2)
    pin(f"all_to_all k{K}", got, prog.wire_bytes(L * (N // 8) * 4))

# broadcast (non-pipelined stepped path, head fan-out double-counted
# per the num_permutes accounting)
chains = ((1, 2, 3), (4, 5, 6, 7))
prog = prg.plan_broadcast(L, 0, chains)
got = coll_bytes(
    lambda v: cw.multi_chain_broadcast(v[0], 'x', 0, chains)[None], x1)
pin("broadcast k2", got, prog.wire_bytes(N * 4))
print("WIRE BYTES OK")
"""


def test_program_wire_bytes_pin_hlo_parses(run_multidevice):
    out = run_multidevice(SNIPPET, timeout=1200)
    assert "WIRE BYTES OK" in out
