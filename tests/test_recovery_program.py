"""Recovery-as-a-program (ISSUE-5 tentpole): ``plan_recovery`` emits
the detection window + re-formed suffixes of a failed multi-chain
broadcast as a ChainProgram, and ``chain_recovery_latency`` is a thin
wrapper pricing it through the generic ``program_latency``.

Pins the acceptance matrix:

* **CC-exact regression** — single-failure ``chain_recovery_latency``
  values are IDENTICAL to the pre-refactor model (the pin table below
  was captured before the rewrite), with and without ``src_read_bw``
  contention.
* **Structure** — the planned program validates; detection is an
  edge-free ``tag="detect"`` step; each re-formed suffix streams from
  the member that banked the payload (``group_heads``); the numpy
  program interpreter replays it and delivers the payload to every
  re-sent survivor.
* **Concurrent failures** — for random meshes/partitions and 2–3
  failures in distinct sub-chains: unaffected chains are CC-exact
  (isolation), the program validates, and the multi-failure program's
  wire bytes are >= every constituent single-failure program's.
* **Accounting** — recovery bytes appear in ``program_wire_bytes`` /
  the ``recovery_wire_bytes`` detail entry.
"""

from __future__ import annotations

import dataclasses

import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.core import chainwrite_ref as ref
from repro.core import program as prg
from repro.core.program import plan_recovery, program_wire_bytes
from repro.core.scheduling import partition_schedule, reform_chain
from repro.core.simulator import (
    DEFAULT_PARAMS,
    chain_recovery_latency,
    multi_chain_latency,
    program_latency,
)
from repro.core.topology import MeshTopology

BIG = MeshTopology(8, 8)
TOPO = MeshTopology(4, 5)
SIZE = 64 * 1024


# ---------------------------------------------------------------------------
# CC-exact regression: the pre-refactor single-failure values
# ---------------------------------------------------------------------------

# Captured from the pre-IR chain_recovery_latency (direct _chain_phases
# pricing) at 64 KiB payloads; "contended" = src_read_bw=48. The
# refactored path (plan_recovery -> program_latency) must reproduce
# every value EXACTLY.
_PIN_CASES = {
    "big_k3_mid": (BIG, list(range(1, 13)), 3),
    "big_k2_16": (BIG, list(range(1, 17)), 2),
    "soc_k2": (TOPO, [3, 7, 12, 14, 9, 18], 2),
    "soc_k1": (TOPO, [3, 7, 12, 14, 9, 18], 1),
}
_PINS = {
    "big_k3_mid.default": {1: 1985, 2: 3131, 3: 3263, 4: 1943, 5: 3103,
                           6: 3009, 7: 1857, 8: 3376, 9: 3297, 10: 3211,
                           11: 3172, 12: 3092},
    "big_k3_mid.contended": {1: 5057, 2: 6545, 3: 6677, 4: 5015, 5: 6517,
                             6: 6423, 7: 4929, 8: 6790, 9: 6711, 10: 6625,
                             11: 6586, 12: 6506},
    "big_k2_16.default": {1: 3543, 2: 3461, 3: 4245, 4: 3990, 5: 3911,
                          6: 3662, 7: 3583, 8: 3214, 9: 3296, 10: 3378,
                          11: 4154, 12: 4075, 13: 3826, 14: 3747,
                          15: 2430, 16: 2067},
    "big_k2_16.contended": {1: 5592, 2: 5510, 3: 6294, 4: 6039, 5: 5960,
                            6: 5711, 7: 5632, 8: 5263, 9: 5345, 10: 5427,
                            11: 6203, 12: 6124, 13: 5875, 14: 5796,
                            15: 4137, 16: 3774},
    "soc_k2.default": {3: 2901, 7: 1746, 9: 3247, 12: 3159, 14: 3080,
                       18: 1926},
    "soc_k2.contended": {3: 4950, 7: 3453, 9: 5296, 12: 5208, 14: 5129,
                         18: 3633},
    "soc_k1.default": {3: 3585, 7: 3497, 9: 3412, 12: 3321, 14: 3242,
                       18: 2088},
    "soc_k1.contended": {3: 4269, 7: 4181, 9: 4096, 12: 4005, 14: 3926,
                         18: 2430},
}


def test_single_failure_latency_is_cc_identical_to_pre_refactor():
    contended = dataclasses.replace(DEFAULT_PARAMS, src_read_bw=48)
    for name, (topo, dests, k) in _PIN_CASES.items():
        chains = partition_schedule(topo, dests, 0, num_chains=k)
        for pname, p in (("default", DEFAULT_PARAMS), ("contended", contended)):
            pins = _PINS[f"{name}.{pname}"]
            for failed, want in pins.items():
                got = chain_recovery_latency(topo, 0, chains, failed, SIZE, p)
                assert got == want, (name, pname, failed, got, want)


def test_single_failure_is_priced_through_the_program():
    """The wrapper's numbers ARE the program model's: detection + the
    program's per-group four phases, nothing else."""
    chains = partition_schedule(BIG, list(range(1, 13)), 0, num_chains=3)
    failed = chains[0][1]
    program = plan_recovery(BIG, 0, chains, failed)
    d = chain_recovery_latency(BIG, 0, chains, failed, SIZE, detail=True)
    rec = d["recovery"]
    pl = program_latency(BIG, 0, program, SIZE, DEFAULT_PARAMS, detail=True)
    assert rec["recovery_cc"] == pl["per_chain"][0]
    assert pl["detect_cc"] == DEFAULT_PARAMS.fail_timeout_cc
    assert (rec["cfg_cc"], rec["grant_cc"], rec["data_cc"],
            rec["finish_cc"]) == tuple(pl["per_phase"][0])
    assert d["recovery_wire_bytes"] == program_wire_bytes(program, SIZE)
    assert d["recovery_wire_bytes"] > 0


# ---------------------------------------------------------------------------
# Program structure (golden, device-free)
# ---------------------------------------------------------------------------


def test_plan_recovery_golden_structure():
    chains = [[1, 2, 3], [9, 17]]
    prog = plan_recovery(BIG, 0, chains, {2, 9})
    prog.validate()
    assert prog.collective == "recovery" and prog.kind == "pipeline"
    # chain 0: prefix [1] banked the payload -> resent [3] from head 1;
    # chain 1: head-of-chain failure -> resent [17] from the source.
    assert prog.groups == ((3,), (17,))
    assert prog.group_heads == (1, 0)
    assert prog.head == 0
    # step 0 is the shared edge-free detection window
    assert prog.steps[0].tag == "detect" and prog.steps[0].edges == ()
    assert prog.steps[0].num_permutes() == 0
    # then the re-formed suffixes' hop slots, one edge per group
    assert prog.steps[1].tag == "chain"
    assert set(prog.steps[1].edges) == {(1, 3), (0, 17)}
    # both resends are depth-1 with distinct sources: one fused permute
    assert program_wire_bytes(prog, SIZE) == SIZE


def test_plan_recovery_tail_failures_emit_no_groups():
    """A pure tail failure orphans nothing: the program is just the
    detection window (zero bytes) and program_latency prices exactly
    the timeout."""
    prog = plan_recovery(BIG, 0, [[1, 2, 3], [9, 17]], 3)
    assert prog.groups == () and prog.group_heads == ()
    assert [s.tag for s in prog.steps] == ["detect"]
    assert program_wire_bytes(prog, SIZE) == 0
    assert program_latency(BIG, 0, prog, SIZE) == DEFAULT_PARAMS.fail_timeout_cc


def test_plan_recovery_validates_failures():
    with pytest.raises(ValueError):
        plan_recovery(BIG, 0, [[1, 2]], 7)  # not a member
    with pytest.raises(ValueError):
        plan_recovery(BIG, 0, [[1, 2]], set())  # empty failure set


def test_interpret_program_replays_recovery_delivery():
    """Seed the banked heads with the payload and the numpy program
    interpreter delivers it to every re-sent survivor — recovery is
    replayable like any other collective's program."""
    chains = [[1, 2, 10, 9], [5, 6, 7]]
    dead = {10, 6}
    prog = plan_recovery(BIG, 0, chains, dead)
    resent = {d for g in prog.groups for d in g}
    payload = np.arange(4.0, dtype=np.float32) + 1.0
    shards = np.zeros((prog.num_devices, 1, 4), np.float32)
    for h in prog.group_heads:
        shards[h, 0] = payload
    out = ref.interpret_program(shards, prog)
    for d in range(prog.num_devices):
        if d in resent or d in prog.group_heads:
            np.testing.assert_array_equal(out[d, 0], payload)
        else:
            assert not out[d, 0].any()
    # the failed members are never touched
    assert not out[10, 0].any() and not out[6, 0].any()


# ---------------------------------------------------------------------------
# Concurrent-failure properties (random meshes — exact-TSP heavy: slow)
# ---------------------------------------------------------------------------


def _draw_partitioned_failures(data, topo, max_failures=3):
    n = topo.num_nodes
    dests = data.draw(
        st.lists(
            st.integers(1, n - 1), min_size=6, max_size=14, unique=True
        )
    )
    k = data.draw(st.integers(2, 3))
    chains = partition_schedule(topo, dests, 0, num_chains=k)
    multi = [c for c in chains if len(c)]
    nf = min(data.draw(st.integers(2, max_failures)), len(multi))
    failed = {
        data.draw(st.sampled_from(c), label=f"f{i}")
        for i, c in enumerate(multi[:nf])
    }
    return chains, failed


@pytest.mark.slow
@settings(max_examples=30, deadline=None)
@given(data=st.data())
def test_concurrent_failures_preserve_isolation_invariant(data):
    nx = data.draw(st.integers(4, 8))
    ny = data.draw(st.integers(4, 8))
    torus = data.draw(st.booleans())
    topo = MeshTopology(nx, ny, torus=torus)
    chains, failed = _draw_partitioned_failures(data, topo)
    base = multi_chain_latency(topo, 0, chains, SIZE, detail=True)
    rec = chain_recovery_latency(topo, 0, chains, failed, SIZE, detail=True)
    affected = {r["chain"] for r in rec["recoveries"]}
    assert affected == {
        i for i, c in enumerate(chains) if any(f in c for f in failed)
    }
    for i, (b, r) in enumerate(zip(base["per_chain"], rec["per_chain"])):
        if i in affected:
            entry = next(x for x in rec["recoveries"] if x["chain"] == i)
            assert r == b + entry["recovery_cc"]
            assert entry["recovery_cc"] >= DEFAULT_PARAMS.fail_timeout_cc
        else:
            assert r == b  # CC-exact isolation
    assert rec["per_phase"] == base["per_phase"]
    assert rec["total"] == max(rec["per_chain"])
    # every affected chain's reform covers exactly its survivors
    for entry in rec["recoveries"]:
        chain = chains[entry["chain"]]
        assert sorted(entry["reformed"]) == sorted(
            d for d in chain if d not in failed
        )


@pytest.mark.slow
@settings(max_examples=30, deadline=None)
@given(data=st.data())
def test_concurrent_failure_program_validates_and_dominates_bytes(data):
    """plan_recovery validates for random concurrent failures, and its
    wire bytes are >= every constituent single failure's program."""
    nx = data.draw(st.integers(4, 8))
    ny = data.draw(st.integers(4, 8))
    topo = MeshTopology(nx, ny)
    chains, failed = _draw_partitioned_failures(data, topo)
    prog = plan_recovery(topo, 0, chains, failed)
    prog.validate()  # idempotent, raises on any invariant breach
    assert prog.collective == "recovery"
    assert len(prog.group_heads) == len(prog.groups)
    multi_bytes = program_wire_bytes(prog, SIZE)
    for f in failed:
        single = program_wire_bytes(plan_recovery(topo, 0, chains, f), SIZE)
        assert multi_bytes >= single, (failed, f, multi_bytes, single)
    # groups = the re-formed resent suffixes, one per affected chain
    for g, h in zip(prog.groups, prog.group_heads):
        assert g  # never empty
        assert h == 0 or any(h in c for c in chains)


@settings(max_examples=15, deadline=None)
@given(data=st.data())
def test_concurrent_failures_quick_smoke(data):
    """QUICK-lane twin of the slow property suites on the 20-node SoC."""
    chains, failed = _draw_partitioned_failures(data, TOPO, max_failures=2)
    base = multi_chain_latency(TOPO, 0, chains, SIZE, detail=True)
    rec = chain_recovery_latency(TOPO, 0, chains, failed, SIZE, detail=True)
    prog = plan_recovery(TOPO, 0, chains, failed)
    affected = {r["chain"] for r in rec["recoveries"]}
    for i, (b, r) in enumerate(zip(base["per_chain"], rec["per_chain"])):
        assert (r == b) == (i not in affected)
    assert rec["recovery_wire_bytes"] == program_wire_bytes(prog, SIZE)
