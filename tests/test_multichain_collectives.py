"""Multi-chain Chainwrite collectives vs pure-numpy oracles, on 8
virtual devices (subprocess via conftest.run_multidevice).

Covers the acceptance matrix: K in {1, 2, 3}, partial chains, with and
without frame pipelining — ``multi_chain_broadcast`` must match
``chainwrite_ref.multi_broadcast_ref`` bit-exactly; plus the K-sub-ring
``multi_chain_all_reduce`` (the hierarchical generalization) under both
schedules — PR 1's full-payload ``rotation`` and PR 3's fused
reduce-scatter/all-gather ``rs_ag`` — pinned BIT-exactly against the
schedule-replaying ``multi_all_reduce_ref`` for K in {1, 2, 4} incl.
shard-padding payloads, and its integration with
``torrent_grad_reduce(num_chains=..., algo=...)``.
"""

from __future__ import annotations

import pytest

# Multidevice oracle tests (subprocess per test): skipped under QUICK=1.
pytestmark = pytest.mark.slow


def test_multi_chain_broadcast_matches_oracle(run_multidevice):
    run_multidevice("""
    from repro.core import chainwrite as cw
    from repro.core import chainwrite_ref as ref

    mesh = jax.make_mesh((8,), ('x',), axis_types=(jax.sharding.AxisType.Auto,))
    xs = jnp.arange(8 * 6 * 2, dtype=jnp.float32).reshape(8, 6, 2)

    cases = [
        # K=1 (full and partial)
        (0, [(1, 2, 3, 4, 5, 6, 7)]),
        (3, [(5, 1)]),
        # K=2, partial chains, non-zero head
        (2, [(3, 4), (1, 0)]),
        (0, [(1, 2, 3), (4, 5, 6, 7)]),
        # K=3, partial
        (0, [(1, 2), (4, 5), (6,)]),
        (5, [(6, 7), (4, 3, 2), (1,)]),
    ]
    for head, chains in cases:
        for frames in (1, 2, 3, 6):  # 1 = no pipelining
            def f(x, head=head, chains=chains, frames=frames):
                return cw.multi_chain_broadcast(
                    x[0], 'x', head, chains, num_frames=frames)[None]
            y = jax.jit(jax.shard_map(
                f, mesh=mesh, in_specs=P('x'), out_specs=P('x')))(xs)
            expect = ref.multi_broadcast_ref(np.asarray(xs), head, chains)
            np.testing.assert_array_equal(
                np.asarray(y), expect, err_msg=f"{head} {chains} {frames}")
    print("multi-chain broadcast OK")
    """, timeout=900)


def test_multi_chain_broadcast_k1_equals_chain_broadcast(run_multidevice):
    run_multidevice("""
    from repro.core import chainwrite as cw

    mesh = jax.make_mesh((8,), ('x',), axis_types=(jax.sharding.AxisType.Auto,))
    xs = jnp.arange(8 * 4, dtype=jnp.float32).reshape(8, 4)
    for frames in (1, 2, 4):
        def multi(x):
            return cw.multi_chain_broadcast(
                x[0], 'x', 2, [(5, 1, 7)], num_frames=frames)[None]
        def single(x):
            return cw.chain_broadcast(
                x[0], 'x', (2, 5, 1, 7), num_frames=frames)[None]
        ym = jax.jit(jax.shard_map(multi, mesh=mesh, in_specs=P('x'), out_specs=P('x')))(xs)
        ys = jax.jit(jax.shard_map(single, mesh=mesh, in_specs=P('x'), out_specs=P('x')))(xs)
        np.testing.assert_array_equal(np.asarray(ym), np.asarray(ys))
    print("K=1 delegation OK")
    """)


def test_multi_chain_broadcast_from_partition_schedule(run_multidevice):
    """End-to-end: schedule the partition on the host, run it as SPMD."""
    run_multidevice("""
    from repro.core import chainwrite as cw
    from repro.core import chainwrite_ref as ref
    from repro.core.scheduling import partition_schedule
    from repro.core.topology import MeshTopology

    topo = MeshTopology(4, 2)  # the 8 devices as a 4x2 mesh
    dests = [1, 2, 3, 4, 5, 6, 7]
    for k in (1, 2, 3):
        chains = partition_schedule(topo, dests, 0, num_chains=k)
        assert sorted(d for c in chains for d in c) == dests
        mesh = jax.make_mesh((8,), ('x',), axis_types=(jax.sharding.AxisType.Auto,))
        xs = jnp.arange(8 * 4, dtype=jnp.float32).reshape(8, 4) + 1.0
        def f(x, chains=chains):
            return cw.multi_chain_broadcast(x[0], 'x', 0, chains, num_frames=2)[None]
        y = jax.jit(jax.shard_map(f, mesh=mesh, in_specs=P('x'), out_specs=P('x')))(xs)
        expect = ref.multi_broadcast_ref(np.asarray(xs), 0, chains)
        np.testing.assert_array_equal(np.asarray(y), expect)
    print("scheduled multi-chain broadcast OK")
    """, timeout=900)


def test_multi_chain_broadcast_validation(run_multidevice):
    run_multidevice("""
    from repro.core import chainwrite as cw
    mesh = jax.make_mesh((8,), ('x',), axis_types=(jax.sharding.AxisType.Auto,))
    xs = jnp.zeros((8, 4))

    def expect_value_error(fn):
        try:
            jax.jit(jax.shard_map(fn, mesh=mesh, in_specs=P('x'), out_specs=P('x')))(xs)
        except ValueError:
            return
        raise SystemExit("expected ValueError")

    # overlapping chains
    expect_value_error(lambda x: cw.multi_chain_broadcast(
        x[0], 'x', 0, [(1, 2), (2, 3)])[None])
    # head inside a chain
    expect_value_error(lambda x: cw.multi_chain_broadcast(
        x[0], 'x', 0, [(1, 0)])[None])
    # empty chain set
    expect_value_error(lambda x: cw.multi_chain_broadcast(
        x[0], 'x', 0, [])[None])
    # frames must divide the leading dim
    expect_value_error(lambda x: cw.multi_chain_broadcast(
        x[0], 'x', 0, [(1, 2), (3,)], num_frames=3)[None])
    print("validation OK")
    """)


def test_degraded_broadcast_matches_oracle(run_multidevice):
    """Fault tolerance: the degraded broadcast (failed member dropped)
    delivers oracle-exact payloads to every survivor for K in {1,2,3},
    with and without frame pipelining."""
    run_multidevice("""
    from repro.core import chainwrite as cw
    from repro.core import chainwrite_ref as ref

    mesh = jax.make_mesh((8,), ('x',), axis_types=(jax.sharding.AxisType.Auto,))
    xs = jnp.arange(8 * 6 * 2, dtype=jnp.float32).reshape(8, 6, 2) + 1.0

    cases = [
        # K=1: head-of-chain, mid-chain and tail failures
        (0, [(1, 2, 3, 4, 5)], 1),
        (0, [(1, 2, 3, 4, 5)], 3),
        (0, [(1, 2, 3, 4, 5)], 5),
        # K=2
        (0, [(1, 2, 3), (4, 5, 6, 7)], 2),
        (2, [(3, 4), (1, 0)], 0),
        # K=3, incl. a failure that wipes out a whole sub-chain
        (0, [(1, 2), (4, 5), (6,)], 6),
        (5, [(6, 7), (4, 3, 2), (1,)], 3),
    ]
    for head, chains, failed in cases:
        for frames in (1, 2, 3):
            def f(x, head=head, chains=chains, failed=failed, frames=frames):
                return cw.degraded_multi_chain_broadcast(
                    x[0], 'x', head, chains, failed, num_frames=frames)[None]
            y = jax.jit(jax.shard_map(
                f, mesh=mesh, in_specs=P('x'), out_specs=P('x')))(xs)
            expect = ref.degraded_multi_broadcast_ref(
                np.asarray(xs), head, chains, failed)
            np.testing.assert_array_equal(
                np.asarray(y), expect, err_msg=f"{head} {chains} {failed} {frames}")
            assert not np.asarray(y)[failed].any()  # dead node untouched

    # validation: dropping the head or a non-member must raise
    def expect_value_error(fn):
        try:
            jax.jit(jax.shard_map(fn, mesh=mesh, in_specs=P('x'), out_specs=P('x')))(xs)
        except ValueError:
            return
        raise SystemExit("expected ValueError")
    expect_value_error(lambda x: cw.degraded_multi_chain_broadcast(
        x[0], 'x', 0, [(1, 2)], 0)[None])
    expect_value_error(lambda x: cw.degraded_multi_chain_broadcast(
        x[0], 'x', 0, [(1, 2)], 5)[None])

    # every destination failed: only the head keeps its payload
    y = jax.jit(jax.shard_map(
        lambda x: cw.degraded_multi_chain_broadcast(x[0], 'x', 3, [(6,)], 6)[None],
        mesh=mesh, in_specs=P('x'), out_specs=P('x')))(xs)
    expect = ref.degraded_multi_broadcast_ref(np.asarray(xs), 3, [(6,)], 6)
    np.testing.assert_array_equal(np.asarray(y), expect)
    print("degraded broadcast OK")
    """, timeout=900)


def test_multichain_plan_reform_and_broadcast(run_multidevice):
    """MultiChainPlan: the re-formed schedule's SPMD broadcast matches
    the degraded oracle — recovery is endpoint-only (a new schedule)."""
    run_multidevice("""
    from repro.core import chainwrite_ref as ref
    from repro.core.topology import MeshTopology
    from repro.parallel.collectives import MultiChainPlan

    topo = MeshTopology(4, 2)  # the 8 devices as a 4x2 mesh
    plan = MultiChainPlan(topo, 0, [1, 2, 3, 4, 5, 6, 7], num_chains=2)
    before = [list(c) for c in plan.chains]
    assert plan.reform(5)
    mesh = jax.make_mesh((8,), ('x',), axis_types=(jax.sharding.AxisType.Auto,))
    xs = jnp.arange(8 * 4, dtype=jnp.float32).reshape(8, 4) + 1.0
    y = jax.jit(jax.shard_map(
        lambda x: plan.broadcast(x[0], 'x', num_frames=2)[None],
        mesh=mesh, in_specs=P('x'), out_specs=P('x')))(xs)
    expect = ref.degraded_multi_broadcast_ref(np.asarray(xs), 0, before, 5)
    np.testing.assert_array_equal(np.asarray(y), expect)
    print("plan reform broadcast OK")
    """, timeout=900)


def test_multi_chain_all_reduce_matches_oracle(run_multidevice):
    run_multidevice("""
    from repro.core import chainwrite as cw
    from repro.core import chainwrite_ref as ref

    mesh = jax.make_mesh((8,), ('x',), axis_types=(jax.sharding.AxisType.Auto,))
    rng = np.random.default_rng(0)
    xs = jnp.asarray(rng.normal(size=(8, 4, 3)).astype(np.float32))
    ring_sets = [
        [(0, 1, 2, 3, 4, 5, 6, 7)],                  # K=1 -> chain_all_reduce
        [(0, 1, 2, 3), (4, 5, 6, 7)],                # K=2 (hierarchical twin)
        [(0, 2), (4, 6), (1, 3), (5, 7)],            # K=4, scrambled rings
        [(3, 1, 0, 2), (7, 5, 6, 4)],                # K=2, scheduled orders
    ]
    for orders in ring_sets:
        for algo in ('rs_ag', 'rotation'):
            def f(x, orders=orders, algo=algo):
                return cw.multi_chain_all_reduce(x[0], 'x', orders, algo=algo)[None]
            y = jax.jit(jax.shard_map(f, mesh=mesh, in_specs=P('x'), out_specs=P('x')))(xs)
            np.testing.assert_allclose(
                np.asarray(y), ref.all_reduce_ref(np.asarray(xs)),
                rtol=1e-5, atol=1e-5, err_msg=f"{orders} {algo}")
            # the schedule-replaying oracle pins the result BIT-exactly
            np.testing.assert_array_equal(
                np.asarray(y),
                ref.multi_all_reduce_ref(np.asarray(xs), orders, algo),
                err_msg=f"{orders} {algo}")

    # validation: unequal rings / non-partition / unknown algo must raise
    for bad in ([(0, 1, 2), (3, 4, 5, 6, 7)], [(0, 1), (2, 3)]):
        try:
            def g(x, bad=bad):
                return cw.multi_chain_all_reduce(x[0], 'x', bad)[None]
            jax.jit(jax.shard_map(g, mesh=mesh, in_specs=P('x'), out_specs=P('x')))(xs)
            raise SystemExit("expected ValueError for " + str(bad))
        except ValueError:
            pass
    try:
        def h(x):
            return cw.multi_chain_all_reduce(
                x[0], 'x', [(0,1,2,3), (4,5,6,7)], algo='bogus')[None]
        jax.jit(jax.shard_map(h, mesh=mesh, in_specs=P('x'), out_specs=P('x')))(xs)
        raise SystemExit("expected ValueError for bad algo")
    except ValueError:
        pass
    print("multi-chain all-reduce OK")
    """, timeout=900)


def test_multi_chain_all_reduce_rs_ag_shard_padding(run_multidevice):
    """The K=4 (and K=2) RS+AG oracle suite over payload lengths NOT
    divisible by the ring size S — the shard pad/unpad path — pinned
    bit-exactly on 8 virtual devices."""
    run_multidevice("""
    from repro.core import chainwrite as cw
    from repro.core import chainwrite_ref as ref

    mesh = jax.make_mesh((8,), ('x',), axis_types=(jax.sharding.AxisType.Auto,))
    rng = np.random.default_rng(7)
    ring_sets = [
        [(0, 1, 2, 3, 4, 5, 6, 7)],                  # K=1, S=8
        [(3, 1, 0, 2), (7, 5, 6, 4)],                # K=2, S=4, scrambled
        [(0, 2), (4, 6), (1, 3), (5, 7)],            # K=4, S=2, scrambled
    ]
    for lead in (5, 6, 13):   # 5 % 2, 6 % 4, 13 % 8 all nonzero
        xs = jnp.asarray(rng.normal(size=(8, lead, 2)).astype(np.float32))
        for orders in ring_sets:
            for algo in ('rs_ag', 'rotation'):
                def f(x, orders=orders, algo=algo):
                    return cw.multi_chain_all_reduce(
                        x[0], 'x', orders, algo=algo)[None]
                y = jax.jit(jax.shard_map(
                    f, mesh=mesh, in_specs=P('x'), out_specs=P('x')))(xs)
                assert np.asarray(y).shape == xs.shape
                np.testing.assert_array_equal(
                    np.asarray(y),
                    ref.multi_all_reduce_ref(np.asarray(xs), orders, algo),
                    err_msg=f"lead={lead} {orders} {algo}")
    print("rs_ag shard padding OK")
    """, timeout=900)


def test_multi_chain_all_reduce_k1_delegates_to_chain(run_multidevice):
    """K=1 (either algo) computes exactly chain_all_reduce over the
    same scheduled ring."""
    run_multidevice("""
    from repro.core import chainwrite as cw

    mesh = jax.make_mesh((8,), ('x',), axis_types=(jax.sharding.AxisType.Auto,))
    rng = np.random.default_rng(3)
    xs = jnp.asarray(rng.normal(size=(8, 7)).astype(np.float32))
    order = (3, 1, 0, 2, 7, 5, 6, 4)
    def single(x):
        return cw.chain_all_reduce(x[0], 'x', order)[None]
    ys = jax.jit(jax.shard_map(single, mesh=mesh, in_specs=P('x'), out_specs=P('x')))(xs)
    for algo in ('rs_ag', 'rotation'):
        def multi(x, algo=algo):
            return cw.multi_chain_all_reduce(x[0], 'x', [order], algo=algo)[None]
        ym = jax.jit(jax.shard_map(multi, mesh=mesh, in_specs=P('x'), out_specs=P('x')))(xs)
        np.testing.assert_array_equal(np.asarray(ym), np.asarray(ys))
    print("K=1 delegation OK")
    """, timeout=900)


def test_degraded_broadcast_k4_matches_program_interpreter(run_multidevice):
    """K=4 degraded broadcast (oracle previously pinned only for
    K ∈ {1,2,3}): the SPMD collective must match BOTH the semantic
    oracle and the ChainProgram interpreter replaying the exact
    degraded schedule (``plan_broadcast`` over the spliced chains)."""
    run_multidevice("""
    from repro.core import chainwrite as cw
    from repro.core import chainwrite_ref as ref
    from repro.core import program as prg

    mesh = jax.make_mesh((8,), ('x',), axis_types=(jax.sharding.AxisType.Auto,))
    xs = jnp.arange(8 * 6 * 2, dtype=jnp.float32).reshape(8, 6, 2) + 1.0

    cases = [
        (0, [(1, 2), (3, 4), (5,), (6, 7)], 2),   # mid-chain
        (0, [(1, 2), (3, 4), (5,), (6, 7)], 5),   # whole sub-chain dies
        (0, [(1, 2), (3, 4), (5,), (6, 7)], 7),   # tail
        (3, [(1, 0), (2,), (4, 5), (6, 7)], 4),   # non-zero head
    ]
    for head, chains, failed in cases:
        for frames in (1, 2, 3):
            def f(x, head=head, chains=chains, failed=failed, frames=frames):
                return cw.degraded_multi_chain_broadcast(
                    x[0], 'x', head, chains, failed, num_frames=frames)[None]
            y = jax.jit(jax.shard_map(
                f, mesh=mesh, in_specs=P('x'), out_specs=P('x')))(xs)
            expect = ref.degraded_multi_broadcast_ref(
                np.asarray(xs), head, chains, failed)
            np.testing.assert_array_equal(
                np.asarray(y), expect, err_msg=f"{head} {chains} {failed}")
            # the program interpreter replays the degraded schedule
            prog = prg.plan_broadcast(
                8, head, tuple(cw.degraded_chains(chains, failed)))
            replay = ref.run_program_ref(np.asarray(xs), prog)
            np.testing.assert_array_equal(
                np.asarray(y), replay, err_msg=f"replay {head} {failed}")
            assert not np.asarray(y)[failed].any()  # dead node untouched
    print("degraded K=4 OK")
    """, timeout=900)


def test_degraded_broadcast_two_dead_nodes_k3(run_multidevice):
    """ISSUE-5 concurrent failures: TWO dead members dropped in one
    degraded K=3 broadcast must be bit-exact against the failure-set
    oracle AND against the program interpreter replaying the spliced
    schedule — dead nodes, like non-members, stay untouched."""
    run_multidevice("""
    from repro.core import chainwrite as cw
    from repro.core import chainwrite_ref as ref
    from repro.core import program as prg

    mesh = jax.make_mesh((8,), ('x',), axis_types=(jax.sharding.AxisType.Auto,))
    xs = jnp.arange(8 * 6 * 2, dtype=jnp.float32).reshape(8, 6, 2) + 1.0

    cases = [
        (0, [(1, 2, 3), (4, 5), (6, 7)], {2, 5}),   # two distinct chains
        (0, [(1, 2, 3), (4, 5), (6, 7)], {1, 3}),   # same chain twice
        (0, [(1, 2, 3), (4, 5), (6, 7)], {6, 7}),   # a whole chain dies
        (4, [(5, 6, 7), (3, 2), (1, 0)], {5, 2}),   # non-zero head
    ]
    for head, chains, failed in cases:
        for frames in (1, 2):
            def f(x, head=head, chains=chains, failed=failed, frames=frames):
                return cw.degraded_multi_chain_broadcast(
                    x[0], 'x', head, chains, frozenset(failed),
                    num_frames=frames)[None]
            y = jax.jit(jax.shard_map(
                f, mesh=mesh, in_specs=P('x'), out_specs=P('x')))(xs)
            expect = ref.degraded_multi_broadcast_ref(
                np.asarray(xs), head, chains, failed)
            np.testing.assert_array_equal(
                np.asarray(y), expect, err_msg=f"{head} {chains} {failed}")
            prog = prg.plan_broadcast(
                8, head, tuple(cw.degraded_chains(chains, failed)))
            replay = ref.run_program_ref(np.asarray(xs), prog)
            np.testing.assert_array_equal(
                np.asarray(y), replay, err_msg=f"replay {head} {failed}")
            for dead in failed:
                assert not np.asarray(y)[dead].any()

    # validation: a set containing the head, or any non-member, raises
    def expect_value_error(fn):
        try:
            jax.jit(jax.shard_map(fn, mesh=mesh, in_specs=P('x'), out_specs=P('x')))(xs)
        except ValueError:
            return
        raise SystemExit("expected ValueError")
    expect_value_error(lambda x: cw.degraded_multi_chain_broadcast(
        x[0], 'x', 0, [(1, 2)], frozenset({0, 1}))[None])
    expect_value_error(lambda x: cw.degraded_multi_chain_broadcast(
        x[0], 'x', 0, [(1, 2)], frozenset({1, 5}))[None])
    print("degraded two-dead K=3 OK")
    """, timeout=900)


def test_multi_ring_rs_ag_a2a_match_program_oracles(run_multidevice):
    """The new K-ring reduce-scatter / all-gather / all-to-all SPMD
    collectives, pinned BIT-exactly against the program interpreter
    (and semantically against the schedule-free oracles)."""
    run_multidevice("""
    from repro.core import chainwrite as cw
    from repro.core import chainwrite_ref as ref

    mesh = jax.make_mesh((8,), ('x',), axis_types=(jax.sharding.AxisType.Auto,))
    rng = np.random.default_rng(11)
    ring_sets = [
        [(0, 1, 2, 3, 4, 5, 6, 7)],
        [(3, 1, 0, 2), (7, 5, 6, 4)],
        [(0, 2), (4, 6), (1, 3), (5, 7)],
    ]
    xs = jnp.asarray(rng.normal(size=(8, 4, 3)).astype(np.float32))
    xs2 = jnp.asarray(rng.normal(size=(8, 8, 5)).astype(np.float32))
    for orders in ring_sets:
        def ag(x, o=orders):
            return cw.multi_chain_all_gather(x[0], 'x', o)[None]
        y = jax.jit(jax.shard_map(ag, mesh=mesh, in_specs=P('x'), out_specs=P('x')))(xs)
        np.testing.assert_array_equal(
            np.asarray(y), ref.multi_all_gather_ref(np.asarray(xs), orders))
        np.testing.assert_allclose(
            np.asarray(y), ref.all_gather_ref(np.asarray(xs)), rtol=1e-6)

        def agt(x, o=orders):
            return cw.multi_chain_all_gather(x[0], 'x', o, tiled=True)[None]
        y = jax.jit(jax.shard_map(agt, mesh=mesh, in_specs=P('x'), out_specs=P('x')))(xs)
        np.testing.assert_array_equal(
            np.asarray(y),
            ref.multi_all_gather_ref(np.asarray(xs), orders, tiled=True))

        def rs(x, o=orders):
            return cw.multi_chain_reduce_scatter(x[0], 'x', o)[None]
        y = jax.jit(jax.shard_map(rs, mesh=mesh, in_specs=P('x'), out_specs=P('x')))(xs2)
        np.testing.assert_array_equal(
            np.asarray(y), ref.multi_reduce_scatter_ref(np.asarray(xs2), orders))
        np.testing.assert_allclose(
            np.asarray(y), ref.reduce_scatter_ref(np.asarray(xs2)),
            rtol=1e-5, atol=1e-5)

        def a2a(x, o=orders):
            return cw.multi_chain_all_to_all(x[0], 'x', o)[None]
        y = jax.jit(jax.shard_map(a2a, mesh=mesh, in_specs=P('x'), out_specs=P('x')))(xs2)
        np.testing.assert_array_equal(
            np.asarray(y), ref.all_to_all_ref(np.asarray(xs2)))

    # K=1 wrappers and multi variants interpret the identical program
    def single(x):
        return cw.chain_reduce_scatter(x[0], 'x', (3, 1, 0, 2, 7, 5, 6, 4))[None]
    def multi(x):
        return cw.multi_chain_reduce_scatter(
            x[0], 'x', [(3, 1, 0, 2, 7, 5, 6, 4)])[None]
    ys = jax.jit(jax.shard_map(single, mesh=mesh, in_specs=P('x'), out_specs=P('x')))(xs2)
    ym = jax.jit(jax.shard_map(multi, mesh=mesh, in_specs=P('x'), out_specs=P('x')))(xs2)
    np.testing.assert_array_equal(np.asarray(ys), np.asarray(ym))

    # validation parity: non-partitions raise
    for bad in ([(0, 1, 2), (3, 4, 5, 6, 7)], [(0, 1), (2, 3)]):
        for fn in (cw.multi_chain_reduce_scatter, cw.multi_chain_all_to_all):
            try:
                jax.jit(jax.shard_map(
                    lambda x, b=bad, f=fn: f(x[0], 'x', b)[None],
                    mesh=mesh, in_specs=P('x'), out_specs=P('x')))(xs2)
                raise SystemExit("expected ValueError for " + str(bad))
            except ValueError:
                pass
    print("multi-ring rs/ag/a2a OK")
    """, timeout=900)


def test_moe_ep_dispatch_end_to_end(run_multidevice):
    """Torrent MoE expert parallelism: moe_apply_ep inside shard_map
    over 8 devices — Torrent chain a2a dispatch/combine — matches the
    dense per-token reference at generous capacity, for K ∈ {1, 2}
    dispatch chains; and the cfg.moe_ep_dispatch auto path (nested
    subset shard_map under GSPMD) produces the same result."""
    run_multidevice("""
    import dataclasses
    from repro import configs as C
    from repro.models import moe as M

    cfg = C.get_smoke_config("deepseek-moe-16b")
    cfg = dataclasses.replace(cfg, capacity_factor=8.0)
    assert cfg.num_experts % 8 == 0
    params = M.moe_init(jax.random.PRNGKey(0), cfg)
    B, S, d = 8, 4, cfg.d_model
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, d), jnp.float32) * 0.5
    mesh = jax.make_mesh((8,), ('data',), axis_types=(jax.sharding.AxisType.Auto,))
    want = np.asarray(M.moe_ref(params, x, cfg))
    flat_out, flat_aux = M.moe_apply(params, x, cfg)

    outs = {}
    for k in (1, 2):
        def ep(p, xs, k=k):
            return M.moe_apply_ep(p, xs, cfg, 'data', num_chains=k)
        out, aux = jax.jit(jax.shard_map(
            ep, mesh=mesh, in_specs=(P(), P('data')),
            out_specs=(P('data'), P()), check_vma=False))(params, x)
        np.testing.assert_allclose(np.asarray(out), want, rtol=2e-2, atol=2e-2)
        np.testing.assert_allclose(
            float(aux), float(flat_aux), rtol=1e-4, atol=1e-6)
        outs[k] = np.asarray(out)
    np.testing.assert_array_equal(outs[1], outs[2])

    # the auto path: cfg.moe_ep_dispatch under GSPMD (jax.set_mesh)
    cfg_ep = dataclasses.replace(cfg, moe_ep_dispatch=True)
    with jax.set_mesh(mesh):
        out_auto, aux_auto = jax.jit(
            lambda p, xs: M.moe_apply(p, xs, cfg_ep))(params, x)
    np.testing.assert_array_equal(np.asarray(out_auto), outs[1])

    # int8 token payloads on the dispatch/return exchanges
    # (wire_dtype="int8"): lossy but close to the dense reference, and
    # the expert-id metadata stays exact (routing unchanged)
    def ep_int8(p, xs):
        return M.moe_apply_ep(p, xs, cfg, 'data', wire_dtype='int8')
    out8, aux8 = jax.jit(jax.shard_map(
        ep_int8, mesh=mesh, in_specs=(P(), P('data')),
        out_specs=(P('data'), P()), check_vma=False))(params, x)
    scale = np.abs(want).max()
    assert np.abs(np.asarray(out8) - want).max() / scale < 0.1
    np.testing.assert_allclose(float(aux8), float(flat_aux),
                               rtol=1e-4, atol=1e-6)
    cfg_ep8 = dataclasses.replace(cfg_ep, moe_ep_int8_wire=True)
    with jax.set_mesh(mesh):
        out_auto8, _ = jax.jit(
            lambda p, xs: M.moe_apply(p, xs, cfg_ep8))(params, x)
    np.testing.assert_array_equal(np.asarray(out_auto8), np.asarray(out8))

    # gradients flow through the dispatch/combine exchanges
    def loss(p, xs):
        def inner(pp, xx):
            return M.moe_apply_ep(pp, xx, cfg, 'data')
        o, a = jax.shard_map(
            inner, mesh=mesh, in_specs=(P(), P('data')),
            out_specs=(P('data'), P()), check_vma=False)(p, xs)
        return jnp.mean(o ** 2) + a
    g = jax.jit(jax.grad(loss))(params, x)
    assert all(np.isfinite(np.asarray(v)).all() for v in jax.tree.leaves(g))
    print("moe ep OK")
    """, timeout=900)


def test_torrent_grad_reduce_num_chains(run_multidevice):
    """The num_chains/algo knobs: identical grads for K in {1, 2, 4,
    "auto"} under either all-reduce schedule."""
    run_multidevice("""
    from repro.parallel.collectives import (
        auto_ring_chains, torrent_grad_reduce, sub_ring_orders)

    assert sub_ring_orders(8, 2) == [(0, 1, 2, 3), (4, 5, 6, 7)]
    try:
        sub_ring_orders(8, 3)
        raise SystemExit("expected ValueError")
    except ValueError:
        pass
    try:
        torrent_grad_reduce(lambda p, b: (p, {}), None, None, algo='bogus')
        raise SystemExit("expected ValueError for bad algo")
    except ValueError:
        pass
    # the auto resolver returns a divisor-K partition of the group
    k, rings = auto_ring_chains(8, 1 << 20)
    assert 8 % k == 0
    assert sorted(d for r in rings for d in r) == list(range(8))

    mesh = jax.make_mesh((8,), ('data',), axis_types=(jax.sharding.AxisType.Auto,))
    def grad_fn(params, batch):
        g = jax.grad(lambda p: jnp.mean((batch @ p['w']) ** 2))(params)
        loss = jnp.mean((batch @ params['w']) ** 2)
        return g, {'loss': loss}

    params = {'w': jnp.ones((4, 2))}
    rng = np.random.default_rng(0)
    batch = jnp.asarray(rng.normal(size=(16, 4)).astype(np.float32))
    outs = {}
    for k in (1, 2, 4, 'auto'):
        for algo in ('rs_ag', 'rotation'):
            f = torrent_grad_reduce(grad_fn, mesh, P('data'),
                                    num_chains=k, algo=algo,
                                    hierarchical=False)
            g, m = f(params, batch)
            outs[(k, algo)] = np.asarray(g['w'])
    base = outs[(1, 'rs_ag')]
    for key, got in outs.items():
        np.testing.assert_allclose(base, got, rtol=1e-5, atol=1e-6, err_msg=str(key))
    ref_g = np.asarray(jax.grad(lambda p: jnp.mean((batch @ p['w']) ** 2))(params)['w'])
    np.testing.assert_allclose(base, ref_g, rtol=1e-4, atol=1e-6)
    print("num_chains grad reduce OK")
    """, timeout=900)
