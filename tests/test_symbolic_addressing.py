"""Symbolic shard addressing: materialized equivalence + cache audits.

Three pins on the symbolic-addressing refactor of ``core/program.py``:

1. **Dense equivalence (property test).** Every table the live planners
   emit symbolically (``Affine`` / ``MemberLookup`` / ``Diag`` /
   ``AtDevices``) must materialize — via ``resolve_table`` — to exactly
   the dense tuple the pre-refactor planners built for the same inputs.
   The pre-refactor module is vendored verbatim as
   ``tests/_dense_planners.py`` (a frozen golden reference), so this is
   a bit-exact schedule pin, not a semantic approximation. Random
   rings/partitions at L ≤ 64 cover all five planners plus recovery,
   including scrambled (non-canonical) ring orders.

2. **Golden large-ring schedules (device-free, QUICK lane).** Planning
   + ``validate()`` for L ∈ {256, 1024} completes in seconds because
   both are now O(L) per step, and spot-checked ``resolve_row`` values
   match closed forms. The 1024-ring all-to-all is the ROADMAP
   acceptance case.

3. **Planner cache audit (mirrors the PR 8 ``auto_ring_chains``
   audit).** The six planner caches are bounded, expose stats, key
   completely on everything that changes the plan, and do NOT key on
   ``wire_dtype`` (wire variants are O(1) ``with_wire_dtype`` replicas
   of one cached base).
"""

from __future__ import annotations

import pickle
import random
import time

import pytest

import _dense_planners as old
from _hypothesis_compat import given, settings, strategies as st

from repro.core import program as prg
from repro.core.topology import MeshTopology

# (L, K) partitions exercised by the property test: mixes K=1, K=L
# (S=1), and proper multi-ring splits.
_PARTITIONS = [
    (2, 1), (4, 1), (4, 2), (6, 2), (8, 1), (8, 2), (8, 4), (8, 8),
    (12, 3), (16, 2), (16, 4), (24, 4), (32, 8), (48, 6), (64, 4),
]


def _scrambled_rings(L: int, K: int, seed: int) -> tuple[tuple[int, ...], ...]:
    """K contiguous slices of a seeded permutation of range(L)."""
    perm = list(range(L))
    random.Random(seed).shuffle(perm)
    S = L // K
    return tuple(tuple(perm[i * S : (i + 1) * S]) for i in range(K))


def _materialize(program, table):
    return None if table is None else prg.resolve_table(program, table)


def assert_programs_dense_equal(new_p, old_p):
    """Field-by-field: the symbolic program materializes to the dense one."""
    for fld in (
        "collective", "kind", "num_devices", "addr_shards", "out_slots",
        "groups", "head", "algo", "group_heads", "wire_dtype",
    ):
        assert getattr(new_p, fld) == getattr(old_p, fld), fld
    assert _materialize(new_p, new_p.buf_init) == old_p.buf_init
    assert _materialize(new_p, new_p.out_init) == old_p.out_init
    assert len(new_p.steps) == len(old_p.steps)
    for t, (sn, so) in enumerate(zip(new_p.steps, old_p.steps)):
        assert sn.edges == so.edges, t
        assert sn.width == so.width, t
        assert sn.combine == so.combine, t
        assert sn.add_from == so.add_from, t
        assert sn.write_op == so.write_op, t
        assert sn.tag == so.tag, t
        assert sn.wire_dtype == so.wire_dtype, t
        for fld in ("add_src", "load", "write"):
            got = _materialize(new_p, getattr(sn, fld))
            want = getattr(so, fld)
            assert got == want, f"step {t} {fld}"
        # single-row resolution agrees with the full table
        if sn.write is not None:
            for d in (0, new_p.num_devices - 1):
                assert prg.resolve_row(new_p, sn.write, d) == so.write[d]


@settings(max_examples=40, deadline=None)
@given(
    part=st.sampled_from(_PARTITIONS),
    seed=st.integers(min_value=0, max_value=10**6),
    scramble=st.booleans(),
)
def test_planners_materialize_to_prerefactor_dense_tables(
    part, seed, scramble
):
    L, K = part
    rings = (
        _scrambled_rings(L, K, seed)
        if scramble
        else tuple(
            tuple(range(i * (L // K), (i + 1) * (L // K))) for i in range(K)
        )
    )
    cases = [
        (prg.plan_all_gather(L, rings), old.plan_all_gather(L, rings)),
        (prg.plan_reduce_scatter(L, rings), old.plan_reduce_scatter(L, rings)),
        (prg.plan_all_to_all(L, rings), old.plan_all_to_all(L, rings)),
    ]
    for algo in prg.ALL_REDUCE_ALGOS:
        wire = "int8" if seed % 2 else None
        cases.append(
            (
                prg.plan_all_reduce(L, rings, algo=algo, wire_dtype=wire),
                old.plan_all_reduce(L, rings, algo=algo, wire_dtype=wire),
            )
        )
    # broadcast: head = first member, chains = the rings minus the head
    head = rings[0][0]
    chains = tuple(
        c for c in (rings[0][1:],) + rings[1:] if len(c)
    )
    cases.append(
        (
            prg.plan_broadcast(L, head, chains),
            old.plan_broadcast(L, head, chains),
        )
    )
    for new_p, old_p in cases:
        assert_programs_dense_equal(new_p, old_p)


def test_noncanonical_ring_sets_match_dense():
    """The scrambled K=2 rings from test_program.py exercise the
    irregular (non-canonical) ring context fallback explicitly."""
    rings = ((3, 1, 0, 2), (7, 5, 6, 4))
    for maker in (
        lambda m: m.plan_all_gather(8, rings),
        lambda m: m.plan_reduce_scatter(8, rings),
        lambda m: m.plan_all_reduce(8, rings, algo="rs_ag"),
        lambda m: m.plan_all_reduce(8, rings, algo="rotation"),
        lambda m: m.plan_all_to_all(8, rings),
    ):
        assert_programs_dense_equal(maker(prg), maker(old))


def test_recovery_planner_matches_dense():
    topo = MeshTopology(4, 2)
    chains = ((1, 2, 3), (4, 5, 6, 7))
    for failed in (2, 4, (2, 5)):
        new_p = prg.plan_recovery(topo, 0, chains, failed)
        old_p = old.plan_recovery(topo, 0, chains, failed)
        assert_programs_dense_equal(new_p, old_p)


# ---------------------------------------------------------------------------
# Golden large-ring schedules — device-free, QUICK lane.
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("L,K", [(256, 8), (1024, 16)])
def test_large_ring_a2a_plans_in_seconds(L, K):
    """O(L) planning + validation: the 1024-ring all-to-all (the
    ROADMAP acceptance case) plans and validates in seconds without
    ever materializing an L×L table."""
    S = L // K
    rings = tuple(
        tuple(range(i * S, (i + 1) * S)) for i in range(K)
    )
    prg.clear_planner_caches()
    t0 = time.perf_counter()
    p = prg.plan_all_to_all(L, rings)
    elapsed = time.perf_counter() - t0
    assert elapsed < 30.0, f"plan+validate took {elapsed:.1f}s"
    assert len(p.steps) == L - 1  # chunk train cannot shrink
    assert p.addr_shards == L and p.out_slots == L
    # no dense table anywhere in the program
    tables = [p.buf_init, p.out_init]
    for s in p.steps:
        tables += [t for t in (s.add_src, s.load, s.write) if t is not None]
    assert not any(isinstance(t, tuple) for t in tables)
    # spot checks against closed forms: every device's train starts as
    # the identity chunk order, and the final output is chunk j from
    # source j (out_init row d has slot d at its own column only).
    for d in (0, L // 2, L - 1):
        assert prg.resolve_row(p, p.buf_init, d) == tuple(range(L))
        own = prg.resolve_row(p, p.out_init, d)
        assert own[d] == d and all(
            v == -1 for j, v in enumerate(own) if j != d
        )


@pytest.mark.parametrize("L,K", [(256, 8), (1024, 16)])
def test_large_ring_all_reduce_golden(L, K):
    S = L // K
    rings = tuple(
        tuple(range(i * S, (i + 1) * S)) for i in range(K)
    )
    t0 = time.perf_counter()
    p = prg.plan_all_reduce(L, rings, algo="rs_ag")
    elapsed = time.perf_counter() - t0
    assert elapsed < 30.0
    # rs_ag over K rings: (S-1) RS + (S-1) AG intra steps, plus K-1
    # cross-ring rotation steps in between.
    assert len(p.steps) == 2 * (S - 1) + (K - 1)
    assert p.addr_shards == S
    # position-addressed chunks: the RS add target depends only on the
    # device's ring position (d % S), never on which ring it sits in
    for d in (0, S - 1, L - 1):
        row = prg.resolve_row(p, p.steps[0].add_src, d)
        assert row == prg.resolve_row(p, p.steps[0].add_src, d % S)
        assert 0 <= row[0] < S


def test_program_pickle_size_scales_linearly():
    """The serialized program must not hide O(L^2) dense state: pickle
    bytes per step stay O(K), not O(L)."""
    sizes = {}
    for L, K in ((256, 8), (1024, 8)):
        S = L // K
        rings = tuple(
            tuple(range(i * S, (i + 1) * S)) for i in range(K)
        )
        p = prg.plan_all_to_all(L, rings)
        sizes[L] = len(pickle.dumps(p)) / len(p.steps)
    # quadrupling L (same K) must not even double per-step bytes
    assert sizes[1024] < 2 * sizes[256], sizes


# ---------------------------------------------------------------------------
# Planner cache audit (satellite: bounded caches + complete keys).
# ---------------------------------------------------------------------------


def test_planner_caches_are_bounded_and_registered():
    assert set(prg.PLANNER_CACHES) == {
        "plan_broadcast", "plan_recovery", "plan_all_gather",
        "plan_reduce_scatter", "plan_all_reduce", "plan_all_to_all",
    }
    for name, fn in prg.PLANNER_CACHES.items():
        assert fn.cache_info().maxsize == prg._PLANNER_CACHE_MAXSIZE, name
    stats = prg.planner_cache_stats()
    assert set(stats) == set(prg.PLANNER_CACHES)
    for name, s in stats.items():
        assert {"hits", "misses", "maxsize", "currsize"} <= set(s), name


def test_planner_cache_keys_are_complete_and_wire_free():
    """Distinct (L, rings, algo) inputs never alias; wire_dtype is NOT
    part of the key — int8 variants are with_wire_dtype replicas of one
    cached base program."""
    prg.clear_planner_caches()
    assert all(
        s["currsize"] == 0 for s in prg.planner_cache_stats().values()
    )
    r8 = (tuple(range(8)),)
    r44 = ((0, 1, 2, 3), (4, 5, 6, 7))
    a = prg.plan_all_reduce(8, r8, algo="rs_ag")
    b = prg.plan_all_reduce(8, r8, algo="rotation")
    c = prg.plan_all_reduce(8, r44, algo="rs_ag")
    info = prg.PLANNER_CACHES["plan_all_reduce"].cache_info()
    assert info.currsize == 3  # algo and ring set are both in the key
    # wire variants share the cached base: no new entry, O(1) replace
    q = prg.plan_all_reduce(8, r8, algo="rs_ag", wire_dtype="int8")
    assert prg.PLANNER_CACHES["plan_all_reduce"].cache_info().currsize == 3
    assert q.wire_dtype == "int8" and q.steps[0].edges == a.steps[0].edges
    assert q.with_wire_dtype(None) is not q
    assert a.with_wire_dtype(None) is a  # no-op returns the same object
    # cold-vs-warm agreement regardless of call order
    prg.clear_planner_caches()
    assert prg.plan_all_reduce(8, r44, algo="rs_ag") == c
    assert prg.plan_all_reduce(8, r8, algo="rotation") == b
    assert prg.plan_all_reduce(8, r8, algo="rs_ag") == a
    # same completeness for all_to_all (the other wire-capable planner)
    prg.clear_planner_caches()
    prg.plan_all_to_all(8, r8)
    prg.plan_all_to_all(8, r44)
    prg.plan_all_to_all(8, r44, wire_dtype="int8")
    assert prg.PLANNER_CACHES["plan_all_to_all"].cache_info().currsize == 2


def test_plan_recovery_cache_distinguishes_tiered_topology():
    """plan_recovery is the one planner keyed on the topology: a
    weighted link graph of the same shape must be a distinct cache
    entry from the uniform mesh (its reform routes price differently),
    which holds because the frozen topology object IS part of the key."""
    from repro.core.topology import MeshTopology, TieredMeshTopology

    prg.clear_planner_caches()
    flat = MeshTopology(8, 8)
    tiered = TieredMeshTopology(8, 8, pods_x=2, pods_y=2,
                                interpod_bw=0.25, interpod_latency=4)
    chains = ((1, 2, 3), (4, 5, 6))
    prg.plan_recovery(flat, 0, chains, frozenset({2}))
    prg.plan_recovery(tiered, 0, chains, frozenset({2}))
    info = prg.PLANNER_CACHES["plan_recovery"].cache_info()
    assert info.currsize == 2 and info.misses == 2
    # warm hit on each: the two topologies stay separate entries
    prg.plan_recovery(flat, 0, chains, frozenset({2}))
    prg.plan_recovery(tiered, 0, chains, frozenset({2}))
    info = prg.PLANNER_CACHES["plan_recovery"].cache_info()
    assert info.currsize == 2 and info.hits == 2
