"""Cycle-level NoC model: Fig. 5 / Fig. 7 calibration and invariants."""

from __future__ import annotations

import pytest

from repro.core.simulator import (
    DEFAULT_PARAMS,
    chainwrite_latency,
    config_overhead_per_destination,
    eta_p2mp,
    multicast_latency,
    p2mp_efficiency_point,
    p2p_latency,
    unicast_latency,
)
from repro.core.scheduling import SCHEDULERS
from repro.core.topology import MeshTopology

TOPO = MeshTopology(4, 5)  # the paper's 20-cluster Occamy-derived SoC


def test_unicast_eta_at_most_one():
    """iDMA re-reads the source per destination: eta <= 1 (paper Eq. 1)."""
    for n_dst in (2, 4, 8, 16):
        for size_kb in (1, 8, 64, 128):
            dsts = list(range(1, 1 + n_dst))
            lat = unicast_latency(TOPO, 0, dsts, size_kb * 1024)
            assert eta_p2mp(n_dst, size_kb * 1024, lat) <= 1.0 + 1e-9


def test_chainwrite_eta_approaches_ndst():
    """Large transfers amortize the 4-phase overhead: eta -> N_dst."""
    n_dst = 8
    dsts = list(range(1, 1 + n_dst))
    order = SCHEDULERS["greedy"](TOPO, dsts, 0)
    big = chainwrite_latency(TOPO, 0, order, 128 * 1024)
    eta = eta_p2mp(n_dst, 128 * 1024, big)
    # paper's own calibration (82 CC/dst) implies eta ~= 6.06/8 at 128 KB:
    # 8*2048 / (2048 + 8*82) — asymptotically -> N_dst with size.
    assert eta > 0.7 * n_dst, eta
    huge = chainwrite_latency(TOPO, 0, order, 4 * 1024 * 1024)
    assert eta_p2mp(n_dst, 4 * 1024 * 1024, huge) > 0.95 * n_dst
    # and grows with size
    small = chainwrite_latency(TOPO, 0, order, 1024)
    assert eta_p2mp(n_dst, 1024, small) < eta


def test_small_transfers_control_dominated():
    """Paper: at 1-4 KB the control overhead dominates (eta well below ideal)."""
    dsts = list(range(1, 9))
    order = SCHEDULERS["greedy"](TOPO, dsts, 0)
    lat = chainwrite_latency(TOPO, 0, order, 1024)
    assert eta_p2mp(8, 1024, lat) < 0.5 * 8


def test_multicast_beats_chainwrite_for_few_dsts():
    """Paper Fig. 5: ESP better at N_dst 2-4 (lower setup)."""
    pt = p2mp_efficiency_point(TOPO, 0, [1, 2], 8 * 1024)
    assert pt["eta_multicast"] > pt["eta_chainwrite"]


def test_chainwrite_competitive_at_many_dsts():
    """...but Torrent's linear config scaling wins at N_dst = 16."""
    dsts = list(range(1, 17))
    pt = p2mp_efficiency_point(TOPO, 0, dsts, 64 * 1024)
    assert pt["eta_chainwrite"] > pt["eta_multicast"] * 0.95
    # both beat unicast by a wide margin
    assert pt["eta_chainwrite"] > 4 * pt["eta_unicast"]


def test_fig7_config_overhead_is_82cc_per_dst():
    """Fig. 7 calibration: 64 KB chainwrite, 1-8 dests -> 82 CC slope."""
    res = config_overhead_per_destination(TOPO, src=0, max_dsts=8)
    assert res["slope_cc_per_dst"] == pytest.approx(82.0, abs=3.0)
    lats = res["latencies_cc"]
    # strictly increasing, near-linear trend (the chain turning a mesh
    # corner adds a couple of router cycles at one step)
    assert all(b > a for a, b in zip(lats, lats[1:]))
    diffs = [b - a for a, b in zip(lats, lats[1:])]
    assert max(diffs) - min(diffs) <= 16


def test_p2p_latency_components():
    p = DEFAULT_PARAMS
    lat = p2p_latency(TOPO, 0, 1, 64)
    assert lat == p.dma_setup_cc + 1 * p.router_cc + 1  # 64B = 1 cycle


def test_multicast_setup_superlinear():
    """ESP config complexity grows faster than Torrent's (paper §IV-B)."""
    size = 4 * 1024

    def marginal(fn, n):
        a = fn(list(range(1, n)), size)
        b = fn(list(range(1, n + 1)), size)
        return b - a

    def mc(dsts, s):
        return multicast_latency(TOPO, 0, dsts, s)

    def cw(dsts, s):
        order = SCHEDULERS["greedy"](TOPO, dsts, 0)
        return chainwrite_latency(TOPO, 0, order, s)

    # multicast marginal cost grows with n; chainwrite stays ~constant
    assert marginal(mc, 16) > marginal(mc, 4)
    assert abs(marginal(cw, 16) - marginal(cw, 4)) <= 100


def test_speedup_vs_unicast_in_paper_range():
    """Best-case chainwrite speedup lands in the paper's 2-8x zone."""
    dsts = list(range(1, 17))
    order = SCHEDULERS["tsp"](TOPO, dsts, 0)
    size = 128 * 1024
    s = unicast_latency(TOPO, 0, dsts, size) / chainwrite_latency(TOPO, 0, order, size)
    assert 2.0 < s < 20.0
