"""Pallas relayout (DSE) kernel: shape/dtype sweep vs pure-jnp oracle."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.kernels.relayout import ops

BLOCKS = [(16, 8), (8, 8), (64, 16), (16, 16)]  # the paper's layouts


def _rand(shape, dtype, seed=0):
    x = jax.random.normal(jax.random.PRNGKey(seed), shape, jnp.float32)
    if jnp.issubdtype(dtype, jnp.integer):
        return (x * 10).astype(dtype)
    return x.astype(dtype)


@pytest.mark.parametrize("src", BLOCKS)
@pytest.mark.parametrize("dst", BLOCKS)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16, jnp.int8])
def test_relayout_sweep(src, dst, dtype):
    shape = (256, 192) if dtype != jnp.int8 else (128, 64)
    if any(shape[0] % b[0] or shape[1] % b[1] for b in (src, dst)):
        pytest.skip("blocks must divide shape")
    dense = _rand(shape, dtype)
    x = ops.dense_to_blocked(dense, src)
    got = ops.relayout(x, shape, src, dst)
    want = ops.relayout_ref(x, shape, src, dst)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    # round-trip through dense
    np.testing.assert_array_equal(
        np.asarray(ops.blocked_to_dense(got, shape)), np.asarray(dense)
    )


def test_paper_layout_strings():
    """P1/P2 workloads: MNM16N8 -> MNM8N8; D1/D2: MNM16N8 -> MNM64N16."""
    assert ops.parse_layout("MNM16N8") == (16, 8)
    assert ops.parse_layout("MNM64N16") == (64, 16)
    with pytest.raises(ValueError):
        ops.parse_layout("N8M16")
    shape = (2048, 192)  # paper P1 QK^T single head shape
    dense = _rand(shape, jnp.bfloat16)
    x = ops.dense_to_blocked(dense, (16, 8))
    got = ops.relayout_str(x, shape, "MNM16N8", "MNM8N8")
    want = ops.relayout_ref(x, shape, (16, 8), (8, 8))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_identity_relayout():
    shape = (64, 64)
    x = ops.dense_to_blocked(_rand(shape, jnp.float32), (16, 8))
    got = ops.relayout(x, shape, (16, 8), (16, 8))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(x))


def test_indivisible_raises():
    x = jnp.zeros((4, 4, 16, 8))
    with pytest.raises(ValueError):
        ops.relayout(x, (64, 32), (16, 8), (24, 8))


@settings(max_examples=25, deadline=None)
@given(
    mi=st.integers(1, 6),
    ni=st.integers(1, 6),
    si=st.sampled_from(BLOCKS),
    di=st.sampled_from(BLOCKS),
)
def test_relayout_property(mi, ni, si, di):
    """Random multiples of lcm(block) shapes: kernel == oracle."""
    import math

    lm = math.lcm(si[0], di[0])
    ln = math.lcm(si[1], di[1])
    shape = (lm * mi, ln * ni)
    dense = _rand(shape, jnp.float32, seed=mi * 7 + ni)
    x = ops.dense_to_blocked(dense, si)
    got = ops.relayout(x, shape, si, di)
    want = ops.relayout_ref(x, shape, si, di)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
