"""PartitionSpec rules, ZeRO-1, elastic resharding, multi-device steps."""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro import configs as C
from repro.models import transformer as T
from repro.optim import adamw
from repro.parallel import sharding as shd
from repro.runtime.elastic import choose_mesh_shape


def _leaf_specs(arch: str, tp: int):
    cfg = C.get_config(arch)
    shapes = jax.eval_shape(lambda: T.model_init(jax.random.PRNGKey(0), cfg))
    specs = shd.param_pspecs(shapes, cfg, tp=tp)
    return cfg, shapes, specs


def test_param_specs_cover_every_leaf():
    for arch in C.ARCHS:
        cfg, shapes, specs = _leaf_specs(arch, tp=16)
        ls, ss = jax.tree.leaves(shapes), jax.tree.leaves(
            specs, is_leaf=lambda x: isinstance(x, P)
        )
        assert len(ls) == len(ss), arch
        for leaf, spec in zip(ls, ss):
            assert len(spec) <= len(leaf.shape), (arch, spec, leaf.shape)
            # any sharded dim must divide by tp
            for dim, ax in zip(leaf.shape, tuple(spec) + (None,) * 8):
                if ax == "model":
                    assert dim % 16 == 0, (arch, spec, leaf.shape)


def test_indivisible_dims_stay_replicated():
    """Dims that don't divide the TP axis must be replicated, not
    padded; divisible dims must be sharded."""
    from repro.parallel.sharding import _param_spec

    cfg = C.get_config("whisper-tiny")
    # whisper wq: (384, 384) — 384 % 16 == 0 -> sharded on the out dim
    s = _param_spec(("mixer", "wq"), (384, 384), cfg, tp=16)
    assert tuple(s) == (None, "model")
    # synthetic indivisible out dim -> fully replicated
    s = _param_spec(("mixer", "wq"), (384, 250), cfg, tp=16)
    assert "model" not in tuple(s)
    # vocab table: 51865 % 16 != 0 -> replicated
    s = _param_spec(("embed", "table"), (51865, 384), cfg, tp=16)
    assert "model" not in tuple(s)
    # llama3 vocab 128256 % 16 == 0 -> vocab-sharded
    s = _param_spec(("embed", "table"), (128256, 4096), cfg, tp=16)
    assert tuple(s) == ("model", None)


def test_zero1_adds_data_axis():
    param_specs = {"w": P(None, "model")}
    shapes = {"w": jax.ShapeDtypeStruct((64, 256), jnp.float32)}
    out = adamw.zero1_specs(param_specs, shapes, data_size=16)
    assert out["mu"]["w"] == P("data", "model")
    assert out["nu"]["w"] == P("data", "model")
    # indivisible first dim -> falls back to param spec
    shapes2 = {"w": jax.ShapeDtypeStruct((10, 256), jnp.float32)}
    out2 = adamw.zero1_specs({"w": P(None, "model")}, shapes2, data_size=16)
    assert out2["mu"]["w"] == P(None, "model")


@pytest.mark.parametrize(
    "n,tp,expect",
    # policy: keep TP as large as availability allows (memory-dictated),
    # absorb device-count changes in the data axis
    [(256, 16, (16, 16)), (8, 16, (1, 8)), (12, 16, (1, 12)),
     (7, 4, (7, 1)), (24, 16, (3, 8))],
)
def test_choose_mesh_shape(n, tp, expect):
    assert choose_mesh_shape(n, tp) == expect


def test_batch_and_cache_specs():
    cfg = C.get_config("llama3-8b")
    shape = C.SHAPES["train_4k"]
    b = shd.batch_pspecs(cfg, shape)
    assert b["tokens"] == P(("pod", "data"), None)
    assert b["labels"] == P(("pod", "data"), None)

    dshape = C.SHAPES["decode_32k"]
    cache = jax.eval_shape(lambda: T.init_cache(cfg, 8, 128))
    cspecs = shd.cache_pspecs(cache, cfg, dshape, tp=16)
    flat = jax.tree.leaves(cspecs, is_leaf=lambda x: isinstance(x, P))
    assert flat, "no cache specs"
    # k/v caches: batch over (pod,data), heads over model when divisible
    # llama3: kv heads = 8 -> 8 % 16 != 0 -> heads replicated
    for s in flat:
        assert "model" not in tuple(s) or True  # structural smoke


def test_elastic_reshard_roundtrip(run_multidevice):
    run_multidevice("""
    from repro.runtime.elastic import make_elastic_mesh, reshard_state
    from jax.sharding import NamedSharding

    state = {"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8),
             "b": jnp.ones((8,), jnp.float32)}
    specs = {"w": P("data", "model"), "b": P("model")}

    m1 = make_elastic_mesh(8, preferred_tp=4)   # (2, 4)
    s1 = reshard_state(state, m1, specs)
    assert s1["w"].sharding.mesh.shape["model"] == 4

    # devices "fail": rescale to 4 devices, tp capped
    m2 = make_elastic_mesh(4, preferred_tp=4)   # (1, 4)
    s2 = reshard_state(jax.device_get(s1), m2, specs)
    np.testing.assert_array_equal(np.asarray(s2["w"]), np.asarray(state["w"]))
    np.testing.assert_array_equal(np.asarray(s2["b"]), np.asarray(state["b"]))

    # computation still works on the new mesh
    out = jax.jit(lambda s: s["w"].sum() + s["b"].sum())(s2)
    assert float(out) == float(state["w"].sum() + state["b"].sum())
    print("elastic OK")
    """)


def test_dp_tp_train_step_matches_single_device(run_multidevice):
    """The same tiny train step on (2,2) mesh == single-device result."""
    run_multidevice("""
    import dataclasses
    from repro import configs as C
    from repro.models import transformer as T
    from repro.optim import adamw
    from repro.parallel import sharding as shd
    from jax.sharding import NamedSharding

    cfg = dataclasses.replace(
        C.get_smoke_config("yi-6b"), num_layers=2, d_model=64,
        num_heads=4, num_kv_heads=4, d_ff=128, vocab_size=64, head_dim=16)
    params = T.model_init(jax.random.PRNGKey(0), cfg)
    opt_cfg = adamw.OptConfig(peak_lr=1e-3, warmup_steps=1, decay_steps=10)
    opt = adamw.init(params)
    batch = {
        "tokens": jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0, 64),
        "labels": jax.random.randint(jax.random.PRNGKey(2), (4, 16), 0, 64),
    }

    def step(params, opt, batch):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: T.loss_fn(p, cfg, batch, loss_chunks=2), has_aux=True)(params)
        params, opt, om = adamw.update(opt_cfg, grads, opt, params)
        return params, opt, metrics["loss"]

    # single device
    p1, o1, l1 = jax.jit(step)(params, opt, batch)

    # (data=2, model=2) mesh with real shardings
    mesh = jax.make_mesh((2, 2), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    pspecs = shd.param_pspecs(jax.eval_shape(lambda: params), cfg, tp=2)
    psh = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs,
                       is_leaf=lambda x: isinstance(x, P))
    bsh = {k: NamedSharding(mesh, P("data", None)) for k in batch}
    params_d = jax.tree.map(jax.device_put, params, psh)
    batch_d = {k: jax.device_put(v, bsh[k]) for k, v in batch.items()}
    with jax.set_mesh(mesh):
        p2, o2, l2 = jax.jit(step)(params_d, opt, batch_d)

    assert abs(float(l1) - float(l2)) < 1e-3, (float(l1), float(l2))
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32),
            atol=2e-3, rtol=2e-3)
    print("dp/tp parity OK")
    """, timeout=900)


def test_torrent_grad_reduce_matches_xla(run_multidevice):
    """Torrent chain all-reduce gradient sync == plain data-parallel."""
    run_multidevice("""
    import dataclasses
    from repro import configs as C
    from repro.models import transformer as T
    from repro.parallel.collectives import torrent_grad_reduce
    from jax.sharding import NamedSharding

    cfg = dataclasses.replace(
        C.get_smoke_config("yi-6b"), num_layers=1, d_model=32,
        num_heads=2, num_kv_heads=2, d_ff=64, vocab_size=32, head_dim=16)
    params = T.model_init(jax.random.PRNGKey(0), cfg)
    batch = {
        "tokens": jax.random.randint(jax.random.PRNGKey(1), (8, 8), 0, 32),
        "labels": jax.random.randint(jax.random.PRNGKey(2), (8, 8), 0, 32),
    }

    def grad_fn(params, batch):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: T.loss_fn(p, cfg, batch, loss_chunks=1), has_aux=True)(params)
        return grads, metrics

    # reference: single-device grads on the full batch
    ref_grads, _ = jax.jit(grad_fn)(params, batch)

    mesh = jax.make_mesh((8, 1), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    bspecs = {k: P("data", None) for k in batch}
    wrapped = torrent_grad_reduce(grad_fn, mesh, bspecs, scheduler="tsp")
    batch_d = {k: jax.device_put(v, NamedSharding(mesh, P("data", None)))
               for k, v in batch.items()}
    with jax.set_mesh(mesh):
        grads_t, _ = jax.jit(wrapped)(params, batch_d)

    # torrent_grad_reduce returns global-MEAN grads (drop-in parity
    # with the "xla" backend) — must match single-device full-batch grads.
    for a, b in zip(jax.tree.leaves(ref_grads), jax.tree.leaves(grads_t)):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32),
            atol=3e-3, rtol=3e-3)
    print("torrent grad reduce OK")
    """, timeout=900)
