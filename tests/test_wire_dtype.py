"""Wire dtype as a first-class IR dimension.

Device-free: golden int8 schedules (4x fewer payload bytes through
``program_wire_bytes``), validation errors, the joint (K, algo,
wire_dtype) argmin, and quantize edge cases. Subprocess (8 virtual
devices): executor-vs-oracle bit-exactness including every per-hop
quantization, hierarchical 2-axis single-quantization pinning,
compress x num_chains composition, and the compress_grads HLO knob.
"""

from __future__ import annotations

import numpy as np
import pytest

from repro.core import program as prg
from repro.core.simulator import choose_num_chains, program_latency
from repro.core.topology import MeshTopology

L = 8
PAYLOAD = (1 << 18) * 4  # 256k f32 = 1 MiB
RINGS = {
    1: ((0, 1, 2, 3, 4, 5, 6, 7),),
    2: ((0, 1, 2, 3), (4, 5, 6, 7)),
    4: ((0, 1), (2, 3), (4, 5), (6, 7)),
}
# steps * (shard_elems + 4 scale bytes) per device — see Step.bytes
INT8_BYTES = {1: 458808, 2: 458780, 4: 655380}


# -- golden schedules (no devices) --------------------------------------


@pytest.mark.parametrize("k", [1, 2, 4])
def test_int8_all_reduce_golden_bytes(k):
    prog = prg.plan_all_reduce(L, RINGS[k], "rs_ag", wire_dtype="int8")
    prog.validate()
    assert prog.wire_dtype == "int8"
    got = prg.program_wire_bytes(prog, PAYLOAD)
    assert got == INT8_BYTES[k], (k, got)
    f32 = prg.program_wire_bytes(
        prg.plan_all_reduce(L, RINGS[k], "rs_ag"), PAYLOAD
    )
    # int8 frames + one f32 scale per hop: ~4x below the f32 twin
    assert got < f32 / 3.5, (k, got, f32)
    # pricing follows the bytes: the int8 program models strictly faster
    topo = MeshTopology(L, 1)
    assert program_latency(topo, 0, prog, PAYLOAD) < program_latency(
        topo, 0, prg.plan_all_reduce(L, RINGS[k], "rs_ag"), PAYLOAD
    )


def test_int8_all_to_all_golden_bytes():
    prog = prg.plan_all_to_all(L, RINGS[2], wire_dtype="int8")
    prog.validate()
    f32 = prg.program_wire_bytes(prg.plan_all_to_all(L, RINGS[2]), PAYLOAD)
    got = prg.program_wire_bytes(prog, PAYLOAD)
    assert got < f32 / 3.5, (got, f32)


def test_wire_dtype_validation():
    assert prg.normalize_wire_dtype(None) is None
    assert prg.normalize_wire_dtype("int8") == "int8"
    with pytest.raises(ValueError, match="wire dtype"):
        prg.normalize_wire_dtype("fp4")
    with pytest.raises(ValueError):
        prg.plan_all_reduce(L, RINGS[1], "rs_ag", wire_dtype="bogus")


def test_choose_num_chains_joint_argmin():
    """The K x algo x wire_dtype argmin: big payloads take the int8
    wire, tiny payloads keep the exact wire (the fixed f32-scale
    sideband dominates) and fall back to rotation."""
    topo = MeshTopology(L, 1)
    big = choose_num_chains(
        topo, 0, list(range(1, L)), 1 << 20,
        collective="all_reduce", algo="auto", wire_dtype="auto", detail=True,
    )
    assert (big["num_chains"], big["algo"], big["wire_dtype"]) == (
        2, "rs_ag", "int8"
    ), big
    tiny = choose_num_chains(
        topo, 0, list(range(1, L)), 4,
        collective="all_reduce", algo="auto", wire_dtype="auto", detail=True,
    )
    assert (tiny["num_chains"], tiny["algo"], tiny["wire_dtype"]) == (
        4, "rotation", None
    ), tiny
    # the default 2-tuple return shape is preserved
    k, rings = choose_num_chains(
        topo, 0, list(range(1, L)), 1 << 20,
        collective="all_reduce", algo="auto", wire_dtype="auto",
    )
    assert k == 2 and len(rings) == 2


# -- quantize numerics (1 device) ---------------------------------------


def test_quantize_edge_cases():
    import jax.numpy as jnp

    from repro.runtime.compression import dequantize, quantize

    # all-zero: the +1e-12 floor keeps the scale finite and q at zero
    q, s = quantize(jnp.zeros((16,), jnp.float32))
    assert float(s) > 0 and np.isfinite(float(s))
    np.testing.assert_array_equal(np.asarray(q), np.zeros(16, np.int8))
    np.testing.assert_array_equal(
        np.asarray(dequantize(q, s)), np.zeros(16, np.float32)
    )

    # inf / NaN inputs poison the scale (detectably non-finite) instead
    # of silently shipping garbage int8 frames
    for bad in (np.inf, np.nan):
        x = jnp.asarray([1.0, bad, -2.0], jnp.float32)
        _, s = quantize(x)
        assert not np.isfinite(float(s)), (bad, float(s))

    # non-divisible (padded-frame) payloads round-trip within 1.5 steps
    # (the max-abs element rounds to 128 and clips to 127, so its error
    # is a full scale rather than the half-step of interior values)
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.normal(size=(13,)).astype(np.float32))
    q, s = quantize(x)
    err = np.abs(np.asarray(dequantize(q, s)) - np.asarray(x))
    assert err.max() <= 1.5 * float(s)


def test_quantize_matches_numpy_oracle_bitwise():
    """The jitted wire format equals the numpy oracle twin bit-for-bit
    — the property every executor-vs-oracle pin below rests on."""
    import jax
    import jax.numpy as jnp

    from repro.core.chainwrite_ref import _quantize_ref
    from repro.runtime.compression import quantize

    jq = jax.jit(quantize)
    rng = np.random.default_rng(0)
    for i in range(20):
        scale_pow = float(10.0 ** rng.integers(-6, 6))
        x = (rng.normal(size=(257,)) * scale_pow).astype(np.float32)
        q, s = jq(jnp.asarray(x))
        qr, sr = _quantize_ref(x)
        np.testing.assert_array_equal(np.asarray(q), qr, err_msg=str(i))
        assert np.float32(s) == sr, (i, float(s), sr)


# -- host-side knob validation ------------------------------------------


def test_grad_reduce_knob_validation():
    from repro.parallel.collectives import torrent_grad_reduce

    with pytest.raises(ValueError, match="error_feedback"):
        torrent_grad_reduce(
            lambda p, b: (p, {}), None, None, error_feedback=True
        )
    with pytest.raises(ValueError, match="wire dtype"):
        torrent_grad_reduce(lambda p, b: (p, {}), None, None, wire_dtype="fp4")
    with pytest.raises(ValueError, match="algo"):
        torrent_grad_reduce(lambda p, b: (p, {}), None, None, algo="tree")


def test_train_step_knob_validation():
    import dataclasses

    from repro import configs as C
    from repro.launch.steps import make_train_step
    from repro.optim import adamw

    cfg = dataclasses.replace(
        C.get_smoke_config("yi-6b"), num_layers=1, d_model=16, num_heads=2,
        num_kv_heads=2, d_ff=32, vocab_size=32, head_dim=8,
    )
    opt = adamw.OptConfig()
    with pytest.raises(ValueError, match="torrent"):
        make_train_step(cfg, opt, collectives="xla", compress_grads=True)
    with pytest.raises(ValueError, match="compress_grads"):
        make_train_step(cfg, opt, collectives="torrent", error_feedback=True)
    with pytest.raises(ValueError, match="microbatches"):
        make_train_step(
            cfg, opt, collectives="torrent", compress_grads=True,
            error_feedback=True, microbatches=2,
        )


# -- SPMD executor vs numpy oracle (subprocess) -------------------------


def test_int8_executor_bit_exact_vs_oracle(run_multidevice):
    """Every per-hop quantization in the SPMD executor is replayed
    bit-exactly by the numpy oracle — for K in {1,2,4} x both all-reduce
    algos, non-divisible leads, all-to-all, and bf16 round-trip."""
    run_multidevice("""
    from repro.core import chainwrite as cw
    from repro.core import chainwrite_ref as ref

    mesh = jax.make_mesh((8,), ('x',), axis_types=(jax.sharding.AxisType.Auto,))
    rng = np.random.default_rng(0)
    RINGS = {1: ((0,1,2,3,4,5,6,7),),
             2: ((0,1,2,3),(4,5,6,7)),
             4: ((0,1),(2,3),(4,5),(6,7))}

    def run(fn, xs):
        sm = jax.shard_map(fn, mesh=mesh, in_specs=P('x'), out_specs=P('x'))
        return np.asarray(jax.jit(sm)(xs))

    for lead in (64, 13):
        xs = jnp.asarray(rng.normal(size=(8, lead)).astype(np.float32))
        for k, orders in RINGS.items():
            for algo in ('rs_ag', 'rotation'):
                got = run(lambda v: cw.multi_chain_all_reduce(
                    v[0], 'x', orders, algo=algo, wire_dtype='int8')[None], xs)
                want = ref.multi_all_reduce_ref(
                    np.asarray(xs), orders, algo=algo, wire_dtype='int8')
                np.testing.assert_array_equal(
                    got, want, err_msg=f'lead={lead} K={k} {algo}')

    # all-to-all: per-hop quantized chunk train, K=2
    xs = jnp.asarray(rng.normal(size=(8, 8, 16)).astype(np.float32))
    got = run(lambda v: cw.multi_chain_all_to_all(
        v[0], 'x', RINGS[2], wire_dtype='int8')[None], xs)
    want = ref.multi_all_to_all_ref(np.asarray(xs), RINGS[2], wire_dtype='int8')
    np.testing.assert_array_equal(got, want)

    # bf16 payload: f32 on the accumulate path, bf16 back out
    xb = jnp.asarray(rng.normal(size=(8, 32)), dtype=jnp.bfloat16)
    def f_bf16(v):
        out = cw.chain_all_reduce(v[0], 'x', wire_dtype='int8')
        assert out.dtype == jnp.bfloat16, out.dtype
        return out[None]
    got = run(f_bf16, xb)
    want = ref.multi_all_reduce_ref(np.asarray(xb), RINGS[1], wire_dtype='int8')
    np.testing.assert_array_equal(got.astype(np.float32),
                                  np.asarray(want, np.float32))

    # integer payloads cannot take a lossy wire
    xi = jnp.ones((8, 8), jnp.int32)
    try:
        run(lambda v: cw.chain_all_reduce(v[0], 'x', wire_dtype='int8')[None], xi)
        raise SystemExit('expected ValueError for int32 payload')
    except ValueError:
        pass
    print('int8 executor bit-exact OK')
    """, timeout=900)


def test_hierarchical_2axis_int8_single_quantization(run_multidevice):
    """2-axis hierarchical compressed reduction: the inner ring's f32
    output enters the outer ring and is quantized once per WIRE HOP
    there — never a second whole-payload pass in between. Pinned
    bit-exactly against composing the two oracle replays."""
    run_multidevice("""
    from repro.core import chainwrite as cw
    from repro.core import chainwrite_ref as ref
    from repro.parallel.collectives import torrent_grad_reduce

    mesh = jax.make_mesh((2, 4), ('pod', 'data'),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    rng = np.random.default_rng(1)
    xs = jnp.asarray(rng.normal(size=(8, 48)).astype(np.float32))

    def nested(v):
        y = cw.chain_all_reduce(v[0], 'data', wire_dtype='int8')
        y = cw.chain_all_reduce(y, 'pod', wire_dtype='int8')
        return y[None]

    sm = jax.shard_map(nested, mesh=mesh,
                       in_specs=P(('pod', 'data'), None),
                       out_specs=P(('pod', 'data'), None))
    got = np.asarray(jax.jit(sm)(xs))

    # oracle: inner int8 ring per pod, then the outer int8 ring over
    # pods — each all_reduce_ref replays the per-hop roundings exactly
    x = np.asarray(xs).reshape(2, 4, 48)
    inner = np.stack([
        ref.multi_all_reduce_ref(x[p], ((0, 1, 2, 3),), wire_dtype='int8')
        for p in range(2)
    ])
    want = np.empty_like(inner)
    for j in range(4):
        want[:, j] = ref.multi_all_reduce_ref(
            inner[:, j], ((0, 1),), wire_dtype='int8')
    np.testing.assert_array_equal(got, want.reshape(8, 48))

    # and through the full torrent_grad_reduce seam: grads land near the
    # exact DP mean (error relative to the tensor max, int8 wire)
    params = {'w': jnp.zeros((48,), jnp.float32)}
    def grad_fn(p, batch):
        return {'w': batch['g'][0]}, {'loss': jnp.float32(0.0)}
    reduce = torrent_grad_reduce(
        grad_fn, mesh, {'g': P(('pod', 'data'), None)}, wire_dtype='int8')
    with jax.set_mesh(mesh):
        grads, _ = jax.jit(reduce)(params, {'g': xs})
    exact = np.asarray(xs).mean(0)
    err = np.abs(np.asarray(grads['w']) - exact).max() / np.abs(exact).max()
    assert err < 0.08, err
    print('hierarchical int8 OK')
    """, timeout=900)


def test_compress_composes_with_num_chains(run_multidevice):
    """compress used to silently ignore num_chains/algo; now they
    compose (and invalid K still raises the partition ValueError)."""
    run_multidevice("""
    from repro.core import chainwrite as cw
    from repro.core import chainwrite_ref as ref
    from repro.parallel.collectives import torrent_grad_reduce

    mesh = jax.make_mesh((8, 1), ('data', 'model'),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    rng = np.random.default_rng(2)
    xs = jnp.asarray(rng.normal(size=(8, 64)).astype(np.float32))
    params = {'w': jnp.zeros((64,), jnp.float32)}
    def grad_fn(p, batch):
        return {'w': batch['g'][0]}, {'loss': jnp.float32(0.0)}

    for kwargs in ({'num_chains': 2}, {'num_chains': 2, 'algo': 'rotation'},
                   {'num_chains': 'auto'}):
        reduce = torrent_grad_reduce(
            grad_fn, mesh, {'g': P('data', None)},
            wire_dtype='int8', **kwargs)
        with jax.set_mesh(mesh):
            grads, _ = jax.jit(reduce)(params, {'g': xs})
        exact = np.asarray(xs).mean(0)
        err = np.abs(np.asarray(grads['w']) - exact).max() / np.abs(exact).max()
        assert err < 0.08, (kwargs, err)

    # K that does not divide the DP group still raises loudly
    bad = torrent_grad_reduce(
        grad_fn, mesh, {'g': P('data', None)},
        wire_dtype='int8', num_chains=3)
    try:
        with jax.set_mesh(mesh):
            jax.jit(bad)(params, {'g': xs})
        raise SystemExit('expected ValueError for K=3 on 8 ranks')
    except ValueError:
        pass
    print('compose OK')
    """, timeout=900)


def test_compress_grads_changes_hlo(run_multidevice):
    """Satellite regression: the compress_grads knob must actually
    change the emitted program (it used to be declared-but-never-read).
    int8 collective traffic shows up as s8 ops in the optimized HLO."""
    run_multidevice("""
    import dataclasses
    from repro import configs as C
    from repro.launch.mesh import make_host_mesh
    from repro.launch.steps import _named, _sanitize, make_train_step
    from repro.models import transformer as T
    from repro.optim import adamw
    from repro.parallel import sharding as shd

    cfg = dataclasses.replace(
        C.get_smoke_config('yi-6b'), num_layers=1, d_model=16, num_heads=2,
        num_kv_heads=2, d_ff=32, vocab_size=32, head_dim=8)
    mesh = make_host_mesh(model=1)
    opt_cfg = adamw.OptConfig()
    params_shape = jax.eval_shape(
        lambda: T.model_init(jax.random.PRNGKey(0), cfg))
    pspecs = shd.param_pspecs(params_shape, cfg, tp=1)
    ospecs = shd.opt_pspecs(pspecs, params_shape, mesh.shape['data'])
    bspec = P('data', None)
    bspecs = {'tokens': bspec, 'labels': bspec}
    batch = {
        k: jax.ShapeDtypeStruct((8, 16), jnp.int32) for k in bspecs
    }
    opt_shape = jax.eval_shape(lambda: adamw.init(params_shape))

    def lower(compress):
        step = make_train_step(
            cfg, opt_cfg, collectives='torrent', compress_grads=compress,
            mesh=mesh, batch_specs={k: _sanitize(v, mesh)
                                    for k, v in bspecs.items()},
            loss_chunks=2)
        jitted = jax.jit(
            step,
            in_shardings=(_named(mesh, pspecs), _named(mesh, ospecs),
                          {k: jax.NamedSharding(mesh, _sanitize(v, mesh))
                           for k, v in bspecs.items()}))
        with jax.set_mesh(mesh):
            return jitted.lower(params_shape, opt_shape, batch)\
                .compile().as_text()

    base, compressed = lower(False), lower(True)
    assert 's8[' not in base
    assert 's8[' in compressed, 'compress_grads did not change the HLO'
    print('hlo knob OK')
    """, timeout=900)
