"""MeshTopology: XY routing, distances, multicast trees (unit + property),
and the weighted link-graph generalization (LinkGraph / tiered meshes)."""

from __future__ import annotations

import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.core.topology import (
    LinkGraph,
    MeshTopology,
    TieredMeshTopology,
)


def test_coord_node_id_roundtrip():
    topo = MeshTopology(4, 5)
    for n in topo.nodes():
        assert topo.node_id(topo.coord(n)) == n
    assert topo.coord(0) == (0, 0)
    assert topo.coord(4) == (0, 1)  # row-major by rows of nx


def test_bad_coords_raise():
    topo = MeshTopology(4, 4)
    with pytest.raises(ValueError):
        topo.coord(16)
    with pytest.raises(ValueError):
        topo.node_id((4, 0))


def test_xy_path_is_x_first():
    topo = MeshTopology(4, 4)
    path = topo.xy_path((0, 0), (2, 2))
    # first moves change x, later moves change y
    assert path[0] == ((0, 0), (1, 0))
    assert path[1] == ((1, 0), (2, 0))
    assert path[2] == ((2, 0), (2, 1))
    assert path[3] == ((2, 1), (2, 2))


@settings(max_examples=200, deadline=None)
@given(
    nx=st.integers(2, 8),
    ny=st.integers(2, 8),
    data=st.data(),
)
def test_path_length_equals_manhattan(nx, ny, data):
    topo = MeshTopology(nx, ny)
    a = data.draw(st.integers(0, nx * ny - 1))
    b = data.draw(st.integers(0, nx * ny - 1))
    path = topo.xy_path(a, b)
    ca, cb = topo.coord(a), topo.coord(b)
    manhattan = abs(ca[0] - cb[0]) + abs(ca[1] - cb[1])
    assert len(path) == manhattan == topo.distance(a, b)
    # path is connected and ends at b
    if path:
        assert path[0][0] == ca
        assert path[-1][1] == cb
        for (s0, d0), (s1, _) in zip(path, path[1:]):
            assert d0 == s1
        # every link is between adjacent nodes
        for s, d in path:
            assert abs(s[0] - d[0]) + abs(s[1] - d[1]) == 1


@settings(max_examples=100, deadline=None)
@given(nx=st.integers(2, 8), ny=st.integers(2, 8), data=st.data())
def test_torus_distance_leq_mesh(nx, ny, data):
    mesh = MeshTopology(nx, ny, torus=False)
    torus = MeshTopology(nx, ny, torus=True)
    a = data.draw(st.integers(0, nx * ny - 1))
    b = data.draw(st.integers(0, nx * ny - 1))
    assert torus.distance(a, b) <= mesh.distance(a, b)
    # torus axis distance is at most half the ring
    ca, cb = mesh.coord(a), mesh.coord(b)
    assert torus.distance(a, b) <= nx // 2 + ny // 2 + 1


def test_torus_wraps():
    topo = MeshTopology(4, 4, torus=True)
    assert topo.distance((0, 0), (3, 0)) == 1
    path = topo.xy_path((0, 0), (3, 0))
    assert path == [((0, 0), (3, 0))]


def test_multicast_tree_shares_prefix():
    topo = MeshTopology(4, 4)
    # two dests in the same row beyond each other: shared prefix
    links = topo.multicast_tree_links(0, [topo.node_id((2, 0)), topo.node_id((3, 0))])
    assert len(links) == 3  # 0->1->2->3, not 2+3
    # diverging dests: union
    links = topo.multicast_tree_links(0, [topo.node_id((0, 2)), topo.node_id((2, 0))])
    assert len(links) == 4


@settings(max_examples=60, deadline=None)
@given(data=st.data())
def test_multicast_tree_bounds(data):
    topo = MeshTopology(8, 8)
    dsts = data.draw(
        st.lists(st.integers(1, 63), min_size=1, max_size=12, unique=True)
    )
    tree = topo.multicast_tree_links(0, dsts)
    per_path = [len(topo.xy_path(0, d)) for d in dsts]
    assert max(per_path) <= len(tree) <= sum(per_path)


def test_snake_order_unit_hops():
    topo = MeshTopology(5, 4)
    order = topo.snake_order()
    assert sorted(order) == list(range(20))
    for a, b in zip(order, order[1:]):
        assert topo.distance(a, b) == 1


def test_path_nodes_inclusive():
    topo = MeshTopology(4, 4)
    nodes = topo.path_nodes(0, topo.node_id((2, 1)))
    assert nodes[0] == (0, 0) and nodes[-1] == (2, 1)
    assert len(nodes) == topo.distance(0, topo.node_id((2, 1))) + 1


# ---------------------------------------------------------------------------
# torus routing properties (previously only exercised indirectly)
# ---------------------------------------------------------------------------


@settings(max_examples=200, deadline=None)
@given(
    nx=st.integers(2, 8),
    ny=st.integers(2, 8),
    torus=st.booleans(),
    data=st.data(),
)
def test_distance_is_symmetric(nx, ny, torus, data):
    topo = MeshTopology(nx, ny, torus=torus)
    a = data.draw(st.integers(0, nx * ny - 1))
    b = data.draw(st.integers(0, nx * ny - 1))
    assert topo.distance(a, b) == topo.distance(b, a)
    assert topo.distance(a, a) == 0


@settings(max_examples=200, deadline=None)
@given(nx=st.integers(2, 8), ny=st.integers(2, 8), data=st.data())
def test_torus_xy_path_length_equals_distance(nx, ny, data):
    topo = MeshTopology(nx, ny, torus=True)
    a = data.draw(st.integers(0, nx * ny - 1))
    b = data.draw(st.integers(0, nx * ny - 1))
    path = topo.xy_path(a, b)
    assert len(path) == topo.distance(a, b)
    # connected, endpoints right, every link wraps to an adjacent node
    if path:
        assert path[0][0] == topo.coord(a)
        assert path[-1][1] == topo.coord(b)
        for (s0, d0), (s1, _) in zip(path, path[1:]):
            assert d0 == s1
        for s, d in path:
            dx = min((s[0] - d[0]) % nx, (d[0] - s[0]) % nx)
            dy = min((s[1] - d[1]) % ny, (d[1] - s[1]) % ny)
            assert dx + dy == 1


@settings(max_examples=200, deadline=None)
@given(nx=st.integers(2, 8), ny=st.integers(2, 8), data=st.data())
def test_torus_paths_never_exceed_mesh_paths(nx, ny, data):
    mesh = MeshTopology(nx, ny, torus=False)
    torus = MeshTopology(nx, ny, torus=True)
    a = data.draw(st.integers(0, nx * ny - 1))
    b = data.draw(st.integers(0, nx * ny - 1))
    assert len(torus.xy_path(a, b)) <= len(mesh.xy_path(a, b))


@settings(max_examples=120, deadline=None)
@given(
    nx=st.integers(2, 8),
    ny=st.integers(2, 8),
    torus=st.booleans(),
    data=st.data(),
)
def test_path_nodes_endpoints_match(nx, ny, torus, data):
    topo = MeshTopology(nx, ny, torus=torus)
    a = data.draw(st.integers(0, nx * ny - 1))
    b = data.draw(st.integers(0, nx * ny - 1))
    nodes = topo.path_nodes(a, b)
    assert nodes[0] == topo.coord(a)
    assert nodes[-1] == topo.coord(b)
    assert len(nodes) == topo.distance(a, b) + 1


# ---------------------------------------------------------------------------
# weighted link-graph properties (the routing properties above, generalized)
# ---------------------------------------------------------------------------


def _tiered(nx, ny, pods_x, pods_y, torus=False):
    return TieredMeshTopology(
        nx, ny, torus=torus, pods_x=pods_x, pods_y=pods_y,
        interpod_bw=0.25, interpod_latency=4,
    )


@settings(max_examples=150, deadline=None)
@given(
    nx=st.integers(2, 4).map(lambda p: 2 * p),
    ny=st.integers(2, 4).map(lambda p: 2 * p),
    data=st.data(),
)
def test_weighted_distance_symmetric_on_tiered_mesh(nx, ny, data):
    topo = _tiered(nx, ny, 2, 2)
    a = data.draw(st.integers(0, nx * ny - 1))
    b = data.draw(st.integers(0, nx * ny - 1))
    assert topo.weighted_distance(a, b) == topo.weighted_distance(b, a)
    assert topo.weighted_distance(a, a) == 0
    assert topo.path_min_bw(a, b) == topo.path_min_bw(b, a)
    assert topo.path_tier_crossings(a, b) == topo.path_tier_crossings(b, a)


@settings(max_examples=150, deadline=None)
@given(
    nx=st.integers(2, 4).map(lambda p: 2 * p),
    ny=st.integers(2, 4).map(lambda p: 2 * p),
    data=st.data(),
)
def test_weighted_triangle_inequality_on_tiered_mesh(nx, ny, data):
    # Non-torus XY routing on an axis-aligned tiering is separable per
    # axis, so the weighted distance is a metric. (On a TORUS the wrap
    # direction is chosen by hop count, not weight, so no such claim.)
    topo = _tiered(nx, ny, 2, 2)
    a = data.draw(st.integers(0, nx * ny - 1))
    b = data.draw(st.integers(0, nx * ny - 1))
    c = data.draw(st.integers(0, nx * ny - 1))
    assert topo.weighted_distance(a, c) <= (
        topo.weighted_distance(a, b) + topo.weighted_distance(b, c)
    )


@settings(max_examples=150, deadline=None)
@given(
    nx=st.integers(2, 4).map(lambda p: 2 * p),
    ny=st.integers(2, 4).map(lambda p: 2 * p),
    torus=st.booleans(),
    data=st.data(),
)
def test_weighted_path_cost_is_summed_link_weights(nx, ny, torus, data):
    topo = _tiered(nx, ny, 2, 2, torus=torus)
    a = data.draw(st.integers(0, nx * ny - 1))
    b = data.draw(st.integers(0, nx * ny - 1))
    links = topo.xy_path(a, b)
    assert topo.weighted_distance(a, b) == sum(
        topo.link_attrs(l).latency for l in links
    )
    assert topo.path_tier_crossings(a, b) == sum(
        1 for l in links if topo.link_attrs(l).tier > 0
    )
    bws = [topo.link_attrs(l).bandwidth for l in links]
    assert topo.path_min_bw(a, b) == (min(bws) if bws else 1.0)


@pytest.mark.parametrize("torus", [False, True])
def test_uniform_link_graph_matches_mesh_distance_all_pairs(torus):
    topo = MeshTopology(4, 4, torus=torus)
    g = topo.to_link_graph()
    for a in topo.nodes():
        for b in topo.nodes():
            assert g.weighted_distance(a, b) == topo.distance(a, b), (a, b)
            assert g.path_min_bw(a, b) == 1.0
            assert g.path_tier_crossings(a, b) == 0


@settings(max_examples=100, deadline=None)
@given(
    nx=st.integers(2, 6),
    ny=st.integers(2, 6),
    torus=st.booleans(),
    data=st.data(),
)
def test_uniform_link_graph_matches_mesh_distance_property(nx, ny, torus, data):
    topo = MeshTopology(nx, ny, torus=torus)
    g = topo.to_link_graph()
    a = data.draw(st.integers(0, nx * ny - 1))
    b = data.draw(st.integers(0, nx * ny - 1))
    assert g.weighted_distance(a, b) == topo.distance(a, b)


@settings(max_examples=100, deadline=None)
@given(data=st.data())
def test_link_graph_triangle_inequality(data):
    # Dijkstra shortest-path costs are a metric by construction, even
    # on the tiered torus where XY routing is not.
    g = _tiered(4, 4, 2, 2, torus=True).to_link_graph()
    a = data.draw(st.integers(0, 15))
    b = data.draw(st.integers(0, 15))
    c = data.draw(st.integers(0, 15))
    assert g.weighted_distance(a, c) <= (
        g.weighted_distance(a, b) + g.weighted_distance(b, c)
    )
    assert g.weighted_distance(a, b) == g.weighted_distance(b, a)


@settings(max_examples=100, deadline=None)
@given(data=st.data())
def test_link_graph_never_exceeds_xy_route_cost(data):
    # the oracle's shortest path can only improve on deterministic XY
    topo = _tiered(8, 4, 2, 2)
    g = topo.to_link_graph()
    a = data.draw(st.integers(0, 31))
    b = data.draw(st.integers(0, 31))
    assert g.weighted_distance(a, b) <= topo.weighted_distance(a, b)


def test_mesh_uniform_weight_hooks():
    topo = MeshTopology(5, 3, torus=True)
    assert topo.num_pods == 1
    for n in (0, 7, 14):
        assert topo.pod_of(n) == 0
    assert topo.weighted_distance(0, 14) == topo.distance(0, 14)
    assert topo.path_min_bw(0, 14) == 1.0
    assert topo.path_tier_crossings(0, 14) == 0
