"""Property tests for the multi-chain partitioner (scheduling layer).

Invariants (documented in ``repro.core.scheduling``):

* exact cover — every destination lands in exactly one sub-chain;
* balance — each chain's hop total <= H(K=1)/K + 2*(nx+ny);
* latency — the simulator's K-chain completion never exceeds the K=1
  schedule for the same destination set (auto-K includes K=1 as a
  candidate, and fixed K>=2 must win outright on large sets).
"""

from __future__ import annotations

import random

import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.core.scheduling import (
    SCHEDULERS,
    chain_total_hops,
    hop_proxy_cost,
    partition_balance_slack,
    partition_schedule,
    partition_total_hops,
    tsp_schedule,
)
from repro.core.simulator import (
    chainwrite_latency,
    choose_num_chains,
    multi_chain_latency,
)
from repro.core.topology import MeshTopology

TOPO = MeshTopology(8, 8)
SIZE = 64 * 1024


# ---------------------------------------------------------------------------
# exact cover
# ---------------------------------------------------------------------------


@settings(max_examples=40, deadline=None)
@given(
    data=st.data(),
    k=st.integers(1, 4),
)
def test_every_destination_in_exactly_one_chain(data, k):
    dests = data.draw(
        st.lists(st.integers(1, 63), min_size=2, max_size=20, unique=True)
    )
    chains = partition_schedule(TOPO, dests, 0, num_chains=k)
    flat = [d for c in chains for d in c]
    assert sorted(flat) == sorted(dests)
    assert len(flat) == len(set(flat))  # no destination twice
    assert 1 <= len(chains) <= min(k, len(dests))
    assert all(c for c in chains)  # no empty chain


def test_auto_k_also_exact_cover():
    rng = random.Random(11)
    for n in (3, 8, 16, 24):
        dests = rng.sample(range(1, 64), n)
        chains = partition_schedule(TOPO, dests, 0)
        assert sorted(d for c in chains for d in c) == sorted(dests)


def test_degenerate_inputs():
    assert partition_schedule(TOPO, [], 0) == []
    assert partition_schedule(TOPO, [5], 0, num_chains=3) == [[5]]
    # K > N clamps to N chains of one destination each
    chains = partition_schedule(TOPO, [3, 9], 0, num_chains=4)
    assert sorted(d for c in chains for d in c) == [3, 9]


def test_k1_reproduces_single_schedule():
    rng = random.Random(5)
    for n in (2, 5, 9, 13):
        dests = rng.sample(range(1, 64), n)
        assert partition_schedule(TOPO, dests, 0, num_chains=1) == [
            tsp_schedule(TOPO, dests, 0)
        ]


# ---------------------------------------------------------------------------
# balance bound
# ---------------------------------------------------------------------------


@settings(max_examples=40, deadline=None)
@given(data=st.data(), k=st.integers(2, 4))
def test_per_chain_hops_within_balance_bound(data, k):
    dests = data.draw(
        st.lists(st.integers(1, 63), min_size=6, max_size=24, unique=True)
    )
    single = tsp_schedule(TOPO, dests, 0)
    h1 = chain_total_hops(TOPO, single, 0)
    chains = partition_schedule(TOPO, dests, 0, num_chains=k)
    bound = h1 / len(chains) + partition_balance_slack(TOPO)
    for c in chains:
        assert chain_total_hops(TOPO, c, 0) <= bound, (c, bound)


# ---------------------------------------------------------------------------
# latency: K chains never lose to the single chain
# ---------------------------------------------------------------------------


@settings(max_examples=30, deadline=None)
@given(data=st.data())
def test_auto_k_latency_never_exceeds_single_chain(data):
    dests = data.draw(
        st.lists(st.integers(1, 63), min_size=2, max_size=20, unique=True)
    )
    lat1 = chainwrite_latency(TOPO, 0, tsp_schedule(TOPO, dests, 0), SIZE)
    _, chains = choose_num_chains(TOPO, 0, dests, SIZE)
    assert multi_chain_latency(TOPO, 0, chains, SIZE) <= lat1


@settings(max_examples=25, deadline=None)
@given(data=st.data(), k=st.integers(2, 3))
def test_fixed_k_beats_single_chain_on_large_sets(data, k):
    """The acceptance-criterion property: K>=2 strictly below K=1 for
    >= 16 destinations on the 8x8 mesh."""
    dests = data.draw(
        st.lists(st.integers(1, 63), min_size=16, max_size=32, unique=True)
    )
    lat1 = chainwrite_latency(TOPO, 0, tsp_schedule(TOPO, dests, 0), SIZE)
    chains = partition_schedule(TOPO, dests, 0, num_chains=k)
    assert multi_chain_latency(TOPO, 0, chains, SIZE) < lat1


def test_partition_prefers_link_disjoint_growth():
    """Chains grown from spread seeds should overlap (and so serialize
    on) far fewer links than a naive round-robin split."""
    rng = random.Random(3)
    better = 0
    trials = 12
    for _ in range(trials):
        dests = rng.sample(range(1, 64), 16)
        chains = partition_schedule(TOPO, dests, 0, num_chains=2)
        naive = [sorted(dests)[0::2], sorted(dests)[1::2]]

        def shared_links(split):
            linksets = []
            for c in split:
                links: set = set(TOPO.xy_path(0, c[0]))
                for a, b in zip(c, c[1:]):
                    links.update(TOPO.xy_path(a, b))
                linksets.append(links)
            return len(linksets[0] & linksets[1])

        if shared_links(chains) <= shared_links(naive):
            better += 1
    assert better >= trials - 2, better


def test_hop_proxy_cost_ranks_like_the_simulator():
    """The scheduling-layer proxy and the cycle model agree on the K
    ranking often enough to drive auto-K (spot check, not exact)."""
    rng = random.Random(9)
    agree = 0
    trials = 10
    for _ in range(trials):
        dests = rng.sample(range(1, 64), 20)
        proxy = hop_proxy_cost(TOPO, 0)
        by_proxy = min(
            range(1, 5),
            key=lambda k: proxy(
                partition_schedule(TOPO, dests, 0, num_chains=k)
            ),
        )
        by_sim, _ = choose_num_chains(TOPO, 0, dests, SIZE)
        if abs(by_proxy - by_sim) <= 1:
            agree += 1
    assert agree >= trials - 2, agree


def test_partition_total_hops_metric():
    dests = [9, 18, 27, 36, 45, 54, 63]
    chains = partition_schedule(TOPO, dests, 0, num_chains=2)
    assert partition_total_hops(TOPO, chains, 0) == sum(
        chain_total_hops(TOPO, c, 0) for c in chains
    )
