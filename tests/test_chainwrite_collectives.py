"""Chainwrite collectives vs pure-numpy oracles, on 8 virtual devices.

Runs inside subprocesses (conftest.run_multidevice) so the rest of the
suite keeps seeing 1 device. Each snippet asserts internally.
"""

from __future__ import annotations

import pytest

# Multidevice oracle tests (subprocess per test): skipped under QUICK=1.
pytestmark = pytest.mark.slow


def test_chain_broadcast_subset_and_frames(run_multidevice):
    run_multidevice("""
    from repro.core import chainwrite as cw
    from repro.core import chainwrite_ref as ref

    mesh = jax.make_mesh((8,), ('x',), axis_types=(jax.sharding.AxisType.Auto,))
    xs = jnp.arange(8 * 6 * 2, dtype=jnp.float32).reshape(8, 6, 2)

    for order in [(2, 5, 1, 7), (0, 1), (3,), tuple(range(8))]:
        for frames in (1, 2, 3, 6):
            if 6 % frames:
                continue
            def f(x, order=order, frames=frames):
                return cw.chain_broadcast(x[0], 'x', order, num_frames=frames)[None]
            y = jax.jit(jax.shard_map(f, mesh=mesh, in_specs=P('x'), out_specs=P('x')))(xs)
            expect = ref.broadcast_ref(np.asarray(xs), order)
            np.testing.assert_allclose(np.asarray(y), expect, err_msg=f"{order} {frames}")

    # frame count must divide the leading dim
    try:
        def g(x):
            return cw.chain_broadcast(x[0], 'x', (0, 1), num_frames=4)[None]
        jax.jit(jax.shard_map(g, mesh=mesh, in_specs=P('x'), out_specs=P('x')))(xs)
        raise SystemExit("expected ValueError")
    except ValueError:
        pass
    print("broadcast OK")
    """)


def test_chain_ring_collectives_match_oracles(run_multidevice):
    run_multidevice("""
    import itertools, random
    from repro.core import chainwrite as cw
    from repro.core import chainwrite_ref as ref

    mesh = jax.make_mesh((8,), ('x',), axis_types=(jax.sharding.AxisType.Auto,))
    rng = np.random.default_rng(0)
    orders = [tuple(range(8)), (0, 3, 1, 2, 7, 5, 6, 4), (7, 6, 5, 4, 3, 2, 1, 0)]
    random.seed(1)
    perm = list(range(8)); random.shuffle(perm)
    orders.append(tuple(perm))

    xs = jnp.asarray(rng.normal(size=(8, 4, 3)).astype(np.float32))
    for order in orders:
        # all_gather (stacked + tiled)
        def ag(x, order=order):
            return cw.chain_all_gather(x[0], 'x', order)[None]
        y = jax.jit(jax.shard_map(ag, mesh=mesh, in_specs=P('x'), out_specs=P('x')))(xs)
        np.testing.assert_allclose(np.asarray(y), ref.all_gather_ref(np.asarray(xs)), rtol=1e-6)

        def agt(x, order=order):
            return cw.chain_all_gather(x[0], 'x', order, tiled=True)[None]
        y = jax.jit(jax.shard_map(agt, mesh=mesh, in_specs=P('x'), out_specs=P('x')))(xs)
        np.testing.assert_allclose(
            np.asarray(y), ref.all_gather_ref(np.asarray(xs), tiled=True), rtol=1e-6)

        # all_reduce
        def ar(x, order=order):
            return cw.chain_all_reduce(x[0], 'x', order)[None]
        y = jax.jit(jax.shard_map(ar, mesh=mesh, in_specs=P('x'), out_specs=P('x')))(xs)
        np.testing.assert_allclose(
            np.asarray(y), ref.all_reduce_ref(np.asarray(xs)), rtol=1e-5, atol=1e-5)

    # reduce_scatter + all_to_all need (L, L, ...) inputs
    xs2 = jnp.asarray(rng.normal(size=(8, 8, 5)).astype(np.float32))
    for order in orders:
        def rs(x, order=order):
            return cw.chain_reduce_scatter(x[0], 'x', order)[None]
        y = jax.jit(jax.shard_map(rs, mesh=mesh, in_specs=P('x'), out_specs=P('x')))(xs2)
        np.testing.assert_allclose(
            np.asarray(y), ref.reduce_scatter_ref(np.asarray(xs2)), rtol=1e-5, atol=1e-5)

        def a2a(x, order=order):
            return cw.chain_all_to_all(x[0], 'x', order)[None]
        y = jax.jit(jax.shard_map(a2a, mesh=mesh, in_specs=P('x'), out_specs=P('x')))(xs2)
        np.testing.assert_allclose(
            np.asarray(y), ref.all_to_all_ref(np.asarray(xs2)), rtol=1e-6)
    print("ring collectives OK")
    """, timeout=900)


def test_chain_all_reduce_non_divisible_payload(run_multidevice):
    """The pad/unpad path: payload leading dims NOT divisible by the
    ring size L must round-trip through the zero-padded reduce-scatter
    + all-gather and come back at the original shape."""
    run_multidevice("""
    from repro.core import chainwrite as cw
    from repro.core import chainwrite_ref as ref

    mesh = jax.make_mesh((8,), ('x',), axis_types=(jax.sharding.AxisType.Auto,))
    rng = np.random.default_rng(2)
    for lead in (1, 5, 13, 23):   # all have lead % 8 != 0
        for order in [tuple(range(8)), (3, 1, 0, 2, 7, 5, 6, 4)]:
            xs = jnp.asarray(rng.normal(size=(8, lead, 3)).astype(np.float32))
            def f(x, order=order):
                return cw.chain_all_reduce(x[0], 'x', order)[None]
            y = jax.jit(jax.shard_map(
                f, mesh=mesh, in_specs=P('x'), out_specs=P('x')))(xs)
            assert np.asarray(y).shape == xs.shape, (lead, np.asarray(y).shape)
            np.testing.assert_allclose(
                np.asarray(y), ref.all_reduce_ref(np.asarray(xs)),
                rtol=1e-5, atol=1e-5, err_msg=f"lead={lead} {order}")
            # bit-exact against the schedule-replaying oracle too
            np.testing.assert_array_equal(
                np.asarray(y),
                ref.multi_all_reduce_ref(np.asarray(xs), [order]),
                err_msg=f"lead={lead} {order}")
    print("pad path OK")
    """, timeout=900)


def test_order_must_be_full_permutation(run_multidevice):
    run_multidevice("""
    from repro.core import chainwrite as cw
    mesh = jax.make_mesh((8,), ('x',), axis_types=(jax.sharding.AxisType.Auto,))
    xs = jnp.zeros((8, 4))
    try:
        def f(x):
            return cw.chain_all_gather(x[0], 'x', (0, 1, 2))[None]
        jax.jit(jax.shard_map(f, mesh=mesh, in_specs=P('x'), out_specs=P('x')))(xs)
        raise SystemExit("expected ValueError")
    except ValueError:
        pass
    print("validation OK")
    """)


def test_xla_broadcast_baseline(run_multidevice):
    run_multidevice("""
    from repro.core import chainwrite as cw
    mesh = jax.make_mesh((8,), ('x',), axis_types=(jax.sharding.AxisType.Auto,))
    xs = jnp.arange(8 * 3, dtype=jnp.float32).reshape(8, 3)
    def f(x):
        return cw.xla_broadcast(x[0], 'x', root=5)[None]
    y = jax.jit(jax.shard_map(f, mesh=mesh, in_specs=P('x'), out_specs=P('x')))(xs)
    expect = np.broadcast_to(np.asarray(xs[5]), (8, 3))
    np.testing.assert_allclose(np.asarray(y), expect)
    print("xla broadcast OK")
    """)


def test_compressed_all_reduce_and_error_feedback(run_multidevice):
    """int8 wire is now the IR dimension: ``chain_all_reduce(...,
    wire_dtype="int8")`` replaces the deleted hand-written
    ``compressed_chain_all_reduce``."""
    run_multidevice("""
    from repro.core import chainwrite as cw
    from repro.runtime.compression import ErrorFeedback, dequantize, quantize

    # quantize/dequantize roundtrip error bound: interior values round
    # within scale/2; the max-abs element clips 128 -> 127 (the
    # power-of-two divisor), so the bound is 1.5 steps
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.normal(size=(1000,)).astype(np.float32))
    q, s = quantize(g)
    assert q.dtype == jnp.int8
    err = np.abs(np.asarray(dequantize(q, s) - g))
    assert err.max() <= 1.5 * float(s)

    # ErrorFeedback compensates the residual on the next round
    ef = ErrorFeedback.init(g)
    (q1, s1), ef1 = ErrorFeedback.compress(g, ef)
    assert np.abs(np.asarray(ef1)).max() > 0  # residual captured
    (q2, s2), _ = ErrorFeedback.compress(g, ef1)
    two_round = np.asarray(dequantize(q1, s1) + dequantize(q2, s2))
    plain = np.asarray(dequantize(*quantize(g))) * 2
    # two EF rounds approximate 2g better than two independent rounds
    assert np.abs(two_round - 2 * np.asarray(g)).sum() <= \
        np.abs(plain - 2 * np.asarray(g)).sum() + 1e-6

    mesh = jax.make_mesh((8,), ('x',), axis_types=(jax.sharding.AxisType.Auto,))
    xs = jnp.asarray(rng.normal(size=(8, 64)).astype(np.float32))
    def f(x):
        return cw.chain_all_reduce(x[0], 'x', wire_dtype='int8')[None]
    y = jax.jit(jax.shard_map(f, mesh=mesh, in_specs=P('x'), out_specs=P('x')))(xs)
    exact = np.asarray(xs).sum(0)
    got = np.asarray(y)[0]
    # int8 wire: approximate, but well-correlated
    denom = np.abs(exact).max()
    assert np.abs(got - exact).max() / denom < 0.15
    print("compressed all-reduce OK")
    """)
