"""Bucketed, backward-overlapped gradient reduction: bucket-assembly
invariants (property-tested), the shard-count pin against the
ChainProgram planner, the overlap timeline model, HLO overlap
counting, bit-identical bucketed-vs-per-leaf reduction on 8 virtual
devices, and the int8+EF convergence pin under bucketing."""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, strategies as st
from repro.core.program import plan_all_reduce
from repro.core.simulator import choose_num_chains, overlap_timeline
from repro.core.topology import MeshTopology
from repro.parallel.collectives import (
    GradBucket,
    all_reduce_shards,
    assign_buckets,
    auto_ring_chains,
    bucket_shard_layout,
    resolve_ring_chains,
    sub_ring_orders,
)

_DTYPES = ("float32", "bfloat16", "int8")


def _leaves_from(spec):
    """[(num_elems, dtype_idx)] -> ShapeDtypeStruct leaves."""
    return [
        jax.ShapeDtypeStruct((n,), jnp.dtype(_DTYPES[d]))
        for n, d in spec
    ]


@settings(max_examples=60)
@given(
    spec=st.lists(
        st.tuples(st.integers(1, 5000), st.integers(0, len(_DTYPES) - 1)),
        min_size=1, max_size=24,
    ),
    target=st.integers(1, 1 << 14),
)
def test_assign_buckets_invariants(spec, target):
    leaves = _leaves_from(spec)
    buckets = assign_buckets(leaves, target)

    # 1. exact partition: every leaf index in exactly one bucket
    seen = [i for b in buckets for i in b.indices]
    assert sorted(seen) == list(range(len(leaves)))

    # 2. total bytes preserved
    nbytes = [
        int(np.prod(x.shape)) * jnp.dtype(x.dtype).itemsize for x in leaves
    ]
    assert sum(b.num_bytes for b in buckets) == sum(nbytes)
    for b in buckets:
        assert b.num_bytes == sum(nbytes[i] for i in b.indices)

    # 3. dtype purity: a bucket never mixes dtypes
    for b in buckets:
        assert {str(leaves[i].dtype) for i in b.indices} == {b.dtype}

    # 4. size target within one leaf's slack: a bucket only exceeds the
    # target when it is a single oversized leaf
    for b in buckets:
        assert b.num_bytes <= target or len(b.indices) == 1, (b, target)

    # 5. dispatch order is reverse-topological: indices descend within
    # and across buckets (bucket 0 holds the LAST leaves — the first
    # gradients backward produces)
    assert seen == sorted(seen, reverse=True)


def test_assign_buckets_rejects_bad_target():
    leaves = _leaves_from([(8, 0)])
    with pytest.raises(ValueError):
        assign_buckets(leaves, 0)
    with pytest.raises(ValueError):
        assign_buckets(leaves, -4)
    assert assign_buckets([], 1024) == ()


def test_assign_buckets_groups_and_splits():
    # same-dtype neighbours merge under the target; a dtype flip splits
    leaves = _leaves_from([(16, 0), (16, 0), (16, 1), (16, 0)])
    buckets = assign_buckets(leaves, 1 << 20)
    assert [b.indices for b in buckets] == [(3,), (2,), (1, 0)]
    assert [b.dtype for b in buckets] == ["float32", "bfloat16", "float32"]
    assert isinstance(buckets[0], GradBucket)


@settings(max_examples=40)
@given(
    log_l=st.integers(1, 4),
    k=st.sampled_from((1, 2, 4)),
    algo=st.sampled_from(("rs_ag", "rotation")),
)
def test_all_reduce_shards_matches_planner(log_l, k, algo):
    """The module-level shard-count twin must equal the planner's
    addr_shards for every (L, K, algo) — the layout the executor pads
    to IS the layout the schedule addresses."""
    L = 2 ** log_l
    if k > 1 and (L % k or L == k):
        return
    rings = (
        (tuple(range(L)),) if k == 1
        else tuple(tuple(r) for r in sub_ring_orders(L, k))
    )
    program = plan_all_reduce(L, rings, algo)
    assert all_reduce_shards(L, k, algo) == program.addr_shards


@settings(max_examples=40)
@given(
    sizes=st.lists(st.integers(1, 10_000), min_size=1, max_size=12),
    shards=st.sampled_from((1, 2, 4, 8)),
)
def test_bucket_shard_layout_properties(sizes, shards):
    widths, total = bucket_shard_layout(sizes, shards)
    assert len(widths) == len(sizes)
    # every leaf fits its column block, padding < one row per leaf
    for n, w in zip(sizes, widths):
        assert w * shards >= n > (w - 1) * shards
    assert total == shards * sum(widths)
    assert total % shards == 0


def test_overlap_timeline_hand_case():
    tl = overlap_timeline([0, 10, 20], [15, 15, 15])
    # comm is the bottleneck: buckets queue back-to-back on the NoC
    assert tl["start_cc"] == [0, 15, 30]
    assert tl["finish_cc"] == [15, 30, 45]
    assert tl["overlap_cc"] == 45
    assert tl["serial_cc"] == 20 + 45  # all comm after last ready
    assert tl["hidden_cc"] == 20
    assert tl["efficiency"] == pytest.approx(20 / 45)

    # compute-bound: every bucket's comm hides entirely but the last's
    tl = overlap_timeline([0, 100, 200], [5, 5, 5])
    assert tl["overlap_cc"] == 205
    assert tl["serial_cc"] == 215
    assert tl["hidden_cc"] == 10


@settings(max_examples=50)
@given(
    n=st.integers(1, 8),
    data=st.data(),
)
def test_overlap_timeline_properties(n, data):
    gaps = [data.draw(st.integers(0, 50)) for _ in range(n)]
    ready = list(np.cumsum(gaps))
    comm = [data.draw(st.integers(0, 50)) for _ in range(n)]
    tl = overlap_timeline(ready, comm)
    # overlapping never beats the physics: >= max(compute, comm) and
    # never worse than fully serial
    assert tl["overlap_cc"] >= max(ready[-1], sum(comm))
    assert tl["overlap_cc"] <= tl["serial_cc"] == ready[-1] + sum(comm)
    assert tl["hidden_cc"] == tl["serial_cc"] - tl["overlap_cc"]
    # busy NoC: starts are serialized and ready-respecting
    for i, (s, f) in enumerate(zip(tl["start_cc"], tl["finish_cc"])):
        assert s >= ready[i]
        assert f == s + comm[i]
        if i:
            assert s >= tl["finish_cc"][i - 1]
    assert 0.0 <= tl["efficiency"] <= 1.0


def test_overlap_timeline_validation():
    with pytest.raises(ValueError):
        overlap_timeline([0, 1], [1])  # length mismatch
    with pytest.raises(ValueError):
        overlap_timeline([5, 3], [1, 1])  # ready must be nondecreasing
    with pytest.raises(ValueError):
        overlap_timeline([0, -1], [1, 1])  # negative ready
    with pytest.raises(ValueError):
        overlap_timeline([0, 1], [1, -2])  # negative comm
    assert overlap_timeline([], [])["efficiency"] == 0.0


def test_choose_num_chains_bucket_mode():
    """The bucket-aware step-time mode scores candidates by the modeled
    overlapped step, and still never loses to K=1."""
    topo = MeshTopology(8, 1)
    dsts = list(range(1, 8))
    buckets = [(0, 1 << 18), (5000, 1 << 18), (10000, 1 << 16)]
    d = choose_num_chains(
        topo, 0, dsts, 0, collective="all_reduce", buckets=buckets,
        detail=True,
    )
    assert d["step_cc"] == d["latency_cc"] == d["timeline"]["overlap_cc"]
    assert len(d["timeline"]["start_cc"]) == len(buckets)
    # K=1 is always a candidate: the winner can't model worse than it
    k1, rings1 = choose_num_chains(
        topo, 0, dsts, 0, collective="all_reduce", max_chains=1,
        buckets=buckets,
    )
    assert k1 == 1 and len(rings1) == 1
    with pytest.raises(ValueError):
        choose_num_chains(
            topo, 0, dsts, 1 << 18, collective="broadcast", buckets=buckets
        )


def test_modeled_train_overlap_smoke():
    """QUICK-lane twin of benchmarks/bench_train.py: the end-to-end
    modeled pipeline on a synthetic grad tree."""
    from repro.launch.roofline import (
        bucket_ready_cc,
        modeled_train_overlap,
        noc_cycles,
    )

    leaves = [
        jax.ShapeDtypeStruct((256, 128), jnp.float32),
        jax.ShapeDtypeStruct((512,), jnp.float32),
        jax.ShapeDtypeStruct((128, 128), jnp.bfloat16),
        jax.ShapeDtypeStruct((64, 64), jnp.float32),
    ]
    m = modeled_train_overlap(
        leaves, 8, 1 << 16, bucket_bytes=64 << 10, num_chains="auto"
    )
    assert len(m["buckets"]) >= 2
    assert m["overlap_cc"] <= m["serial_cc"]
    assert 0.0 <= m["efficiency"] <= 1.0
    assert m["total_wire_bytes"] == sum(
        b["wire_bytes"] for b in m["buckets"]
    )
    for b in m["buckets"]:
        # chunk-aligned padding never shrinks the payload and each
        # bucket's comm is priced on the padded bytes
        assert b["padded_bytes"] >= b["bytes"]
        assert b["comm_cc"] > 0 and b["wire_bytes"] > 0
        k, rings = resolve_ring_chains(8, b["bytes"], num_chains="auto")
        assert b["num_chains"] == k == len(rings)
    # readiness is cumulative backward time, nondecreasing
    ready = [b["ready_cc"] for b in m["buckets"]]
    assert ready == sorted(ready)
    assert bucket_ready_cc([0], 1) == [0]
    assert noc_cycles(0.0) == 0


def test_auto_ring_chains_cache_keys_are_shape_and_dtype_distinct():
    """Regression: the lru_cache key must separate payloads that differ
    only in shape or dtype — a (1<<20, f32) leaf and a (1<<20, int8)
    leaf have different byte counts and may pick different K."""
    auto_ring_chains.cache_clear()
    big_f32 = (1 << 18) * 4  # 1 MiB
    small_i8 = 1 << 10
    k_big, _ = auto_ring_chains(8, big_f32)
    k_small, _ = auto_ring_chains(8, small_i8)
    info = auto_ring_chains.cache_info()
    assert info.currsize >= 2  # distinct sizes -> distinct entries
    # cold-vs-warm answers agree regardless of call order
    auto_ring_chains.cache_clear()
    assert auto_ring_chains(8, small_i8)[0] == k_small
    assert auto_ring_chains(8, big_f32)[0] == k_big
    # other key dimensions also never alias
    k_rot = auto_ring_chains(8, big_f32, algo="rotation")
    k_int8 = auto_ring_chains(8, big_f32, wire_dtype="int8")
    k_mc2 = auto_ring_chains(8, big_f32, max_chains=2)
    assert auto_ring_chains.cache_info().currsize >= 5
    assert k_mc2[0] <= 2
    assert auto_ring_chains(8, big_f32, algo="rotation") == k_rot
    assert auto_ring_chains(8, big_f32, wire_dtype="int8") == k_int8


def test_auto_ring_chains_cache_keys_topology_distinct():
    """The lru_cache keys on the frozen topology OBJECT: a weighted
    link graph must never alias the uniform mesh of the same shape."""
    from repro.core.topology import TieredMeshTopology

    auto_ring_chains.cache_clear()
    nbytes = (1 << 18) * 4
    flat = MeshTopology(8, 1)
    tiered = TieredMeshTopology(8, 1, pods_x=2, interpod_bw=0.25,
                                interpod_latency=4)
    k_default = auto_ring_chains(8, nbytes)
    k_flat = auto_ring_chains(8, nbytes, topo=flat)
    k_tiered = auto_ring_chains(8, nbytes, topo=tiered)
    # an explicit uniform ring plans identically to the default...
    assert k_flat == k_default
    # ...but every distinct topology identity is a distinct entry
    assert auto_ring_chains.cache_info().currsize >= 3
    # cold-vs-warm agreement regardless of call order
    auto_ring_chains.cache_clear()
    assert auto_ring_chains(8, nbytes, topo=tiered) == k_tiered
    assert auto_ring_chains(8, nbytes, topo=flat) == k_flat
    # a topology of the wrong node count is a planning bug, not a knob
    with pytest.raises(ValueError):
        auto_ring_chains(8, nbytes, topo=MeshTopology(4, 1))


def test_resolve_ring_chains_topology_spec_is_advisory():
    """A spec string steers auto-K planning when it applies to the axis
    and degrades to the uniform ring when it does not (one VARIANTS
    entry spans meshes of different data-axis sizes)."""
    nbytes = (1 << 18) * 4
    k_flat, rings_flat = resolve_ring_chains(8, nbytes, num_chains="auto")
    k_pod, rings_pod = resolve_ring_chains(
        8, nbytes, num_chains="auto",
        topology="pods=2:interpod_bw=0.25:interpod_lat=4",
    )
    from repro.core.topology import TieredMeshTopology

    tiered = TieredMeshTopology(8, 1, pods_x=2, interpod_bw=0.25,
                                interpod_latency=4)
    # pod-aligned: each ring confined to one pod of the tiered 1-D ring
    for ring in rings_pod:
        assert len({tiered.pod_of(m) for m in ring}) == 1
    # a spec that cannot tile this axis falls back to the uniform plan
    assert resolve_ring_chains(
        8, nbytes, num_chains="auto", topology="pods=3"
    ) == (k_flat, rings_flat)
    assert resolve_ring_chains(
        8, nbytes, num_chains="auto", topology="4x4"
    ) == (k_flat, rings_flat)
    # explicit K ignores the topology knob entirely (contiguous splits)
    assert resolve_ring_chains(
        8, nbytes, num_chains=2, topology="pods=2"
    ) == resolve_ring_chains(8, nbytes, num_chains=2)


def test_overlap_stats_counts_async_and_interleavings():
    from repro.launch.hlo_breakdown import overlap_stats

    hlo = """
HloModule m

ENTRY %main (p0: f32[64]) -> (f32[64], f32[128]) {
  %p0 = f32[64]{0} parameter(0)
  %ar0 = f32[64]{0} all-reduce-start(%p0), replica_groups={{0,1}}
  %f0 = f32[64]{0} fusion(%p0), kind=kLoop, calls=%c0
  %ar0d = f32[64]{0} all-reduce-done(%ar0)
  %cp = f32[64]{0} collective-permute(%f0), source_target_pairs={{0,1}}
  %f1 = f32[64]{0} fusion(%cp), kind=kLoop, calls=%c1
  %ag = f32[128]{0} all-gather(%f1), replica_groups={{0,1}}, dimensions={0}
  ROOT %t = (f32[64]{0}, f32[128]{0}) tuple(%cp, %ag)
}
"""
    s = overlap_stats(hlo)
    assert s["async_start"] == 1
    assert s["async_done"] == 1
    assert s["max_in_flight"] == 1
    # ar0(+f0 in flight) -> cp -> f1 -> ag: two collective->compute->
    # collective interleavings, 3 collectives total
    assert s["collectives"] == 3
    assert s["interleavings"] == 2
    empty = overlap_stats(
        "HloModule e\n\nENTRY %e () -> f32[] {\n"
        "  ROOT %c = f32[] constant(0)\n}\n"
    )
    assert empty["collectives"] == 0 and empty["interleavings"] == 0


def test_variants_and_step_builder_plumbing():
    from repro.launch.dryrun import _cell_suffix
    from repro.launch.steps import VARIANTS, make_train_step
    from repro import configs as C
    from repro.optim import adamw

    assert VARIANTS["bucketed"] == {
        "bucket_bytes": 4 << 20, "num_chains": "auto",
    }
    assert VARIANTS["bucketed-int8"] == {
        "bucket_bytes": 4 << 20, "num_chains": "auto",
        "compress_grads": True,
    }
    # bucketed dispatch is a property of the Chainwrite reduction
    with pytest.raises(ValueError, match="torrent"):
        make_train_step(
            C.get_smoke_config("yi-6b"), adamw.OptConfig(),
            collectives="xla", bucket_bytes=1 << 20,
        )
    # the dryrun suffix encodes the bucket knob so sweeps don't collide
    ns = argparse.Namespace(
        collectives="torrent", num_chains="auto", ar_algo="rs_ag",
        compress_grads=False, bucket_mb=4.0, variant="baseline",
        remat="dots",
    )
    assert _cell_suffix(ns) == "__torrent__kauto__b4MB"
    ns.bucket_mb = None
    assert _cell_suffix(ns) == "__torrent__kauto"


def test_build_cell_bucket_conflicts():
    from repro.launch.mesh import make_host_mesh
    from repro.launch.steps import build_cell

    mesh = make_host_mesh(model=1)
    with pytest.raises(ValueError, match="bucket_bytes"):
        build_cell(
            "yi-6b", "train_4k", mesh, smoke=True, collectives="torrent",
            variant="bucketed", bucket_bytes=1 << 20,
        )
    # agreeing explicit value is fine; cell records the resolved knob
    cell = build_cell(
        "yi-6b", "train_4k", mesh, smoke=True, collectives="torrent",
        variant="bucketed", bucket_bytes=4 << 20,
    )
    assert cell.bucket_bytes == 4 << 20
    assert cell.num_chains == "auto"


def test_bucket_fold_order_matches_per_leaf_numpy_twin():
    """The fold-order half of the bit-identity claim, pinned on the
    numpy twin (which is immune to XLA's context-dependent FMA
    contraction — see test_bucketed_reduce_bit_identical): replaying
    the SAME all-reduce ChainProgram over a chunk-aligned bucket
    payload and over each leaf alone yields bit-identical per-element
    sums for arbitrary (inexact-product) float values — the
    chunk-aligned layout gives every element the same ring fold order
    as its per-leaf reduce."""
    from repro.core.chainwrite_ref import run_program_ref
    from repro.parallel.collectives import ring_order_for_axis

    L = 8
    rng = np.random.default_rng(7)
    sizes = [384, 5, 256, 256, 231, 97]
    leaves = [rng.standard_normal(n).astype(np.float32) for n in sizes]
    scales = rng.uniform(0.5, 3.0, L).astype(np.float32)

    base = ring_order_for_axis(L, "tsp")
    for algo, k in (("rs_ag", 1), ("rs_ag", 2), ("rotation", 2)):
        ring = L // k
        orders = tuple(
            tuple(base[i * ring : (i + 1) * ring]) for i in range(k)
        )
        prog = plan_all_reduce(L, orders, algo)
        shards = all_reduce_shards(L, k, algo)
        assert shards == prog.addr_shards

        def reduce_payload(flat):
            xs = np.stack([(flat * s).astype(np.float32) for s in scales])
            out = run_program_ref(xs, prog)  # (L, n) per-rank results
            if algo == "rs_ag":
                # RS+AG folds each chunk in one chunk-determined order,
                # so all ranks hold identical bits; rotation folds in a
                # per-rank rotation order and ranks legitimately differ
                # by rounding, so there we compare rank-by-rank only.
                np.testing.assert_array_equal(
                    out, np.broadcast_to(out[:1], out.shape)
                )
            return out

        widths, _ = bucket_shard_layout(sizes, shards)
        padded = [
            np.pad(f, (0, shards * m - f.size)).reshape(shards, m)
            for f, m in zip(leaves, widths)
        ]
        bucket = np.concatenate(padded, axis=1).reshape(-1)
        mat = reduce_payload(bucket).reshape(L, shards, -1)
        off = 0
        for f, m in zip(leaves, widths):
            got = mat[:, :, off : off + m].reshape(L, -1)[:, : f.size]
            off += m
            np.testing.assert_array_equal(
                got, reduce_payload(f), err_msg=f"{algo} K={k} n={f.size}"
            )


@pytest.mark.slow
def test_bucketed_reduce_bit_identical(run_multidevice):
    """The chunk-aligned bucket layout keeps every element's ring fold
    order equal to its per-leaf reduction's, so the bucketed reduce is
    BIT-identical to the per-leaf reduce at the exact f32 wire — for
    K=1, fixed multi-chain K, auto-K, both algos, several bucket
    sizes.

    The per-rank grads scale by an exact power of two (``2**rank``):
    XLA CPU freely FMA-contracts a producer multiply into the ring's
    combine adds (context-dependently, and ``optimization_barrier``
    does not stop it), so inexact products can pick up 1-ulp excess
    precision in one compiled layout but not the other. Power-of-two
    products are exact, making contraction invisible and leaving fold
    ORDER — the thing the bucket layout must preserve — as the only
    way this equality can break. Fold-order identity for arbitrary
    float values is pinned separately against the numpy twin in
    test_bucket_fold_order_matches_per_leaf_numpy_twin."""
    run_multidevice("""
    from repro.parallel.collectives import torrent_grad_reduce

    mesh = jax.make_mesh((8, 1), ('data', 'model'),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    batch_specs = {'d': P('data', None)}
    dummy = {'d': jnp.zeros((8, 1), jnp.float32)}

    rng = np.random.default_rng(0)
    shapes = [(97,), (33, 7), (256,), (16, 16), (5,), (128, 3)]
    tree = {
        f'w{i}': jnp.asarray(
            rng.standard_normal(s).astype(np.float32) * (i + 1))
        for i, s in enumerate(shapes)
    }

    def grad_fn(params, batch):
        # per-rank distinct grads: scale by 2**rank (exact product; see
        # the test docstring) via the batch shard
        r = batch['d'][0, 0]
        return jax.tree.map(lambda g: g * jnp.exp2(r), params), {}

    batch = {'d': jnp.arange(8, dtype=jnp.float32).reshape(8, 1)}

    def run(**kw):
        red = torrent_grad_reduce(grad_fn, mesh, batch_specs, **kw)
        with jax.set_mesh(mesh):
            out = jax.jit(lambda p, b: red(p, b)[0])(tree, batch)
        return jax.tree.map(np.asarray, out)

    for kw in (
        dict(num_chains=1),
        dict(num_chains=2),
        dict(num_chains=2, algo='rotation'),
        dict(num_chains=4),
        dict(num_chains='auto'),
    ):
        base = run(**kw)
        for bb in (1, 512, 4096, 1 << 20):
            got = run(bucket_bytes=bb, **kw)
            for k in tree:
                np.testing.assert_array_equal(
                    base[k], got[k], err_msg=f'{kw} bb={bb} leaf={k}')
    print('bucketed bit-identical OK')
    """, timeout=900)


@pytest.mark.slow
def test_bucketed_int8_ef_convergence(run_multidevice):
    """The PR 6 EF separation, under bucketing: bucketed int8+EF
    converges like per-leaf int8+EF does, and plain bucketed int8
    still provably stalls — bucketing composes with compression
    without changing the EF story."""
    run_multidevice("""
    from repro.parallel.collectives import (
        ef_residual_init, torrent_grad_reduce)

    mesh = jax.make_mesh((8, 1), ('data', 'model'),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)

    n = 32
    idx = np.arange(n)
    is_a = idx % 4 == 0
    h = jnp.asarray(np.where(is_a, 0.05, 1.0).astype(np.float32))
    t = jnp.asarray(np.where(is_a, 80000.0, 2.0).astype(np.float32))
    lr, steps = 0.05, 60

    def grad_fn(params, batch):
        return {'w': h * (params['w'] - t)}, {'loss': jnp.float32(0.0)}

    batch_specs = {'d': P('data', None)}
    dummy = {'d': jnp.zeros((8, 1), jnp.float32)}

    def run(mode, bucket_bytes=None):
        w = jnp.zeros((n,), jnp.float32)
        kw = {'bucket_bytes': bucket_bytes}
        if mode != 'f32':
            kw['wire_dtype'] = 'int8'
        if mode == 'ef':
            kw['error_feedback'] = True
        reduce = torrent_grad_reduce(grad_fn, mesh, batch_specs, **kw)
        if mode == 'ef':
            res = ef_residual_init({'w': w}, 8)
            @jax.jit
            def step(w, res):
                grads, _, new_res = reduce({'w': w}, {'d': dummy}, res)
                return w - lr * grads['w'], new_res
            with jax.set_mesh(mesh):
                for _ in range(steps):
                    w, res = step(w, res)
                    w.block_until_ready()
        else:
            @jax.jit
            def step(w):
                grads, _ = reduce({'w': w}, {'d': dummy})
                return w - lr * grads['w']
            with jax.set_mesh(mesh):
                for _ in range(steps):
                    w = step(w)
                    w.block_until_ready()
        wb = np.asarray(w)[~is_a]
        tb = np.asarray(t)[~is_a]
        return float(np.sum((wb - tb) ** 2) / np.sum(tb ** 2))

    BB = 64
    f32 = run('f32', BB)
    int8 = run('int8', BB)
    ef = run('ef', BB)
    print('bucketed residual fractions:', f32, int8, ef)
    assert f32 < 0.05, f32           # exact wire converges, bucketed
    assert ef < 0.25, ef             # EF recovers most of it
    assert int8 > 0.6, int8          # plain bucketed int8 stalls
    assert ef < int8 / 2, (ef, int8)
    print('bucketed ef OK')
    """, timeout=900)
