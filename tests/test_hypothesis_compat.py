"""The offline hypothesis shim itself: determinism and settings.

Guarded so the file also passes when real hypothesis is installed
(where example counts and draw sequences are its own business).
"""

from __future__ import annotations

import pytest

import _hypothesis_compat as hc
from _hypothesis_compat import given, settings, strategies as st

_calls_a: list[int] = []


@settings(max_examples=7, deadline=None)
@given(x=st.integers(0, 10**6))
def test_settings_above_given_collects(x):
    _calls_a.append(x)
    assert 0 <= x <= 10**6


def test_settings_max_examples_honored():
    """@settings stacked ABOVE @given (the repo's order) must cap the
    example count — regression for the shim reading it too early."""
    if hc.HAVE_HYPOTHESIS:
        pytest.skip("real hypothesis manages its own example budget")
    assert len(_calls_a) == 7, len(_calls_a)


def test_draws_are_deterministic():
    if hc.HAVE_HYPOTHESIS:
        pytest.skip("real hypothesis manages its own RNG")
    seen: list[list[int]] = []

    def collect():
        drawn: list[int] = []

        @settings(max_examples=5, deadline=None)
        @given(x=st.integers(0, 1000))
        def inner(x):
            drawn.append(x)

        inner.__qualname__ = "stable_name_for_seed"
        inner()
        return drawn

    seen.append(collect())
    seen.append(collect())
    assert seen[0] == seen[1]


def test_failing_example_is_reported():
    if hc.HAVE_HYPOTHESIS:
        pytest.skip("shim-specific error format")

    @settings(max_examples=10, deadline=None)
    @given(x=st.integers(0, 5))
    def always_fails(x):
        assert x < 0

    with pytest.raises(AssertionError, match="property failed on example"):
        always_fails()


def test_unique_lists_and_sampled_from():
    if hc.HAVE_HYPOTHESIS:
        pytest.skip("shim-specific API subset")
    import random

    rng = random.Random(0)
    strat = st.lists(st.integers(1, 9), min_size=4, max_size=9, unique=True)
    for _ in range(20):
        vals = strat.draw(rng)
        assert len(vals) == len(set(vals))
        assert 4 <= len(vals) <= 9
    pool = ["a", "b", "c"]
    assert all(st.sampled_from(pool).draw(rng) in pool for _ in range(10))
