"""Pallas flash-attention kernel: sweep vs pure-jnp oracle (interpret mode)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref


def _qkv(B, H, Hkv, S, D, dtype=jnp.float32, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (B, H, S, D), dtype)
    k = jax.random.normal(ks[1], (B, Hkv, S, D), dtype)
    v = jax.random.normal(ks[2], (B, Hkv, S, D), dtype)
    return q, k, v


@pytest.mark.parametrize("S,D", [(128, 64), (256, 64), (256, 128), (512, 32)])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_matches_ref(S, D, causal):
    q, k, v = _qkv(2, 4, 4, S, D)
    got = flash_attention(q, k, v, causal=causal, block_q=128, block_k=128)
    want = attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-3, rtol=2e-3)


@pytest.mark.parametrize("H,Hkv", [(8, 2), (8, 8), (6, 1), (4, 2)])
def test_flash_gqa_head_mapping(H, Hkv):
    q, k, v = _qkv(1, H, Hkv, 256, 64, seed=1)
    got = flash_attention(q, k, v, causal=True, block_q=128, block_k=128)
    want = attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-3, rtol=2e-3)


@pytest.mark.parametrize("window", [32, 128, 300])
def test_flash_sliding_window(window):
    q, k, v = _qkv(1, 2, 2, 512, 64, seed=2)
    got = flash_attention(
        q, k, v, causal=True, window=window, block_q=128, block_k=128
    )
    want = attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-3, rtol=2e-3)


def test_flash_bf16():
    q, k, v = _qkv(1, 2, 2, 256, 64, dtype=jnp.bfloat16, seed=3)
    got = flash_attention(q, k, v, causal=True, block_q=128, block_k=128)
    want = attention_ref(q, k, v, causal=True)
    assert got.dtype == jnp.bfloat16
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        atol=3e-2, rtol=3e-2,
    )


def test_flash_uneven_blocks():
    """block_q != block_k and blocks smaller than S."""
    q, k, v = _qkv(1, 2, 2, 512, 64, seed=4)
    got = flash_attention(q, k, v, causal=True, block_q=256, block_k=128)
    want = attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-3, rtol=2e-3)


def test_flash_rejects_indivisible():
    q, k, v = _qkv(1, 2, 2, 200, 64)
    with pytest.raises(ValueError):
        flash_attention(q, k, v, block_q=128, block_k=128)


def test_custom_scale():
    q, k, v = _qkv(1, 2, 2, 128, 64, seed=5)
    got = flash_attention(q, k, v, causal=False, scale=0.5, block_q=128, block_k=128)
    want = attention_ref(q, k, v, causal=False, scale=0.5)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-3, rtol=2e-3)
