"""Host-side four-phase ChainTask orchestration (paper Fig. 4)."""

from __future__ import annotations

import numpy as np
import pytest

from repro.core.chaintask import AffinePattern, ChainTask, Phase
from repro.core.topology import MeshTopology

TOPO = MeshTopology(4, 5)


def test_four_phases_deliver_payload():
    payload = np.arange(1024, dtype=np.float32)
    task = ChainTask(TOPO, 0, [3, 7, 12], payload)
    assert task.phase is Phase.IDLE
    bufs = task.run()
    assert task.phase is Phase.DONE
    assert set(bufs) == {3, 7, 12}
    for d in (3, 7, 12):
        np.testing.assert_array_equal(bufs[d], payload)
    # grant/finish reached every member
    assert task.grants == {3, 7, 12}
    assert task.finishes == {3, 7, 12}


def test_cycle_ledger_sums_and_matches_prediction():
    payload = np.zeros(64 * 1024, np.uint8)
    task = ChainTask(TOPO, 0, [1, 2, 3], payload, scheduler="greedy")
    task.run()
    lg = task.cycle_ledger
    assert lg["total"] == lg["cfg"] + lg["grant"] + lg["data"] + lg["finish"]
    assert lg["total"] == task.predicted_cycles()


def test_configs_form_doubly_linked_list():
    task = ChainTask(TOPO, 0, [5, 2, 9], payload=np.zeros(8))
    cfgs = task.configs()
    chain = [0] + task.order
    assert [c.node for c in cfgs] == chain
    assert cfgs[0].prev_node is None
    assert cfgs[-1].next_node is None
    for i in range(1, len(cfgs)):
        assert cfgs[i].prev_node == chain[i - 1]
        assert cfgs[i - 1].next_node == chain[i]
    assert all(c.size_bytes == 64 for c in cfgs)  # 8 f64


def test_affine_pattern_gather():
    """Field F: the DSE ND-affine access (cfg Fig. 4c) reshuffles on the fly."""
    payload = np.arange(24, dtype=np.int64).reshape(4, 6)
    # transpose via strides: bounds (6,4), strides (1,6)
    pat = AffinePattern(base=0, bounds=(6, 4), strides=(1, 6))
    task = ChainTask(TOPO, 0, [1], payload, pattern=pat)
    bufs = task.run()
    np.testing.assert_array_equal(
        bufs[1].reshape(6, 4), payload.T
    )


def test_transport_hook_sees_every_hop():
    hops = []
    task = ChainTask(TOPO, 0, [1, 2], np.zeros(16))
    task.run(transport=lambda src, dst, data: hops.append((src, dst)))
    chain = [0] + task.order
    assert hops == list(zip(chain, chain[1:]))


def test_validation_errors():
    with pytest.raises(ValueError):
        ChainTask(TOPO, 0, [1, 1], np.zeros(4))
    with pytest.raises(ValueError):
        ChainTask(TOPO, 0, [0, 1], np.zeros(4))


def test_speedup_vs_unicast_multi_dst():
    payload = np.zeros(64 * 1024, np.uint8)
    task = ChainTask(TOPO, 0, list(range(1, 13)), payload, scheduler="tsp")
    assert task.speedup_vs_unicast() > 2.0
