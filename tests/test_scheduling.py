"""Chainwrite schedulers: Alg. 1 greedy, open-path TSP, hop accounting."""

from __future__ import annotations

import random

import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.core.scheduling import (
    SCHEDULERS,
    brute_force_schedule,
    chain_total_hops,
    greedy_schedule,
    multicast_total_hops,
    naive_schedule,
    tsp_schedule,
    unicast_total_hops,
)
from repro.core.topology import MeshTopology


TOPO = MeshTopology(8, 8)


def _rand_dests(rng: random.Random, n: int, num_nodes: int = 64) -> list[int]:
    return rng.sample(range(1, num_nodes), n)


# ---------------------------------------------------------------------------
# correctness / invariants
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", ["naive", "greedy", "tsp"])
def test_schedules_are_permutations(name):
    rng = random.Random(0)
    for n in (1, 2, 5, 9, 16):
        dests = _rand_dests(rng, n)
        order = SCHEDULERS[name](TOPO, dests, 0)
        assert sorted(order) == sorted(dests)


@pytest.mark.parametrize("name", ["naive", "greedy", "tsp"])
def test_empty_and_single(name):
    assert SCHEDULERS[name](TOPO, [], 0) == []
    assert SCHEDULERS[name](TOPO, [7], 0) == [7]


def test_greedy_starts_nearest_to_source():
    # paper Alg.1 line 2: start from dest closest to C0
    dests = [63, 9, 1]
    assert greedy_schedule(TOPO, dests, 0)[0] == 1


@pytest.mark.slow
def test_tsp_exact_matches_brute_force():
    rng = random.Random(1)
    for n in (2, 3, 5, 7):
        dests = _rand_dests(rng, n)
        exact = tsp_schedule(TOPO, dests, 0)
        brute = brute_force_schedule(TOPO, dests, 0)
        assert chain_total_hops(TOPO, exact, 0) == chain_total_hops(TOPO, brute, 0)


@pytest.mark.slow
def test_tsp_heuristic_close_to_exact():
    """Force the 2-opt path (exact_threshold=0) and compare to Held-Karp."""
    rng = random.Random(2)
    for _ in range(6):
        dests = _rand_dests(rng, 9)
        heur = tsp_schedule(TOPO, dests, 0, exact_threshold=0)
        exact = tsp_schedule(TOPO, dests, 0)
        h = chain_total_hops(TOPO, heur, 0)
        e = chain_total_hops(TOPO, exact, 0)
        assert h <= 1.35 * e, (h, e)


@settings(max_examples=40, deadline=None)
@given(data=st.data())
def test_schedulers_never_worse_than_each_other_bounds(data):
    dests = data.draw(
        st.lists(st.integers(1, 63), min_size=2, max_size=10, unique=True)
    )
    naive = chain_total_hops(TOPO, naive_schedule(TOPO, dests, 0), 0)
    greedy = chain_total_hops(TOPO, greedy_schedule(TOPO, dests, 0), 0)
    tsp = chain_total_hops(TOPO, tsp_schedule(TOPO, dests, 0), 0)
    # TSP is optimal for n<=13: it lower-bounds the others.
    assert tsp <= naive
    assert tsp <= greedy
    # any chain visits every destination: at least n hops... no — at
    # least max(distance) and at least n-1 + nearest: use weak bound
    assert tsp >= max(TOPO.distance(0, d) for d in dests)


# ---------------------------------------------------------------------------
# paper Fig. 6 qualitative reproduction (full sweep in benchmarks/)
# ---------------------------------------------------------------------------


def _avg_hops(fn, n_dst: int, repeats: int = 32, seed: int = 3) -> float:
    rng = random.Random(seed)
    total = 0.0
    for _ in range(repeats):
        dests = _rand_dests(rng, n_dst)
        total += fn(dests) / n_dst
    return total / repeats


def test_fig6_ordering_at_scale():
    """naive chain > multicast; tsp <= multicast at N_dst = 48+ (8x8)."""
    n = 48
    naive = _avg_hops(lambda d: chain_total_hops(TOPO, naive_schedule(TOPO, d, 0), 0), n)
    greedy = _avg_hops(lambda d: chain_total_hops(TOPO, greedy_schedule(TOPO, d, 0), 0), n)
    tsp = _avg_hops(lambda d: chain_total_hops(TOPO, tsp_schedule(TOPO, d, 0), 0), n)
    mcast = _avg_hops(lambda d: multicast_total_hops(TOPO, d, 0), n)
    uni = _avg_hops(lambda d: unicast_total_hops(TOPO, d, 0), n)
    assert naive > mcast, (naive, mcast)
    assert tsp <= mcast * 1.02, (tsp, mcast)
    assert greedy <= naive
    assert uni > mcast  # unicast pays full Manhattan per dest


def test_fig6_converges_to_one_hop_per_dst():
    """At N_dst=63 (all nodes) the tsp chain = Hamiltonian path: 1 hop/dst."""
    dests = list(range(1, 64))
    order = tsp_schedule(TOPO, dests, 0, exact_threshold=0)
    hops = chain_total_hops(TOPO, order, 0)
    assert hops / 63 <= 1.1  # paper: converges to ~1
    # multicast too
    assert multicast_total_hops(TOPO, dests, 0) / 63 <= 1.1


def test_snake_is_optimal_for_full_mesh():
    dests = TOPO.snake_order()[1:]
    assert chain_total_hops(TOPO, dests, 0) == 63
