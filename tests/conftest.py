"""Shared fixtures and the multi-device subprocess harness.

The container has ONE real CPU device and the dry-run instructions forbid
setting ``xla_force_host_platform_device_count`` globally — smoke tests
must see 1 device. Collective tests therefore run in a *subprocess* with
the flag set locally (``run_multidevice``).
"""

from __future__ import annotations

import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")

_PREAMBLE = """\
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={n}"
import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P
assert jax.device_count() == {n}, jax.device_count()
"""


def _run_multidevice(code: str, devices: int = 8, timeout: int = 600) -> str:
    """Run ``code`` in a fresh python with N virtual CPU devices.

    The snippet must raise (or assert) on failure; stdout is returned
    for extra checks.
    """
    src = _PREAMBLE.format(n=devices) + textwrap.dedent(code)
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, "-c", src],
        capture_output=True,
        text=True,
        timeout=timeout,
        env=env,
    )
    if proc.returncode != 0:
        raise AssertionError(
            f"multidevice snippet failed (rc={proc.returncode})\n"
            f"--- stdout ---\n{proc.stdout}\n--- stderr ---\n{proc.stderr[-4000:]}"
        )
    return proc.stdout


@pytest.fixture(scope="session")
def run_multidevice():
    return _run_multidevice


@pytest.fixture()
def tmp_ckpt_dir(tmp_path):
    return str(tmp_path / "ckpts")
