"""Algo-aware all-reduce latency model + ring-partition validation.

Pins the PR 3 acceptance invariants host-side (no devices needed):

* ``all_reduce_latency`` at K=1 reduces CC-exactly to the single-ring
  reduce-scatter + all-gather model (closed form recomputed here from
  ``SimParams`` — for either ``algo``, mirroring the SPMD delegation);
* the byte/latency trade: ``rs_ag`` wins for large payloads, the
  step-count-lean ``rotation`` for tiny ones;
* ``choose_num_chains(collective="all_reduce")`` returns a divisor K
  whose sub-rings partition the group and never models worse than K=1;
* ``all_reduce_wire_bytes`` matches the schedule formulas;
* ``chainwrite.validate_ring_partition`` + the numpy schedule oracle
  ``multi_all_reduce_ref``, property-style via _hypothesis_compat.
"""

from __future__ import annotations

import numpy as np
import pytest

from _hypothesis_compat import given, settings, strategies as st
from repro.core.chainwrite import validate_ring_partition
from repro.core.chainwrite_ref import all_reduce_ref, multi_all_reduce_ref
from repro.core.simulator import (
    DEFAULT_PARAMS,
    _ceil_div,
    all_reduce_latency,
    all_reduce_wire_bytes,
    choose_num_chains,
)
from repro.core.topology import MeshTopology

LINE8 = MeshTopology(8, 1)  # the DP-ring analogue topo (1 hop/neighbour)
MESH = MeshTopology(4, 5)  # the paper's 20-cluster SoC
KB = 1024


def _single_ring_closed_form(topo, src, ring, size_bytes, p=DEFAULT_PARAMS):
    """The single-ring RS+AG model, written out independently."""
    L = len(ring)
    loop = list(ring) + [ring[0]]
    hops = sum(topo.distance(a, b) for a, b in zip(loop, loop[1:]))
    far = max(topo.distance(src, d) for d in ring)
    max_edge = max(topo.distance(a, b) for a, b in zip(loop, loop[1:]))
    cfg = (
        p.dma_setup_cc + L * p.cfg_inject_cc + far * p.router_cc + p.cfg_proc_cc
    )
    grant = hops * p.router_cc + L * p.grant_fwd_cc
    finish = hops * p.router_cc + L * p.finish_fwd_cc
    shard_cc = _ceil_div(_ceil_div(size_bytes, L), p.link_bw)
    data = 2 * (L - 1) * (max_edge * p.router_cc + p.sf_fill_cc + shard_cc)
    return cfg + grant + data + finish


def test_k1_reduces_exactly_to_single_ring_model():
    ring = list(range(8))
    for size in (1 * KB, 64 * KB, 1 << 20):
        want = _single_ring_closed_form(LINE8, 0, ring, size)
        for algo in ("rs_ag", "rotation"):  # K=1 delegates for either
            assert all_reduce_latency(LINE8, 0, [ring], size, algo=algo) == want


def test_rs_ag_wins_large_payloads_rotation_wins_tiny():
    rings = [[0, 1, 2, 3], [4, 5, 6, 7]]
    big = 1 << 20
    assert all_reduce_latency(LINE8, 0, rings, big, algo="rs_ag") < (
        all_reduce_latency(LINE8, 0, rings, big, algo="rotation")
    )
    # 1-byte payload: the extra S-1 steps of RS+AG cost more than the
    # (negligible) byte saving — the trade choose_num_chains models.
    assert all_reduce_latency(LINE8, 0, rings, 1, algo="rotation") < (
        all_reduce_latency(LINE8, 0, rings, 1, algo="rs_ag")
    )


def test_detail_dict_consistent():
    rings = [[0, 1, 2, 3], [4, 5, 6, 7]]
    d = all_reduce_latency(LINE8, 0, rings, 64 * KB, algo="rs_ag", detail=True)
    assert d["total"] == max(d["per_chain"])
    for per_chain, phases in zip(d["per_chain"], d["per_phase"]):
        assert per_chain == sum(phases)
    assert d["algo"] == "rs_ag"
    assert d["wire_bytes"] == all_reduce_wire_bytes(4, 2, 64 * KB, "rs_ag")
    # cfg-port serialization: the second ring's cfg phase starts later
    assert d["per_phase"][1][0] > d["per_phase"][0][0]
    assert all_reduce_latency(LINE8, 0, [], 64 * KB) == 0


def test_unequal_rings_and_bad_algo_raise():
    with pytest.raises(ValueError):
        all_reduce_latency(LINE8, 0, [[0, 1, 2], [3, 4]], KB)
    with pytest.raises(ValueError):
        all_reduce_latency(LINE8, 0, [[0, 1]], KB, algo="bogus")
    with pytest.raises(ValueError):
        all_reduce_wire_bytes(4, 2, KB, algo="bogus")
    with pytest.raises(ValueError):
        all_reduce_wire_bytes(0, 2, KB)
    with pytest.raises(ValueError):
        choose_num_chains(LINE8, 0, [1, 2], KB, collective="bogus")


def test_wire_bytes_formulas():
    B = 256 * KB
    # rotation: (S+K-2) full payloads
    assert all_reduce_wire_bytes(4, 2, B, "rotation") == 4 * B
    assert all_reduce_wire_bytes(2, 4, B, "rotation") == 4 * B
    # rs_ag: (2(S-1)+(K-1)) shards of ceil(B/S)
    assert all_reduce_wire_bytes(4, 2, B, "rs_ag") == 7 * (B // 4)
    assert all_reduce_wire_bytes(2, 4, B, "rs_ag") == 5 * (B // 2)
    # K=1 delegates to single-ring RS+AG for either algo: 2(L-1)/L
    assert (
        all_reduce_wire_bytes(8, 1, B, "rotation")
        == all_reduce_wire_bytes(8, 1, B, "rs_ag")
        == 14 * (B // 8)
    )
    # the collapse the tentpole claims: rs_ag strictly below rotation
    for S, K in ((4, 2), (2, 4), (8, 2), (4, 4)):
        assert all_reduce_wire_bytes(S, K, B, "rs_ag") < (
            all_reduce_wire_bytes(S, K, B, "rotation")
        )


def test_choose_num_chains_all_reduce_invariants():
    for topo, n in ((LINE8, 8), (MeshTopology(16, 1), 16), (MESH, 20)):
        for size in (1 * KB, 64 * KB, 4 << 20):
            for algo in ("rs_ag", "rotation"):
                k, rings = choose_num_chains(
                    topo, 0, list(range(1, n)), size,
                    collective="all_reduce", algo=algo,
                )
                assert 1 <= k <= 4 and n % k == 0 and len(rings) == k
                assert sorted(d for r in rings for d in r) == list(range(n))
                assert all(len(r) == n // k for r in rings)
                lat = all_reduce_latency(topo, 0, rings, size, algo=algo)
                ring1 = choose_num_chains(
                    topo, 0, list(range(1, n)), size,
                    collective="all_reduce", algo=algo, max_chains=1,
                )[1]
                assert lat <= all_reduce_latency(topo, 0, ring1, size, algo=algo)


def test_choose_num_chains_broadcast_path_unchanged():
    """The PR 1 behaviour survives the algo-aware extension."""
    k, chains = choose_num_chains(MESH, 0, [3, 7, 12, 14], 64 * KB)
    assert 1 <= k <= 4
    assert sorted(d for c in chains for d in c) == [3, 7, 12, 14]


# ---------------------------------------------------------------------------
# Property tests (deterministic via _hypothesis_compat)
# ---------------------------------------------------------------------------


def _random_partition(rng, L, K):
    perm = list(range(L))
    rng.shuffle(perm)
    S = L // K
    return [tuple(perm[i * S : (i + 1) * S]) for i in range(K)]


@settings(max_examples=40)
@given(data=st.data())
def test_validate_ring_partition_properties(data):
    K = data.draw(st.sampled_from([1, 2, 3, 4]), label="K")
    S = data.draw(st.integers(min_value=1, max_value=5), label="S")
    L = K * S
    import random as _random

    rng = _random.Random(data.draw(st.integers(min_value=0, max_value=9999)))
    orders = _random_partition(rng, L, K)
    cleaned = validate_ring_partition(L, orders)
    assert sorted(d for c in cleaned for d in c) == list(range(L))

    # a duplicated member (no longer a partition) must raise
    if L > 1:
        bad = [list(c) for c in orders]
        bad[0][0] = bad[-1][-1]
        with pytest.raises(ValueError):
            validate_ring_partition(L, bad)
    # unequal sizes must raise
    if K > 1 and S > 1:
        lop = [orders[0][:-1]] + [orders[1] + orders[0][-1:]] + list(orders[2:])
        with pytest.raises(ValueError):
            validate_ring_partition(L, lop)
    # missing a device must raise
    with pytest.raises(ValueError):
        validate_ring_partition(L + 1, orders)
    with pytest.raises(ValueError):
        validate_ring_partition(L, [])


@settings(max_examples=25)
@given(data=st.data())
def test_multi_all_reduce_ref_sums_any_schedule(data):
    """The schedule-replaying oracle computes a true all-reduce for any
    ring partition, either algo, any (incl. non-divisible) payload."""
    K = data.draw(st.sampled_from([1, 2, 3, 4]), label="K")
    S = data.draw(st.integers(min_value=1, max_value=4), label="S")
    lead = data.draw(st.integers(min_value=1, max_value=9), label="lead")
    algo = data.draw(st.sampled_from(["rs_ag", "rotation"]), label="algo")
    L = K * S
    import random as _random

    rng = _random.Random(data.draw(st.integers(min_value=0, max_value=9999)))
    orders = _random_partition(rng, L, K)
    xs = np.random.default_rng(L * lead).normal(size=(L, lead, 2))
    xs = xs.astype(np.float32)
    out = multi_all_reduce_ref(xs, orders, algo)
    assert out.shape == xs.shape
    np.testing.assert_allclose(
        out, all_reduce_ref(xs), rtol=2e-5, atol=2e-5,
        err_msg=f"{orders} {algo} lead={lead}",
    )


@settings(max_examples=20)
@given(data=st.data())
def test_wire_bytes_monotone_and_model_agrees(data):
    """rs_ag wire bytes never exceed rotation's for K>=2 at any
    non-degenerate payload (shard rounding can invert the order only
    when the payload is smaller than one shard per step, i.e. a few
    bytes), and the latency model's detail reports exactly the formula
    bytes."""
    K = data.draw(st.integers(min_value=2, max_value=4), label="K")
    S = data.draw(st.integers(min_value=2, max_value=8), label="S")
    size = data.draw(st.sampled_from([4096, 65536, 1 << 20]), label="size")
    assert all_reduce_wire_bytes(S, K, size, "rs_ag") <= (
        all_reduce_wire_bytes(S, K, size, "rotation")
    )
    topo = MeshTopology(S * K, 1)
    rings = [
        list(range(c * S, (c + 1) * S)) for c in range(K)
    ]
    for algo in ("rs_ag", "rotation"):
        d = all_reduce_latency(topo, 0, rings, size, algo=algo, detail=True)
        assert d["wire_bytes"] == all_reduce_wire_bytes(S, K, size, algo)
