# Frozen pre-refactor dense-table planners (golden reference).
#
# This is the core/program.py planner module as it stood before the
# symbolic-addressing refactor, vendored verbatim (only the two
# relative `.scheduling` imports rewritten as absolute ones). The
# property tests in test_symbolic_addressing.py materialize every
# symbolic table emitted by the live planners and require bit-exact
# equality with the dense tuples these planners build. Do not edit
# except to re-freeze against a deliberate schedule change.

"""ChainProgram: the single schedule IR behind every Torrent collective.

The paper's core claim is that every P2MP pattern is *just a schedule*
of P2P hops over an unmodified NoC. This module makes that literal: a
:class:`ChainProgram` is an ordered list of :class:`Step`\\ s, each step
a set of ``(src, dst)`` edges plus static per-device shard-addressing
tables, generated once by the ``plan_*`` functions from a chain/ring
partition. Three interchangeable backends consume the same program:

* the SPMD executor (``chainwrite.execute_program`` — fused ppermutes),
* the numpy interpreter (``chainwrite_ref.interpret_program`` — the
  bit-exactness oracle),
* the cycle/byte models (``simulator.program_latency`` /
  ``simulator.program_wire_bytes``).

Machine model (identical in every backend). Each device ``d`` holds:

* ``shards`` — its local input viewed as ``(addr_shards, m, ...)``
  (``addr_shards == 1`` means the whole payload is one frame);
* ``buf``   — the transit register: ``(width, m, ...)`` where ``width``
  is per-step (a step may carry a multi-shard block);
* ``out``   — ``(out_slots, m, ...)`` result/accumulator slots.

Per step, in order:

1. *load*    — ``buf[j] = out[load[d][j]]`` (``-1`` keeps the current
   row; required in full whenever the width changes);
2. *hop*     — ``buf = permute(buf, edges)``: ``dst`` receives ``src``'s
   buffer, devices no edge targets receive zeros;
3. *combine* — ``combine == "add"``: ``buf[j] += source[add_src[d][j]]``
   where ``source`` is the input shards (``add_from == "input"``) or the
   out slots (``add_from == "out"``); ``-1`` adds nothing;
4. *write*   — ``out[write[d][j]] (op)= buf[j]`` with ``write_op`` in
   ``{"copy", "add"}``; ``-1`` discards the row.

IR invariants (enforced by :meth:`ChainProgram.validate`, pinned by the
device-free golden-schedule tests):

* **edge-disjointness within a step** — a device receives at most one
  frame per step (unique destinations always; unique sources too for
  ``kind == "stepped"`` programs, so every step is ONE fused ppermute;
  ``kind == "pipeline"`` may repeat the head as a source — the
  executor splits the extra fan-out sends into their own permutes,
  which :func:`program_wire_bytes` accounts via
  :meth:`Step.num_permutes`);
* **shard-fraction accounting** — every step moves
  ``width / addr_shards`` of the payload per edge
  (:meth:`ChainProgram.step_bytes`); all addressing tables index within
  ``addr_shards`` / ``out_slots`` bounds, and a device's write rows
  target distinct slots;
* **combine-op semantics** — ``"copy"`` steps move data unchanged;
  ``"add"`` steps fold exactly one addressed local shard into each buf
  row *after* the hop (left-fold: ``buf + shard``), so replaying the
  program fixes the floating-point reduction order and any two
  backends agree BIT-exactly.

Planners (``orders``/``chains`` are the scheduled partitions from
``core.scheduling``; ``num_devices`` is the SPMD axis size or the NoC
node count):

* :func:`plan_broadcast`       — P2MP multicast down K disjoint chains
  (``kind="pipeline"``: the data phase streams, frames optional);
* :func:`plan_recovery`        — the endpoint-side failure recovery of
  a multi-chain broadcast as a program: one detection-window step
  (``tag="detect"``, no edges) plus the re-formed orphaned suffix of
  every affected sub-chain as ordered chain steps, each suffix
  streaming from the surviving member that banked the payload
  (``group_heads``); concurrent failures in distinct sub-chains share
  the steps (and the initiator's cfg port, in the latency model);
* :func:`plan_all_gather`      — per-ring all-gather, then a cross-ring
  block exchange for K > 1;
* :func:`plan_reduce_scatter`  — per-ring reduce-scatter over K-chunk
  groups, then a cross-ring group reduce-scatter for K > 1;
* :func:`plan_all_reduce`      — ``algo="rs_ag"`` (fused per-ring RS →
  cross-ring shard rotation → fused per-ring AG, shards addressed by
  ring position) or ``algo="rotation"`` (full-payload rotations); K=1
  is the single-ring RS+AG with *device-id* chunk addressing (the
  historical ``chain_all_reduce`` schedule);
* :func:`plan_all_to_all`      — the rotating chunk train; K > 1
  interleaves intra-ring rotations with cross-ring hops (same total
  wire, shorter per-step distances).

Every :class:`Step` (and the program as a default) carries a
``wire_dtype``: ``None`` ships frames in the payload dtype; ``"int8"``
quantizes each hop's frame to int8 with one f32 scale riding alongside
(per-hop quantize → ship → dequantize → f32 combine). Compression is
therefore an ordinary IR dimension — the same executor, oracle replay,
byte/latency accounting and (K, algo, wire_dtype) selection apply.

This module is dependency-light (stdlib only) so the SPMD layer, the
numpy oracle, the simulator and the CLI all share ONE schedule source.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Iterable, Iterator, Sequence

# Canonical multi-ring all-reduce schedule names — the single tuple the
# SPMD layer, the simulator and the CLI validate against.
ALL_REDUCE_ALGOS = ("rs_ag", "rotation")

# Wire dtypes a step may ship. None = payload dtype unchanged; "int8" =
# per-hop symmetric quantization: an int8 frame plus one f32 scale.
WIRE_DTYPES = ("int8",)
_WIRE_SCALE_BYTES = 4  # the f32 scale shipped alongside each int8 frame


def normalize_wire_dtype(wire_dtype) -> str | None:
    """Canonical IR form of a wire dtype: ``None`` (ship the payload
    dtype) or a name from :data:`WIRE_DTYPES`. Accepts the string form
    or any numpy/jax dtype object whose name matches — keeping this
    module stdlib-only while letting callers pass ``jnp.int8``."""
    if wire_dtype is None:
        return None
    if isinstance(wire_dtype, str):
        name = wire_dtype
    else:
        name = (
            getattr(wire_dtype, "__name__", None)
            or getattr(wire_dtype, "name", None)
            or str(wire_dtype)
        )
    if name not in WIRE_DTYPES:
        raise ValueError(
            f"unknown wire dtype {wire_dtype!r}; "
            f"expected None or one of {WIRE_DTYPES}"
        )
    return name


Edge = tuple[int, int]
Table = tuple[tuple[int, ...], ...]  # (num_devices, width); -1 = none

COPY = "copy"
ADD = "add"


def _table(rows: Sequence[Sequence[int]]) -> Table:
    return tuple(tuple(int(v) for v in row) for row in rows)


@dataclasses.dataclass(frozen=True)
class Step:
    """One schedule step: a set of concurrent P2P hops + addressing."""

    edges: tuple[Edge, ...]
    width: int = 1
    combine: str = COPY  # buf update after the hop: copy | add
    add_from: str = "input"  # add reads "input" shards or "out" slots
    add_src: Table | None = None
    load: Table | None = None  # out slots loaded into buf BEFORE the hop
    write: Table | None = None  # out slot written per buf row after combine
    write_op: str = COPY  # copy | add
    # Latency-model grouping: "intra" | "cross" (ring rounds), "chain"
    # (pipeline hop slots), "detect" (edge-free failure-timeout window —
    # priced as SimParams.fail_timeout_cc per occurrence, zero bytes).
    tag: str = "intra"
    # Per-step wire dtype override; None defers to the program default.
    wire_dtype: str | None = None

    def num_permutes(self) -> int:
        """ppermute ops the SPMD executor emits for this step: one fused
        permute for the unique-source edge set, plus one extra permute
        per repeated source (the pipeline head's same-step fan-out)."""
        if not self.edges:
            return 0
        counts: dict[int, int] = {}
        for src, _ in self.edges:
            counts[src] = counts.get(src, 0) + 1
        return 1 + sum(c - 1 for c in counts.values())


@dataclasses.dataclass(frozen=True)
class ChainProgram:
    """A complete collective schedule (see module docstring)."""

    collective: str  # broadcast | all_gather | reduce_scatter | ...
    kind: str  # "pipeline" (streamed chains) | "stepped" (ring rounds)
    num_devices: int
    addr_shards: int  # input viewed as (addr_shards, m, ...)
    out_slots: int
    buf_init: Table  # (L, width0) input-shard indices; -1 = zeros
    out_init: Table  # (L, out_slots) input-shard indices; -1 = zeros
    steps: tuple[Step, ...]
    # Schedule metadata for the latency model: for kind="pipeline" the
    # per-chain destination orders (head excluded) + head; for
    # kind="stepped" the K sub-rings (full member orders).
    groups: tuple[tuple[int, ...], ...]
    head: int | None = None
    algo: str | None = None
    # Per-group data-entry nodes for kind="pipeline" programs whose
    # streams do NOT all start at the cfg initiator (recovery: each
    # re-formed suffix streams from the member that banked the payload).
    # None = every group streams from the initiator.
    group_heads: tuple[int, ...] | None = None
    # Program-default wire dtype (``Step.wire_dtype`` overrides per
    # step); None = frames ship in the payload dtype.
    wire_dtype: str | None = None

    # -- accounting ---------------------------------------------------
    def step_wire_dtype(self, step: Step) -> str | None:
        """Resolved wire dtype of ``step``: its own override, else the
        program default; ``None`` = payload dtype."""
        return step.wire_dtype if step.wire_dtype is not None else self.wire_dtype

    def step_bytes(self, step: Step, size_bytes: int) -> int:
        """Frame bytes one edge of ``step`` carries, for a per-device
        input payload of ``size_bytes``. An int8-wire step ships a
        quarter-size frame (the byte model assumes a 4-byte payload
        dtype, matching the executor's f32 wire arithmetic) plus one
        f32 scale scalar per frame."""
        frame = step.width * _ceil_div(size_bytes, self.addr_shards)
        if self.step_wire_dtype(step) == "int8":
            return _ceil_div(frame, 4) + _WIRE_SCALE_BYTES
        return frame

    def wire_bytes(self, size_bytes: int) -> int:
        """Modeled collective wire bytes of the whole program — the
        trip-count-aware HLO ``collective-permute`` attribution: every
        emitted ppermute counts its (per-device) operand bytes. For
        ring ("stepped") programs every device sends each step, so this
        is also the per-device wire-byte total."""
        return sum(
            s.num_permutes() * self.step_bytes(s, size_bytes)
            for s in self.steps
        )

    @property
    def num_steps(self) -> int:
        return len(self.steps)

    def describe(self, size_bytes: int | None = None) -> Iterator[str]:
        """Human-readable step table (the examples/ demo)."""
        yield (
            f"{self.collective} [{self.kind}"
            + (f", algo={self.algo}" if self.algo else "")
            + (f", wire={self.wire_dtype}" if self.wire_dtype else "")
            + f"] devices={self.num_devices} shards=1/{self.addr_shards}"
            f" out_slots={self.out_slots} groups={list(self.groups)}"
        )
        for i, s in enumerate(self.steps):
            line = (
                f"  step {i:2d} [{s.tag:5s}] edges={len(s.edges)}"
                f" permutes={s.num_permutes()} frac={s.width}/{self.addr_shards}"
                f" combine={s.combine} {list(s.edges)}"
            )
            wd = self.step_wire_dtype(s)
            if wd is not None:
                line += f" wire={wd}"
            if size_bytes is not None:
                line += f" bytes/edge={self.step_bytes(s, size_bytes)}"
            yield line
        if size_bytes is not None:
            yield f"  total wire bytes: {self.wire_bytes(size_bytes)}"

    # -- validation ---------------------------------------------------
    def validate(self) -> "ChainProgram":
        L = self.num_devices
        if L < 1 or self.addr_shards < 1 or self.out_slots < 1:
            raise ValueError("degenerate program dimensions")
        if self.kind not in ("pipeline", "stepped"):
            raise ValueError(f"unknown program kind {self.kind!r}")
        if normalize_wire_dtype(self.wire_dtype) is not None and self.kind != "stepped":
            raise ValueError(
                "wire_dtype is only supported on stepped programs "
                "(the frame-pipelined executor ships payload-dtype frames)"
            )
        if self.group_heads is not None:
            if self.kind != "pipeline":
                raise ValueError("group_heads only applies to pipeline programs")
            if len(self.group_heads) != len(self.groups):
                raise ValueError(
                    f"group_heads has {len(self.group_heads)} entries, "
                    f"expected one per group ({len(self.groups)})"
                )
            for h in self.group_heads:
                if not 0 <= h < L:
                    raise ValueError(f"group head {h} out of range")
        self._check_table(self.buf_init, None, self.addr_shards, "buf_init")
        self._check_table(self.out_init, self.out_slots, self.addr_shards, "out_init")
        width = len(self.buf_init[0]) if self.buf_init else 1
        for i, s in enumerate(self.steps):
            if s.width < 1:
                raise ValueError(f"step {i}: width < 1")
            if normalize_wire_dtype(s.wire_dtype) is not None and self.kind != "stepped":
                raise ValueError(f"step {i}: wire_dtype on a {self.kind} program")
            dsts = [e[1] for e in s.edges]
            if len(set(dsts)) != len(dsts):
                raise ValueError(f"step {i}: duplicate edge destinations")
            if self.kind == "stepped":
                srcs = [e[0] for e in s.edges]
                if len(set(srcs)) != len(srcs):
                    raise ValueError(f"step {i}: duplicate edge sources")
            for a, b in s.edges:
                if not (0 <= a < L and 0 <= b < L):
                    raise ValueError(f"step {i}: edge ({a},{b}) out of range")
            if s.width != width and s.load is None:
                raise ValueError(f"step {i}: width change without load")
            if s.load is not None:
                self._check_table(s.load, s.width, self.out_slots, f"step {i} load")
            if s.combine == ADD:
                bound = self.addr_shards if s.add_from == "input" else self.out_slots
                if s.add_src is None:
                    raise ValueError(f"step {i}: add without add_src")
                self._check_table(s.add_src, s.width, bound, f"step {i} add_src")
            elif s.combine != COPY:
                raise ValueError(f"step {i}: unknown combine {s.combine!r}")
            if s.write is not None:
                self._check_table(s.write, s.width, self.out_slots, f"step {i} write")
                for d, row in enumerate(s.write):
                    live = [v for v in row if v >= 0]
                    if len(set(live)) != len(live):
                        raise ValueError(
                            f"step {i}: device {d} writes one slot twice"
                        )
            width = s.width
        return self

    def _check_table(self, table, width, bound, name) -> None:
        if len(table) != self.num_devices:
            raise ValueError(f"{name}: table has {len(table)} rows, "
                             f"expected {self.num_devices}")
        for row in table:
            if width is not None and len(row) != width:
                raise ValueError(f"{name}: row width {len(row)} != {width}")
            for v in row:
                if not (-1 <= v < bound):
                    raise ValueError(f"{name}: index {v} out of range {bound}")


def program_wire_bytes(program: ChainProgram, size_bytes: int) -> int:
    """Functional alias of :meth:`ChainProgram.wire_bytes`."""
    return program.wire_bytes(size_bytes)


def pipelined_wire_bytes(
    program: ChainProgram, size_bytes: int, num_frames: int = 1
) -> int:
    """Wire bytes of the frame-pipelined execution of a ``pipeline``
    program: the store-and-forward scan applies EVERY chain edge on
    each of its F + L - 2 slots at 1/F-payload frame granularity
    (idle edge slots still ship a frame-sized buffer — the modeled HLO
    attribution of the scanned executor). ``num_frames <= 1`` is the
    stepped execution, i.e. :func:`program_wire_bytes`."""
    if program.kind != "pipeline" or num_frames <= 1 or not program.steps:
        return program.wire_bytes(size_bytes)
    counts: dict[int, int] = {}
    for s in program.steps:
        for src, _ in s.edges:
            counts[src] = counts.get(src, 0) + 1
    permutes = 1 + sum(c - 1 for c in counts.values())
    slots = num_frames + len(program.steps) - 1
    return slots * permutes * _ceil_div(size_bytes, num_frames)


# ---------------------------------------------------------------------------
# Partition validation helpers
# ---------------------------------------------------------------------------


def validate_chains(
    head: int, chains: Sequence[Sequence[int]]
) -> tuple[tuple[int, ...], ...]:
    """Clean + validate K disjoint broadcast sub-chains (head excluded
    from every chain; empty chains dropped). An empty *result* is
    allowed here (a head-only broadcast); ``multi_chain_broadcast``
    rejects it at its own layer."""
    head = int(head)
    clean = [tuple(int(d) for d in c) for c in chains if len(c)]
    seen: set[int] = set()
    for c in clean:
        for d in c:
            if d == head:
                raise ValueError("head cannot appear inside a chain")
            if d in seen:
                raise ValueError(f"destination {d} appears in two chains")
            seen.add(d)
    return tuple(clean)


def validate_ring_partition(
    axis_size: int, orders: Sequence[Sequence[int]]
) -> list[tuple[int, ...]]:
    """Clean + validate K disjoint equal-size sub-rings covering the
    whole axis. Pure host-side helper shared by the SPMD ring
    collectives, the planners and the property tests."""
    clean = [tuple(int(o) for o in c) for c in orders if len(c)]
    if not clean:
        raise ValueError("empty ring set")
    S = len(clean[0])
    if any(len(c) != S for c in clean):
        raise ValueError("sub-rings must have equal sizes")
    flat = [d for c in clean for d in c]
    if sorted(flat) != list(range(axis_size)):
        raise ValueError("sub-rings must partition the whole axis")
    return clean


def _check_rings(
    num_devices: int, orders: Sequence[Sequence[int]]
) -> tuple[tuple[int, ...], ...]:
    """Planner-level ring validation: disjoint, equal sizes, members in
    range. (Unlike :func:`validate_ring_partition` the rings need not
    cover every device — the simulator models rings over node subsets
    of a larger NoC.)"""
    clean = [tuple(int(o) for o in c) for c in orders if len(c)]
    if not clean:
        raise ValueError("empty ring set")
    S = len(clean[0])
    if any(len(c) != S for c in clean):
        raise ValueError("sub-rings must have equal sizes")
    flat = [d for c in clean for d in c]
    if len(set(flat)) != len(flat):
        raise ValueError("sub-rings must be disjoint")
    if any(not 0 <= d < num_devices for d in flat):
        raise ValueError("ring member out of device range")
    return tuple(clean)


def _ring_maps(orders: tuple[tuple[int, ...], ...]):
    """(intra_edges, cross_edges, pos, ring_of) for K equal-size rings."""
    K, S = len(orders), len(orders[0])
    intra = tuple(
        (c[p], c[(p + 1) % S]) for c in orders for p in range(S)
    ) if S > 1 else ()
    cross = tuple(
        (orders[j][r], orders[(j + 1) % K][r])
        for j in range(K)
        for r in range(S)
    ) if K > 1 else ()
    pos: dict[int, int] = {}
    ring_of: dict[int, int] = {}
    for j, ring in enumerate(orders):
        for p, d in enumerate(ring):
            pos[d] = p
            ring_of[d] = j
    return intra, cross, pos, ring_of


def _rows(num_devices: int, width: int) -> list[list[int]]:
    return [[-1] * width for _ in range(num_devices)]


# ---------------------------------------------------------------------------
# Planners
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=None)
def plan_broadcast(
    num_devices: int, head: int, chains: tuple[tuple[int, ...], ...]
) -> ChainProgram:
    """P2MP multicast from ``head`` down K disjoint sub-chains.

    ``kind="pipeline"``: step ``t`` holds every chain's depth-``t``
    edge, so the steps double as the per-frame hop slots of the
    streamed (frame-pipelined) execution.
    """
    head = int(head)
    chains = validate_chains(head, chains)
    L = int(num_devices)
    full = [(head,) + c for c in chains]
    buf_init = _rows(L, 1)
    out_init = _rows(L, 1)
    buf_init[head][0] = 0
    out_init[head][0] = 0
    steps = []
    max_len = max((len(f) for f in full), default=1)
    for t in range(max_len - 1):
        edges = tuple((f[t], f[t + 1]) for f in full if t + 1 < len(f))
        write = _rows(L, 1)
        for _, dst in edges:
            write[dst][0] = 0
        steps.append(
            Step(edges=edges, width=1, tag="chain", write=_table(write))
        )
    return ChainProgram(
        collective="broadcast", kind="pipeline", num_devices=L,
        addr_shards=1, out_slots=1,
        buf_init=_table(buf_init), out_init=_table(out_init),
        steps=tuple(steps), groups=chains, head=head,
    ).validate()


def plan_recovery(
    topo,
    src: int,
    chains: Sequence[Sequence[int]],
    failed: "int | Iterable[int]",
    *,
    scheduler: str = "tsp",
) -> ChainProgram:
    """Failure recovery of a multi-chain broadcast as a ChainProgram.

    ``chains`` is the (failure-free) partition the broadcast ran with;
    ``failed`` is one dead member or a set of concurrently dead members
    (each must belong to some chain; the initiator ``src`` cannot be
    recovered — raise before calling for that case). Per affected
    sub-chain the orphaned suffix is re-formed by
    ``scheduling.reform_chain`` (upstream prefix kept verbatim — the
    payload is banked there by store-and-forward) and emitted as
    ordered chain steps; the suffix streams from the last surviving
    prefix member (``group_heads``), or from ``src`` when the failure
    hit the chain head. Step 0 is the shared detection window
    (``tag="detect"``, no edges — the initiator's finish timeout fires
    once for every concurrent failure).

    Sub-chains with no failed member do not appear: recovery never
    perturbs them (the isolation invariant). A chain whose survivors
    all sit upstream of its failures contributes no steps either —
    nothing downstream is orphaned, only the detection window is paid
    (priced by ``simulator.chain_recovery_latency``).

    The returned program is consumed by ``simulator.program_latency`` /
    ``program_wire_bytes`` (recovery priced through the same machinery
    as every other schedule) and replays under
    ``chainwrite_ref.interpret_program`` — seed the banked heads with
    the payload and every re-sent survivor receives it.
    """
    chains_t = tuple(
        tuple(int(d) for d in c) for c in chains if len(c)
    )
    from repro.core.scheduling import normalize_failed  # host-side only

    return _plan_recovery_cached(
        topo, int(src), chains_t, tuple(normalize_failed(failed)), scheduler
    )


@functools.lru_cache(maxsize=None)
def _plan_recovery_cached(
    topo,
    src: int,
    chains: tuple[tuple[int, ...], ...],
    failed: tuple[int, ...],
    scheduler: str,
) -> ChainProgram:
    from repro.core.scheduling import reform_chain  # host-side only

    dead = set(failed)
    members = {d for c in chains for d in c}
    missing = dead - members
    if missing:
        raise ValueError(f"failed node(s) {sorted(missing)} are in no chain")
    L = int(topo.num_nodes)

    groups: list[tuple[int, ...]] = []
    heads: list[int] = []
    for chain in chains:
        chain_dead = [f for f in chain if f in dead]
        if not chain_dead:
            continue
        first = min(chain.index(f) for f in chain_dead)
        reformed = reform_chain(topo, chain, chain_dead, src, scheduler=scheduler)
        prefix, resent = reformed[:first], reformed[first:]
        if not resent:
            continue  # tail failure: nothing downstream to re-send
        groups.append(tuple(resent))
        heads.append(prefix[-1] if prefix else src)

    buf_init = _rows(L, 1)
    out_init = _rows(L, 1)
    for h in heads:
        buf_init[h][0] = 0
        out_init[h][0] = 0
    steps: list[Step] = [Step(edges=(), tag="detect")]
    full = [(h,) + g for h, g in zip(heads, groups)]
    max_len = max((len(f) for f in full), default=1)
    for t in range(max_len - 1):
        edges = tuple((f[t], f[t + 1]) for f in full if t + 1 < len(f))
        write = _rows(L, 1)
        for _, dst in edges:
            write[dst][0] = 0
        load = None
        if t == 0:
            # The banked members re-read the payload from local memory
            # (the detection window cleared the transit registers).
            load_rows = _rows(L, 1)
            for h in heads:
                load_rows[h][0] = 0
            load = _table(load_rows)
        steps.append(
            Step(edges=edges, width=1, tag="chain", load=load,
                 write=_table(write))
        )
    return ChainProgram(
        collective="recovery", kind="pipeline", num_devices=L,
        addr_shards=1, out_slots=1,
        buf_init=_table(buf_init), out_init=_table(out_init),
        steps=tuple(steps), groups=tuple(groups), head=src,
        group_heads=tuple(heads),
    ).validate()


@functools.lru_cache(maxsize=None)
def plan_all_gather(
    num_devices: int, orders: tuple[tuple[int, ...], ...]
) -> ChainProgram:
    """Per-ring all-gather; K > 1 adds a cross-ring exchange of the
    gathered ring *blocks* (width-S steps). Output slots are device-id
    addressed — standard all_gather semantics for any ring order."""
    L = int(num_devices)
    orders = _check_rings(L, orders)
    K, S = len(orders), len(orders[0])
    intra, cross, pos, ring_of = _ring_maps(orders)

    buf_init = _rows(L, 1)
    out_init = _rows(L, L)
    for d in pos:
        buf_init[d][0] = 0
        out_init[d][d] = 0

    steps: list[Step] = []
    for s in range(1, S):
        write = _rows(L, 1)
        for d in pos:
            write[d][0] = orders[ring_of[d]][(pos[d] - s) % S]
        steps.append(Step(edges=intra, width=1, tag="intra", write=_table(write)))
    for c in range(1, K):
        load = None
        if c == 1:
            load_rows = _rows(L, S)
            for d in pos:
                load_rows[d] = list(orders[ring_of[d]])
            load = _table(load_rows)
        write = _rows(L, S)
        for d in pos:
            write[d] = list(orders[(ring_of[d] - c) % K])
        steps.append(
            Step(edges=cross, width=S, tag="cross", load=load, write=_table(write))
        )
    return ChainProgram(
        collective="all_gather", kind="stepped", num_devices=L,
        addr_shards=1, out_slots=L,
        buf_init=_table(buf_init), out_init=_table(out_init),
        steps=tuple(steps), groups=orders,
    ).validate()


@functools.lru_cache(maxsize=None)
def plan_reduce_scatter(
    num_devices: int, orders: tuple[tuple[int, ...], ...]
) -> ChainProgram:
    """Reduce-scatter over K sub-rings: the input is ``num_devices``
    device-id-addressed chunks; device ``d`` ends with the fully
    reduced chunk ``d`` in out slot 0.

    K=1 is the classic ring schedule (1/L frames, L-1 steps). K > 1
    first reduce-scatters width-K chunk *groups* within each ring
    (group ``p`` = the chunks of every ring's position-``p`` member),
    then reduce-scatters each group across the rings at single-chunk
    width — same total wire as the single ring, shorter rounds.
    """
    L = int(num_devices)
    orders = _check_rings(L, orders)
    K, S = len(orders), len(orders[0])
    intra, cross, pos, ring_of = _ring_maps(orders)
    steps: list[Step] = []

    if K == 1:
        ring = orders[0]
        buf_init = _rows(L, 1)
        out_init = _rows(L, 1)
        if S == 1:
            out_init[ring[0]][0] = ring[0]
        for d in pos:
            buf_init[d][0] = ring[(pos[d] - 1) % S]
        for s in range(1, S):
            add = _rows(L, 1)
            for d in pos:
                add[d][0] = ring[(pos[d] - s - 1) % S]
            write = None
            if s == S - 1:
                w = _rows(L, 1)
                for d in pos:
                    w[d][0] = 0
                write = _table(w)
            steps.append(Step(
                edges=intra, width=1, tag="intra", combine=ADD,
                add_src=_table(add), write=write,
            ))
        return ChainProgram(
            collective="reduce_scatter", kind="stepped", num_devices=L,
            addr_shards=L, out_slots=1,
            buf_init=_table(buf_init), out_init=_table(out_init),
            steps=tuple(steps), groups=orders,
        ).validate()

    out_slots = K
    buf_init = _rows(L, K)
    out_init = _rows(L, K)
    if S == 1:
        # No intra phase: seed the group slots straight from the input.
        for d in pos:
            for j in range(K):
                out_init[d][j] = orders[j][0]
    else:
        for d in pos:
            buf_init[d] = [orders[j][(pos[d] - 1) % S] for j in range(K)]
        for s in range(1, S):
            add = _rows(L, K)
            for d in pos:
                add[d] = [orders[j][(pos[d] - s - 1) % S] for j in range(K)]
            write = None
            if s == S - 1:
                w = _rows(L, K)
                for d in pos:
                    w[d] = list(range(K))
                write = _table(w)
            steps.append(Step(
                edges=intra, width=K, tag="intra", combine=ADD,
                add_src=_table(add), write=write,
            ))
    for c in range(1, K):
        load = None
        if c == 1:
            load_rows = _rows(L, 1)
            for d in pos:
                load_rows[d][0] = (ring_of[d] - 1) % K
            load = _table(load_rows)
        add = _rows(L, 1)
        for d in pos:
            add[d][0] = (ring_of[d] - c - 1) % K
        write = None
        if c == K - 1:
            w = _rows(L, 1)
            for d in pos:
                w[d][0] = 0
            write = _table(w)
        steps.append(Step(
            edges=cross, width=1, tag="cross", combine=ADD,
            add_from="out", add_src=_table(add), load=load, write=write,
        ))
    return ChainProgram(
        collective="reduce_scatter", kind="stepped", num_devices=L,
        addr_shards=L, out_slots=out_slots,
        buf_init=_table(buf_init), out_init=_table(out_init),
        steps=tuple(steps), groups=orders,
    ).validate()


def plan_all_reduce(
    num_devices: int,
    orders: tuple[tuple[int, ...], ...],
    algo: str = "rs_ag",
    wire_dtype: str | None = None,
) -> ChainProgram:
    """All-reduce over K sub-rings (see module docstring for the two
    schedules). K=1 is the single-ring reduce-scatter + all-gather
    with *device-id* chunk addressing for either ``algo`` — the
    historical ``chain_all_reduce`` schedule, kept so its fold order
    (and therefore every bit-exactness pin) is unchanged.
    ``wire_dtype="int8"`` ships every hop quantized (per-hop int8 frame
    + f32 scale); it composes with any (K, algo)."""
    return _plan_all_reduce(
        num_devices, orders, algo, normalize_wire_dtype(wire_dtype)
    )


@functools.lru_cache(maxsize=None)
def _plan_all_reduce(
    num_devices: int,
    orders: tuple[tuple[int, ...], ...],
    algo: str,
    wire_dtype: str | None,
) -> ChainProgram:
    if algo not in ALL_REDUCE_ALGOS:
        raise ValueError(f"unknown algo {algo!r}; expected {ALL_REDUCE_ALGOS}")
    L = int(num_devices)
    orders = _check_rings(L, orders)
    K, S = len(orders), len(orders[0])
    intra, cross, pos, ring_of = _ring_maps(orders)
    steps: list[Step] = []

    if K == 1 and S == L:
        # The full-axis single ring keeps the historical device-id
        # addressing (chunk i = device i's chunk). A *subset* ring —
        # simulator-only, the SPMD layer requires a full partition —
        # falls through to the position-addressed schedules below, so
        # its shard size is payload/S, not payload/num_devices.
        ring = orders[0]
        buf_init = _rows(L, 1)
        out_init = _rows(L, L)
        if S == 1:
            out_init[ring[0]][ring[0]] = ring[0]
        for d in pos:
            buf_init[d][0] = ring[(pos[d] - 1) % S]
        for s in range(1, S):  # reduce-scatter (device-id chunks)
            add = _rows(L, 1)
            for d in pos:
                add[d][0] = ring[(pos[d] - s - 1) % S]
            write = None
            if s == S - 1:
                w = _rows(L, 1)
                for d in pos:
                    w[d][0] = d  # own chunk lands in slot = device id
                write = _table(w)
            steps.append(Step(
                edges=intra, width=1, tag="intra", combine=ADD,
                add_src=_table(add), write=write,
            ))
        for s in range(1, S):  # all-gather
            write = _rows(L, 1)
            for d in pos:
                write[d][0] = ring[(pos[d] - s) % S]
            steps.append(
                Step(edges=intra, width=1, tag="intra", write=_table(write))
            )
        return ChainProgram(
            collective="all_reduce", kind="stepped", num_devices=L,
            addr_shards=L, out_slots=L,
            buf_init=_table(buf_init), out_init=_table(out_init),
            steps=tuple(steps), groups=orders, algo=algo,
            wire_dtype=wire_dtype,
        ).validate()

    if algo == "rotation" or S == 1:
        # Full-payload rotations (S=1 rs_ag degenerates to the same
        # cross-only schedule: there is nothing to shard over).
        buf_init = _rows(L, 1)
        out_init = _rows(L, 1)
        for d in pos:
            buf_init[d][0] = 0
            out_init[d][0] = 0
        w = _rows(L, 1)
        for d in pos:
            w[d][0] = 0
        acc_write = _table(w)
        for _s in range(1, S):
            steps.append(Step(
                edges=intra, width=1, tag="intra",
                write=acc_write, write_op=ADD,
            ))
        for c in range(1, K):
            load = acc_write if c == 1 else None  # same table shape: slot 0
            steps.append(Step(
                edges=cross, width=1, tag="cross",
                load=load, write=acc_write, write_op=ADD,
            ))
        return ChainProgram(
            collective="all_reduce", kind="stepped", num_devices=L,
            addr_shards=1, out_slots=1,
            buf_init=_table(buf_init), out_init=_table(out_init),
            steps=tuple(steps), groups=orders, algo=algo,
            wire_dtype=wire_dtype,
        ).validate()

    # rs_ag, K > 1, S > 1: shards addressed by ring position.
    buf_init = _rows(L, 1)
    out_init = _rows(L, S)
    for d in pos:
        buf_init[d][0] = (pos[d] - 1) % S
    for s in range(1, S):  # fused per-ring reduce-scatter
        add = _rows(L, 1)
        for d in pos:
            add[d][0] = (pos[d] - s - 1) % S
        write = None
        if s == S - 1:
            w = _rows(L, 1)
            for d in pos:
                w[d][0] = pos[d]
            write = _table(w)
        steps.append(Step(
            edges=intra, width=1, tag="intra", combine=ADD,
            add_src=_table(add), write=write,
        ))
    w = _rows(L, 1)
    for d in pos:
        w[d][0] = pos[d]
    pos_write = _table(w)
    for _c in range(1, K):  # cross-ring shard rotation (accumulating)
        steps.append(Step(
            edges=cross, width=1, tag="cross",
            write=pos_write, write_op=ADD,
        ))
    for s in range(1, S):  # fused per-ring all-gather
        load = pos_write if s == 1 else None
        write = _rows(L, 1)
        for d in pos:
            write[d][0] = (pos[d] - s) % S
        steps.append(Step(
            edges=intra, width=1, tag="intra", load=load, write=_table(write)
        ))
    return ChainProgram(
        collective="all_reduce", kind="stepped", num_devices=L,
        addr_shards=S, out_slots=S,
        buf_init=_table(buf_init), out_init=_table(out_init),
        steps=tuple(steps), groups=orders, algo=algo,
        wire_dtype=wire_dtype,
    ).validate()


def plan_all_to_all(
    num_devices: int,
    orders: tuple[tuple[int, ...], ...],
    wire_dtype: str | None = None,
) -> ChainProgram:
    """All-to-all (MoE dispatch): chunk ``j`` of each device's train is
    destined to device ``j``. The train rotates whole; each device
    peels the chunk addressed to it every step. K > 1 interleaves
    intra-ring rotations with cross-ring hops — (K·(S-1) + (K-1)) =
    L-1 steps either way (a chunk train cannot shrink), but every hop
    stays ring-local/position-paired. ``wire_dtype="int8"`` ships the
    rotating train quantized (per-hop int8 frame + f32 scale)."""
    return _plan_all_to_all(
        num_devices, orders, normalize_wire_dtype(wire_dtype)
    )


@functools.lru_cache(maxsize=None)
def _plan_all_to_all(
    num_devices: int,
    orders: tuple[tuple[int, ...], ...],
    wire_dtype: str | None,
) -> ChainProgram:
    L = int(num_devices)
    orders = _check_rings(L, orders)
    K, S = len(orders), len(orders[0])
    intra, cross, pos, ring_of = _ring_maps(orders)

    buf_init = _rows(L, L)
    out_init = _rows(L, L)
    for d in pos:
        buf_init[d] = list(range(L))
        out_init[d][d] = d

    def peel(origin_of) -> Table:
        write = _rows(L, L)
        for d in pos:
            write[d][d] = origin_of(d)
        return _table(write)

    steps: list[Step] = []
    for j in range(K):
        # After j cross hops and t intra hops the train at device (c, p)
        # originated at ring (c - j), position (p - t) — the intra
        # offset accumulates across stages.
        if j > 0:
            t = j * (S - 1)
            steps.append(Step(
                edges=cross, width=L, tag="cross",
                write=peel(
                    lambda d, j=j, t=t: orders[(ring_of[d] - j) % K][
                        (pos[d] - t) % S
                    ]
                ),
            ))
        for s in range(1, S):
            t = j * (S - 1) + s
            steps.append(Step(
                edges=intra, width=L, tag="intra",
                write=peel(
                    lambda d, j=j, t=t: orders[(ring_of[d] - j) % K][
                        (pos[d] - t) % S
                    ]
                ),
            ))
    return ChainProgram(
        collective="all_to_all", kind="stepped", num_devices=L,
        addr_shards=L, out_slots=L,
        buf_init=_table(buf_init), out_init=_table(out_init),
        steps=tuple(steps), groups=orders, wire_dtype=wire_dtype,
    ).validate()


def _ceil_div(a: int, b: int) -> int:
    return -(-a // b)
