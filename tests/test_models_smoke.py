"""Per-arch smoke tests (reduced configs): forward / train step / decode.

One test per assigned architecture instantiates the reduced config of
the same family and runs a forward + one train step on CPU, asserting
output shapes and no NaNs (the instructions' smoke contract). Decode
parity tests check prefill+decode against the full-sequence forward.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs as C
from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.optim import adamw


def _batch(cfg: ModelConfig, B=2, S=32, seed=0):
    key = jax.random.PRNGKey(seed)
    batch = {}
    if cfg.family == "vlm":
        batch["embeds"] = jax.random.normal(key, (B, S, cfg.d_model), jnp.bfloat16)
        batch["positions"] = jnp.broadcast_to(
            jnp.arange(S, dtype=jnp.int32), (3, B, S)
        )
    else:
        batch["tokens"] = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    if cfg.is_encdec:
        batch["enc_frames"] = jax.random.normal(
            key, (B, cfg.encoder_seq_len, cfg.d_model), jnp.bfloat16
        )
    batch["labels"] = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    return batch


@pytest.mark.parametrize("arch", C.ARCHS)
def test_smoke_forward_and_train_step(arch):
    cfg = C.get_smoke_config(arch)
    params = T.model_init(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg)
    B, S = batch["labels"].shape

    hidden, aux = T.forward_hidden(params, cfg, batch)
    assert hidden.shape == (B, S, cfg.d_model)
    assert not bool(jnp.isnan(hidden).any())

    opt_cfg = adamw.OptConfig(warmup_steps=2, decay_steps=10)
    opt = adamw.init(params)

    @jax.jit
    def step(params, opt, batch):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: T.loss_fn(p, cfg, batch), has_aux=True
        )(params)
        params, opt, om = adamw.update(opt_cfg, grads, opt, params)
        return params, opt, {**metrics, **om}

    params2, opt2, m = step(params, opt, batch)
    assert np.isfinite(float(m["loss"]))
    assert np.isfinite(float(m["grad_norm"]))
    assert float(m["grad_norm"]) > 0
    # params actually moved
    moved = any(
        not np.allclose(np.asarray(a, np.float32), np.asarray(b, np.float32))
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(params2))
    )
    assert moved


@pytest.mark.parametrize("arch", C.ARCHS)
def test_smoke_full_config_consistency(arch):
    """Full config matches the assigned table (spot dims, no allocation)."""
    cfg = C.get_config(arch)
    smoke = C.get_smoke_config(arch)
    assert cfg.family == smoke.family
    assert cfg.num_layers >= smoke.num_layers
    # params materialize abstractly
    shapes = jax.eval_shape(lambda: T.model_init(jax.random.PRNGKey(0), cfg))
    n = sum(x.size for x in jax.tree.leaves(shapes))
    # every assigned arch is large (whisper-tiny ~70M; the rest >= 1B)
    assert n > 5e7, n


FULL_DIMS = {
    "starcoder2-3b": (30, 3072, 24, 2, 12288, 49152),
    "yi-6b": (32, 4096, 32, 4, 11008, 64000),
    "h2o-danube-1.8b": (24, 2560, 32, 8, 6912, 32000),
    "llama3-8b": (32, 4096, 32, 8, 14336, 128256),
    "deepseek-v2-lite-16b": (27, 2048, 16, 16, 1408, 102400),
    "deepseek-moe-16b": (28, 2048, 16, 16, 1408, 102400),
    "jamba-v0.1-52b": (32, 4096, 32, 8, 14336, 65536),
    "qwen2-vl-7b": (28, 3584, 28, 4, 18944, 152064),
    "mamba2-2.7b": (64, 2560, 0, 0, 0, 50280),
    "whisper-tiny": (4, 384, 6, 6, 1536, 51865),
}


@pytest.mark.parametrize("arch", C.ARCHS)
def test_assigned_dims_exact(arch):
    L, d, H, Hkv, ff, V = FULL_DIMS[arch]
    cfg = C.get_config(arch)
    assert cfg.num_layers == L
    assert cfg.d_model == d
    assert cfg.vocab_size == V
    if arch == "mamba2-2.7b":
        assert cfg.family == "ssm" and cfg.ssm_state == 128
    else:
        assert cfg.num_heads == H and cfg.num_kv_heads == Hkv
    if ff:
        assert cfg.d_ff == ff or cfg.moe_d_ff == ff
    if arch.startswith("deepseek"):
        assert cfg.num_experts == 64 and cfg.moe_top_k == 6
        assert cfg.num_shared_experts == 2
    if arch == "deepseek-v2-lite-16b":
        assert cfg.attention == "mla" and cfg.kv_lora_rank == 512
    if arch == "jamba-v0.1-52b":
        assert cfg.attn_period == 8  # 1:7 attn:mamba interleave
        assert cfg.num_experts == 16 and cfg.moe_top_k == 2
    if arch == "h2o-danube-1.8b":
        assert cfg.sliding_window
    if arch == "qwen2-vl-7b":
        assert cfg.pos_scheme == "mrope"
    if arch == "whisper-tiny":
        assert cfg.encoder_layers == 4


@pytest.mark.parametrize("arch", C.ARCHS)
def test_smoke_opt_variant_matches_baseline(arch):
    """The §Perf 'opt' bundle (chunked attention, bf16 norms, row-wise
    MoE, absorbed MLA) must stay numerically close to the faithful
    baseline on every arch — guards flag interactions."""
    from repro.launch.steps import VARIANTS

    base = C.get_smoke_config(arch)
    # high capacity so flat vs row-wise dispatch see no differential drops
    base = dataclasses.replace(base, capacity_factor=16.0, attn_chunk=16)
    opt = dataclasses.replace(base, **VARIANTS["opt"])
    params = T.model_init(jax.random.PRNGKey(0), base)
    batch = _batch(base, B=2, S=32)

    h_base, _ = T.forward_hidden(params, base, batch)
    h_opt, _ = T.forward_hidden(params, opt, batch)
    assert not bool(jnp.isnan(h_opt).any())
    a = np.asarray(h_base, np.float32)
    b = np.asarray(h_opt, np.float32)
    if base.num_experts:
        # MoE routing is discontinuous: bf16-norm rounding flips top-k
        # for near-tie tokens, changing those positions entirely. Bound
        # the flip fraction instead of elementwise closeness.
        close = np.isclose(a, b, atol=8e-2, rtol=8e-2)
        assert close.mean() > 0.9, close.mean()
    else:
        np.testing.assert_allclose(a, b, atol=8e-2, rtol=8e-2)


# ---------------------------------------------------------------------------
# decode parity: prefill + decode == full forward
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "arch", ["yi-6b", "h2o-danube-1.8b", "deepseek-v2-lite-16b", "mamba2-2.7b",
             "jamba-v0.1-52b", "whisper-tiny"]
)
def test_prefill_decode_matches_forward(arch):
    """Greedy tokens from (prefill S) + (decode 1) must match the
    argmax of the full-forward logits at the same positions."""
    cfg = C.get_smoke_config(arch)
    # capacity drops depend on batch size, so prefill+decode == forward
    # only holds when no token is dropped — lift the MoE capacity.
    cfg = dataclasses.replace(cfg, attn_impl="reference", capacity_factor=16.0)
    params = T.model_init(jax.random.PRNGKey(0), cfg)
    B, S, extra = 2, 16, 4
    max_seq = S + extra
    batch = _batch(cfg, B, S, seed=1)
    batch.pop("labels")

    logits_p, cache = T.prefill(params, cfg, batch, max_seq)

    # reference: full forward over S tokens -> last-position logits
    hidden, _ = T.forward_hidden(params, cfg, batch)
    from repro.models.layers import rmsnorm  # noqa: F401  (hidden is normed)

    table = (params["embed"] if cfg.tie_embeddings else params["lm_head"])["table"]
    ref_logits = jnp.einsum(
        "bd,vd->bv", hidden[:, -1].astype(jnp.float32), table.astype(jnp.float32)
    )
    np.testing.assert_allclose(
        np.asarray(logits_p), np.asarray(ref_logits), atol=2e-2, rtol=2e-2
    )

    if cfg.family == "vlm":
        return  # decode path needs token embeddings; vlm uses embeds

    # decode `extra` steps greedily; compare against running the full
    # sequence through the forward each time.
    toks = batch["tokens"]
    cur = jnp.argmax(logits_p, -1).astype(jnp.int32)
    for t in range(extra):
        full = jnp.concatenate([toks, cur[:, None]], 1)
        logits_d, cache = T.decode_step(params, cfg, cur, jnp.int32(S + t), cache)
        fb = dict(batch)
        fb["tokens"] = full
        hidden_f, _ = T.forward_hidden(params, cfg, fb)
        ref = jnp.einsum(
            "bd,vd->bv", hidden_f[:, -1].astype(jnp.float32),
            table.astype(jnp.float32),
        )
        # bf16 cache quantization drifts slightly over decode steps
        np.testing.assert_allclose(
            np.asarray(logits_d), np.asarray(ref), atol=8e-2, rtol=8e-2
        )
        toks = full
        cur = jnp.argmax(logits_d, -1).astype(jnp.int32)


def test_swa_ring_buffer_decode_matches_full():
    """h2o-danube SWA cache is a ring buffer of `window` slots; beyond
    the window the decode must still match full-sequence attention."""
    cfg = C.get_smoke_config("h2o-danube-1.8b")
    window = cfg.sliding_window
    assert window is not None and window <= 16
    params = T.model_init(jax.random.PRNGKey(0), cfg)
    B, S = 1, int(window)  # prefill exactly one window
    extra = int(window)  # decode a full extra window (forces wrap)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(2), (B, S), 0, cfg.vocab_size)}
    table = params["lm_head"]["table"]

    _, cache = T.prefill(params, cfg, batch, max_seq=S + extra)
    toks = batch["tokens"]
    cur = toks[:, -1] * 0 + 7
    for t in range(extra):
        full = jnp.concatenate([toks, cur[:, None]], 1)
        logits_d, cache = T.decode_step(params, cfg, cur, jnp.int32(S + t), cache)
        hidden_f, _ = T.forward_hidden(params, cfg, {"tokens": full})
        ref = jnp.einsum(
            "bd,vd->bv", hidden_f[:, -1].astype(jnp.float32),
            table.astype(jnp.float32),
        )
        np.testing.assert_allclose(
            np.asarray(logits_d), np.asarray(ref), atol=5e-2, rtol=5e-2
        )
        toks, cur = full, jnp.argmax(logits_d, -1).astype(jnp.int32)


# ---------------------------------------------------------------------------
# mixer-level oracles
# ---------------------------------------------------------------------------


def test_moe_capacity_matches_dense_oracle():
    from repro.models import moe as M

    cfg = dataclasses.replace(
        C.get_smoke_config("deepseek-moe-16b"),
        capacity_factor=8.0,  # no drops -> must equal the dense oracle
    )
    key = jax.random.PRNGKey(0)
    params = M.moe_init(key, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model), jnp.float32)
    got, aux = M.moe_apply(params, x, cfg)
    want = M.moe_ref(params, x, cfg)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-2, rtol=2e-2)
    assert float(aux) >= 0


def test_moe_rowwise_matches_dense_oracle():
    """Row-wise (DP×EP-shardable) dispatch == dense oracle (§Perf it 3)."""
    from repro.models import moe as M

    cfg = dataclasses.replace(
        C.get_smoke_config("deepseek-moe-16b"),
        capacity_factor=8.0, moe_row_dispatch=True,
    )
    params = M.moe_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (3, 16, cfg.d_model), jnp.float32)
    got, aux = M.moe_apply(params, x, cfg)
    want = M.moe_ref(params, x, cfg)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-2, rtol=2e-2)
    assert float(aux) >= 0


def test_moe_rowwise_sharded_parity(run_multidevice):
    """Row-wise dispatch is exact under a (data, model) mesh."""
    run_multidevice("""
    import dataclasses
    from jax.sharding import NamedSharding
    from repro import configs as C
    from repro.models import moe as M

    cfg = dataclasses.replace(C.get_smoke_config('deepseek-moe-16b'),
                              capacity_factor=4.0, moe_row_dispatch=True)
    params = M.moe_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, cfg.d_model), jnp.float32)
    ref, _ = jax.jit(lambda p, x: M.moe_apply(p, x, cfg))(params, x)

    mesh = jax.make_mesh((2, 4), ('data', 'model'),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    xd = jax.device_put(x, NamedSharding(mesh, P('data', None, None)))
    with jax.set_mesh(mesh):
        got, _ = jax.jit(lambda p, x: M.moe_apply(p, x, cfg))(params, xd)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               atol=2e-3, rtol=2e-3)
    print('rowwise sharded parity OK')
    """)


def test_moe_capacity_drops_with_tight_factor():
    from repro.models import moe as M

    cfg = dataclasses.replace(
        C.get_smoke_config("deepseek-moe-16b"), capacity_factor=0.05
    )
    params = M.moe_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model), jnp.float32)
    got, _ = M.moe_apply(params, x, cfg)
    want = M.moe_ref(params, x, cfg)
    # with heavy drops outputs differ from the oracle
    assert not np.allclose(np.asarray(got), np.asarray(want), atol=1e-3)
    assert np.isfinite(np.asarray(got)).all()


def test_mamba2_chunked_matches_naive_recurrence():
    """Chunked SSD == token-by-token recurrence (the decode path)."""
    from repro.models import mamba2 as M

    cfg = C.get_smoke_config("mamba2-2.7b")
    params = M.mamba2_init(jax.random.PRNGKey(0), cfg)
    B, S = 2, int(cfg.ssm_chunk * 2.5)  # exercise padding + multi-chunk
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, cfg.d_model), jnp.float32) * 0.3

    full = M.mamba2_apply(params, x, cfg)

    cache = M.mamba2_init_cache(cfg, B)
    outs = []
    for t in range(S):
        y, cache = M.mamba2_decode(params, x[:, t : t + 1], cache, cfg)
        outs.append(y)
    seq = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(full, np.float32), np.asarray(seq, np.float32),
        atol=3e-2, rtol=3e-2,
    )


def test_mamba2_prefill_state_handoff():
    """prefill(x[:S]) state + decode == apply over the full sequence."""
    from repro.models import mamba2 as M

    cfg = C.get_smoke_config("mamba2-2.7b")
    params = M.mamba2_init(jax.random.PRNGKey(0), cfg)
    B, S = 1, int(cfg.ssm_chunk) + 3
    x = jax.random.normal(jax.random.PRNGKey(2), (B, S + 1, cfg.d_model), jnp.float32) * 0.3

    _, cache = M.mamba2_prefill(params, x[:, :S], cfg)
    y_dec, _ = M.mamba2_decode(params, x[:, S : S + 1], cache, cfg)
    y_full = M.mamba2_apply(params, x, cfg)
    np.testing.assert_allclose(
        np.asarray(y_dec[:, 0], np.float32), np.asarray(y_full[:, S], np.float32),
        atol=3e-2, rtol=3e-2,
    )


def test_layer_groups_cover_all_layers():
    for arch in C.ARCHS:
        cfg = C.get_config(arch)
        groups = cfg.layer_groups()
        total = sum(len(p) * r for p, r in groups)
        assert total == cfg.num_layers, arch
        # group expansion reproduces the per-layer specs exactly
        flat = []
        for pattern, reps in groups:
            flat.extend(list(pattern) * reps)
        assert flat == [cfg.layer_spec(i) for i in range(cfg.num_layers)], arch


def test_jamba_interleave_pattern():
    cfg = C.get_config("jamba-v0.1-52b")
    specs = [cfg.layer_spec(i) for i in range(16)]
    attn_layers = [i for i, s in enumerate(specs) if s.mixer == "gqa"]
    assert attn_layers == [4, 12]  # 1 attention per 8 layers, offset 4
    moe_layers = [i for i, s in enumerate(specs) if s.ffn == "moe"]
    assert moe_layers == [1, 3, 5, 7, 9, 11, 13, 15]  # every other layer
