"""Chunked (online-softmax) attention — the lowerable flash twin —
vs the pure-jnp oracle, plus MLA chunked/absorbed variants."""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs as C
from repro.kernels.flash_attention.chunked import attention_chunked
from repro.kernels.flash_attention.ref import attention_ref
from repro.models import attention as A


def _qkv(B, H, Hkv, S, D, dtype=jnp.float32, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    return (
        jax.random.normal(ks[0], (B, H, S, D), dtype),
        jax.random.normal(ks[1], (B, Hkv, S, D), dtype),
        jax.random.normal(ks[2], (B, Hkv, S, D), dtype),
    )


@pytest.mark.parametrize("S,chunk", [(256, 64), (256, 256), (250, 64), (128, 1024)])
@pytest.mark.parametrize("causal", [True, False])
def test_chunked_matches_ref(S, chunk, causal):
    q, k, v = _qkv(2, 4, 2, S, 32)
    got = attention_chunked(q, k, v, causal=causal, chunk=chunk)
    want = attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-3, rtol=2e-3)


@pytest.mark.parametrize("window", [16, 100])
def test_chunked_sliding_window(window):
    q, k, v = _qkv(1, 2, 2, 256, 32, seed=1)
    got = attention_chunked(q, k, v, causal=True, window=window, chunk=64)
    want = attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-3, rtol=2e-3)


def test_chunked_gqa_grouping():
    q, k, v = _qkv(1, 8, 2, 128, 64, seed=2)
    got = attention_chunked(q, k, v, causal=True, chunk=32)
    want = attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-3, rtol=2e-3)


def test_gqa_apply_chunked_equals_reference():
    cfg = C.get_smoke_config("yi-6b")
    p = A.gqa_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 48, cfg.d_model), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(48, dtype=jnp.int32), (2, 48))
    ref = A.gqa_apply(p, x, pos, cfg, causal=True)
    ck = A.gqa_apply(
        p, x, pos, dataclasses.replace(cfg, attn_impl="chunked", attn_chunk=16),
        causal=True,
    )
    np.testing.assert_allclose(np.asarray(ck, np.float32),
                               np.asarray(ref, np.float32), atol=3e-3, rtol=3e-3)


def test_mla_apply_chunked_equals_reference():
    cfg = C.get_smoke_config("deepseek-v2-lite-16b")
    p = A.mla_init(jax.random.PRNGKey(0), cfg)
    x = (jax.random.normal(jax.random.PRNGKey(1), (2, 48, cfg.d_model)) * 0.5
         ).astype(jnp.bfloat16)
    pos = jnp.broadcast_to(jnp.arange(48, dtype=jnp.int32), (2, 48))
    ref = A.mla_apply(p, x, pos, cfg, causal=True)
    ck = A.mla_apply(
        p, x, pos, dataclasses.replace(cfg, attn_impl="chunked", attn_chunk=16),
        causal=True,
    )
    np.testing.assert_allclose(np.asarray(ck, np.float32),
                               np.asarray(ref, np.float32), atol=2e-2, rtol=2e-2)


def test_mla_decode_absorbed_equals_recovered():
    """Weight-absorbed decode (beyond-paper) == recover-then-attend."""
    cfg = C.get_smoke_config("deepseek-v2-lite-16b")
    p = A.mla_init(jax.random.PRNGKey(0), cfg)
    B, S = 2, 12
    x = (jax.random.normal(jax.random.PRNGKey(1), (B, S + 1, cfg.d_model)) * 0.5
         ).astype(jnp.bfloat16)
    pos = jnp.broadcast_to(jnp.arange(S + 1, dtype=jnp.int32), (B, S + 1))
    _, cache = A.mla_prefill(p, x[:, :S], pos[:, :S], cfg, max_seq=S + 2)

    y_rec, _ = A.mla_decode(p, x[:, S:S + 1], jnp.int32(S), cache, cfg)
    cfg_a = dataclasses.replace(cfg, mla_absorb=True)
    y_abs, _ = A.mla_decode(p, x[:, S:S + 1], jnp.int32(S), cache, cfg_a)
    np.testing.assert_allclose(np.asarray(y_abs, np.float32),
                               np.asarray(y_rec, np.float32),
                               atol=2e-2, rtol=2e-2)
