"""Error feedback convergence: the satellite pinning that
``torrent_grad_reduce(error_feedback=True)`` actually restores training
under the lossy int8 wire.

The quadratic test is the classic EF-SGD separation: coordinates whose
gradients sit far below the tensor max are rounded to zero by plain
int8 quantization every step (they never move), while error feedback
accumulates them in the residual until they cross a quantization step.
The trainer test drives the production path end to end — TrainConfig
.compress_grads through ``make_train_step`` into the int8+EF reduction,
with the residual state checkpointed and restored across an injected
failure."""

from __future__ import annotations


def test_int8_ef_quadratic_convergence(run_multidevice):
    run_multidevice("""
    from repro.parallel.collectives import (
        ef_residual_init, torrent_grad_reduce)

    mesh = jax.make_mesh((8, 1), ('data', 'model'),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)

    # A-coords: tiny curvature, huge gradients (~4000) that set the
    # quantization scale (~31 per int8 step). B-coords: gradients ~2,
    # far below one step -> plain int8 zeroes them out every round.
    n = 32
    idx = np.arange(n)
    is_a = idx % 4 == 0
    h = jnp.asarray(np.where(is_a, 0.05, 1.0).astype(np.float32))
    t = jnp.asarray(np.where(is_a, 80000.0, 2.0).astype(np.float32))
    lr, steps = 0.05, 60

    def grad_fn(params, batch):
        return {'w': h * (params['w'] - t)}, {'loss': jnp.float32(0.0)}

    batch_specs = {'d': P('data', None)}
    dummy = jnp.zeros((8, 1), jnp.float32)

    def run(mode):
        w = jnp.zeros((n,), jnp.float32)
        kw = {} if mode == 'f32' else {'wire_dtype': 'int8'}
        if mode == 'ef':
            kw['error_feedback'] = True
        reduce = torrent_grad_reduce(grad_fn, mesh, batch_specs, **kw)
        if mode == 'ef':
            res = ef_residual_init({'w': w}, 8)
            @jax.jit
            def step(w, res):
                grads, _, new_res = reduce({'w': w}, {'d': dummy}, res)
                return w - lr * grads['w'], new_res
            with jax.set_mesh(mesh):
                for _ in range(steps):
                    w, res = step(w, res)
                    w.block_until_ready()
        else:
            @jax.jit
            def step(w):
                grads, _ = reduce({'w': w}, {'d': dummy})
                return w - lr * grads['w']
            with jax.set_mesh(mesh):
                for _ in range(steps):
                    w = step(w)
                    w.block_until_ready()
        wb = np.asarray(w)[~is_a]
        tb = np.asarray(t)[~is_a]
        return float(np.sum((wb - tb) ** 2) / np.sum(tb ** 2))

    f32, int8, ef = run('f32'), run('int8'), run('ef')
    print('residual fractions:', f32, int8, ef)
    assert f32 < 0.05, f32           # exact wire converges
    assert ef < 0.25, ef             # EF recovers most of it
    assert int8 > 0.6, int8          # plain int8 provably stalls
    assert ef < int8 / 2, (ef, int8)
    print('ef quadratic OK')
    """, timeout=900)


def test_trainer_int8_ef_end_to_end(run_multidevice):
    run_multidevice("""
    import tempfile
    from repro.launch.train import TrainConfig, Trainer

    base = dict(
        arch='yi-6b', smoke=True, steps=25, global_batch=8, seq_len=32,
        peak_lr=2e-3, warmup_steps=5, ckpt_every=10, loss_chunks=2,
        log_every=100, collectives='torrent',
    )
    with tempfile.TemporaryDirectory() as d:
        out_f32 = Trainer(TrainConfig(ckpt_dir=d + '/f32', **base)).run()
        # fail_at forces a restart: the EF residual must checkpoint and
        # restore alongside the optimizer state
        out_int8 = Trainer(TrainConfig(
            ckpt_dir=d + '/int8', compress_grads=True, fail_at=(13,),
            **base)).run()

    assert out_int8['final_step'] == 25
    assert out_int8['restarts'] == 1
    assert np.isfinite(out_int8['losses']).all()
    assert out_int8['last_loss'] < out_int8['first_loss']
    # int8+EF tracks the f32 trajectory closely on this workload
    delta = abs(out_int8['last_loss'] - out_f32['last_loss'])
    assert delta < 0.15, (out_f32['last_loss'], out_int8['last_loss'])
    print('trainer int8+ef OK')
    """, timeout=900)
