"""Offline-safe stand-in for the ``hypothesis`` property-testing API.

The container this suite must run in does not ship ``hypothesis`` and
installing packages is off-limits, yet three tier-1 modules use
``@given``-style property tests. This shim re-exports the real library
when it is importable and otherwise provides a tiny, deterministic
subset of the same API:

* ``@given(**kwargs)``      — runs the test ``max_examples`` times with
  inputs drawn from the supplied strategies;
* ``@settings(max_examples=, deadline=)`` — honoured for
  ``max_examples``; ``deadline`` is accepted and ignored;
* ``strategies``: ``integers``, ``booleans``, ``floats``,
  ``sampled_from``, ``lists``, ``tuples``, ``just``, ``data`` — the
  subset this repo's tests use.

Sampling is seeded from the test function's qualified name plus the
example index, so failures reproduce exactly across runs and machines
(no shrinking — the first failing example is reported as-is).

Usage in tests (drop-in for the hypothesis import)::

    from _hypothesis_compat import given, settings, strategies as st
"""

from __future__ import annotations

import functools
import random
import zlib

try:  # pragma: no cover - exercised only when hypothesis is installed
    from hypothesis import given, settings, strategies  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    _DEFAULT_MAX_EXAMPLES = 50

    class _Strategy:
        """A strategy is just a seeded sampler: ``draw(rng) -> value``."""

        def __init__(self, draw_fn):
            self._draw = draw_fn

        def draw(self, rng: random.Random):
            return self._draw(rng)

        # combinators used via st.integers(...).map(...) style, if ever
        def map(self, fn):
            return _Strategy(lambda rng: fn(self._draw(rng)))

        def filter(self, pred, _max_tries: int = 1000):
            def draw(rng):
                for _ in range(_max_tries):
                    v = self._draw(rng)
                    if pred(v):
                        return v
                raise ValueError("filter predicate never satisfied")

            return _Strategy(draw)

    class _DataObject:
        """The object ``st.data()`` hands to the test body."""

        def __init__(self, rng: random.Random):
            self._rng = rng

        def draw(self, strategy: _Strategy, label: str | None = None):
            del label
            return strategy.draw(self._rng)

    class _DataStrategy(_Strategy):
        def __init__(self):
            super().__init__(lambda rng: _DataObject(rng))

    class _Strategies:
        @staticmethod
        def integers(min_value: int, max_value: int) -> _Strategy:
            return _Strategy(lambda rng: rng.randint(min_value, max_value))

        @staticmethod
        def booleans() -> _Strategy:
            return _Strategy(lambda rng: rng.random() < 0.5)

        @staticmethod
        def floats(
            min_value: float = 0.0,
            max_value: float = 1.0,
            allow_nan: bool = False,
            allow_infinity: bool = False,
        ) -> _Strategy:
            del allow_nan, allow_infinity
            return _Strategy(lambda rng: rng.uniform(min_value, max_value))

        @staticmethod
        def sampled_from(seq) -> _Strategy:
            pool = list(seq)
            return _Strategy(lambda rng: pool[rng.randrange(len(pool))])

        @staticmethod
        def just(value) -> _Strategy:
            return _Strategy(lambda rng: value)

        @staticmethod
        def tuples(*strategies: _Strategy) -> _Strategy:
            return _Strategy(lambda rng: tuple(s.draw(rng) for s in strategies))

        @staticmethod
        def lists(
            elements: _Strategy,
            *,
            min_size: int = 0,
            max_size: int = 10,
            unique: bool = False,
        ) -> _Strategy:
            def draw(rng):
                n = rng.randint(min_size, max_size)
                if not unique:
                    return [elements.draw(rng) for _ in range(n)]
                seen: list = []
                tries = 0
                while len(seen) < n and tries < 1000 * max(1, n):
                    v = elements.draw(rng)
                    tries += 1
                    if v not in seen:
                        seen.append(v)
                if len(seen) < min_size:
                    raise ValueError("could not draw enough unique elements")
                return seen

            return _Strategy(draw)

        @staticmethod
        def data() -> _Strategy:
            return _DataStrategy()

    strategies = _Strategies()

    def settings(max_examples: int = _DEFAULT_MAX_EXAMPLES, deadline=None, **_):
        """Decorator recording ``max_examples`` for a later ``@given``."""
        del deadline

        def deco(fn):
            fn._compat_max_examples = max_examples
            return fn

        return deco

    def given(**strategy_kwargs):
        """Deterministic replacement for ``hypothesis.given``.

        Runs the wrapped test once per example with kwargs drawn from
        the strategies; the RNG seed mixes the test's qualname and the
        example index so runs are reproducible everywhere.
        """

        def deco(fn):
            base_seed = zlib.crc32(fn.__qualname__.encode())

            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                # Read lazily: @settings stacked ABOVE @given (the usual
                # order) sets the attribute on `wrapper` after this deco
                # ran; wraps() already copied it from fn for the other
                # stacking order.
                max_examples = getattr(
                    wrapper, "_compat_max_examples", _DEFAULT_MAX_EXAMPLES
                )
                for i in range(max_examples):
                    rng = random.Random((base_seed << 20) ^ i)
                    drawn = {
                        name: strat.draw(rng)
                        for name, strat in strategy_kwargs.items()
                    }
                    try:
                        fn(*args, **drawn, **kwargs)
                    except Exception as e:  # report the failing example
                        shown = {
                            k: v
                            for k, v in drawn.items()
                            if not isinstance(v, _DataObject)
                        }
                        raise AssertionError(
                            f"property failed on example {i}: {shown!r}"
                        ) from e

            # pytest must not mistake the strategy params for fixtures:
            # present a bare (*args, **kwargs) signature.
            wrapper.__dict__.pop("__wrapped__", None)
            wrapper.hypothesis_compat = True
            return wrapper

        return deco
