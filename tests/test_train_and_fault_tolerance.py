"""Training loop, checkpoint/restart, straggler monitor, fault injection."""

from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs as C
from repro.checkpoint.manager import CheckpointManager
from repro.data.pipeline import MarkovSource
from repro.models import transformer as T
from repro.optim import adamw
from repro.runtime.failure import FaultInjector, SimulatedNodeFailure, resilient_loop
from repro.runtime.monitor import StepMonitor


def _tiny_cfg():
    return dataclasses.replace(
        C.get_smoke_config("yi-6b"), num_layers=2, d_model=32, num_heads=2,
        num_kv_heads=2, d_ff=64, vocab_size=64, head_dim=16,
    )


def _setup(seed=0, steps_cfg=None):
    cfg = _tiny_cfg()
    params = T.model_init(jax.random.PRNGKey(seed), cfg)
    opt_cfg = steps_cfg or adamw.OptConfig(peak_lr=3e-3, warmup_steps=5, decay_steps=60)
    opt = adamw.init(params)
    src = MarkovSource(cfg.vocab_size, seq_len=16, global_batch=8, branch=2, seed=1)

    @jax.jit
    def step(params, opt, batch):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: T.loss_fn(p, cfg, batch, loss_chunks=2), has_aux=True
        )(params)
        params, opt, om = adamw.update(opt_cfg, grads, opt, params)
        return params, opt, {**metrics, **om}

    return cfg, params, opt, src, step


def test_loss_decreases_on_markov_data():
    cfg, params, opt, src, step = _setup()
    losses = []
    for i in range(40):
        batch = {k: jnp.asarray(v) for k, v in src.batch(i).items()}
        params, opt, m = step(params, opt, batch)
        losses.append(float(m["loss"]))
    first, last = np.mean(losses[:5]), np.mean(losses[-5:])
    assert last < first - 0.3, (first, last)
    assert np.isfinite(losses).all()


def test_checkpoint_roundtrip_exact(tmp_ckpt_dir):
    cfg, params, opt, src, step = _setup()
    ckpt = CheckpointManager(tmp_ckpt_dir, keep_last_k=2)
    state = {"params": params, "opt": opt}
    ckpt.save(7, state, blocking=True)
    restored = ckpt.restore(7, jax.tree.map(lambda x: x, state))
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    ckpt.close()


def test_checkpoint_keep_k_and_latest(tmp_ckpt_dir):
    ckpt = CheckpointManager(tmp_ckpt_dir, keep_last_k=2)
    tree = {"x": jnp.arange(4)}
    for s in (1, 2, 3, 4):
        ckpt.save(s, tree, blocking=True)
    assert ckpt.latest_step() == 4
    assert ckpt.all_steps() == [3, 4]  # GC keeps last 2
    ckpt.close()


def test_checkpoint_shape_mismatch_raises(tmp_ckpt_dir):
    ckpt = CheckpointManager(tmp_ckpt_dir)
    ckpt.save(0, {"x": jnp.zeros((4,))}, blocking=True)
    with pytest.raises(ValueError):
        ckpt.restore(0, {"x": jnp.zeros((5,))})
    with pytest.raises(KeyError):
        ckpt.restore(0, {"y": jnp.zeros((4,))})
    ckpt.close()


def test_resilient_loop_restarts_and_replays(tmp_ckpt_dir):
    """Crash at steps 7 and 12 -> run must complete with 2 restarts and
    the final state must equal a crash-free run (exact replay)."""

    def run(fail_at):
        cfg, params, opt, src, step = _setup()
        ckpt = CheckpointManager(tmp_ckpt_dir + str(bool(fail_at)), keep_last_k=3)
        injector = FaultInjector(fail_at)

        def step_fn(state, i):
            injector.maybe_fail(i)
            batch = {k: jnp.asarray(v) for k, v in src.batch(i).items()}
            p, o, m = step(state["params"], state["opt"], batch)
            return {"params": p, "opt": o}, {"loss": float(m["loss"])}

        state, result = resilient_loop(
            state={"params": params, "opt": opt},
            step_fn=step_fn,
            num_steps=15,
            ckpt=ckpt,
            ckpt_every=5,
            max_restarts=4,
        )
        ckpt.close()
        return state, result

    clean_state, clean = run(())
    faulty_state, faulty = run((7, 12))
    assert clean.restarts == 0
    assert faulty.restarts == 2
    assert faulty.final_step == clean.final_step == 15
    for a, b in zip(jax.tree.leaves(clean_state), jax.tree.leaves(faulty_state)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_restart_budget_exhausted(tmp_ckpt_dir):
    ckpt = CheckpointManager(tmp_ckpt_dir, keep_last_k=1)
    injector = FaultInjector((3,))

    def step_fn(state, i):
        injector.pending.add(3)  # re-arm: fails forever at step 3
        injector.maybe_fail(i)
        return state, {}

    with pytest.raises(RuntimeError, match="restart budget"):
        resilient_loop(
            state={"x": jnp.zeros(2)}, step_fn=step_fn, num_steps=5,
            ckpt=ckpt, ckpt_every=100, max_restarts=2,
        )
    ckpt.close()


def test_straggler_monitor_flags_slow_step():
    mon = StepMonitor(threshold=3.0, window=16)
    for i in range(10):
        mon.start_step()
        time.sleep(0.004)
        assert mon.end_step(i) is None
    mon.start_step()
    time.sleep(0.08)
    ev = mon.end_step(10)
    assert ev is not None and ev.step == 10
    assert ev.duration_s > 3.0 * ev.median_s


def test_markov_pipeline_deterministic_and_sharded():
    src = MarkovSource(vocab=97, seq_len=12, global_batch=8, seed=3)
    a = src.batch(5)
    b = src.batch(5)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    # labels are next-token shifted
    np.testing.assert_array_equal(a["tokens"][:, 1:], a["labels"][:, :-1])
    # host slices are disjoint rows of the same global batch
    h0 = src.batch(5, host_slice=slice(0, 4))
    h1 = src.batch(5, host_slice=slice(4, 8))
    np.testing.assert_array_equal(
        np.concatenate([h0["tokens"], h1["tokens"]]), a["tokens"]
    )
    # different steps differ
    c = src.batch(6)
    assert not np.array_equal(a["tokens"], c["tokens"])
    # markov property: every transition is in the table
    tbl = src.table
    toks = np.concatenate([a["tokens"], a["labels"][:, -1:]], 1)
    for row in toks:
        for t in range(len(row) - 1):
            assert row[t + 1] in tbl[row[t]]
