"""Fault-tolerant multi-chain Chainwrite: re-forming, recovery latency,
failure isolation, and the resilient-loop integration.

Pins the ISSUE-2 acceptance matrix:

* ``reform_chain`` splices the failed member and re-orders only the
  orphaned suffix (torus-aware: wrap-around links are scored).
* ``chain_recovery_latency`` isolation invariant — sub-chains without
  the failed member complete at *exactly* their failure-free latency.
* The calibrated Fig. 7 slope (82 CC/destination) and the CC-exact
  K=1 reduction survive the simulator refactor, with and without the
  new ``src_read_bw`` knob.
* ``MultiChainTask.inject_failure`` charges recovery cycles only to
  the affected sub-chain's ledger and still delivers to survivors.
* ``resilient_loop(reform_fn=...)`` + ``MultiChainPlan`` survive a
  ``SimulatedNodeFailure`` by re-forming instead of restarting.
"""

from __future__ import annotations

import dataclasses
import random

import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.checkpoint.manager import CheckpointManager
from repro.core import chainwrite_ref as ref
from repro.core.chaintask import MultiChainTask, Phase
from repro.core.scheduling import (
    chain_total_hops,
    partition_schedule,
    reform_chain,
    tsp_schedule,
)
from repro.core.simulator import (
    DEFAULT_PARAMS,
    SimParams,
    SourceFailedError,
    chain_recovery_latency,
    chainwrite_latency,
    config_overhead_per_destination,
    multi_chain_latency,
)
from repro.core.topology import MeshTopology
from repro.parallel.collectives import MultiChainPlan
from repro.runtime.failure import (
    FaultInjector,
    SimulatedNodeFailure,
    resilient_loop,
)

TOPO = MeshTopology(4, 5)  # the paper's 20-cluster SoC
BIG = MeshTopology(8, 8)
SIZE = 64 * 1024


# ---------------------------------------------------------------------------
# reform_chain (scheduling layer)
# ---------------------------------------------------------------------------


@settings(max_examples=40, deadline=None)
@given(data=st.data())
def test_reform_chain_covers_survivors_and_keeps_prefix(data):
    dests = data.draw(
        st.lists(st.integers(1, 63), min_size=2, max_size=12, unique=True)
    )
    order = tsp_schedule(BIG, dests, 0)
    failed = data.draw(st.sampled_from(order))
    i = order.index(failed)
    new = reform_chain(BIG, order, failed, 0)
    assert sorted(new) == sorted(d for d in order if d != failed)
    assert new[:i] == order[:i]  # upstream members keep the payload


def test_reform_chain_tail_failure_is_pure_splice():
    order = [1, 2, 3, 4]
    assert reform_chain(BIG, order, 4, 0) == [1, 2, 3]


def test_reform_chain_never_worse_than_splice():
    rng = random.Random(7)
    for _ in range(20):
        dests = rng.sample(range(1, 64), 10)
        order = tsp_schedule(BIG, dests, 0)
        failed = rng.choice(order)
        i = order.index(failed)
        new = reform_chain(BIG, order, failed, 0)
        spliced = order[:i] + order[i + 1 :]
        assert chain_total_hops(BIG, new, 0) <= chain_total_hops(
            BIG, spliced, 0
        )


def test_reform_chain_non_member_raises():
    with pytest.raises(ValueError):
        reform_chain(BIG, [1, 2, 3], 9, 0)


@settings(max_examples=30, deadline=None)
@given(data=st.data())
def test_reform_chain_torus_scores_wraparound(data):
    """Re-formed chains on a torus never cost more hops than on the
    equivalent mesh (wrap-around links are exploited)."""
    mesh = MeshTopology(6, 6, torus=False)
    torus = MeshTopology(6, 6, torus=True)
    dests = data.draw(
        st.lists(st.integers(1, 35), min_size=3, max_size=10, unique=True)
    )
    order = tsp_schedule(mesh, dests, 0)
    failed = data.draw(st.sampled_from(order))
    on_mesh = reform_chain(mesh, order, failed, 0)
    on_torus = reform_chain(torus, order, failed, 0)
    assert sorted(on_torus) == sorted(on_mesh)
    assert chain_total_hops(torus, on_torus, 0) <= chain_total_hops(
        mesh, on_mesh, 0
    )


# ---------------------------------------------------------------------------
# chain_recovery_latency (simulator layer)
# ---------------------------------------------------------------------------


@settings(max_examples=30, deadline=None)
@given(data=st.data(), k=st.integers(2, 4))
def test_recovery_isolates_unfailed_chains_cc_exact(data, k):
    """Isolation invariant: every chain without the failed member
    completes at exactly its multi_chain_latency per-chain time."""
    dests = data.draw(
        st.lists(st.integers(1, 63), min_size=6, max_size=20, unique=True)
    )
    chains = partition_schedule(BIG, dests, 0, num_chains=k)
    failed = data.draw(st.sampled_from([d for c in chains for d in c]))
    base = multi_chain_latency(BIG, 0, chains, SIZE, detail=True)
    rec = chain_recovery_latency(BIG, 0, chains, failed, SIZE, detail=True)
    ci = rec["recovery"]["chain"]
    assert failed in chains[ci]
    for i, (b, r) in enumerate(zip(base["per_chain"], rec["per_chain"])):
        if i == ci:
            assert r == b + rec["recovery"]["recovery_cc"]
        else:
            assert r == b  # CC-exact isolation
    assert rec["per_phase"] == base["per_phase"]
    assert rec["total"] == max(rec["per_chain"])
    assert chain_recovery_latency(BIG, 0, chains, failed, SIZE) == rec["total"]


def test_recovery_charges_at_least_the_timeout():
    chains = partition_schedule(BIG, list(range(1, 13)), 0, num_chains=3)
    failed = chains[0][0]
    rec = chain_recovery_latency(BIG, 0, chains, failed, SIZE, detail=True)
    r = rec["recovery"]
    assert r["detect_cc"] == DEFAULT_PARAMS.fail_timeout_cc
    assert r["recovery_cc"] >= DEFAULT_PARAMS.fail_timeout_cc
    # a mid-chain failure re-sends a non-empty suffix: all four phases
    assert r["resent"]
    assert min(r["cfg_cc"], r["grant_cc"], r["data_cc"], r["finish_cc"]) > 0
    # the re-formed order covers the chain minus the failed member
    assert sorted(r["reformed"]) == sorted(
        d for d in chains[0] if d != failed
    )


def test_recovery_tail_failure_costs_only_the_timeout():
    chains = [[1, 2, 3], [9, 17]]
    rec = chain_recovery_latency(BIG, 0, chains, 3, SIZE, detail=True)
    assert rec["recovery"]["resent"] == []
    assert rec["recovery"]["recovery_cc"] == DEFAULT_PARAMS.fail_timeout_cc


def test_recovery_unknown_node_raises():
    with pytest.raises(ValueError):
        chain_recovery_latency(BIG, 0, [[1, 2]], 5, SIZE)
    with pytest.raises(ValueError):  # one unknown poisons the whole set
        chain_recovery_latency(BIG, 0, [[1, 2]], {2, 5}, SIZE)
    with pytest.raises(ValueError):  # empty failure set
        chain_recovery_latency(BIG, 0, [[1, 2]], set(), SIZE)


def test_recovery_source_death_is_typed():
    """Losing the initiator is total loss, not a member failure: a
    typed SourceFailedError (still a ValueError for old callers)."""
    with pytest.raises(SourceFailedError):
        chain_recovery_latency(BIG, 0, [[0, 1, 2]], 0, SIZE)
    with pytest.raises(SourceFailedError):
        chain_recovery_latency(BIG, 0, [[0, 1, 2]], {0, 1}, SIZE)
    assert issubclass(SourceFailedError, ValueError)


def test_concurrent_failures_isolate_and_serialize_cfg():
    """Two failures in distinct sub-chains: unaffected chains stay
    CC-exact, each affected chain pays detection + its own re-send,
    and the recovery cfgs serialize through the one inject port (the
    second recovery's cfg phase sees the first's injections)."""
    chains = partition_schedule(BIG, list(range(1, 13)), 0, num_chains=3)
    f0, f1 = chains[0][1], chains[1][1]
    base = multi_chain_latency(BIG, 0, chains, SIZE, detail=True)
    both = chain_recovery_latency(BIG, 0, chains, {f0, f1}, SIZE, detail=True)
    assert both["failed"] == sorted({f0, f1})
    assert [r["chain"] for r in both["recoveries"]] == [0, 1]
    assert "recovery" not in both  # >1 affected chain: no single alias
    for i, (b, r) in enumerate(zip(base["per_chain"], both["per_chain"])):
        if i == 2:
            assert r == b  # isolation: untouched sub-chain is CC-exact
        else:
            rec = next(x for x in both["recoveries"] if x["chain"] == i)
            assert r == b + rec["recovery_cc"]
    # cfg-port serialization: recovering chain 1 alone (port otherwise
    # free) costs no more cfg cycles than recovering it after chain 0's
    # cfgs went through the shared port.
    alone = chain_recovery_latency(BIG, 0, chains, f1, SIZE, detail=True)
    rec1 = next(x for x in both["recoveries"] if x["chain"] == 1)
    extra = len(both["recoveries"][0]["resent"]) * DEFAULT_PARAMS.cfg_inject_cc
    assert rec1["cfg_cc"] == alone["recovery"]["cfg_cc"] + extra
    # and each single-failure recovery is unchanged by the other chain
    alone0 = chain_recovery_latency(BIG, 0, chains, f0, SIZE, detail=True)
    assert both["recoveries"][0]["recovery_cc"] == (
        alone0["recovery"]["recovery_cc"]
    )


def test_concurrent_failures_same_chain_single_reform():
    """Two dead members of the SAME chain recover as one re-formed
    suffix from the earliest failure's prefix."""
    chains = [[1, 2, 10, 9, 8], [5, 6, 7]]
    dead = {10, 8}
    d = chain_recovery_latency(BIG, 0, chains, dead, SIZE, detail=True)
    assert len(d["recoveries"]) == 1 and "recovery" in d
    rec = d["recoveries"][0]
    assert rec["chain"] == 0 and rec["failed"] == [10, 8]
    assert rec["reformed"][:2] == [1, 2]  # prefix before first failure
    assert sorted(rec["reformed"]) == [1, 2, 9]
    assert rec["recovery_cc"] >= DEFAULT_PARAMS.fail_timeout_cc
    assert d["per_chain"][1] == multi_chain_latency(
        BIG, 0, chains, SIZE, detail=True
    )["per_chain"][1]


# ---------------------------------------------------------------------------
# regression: calibration survives the simulator refactor (satellite b)
# ---------------------------------------------------------------------------


def test_fig7_slope_survives_refactor():
    res = config_overhead_per_destination(TOPO, src=0, max_dsts=8)
    assert res["slope_cc_per_dst"] == pytest.approx(82.0, abs=3.0)


def test_k1_reduction_survives_refactor_with_and_without_src_read_bw():
    rng = random.Random(4)
    contended = dataclasses.replace(DEFAULT_PARAMS, src_read_bw=48)
    for n in (1, 4, 9):
        dests = rng.sample(range(1, 64), n)
        order = tsp_schedule(BIG, dests, 0)
        for p in (DEFAULT_PARAMS, contended):
            assert multi_chain_latency(BIG, 0, [order], SIZE, p) == (
                chainwrite_latency(BIG, 0, order, SIZE, p)
            )


# ---------------------------------------------------------------------------
# src_read_bw knob (satellite: data-port contention)
# ---------------------------------------------------------------------------


def test_src_read_bw_default_changes_nothing():
    """src_read_bw=None (the default) keeps every pinned latency."""
    explicit = SimParams(src_read_bw=None)
    chains = partition_schedule(BIG, list(range(1, 17)), 0, num_chains=3)
    assert multi_chain_latency(BIG, 0, chains, SIZE, explicit) == (
        multi_chain_latency(BIG, 0, chains, SIZE, DEFAULT_PARAMS)
    )
    # generous bandwidth (>= K * link_bw) is also contention-free
    generous = SimParams(src_read_bw=3 * DEFAULT_PARAMS.link_bw)
    assert multi_chain_latency(BIG, 0, chains, SIZE, generous) == (
        multi_chain_latency(BIG, 0, chains, SIZE, DEFAULT_PARAMS)
    )


def test_src_read_bw_contention_slows_only_the_data_phase():
    chains = partition_schedule(BIG, list(range(1, 17)), 0, num_chains=3)
    scarce = SimParams(src_read_bw=DEFAULT_PARAMS.link_bw)  # K shares 1 link
    base = multi_chain_latency(BIG, 0, chains, SIZE, detail=True)
    slow = multi_chain_latency(BIG, 0, chains, SIZE, scarce, detail=True)
    for (bc, bg, bd, bf), (sc, sg, sd, sf) in zip(
        base["per_phase"], slow["per_phase"]
    ):
        assert (sc, sg, sf) == (bc, bg, bf)  # cfg/grant/finish untouched
        assert sd > bd  # data stream pays the shared read port
    assert slow["total"] > base["total"]


def test_src_read_bw_monotone_in_bandwidth():
    chains = partition_schedule(BIG, list(range(1, 17)), 0, num_chains=2)
    lats = [
        multi_chain_latency(
            BIG, 0, chains, SIZE, SimParams(src_read_bw=bw)
        )
        for bw in (16, 32, 64, 128)
    ]
    assert lats == sorted(lats, reverse=True)


# ---------------------------------------------------------------------------
# MultiChainTask failure injection (host orchestration layer)
# ---------------------------------------------------------------------------


def _oracle_rows(num_nodes, payload, head, chains, failed):
    """Global-view degraded-broadcast oracle rows, keyed by node."""
    xs = np.zeros((num_nodes,) + payload.shape, payload.dtype)
    xs[head] = payload
    return ref.degraded_multi_broadcast_ref(xs, head, chains, failed)


def test_multichain_task_failure_delivers_to_survivors_exactly():
    payload = np.arange(2048, dtype=np.float32)
    dests = [3, 7, 12, 14, 9, 18]
    for k in (1, 2, 3):
        for failed in (12, 18):
            task = MultiChainTask(TOPO, 0, dests, payload, num_chains=k)
            task.inject_failure(failed)
            bufs = task.run()
            assert task.phase is Phase.DONE
            assert set(bufs) == set(dests) - {failed}
            expect = _oracle_rows(
                TOPO.num_nodes, payload, 0, task.chains, failed
            )
            for d in bufs:
                np.testing.assert_array_equal(bufs[d], expect[d])
            np.testing.assert_array_equal(
                expect[failed], np.zeros_like(payload)
            )


def test_multichain_task_failure_charges_only_affected_ledger():
    payload = np.zeros(SIZE, np.uint8)
    dests = list(range(1, 13))
    failed = 7
    clean = MultiChainTask(BIG, 0, dests, payload, num_chains=3)
    faulty = MultiChainTask(BIG, 0, dests, payload, num_chains=3)
    assert clean.chains == faulty.chains
    faulty.inject_failure(failed)
    clean.run()
    faulty.run()
    ci = next(i for i, c in enumerate(faulty.chains) if failed in c)
    for i, (a, b) in enumerate(
        zip(clean.per_chain_ledgers, faulty.per_chain_ledgers)
    ):
        if i == ci:
            assert b["recovery"] > 0
            assert b["total"] == a["total"] + b["recovery"]
            for phase in ("cfg", "grant", "data", "finish"):
                assert a[phase] == b[phase]
        else:
            assert a == b  # CC-exact: failure elsewhere is invisible
    assert "recovery" not in clean.cycle_ledger
    assert faulty.cycle_ledger["recovery"] == (
        faulty.per_chain_ledgers[ci]["recovery"]
    )
    assert faulty.cycle_ledger["total"] == max(
        lg["total"] for lg in faulty.per_chain_ledgers
    )
    # the reformed schedule drops exactly the failed member
    assert faulty.reformed_chains is not None
    assert sorted(d for c in faulty.reformed_chains for d in c) == sorted(
        d for d in dests if d != failed
    )
    assert clean.reformed_chains is None


def test_multichain_task_explicit_chains_and_validation():
    payload = np.zeros(64, np.uint8)
    chains = [[3, 7], [12, 14]]
    task = MultiChainTask(TOPO, 0, [3, 7, 12, 14], payload, chains=chains)
    assert task.chains == chains and task.num_chains == 2
    with pytest.raises(ValueError):  # chains must partition destinations
        MultiChainTask(TOPO, 0, [3, 7, 12], payload, chains=chains)
    with pytest.raises(ValueError):  # failure must name a member
        task.inject_failure(5)
    task.run()
    with pytest.raises(RuntimeError):  # and must precede run()
        task.inject_failure(3)


def test_inject_failure_twice_raises_regression():
    """Regression (ISSUE-5 satellite): injecting a second failure used
    to silently overwrite the first. Now failures accumulate into a
    set; re-injecting the same node — or a node already spliced out of
    the partition the task was built with — raises."""
    payload = np.zeros(64, np.uint8)
    task = MultiChainTask(TOPO, 0, [3, 7, 12, 14], payload, num_chains=2)
    task.inject_failure(7)
    with pytest.raises(ValueError):  # same node twice
        task.inject_failure(7)
    task.inject_failure(12)  # a second, distinct failure ACCUMULATES
    assert task.failed_nodes == [7, 12]
    with pytest.raises(RuntimeError):  # ambiguous single-failure alias
        task.failed_node
    # a node already spliced out of a re-formed plan is not a member
    plan = MultiChainPlan(TOPO, 0, [3, 7, 12, 14], num_chains=2)
    assert plan.reform(12) is True
    stale = MultiChainTask(
        TOPO, 0, plan.survivors, payload,
        chains=[list(c) for c in plan.chains],
    )
    with pytest.raises(ValueError):
        stale.inject_failure(12)


def test_multichain_task_concurrent_failures_deliver_and_charge():
    """Two failures in distinct sub-chains: every survivor still gets
    the payload, both affected ledgers are charged their own recovery,
    and unaffected ledgers stay CC-exact."""
    payload = np.arange(1024, dtype=np.float32)
    dests = list(range(1, 13))
    clean = MultiChainTask(BIG, 0, dests, payload, num_chains=3)
    faulty = MultiChainTask(BIG, 0, dests, payload, num_chains=3)
    assert clean.chains == faulty.chains
    dead = {faulty.chains[0][1], faulty.chains[2][0]}
    for n in dead:
        faulty.inject_failure(n)
    clean.run()
    bufs = faulty.run()
    assert set(bufs) == set(dests) - dead
    expect = _oracle_rows(BIG.num_nodes, payload, 0, clean.chains, dead)
    for d in bufs:
        np.testing.assert_array_equal(bufs[d], expect[d])
    affected = {
        i for i, c in enumerate(faulty.chains) if any(n in c for n in dead)
    }
    assert affected == {0, 2}
    for i, (a, b) in enumerate(
        zip(clean.per_chain_ledgers, faulty.per_chain_ledgers)
    ):
        if i in affected:
            assert b["recovery"] >= DEFAULT_PARAMS.fail_timeout_cc
            assert b["total"] == a["total"] + b["recovery"]
        else:
            assert a == b  # CC-exact isolation
    assert faulty.cycle_ledger["recovery"] == max(
        faulty.per_chain_ledgers[i]["recovery"] for i in affected
    )
    # the reformed partition drops exactly the failed members
    assert sorted(d for c in faulty.reformed_chains for d in c) == sorted(
        d for d in dests if d not in dead
    )


# ---------------------------------------------------------------------------
# resilient_loop + MultiChainPlan (the acceptance-criterion test)
# ---------------------------------------------------------------------------


def test_fault_injection_end_to_end(tmp_ckpt_dir):
    """A SimulatedNodeFailure mid-collective is survived by re-forming:
    only the failed member's sub-chain is re-formed and charged
    recovery cycles, every other sub-chain's per-phase ledger is
    CC-identical to the failure-free run, the surviving destinations
    receive oracle-exact payloads, and the loop never rolls back."""
    payload = np.arange(512, dtype=np.float32)
    dests = [3, 7, 12, 14, 9, 18]
    failed = 12
    plan = MultiChainPlan(TOPO, 0, dests, num_chains=3)
    before = [list(c) for c in plan.chains]
    fi = next(i for i, c in enumerate(before) if failed in c)
    injector = FaultInjector(fail_at=(2,), node=failed)
    ckpt = CheckpointManager(tmp_ckpt_dir, keep_last_k=2)
    tasks = []

    def step_fn(state, i):
        task = MultiChainTask(
            TOPO, 0, plan.survivors, payload,
            chains=[list(c) for c in plan.chains],
        )
        try:
            injector.maybe_fail(i)
        except SimulatedNodeFailure as e:
            # the member died mid-collective: finish degraded (recovery
            # charged to its sub-chain), then let the loop re-form the
            # plan and retry the step — no checkpoint rollback.
            task.inject_failure(e.node)
            task.run()
            tasks.append(task)
            raise
        bufs = task.run()
        tasks.append(task)
        return {"count": state["count"] + 1}, {"delivered": len(bufs)}

    state, res = resilient_loop(
        state={"count": 0}, step_fn=step_fn, num_steps=4, ckpt=ckpt,
        ckpt_every=100, max_restarts=3, reform_fn=plan.reform,
    )
    ckpt.close()

    # survived by re-forming, not restarting
    assert res.reforms == 1 and res.restarts == 0
    assert res.final_step == 4 and state["count"] == 4
    assert plan.failed == [failed]
    # only the failed member's sub-chain was re-formed
    assert len(plan.chains) == len(before)
    for i, (old, new) in enumerate(zip(before, plan.chains)):
        if i == fi:
            assert sorted(new) == sorted(d for d in old if d != failed)
        else:
            assert new == old
    # steps after the failure deliver to every survivor
    assert res.metrics_history[-1]["delivered"] == len(dests) - 1

    # the failing step's task: recovery charged only to the affected
    # sub-chain, every other ledger CC-exact vs the failure-free step
    faulty = tasks[2]  # steps 0,1 clean; index 2 = the failing attempt
    clean = tasks[1]
    assert faulty.failed_node == failed
    for i, (a, b) in enumerate(
        zip(clean.per_chain_ledgers, faulty.per_chain_ledgers)
    ):
        if i == fi:
            assert b["recovery"] > 0
        else:
            assert a == b
    # degraded broadcast: survivors match the chainwrite_ref oracle
    expect = _oracle_rows(TOPO.num_nodes, payload, 0, before, failed)
    assert set(faulty.node_buffers) == set(dests) - {failed}
    for d, buf in faulty.node_buffers.items():
        np.testing.assert_array_equal(buf, expect[d])


def test_reform_fn_declining_falls_back_to_restart(tmp_ckpt_dir):
    ckpt = CheckpointManager(tmp_ckpt_dir, keep_last_k=2)
    injector = FaultInjector(fail_at=(1,), node=99)

    def step_fn(state, i):
        injector.maybe_fail(i)
        return {"count": state["count"] + 1}, {}

    state, res = resilient_loop(
        state={"count": 0}, step_fn=step_fn, num_steps=3, ckpt=ckpt,
        ckpt_every=100, max_restarts=2, reform_fn=lambda node: False,
    )
    ckpt.close()
    assert res.restarts == 1 and res.reforms == 0


def test_anonymous_failure_still_restarts(tmp_ckpt_dir):
    """Failures without a node id keep the original rollback path even
    when a reform_fn is installed."""
    ckpt = CheckpointManager(tmp_ckpt_dir, keep_last_k=2)
    injector = FaultInjector(fail_at=(1,))  # no node attribution

    def step_fn(state, i):
        injector.maybe_fail(i)
        return {"count": state["count"] + 1}, {}

    state, res = resilient_loop(
        state={"count": 0}, step_fn=step_fn, num_steps=3, ckpt=ckpt,
        ckpt_every=100, max_restarts=2,
        reform_fn=lambda node: (_ for _ in ()).throw(AssertionError),
    )
    ckpt.close()
    assert res.restarts == 1 and res.reforms == 0


def test_source_death_falls_back_to_rollback(tmp_ckpt_dir):
    """A SimulatedNodeFailure naming the plan HEAD cannot be re-formed
    around (SourceFailedError): the loop must take the checkpoint
    rollback path, not retry-with-reform, and the plan stays intact."""
    plan = MultiChainPlan(TOPO, 0, [3, 7, 12], num_chains=2)
    before = [list(c) for c in plan.chains]
    ckpt = CheckpointManager(tmp_ckpt_dir, keep_last_k=2)
    injector = FaultInjector(fail_at=(1,), node=0)  # the head dies

    def step_fn(state, i):
        injector.maybe_fail(i)
        return {"count": state["count"] + 1}, {}

    state, res = resilient_loop(
        state={"count": 0}, step_fn=step_fn, num_steps=3, ckpt=ckpt,
        ckpt_every=100, max_restarts=2, reform_fn=plan.reform,
    )
    ckpt.close()
    assert res.restarts == 1 and res.reforms == 0
    assert [list(c) for c in plan.chains] == before and plan.failed == []


def test_resilient_loop_concurrent_failure_event(tmp_ckpt_dir):
    """One SimulatedNodeFailure naming TWO dead members re-forms both
    sub-chains in a single reform_fn call — no rollback."""
    plan = MultiChainPlan(TOPO, 0, [3, 7, 12, 14, 9, 18], num_chains=3)
    dead = (plan.chains[0][-1], plan.chains[1][-1])
    ckpt = CheckpointManager(tmp_ckpt_dir, keep_last_k=2)
    injector = FaultInjector(fail_at=(1,), nodes=dead)
    calls = []

    def reform(nodes):
        calls.append(nodes)
        return plan.reform(nodes)

    def step_fn(state, i):
        injector.maybe_fail(i)
        return {"count": state["count"] + 1}, {}

    state, res = resilient_loop(
        state={"count": 0}, step_fn=step_fn, num_steps=3, ckpt=ckpt,
        ckpt_every=100, max_restarts=2, reform_fn=reform,
    )
    ckpt.close()
    assert res.reforms == 1 and res.restarts == 0
    assert calls == [dead]  # the whole set in ONE event
    assert sorted(plan.failed) == sorted(dead)
    assert not set(dead) & set(plan.survivors)


def test_plan_reform_unknown_node_returns_false():
    plan = MultiChainPlan(TOPO, 0, [3, 7, 12], num_chains=2)
    with pytest.raises(SourceFailedError):  # head death = total loss
        plan.reform(0)
    assert plan.reform(11) is False  # never a member
    assert plan.reform(7) is True
    assert plan.reform(7) is False  # already failed
    assert 7 not in plan.survivors


def test_plan_reform_failure_sets():
    plan = MultiChainPlan(TOPO, 0, [3, 7, 12, 14, 9, 18], num_chains=3)
    before = [list(c) for c in plan.chains]
    dead = {before[0][-1], before[1][0]}
    assert plan.reform(dead) is True
    assert sorted(plan.failed) == sorted(dead)
    assert sorted(plan.survivors) == sorted(
        d for c in before for d in c if d not in dead
    )
    # a set containing an already-failed node declines without mutating
    snapshot = [list(c) for c in plan.chains]
    assert plan.reform({before[0][-1], before[2][0]}) is False
    assert [list(c) for c in plan.chains] == snapshot
    # a set containing the head is total loss even if others are live
    with pytest.raises(SourceFailedError):
        plan.reform({0, before[2][0]})
