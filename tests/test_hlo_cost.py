"""Trip-count-aware HLO cost model — unit tests on hand-built HLO text."""

from __future__ import annotations

import pytest

from repro.launch import hlo_cost


SIMPLE = """\
HloModule test

%body (p: (s32[], f32[8,16])) -> (s32[], f32[8,16]) {
  %p = (s32[], f32[8,16]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[8,16] get-tuple-element(%p), index=1
  %w = f32[16,16] constant({...})
  %dot.1 = f32[8,16] dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %one = s32[] constant(1)
  %ni = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[8,16]) tuple(%ni, %dot.1)
}

%cond (p: (s32[], f32[8,16])) -> pred[] {
  %p = (s32[], f32[8,16]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %n = s32[] constant(10)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

ENTRY %main (a: f32[8,16]) -> f32[8,16] {
  %a = f32[8,16] parameter(0)
  %zero = s32[] constant(0)
  %init = (s32[], f32[8,16]) tuple(%zero, %a)
  %while.1 = (s32[], f32[8,16]) while(%init), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"10"}}
  ROOT %out = f32[8,16] get-tuple-element(%while.1), index=1
}
"""


def test_while_trip_count_multiplies_flops():
    cost = hlo_cost.analyze(SIMPLE)
    # one dot: 2*8*16*16 = 4096 flops; body add: 1 flop -> x10 trips
    assert cost.flops == 10 * (2 * 8 * 16 * 16 + 1)


def test_constant_bytes_sums_all_computations():
    # SIMPLE holds four literal constants: f32[16,16] in %body (1024 B)
    # plus three s32[] scalars (4 B each) across body/cond/main.
    assert hlo_cost.constant_bytes(SIMPLE) == 16 * 16 * 4 + 3 * 4


def test_parse_module_structure():
    comps = hlo_cost.parse_module(SIMPLE)
    assert set(comps) == {"body", "cond", "main"}
    main = comps["main"]
    assert [i.opcode for i in main.instrs] == [
        "parameter", "constant", "tuple", "while", "get-tuple-element",
    ]
    w = main.by_name["while.1"]
    assert w.shapes == [("s32", ()), ("f32", (8, 16))]


FUSION = """\
HloModule f

%fused (p0: f32[128,256], p1: f32[128,256]) -> f32[128,256] {
  %p0 = f32[128,256] parameter(0)
  %p1 = f32[128,256] parameter(1)
  %m = f32[128,256] multiply(%p0, %p1)
  ROOT %a = f32[128,256] add(%m, %p1)
}

ENTRY %main (x: f32[128,256], y: f32[128,256]) -> f32[128,256] {
  %x = f32[128,256] parameter(0)
  %y = f32[128,256] parameter(1)
  ROOT %fusion.1 = f32[128,256] fusion(%x, %y), kind=kLoop, calls=%fused
}
"""


def test_fusion_boundary_bytes_and_inner_flops():
    cost = hlo_cost.analyze(FUSION)
    n = 128 * 256
    assert cost.flops == 2 * n  # multiply + add
    # bytes: 2 operands + 1 result at the fusion boundary, f32
    assert cost.bytes == 3 * n * 4


COLLECTIVES = """\
HloModule c

ENTRY %main (x: bf16[64,128]) -> bf16[64,128] {
  %x = bf16[64,128] parameter(0)
  %ar = bf16[64,128] all-reduce(%x), replica_groups=[4,16]<=[64], to_apply=%add
  %ag = bf16[256,128] all-gather(%ar), replica_groups=[16,4]<=[64], dimensions={0}
  %rs = bf16[64,128] reduce-scatter(%ag), replica_groups=[16,4]<=[64], dimensions={0}, to_apply=%add
  %cp = bf16[64,128] collective-permute(%rs), source_target_pairs={{0,1},{1,2}}
  ROOT %out = bf16[64,128] add(%cp, %x)
}
"""


def test_collective_wire_bytes():
    cost = hlo_cost.analyze(COLLECTIVES)
    b = 64 * 128 * 2  # bf16 payload bytes
    assert cost.coll["all-reduce"] == b
    # all-gather result is group_size x operand: wire = result / 4
    assert cost.coll["all-gather"] == b
    # reduce-scatter result is operand / group_size: wire = result * 4
    assert cost.coll["reduce-scatter"] == 4 * b
    assert cost.coll["collective-permute"] == b
    assert cost.coll_bytes == 7 * b


def test_dot_batch_dims():
    hlo = """\
HloModule d

ENTRY %main (a: f32[4,32,64], b: f32[4,64,16]) -> f32[4,32,16] {
  %a = f32[4,32,64] parameter(0)
  %b = f32[4,64,16] parameter(1)
  ROOT %dot.9 = f32[4,32,16] dot(%a, %b), lhs_batch_dims={0}, lhs_contracting_dims={2}, rhs_batch_dims={0}, rhs_contracting_dims={1}
}
"""
    cost = hlo_cost.analyze(hlo)
    assert cost.flops == 2 * (4 * 32 * 16) * 64


def test_nested_while():
    hlo = """\
HloModule n

%inner_body (p: (s32[], f32[4])) -> (s32[], f32[4]) {
  %p = (s32[], f32[4]) parameter(0)
  %x = f32[4] get-tuple-element(%p), index=1
  %y = f32[4] add(%x, %x)
  %i = s32[] get-tuple-element(%p), index=0
  ROOT %t = (s32[], f32[4]) tuple(%i, %y)
}

%inner_cond (p: (s32[], f32[4])) -> pred[] {
  %p = (s32[], f32[4]) parameter(0)
  ROOT %c = pred[] constant(false)
}

%outer_body (p: (s32[], f32[4])) -> (s32[], f32[4]) {
  %p = (s32[], f32[4]) parameter(0)
  ROOT %w = (s32[], f32[4]) while(%p), condition=%inner_cond, body=%inner_body, backend_config={"known_trip_count":{"n":"5"}}
}

%outer_cond (p: (s32[], f32[4])) -> pred[] {
  %p = (s32[], f32[4]) parameter(0)
  ROOT %c = pred[] constant(false)
}

ENTRY %main (a: f32[4]) -> f32[4] {
  %a = f32[4] parameter(0)
  %zero = s32[] constant(0)
  %init = (s32[], f32[4]) tuple(%zero, %a)
  %w = (s32[], f32[4]) while(%init), condition=%outer_cond, body=%outer_body, backend_config={"known_trip_count":{"n":"3"}}
  ROOT %o = f32[4] get-tuple-element(%w), index=1
}
"""
    cost = hlo_cost.analyze(hlo)
    assert cost.flops == 3 * 5 * 4  # nested trip counts multiply


def test_async_collective_counted_once():
    hlo = """\
HloModule a

ENTRY %main (x: f32[32]) -> f32[32] {
  %x = f32[32] parameter(0)
  %s = (f32[32], f32[32]) all-gather-start(%x), replica_groups=[32,1]<=[32], dimensions={0}
  ROOT %d = f32[32] all-gather-done(%s)
}
"""
    cost = hlo_cost.analyze(hlo)
    assert cost.coll["all-gather"] == 32 * 4  # counted at -start only


def test_free_ops_cost_nothing():
    hlo = """\
HloModule z

ENTRY %main (x: f32[1024]) -> f32[1024] {
  %x = f32[1024] parameter(0)
  %b = f32[1024] bitcast(%x)
  %t = (f32[1024]) tuple(%b)
  ROOT %g = f32[1024] get-tuple-element(%t), index=0
}
"""
    cost = hlo_cost.analyze(hlo)
    assert cost.flops == 0 and cost.bytes == 0


def test_real_module_smoke():
    """The parser handles a real compiled module (tiny model, 1 device)."""
    import jax
    import jax.numpy as jnp

    def f(x, w):
        for _ in range(3):
            x = jnp.tanh(x @ w)
        return x.sum()

    xs = jnp.zeros((8, 64)), jnp.zeros((64, 64))
    compiled = jax.jit(f).lower(*xs).compile()
    cost = hlo_cost.analyze(compiled.as_text())
    assert cost.flops >= 3 * 2 * 8 * 64 * 64  # at least the three matmuls
    assert cost.bytes > 0
