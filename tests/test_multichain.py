"""Multi-chain Chainwrite: simulator regressions + MultiChainTask.

Pins the calibrated Fig. 7 behaviour (82 CC/destination slope) for the
single-chain model, asserts the K-chain model reduces exactly to it at
K=1, and exercises the host-side MultiChainTask orchestration.
"""

from __future__ import annotations

import random

import numpy as np
import pytest

from repro.core.chaintask import ChainTask, MultiChainTask, Phase
from repro.core.scheduling import SCHEDULERS, partition_schedule, tsp_schedule
from repro.core.simulator import (
    DEFAULT_PARAMS,
    chainwrite_latency,
    choose_num_chains,
    config_overhead_per_destination,
    multi_chain_latency,
)
from repro.core.topology import MeshTopology

TOPO = MeshTopology(4, 5)  # the paper's 20-cluster SoC
BIG = MeshTopology(8, 8)
SIZE = 64 * 1024


# ---------------------------------------------------------------------------
# simulator regressions
# ---------------------------------------------------------------------------


def test_fig7_slope_is_pinned_at_82cc():
    """Calibration regression: the K=1 model's Fig. 7 slope stays 82."""
    res = config_overhead_per_destination(TOPO, src=0, max_dsts=8)
    assert res["slope_cc_per_dst"] == pytest.approx(82.0, abs=3.0)


def test_multi_chain_k1_reduces_exactly():
    """multi_chain_latency([order]) == chainwrite_latency(order), CC-exact."""
    rng = random.Random(0)
    for topo in (TOPO, BIG):
        for n in (1, 3, 7, 12):
            for size in (1024, SIZE, 1 << 20):
                dests = rng.sample(range(1, topo.num_nodes), n)
                order = tsp_schedule(topo, dests, 0)
                assert multi_chain_latency(topo, 0, [order], size) == (
                    chainwrite_latency(topo, 0, order, size)
                )


def test_multi_chain_k1_slope_also_82cc():
    """The K=1 path through the multi-chain model keeps the Fig. 7
    slope: same adjacent-row experiment, same 82 CC/destination."""
    lats = []
    for n in range(1, 9):
        dsts = list(range(1, 1 + n))
        order = SCHEDULERS["greedy"](TOPO, dsts, 0)
        lats.append(multi_chain_latency(TOPO, 0, [order], SIZE))
    ns = list(range(1, 9))
    mean_n = sum(ns) / len(ns)
    mean_l = sum(lats) / len(lats)
    slope = sum((n - mean_n) * (l - mean_l) for n, l in zip(ns, lats)) / sum(
        (n - mean_n) ** 2 for n in ns
    )
    assert slope == pytest.approx(82.0, abs=3.0)


def test_cfg_port_serialization_staggers_chains():
    """Later chains pay for earlier chains' cfg injection: with two
    identical chains, chain 1's cfg completes cfg_inject_cc * len
    later than chain 0's."""
    p = DEFAULT_PARAMS
    chains = [[1, 2], [5, 6]]
    detail = multi_chain_latency(BIG, 0, chains, SIZE, detail=True)
    cfg0, cfg1 = detail["per_phase"][0][0], detail["per_phase"][1][0]
    far0 = max(BIG.distance(0, d) for d in chains[0])
    far1 = max(BIG.distance(0, d) for d in chains[1])
    assert cfg1 - cfg0 == 2 * p.cfg_inject_cc + (far1 - far0) * p.router_cc


def test_detail_totals_consistent():
    chains = partition_schedule(BIG, list(range(1, 17)), 0, num_chains=3)
    detail = multi_chain_latency(BIG, 0, chains, SIZE, detail=True)
    assert detail["total"] == max(detail["per_chain"])
    for per_chain, phases in zip(detail["per_chain"], detail["per_phase"]):
        assert per_chain == sum(phases)


def test_choose_num_chains_never_worse_than_k1():
    rng = random.Random(1)
    for n in (2, 6, 12, 20):
        dests = rng.sample(range(1, 64), n)
        lat1 = chainwrite_latency(BIG, 0, tsp_schedule(BIG, dests, 0), SIZE)
        k, chains = choose_num_chains(BIG, 0, dests, SIZE)
        assert multi_chain_latency(BIG, 0, chains, SIZE) <= lat1
        assert 1 <= k <= 4


def test_empty_chains_are_zero_latency():
    assert multi_chain_latency(BIG, 0, [], SIZE) == 0
    assert multi_chain_latency(BIG, 0, [[]], SIZE) == 0


# ---------------------------------------------------------------------------
# MultiChainTask orchestration
# ---------------------------------------------------------------------------


def test_multichain_task_delivers_payload_everywhere():
    payload = np.arange(2048, dtype=np.float32)
    dests = [3, 7, 12, 14, 9, 18]
    task = MultiChainTask(TOPO, 0, dests, payload, num_chains=2)
    assert task.phase is Phase.IDLE
    bufs = task.run()
    assert task.phase is Phase.DONE
    assert set(bufs) == set(dests)
    for d in dests:
        np.testing.assert_array_equal(bufs[d], payload)
    # partition covers the destinations exactly
    assert sorted(d for c in task.chains for d in c) == sorted(dests)
    assert task.num_chains == 2


def test_multichain_ledger_is_critical_path():
    payload = np.zeros(SIZE, np.uint8)
    task = MultiChainTask(BIG, 0, list(range(1, 17)), payload, num_chains=3)
    task.run()
    lg = task.cycle_ledger
    assert lg["total"] == task.predicted_cycles()
    # concurrent phases: the critical path is at most the sum of the
    # per-phase maxima and at least every individual phase maximum.
    assert lg["total"] <= lg["cfg"] + lg["grant"] + lg["data"] + lg["finish"]
    assert lg["total"] >= max(lg["cfg"], lg["grant"], lg["data"], lg["finish"])


def test_multichain_k1_ledger_matches_chaintask():
    payload = np.zeros(SIZE, np.uint8)
    dests = [1, 2, 3, 7]
    multi = MultiChainTask(TOPO, 0, dests, payload, num_chains=1, scheduler="greedy")
    single = ChainTask(TOPO, 0, dests, payload, scheduler="greedy")
    multi.run()
    single.run()
    assert multi.cycle_ledger == single.cycle_ledger


def test_multichain_task_auto_k():
    payload = np.zeros(SIZE, np.uint8)
    task = MultiChainTask(BIG, 0, list(range(1, 25)), payload)
    assert task.num_chains >= 2  # 24 spread destinations want chains
    task.run()
    assert task.speedup_vs_single_chain() > 1.0
    assert task.speedup_vs_unicast() > task.speedup_vs_single_chain()


def test_multichain_configs_serialize_all_chains():
    task = MultiChainTask(TOPO, 0, [3, 7, 12, 14], np.zeros(64), num_chains=2)
    cfgs = task.configs()
    # one cfg per chain member plus one initiator cfg per chain
    assert len(cfgs) == 4 + len(task.chains)
    heads = [c for c in cfgs if c.prev_node is None]
    assert all(h.node == 0 for h in heads)
    assert len(heads) == len(task.chains)


def test_multichain_transport_sees_disjoint_chains():
    hops: list[tuple[int, int]] = []
    task = MultiChainTask(TOPO, 0, [3, 7, 12, 14], np.zeros(16), num_chains=2)
    task.run(transport=lambda s, d, data: hops.append((s, d)))
    # every chain contributes len(chain) hops, all starting at source 0
    assert len(hops) == 4
    starts = [h for h in hops if h[0] == 0]
    assert len(starts) == len(task.chains)


def test_multichain_task_empty_destinations():
    """Degenerate but legal: no destinations -> no chains, zero ledger."""
    task = MultiChainTask(TOPO, 0, [], np.zeros(16))
    assert task.chains == []
    bufs = task.run()
    assert bufs == {}
    assert task.cycle_ledger["total"] == 0
    assert task.phase is Phase.DONE


def test_multichain_validation_errors():
    with pytest.raises(ValueError):
        MultiChainTask(TOPO, 0, [1, 1], np.zeros(4))
    with pytest.raises(ValueError):
        MultiChainTask(TOPO, 0, [0, 1], np.zeros(4))
    with pytest.raises(ValueError):  # explicit order must match dests
        ChainTask(TOPO, 0, [1, 2], np.zeros(4), order=[1, 3])


def test_chaintask_explicit_order_is_respected():
    task = ChainTask(TOPO, 0, [5, 2, 9], np.zeros(8), order=[9, 5, 2])
    assert task.order == [9, 5, 2]
    cfgs = task.configs()
    assert [c.node for c in cfgs] == [0, 9, 5, 2]
