"""Device-free ChainProgram golden-schedule tests (QUICK fast lane).

Pins the schedule IR's invariants without touching a device:

* golden step/edge/byte shapes for every planner × K (step counts,
  per-step fused-ppermute structure, shard-fraction accounting);
* :meth:`ChainProgram.validate` — edge-disjointness within a step,
  table bounds, width transitions;
* the numpy program interpreter against the *semantic* oracles for
  every collective × random ring partitions (property-style via
  _hypothesis_compat) — the planners compute the right thing for any
  schedule;
* the simulator re-expression: ``multi_chain_latency`` /
  ``all_reduce_latency`` ARE ``program_latency`` of the planned
  program, and ``program_wire_bytes`` matches the closed-form byte
  predictions;
* ``choose_num_chains`` extended to reduce_scatter / all_gather /
  all_to_all through the unified model.
"""

from __future__ import annotations

import dataclasses
import random

import numpy as np
import pytest

from _hypothesis_compat import given, settings, strategies as st
from repro.core import chainwrite_ref as ref
from repro.core import program as prg
from repro.core.simulator import (
    RING_COLLECTIVES,
    all_reduce_latency,
    all_reduce_wire_bytes,
    choose_num_chains,
    multi_chain_latency,
    plan_ring_collective,
    program_latency,
)
from repro.core.topology import MeshTopology

L = 8
KB = 1024
RING_SETS = {
    1: ((0, 1, 2, 3, 4, 5, 6, 7),),
    2: ((3, 1, 0, 2), (7, 5, 6, 4)),
    4: ((0, 2), (4, 6), (1, 3), (5, 7)),
}


# ---------------------------------------------------------------------------
# Golden schedules
# ---------------------------------------------------------------------------


def test_all_reduce_step_counts_and_fractions():
    for K, orders in RING_SETS.items():
        S = L // K
        p = prg.plan_all_reduce(L, orders, "rs_ag")
        if K == 1:
            # single ring: device-id RS+AG, 1/L shards
            assert p.num_steps == 2 * (L - 1)
            assert p.addr_shards == L and p.out_slots == L
        else:
            assert p.num_steps == 2 * (S - 1) + (K - 1)
            assert p.addr_shards == S and p.out_slots == S
            assert sum(1 for s in p.steps if s.tag == "cross") == K - 1
        assert all(s.width == 1 for s in p.steps)
        assert all(s.num_permutes() == 1 for s in p.steps)

        r = prg.plan_all_reduce(L, orders, "rotation")
        if K > 1:
            assert r.num_steps == S + K - 2
            assert r.addr_shards == 1  # full payloads
        else:
            assert r.num_steps == 2 * (L - 1)  # K=1 delegation: RS+AG


def test_ring_collective_step_counts():
    B = 1 << 20
    for K, orders in RING_SETS.items():
        S = L // K
        rs = prg.plan_reduce_scatter(L, orders)
        assert rs.num_steps == L - 1 if K == 1 else (S - 1) + (K - 1)
        ag = prg.plan_all_gather(L, orders)
        assert ag.num_steps == (S - 1) + (K - 1)
        a2a = prg.plan_all_to_all(L, orders)
        assert a2a.num_steps == L - 1  # a chunk train cannot shrink
        # byte accounting: every K matches the single ring
        assert rs.wire_bytes(B) == (L - 1) * (B // L)
        assert ag.wire_bytes(B) == (L - 1) * B
        assert a2a.wire_bytes(B) == (L - 1) * B


def test_broadcast_program_structure():
    chains = ((1, 2, 3), (4, 5, 6, 7))
    p = prg.plan_broadcast(L, 0, chains)
    assert p.kind == "pipeline" and p.head == 0
    assert p.num_steps == 4  # longest chain
    # step 0 fans out from the head: 2 edges, 2 permutes
    assert p.steps[0].edges == ((0, 1), (0, 4))
    assert p.steps[0].num_permutes() == 2
    # later steps are single fused hops per live chain
    assert p.steps[1].num_permutes() == 1
    assert p.steps[3].edges == ((6, 7),)
    # every step has unique destinations (edge-disjointness)
    for s in p.steps:
        dsts = [e[1] for e in s.edges]
        assert len(set(dsts)) == len(dsts)


def test_stepped_programs_have_disjoint_edges():
    for K, orders in RING_SETS.items():
        for plan in (
            prg.plan_all_reduce(L, orders, "rs_ag"),
            prg.plan_all_reduce(L, orders, "rotation"),
            prg.plan_reduce_scatter(L, orders),
            prg.plan_all_gather(L, orders),
            prg.plan_all_to_all(L, orders),
        ):
            for s in plan.steps:
                srcs = [e[0] for e in s.edges]
                dsts = [e[1] for e in s.edges]
                assert len(set(srcs)) == len(srcs)
                assert len(set(dsts)) == len(dsts)
                assert s.num_permutes() <= 1


def test_validate_rejects_malformed_programs():
    p = prg.plan_all_reduce(L, RING_SETS[2], "rs_ag")
    bad_step = dataclasses.replace(
        p.steps[0], edges=p.steps[0].edges + (p.steps[0].edges[0],)
    )
    with pytest.raises(ValueError):
        dataclasses.replace(p, steps=(bad_step,) + p.steps[1:]).validate()
    # out-of-range table index
    bad_tbl = tuple((99,) for _ in range(L))
    with pytest.raises(ValueError):
        dataclasses.replace(p, out_init=bad_tbl).validate()
    # width change without a load
    widened = dataclasses.replace(p.steps[1], width=3, load=None)
    with pytest.raises(ValueError):
        dataclasses.replace(p, steps=(p.steps[0], widened)).validate()


def test_planner_validation_errors():
    with pytest.raises(ValueError):
        prg.plan_all_reduce(L, RING_SETS[2], "bogus")
    with pytest.raises(ValueError):
        prg.plan_all_reduce(L, ((0, 1, 2), (3, 4)))  # unequal
    with pytest.raises(ValueError):
        prg.plan_all_gather(L, ((0, 1), (1, 2)))  # overlap
    with pytest.raises(ValueError):
        prg.plan_all_to_all(L, ())
    with pytest.raises(ValueError):
        prg.plan_broadcast(L, 0, ((1, 2), (2, 3)))
    with pytest.raises(ValueError):
        prg.plan_broadcast(L, 0, ((1, 0),))


# ---------------------------------------------------------------------------
# Interpreter vs semantic oracles (property-style)
# ---------------------------------------------------------------------------


def _random_partition(rng, total, K):
    perm = list(range(total))
    rng.shuffle(perm)
    S = total // K
    return tuple(tuple(perm[i * S : (i + 1) * S]) for i in range(K))


@settings(max_examples=30)
@given(data=st.data())
def test_planned_programs_compute_their_collectives(data):
    K = data.draw(st.sampled_from([1, 2, 3, 4]), label="K")
    S = data.draw(st.integers(min_value=1, max_value=4), label="S")
    n = K * S
    rng = random.Random(data.draw(st.integers(min_value=0, max_value=9999)))
    orders = _random_partition(rng, n, K)
    xs = np.random.default_rng(n * K + S).normal(size=(n, n, 3))
    xs = xs.astype(np.float32)

    got = ref.multi_reduce_scatter_ref(xs, orders)
    np.testing.assert_allclose(
        got, ref.reduce_scatter_ref(xs), rtol=2e-5, atol=2e-5,
        err_msg=f"rs {orders}")
    got = ref.multi_all_to_all_ref(xs, orders)
    np.testing.assert_array_equal(got, ref.all_to_all_ref(xs))
    shard = xs[:, 0]
    got = ref.multi_all_gather_ref(shard, orders)
    np.testing.assert_array_equal(got, ref.all_gather_ref(shard))
    for algo in ("rs_ag", "rotation"):
        got = ref.multi_all_reduce_ref(xs, orders, algo)
        np.testing.assert_allclose(
            got, ref.all_reduce_ref(xs), rtol=2e-5, atol=2e-5,
            err_msg=f"ar {orders} {algo}")


@settings(max_examples=20)
@given(data=st.data())
def test_broadcast_programs_deliver_everywhere(data):
    n = data.draw(st.integers(min_value=2, max_value=10), label="n")
    rng = random.Random(data.draw(st.integers(min_value=0, max_value=9999)))
    head = rng.randrange(n)
    dests = [d for d in range(n) if d != head]
    rng.shuffle(dests)
    cut = sorted(rng.sample(range(len(dests) + 1), min(2, len(dests))))
    chains = tuple(
        tuple(c)
        for c in np.split(np.asarray(dests), cut)
        if len(c)
    )
    xs = np.random.default_rng(n).normal(size=(n, 3)).astype(np.float32)
    p = prg.plan_broadcast(n, head, chains)
    got = ref.run_program_ref(xs, p)
    np.testing.assert_array_equal(
        got, ref.multi_broadcast_ref(xs, head, chains))


# ---------------------------------------------------------------------------
# Simulator re-expression
# ---------------------------------------------------------------------------

LINE8 = MeshTopology(8, 1)
MESH = MeshTopology(4, 5)


def test_models_are_program_latency_of_the_plans():
    for K, orders in RING_SETS.items():
        for algo in ("rs_ag", "rotation"):
            plan_algo = "rs_ag" if K == 1 else algo
            p = prg.plan_all_reduce(LINE8.num_nodes, orders, plan_algo)
            for size in (KB, 64 * KB):
                assert all_reduce_latency(
                    LINE8, 0, orders, size, algo=algo
                ) == program_latency(LINE8, 0, p, size)
    chains = ((1, 2, 3), (4, 5, 6, 7))
    p = prg.plan_broadcast(LINE8.num_nodes, 0, chains)
    for size in (KB, 64 * KB):
        assert multi_chain_latency(
            LINE8, 0, chains, size
        ) == program_latency(LINE8, 0, p, size)


def test_program_wire_bytes_matches_closed_forms():
    B = 256 * KB
    for K, orders in RING_SETS.items():
        S = L // K
        for algo in ("rs_ag", "rotation"):
            p = prg.plan_all_reduce(L, orders, "rs_ag" if K == 1 else algo)
            assert p.wire_bytes(B) == all_reduce_wire_bytes(S, K, B, algo)
        d = all_reduce_latency(LINE8, 0, orders, B, detail=True)
        assert d["wire_bytes"] == all_reduce_wire_bytes(S, K, B, "rs_ag")


def test_choose_num_chains_ring_collectives():
    for collective in RING_COLLECTIVES:
        for topo, n in ((LINE8, 8), (MESH, 20)):
            k, rings = choose_num_chains(
                topo, 0, list(range(1, n)), 256 * KB, collective=collective,
            )
            assert 1 <= k <= 4 and n % k == 0 and len(rings) == k
            assert sorted(d for r in rings for d in r) == list(range(n))
            p = plan_ring_collective(collective, topo.num_nodes, rings)
            lat = program_latency(topo, 0, p, 256 * KB)
            ring1 = choose_num_chains(
                topo, 0, list(range(1, n)), 256 * KB,
                collective=collective, max_chains=1,
            )[1]
            p1 = plan_ring_collective(collective, topo.num_nodes, ring1)
            assert lat <= program_latency(topo, 0, p1, 256 * KB)
    with pytest.raises(ValueError):
        choose_num_chains(LINE8, 0, [1, 2], KB, collective="bogus")


def test_subset_ring_all_reduce_prices_by_ring_size():
    """Simulator-only subset rings (group ⊂ NoC nodes): the K=1 plan
    must shard by the RING size, not the node count — otherwise
    choose_num_chains underprices K=1 by num_nodes/S and always picks
    it (regression: plan_all_reduce's device-id addressing leaked
    addr_shards=num_devices into subset rings)."""
    big = MeshTopology(8, 8)  # 64 nodes, 8-member group
    ring = list(range(8))
    B = 1 << 20
    d = all_reduce_latency(big, 0, [ring], B, detail=True)
    assert d["wire_bytes"] == all_reduce_wire_bytes(8, 1, B)  # 2·7·B/8
    p = prg.plan_all_reduce(big.num_nodes, (tuple(ring),), "rs_ag")
    assert p.addr_shards == 8
    # and the subset-ring model stays comparable across K
    k2 = [[0, 1, 2, 3], [4, 5, 6, 7]]
    d2 = all_reduce_latency(big, 0, k2, B, detail=True)
    assert d2["wire_bytes"] == all_reduce_wire_bytes(4, 2, B)
    # full-axis rings keep the historical device-id schedule
    full = prg.plan_all_reduce(8, (tuple(range(8)),), "rs_ag")
    assert full.addr_shards == 8 and full.out_slots == 8


def test_pipelined_wire_bytes():
    """The frame-pipelined broadcast byte model: F + L - 2 scan slots,
    every chain edge applied per slot at 1/F frames (bench-pinned
    against the HLO parse in BENCH_collectives.json)."""
    B = 1 << 20
    single = prg.plan_broadcast(L, 0, (tuple(range(1, L)),))
    assert prg.pipelined_wire_bytes(single, B, 1) == single.wire_bytes(B)
    assert prg.pipelined_wire_bytes(single, B, 4) == 10 * (B // 4)
    multi = prg.plan_broadcast(L, 0, ((1, 2, 3), (4, 5, 6, 7)))
    # 2 permutes per slot (head fan-out), 4 + 4 - 1 slots
    assert prg.pipelined_wire_bytes(multi, B, 4) == 7 * 2 * (B // 4)


def test_describe_emits_step_table():
    p = prg.plan_all_reduce(L, RING_SETS[2], "rs_ag")
    lines = list(p.describe(64 * KB))
    assert len(lines) == p.num_steps + 2  # header + steps + total
    assert "all_reduce" in lines[0]
    assert "total wire bytes" in lines[-1]
