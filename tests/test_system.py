"""End-to-end system tests: the Trainer and Server drivers."""

from __future__ import annotations

import numpy as np
import pytest

from repro.launch.serve import Request, ServeConfig, Server
from repro.launch.train import TrainConfig, Trainer


def test_trainer_end_to_end(tmp_path):
    tc = TrainConfig(
        arch="yi-6b", smoke=True, steps=25, global_batch=4, seq_len=32,
        peak_lr=2e-3, warmup_steps=5, ckpt_dir=str(tmp_path / "ck"),
        ckpt_every=10, loss_chunks=2, log_every=100,
    )
    out = Trainer(tc).run()
    assert out["final_step"] == 25
    assert out["restarts"] == 0
    assert out["last_loss"] < out["first_loss"]
    assert np.isfinite(out["losses"]).all()


def test_trainer_survives_injected_failures(tmp_path):
    tc = TrainConfig(
        arch="yi-6b", smoke=True, steps=20, global_batch=4, seq_len=32,
        ckpt_dir=str(tmp_path / "ck"), ckpt_every=5, loss_chunks=2,
        fail_at=(7, 13), log_every=100,
    )
    out = Trainer(tc).run()
    assert out["final_step"] == 20
    assert out["restarts"] == 2


def test_trainer_torrent_collectives_single_device(tmp_path):
    """Torrent mode degenerates gracefully on a 1-device mesh."""
    tc = TrainConfig(
        arch="yi-6b", smoke=True, steps=6, global_batch=2, seq_len=16,
        collectives="torrent", ckpt_dir=str(tmp_path / "ck"),
        ckpt_every=100, loss_chunks=1, log_every=100,
    )
    out = Trainer(tc).run()
    assert out["final_step"] == 6
    assert np.isfinite(out["losses"]).all()


def test_microbatch_accumulation_matches_full_batch(tmp_path):
    """microbatches=2 gives the same grads as one full-batch step."""
    import dataclasses

    import jax
    import jax.numpy as jnp

    from repro import configs as C
    from repro.launch.steps import make_train_step
    from repro.optim import adamw

    from repro.models import transformer as T

    cfg = dataclasses.replace(
        C.get_smoke_config("yi-6b"), num_layers=2, d_model=32,
        num_heads=2, num_kv_heads=2, d_ff=64, vocab_size=64, head_dim=16,
    )
    params = T.model_init(jax.random.PRNGKey(0), cfg)
    opt_cfg = adamw.OptConfig(peak_lr=1e-3, warmup_steps=1, decay_steps=10)
    opt = adamw.init(params)
    batch = {
        "tokens": jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0, 64),
        "labels": jax.random.randint(jax.random.PRNGKey(2), (4, 16), 0, 64),
    }
    s1 = make_train_step(cfg, opt_cfg, loss_chunks=2, microbatches=1)
    s2 = make_train_step(cfg, opt_cfg, loss_chunks=2, microbatches=2)
    p1, _, m1 = jax.jit(s1)(params, opt, batch)
    p2, _, m2 = jax.jit(s2)(params, opt, batch)
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-4
    # Adam's rsqrt amplifies fp-order differences for near-zero grads;
    # post-update params match to ~2 lr units.
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32),
            atol=2.5e-3, rtol=2.5e-3,
        )


def test_server_continuous_batching():
    sc = ServeConfig(arch="yi-6b", smoke=True, batch=3, prompt_len=8,
                     max_seq=64)
    server = Server(sc)
    rng = np.random.default_rng(1)
    # more requests than slots -> exercises admission/recycling
    reqs = [
        server.submit(rng.integers(0, server.cfg.vocab_size, size=8), 6)
        for _ in range(7)
    ]
    out = server.run(reqs)
    assert all(r.done for r in reqs)
    assert all(len(r.out) >= 6 for r in reqs)
    assert out["generated_tokens"] >= 7 * 6
    # the weight multicast ChainTask ran and beat unicast
    wm = out["weight_multicast"]
    assert wm is not None and wm["speedup_vs_unicast"] > 1.0


def test_server_weight_refresh_is_full_tree_and_elastic():
    """ISSUE-5 elastic serving: broadcast_weights streams the WHOLE
    flattened parameter tree (logged bytes == the params' true nbytes),
    every replica receives it bit-exactly, and Server.scale_down
    re-forms the live MultiChainPlan (same object, no rebuild) so the
    survivors still get full weights after replica loss."""
    import jax

    sc = ServeConfig(arch="yi-6b", smoke=True, batch=2, prompt_len=8,
                     max_seq=48, replicas=6)
    server = Server(sc)
    flat, _ = jax.tree_util.tree_flatten(server.params)
    true_nbytes = sum(int(np.asarray(x).nbytes) for x in flat)
    payload = np.concatenate(
        [np.ascontiguousarray(x).reshape(-1).view(np.uint8) for x in flat]
    )

    rec = server.broadcast_weights(chunk_bytes=64 * 1024)
    assert rec["bytes"] == true_nbytes  # a REAL weight refresh
    assert rec["chunks"] == -(-true_nbytes // (64 * 1024))
    assert rec["speedup_vs_unicast"] > 1.0
    assert sorted(server.last_delivery) == [1, 2, 3, 4, 5]
    for buf in server.last_delivery.values():
        np.testing.assert_array_equal(buf, payload)

    plan_before = server.plan
    lost = server.scale_down(4)
    assert lost == (4, 5)
    assert server.plan is plan_before  # re-formed, never rebuilt
    assert sorted(server.plan.failed) == [4, 5]
    rec2 = server.broadcast_weights(chunk_bytes=64 * 1024)
    assert rec2["bytes"] == true_nbytes
    assert sorted(server.last_delivery) == [1, 2, 3]
    for buf in server.last_delivery.values():
        np.testing.assert_array_equal(buf, payload)  # still bit-exact
    with pytest.raises(ValueError):  # cannot drop the plan head
        server.scale_down(0)


def test_server_greedy_is_deterministic():
    sc = ServeConfig(arch="yi-6b", smoke=True, batch=2, prompt_len=8,
                     max_seq=48)
    rng = np.random.default_rng(2)
    prompt = rng.integers(0, 256, size=8)

    outs = []
    for _ in range(2):
        server = Server(sc)
        req = server.submit(prompt, 8)
        server.run([req])
        outs.append(list(req.out))
    assert outs[0] == outs[1]


def test_server_mid_decode_admission_keeps_inflight_output():
    """ISSUE-7 regression: admitting a request mid-decode used to
    re-prefill the WHOLE batch from truncated prompts, resetting the
    global position and dropping every in-flight request's generated
    context. With per-slot prefill + per-slot positions, an in-flight
    request's tokens are identical whether or not another request is
    admitted during its decode."""
    rng = np.random.default_rng(7)
    p1 = rng.integers(0, 256, size=8).astype(np.int32)
    p2 = rng.integers(0, 256, size=6).astype(np.int32)
    sc = ServeConfig(arch="yi-6b", smoke=True, batch=2, prompt_len=8,
                     max_seq=48)

    solo = Server(sc)
    r_solo = solo.submit(p1, 10)
    solo.run([r_solo])

    server = Server(sc)
    r1 = server.submit(p1, 10)
    r2 = server.submit(p2, 10, arrival=4)  # lands mid-decode of r1
    server.run([r1, r2])
    assert r2.t_admit is not None and r2.t_admit >= 4
    assert 0 < r2.t_admit < (r1.t_done or 99)  # genuinely mid-flight
    assert len(r2.out) == 10
    assert r_solo.out == r1.out  # in-flight output unchanged


def test_server_per_slot_positions_no_global_cutoff():
    """ISSUE-7 regression: the old ``pos >= max_seq - 1`` cutoff was
    global, killing a late-admitted request after fewer than max_new
    tokens. Positions are per-slot now: only the slot actually out of
    room finishes."""
    rng = np.random.default_rng(8)
    sc = ServeConfig(arch="yi-6b", smoke=True, batch=2, prompt_len=8,
                     max_seq=24)
    server = Server(sc)
    ra = server.submit(rng.integers(0, 256, size=8), 14)  # 8+14 = 22 < 24
    rb = server.submit(rng.integers(0, 256, size=8), 14, arrival=10)
    server.run([ra, rb])
    assert len(ra.out) == 14
    # admitted near ra's cutoff, still gets its full budget
    assert len(rb.out) == 14
    # a slot genuinely out of room finishes early — per-slot, not global
    server2 = Server(sc)
    rc = server2.submit(rng.integers(0, 256, size=8), 100)  # wants > room
    rd = server2.submit(rng.integers(0, 256, size=8), 4, arrival=2)
    server2.run([rc, rd])
    assert len(rc.out) == 24 - 8  # clamped by ITS OWN max_seq room
    assert len(rd.out) == 4  # neighbor unaffected


def test_server_submit_rejects_overlong_prompt():
    """ISSUE-7 regression: prompts longer than the admission window are
    rejected at submit time, never silently truncated into a different
    prompt; prompts at exactly the window still serve."""
    rng = np.random.default_rng(3)
    sc = ServeConfig(arch="yi-6b", smoke=True, batch=2, prompt_len=8,
                     max_seq=48)
    server = Server(sc)
    with pytest.raises(ValueError, match="refusing to truncate"):
        server.submit(rng.integers(0, 256, size=9), 4)
    with pytest.raises(ValueError, match="empty"):
        server.submit(np.zeros(0, np.int32), 4)
    req = server.submit(rng.integers(0, 256, size=8), 4)  # at the limit
    out = server.run([req])
    assert req.done and len(req.out) == 4
    assert out["served"] == 1


def test_server_single_replica_broadcast_is_noop_record():
    """ISSUE-7 regression: ``broadcast_weights`` with no destinations
    (replicas=1, or scaled down to one survivor) used to log the full
    payload bytes while delivering nothing. It now records a distinct
    no-op: 0 chunks, 0 delivered bytes."""
    sc = ServeConfig(arch="yi-6b", smoke=True, batch=2, prompt_len=8,
                     max_seq=48, replicas=1)
    server = Server(sc)
    rec = server.broadcast_weights()
    assert rec["noop"] is True
    assert rec["chunks"] == 0 and rec["delivered_bytes"] == 0
    assert rec["bytes"] == 0 and rec["replicas"] == 1
    assert server.last_delivery == {}

    # same no-op after scaling a real replica set down to the head only
    sc2 = ServeConfig(arch="yi-6b", smoke=True, batch=2, prompt_len=8,
                      max_seq=48, replicas=3)
    server2 = Server(sc2)
    rec_full = server2.broadcast_weights()
    assert rec_full.get("noop") is None and rec_full["delivered_bytes"] > 0
    assert server2.scale_down(1) == (1, 2)
    rec2 = server2.broadcast_weights()
    assert rec2["noop"] is True and rec2["delivered_bytes"] == 0
    assert server2.last_delivery == {}


def test_server_scale_down_then_readmission_traffic():
    """ISSUE-7: after replica loss the re-formed plan still streams full
    weights byte-exactly to every survivor AND the serving loop keeps
    admitting/recycling requests (continuous batching survives the
    scale-down)."""
    import jax

    sc = ServeConfig(arch="yi-6b", smoke=True, batch=2, prompt_len=8,
                     max_seq=48, replicas=5)
    server = Server(sc)
    flat, _ = jax.tree_util.tree_flatten(server.params)
    payload = np.concatenate(
        [np.ascontiguousarray(x).reshape(-1).view(np.uint8) for x in flat]
    )
    assert server.scale_down(3) == (3, 4)
    rec = server.broadcast_weights(chunk_bytes=64 * 1024)
    assert rec["delivered_bytes"] == 2 * payload.nbytes
    assert sorted(server.last_delivery) == [1, 2]
    for buf in server.last_delivery.values():
        np.testing.assert_array_equal(buf, payload)  # byte-exact survivors

    rng = np.random.default_rng(4)
    reqs = [
        server.submit(rng.integers(0, 256, size=8), 5, arrival=i)
        for i in range(5)  # > batch -> admission + slot recycling
    ]
    out = server.run(reqs)
    assert out["served"] == 5
    assert all(r.done and len(r.out) == 5 for r in reqs)
