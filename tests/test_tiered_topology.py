"""Tiered link-graph topology: spec parsing, pod structure, CC-exact
uniform reduction, and hierarchical all-reduce as a planning OUTCOME."""

from __future__ import annotations

import pytest

from repro.core.program import tier_crossing_stats
from repro.core.scheduling import (
    chain_slow_links,
    chain_tier_crossings,
    chain_total_cost,
    chain_total_hops,
    partition_schedule,
    partition_tier_crossings,
)
from repro.core.simulator import (
    all_reduce_latency,
    choose_num_chains,
    multi_chain_latency,
    plan_ring_collective,
    program_latency,
)
from repro.core.topology import (
    MeshTopology,
    TieredMeshTopology,
    parse_topology_spec,
)

P4 = TieredMeshTopology.from_pods(4, 4, 4, interpod_bw=0.25, interpod_latency=4)


# ---------------------------------------------------------------------------
# spec parsing / construction
# ---------------------------------------------------------------------------


def test_from_pods_shape_and_pod_grid():
    assert (P4.nx, P4.ny) == (8, 8)
    assert (P4.pods_x, P4.pods_y) == (2, 2)
    assert P4.num_pods == 4
    assert (P4.pod_nx, P4.pod_ny) == (4, 4)


def test_pod_of_corners_and_members():
    # row-major pod ids over the 2x2 pod grid
    assert P4.pod_of(P4.node_id((0, 0))) == 0
    assert P4.pod_of(P4.node_id((7, 0))) == 1
    assert P4.pod_of(P4.node_id((0, 7))) == 2
    assert P4.pod_of(P4.node_id((7, 7))) == 3
    members = [P4.pod_members(p) for p in range(4)]
    assert sorted(m for ms in members for m in ms) == list(range(64))
    for p, ms in enumerate(members):
        assert all(P4.pod_of(m) == p for m in ms)


def test_link_attrs_tier_only_on_pod_boundary():
    intra = P4.link_attrs(((0, 0), (1, 0)))
    cross = P4.link_attrs(((3, 0), (4, 0)))
    assert intra.tier == 0 and intra.bandwidth == 1.0 and intra.latency == 1
    assert cross.tier == 1 and cross.bandwidth == 0.25 and cross.latency == 4


@pytest.mark.parametrize("spec,topo", [
    ("8x8", MeshTopology(8, 8)),
    ("8x8:torus", MeshTopology(8, 8, torus=True)),
    ("pods=4x(4x4):interpod_bw=0.25", P4),
    ("8x8:pods=2x2:interpod_bw=0.25:interpod_lat=4", P4),
])
def test_parse_topology_spec(spec, topo):
    assert parse_topology_spec(spec) == topo


def test_spec_round_trips():
    for t in (
        MeshTopology(8, 8),
        MeshTopology(4, 2, torus=True),
        P4,
        TieredMeshTopology.from_pods(2, 4, 4, interpod_bw=0.5,
                                     interpod_latency=2),
    ):
        assert parse_topology_spec(t.spec()) == t


def test_relative_pods_spec_needs_num_nodes():
    t = parse_topology_spec("pods=4", num_nodes=16)
    assert isinstance(t, TieredMeshTopology)
    assert (t.nx, t.ny, t.num_pods) == (16, 1, 4)
    with pytest.raises(ValueError):
        parse_topology_spec("pods=4")


def test_parse_rejects_bad_specs():
    for bad in ("", "8x", "8x8:pods=3x3", "interpod_bw=0.5",
                "8x8:wat=1", "pods=4x(4x4):pods=2"):
        with pytest.raises(ValueError):
            parse_topology_spec(bad)


def test_tiered_validation():
    with pytest.raises(ValueError):
        TieredMeshTopology(8, 8, pods_x=3)  # 3 does not divide 8
    with pytest.raises(ValueError):
        TieredMeshTopology(8, 8, pods_x=2, interpod_bw=0.0)
    with pytest.raises(ValueError):
        TieredMeshTopology(8, 8, pods_x=2, interpod_latency=0)


# ---------------------------------------------------------------------------
# CC-exact uniform reduction: neutral tiering weighs exactly like the mesh
# ---------------------------------------------------------------------------


def test_neutral_tiering_prices_cc_exactly():
    # tiering with unit weights changes WHICH plan is preferred (the
    # planner still avoids tier crossings) but never what a given plan
    # COSTS: every latency term reduces to the uniform-mesh model
    flat = MeshTopology(8, 8)
    neutral = TieredMeshTopology(8, 8, pods_x=2, pods_y=2,
                                 interpod_bw=1.0, interpod_latency=1)
    dests = list(range(1, 17))
    payload = 1 << 16
    for a in range(64):
        assert neutral.weighted_distance(0, a) == flat.distance(0, a)
        assert neutral.path_min_bw(0, a) == 1.0
    for k in (1, 2, 4):
        cf = partition_schedule(flat, dests, 0, num_chains=k)
        assert multi_chain_latency(flat, 0, cf, payload) == \
            multi_chain_latency(neutral, 0, cf, payload)
    rings = ((0, 1, 2, 3), (4, 5, 6, 7))
    assert all_reduce_latency(flat, 0, rings, payload) == \
        all_reduce_latency(neutral, 0, rings, payload)


def test_uniform_weighted_accessors_match_hops():
    topo = MeshTopology(8, 8)
    order = [5, 9, 3, 17]
    assert chain_total_cost(topo, order) == chain_total_hops(topo, order)
    assert chain_slow_links(topo, order) == 0
    assert chain_tier_crossings(topo, order) == 0


# ---------------------------------------------------------------------------
# tier-aware planning outcomes
# ---------------------------------------------------------------------------


def test_pod_partition_crosses_interpod_exactly_once_per_remote_chain():
    # the acceptance pin: K=#pods chains from a pod-0 source cross the
    # slow boundary exactly once each (never for the home-pod chain)
    chains = partition_schedule(P4, list(range(1, 64)), 0, num_chains=4)
    crossings = partition_tier_crossings(P4, chains, 0)
    assert sorted(crossings) == [0, 1, 1, 1], crossings
    # and each chain stays inside one pod
    for c in chains:
        assert len({P4.pod_of(m) for m in c}) == 1


def test_hierarchical_all_reduce_emerges():
    payload = 1 << 20
    dests = list(range(1, 64))
    aware = choose_num_chains(
        P4, 0, dests, payload, max_chains=4,
        collective="all_reduce", algo="rs_ag", detail=True,
    )
    # one sub-ring per pod
    assert aware["num_chains"] == 4
    pods = [sorted({P4.pod_of(m) for m in r}) for r in aware["rings"]]
    assert sorted(p for ps in pods for p in ps) == [0, 1, 2, 3]
    assert all(len(ps) == 1 for ps in pods)
    # strictly below the tier-blind plan priced on the same links
    flat = MeshTopology(8, 8)
    _, blind_rings = choose_num_chains(
        flat, 0, dests, payload, max_chains=4,
        collective="all_reduce", algo="rs_ag",
    )
    blind_cc = all_reduce_latency(P4, 0, blind_rings, payload)
    assert aware["latency_cc"] < blind_cc, (aware["latency_cc"], blind_cc)


def test_tier_aware_choice_never_slower_than_blind():
    # the blind candidate set is a subset of the aware one, so this
    # holds by construction for every K cap
    payload = 1 << 18
    dests = list(range(1, 64))
    flat = MeshTopology(8, 8)
    for mk in (1, 2, 4):
        aware = choose_num_chains(
            P4, 0, dests, payload, max_chains=mk,
            collective="all_reduce", algo="rs_ag", detail=True,
        )
        _, blind_rings = choose_num_chains(
            flat, 0, dests, payload, max_chains=mk,
            collective="all_reduce", algo="rs_ag",
        )
        blind_cc = all_reduce_latency(P4, 0, blind_rings, payload)
        assert aware["latency_cc"] <= blind_cc, (mk, aware, blind_cc)


def test_tier_crossing_stats_structure():
    dests = list(range(1, 64))
    _, rings = choose_num_chains(
        P4, 0, dests, 1 << 20, max_chains=4,
        collective="all_reduce", algo="rs_ag",
    )
    program = plan_ring_collective("all_reduce", 64, rings)
    stats = tier_crossing_stats(program, P4)
    # pod-aligned rings: intra-ring routes never cross; only the K-1
    # cross-ring exchange steps touch inter-pod links
    assert stats["per_group"] == [0, 0, 0, 0]
    assert stats["crossing_steps"] == 3
    assert len(stats["per_step"]) == len(program.steps)
    assert stats["total"] == 0  # group routes only (steps counted above)
    # the program still prices finitely on the tiered graph
    assert program_latency(P4, 0, program, 1 << 20) > 0


def test_stepped_program_step_structure_on_pods():
    # rs_ag over 4 pod rings of 16: 2*(S-1) intra steps with zero
    # crossing edges + (K-1) cross steps that do cross
    dests = list(range(1, 64))
    _, rings = choose_num_chains(
        P4, 0, dests, 1 << 20, max_chains=4,
        collective="all_reduce", algo="rs_ag",
    )
    program = plan_ring_collective("all_reduce", 64, rings)
    stats = tier_crossing_stats(program, P4)
    crossing = [n > 0 for n in stats["per_step"]]
    assert sum(crossing) == 3
    assert len(crossing) == 2 * (16 - 1) + (4 - 1)
