"""build_cell / input_specs / hints: the dry-run path on tiny meshes.

The full 512-device dry-run runs via ``python -m repro.launch.dryrun``;
here we verify the same machinery lowers + compiles for every arch on a
1-device mesh with smoke configs (fast), plus spec plumbing units.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro import configs as C
from repro.configs.shapes import SHAPES, Shape, applicable, input_specs
from repro.launch.mesh import make_host_mesh
from repro.launch.steps import _sanitize, build_cell
from repro.parallel.hints import maybe_shard, resolve_spec


SMOKE_SHAPES = {
    "train": Shape("train_smoke", "train", 32, 4),
    "prefill": Shape("prefill_smoke", "prefill", 32, 2),
    "decode": Shape("decode_smoke", "decode", 64, 4),
}


@pytest.fixture(scope="module")
def host_mesh():
    return make_host_mesh(model=1)


@pytest.mark.parametrize("arch", C.ARCHS)
@pytest.mark.parametrize("kind", ["train", "prefill", "decode"])
def test_smoke_cell_lowers_and_compiles(arch, kind, host_mesh, monkeypatch):
    shape = SMOKE_SHAPES[kind]
    monkeypatch.setitem(C.SHAPES, shape.name, shape)
    cell = build_cell(arch, shape.name, host_mesh, smoke=True)
    compiled = cell.lower().compile()
    assert compiled.cost_analysis() is not None


def test_num_chains_variant_plumbs_to_train_step(host_mesh, monkeypatch):
    """The 'k2' VARIANTS bundle (and the build_cell kwarg) route
    num_chains to the torrent grad reduction without touching the model
    config — sweepable next to collectives=."""
    from repro.launch.steps import VARIANTS

    shape = SMOKE_SHAPES["train"]
    monkeypatch.setitem(C.SHAPES, shape.name, shape)
    assert VARIANTS["k2"] == {"num_chains": 2}
    cell = build_cell(
        "llama3-8b", shape.name, host_mesh, smoke=True,
        collectives="torrent", variant="k2",
    )
    # num_chains is a step-builder knob, not a ModelConfig field
    assert VARIANTS["k2"] == {"num_chains": 2}  # not mutated by the pop
    assert cell.cfg == C.get_smoke_config("llama3-8b")
    assert cell.ar_algo == "rs_ag"  # the bandwidth-optimal default
    compiled = cell.lower().compile()
    assert compiled.cost_analysis() is not None


def test_ar_algo_and_auto_variants_plumb_to_train_step(host_mesh, monkeypatch):
    """'k2-rot' (rotation schedule) and 'k-auto' (model-picked K) are
    step-builder knobs like num_chains: resolved by build_cell, never
    ModelConfig fields, and the cells still lower + compile."""
    from repro.launch.steps import VARIANTS

    shape = SMOKE_SHAPES["train"]
    monkeypatch.setitem(C.SHAPES, shape.name, shape)
    assert VARIANTS["k2-rot"] == {"num_chains": 2, "ar_algo": "rotation"}
    assert VARIANTS["k-auto"] == {"num_chains": "auto"}

    cell = build_cell(
        "llama3-8b", shape.name, host_mesh, smoke=True,
        collectives="torrent", variant="k2-rot",
    )
    assert VARIANTS["k2-rot"] == {"num_chains": 2, "ar_algo": "rotation"}
    assert cell.cfg == C.get_smoke_config("llama3-8b")
    assert (cell.num_chains, cell.ar_algo) == (2, "rotation")
    assert cell.lower().compile().cost_analysis() is not None

    cell = build_cell(
        "llama3-8b", shape.name, host_mesh, smoke=True,
        collectives="torrent", variant="k-auto",
    )
    assert cell.num_chains == "auto"
    assert cell.lower().compile().cost_analysis() is not None

    # conflicting explicit knobs are rejected (ar_algo="rs_ag" is the
    # default and therefore never conflicts; a variant pinning rs_ag
    # conflicts with an explicit rotation)
    monkeypatch.setitem(VARIANTS, "pin-rsag", {"ar_algo": "rs_ag"})
    with pytest.raises(ValueError):
        build_cell(
            "llama3-8b", shape.name, host_mesh, smoke=True,
            collectives="torrent", variant="pin-rsag", ar_algo="rotation",
        )
    with pytest.raises(ValueError):
        build_cell(
            "llama3-8b", shape.name, host_mesh, smoke=True,
            collectives="torrent", variant="k2", num_chains=4,
        )


def test_tiered_variant_plumbs_topology_to_train_step(host_mesh, monkeypatch):
    """The 'tiered' VARIANTS bundle routes a link-graph spec (and
    auto-K) to the torrent grad reduction; the spec is advisory per
    axis, so the same bundle compiles on any mesh. Topology without
    torrent collectives is rejected (the XLA path cannot honour it)."""
    from repro.launch.steps import VARIANTS

    shape = SMOKE_SHAPES["train"]
    monkeypatch.setitem(C.SHAPES, shape.name, shape)
    assert VARIANTS["tiered"] == {
        "topology": "pods=2:interpod_bw=0.25", "num_chains": "auto",
    }
    cell = build_cell(
        "llama3-8b", shape.name, host_mesh, smoke=True,
        collectives="torrent", variant="tiered",
    )
    assert cell.topology == "pods=2:interpod_bw=0.25"
    assert cell.num_chains == "auto"
    assert cell.cfg == C.get_smoke_config("llama3-8b")
    assert cell.lower().compile().cost_analysis() is not None

    with pytest.raises(ValueError):
        build_cell(
            "llama3-8b", shape.name, host_mesh, smoke=True,
            collectives="xla", variant="tiered",
        )
    with pytest.raises(ValueError):
        build_cell(
            "llama3-8b", shape.name, host_mesh, smoke=True,
            collectives="torrent", variant="tiered", topology="pods=4",
        )


def test_moe_ep_variant_plumbs_and_compiles(host_mesh, monkeypatch):
    """The 'moe-ep' VARIANTS bundle is a ModelConfig override (the
    Torrent expert-parallel dispatch knob) that still lowers + compiles
    — on a dp=1 mesh the EP path degenerates gracefully (single-member
    exchange / flat fallback)."""
    from repro.launch.steps import VARIANTS

    shape = SMOKE_SHAPES["train"]
    monkeypatch.setitem(C.SHAPES, shape.name, shape)
    assert VARIANTS["moe-ep"] == {"moe_ep_dispatch": True}
    assert VARIANTS["moe-ep-k2"] == {
        "moe_ep_dispatch": True, "moe_ep_chains": 2}
    cell = build_cell(
        "deepseek-moe-16b", shape.name, host_mesh, smoke=True,
        collectives="torrent", variant="moe-ep",
    )
    assert cell.cfg.moe_ep_dispatch
    compiled = cell.lower().compile()
    assert compiled.cost_analysis() is not None


def test_dryrun_cell_suffix_and_num_chains_parse():
    """--num-chains accepts ints or 'auto'; the output-file suffix
    encodes the algo and K knobs so sweeps never collide."""
    import argparse

    from repro.launch.dryrun import _cell_suffix, _parse_num_chains

    assert _parse_num_chains("2") == 2
    assert _parse_num_chains("auto") == "auto"
    with pytest.raises(argparse.ArgumentTypeError):
        _parse_num_chains("0")

    def ns(**kw):
        base = dict(collectives="xla", num_chains=1, ar_algo="rs_ag",
                    variant="baseline", remat="dots", compress_grads=False)
        base.update(kw)
        return argparse.Namespace(**base)

    assert _cell_suffix(ns()) == ""
    assert _cell_suffix(ns(collectives="torrent", num_chains=2)) == "__torrent__k2"
    assert _cell_suffix(
        ns(collectives="torrent", num_chains="auto", ar_algo="rotation")
    ) == "__torrent__kauto__rotation"
    assert _cell_suffix(
        ns(collectives="torrent", compress_grads=True)
    ) == "__torrent__int8"


def test_applicability_matrix():
    runs = {(a, s) for a in C.ARCHS for s in SHAPES if applicable(a, s)[0]}
    # long_500k only for sub-quadratic archs
    assert ("mamba2-2.7b", "long_500k") in runs
    assert ("jamba-v0.1-52b", "long_500k") in runs
    assert ("h2o-danube-1.8b", "long_500k") in runs
    assert ("llama3-8b", "long_500k") not in runs
    assert ("whisper-tiny", "long_500k") not in runs
    # everything else runs everywhere
    assert ("llama3-8b", "train_4k") in runs
    assert len(runs) == 10 * 4 - 7  # 7 long_500k skips


def test_input_specs_shapes():
    cfg = C.get_config("llama3-8b")
    s = input_specs(cfg, SHAPES["train_4k"])
    assert s["batch"]["tokens"].shape == (256, 4096)
    assert s["batch"]["labels"].dtype == jnp.int32

    s = input_specs(cfg, SHAPES["decode_32k"])
    assert s["tokens"].shape == (128,)
    cache_leaves = jax.tree.leaves(s["cache"])
    assert any(l.shape[-3:-2] == (32768,) or 32768 in l.shape for l in cache_leaves)

    vlm = C.get_config("qwen2-vl-7b")
    s = input_specs(vlm, SHAPES["prefill_32k"])
    assert s["batch"]["embeds"].shape == (32, 32768, vlm.d_model)
    assert s["batch"]["positions"].shape == (3, 32, 32768)

    wt = C.get_config("whisper-tiny")
    s = input_specs(wt, SHAPES["train_4k"])
    assert s["batch"]["enc_frames"].shape == (256, 1500, 384)


def test_sanitize_drops_missing_axes():
    mesh = make_host_mesh(model=1)  # axes: data, model
    assert _sanitize(P(("pod", "data"), None), mesh) == P("data", None)
    assert _sanitize(P("pod"), mesh) == P(None)
    assert _sanitize(None, mesh) == P()
    assert _sanitize(P(None, "model"), mesh) == P(None, "model")


def test_maybe_shard_no_mesh_noop():
    x = jnp.ones((4, 4))
    assert maybe_shard(x, ("pod", "data"), None) is x
    assert resolve_spec("model") is None
