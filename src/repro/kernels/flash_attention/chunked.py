"""Chunked (online-softmax) attention in pure JAX — the lowerable twin
of the Pallas flash kernel.

The Pallas kernel is the TPU execution path; this ``lax.scan`` over KV
chunks is semantically identical, runs/lowers on every backend (the
512-device dry-run can't lower Mosaic), and has the same O(S·chunk)
memory profile, so roofline terms derived from it transfer to the
kernel. Supports GQA (grouped heads without materializing repeated
K/V), causal masking and sliding windows.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax

NEG_INF = -1e30


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "scale", "chunk")
)
def attention_chunked(
    q: jax.Array,  # (B, H, S, D)
    k: jax.Array,  # (B, Hkv, S, D)
    v: jax.Array,  # (B, Hkv, S, D)
    *,
    causal: bool = True,
    window: int | None = None,
    scale: float | None = None,
    chunk: int = 1024,
) -> jax.Array:
    B, H, S, D = q.shape
    Hkv = k.shape[1]
    assert H % Hkv == 0, (H, Hkv)
    group = H // Hkv
    scale = D ** -0.5 if scale is None else scale

    C = min(chunk, S)
    pad = (-S) % C
    Sk = S + pad
    nc = Sk // C
    if pad:  # pad K/V with masked-out slots
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))

    qg = (q.astype(jnp.float32) * scale).reshape(B, Hkv, group, S, D)
    kc = jnp.moveaxis(k.reshape(B, Hkv, nc, C, D), 2, 0)  # (nc,B,Hkv,C,D)
    vc = jnp.moveaxis(v.reshape(B, Hkv, nc, C, D), 2, 0)
    starts = jnp.arange(nc) * C
    rows = jnp.arange(S)[:, None]  # (S, 1)

    def body(carry, xs):
        m, l, acc = carry
        kb, vb, start = xs
        s = jnp.einsum(
            "bhgsd,bhcd->bhgsc", qg, kb.astype(jnp.float32)
        )  # (B,Hkv,g,S,C)
        cols = start + jnp.arange(C)[None, :]  # (1, C)
        mask = cols < S  # padding
        if causal:
            mask = mask & (cols <= rows)
        if window is not None:
            mask = mask & (cols > rows - window)
        s = jnp.where(mask[None, None, None], s, NEG_INF)
        m_cur = s.max(-1, keepdims=True)
        m_new = jnp.maximum(m, m_cur)
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m - m_new)
        l_new = alpha * l + p.sum(-1, keepdims=True)
        acc_new = acc * alpha + jnp.einsum(
            "bhgsc,bhcd->bhgsd", p, vb.astype(jnp.float32)
        )
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, Hkv, group, S, 1), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Hkv, group, S, 1), jnp.float32)
    acc0 = jnp.zeros((B, Hkv, group, S, D), jnp.float32)
    (m, l, acc), _ = lax.scan(body, (m0, l0, acc0), (kc, vc, starts))
    l = jnp.where(l == 0.0, 1.0, l)  # fully-masked rows -> 0
    out = (acc / l).reshape(B, H, S, D)
    return out.astype(q.dtype)
