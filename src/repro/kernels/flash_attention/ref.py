"""Pure-jnp oracle for blockwise (flash) attention.

Semantics: softmax(Q K^T * scale + mask) V with optional causal masking
and sliding-window attention (SWA, window counts how many past tokens a
query may attend to, inclusive of itself). GQA: K/V have ``num_kv_heads``
heads; query head h attends to kv head ``h // (H // H_kv)``.
"""

from __future__ import annotations

import jax.numpy as jnp

NEG_INF = -1e30


def attention_ref(
    q: jnp.ndarray,  # (B, H, S, D)
    k: jnp.ndarray,  # (B, Hkv, S, D)
    v: jnp.ndarray,  # (B, Hkv, S, D)
    *,
    causal: bool = True,
    window: int | None = None,
    scale: float | None = None,
) -> jnp.ndarray:
    B, H, S, D = q.shape
    Hkv = k.shape[1]
    assert H % Hkv == 0, (H, Hkv)
    group = H // Hkv
    scale = D ** -0.5 if scale is None else scale
    kx = jnp.repeat(k, group, axis=1)
    vx = jnp.repeat(v, group, axis=1)
    s = jnp.einsum(
        "bhqd,bhkd->bhqk", q.astype(jnp.float32), kx.astype(jnp.float32)
    ) * scale
    rows = jnp.arange(S)[:, None]
    cols = jnp.arange(S)[None, :]
    mask = jnp.ones((S, S), bool)
    if causal:
        mask &= cols <= rows
    if window is not None:
        mask &= cols > rows - window
    s = jnp.where(mask, s, NEG_INF)
    p = jnp.exp(s - s.max(-1, keepdims=True))
    p = p / p.sum(-1, keepdims=True)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, vx.astype(jnp.float32))
    return out.astype(q.dtype)
