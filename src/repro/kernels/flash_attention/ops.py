"""Public jit'd entry points for the flash-attention kernel."""

from __future__ import annotations

import jax

from .kernel import flash_attention_pallas
from .ref import attention_ref

__all__ = ["flash_attention", "attention_ref"]


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int | None = None,
    scale: float | None = None,
    block_q: int = 512,
    block_k: int = 512,
    interpret: bool | None = None,
) -> jax.Array:
    """Blockwise attention, (B, H, S, D) x (B, Hkv, S, D)^2 -> (B, H, S, D).

    Pallas kernel on TPU; ``interpret=True`` (Python emulation) on CPU.
    """
    if interpret is None:
        interpret = not _on_tpu()
    return flash_attention_pallas(
        q, k, v,
        causal=causal, window=window, scale=scale,
        block_q=block_q, block_k=block_k, interpret=interpret,
    )
