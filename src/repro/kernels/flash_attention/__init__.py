from .ops import attention_ref, flash_attention
