"""Pallas TPU kernel: blockwise (flash) attention with online softmax.

Grid is ``(B*H, num_q_blocks, num_kv_blocks)``; the kv dimension is the
innermost (fastest-iterating) grid axis, so the f32 accumulator, running
max and running sum live in VMEM scratch and persist across kv steps —
the canonical TPU flash-attention schedule. Q/K/V blocks are staged via
BlockSpec into VMEM; GQA is handled in the K/V index maps (query head h
reads kv head ``h // group``), so K/V are never materialized per-head.

VMEM working set per step: q(block_q×D) + k,v(block_k×D) + acc — with
the default 512/512 blocks and D=128 at bf16 that is < 1 MiB.

Masking supports causal and sliding-window (SWA); fully-masked kv blocks
are skipped via ``pl.when`` so SWA cost scales with the window, not the
sequence.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(
    q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref,
    *, scale: float, causal: bool, window: int | None,
    block_q: int, block_k: int, num_kv_blocks: int,
):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_start = qi * block_q
    k_start = ki * block_k

    # Block-level relevance: skip kv blocks that are entirely masked.
    relevant = jnp.bool_(True)
    if causal:
        relevant &= k_start <= q_start + block_q - 1
    if window is not None:
        # newest query row may look back `window-1`; block relevant if
        # its newest column >= oldest allowed column of oldest query.
        relevant &= k_start + block_k - 1 > q_start - window

    @pl.when(relevant)
    def _step():
        q = q_ref[0].astype(jnp.float32)  # (block_q, D)
        k = k_ref[0].astype(jnp.float32)  # (block_k, D)
        v = v_ref[0].astype(jnp.float32)  # (block_k, D)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale  # (block_q, block_k)

        rows = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        cols = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = jnp.ones_like(s, dtype=jnp.bool_)
        if causal:
            mask &= cols <= rows
        if window is not None:
            mask &= cols > rows - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]  # (block_q, 1)
        l_prev = l_ref[...]
        m_cur = s.max(-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        l_new = alpha * l_prev + p.sum(-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_ref[...] = m_new
        l_ref[...] = l_new

    @pl.when(ki == num_kv_blocks - 1)
    def _finish():
        l = l_ref[...]
        l = jnp.where(l == 0.0, 1.0, l)  # fully-masked rows -> 0 output
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=(
        "causal", "window", "scale", "block_q", "block_k", "interpret"
    ),
)
def flash_attention_pallas(
    q: jax.Array,  # (B, H, S, D)
    k: jax.Array,  # (B, Hkv, S, D)
    v: jax.Array,  # (B, Hkv, S, D)
    *,
    causal: bool = True,
    window: int | None = None,
    scale: float | None = None,
    block_q: int = 512,
    block_k: int = 512,
    interpret: bool = False,
) -> jax.Array:
    B, H, S, D = q.shape
    Hkv = k.shape[1]
    assert H % Hkv == 0, (H, Hkv)
    group = H // Hkv
    scale = float(D ** -0.5) if scale is None else float(scale)
    block_q = min(block_q, S)
    block_k = min(block_k, S)
    if S % block_q or S % block_k:
        raise ValueError(f"seq {S} must be divisible by blocks {block_q}/{block_k}")
    num_q, num_kv = S // block_q, S // block_k

    qr = q.reshape(B * H, S, D)
    kr = k.reshape(B * Hkv, S, D)
    vr = v.reshape(B * Hkv, S, D)

    def q_map(bh, qi, ki):
        return (bh, qi, 0)

    def kv_map(bh, qi, ki):
        b, h = bh // H, bh % H
        return (b * Hkv + h // group, ki, 0)

    kernel = functools.partial(
        _flash_kernel,
        scale=scale, causal=causal, window=window,
        block_q=block_q, block_k=block_k, num_kv_blocks=num_kv,
    )
    out = pl.pallas_call(
        kernel,
        grid=(B * H, num_q, num_kv),
        in_specs=[
            pl.BlockSpec((1, block_q, D), q_map),
            pl.BlockSpec((1, block_k, D), kv_map),
            pl.BlockSpec((1, block_k, D), kv_map),
        ],
        out_specs=pl.BlockSpec((1, block_q, D), q_map),
        out_shape=jax.ShapeDtypeStruct((B * H, S, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, D), jnp.float32),  # acc
            pltpu.VMEM((block_q, 1), jnp.float32),  # running max m
            pltpu.VMEM((block_q, 1), jnp.float32),  # running sum l
        ],
        interpret=interpret,
    )(qr, kr, vr)
    return out.reshape(B, H, S, D)
