"""Pallas TPU kernels for the compute hot spots (validated in
interpret mode on CPU; Mosaic-compiled on TPU):

* :mod:`.relayout`        — DSE blocked-layout transform (paper P1/P2).
* :mod:`.flash_attention` — blockwise attention (prefill hot spot),
  causal + sliding-window, GQA via index maps.
"""
