"""Pure-jnp oracle for the relayout (DSE layout-transform) kernel.

A *blocked layout* ``(bm, bn)`` stores an (M, N) matrix as the 4-D array
``(M//bm, N//bn, bm, bn)`` — the paper's ``MNM16N8`` notation is block
height 16 × block width 8 (elements within a block are row-major, blocks
are row-major over the block grid). The DSE's ND-affine access engine
converts between such layouts; this oracle defines the semantics the
Pallas kernel must match.
"""

from __future__ import annotations

import re

import jax.numpy as jnp

_LAYOUT_RE = re.compile(r"^MNM(\d+)N(\d+)$")


def parse_layout(layout: str) -> tuple[int, int]:
    """Parse the paper's layout string, e.g. ``"MNM16N8"`` -> (16, 8)."""
    m = _LAYOUT_RE.match(layout)
    if not m:
        raise ValueError(f"unrecognized layout string: {layout!r}")
    return int(m.group(1)), int(m.group(2))


def blocked_to_dense(x: jnp.ndarray, shape: tuple[int, int]) -> jnp.ndarray:
    """(M//bm, N//bn, bm, bn) blocked -> (M, N) dense."""
    mb, nb, bm, bn = x.shape
    M, N = shape
    assert mb * bm == M and nb * bn == N, (x.shape, shape)
    return x.transpose(0, 2, 1, 3).reshape(M, N)


def dense_to_blocked(x: jnp.ndarray, block: tuple[int, int]) -> jnp.ndarray:
    """(M, N) dense -> (M//bm, N//bn, bm, bn) blocked."""
    M, N = x.shape
    bm, bn = block
    assert M % bm == 0 and N % bn == 0, (x.shape, block)
    return x.reshape(M // bm, bm, N // bn, bn).transpose(0, 2, 1, 3)


def relayout_ref(
    x: jnp.ndarray,
    shape: tuple[int, int],
    src_block: tuple[int, int],
    dst_block: tuple[int, int],
) -> jnp.ndarray:
    """Oracle: blocked(src) -> dense -> blocked(dst)."""
    return dense_to_blocked(blocked_to_dense(x, shape), dst_block)
