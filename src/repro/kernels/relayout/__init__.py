from .ops import (
    blocked_to_dense,
    dense_to_blocked,
    parse_layout,
    relayout,
    relayout_ref,
    relayout_str,
)
