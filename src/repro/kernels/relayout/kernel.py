"""Pallas TPU kernel: blocked-layout transform (the Torrent DSE).

The paper's Data Streaming Engine performs ND-affine reads so a matrix
can leave the source memory already in the destination layout (the P1/P2
workloads transform ``MNM16N8 -> MNM8N8`` on the fly). On TPU the same
job is an HBM->VMEM->HBM tiled relayout: each grid step stages one
*super-tile* — ``lcm`` of the two block heights × ``lcm`` of the two
block widths, padded up to MXU/VPU-friendly multiples — in VMEM,
re-tiles it with registers only (transpose/reshape), and writes it back
in the destination blocking.

VMEM budget: one super-tile in, one out. With the default 256×256 f32
super-tile that is 2 × 256 KiB, well inside the ~16 MiB/core VMEM.
"""

from __future__ import annotations

import functools
import math

import jax
from jax.experimental import pallas as pl


def _supertile(src_block: tuple[int, int], dst_block: tuple[int, int],
               shape: tuple[int, int], target: int = 256) -> tuple[int, int]:
    """Smallest VMEM super-tile compatible with both blockings, scaled
    up toward ``target`` (sublane/lane-aligned) when it divides shape."""
    lm = math.lcm(src_block[0], dst_block[0])
    ln = math.lcm(src_block[1], dst_block[1])
    M, N = shape
    tm, tn = lm, ln
    while tm * 2 <= min(target, M) and M % (tm * 2) == 0:
        tm *= 2
    while tn * 2 <= min(target, N) and N % (tn * 2) == 0:
        tn *= 2
    return tm, tn


def _relayout_kernel(x_ref, o_ref, *, tm: int, tn: int,
                     src_block: tuple[int, int], dst_block: tuple[int, int]):
    sbm, sbn = src_block
    dbm, dbn = dst_block
    # x_ref: (tm//sbm, tn//sbn, sbm, sbn) — the super-tile in src blocking.
    x = x_ref[...]
    dense = x.transpose(0, 2, 1, 3).reshape(tm, tn)
    out = dense.reshape(tm // dbm, dbm, tn // dbn, dbn).transpose(0, 2, 1, 3)
    o_ref[...] = out


@functools.partial(
    jax.jit,
    static_argnames=("shape", "src_block", "dst_block", "interpret"),
)
def relayout_pallas(
    x: jax.Array,
    shape: tuple[int, int],
    src_block: tuple[int, int],
    dst_block: tuple[int, int],
    interpret: bool = False,
) -> jax.Array:
    """Blocked(src_block) -> blocked(dst_block) layout transform.

    ``x``: (M//sbm, N//sbn, sbm, sbn). Returns (M//dbm, N//dbn, dbm, dbn).
    """
    M, N = shape
    sbm, sbn = src_block
    dbm, dbn = dst_block
    if (M % sbm, N % sbn, M % dbm, N % dbn) != (0, 0, 0, 0):
        raise ValueError(f"blocks {src_block}/{dst_block} must divide {shape}")
    tm, tn = _supertile(src_block, dst_block, shape)
    grid = (M // tm, N // tn)
    out_shape = jax.ShapeDtypeStruct((M // dbm, N // dbn, dbm, dbn), x.dtype)
    kernel = functools.partial(
        _relayout_kernel, tm=tm, tn=tn, src_block=src_block, dst_block=dst_block
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(
                (tm // sbm, tn // sbn, sbm, sbn),
                lambda i, j: (i, j, 0, 0),
            )
        ],
        out_specs=pl.BlockSpec(
            (tm // dbm, tn // dbn, dbm, dbn),
            lambda i, j: (i, j, 0, 0),
        ),
        out_shape=out_shape,
        interpret=interpret,
    )(x)
