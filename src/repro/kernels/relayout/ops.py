"""Public jit'd entry points for the relayout kernel.

On CPU (this container) the Pallas kernel runs in ``interpret=True``;
on TPU it compiles to Mosaic. ``relayout`` auto-selects; benchmarks and
tests can force either path.
"""

from __future__ import annotations

import jax

from .kernel import relayout_pallas
from .ref import dense_to_blocked, blocked_to_dense, parse_layout, relayout_ref

__all__ = [
    "relayout",
    "relayout_str",
    "relayout_ref",
    "parse_layout",
    "dense_to_blocked",
    "blocked_to_dense",
]


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def relayout(
    x: jax.Array,
    shape: tuple[int, int],
    src_block: tuple[int, int],
    dst_block: tuple[int, int],
    *,
    interpret: bool | None = None,
) -> jax.Array:
    """Blocked-layout transform (see :mod:`.kernel`)."""
    if interpret is None:
        interpret = not _on_tpu()
    return relayout_pallas(x, shape, src_block, dst_block, interpret=interpret)


def relayout_str(
    x: jax.Array,
    shape: tuple[int, int],
    src_layout: str,
    dst_layout: str,
    *,
    interpret: bool | None = None,
) -> jax.Array:
    """Same, with the paper's layout strings (e.g. ``"MNM16N8"``)."""
    return relayout(
        x, shape, parse_layout(src_layout), parse_layout(dst_layout),
        interpret=interpret,
    )
