"""Collectives backend seam: "xla" (fabric does replication — the
network-layer-multicast analogue) vs "torrent" (Chainwrite: explicitly
scheduled ppermute rings at the application layer).

The flagship integration point is the data-parallel gradient reduction:
``torrent_grad_reduce`` runs the whole grad computation under a
*subset* ``shard_map`` (manual over the DP axes, auto over ``model``)
so the DP reduction is OURS — a scheduled, bucketed, optionally
int8-compressed chain all-reduce — while TP sharding inside the model
stays GSPMD-managed. Options mirror the paper's knobs:

* ``scheduler`` — chain order from core.scheduling over the DP ring;
* ``hierarchical`` — reduce within a pod, then across pods (two short
  chains instead of one long one: (16-1)+(2-1) hops vs 31);
* ``num_chains`` — multi-chain Chainwrite: the flat DP ring is split
  into K disjoint equal sub-rings that reduce concurrently, then
  exchange across rings (``core.chainwrite.multi_chain_all_reduce``).
  ``hierarchical`` over a (pod, data) mesh is exactly the
  ``num_chains = #pods`` special case of this schedule on the
  flattened DP axis — K=2 for the production two-pod system.
  ``num_chains="auto"`` picks K per gradient leaf from the calibrated
  ``core.simulator.all_reduce_latency`` model (modeled bytes *and*
  cycles for the chosen ``algo``);
* ``algo`` — multi-ring all-reduce schedule: ``"rs_ag"`` (default,
  fused per-ring reduce-scatter/all-gather + cross-ring shard
  rotation — ≈ (2·(S-1)+(K-1))/S payloads of wire per device) or
  ``"rotation"`` (PR 1's full-payload rotations — fewer steps,
  (S+K-2) payloads of wire; only wins for tiny payloads);
* ``wire_dtype`` — lossy wire compression as an IR dimension:
  ``wire_dtype="int8"`` plans the SAME multi-chain schedules with every
  hop shipped as an int8 frame + f32 scale (4× fewer payload bytes;
  per-hop quantize → dequantize → f32 accumulate in the executor), so
  compression composes with ``num_chains``, ``algo``, ``hierarchical``
  and the recovery pricing instead of overriding them;
* ``error_feedback`` — EF-SGD (Seide et al.): each DP rank keeps the
  local residual of the lossy wire and adds it back into the next
  step's gradient before compression, restoring convergence. Requires
  a lossy ``wire_dtype``; state rides as an explicit residual pytree
  (``ef_residual_init`` / ``ef_residual_specs``).

Since the ChainProgram refactor the OTHER ring collectives are exposed
through the same seam: ``torrent_all_to_all`` (the MoE expert-dispatch
exchange — see ``models.moe.moe_apply_ep``), ``torrent_reduce_scatter``
and ``torrent_all_gather`` each accept ``num_chains`` and route through
``core.chainwrite.multi_chain_*`` (K disjoint sub-rings planned by
``core.program``; K=1 is the classic scheduled ring).
"""

from __future__ import annotations

import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import chainwrite as cw
from repro.core import simulator as sim
from repro.core.scheduling import (
    SCHEDULERS,
    FailureSpec,
    normalize_failed,
    partition_schedule,
    reform_chain,
)
from repro.core.simulator import SourceFailedError
from repro.core.topology import MeshTopology
from repro.core import program as prg
from repro.parallel import hints
from repro.runtime.compression import dequantize, quantize

PyTree = Any


class MultiChainPlan:
    """Host-side multi-chain broadcast plan with endpoint-only
    re-forming — the integration seam between the Torrent fault model
    and ``runtime.failure.resilient_loop``.

    The destination set is partitioned into K link-disjoint-preferring
    sub-chains (``core.scheduling.partition_schedule``). On a node
    failure, :meth:`reform` splices the dead member(s) — one node or a
    concurrent failure *set* — out of their sub-chains and re-orders
    each orphaned suffix
    (``core.scheduling.reform_chain`` — torus-aware), so the next
    :meth:`broadcast` is the degraded collective over the survivors:
    recovery is just a new chain schedule (the XDMA property — no NoC
    change), and a training step retries instead of restarting the
    whole collective from a checkpoint. Pass ``plan.reform`` as
    ``resilient_loop(reform_fn=...)``.
    """

    def __init__(
        self,
        topo: MeshTopology,
        head: int,
        destinations,
        *,
        num_chains: int | None = None,
        scheduler: str = "tsp",
        max_chains: int = 4,
    ) -> None:
        self.topo = topo
        self.head = int(head)
        self.scheduler = scheduler
        self.chains: list[list[int]] = [
            list(c)
            for c in partition_schedule(
                topo, list(destinations), self.head,
                num_chains=num_chains, scheduler=scheduler,
                max_chains=max_chains,
            )
        ]
        self.failed: list[int] = []

    @property
    def survivors(self) -> list[int]:
        return [d for c in self.chains for d in c]

    def reform(self, node: FailureSpec) -> bool:
        """Re-form around the dead member(s) ``node`` — one node id or
        a set of concurrently dead members; True when handled.

        Only the sub-chains containing dead members change (each
        orphaned suffix is re-scheduled from its surviving tail, one
        ``reform_chain`` per affected chain — exactly the schedule
        ``core.program.plan_recovery`` prices); every other sub-chain
        keeps its schedule verbatim. The *head* dying is total loss —
        no survivor banked the payload — and raises
        :class:`~repro.core.simulator.SourceFailedError` so
        ``resilient_loop`` falls back to checkpoint rollback. Unknown
        nodes (already failed or never a member) return False, without
        touching the plan, so the caller can fall back too.
        """
        dead = set(normalize_failed(node))
        if self.head in dead:
            raise SourceFailedError(
                f"node {self.head} is the plan head: total loss, "
                "re-forming cannot recover the source"
            )
        live = {d for c in self.chains for d in c}
        if dead - live:  # unknown/already-failed: leave the plan alone
            return False
        reformed: list[list[int]] = []
        for chain in self.chains:
            chain_dead = [d for d in chain if d in dead]
            if not chain_dead:
                reformed.append(chain)
                continue
            new = reform_chain(
                self.topo, chain, chain_dead, self.head,
                scheduler=self.scheduler,
            )
            if new:
                reformed.append(new)
        self.chains = reformed
        self.failed.extend(sorted(dead))
        return True

    def broadcast(self, x, axis_name, *, num_frames: int = 1):
        """The (possibly degraded) multi-chain broadcast over the
        current survivor schedule. Must run inside ``shard_map``."""
        if not self.chains:
            # every destination failed: only the head keeps its payload
            idx = cw._axis_index(axis_name)
            return jnp.where(idx == self.head, x, jnp.zeros_like(x))
        return cw.multi_chain_broadcast(
            x, axis_name, self.head, self.chains, num_frames=num_frames
        )


def ring_order_for_axis(axis_size: int, scheduler: str = "tsp") -> tuple[int, ...]:
    """Chain order for a DP ring: schedule the axis's devices as a 1-D
    NoC (linear neighbours), which the TSP/greedy scheduler traverses
    with 1 hop per destination — the ICI-torus-matched snake order."""
    if axis_size <= 2 or scheduler == "naive":
        return tuple(range(axis_size))
    topo = MeshTopology(axis_size, 1)
    order = SCHEDULERS[scheduler](topo, list(range(1, axis_size)), source=0)
    return (0, *order)


def sub_ring_orders(
    axis_size: int, num_chains: int, scheduler: str = "tsp"
) -> list[tuple[int, ...]]:
    """Split the scheduled DP ring into ``num_chains`` contiguous
    sub-rings for ``multi_chain_all_reduce``. Contiguous slices of the
    snake order keep every intra-ring hop at 1 physical link on the
    ICI torus (the multi-chain analogue of ``ring_order_for_axis``)."""
    if axis_size % num_chains:
        raise ValueError(
            f"num_chains={num_chains} must divide the DP group size {axis_size}"
        )
    ring = ring_order_for_axis(axis_size, scheduler)
    size = axis_size // num_chains
    return [tuple(ring[i * size : (i + 1) * size]) for i in range(num_chains)]


def _dp_axes(mesh) -> tuple[str, ...]:
    return hints.dp_axes(mesh.axis_names)


def _axis_orders(
    axis_name, num_chains: int, scheduler: str
) -> list[tuple[int, ...]]:
    """Resolve the K sub-ring partition of a manual axis at trace time
    (K=1 -> the single snake ring). Must run inside ``shard_map``."""
    size = cw._axis_size(axis_name)
    if num_chains <= 1 or size <= num_chains:
        return [ring_order_for_axis(size, scheduler)]
    return sub_ring_orders(size, num_chains, scheduler)


def torrent_all_to_all(
    x, axis_name, *, num_chains: int = 1, scheduler: str = "tsp",
    wire_dtype: str | None = None,
):
    """Scheduled-ring all-to-all over a manual axis (the MoE
    expert-dispatch exchange): ``x`` has leading dim = axis size, chunk
    ``x[j]`` is destined to device ``j``; returns ``out[s]`` = the
    chunk device ``s`` sent here. ``num_chains > 1`` uses the K-ring
    schedule (same wire bytes — a chunk train cannot shrink — but
    ring-local/position-paired hops). ``wire_dtype="int8"`` ships every
    hop of the chunk train quantized (int8 frame + f32 scale). Must run
    inside ``shard_map``."""
    orders = _axis_orders(axis_name, num_chains, scheduler)
    if len(orders) == 1:
        return cw.chain_all_to_all(x, axis_name, orders[0], wire_dtype=wire_dtype)
    return cw.multi_chain_all_to_all(x, axis_name, orders, wire_dtype=wire_dtype)


def torrent_reduce_scatter(
    x, axis_name, *, num_chains: int = 1, scheduler: str = "tsp"
):
    """Scheduled-ring reduce-scatter over a manual axis: ``x`` has
    leading dim = axis size; returns this device's fully reduced
    chunk. Must run inside ``shard_map``."""
    orders = _axis_orders(axis_name, num_chains, scheduler)
    if len(orders) == 1:
        return cw.chain_reduce_scatter(x, axis_name, orders[0])
    return cw.multi_chain_reduce_scatter(x, axis_name, orders)


def torrent_all_gather(
    x, axis_name, *, num_chains: int = 1, scheduler: str = "tsp",
    tiled: bool = False,
):
    """Scheduled-ring all-gather over a manual axis (device-id indexed
    stack, or concatenation with ``tiled=True``). Must run inside
    ``shard_map``."""
    orders = _axis_orders(axis_name, num_chains, scheduler)
    if len(orders) == 1:
        return cw.chain_all_gather(x, axis_name, orders[0], tiled=tiled)
    return cw.multi_chain_all_gather(x, axis_name, orders, tiled=tiled)


@functools.lru_cache(maxsize=None)
def auto_ring_chains(
    axis_size: int,
    size_bytes: int,
    scheduler: str = "tsp",
    algo: str = "rs_ag",
    wire_dtype: str | None = None,
    max_chains: int = 4,
) -> tuple[int, tuple[tuple[int, ...], ...]]:
    """Model-driven (K, sub_rings) for one DP reduction of
    ``size_bytes`` over ``axis_size`` devices — the ``num_chains=
    "auto"`` resolver. Delegates to the algo-aware
    ``core.simulator.choose_num_chains(collective="all_reduce")`` on
    the 1-D ring topology (the same snake construction as
    ``ring_order_for_axis``, so intra-ring hops stay 1 physical link).
    ``wire_dtype`` prices the candidate schedules with the compressed
    frame bytes (int8 payload + f32 scale sideband), so the chosen K
    matches what actually goes over the wire.
    Cached: the choice is static per (shape, axis) and runs at trace
    time for every gradient leaf.
    """
    if axis_size <= 2:
        return 1, (tuple(range(axis_size)),)
    topo = MeshTopology(axis_size, 1)
    k, rings = sim.choose_num_chains(
        topo, 0, list(range(1, axis_size)), int(size_bytes),
        scheduler=scheduler, max_chains=max_chains,
        collective="all_reduce", algo=algo, wire_dtype=wire_dtype,
    )
    return k, tuple(tuple(r) for r in rings)


def ef_residual_init(params: PyTree, dp_size: int) -> PyTree:
    """Zero error-feedback residual state for
    ``torrent_grad_reduce(error_feedback=True)``: one f32 residual per
    gradient leaf PER DP RANK, carried as a global ``(dp_size, *shape)``
    array whose leading dim is sharded over the DP axes
    (:func:`ef_residual_specs`)."""
    return jax.tree.map(
        lambda p: jnp.zeros((int(dp_size),) + tuple(p.shape), jnp.float32),
        params,
    )


def ef_residual_specs(mesh, params: PyTree) -> PyTree:
    """PartitionSpecs for :func:`ef_residual_init` state: dim 0 manual
    over the DP axes (each rank owns its own residual row)."""
    dp = _dp_axes(mesh)
    return jax.tree.map(lambda _: P(dp), params)


def torrent_grad_reduce(
    grad_fn: Callable[..., tuple[PyTree, PyTree]],
    mesh,
    batch_specs: PyTree,
    *,
    scheduler: str = "tsp",
    hierarchical: bool = True,
    num_chains: int | str = 1,
    algo: str = "rs_ag",
    wire_dtype: str | None = None,
    error_feedback: bool = False,
) -> Callable[..., tuple[PyTree, PyTree]]:
    """Wrap ``grad_fn(params, batch) -> (grads, metrics)`` (grads LOCAL
    to the batch shard) so grads come back chain-all-reduced over the DP
    axes. Model-axis sharding stays automatic (subset shard_map).

    ``num_chains > 1`` switches each DP reduction to the multi-chain
    schedule (K concurrent sub-rings; see module docstring). It must
    divide the group size being reduced. ``num_chains="auto"`` picks K
    per gradient leaf from the ``all_reduce_latency`` model for the
    chosen ``algo`` and ``wire_dtype`` (modeled bytes and cycles).

    ``wire_dtype="int8"`` runs the SAME schedules with each hop shipped
    quantized — it composes with ``num_chains``, ``algo`` and
    ``hierarchical`` (a 2-axis hierarchical reduction quantizes once
    per wire hop, never a second whole-payload pass on the outer ring).

    ``error_feedback=True`` (requires a lossy ``wire_dtype``) changes
    the wrapped signature to ``wrapped(params, batch, residual) ->
    (grads, metrics, new_residual)``: each DP rank adds its carried
    residual into the local gradient before the compressed reduction
    and banks the new local quantization error — the Seide-style local
    proxy for the distributed wire error (the per-hop errors inside the
    ring are not recoverable per rank; the first-quantization residual
    is the standard EF-SGD approximation). Residual state comes from
    :func:`ef_residual_init` / :func:`ef_residual_specs` and should be
    checkpointed alongside the optimizer state."""
    if algo not in cw.ALL_REDUCE_ALGOS:
        raise ValueError(
            f"unknown algo {algo!r}; expected {cw.ALL_REDUCE_ALGOS}"
        )
    if num_chains != "auto" and not isinstance(num_chains, int):
        raise ValueError(f'num_chains must be an int or "auto", got {num_chains!r}')
    wire_dtype = prg.normalize_wire_dtype(wire_dtype)
    if error_feedback and wire_dtype is None:
        raise ValueError(
            "error_feedback=True requires a lossy wire_dtype "
            '(e.g. wire_dtype="int8"): with an exact wire there is no '
            "quantization residual to feed back"
        )
    dp = _dp_axes(mesh)

    dp_size = 1
    for a in dp:
        dp_size *= mesh.shape[a]

    def reduce_one(g, r=None):
        flat = g.reshape(-1)
        new_r = None
        if r is not None:
            flat = flat.astype(jnp.float32) + r.reshape(-1)
            q, s = quantize(flat)
            new_r = (flat - dequantize(q, s)).reshape(g.shape)

        def ar(x, axis):
            size = 1
            for a in (axis if isinstance(axis, tuple) else (axis,)):
                size *= mesh.shape[a]
            order = ring_order_for_axis(size, scheduler)
            if num_chains == "auto":
                k, rings = auto_ring_chains(
                    size, x.size * x.dtype.itemsize, scheduler, algo,
                    wire_dtype,
                )
                if k > 1:
                    return cw.multi_chain_all_reduce(
                        x, axis, rings, algo=algo, wire_dtype=wire_dtype
                    )
            elif num_chains > 1 and size > num_chains:
                return cw.multi_chain_all_reduce(
                    x, axis, sub_ring_orders(size, num_chains, scheduler),
                    algo=algo, wire_dtype=wire_dtype,
                )
            return cw.chain_all_reduce(x, axis, order, wire_dtype=wire_dtype)

        if hierarchical and len(dp) == 2:
            flat = ar(flat, dp[1])  # within pod ("data")
            flat = ar(flat, dp[0])  # across pods
        else:
            flat = ar(flat, dp if len(dp) > 1 else dp[0])
        # shards hold grads of their LOCAL mean loss; the chain sums them,
        # so divide by the DP group size to recover the global-mean grad
        # (drop-in parity with the "xla" backend).
        reduced = (flat / dp_size).reshape(g.shape).astype(g.dtype)
        return reduced if r is None else (reduced, new_r)

    def _avg_metrics(metrics):
        # metrics are per-shard means -> average over the DP group
        return jax.tree.map(
            lambda m: jax.lax.psum(m, dp) / dp_size, metrics
        )

    if not error_feedback:

        def wrapped(params, batch):
            def inner(params, batch):
                grads, metrics = grad_fn(params, batch)
                grads = jax.tree.map(reduce_one, grads)
                return grads, _avg_metrics(metrics)

            in_specs = (jax.tree.map(lambda _: P(), params), batch_specs)
            out_specs = (jax.tree.map(lambda _: P(), params), P())
            return jax.shard_map(
                inner,
                mesh=mesh,
                in_specs=in_specs,
                out_specs=out_specs,
                axis_names=set(dp),
                check_vma=False,
            )(params, batch)

        return wrapped

    def wrapped_ef(params, batch, residual):
        def inner(params, batch, residual):
            grads, metrics = grad_fn(params, batch)
            # each rank's residual row: (1, *shape) -> (*shape)
            res = jax.tree.map(lambda r: r[0], residual)
            pairs = jax.tree.map(reduce_one, grads, res)
            grads = jax.tree.map(
                lambda pair: pair[0], pairs,
                is_leaf=lambda x: isinstance(x, tuple),
            )
            new_res = jax.tree.map(
                lambda pair: pair[1][None], pairs,
                is_leaf=lambda x: isinstance(x, tuple),
            )
            return grads, _avg_metrics(metrics), new_res

        param_specs = jax.tree.map(lambda _: P(), params)
        res_specs = ef_residual_specs(mesh, params)
        return jax.shard_map(
            inner,
            mesh=mesh,
            in_specs=(param_specs, batch_specs, res_specs),
            out_specs=(param_specs, P(), res_specs),
            axis_names=set(dp),
            check_vma=False,
        )(params, batch, residual)

    return wrapped_ef
