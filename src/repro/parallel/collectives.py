"""Collectives backend seam: "xla" (fabric does replication — the
network-layer-multicast analogue) vs "torrent" (Chainwrite: explicitly
scheduled ppermute rings at the application layer).

The flagship integration point is the data-parallel gradient reduction:
``torrent_grad_reduce`` runs the whole grad computation under a
*subset* ``shard_map`` (manual over the DP axes, auto over ``model``)
so the DP reduction is OURS — a scheduled, bucketed, optionally
int8-compressed chain all-reduce — while TP sharding inside the model
stays GSPMD-managed. Options mirror the paper's knobs:

* ``scheduler`` — chain order from core.scheduling over the DP ring;
* ``hierarchical`` — reduce within a pod, then across pods (two short
  chains instead of one long one: (16-1)+(2-1) hops vs 31);
* ``num_chains`` — multi-chain Chainwrite: the flat DP ring is split
  into K disjoint equal sub-rings that reduce concurrently, then
  exchange across rings (``core.chainwrite.multi_chain_all_reduce``).
  ``hierarchical`` over a (pod, data) mesh is exactly the
  ``num_chains = #pods`` special case of this schedule on the
  flattened DP axis — K=2 for the production two-pod system.
  ``num_chains="auto"`` picks K per gradient leaf from the calibrated
  ``core.simulator.all_reduce_latency`` model (modeled bytes *and*
  cycles for the chosen ``algo``);
* ``algo`` — multi-ring all-reduce schedule: ``"rs_ag"`` (default,
  fused per-ring reduce-scatter/all-gather + cross-ring shard
  rotation — ≈ (2·(S-1)+(K-1))/S payloads of wire per device) or
  ``"rotation"`` (PR 1's full-payload rotations — fewer steps,
  (S+K-2) payloads of wire; only wins for tiny payloads);
* ``wire_dtype`` — lossy wire compression as an IR dimension:
  ``wire_dtype="int8"`` plans the SAME multi-chain schedules with every
  hop shipped as an int8 frame + f32 scale (4× fewer payload bytes;
  per-hop quantize → dequantize → f32 accumulate in the executor), so
  compression composes with ``num_chains``, ``algo``, ``hierarchical``
  and the recovery pricing instead of overriding them;
* ``error_feedback`` — EF-SGD (Seide et al.): each DP rank keeps the
  local residual of the lossy wire and adds it back into the next
  step's gradient before compression, restoring convergence. Requires
  a lossy ``wire_dtype``; state rides as an explicit residual pytree
  (``ef_residual_init`` / ``ef_residual_specs``);
* ``bucket_bytes`` — bucketed, backward-overlapped reduction: gradient
  leaves are partitioned into size-targeted, dtype-grouped buckets
  (:func:`assign_buckets`) walked in REVERSE leaf order — the
  reverse-topological approximation of backward production order — and
  each bucket is reduced as ONE chain all-reduce over a chunk-aligned
  flat payload (:func:`bucket_shard_layout`), so the per-collective cfg
  overhead is amortized over many leaves and the first buckets' chains
  can run while the rest of backward is still producing gradients
  (dispatch-order scheduling; XLA is free to interleave each bucket's
  collective with the remaining backward fusions — the overlap
  evidence is counted by ``launch.hlo_breakdown.overlap_stats`` and
  the modeled timeline lives in ``core.simulator.overlap_timeline``).
  Chunk alignment keeps every element's ring fold order identical to
  the per-leaf reduce, so the bucketed path is BIT-identical at the
  exact (f32) wire (fold-order identity is pinned against the numpy
  twin; compiled artifacts can still pick up 1-ulp excess precision
  when XLA FMA-contracts the gradient producer into the combine adds —
  a backend freedom independent of bucketing); it composes with
  ``num_chains="auto"`` (K resolved per bucket from the bucket's
  bytes), ``algo``, ``hierarchical`` and ``compress_grads`` (per-leaf
  EF residuals, bucketed int8 wire).

Since the ChainProgram refactor the OTHER ring collectives are exposed
through the same seam: ``torrent_all_to_all`` (the MoE expert-dispatch
exchange — see ``models.moe.moe_apply_ep``), ``torrent_reduce_scatter``
and ``torrent_all_gather`` each accept ``num_chains`` and route through
``core.chainwrite.multi_chain_*`` (K disjoint sub-rings planned by
``core.program``; K=1 is the classic scheduled ring).
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import chainwrite as cw
from repro.core import simulator as sim
from repro.core.scheduling import (
    SCHEDULERS,
    FailureSpec,
    normalize_failed,
    partition_schedule,
    reform_chain,
)
from repro.core.simulator import SourceFailedError
from repro.core.topology import MeshTopology, parse_topology_spec
from repro.core import program as prg
from repro.parallel import hints
from repro.runtime.compression import dequantize, quantize

PyTree = Any


class MultiChainPlan:
    """Host-side multi-chain broadcast plan with endpoint-only
    re-forming — the integration seam between the Torrent fault model
    and ``runtime.failure.resilient_loop``.

    The destination set is partitioned into K link-disjoint-preferring
    sub-chains (``core.scheduling.partition_schedule``). On a node
    failure, :meth:`reform` splices the dead member(s) — one node or a
    concurrent failure *set* — out of their sub-chains and re-orders
    each orphaned suffix
    (``core.scheduling.reform_chain`` — torus-aware), so the next
    :meth:`broadcast` is the degraded collective over the survivors:
    recovery is just a new chain schedule (the XDMA property — no NoC
    change), and a training step retries instead of restarting the
    whole collective from a checkpoint. Pass ``plan.reform`` as
    ``resilient_loop(reform_fn=...)``.
    """

    def __init__(
        self,
        topo: MeshTopology,
        head: int,
        destinations,
        *,
        num_chains: int | None = None,
        scheduler: str = "tsp",
        max_chains: int = 4,
    ) -> None:
        self.topo = topo
        self.head = int(head)
        self.scheduler = scheduler
        self.chains: list[list[int]] = [
            list(c)
            for c in partition_schedule(
                topo, list(destinations), self.head,
                num_chains=num_chains, scheduler=scheduler,
                max_chains=max_chains,
            )
        ]
        self.failed: list[int] = []

    @property
    def survivors(self) -> list[int]:
        return [d for c in self.chains for d in c]

    def reform(self, node: FailureSpec) -> bool:
        """Re-form around the dead member(s) ``node`` — one node id or
        a set of concurrently dead members; True when handled.

        Only the sub-chains containing dead members change (each
        orphaned suffix is re-scheduled from its surviving tail, one
        ``reform_chain`` per affected chain — exactly the schedule
        ``core.program.plan_recovery`` prices); every other sub-chain
        keeps its schedule verbatim. The *head* dying is total loss —
        no survivor banked the payload — and raises
        :class:`~repro.core.simulator.SourceFailedError` so
        ``resilient_loop`` falls back to checkpoint rollback. Unknown
        nodes (already failed or never a member) return False, without
        touching the plan, so the caller can fall back too.
        """
        dead = set(normalize_failed(node))
        if self.head in dead:
            raise SourceFailedError(
                f"node {self.head} is the plan head: total loss, "
                "re-forming cannot recover the source"
            )
        live = {d for c in self.chains for d in c}
        if dead - live:  # unknown/already-failed: leave the plan alone
            return False
        reformed: list[list[int]] = []
        for chain in self.chains:
            chain_dead = [d for d in chain if d in dead]
            if not chain_dead:
                reformed.append(chain)
                continue
            new = reform_chain(
                self.topo, chain, chain_dead, self.head,
                scheduler=self.scheduler,
            )
            if new:
                reformed.append(new)
        self.chains = reformed
        self.failed.extend(sorted(dead))
        return True

    def broadcast(self, x, axis_name, *, num_frames: int = 1):
        """The (possibly degraded) multi-chain broadcast over the
        current survivor schedule. Must run inside ``shard_map``."""
        if not self.chains:
            # every destination failed: only the head keeps its payload
            idx = cw._axis_index(axis_name)
            return jnp.where(idx == self.head, x, jnp.zeros_like(x))
        return cw.multi_chain_broadcast(
            x, axis_name, self.head, self.chains, num_frames=num_frames
        )


def ring_order_for_axis(axis_size: int, scheduler: str = "tsp") -> tuple[int, ...]:
    """Chain order for a DP ring: schedule the axis's devices as a 1-D
    NoC (linear neighbours), which the TSP/greedy scheduler traverses
    with 1 hop per destination — the ICI-torus-matched snake order."""
    if axis_size <= 2 or scheduler == "naive":
        return tuple(range(axis_size))
    topo = MeshTopology(axis_size, 1)
    order = SCHEDULERS[scheduler](topo, list(range(1, axis_size)), source=0)
    return (0, *order)


def sub_ring_orders(
    axis_size: int, num_chains: int, scheduler: str = "tsp"
) -> list[tuple[int, ...]]:
    """Split the scheduled DP ring into ``num_chains`` contiguous
    sub-rings for ``multi_chain_all_reduce``. Contiguous slices of the
    snake order keep every intra-ring hop at 1 physical link on the
    ICI torus (the multi-chain analogue of ``ring_order_for_axis``)."""
    if axis_size % num_chains:
        raise ValueError(
            f"num_chains={num_chains} must divide the DP group size {axis_size}"
        )
    ring = ring_order_for_axis(axis_size, scheduler)
    size = axis_size // num_chains
    return [tuple(ring[i * size : (i + 1) * size]) for i in range(num_chains)]


def _dp_axes(mesh) -> tuple[str, ...]:
    return hints.dp_axes(mesh.axis_names)


def _axis_orders(
    axis_name, num_chains: int, scheduler: str
) -> list[tuple[int, ...]]:
    """Resolve the K sub-ring partition of a manual axis at trace time
    (K=1 -> the single snake ring). Must run inside ``shard_map``."""
    size = cw._axis_size(axis_name)
    if num_chains <= 1 or size <= num_chains:
        return [ring_order_for_axis(size, scheduler)]
    return sub_ring_orders(size, num_chains, scheduler)


def torrent_all_to_all(
    x, axis_name, *, num_chains: int = 1, scheduler: str = "tsp",
    wire_dtype: str | None = None,
):
    """Scheduled-ring all-to-all over a manual axis (the MoE
    expert-dispatch exchange): ``x`` has leading dim = axis size, chunk
    ``x[j]`` is destined to device ``j``; returns ``out[s]`` = the
    chunk device ``s`` sent here. ``num_chains > 1`` uses the K-ring
    schedule (same wire bytes — a chunk train cannot shrink — but
    ring-local/position-paired hops). ``wire_dtype="int8"`` ships every
    hop of the chunk train quantized (int8 frame + f32 scale). Must run
    inside ``shard_map``."""
    orders = _axis_orders(axis_name, num_chains, scheduler)
    if len(orders) == 1:
        return cw.chain_all_to_all(x, axis_name, orders[0], wire_dtype=wire_dtype)
    return cw.multi_chain_all_to_all(x, axis_name, orders, wire_dtype=wire_dtype)


def torrent_reduce_scatter(
    x, axis_name, *, num_chains: int = 1, scheduler: str = "tsp"
):
    """Scheduled-ring reduce-scatter over a manual axis: ``x`` has
    leading dim = axis size; returns this device's fully reduced
    chunk. Must run inside ``shard_map``."""
    orders = _axis_orders(axis_name, num_chains, scheduler)
    if len(orders) == 1:
        return cw.chain_reduce_scatter(x, axis_name, orders[0])
    return cw.multi_chain_reduce_scatter(x, axis_name, orders)


def torrent_all_gather(
    x, axis_name, *, num_chains: int = 1, scheduler: str = "tsp",
    tiled: bool = False,
):
    """Scheduled-ring all-gather over a manual axis (device-id indexed
    stack, or concatenation with ``tiled=True``). Must run inside
    ``shard_map``."""
    orders = _axis_orders(axis_name, num_chains, scheduler)
    if len(orders) == 1:
        return cw.chain_all_gather(x, axis_name, orders[0], tiled=tiled)
    return cw.multi_chain_all_gather(x, axis_name, orders, tiled=tiled)


def _ring_topology(
    axis_size: int, topology: "str | MeshTopology | None"
) -> MeshTopology:
    """Resolve the (optional) topology knob for one DP ring of
    ``axis_size`` devices: ``None`` -> the uniform 1-D ring; a spec
    string -> ``core.topology.parse_topology_spec``; a topology object
    passes through. The knob is ADVISORY: a spec that does not apply to
    this axis (wrong node count, pods that do not divide it) degrades
    to the uniform ring instead of erroring, so one VARIANTS entry can
    span meshes whose data-axis sizes differ."""
    if topology is None:
        return MeshTopology(axis_size, 1)
    if isinstance(topology, MeshTopology):
        topo = topology
    else:
        try:
            topo = parse_topology_spec(str(topology), num_nodes=axis_size)
        except ValueError:
            return MeshTopology(axis_size, 1)
    if topo.num_nodes != axis_size:
        return MeshTopology(axis_size, 1)
    return topo


@functools.lru_cache(maxsize=None)
def auto_ring_chains(
    axis_size: int,
    size_bytes: int,
    scheduler: str = "tsp",
    algo: str = "rs_ag",
    wire_dtype: str | None = None,
    max_chains: int = 4,
    topo: MeshTopology | None = None,
) -> tuple[int, tuple[tuple[int, ...], ...]]:
    """Model-driven (K, sub_rings) for one DP reduction of
    ``size_bytes`` over ``axis_size`` devices — the ``num_chains=
    "auto"`` resolver. Delegates to the algo-aware
    ``core.simulator.choose_num_chains(collective="all_reduce")`` on
    the 1-D ring topology (the same snake construction as
    ``ring_order_for_axis``, so intra-ring hops stay 1 physical link),
    or on ``topo`` when given — a tiered topology makes the pod-aligned
    hierarchical schedule a candidate, and the cache keys on the frozen
    topology object itself, so a weighted graph can never alias the
    uniform ring of the same shape.
    ``wire_dtype`` prices the candidate schedules with the compressed
    frame bytes (int8 payload + f32 scale sideband), so the chosen K
    matches what actually goes over the wire.
    Cached: the choice is static per (shape, axis) and runs at trace
    time for every gradient leaf.
    """
    if axis_size <= 2:
        return 1, (tuple(range(axis_size)),)
    if topo is None:
        topo = MeshTopology(axis_size, 1)
    elif topo.num_nodes != axis_size:
        raise ValueError(
            f"topology has {topo.num_nodes} nodes for a ring of {axis_size}"
        )
    k, rings = sim.choose_num_chains(
        topo, 0, list(range(1, axis_size)), int(size_bytes),
        scheduler=scheduler, max_chains=max_chains,
        collective="all_reduce", algo=algo, wire_dtype=wire_dtype,
    )
    return k, tuple(tuple(r) for r in rings)


# ---------------------------------------------------------------------------
# Bucket assembly (backward-overlapped gradient reduction)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class GradBucket:
    """One reduction bucket: the leaf indices it owns (positions in the
    flattened gradient tree, descending = reverse-topological dispatch
    order), their common dtype, and their total unpadded bytes."""

    indices: tuple[int, ...]
    dtype: str
    num_bytes: int


def assign_buckets(leaves: Sequence, bucket_bytes: int) -> tuple[GradBucket, ...]:
    """Partition gradient leaves into dtype-grouped, size-targeted
    buckets in REVERSE leaf order (reverse-topological ≈ backward
    production order: the last parameters' grads are produced first, so
    the first bucket closes — and its chain reduce can dispatch — while
    the rest of backward is still running).

    ``leaves`` need only ``.shape``/``.dtype`` (arrays or
    ``ShapeDtypeStruct``s). Invariants (property-tested in
    tests/test_bucketed_reduce.py): every leaf index appears in exactly
    one bucket; bucket bytes sum to the leaves' total; a bucket never
    mixes dtypes; a bucket exceeds ``bucket_bytes`` only when it holds a
    single oversized leaf (the one-leaf-slack rule — the target is
    respected by closing before adding, never by splitting a leaf).
    """
    target = int(bucket_bytes)
    if target <= 0:
        raise ValueError(f"bucket_bytes must be positive, got {bucket_bytes}")
    buckets: list[GradBucket] = []
    idxs: list[int] = []
    cur_dtype = ""
    cur_bytes = 0

    def close() -> None:
        nonlocal idxs, cur_dtype, cur_bytes
        if idxs:
            buckets.append(GradBucket(tuple(idxs), cur_dtype, cur_bytes))
        idxs, cur_dtype, cur_bytes = [], "", 0

    for i in reversed(range(len(leaves))):
        dt = jnp.dtype(leaves[i].dtype)
        nbytes = math.prod(leaves[i].shape) * dt.itemsize
        if idxs and (dt.name != cur_dtype or cur_bytes + nbytes > target):
            close()
        idxs.append(i)
        cur_dtype = dt.name
        cur_bytes += nbytes
    close()
    return tuple(buckets)


def all_reduce_shards(axis_size: int, num_chains: int, algo: str) -> int:
    """Chunk-address shard count of the planned all-reduce schedule —
    ``plan_all_reduce(...).addr_shards`` read off the plan itself.
    Symbolic addressing makes planning O(L) per step, so asking the
    planner is cheap; ``addr_shards`` depends only on the (L, K, algo)
    shape, never on ring identity, so canonical contiguous sub-rings
    stand in for the scheduled ones. K=1 uses device-id chunks
    (L shards, either algo); multi-ring rotation carries the whole
    payload as one slot; multi-ring rs_ag addresses by ring position
    (S = L/K shards)."""
    L, k = int(axis_size), max(1, int(num_chains))
    size = L // k
    orders = tuple(
        tuple(range(i * size, (i + 1) * size)) for i in range(k)
    )
    return prg.plan_all_reduce(L, orders, algo=algo).addr_shards


def bucket_shard_layout(
    num_elems: Sequence[int], shards: int
) -> tuple[tuple[int, ...], int]:
    """Chunk-aligned bucket layout: leaf i occupies ``shards`` rows of
    ``ceil(n_i / shards)`` elements (zero-padded), concatenated along
    the row axis. Aligning every leaf's chunk boundaries to the
    schedule's shard count keeps each element's ring fold order
    identical to the per-leaf reduce — that is what makes the bucketed
    path bit-identical at the exact wire. Returns ``(widths,
    total_elems)`` with ``total_elems = shards * sum(widths)`` (the
    payload size the wire and the cost model both see)."""
    widths = tuple(-(-int(n) // int(shards)) for n in num_elems)
    return widths, int(shards) * sum(widths)


def resolve_ring_chains(
    axis_size: int,
    nbytes: int,
    *,
    num_chains: int | str = 1,
    scheduler: str = "tsp",
    algo: str = "rs_ag",
    wire_dtype: str | None = None,
    max_chains: int = 4,
    topology: "str | MeshTopology | None" = None,
) -> tuple[int, tuple[tuple[int, ...], ...]]:
    """(K, sub_rings) for one DP reduction — the module-level twin of
    ``torrent_grad_reduce``'s per-reduction resolution, shared with the
    overlap/step-time model (``launch.roofline.modeled_train_overlap``)
    so modeled schedules stay in lockstep with what the executor runs
    (the EXACT modeled-vs-HLO byte match depends on it).

    ``topology`` (spec string or topology object, see
    :func:`_ring_topology`) only steers the ``num_chains="auto"``
    model: a tiered topology makes the hierarchical pod-aligned split a
    scored candidate. Explicit ``num_chains`` keeps the contiguous
    snake splits, which on a 1-D tiered ring are already pod-aligned."""
    if num_chains == "auto":
        k, rings = auto_ring_chains(
            axis_size, nbytes, scheduler, algo, wire_dtype, max_chains,
            _ring_topology(axis_size, topology),
        )
        if k > 1:
            return k, rings
    elif (
        isinstance(num_chains, int)
        and num_chains > 1
        and axis_size > num_chains
    ):
        return num_chains, tuple(
            sub_ring_orders(axis_size, num_chains, scheduler)
        )
    return 1, (ring_order_for_axis(axis_size, scheduler),)


def ef_residual_init(params: PyTree, dp_size: int) -> PyTree:
    """Zero error-feedback residual state for
    ``torrent_grad_reduce(error_feedback=True)``: one f32 residual per
    gradient leaf PER DP RANK, carried as a global ``(dp_size, *shape)``
    array whose leading dim is sharded over the DP axes
    (:func:`ef_residual_specs`)."""
    return jax.tree.map(
        lambda p: jnp.zeros((int(dp_size),) + tuple(p.shape), jnp.float32),
        params,
    )


def ef_residual_specs(mesh, params: PyTree) -> PyTree:
    """PartitionSpecs for :func:`ef_residual_init` state: dim 0 manual
    over the DP axes (each rank owns its own residual row)."""
    dp = _dp_axes(mesh)
    return jax.tree.map(lambda _: P(dp), params)


def torrent_grad_reduce(
    grad_fn: Callable[..., tuple[PyTree, PyTree]],
    mesh,
    batch_specs: PyTree,
    *,
    scheduler: str = "tsp",
    hierarchical: bool = True,
    num_chains: int | str = 1,
    algo: str = "rs_ag",
    wire_dtype: str | None = None,
    error_feedback: bool = False,
    bucket_bytes: int | None = None,
    topology: "str | MeshTopology | None" = None,
) -> Callable[..., tuple[PyTree, PyTree]]:
    """Wrap ``grad_fn(params, batch) -> (grads, metrics)`` (grads LOCAL
    to the batch shard) so grads come back chain-all-reduced over the DP
    axes. Model-axis sharding stays automatic (subset shard_map).

    ``num_chains > 1`` switches each DP reduction to the multi-chain
    schedule (K concurrent sub-rings; see module docstring). It must
    divide the group size being reduced. ``num_chains="auto"`` picks K
    per gradient leaf from the ``all_reduce_latency`` model for the
    chosen ``algo`` and ``wire_dtype`` (modeled bytes and cycles).

    ``wire_dtype="int8"`` runs the SAME schedules with each hop shipped
    quantized — it composes with ``num_chains``, ``algo`` and
    ``hierarchical`` (a 2-axis hierarchical reduction quantizes once
    per wire hop, never a second whole-payload pass on the outer ring).

    ``error_feedback=True`` (requires a lossy ``wire_dtype``) changes
    the wrapped signature to ``wrapped(params, batch, residual) ->
    (grads, metrics, new_residual)``: each DP rank adds its carried
    residual into the local gradient before the compressed reduction
    and banks the new local quantization error — the Seide-style local
    proxy for the distributed wire error (the per-hop errors inside the
    ring are not recoverable per rank; the first-quantization residual
    is the standard EF-SGD approximation). Residual state comes from
    :func:`ef_residual_init` / :func:`ef_residual_specs` and should be
    checkpointed alongside the optimizer state.

    ``bucket_bytes`` switches to the bucketed, backward-overlapped
    reduction (module docstring): leaves are grouped by
    :func:`assign_buckets` and each bucket reduces as one chunk-aligned
    chain all-reduce, dispatched in reverse-topological bucket order.
    ``num_chains="auto"`` then resolves K per BUCKET (from the bucket's
    total bytes) instead of per leaf; EF residuals stay per leaf.

    ``topology`` (a ``core.topology`` spec string such as
    ``"pods=4:interpod_bw=0.25"``, or a topology object) models the DP
    ring as a tiered link graph for the ``num_chains="auto"``
    selection, making the hierarchical pod-aligned schedule a scored
    candidate; see :func:`resolve_ring_chains`. Advisory: specs that
    do not fit the reduced axis degrade to the uniform ring."""
    if algo not in cw.ALL_REDUCE_ALGOS:
        raise ValueError(
            f"unknown algo {algo!r}; expected {cw.ALL_REDUCE_ALGOS}"
        )
    if num_chains != "auto" and not isinstance(num_chains, int):
        raise ValueError(f'num_chains must be an int or "auto", got {num_chains!r}')
    wire_dtype = prg.normalize_wire_dtype(wire_dtype)
    if error_feedback and wire_dtype is None:
        raise ValueError(
            "error_feedback=True requires a lossy wire_dtype "
            '(e.g. wire_dtype="int8"): with an exact wire there is no '
            "quantization residual to feed back"
        )
    if bucket_bytes is not None and int(bucket_bytes) <= 0:
        raise ValueError(f"bucket_bytes must be positive, got {bucket_bytes}")
    dp = _dp_axes(mesh)

    dp_size = 1
    for a in dp:
        dp_size *= mesh.shape[a]

    def _axis_len(axis) -> int:
        size = 1
        for a in (axis if isinstance(axis, tuple) else (axis,)):
            size *= mesh.shape[a]
        return size

    def _rings_for(size: int, nbytes: int):
        """(K, sub_rings) for one axis reduction of ``nbytes``."""
        return resolve_ring_chains(
            size, nbytes, num_chains=num_chains, scheduler=scheduler,
            algo=algo, wire_dtype=wire_dtype, topology=topology,
        )

    def _ar(x, axis, k, rings):
        if k > 1:
            return cw.multi_chain_all_reduce(
                x, axis, rings, algo=algo, wire_dtype=wire_dtype
            )
        return cw.chain_all_reduce(x, axis, rings[0], wire_dtype=wire_dtype)

    def _ar_stages():
        if hierarchical and len(dp) == 2:
            return [dp[1], dp[0]]  # within pod ("data"), then across pods
        return [dp if len(dp) > 1 else dp[0]]

    def reduce_one(g, r=None):
        flat = g.reshape(-1)
        new_r = None
        if r is not None:
            flat = flat.astype(jnp.float32) + r.reshape(-1)
            q, s = quantize(flat)
            new_r = (flat - dequantize(q, s)).reshape(g.shape)

        def ar(x, axis):
            k, rings = _rings_for(
                _axis_len(axis), x.size * x.dtype.itemsize
            )
            return _ar(x, axis, k, rings)

        if hierarchical and len(dp) == 2:
            flat = ar(flat, dp[1])  # within pod ("data")
            flat = ar(flat, dp[0])  # across pods
        else:
            flat = ar(flat, dp if len(dp) > 1 else dp[0])
        # shards hold grads of their LOCAL mean loss; the chain sums them,
        # so divide by the DP group size to recover the global-mean grad
        # (drop-in parity with the "xla" backend).
        reduced = (flat / dp_size).reshape(g.shape).astype(g.dtype)
        return reduced if r is None else (reduced, new_r)

    def _reduce_bucket_flats(flats):
        """One bucket = ONE chain all-reduce: chunk-align each flat leaf
        to the schedule's shard count, concatenate along the row axis,
        reduce the whole payload, slice the leaves back out. Returns the
        per-leaf reduced flats (un-averaged)."""
        nbytes = sum(f.size * f.dtype.itemsize for f in flats)
        stages = _ar_stages()
        plans = [
            (axis,) + _rings_for(_axis_len(axis), nbytes) for axis in stages
        ]
        shards = all_reduce_shards(_axis_len(stages[0]), plans[0][1], algo)
        widths, _ = bucket_shard_layout([f.size for f in flats], shards)
        padded = [
            jnp.pad(f, (0, shards * m - f.size)).reshape(shards, m)
            for f, m in zip(flats, widths)
        ]
        payload = (
            padded[0] if len(padded) == 1 else jnp.concatenate(padded, axis=1)
        ).reshape(-1)
        for axis, k, rings in plans:
            payload = _ar(payload, axis, k, rings)
        mat = payload.reshape(shards, -1)
        outs, off = [], 0
        for f, m in zip(flats, widths):
            outs.append(mat[:, off : off + m].reshape(-1)[: f.size])
            off += m
        return outs

    def reduce_bucketed(grads, res=None):
        """Bucketed tree reduce: buckets dispatch in reverse-topological
        order (assign_buckets walks leaves last-to-first), so the
        schedule XLA sees issues each bucket's collective as soon as its
        leaves' grads exist — the dispatch-order half of the overlap
        story. Returns grads, or (grads, new_residuals) under EF."""
        leaves, treedef = jax.tree.flatten(grads)
        res_leaves = (
            jax.tree.flatten(res)[0] if res is not None else [None] * len(leaves)
        )
        out = [None] * len(leaves)
        new_res = [None] * len(leaves)
        for b in assign_buckets(leaves, bucket_bytes):
            flats = []
            for i in b.indices:
                g, r = leaves[i], res_leaves[i]
                flat = g.reshape(-1)
                if r is not None:
                    flat = flat.astype(jnp.float32) + r.reshape(-1)
                    q, s = quantize(flat)
                    new_res[i] = (flat - dequantize(q, s)).reshape(g.shape)
                flats.append(flat)
            for i, rf in zip(b.indices, _reduce_bucket_flats(flats)):
                g = leaves[i]
                out[i] = (rf / dp_size).reshape(g.shape).astype(g.dtype)
        grads_out = jax.tree.unflatten(treedef, out)
        if res is None:
            return grads_out
        return grads_out, jax.tree.unflatten(treedef, new_res)

    def _avg_metrics(metrics):
        # metrics are per-shard means -> average over the DP group
        return jax.tree.map(
            lambda m: jax.lax.psum(m, dp) / dp_size, metrics
        )

    if not error_feedback:

        def wrapped(params, batch):
            def inner(params, batch):
                grads, metrics = grad_fn(params, batch)
                if bucket_bytes is None:
                    grads = jax.tree.map(reduce_one, grads)
                else:
                    grads = reduce_bucketed(grads)
                return grads, _avg_metrics(metrics)

            in_specs = (jax.tree.map(lambda _: P(), params), batch_specs)
            out_specs = (jax.tree.map(lambda _: P(), params), P())
            return jax.shard_map(
                inner,
                mesh=mesh,
                in_specs=in_specs,
                out_specs=out_specs,
                axis_names=set(dp),
                check_vma=False,
            )(params, batch)

        return wrapped

    def wrapped_ef(params, batch, residual):
        def inner(params, batch, residual):
            grads, metrics = grad_fn(params, batch)
            # each rank's residual row: (1, *shape) -> (*shape)
            res = jax.tree.map(lambda r: r[0], residual)
            if bucket_bytes is None:
                pairs = jax.tree.map(reduce_one, grads, res)
                grads = jax.tree.map(
                    lambda pair: pair[0], pairs,
                    is_leaf=lambda x: isinstance(x, tuple),
                )
                new_res = jax.tree.map(
                    lambda pair: pair[1][None], pairs,
                    is_leaf=lambda x: isinstance(x, tuple),
                )
            else:
                grads, new_r = reduce_bucketed(grads, res)
                new_res = jax.tree.map(lambda r: r[None], new_r)
            return grads, _avg_metrics(metrics), new_res

        param_specs = jax.tree.map(lambda _: P(), params)
        res_specs = ef_residual_specs(mesh, params)
        return jax.shard_map(
            inner,
            mesh=mesh,
            in_specs=(param_specs, batch_specs, res_specs),
            out_specs=(param_specs, P(), res_specs),
            axis_names=set(dp),
            check_vma=False,
        )(params, batch, residual)

    return wrapped_ef
