"""Parameter / batch / cache PartitionSpecs (DP + TP/EP + ZeRO-1 + SP).

Rules are name-based over the param pytree paths (built from
``jax.eval_shape`` — no allocation) with divisibility checks against the
TP axis size: a dim that doesn't divide is left replicated rather than
relying on GSPMD padding for weights (activat­ion reshapes may still pad;
that is fine and shows up in the roofline, e.g. starcoder2's 24 heads on
a 16-way model axis).

Scheme (Megatron-style):
* embeddings / lm_head: vocab-sharded over ``model``;
* attention: column-parallel QKV (head dim), row-parallel output proj;
* MLA: compress proj replicated (small), recovery projections
  column-parallel — the compressed KV is the multicast operand (paper
  P3/D3);
* dense FFN: column-parallel gate/up, row-parallel down;
* MoE: experts sharded over ``model`` (EP);
* mamba2: d_inner (head) dim column-parallel, B/C/dt projections
  replicated (small);
* optimizer state: params' spec + extra ``data`` sharding (ZeRO-1);
* decode caches: batch over ``(pod, data)``, heads over ``model``; the
  ``long_500k`` cells instead shard KV slots over ``data`` (SP).
"""

from __future__ import annotations

from typing import Any

import jax
from jax.sharding import PartitionSpec as P

from repro.models.config import ModelConfig
from repro.configs.shapes import Shape

PyTree = Any

BATCH_AXES = ("pod", "data")


def _div(n: int, k: int) -> bool:
    return k > 0 and n % k == 0


def _param_spec(path: tuple[str, ...], shape: tuple[int, ...], cfg: ModelConfig,
                tp: int) -> P:
    """Spec for one (unstacked) param leaf."""
    name = path[-1]
    parent = path[-2] if len(path) >= 2 else ""

    def col(dim_idx: int) -> P:  # shard output dim over model
        if _div(shape[dim_idx], tp):
            spec = [None] * len(shape)
            spec[dim_idx] = "model"
            return P(*spec)
        return P(*([None] * len(shape)))

    if name == "table":  # embed / lm_head: vocab-sharded
        return P("model", None) if _div(shape[0], tp) else P(None, None)
    if name == "pos_emb":
        return P(*([None] * len(shape)))
    if name in ("wq", "wk", "wv", "gate", "up", "fc1", "in_z", "in_x", "w_uk", "w_uv"):
        return col(1)
    if name in ("bq", "bk", "bv", "b1"):
        return col(0)
    if name in ("wo", "down", "fc2", "out_proj"):
        return col(0)  # row-parallel: shard input (first) dim
    if name in ("wg", "wu", "wd"):  # MoE experts: EP over model
        return P("model", None, None) if _div(shape[0], tp) else P(None, None, None)
    if name in ("conv_x_w",):
        return col(1)
    if name in ("conv_x_b",):
        return col(0)
    if parent == "norm" and len(shape) == 1:  # mamba gated-norm scale (d_inner)
        return col(0)
    # router, w_dkv, in_BC, in_dt, conv_BC_*, dt_bias, A_log, D,
    # norms, biases: replicated
    return P(*([None] * len(shape)))


def param_pspecs(params_shape: PyTree, cfg: ModelConfig, tp: int = 16) -> PyTree:
    """Pytree of PartitionSpecs matching ``jax.eval_shape(model_init)``.

    Leaves under ``groups`` are stacked with a leading ``repeat`` dim —
    their spec gets a ``None`` prefix.
    """

    def one(path, leaf):
        keys = tuple(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path
        )
        stacked = "groups" in keys
        shape = leaf.shape
        if stacked:
            spec = _param_spec(keys, shape[1:], cfg, tp)
            return P(None, *spec)
        return _param_spec(keys, shape, cfg, tp)

    return jax.tree_util.tree_map_with_path(one, params_shape)


# ---------------------------------------------------------------------------
# Batch / cache specs
# ---------------------------------------------------------------------------


def batch_pspecs(cfg: ModelConfig, shape: Shape) -> dict:
    B = P(BATCH_AXES)
    out: dict = {}
    if shape.kind in ("train", "prefill"):
        if cfg.family == "vlm":
            out["embeds"] = P(BATCH_AXES, None, None)
            out["positions"] = P(None, BATCH_AXES, None)
        else:
            out["tokens"] = P(BATCH_AXES, None)
        if cfg.is_encdec:
            out["enc_frames"] = P(BATCH_AXES, None, None)
        if shape.kind == "train":
            out["labels"] = P(BATCH_AXES, None)
        return out
    raise ValueError(shape.kind)


def _cache_leaf_spec(path: tuple[str, ...], shape: tuple[int, ...],
                     cfg: ModelConfig, shape_cfg: Shape, tp: int) -> P:
    """Decode-cache leaf specs. Leaf shapes are stacked: (reps, B, ...)."""
    name = path[-1]
    long_ctx = shape_cfg.global_batch == 1  # long_500k: SP over slots
    batch = None if long_ctx else BATCH_AXES
    if name in ("k", "v"):  # (reps, B, slots, Hkv, Dh)
        heads = "model" if _div(shape[3], tp) else None
        slots = "data" if long_ctx and _div(shape[2], 16) else None
        return P(None, batch, slots, heads, None)
    if name in ("ckv", "krope"):  # (reps, B, slots, r)
        slots = "data" if long_ctx and _div(shape[2], 16) else None
        return P(None, batch, slots, None)
    if name == "conv":  # (reps, B, W-1, conv_dim)
        return P(None, batch, None, "model" if _div(shape[3], tp) else None)
    if name == "ssm":  # (reps, B, H, N, Pdim)
        return P(None, batch, "model" if _div(shape[2], tp) else None, None, None)
    if name == "enc":  # (B, T, d) encoder output (unstacked)
        return P(batch, None, None)
    return P(*([None] * len(shape)))


def cache_pspecs(cache_shape: PyTree, cfg: ModelConfig, shape_cfg: Shape,
                 tp: int = 16) -> PyTree:
    def one(path, leaf):
        keys = tuple(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        return _cache_leaf_spec(keys, leaf.shape, cfg, shape_cfg, tp)

    return jax.tree_util.tree_map_with_path(one, cache_shape)


def opt_pspecs(param_specs: PyTree, params_shape: PyTree, data_size: int) -> dict:
    from repro.optim.adamw import zero1_specs

    return zero1_specs(param_specs, params_shape, data_size)
