"""Sharding-hint seam between model code and the mesh.

Model code annotates activations with *logical* axes; the hints resolve
against whatever mesh is active (``jax.sharding.use_mesh``) and silently
drop axes the mesh doesn't have — so the same model runs on a laptop
(no mesh), a single pod ``(data, model)``, or multi-pod
``(pod, data, model)`` without edits.

Logical axis vocabulary:
* ``BATCH``  -> ``("pod", "data")``  (data parallel, pods included)
* ``TP``     -> ``"model"``          (tensor / expert parallel)
* ``SEQ``    -> ``"data"``           (sequence parallelism for long ctx)
"""

from __future__ import annotations

import jax
from jax.sharding import PartitionSpec as P

BATCH: tuple[str, ...] = ("pod", "data")
TP = "model"
SEQ = "data"

AxisLike = str | tuple[str, ...] | None


def _active_axis_names() -> tuple[str, ...] | None:
    """Axis names usable in sharding constraints: Auto axes of the
    active mesh (Manual axes — e.g. the DP axes inside a Torrent
    subset-shard_map region — must not appear in constraints)."""
    mesh = jax.sharding.get_abstract_mesh()
    if mesh is None or mesh.empty:
        return None
    auto = jax.sharding.AxisType.Auto
    return tuple(
        name
        for name, kind in zip(mesh.axis_names, mesh.axis_types)
        if kind == auto
    )


def resolve_spec(*axes: AxisLike) -> P | None:
    """Resolve logical axes to a PartitionSpec on the active mesh, or
    None when no mesh is active."""
    names = _active_axis_names()
    if names is None:
        return None
    out: list[AxisLike] = []
    for ax in axes:
        if ax is None:
            out.append(None)
        elif isinstance(ax, tuple):
            kept = tuple(a for a in ax if a in names)
            out.append(kept if kept else None)
        else:
            out.append(ax if ax in names else None)
    return P(*out)


def maybe_shard(x: jax.Array, *axes: AxisLike) -> jax.Array:
    """``with_sharding_constraint`` if a mesh is active; no-op otherwise."""
    spec = resolve_spec(*axes)
    if spec is None:
        return x
    return jax.lax.with_sharding_constraint(x, spec)


def dp_axes(axis_names) -> tuple[str, ...]:
    """The data-parallel subset of ``axis_names``, in canonical
    (:data:`BATCH`) order — the ONE definition shared by the gradient
    reduction seam and the MoE expert-parallel dispatch."""
    return tuple(a for a in BATCH if a in axis_names)


def concrete_mesh():
    """The concrete :class:`jax.sharding.Mesh` behind the active
    context (``jax.set_mesh``), when recoverable — the seam model code
    needs to open a nested subset ``shard_map`` (e.g. the Torrent MoE
    expert-parallel dispatch). Returns ``None`` when no concrete mesh
    is reachable, in which case callers must fall back to a
    GSPMD-managed path."""
    # The repo's _jax_compat shim stores the jax.set_mesh mesh on its
    # abstract-mesh wrapper; current jax exposes no reverse lookup
    # from AbstractMesh, so fall back to the legacy resource-env mesh
    # (populated by `with mesh:`, which the compat set_mesh enters).
    mesh = getattr(jax.sharding.get_abstract_mesh(), "_mesh", None)
    if mesh is not None:
        return mesh
    try:
        from jax.interpreters import pxla

        env_mesh = pxla.thread_resources.env.physical_mesh
        if env_mesh is not None and not env_mesh.empty:
            return env_mesh
    except Exception:
        pass
    return None


def manual_axis_names() -> tuple[str, ...]:
    """Axis names currently in Manual (shard_map) mode."""
    mesh = jax.sharding.get_abstract_mesh()
    if mesh is None or mesh.empty:
        return ()
    manual = jax.sharding.AxisType.Manual
    return tuple(
        name
        for name, kind in zip(mesh.axis_names, mesh.axis_types)
        if kind == manual
    )
