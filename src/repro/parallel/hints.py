"""Sharding-hint seam between model code and the mesh.

Model code annotates activations with *logical* axes; the hints resolve
against whatever mesh is active (``jax.sharding.use_mesh``) and silently
drop axes the mesh doesn't have — so the same model runs on a laptop
(no mesh), a single pod ``(data, model)``, or multi-pod
``(pod, data, model)`` without edits.

Logical axis vocabulary:
* ``BATCH``  -> ``("pod", "data")``  (data parallel, pods included)
* ``TP``     -> ``"model"``          (tensor / expert parallel)
* ``SEQ``    -> ``"data"``           (sequence parallelism for long ctx)
"""

from __future__ import annotations

import jax
from jax.sharding import PartitionSpec as P

BATCH: tuple[str, ...] = ("pod", "data")
TP = "model"
SEQ = "data"

AxisLike = str | tuple[str, ...] | None


def _active_axis_names() -> tuple[str, ...] | None:
    """Axis names usable in sharding constraints: Auto axes of the
    active mesh (Manual axes — e.g. the DP axes inside a Torrent
    subset-shard_map region — must not appear in constraints)."""
    mesh = jax.sharding.get_abstract_mesh()
    if mesh is None or mesh.empty:
        return None
    auto = jax.sharding.AxisType.Auto
    return tuple(
        name
        for name, kind in zip(mesh.axis_names, mesh.axis_types)
        if kind == auto
    )


def resolve_spec(*axes: AxisLike) -> P | None:
    """Resolve logical axes to a PartitionSpec on the active mesh, or
    None when no mesh is active."""
    names = _active_axis_names()
    if names is None:
        return None
    out: list[AxisLike] = []
    for ax in axes:
        if ax is None:
            out.append(None)
        elif isinstance(ax, tuple):
            kept = tuple(a for a in ax if a in names)
            out.append(kept if kept else None)
        else:
            out.append(ax if ax in names else None)
    return P(*out)


def maybe_shard(x: jax.Array, *axes: AxisLike) -> jax.Array:
    """``with_sharding_constraint`` if a mesh is active; no-op otherwise."""
    spec = resolve_spec(*axes)
    if spec is None:
        return x
    return jax.lax.with_sharding_constraint(x, spec)
