"""Distribution layer: mesh-axis hints, partition-spec rules
(DP/TP/EP/ZeRO-1/SP), and the collectives backend seam
("xla" vs "torrent" Chainwrite rings)."""

from .collectives import (
    ef_residual_init,
    ef_residual_specs,
    ring_order_for_axis,
    torrent_grad_reduce,
)
from .hints import BATCH, SEQ, TP, maybe_shard, resolve_spec
from .sharding import (
    batch_pspecs,
    cache_pspecs,
    opt_pspecs,
    param_pspecs,
)
