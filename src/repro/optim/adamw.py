"""AdamW with warmup+cosine schedule, global-norm clipping, and ZeRO-1
partition-spec helpers (optimizer state sharded over the data axis).

Pure-pytree implementation (no optax offline). The update is written
shard-local-friendly: every op is elementwise, so ZeRO-1 sharding of
``mu``/``nu`` over the data axis needs no algorithm change — only the
PartitionSpecs from :func:`zero1_specs`.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp

PyTree = Any


@dataclasses.dataclass(frozen=True)
class OptConfig:
    peak_lr: float = 3e-4
    warmup_steps: int = 100
    decay_steps: int = 10_000
    min_lr_ratio: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0


def schedule(cfg: OptConfig, step: jax.Array) -> jax.Array:
    """Linear warmup -> cosine decay to min_lr_ratio * peak."""
    step = step.astype(jnp.float32)
    warm = cfg.peak_lr * step / max(1, cfg.warmup_steps)
    t = jnp.clip(
        (step - cfg.warmup_steps) / max(1, cfg.decay_steps - cfg.warmup_steps),
        0.0,
        1.0,
    )
    cos = cfg.peak_lr * (
        cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (1 + jnp.cos(math.pi * t))
    )
    return jnp.where(step < cfg.warmup_steps, warm, cos)


def init(params: PyTree) -> dict:
    zeros = jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)
    return {
        "mu": zeros,
        "nu": jax.tree.map(jnp.zeros_like, zeros),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree: PyTree) -> jax.Array:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree))
    )


def update(
    cfg: OptConfig,
    grads: PyTree,
    state: dict,
    params: PyTree,
) -> tuple[PyTree, dict, dict]:
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    lr = schedule(cfg, step)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    grads = jax.tree.map(lambda g: g.astype(jnp.float32) * scale, grads)

    b1, b2 = cfg.b1, cfg.b2
    mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state["mu"], grads)
    nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * g * g, state["nu"], grads)
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, m, v):
        mhat = m / bc1
        vhat = v / bc2
        return (
            p.astype(jnp.float32)
            - lr * (mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p)
        ).astype(p.dtype)

    new_params = jax.tree.map(upd, params, mu, nu)
    return (
        new_params,
        {"mu": mu, "nu": nu, "step": step},
        {"lr": lr, "grad_norm": gnorm},
    )


# ---------------------------------------------------------------------------
# ZeRO-1 partition specs
# ---------------------------------------------------------------------------


def zero1_leaf_spec(param_spec, shape: tuple[int, ...], data_size: int,
                    axis: str = "data"):
    """Additionally shard an optimizer leaf over the data axis: pick the
    first dim that is divisible by the data-axis size and not already
    sharded. Falls back to the param's own spec."""
    from jax.sharding import PartitionSpec as P

    existing = tuple(param_spec) if param_spec is not None else (None,) * len(shape)
    existing = existing + (None,) * (len(shape) - len(existing))
    for i, dim in enumerate(shape):
        taken = existing[i]
        if taken is None and dim % data_size == 0 and dim >= data_size:
            new = list(existing)
            new[i] = axis
            return P(*new)
    return P(*existing)


def zero1_specs(param_specs: PyTree, param_shapes: PyTree, data_size: int) -> dict:
    """Specs for the optimizer state pytree given param specs/shapes."""
    mu_specs = jax.tree.map(
        lambda spec, shp: zero1_leaf_spec(spec, shp.shape, data_size),
        param_specs,
        param_shapes,
    )
    from jax.sharding import PartitionSpec as P

    return {"mu": mu_specs, "nu": mu_specs, "step": P()}
