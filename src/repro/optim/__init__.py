from .adamw import OptConfig, global_norm, init, schedule, update, zero1_specs
