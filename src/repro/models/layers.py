"""Shared neural-net layers (pure functions over param pytrees).

Conventions:
* params are nested dicts of f32 arrays; compute casts to bf16
  (``COMPUTE_DTYPE``) at the matmul boundary, norms/softmax in f32;
* initializers take an explicit PRNG key;
* all functions are shape-polymorphic over leading batch dims where
  reasonable.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax

COMPUTE_DTYPE = jnp.bfloat16


def cast(x: jax.Array) -> jax.Array:
    return x.astype(COMPUTE_DTYPE)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def rmsnorm_init(d: int) -> dict:
    return {"scale": jnp.ones((d,), jnp.float32)}


def rmsnorm(params: dict, x: jax.Array, eps: float = 1e-5,
            bf16: bool = False) -> jax.Array:
    if bf16:
        return _rmsnorm_bf16(params["scale"], x, eps)
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * lax.rsqrt(var + eps) * params["scale"]
    return out.astype(x.dtype)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def _rmsnorm_bf16(scale: jax.Array, x: jax.Array, eps: float) -> jax.Array:
    """RMSNorm whose forward AND backward keep every (B,S,d) tensor in
    the input dtype; f32 appears only in rowwise scalars (variance and
    the g·s·x reduction). Without this, the autodiff backward of the
    f32-variance path materializes several f32 (B,S,d) cotangents per
    norm — the dominant memory-term contributor in training (§Perf).
    """
    y, _ = _rmsnorm_bf16_fwd(scale, x, eps)
    return y


def _rmsnorm_inv(scale, x, eps):
    var = jnp.einsum("...d,...d->...", x, x,
                     preferred_element_type=jnp.float32)[..., None]
    var = var / x.shape[-1]
    return lax.rsqrt(var + eps)  # (..., 1) f32


def _rmsnorm_bf16_fwd(scale, x, eps):
    inv = _rmsnorm_inv(scale, x, eps)
    y = x * (inv.astype(x.dtype) * scale.astype(x.dtype))
    return y, (scale, x, inv)


def _rmsnorm_bf16_bwd(eps, res, g):
    scale, x, inv = res
    d = x.shape[-1]
    sb = scale.astype(x.dtype)
    # rowwise t = sum_i g_i s_i x_i  (f32 accumulation, scalar per row)
    t = jnp.einsum("...d,...d->...", g * sb, x,
                   preferred_element_type=jnp.float32)[..., None]
    coeff = (inv ** 3) * (t / d)  # (..., 1) f32
    dx = inv.astype(x.dtype) * sb * g - x * coeff.astype(x.dtype)
    # dscale reduces over all leading dims (f32 accumulation)
    gx = (g * x).astype(jnp.float32) * inv
    dscale = gx.reshape(-1, d).sum(0)
    return dscale.astype(scale.dtype), dx


_rmsnorm_bf16.defvjp(_rmsnorm_bf16_fwd, _rmsnorm_bf16_bwd)


def gated_rmsnorm(params: dict, x: jax.Array, z: jax.Array, eps: float = 1e-5) -> jax.Array:
    """Mamba-2's RMSNorm(x * silu(z))."""
    xf = x.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * lax.rsqrt(var + eps) * params["scale"]
    return out.astype(x.dtype)


def layernorm_init(d: int) -> dict:
    return {"scale": jnp.ones((d,), jnp.float32), "bias": jnp.zeros((d,), jnp.float32)}


def layernorm(params: dict, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = xf.mean(-1, keepdims=True)
    var = ((xf - mu) ** 2).mean(-1, keepdims=True)
    out = (xf - mu) * lax.rsqrt(var + eps) * params["scale"] + params["bias"]
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------


def embedding_init(key: jax.Array, vocab: int, d: int) -> dict:
    return {"table": jax.random.normal(key, (vocab, d), jnp.float32) * 0.02}


def embed(params: dict, tokens: jax.Array) -> jax.Array:
    return cast(params["table"])[tokens]


def unembed(params: dict, x: jax.Array) -> jax.Array:
    """Logits in f32 (loss numerics)."""
    return jnp.einsum(
        "...d,vd->...v", x.astype(jnp.float32), params["table"].astype(jnp.float32)
    )


# ---------------------------------------------------------------------------
# Dense FFNs
# ---------------------------------------------------------------------------


def swiglu_init(key: jax.Array, d: int, ff: int) -> dict:
    kg, ku, kd = jax.random.split(key, 3)
    s_in, s_out = d ** -0.5, ff ** -0.5
    return {
        "gate": jax.random.normal(kg, (d, ff), jnp.float32) * s_in,
        "up": jax.random.normal(ku, (d, ff), jnp.float32) * s_in,
        "down": jax.random.normal(kd, (ff, d), jnp.float32) * s_out,
    }


def swiglu(params: dict, x: jax.Array) -> jax.Array:
    h = jax.nn.silu(x @ cast(params["gate"])) * (x @ cast(params["up"]))
    return h @ cast(params["down"])


def gelu_mlp_init(key: jax.Array, d: int, ff: int) -> dict:
    k1, k2 = jax.random.split(key)
    return {
        "fc1": jax.random.normal(k1, (d, ff), jnp.float32) * d ** -0.5,
        "b1": jnp.zeros((ff,), jnp.float32),
        "fc2": jax.random.normal(k2, (ff, d), jnp.float32) * ff ** -0.5,
        "b2": jnp.zeros((d,), jnp.float32),
    }


def gelu_mlp(params: dict, x: jax.Array) -> jax.Array:
    h = jax.nn.gelu(x @ cast(params["fc1"]) + cast(params["b1"]))
    return h @ cast(params["fc2"]) + cast(params["b2"])


# ---------------------------------------------------------------------------
# RoPE (+ M-RoPE)
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (
        theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim)
    )


def apply_rope(
    x: jax.Array,  # (..., S, H, D)
    positions: jax.Array,  # (..., S)
    theta: float,
) -> jax.Array:
    """Standard rotary embedding over the last dim (pairs split as
    [0:D/2], [D/2:D], llama convention)."""
    D = x.shape[-1]
    freqs = rope_freqs(D, theta)  # (D/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, D/2)
    cos = jnp.cos(angles)[..., None, :]  # (..., S, 1, D/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = x[..., : D // 2], x[..., D // 2 :]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], -1)
    return out.astype(x.dtype)


def apply_mrope(
    x: jax.Array,  # (B, S, H, D)
    positions: jax.Array,  # (3, B, S) — temporal / height / width
    theta: float,
    sections: tuple[int, int, int],
) -> jax.Array:
    """Qwen2-VL multimodal RoPE: the D/2 frequency slots are split into
    three sections, each rotated by its own position stream."""
    D = x.shape[-1]
    assert sum(sections) == D // 2, (sections, D)
    freqs = rope_freqs(D, theta)  # (D/2,)
    # per-frequency-slot position source
    sec_ids = jnp.repeat(
        jnp.arange(3), jnp.asarray(sections), total_repeat_length=D // 2
    )  # (D/2,) in {0,1,2}
    pos = jnp.take_along_axis(
        positions.astype(jnp.float32),  # (3, B, S)
        sec_ids[:, None, None].repeat(positions.shape[1], 1).repeat(positions.shape[2], 2),
        axis=0,
    )  # (D/2, B, S)
    angles = jnp.moveaxis(pos, 0, -1) * freqs  # (B, S, D/2)
    cos = jnp.cos(angles)[..., None, :]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = x[..., : D // 2], x[..., D // 2 :]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], -1)
    return out.astype(x.dtype)
