"""Fine-grained MoE (DeepSeek-MoE style: shared + routed experts, top-k).

Dispatch is sort-based with a static capacity — no (T, E, C) one-hot
tensors, so memory scales with T·k·d (the real dispatch traffic):

1. router top-k → flat assignment list (T·k,),
2. position-in-expert via argsort + searchsorted,
3. scatter into the (E, C, d) expert buffer (``mode='drop'`` enforces
   capacity — overflow assignments are dropped, standard practice),
4. batched expert FFN — one einsum over the expert dim (EP: experts
   sharded over the ``model`` axis; XLA materializes the token exchange
   as all-to-all, or the Torrent chain collective in torrent mode),
5. gather-combine weighted by router probs (``mode='fill'`` zeroes
   dropped assignments).

``moe_apply_ep`` is the *Torrent* expert-parallel formulation: tokens
stay sharded over the DP axes, experts are partitioned over the same
axes, and the dispatch/combine exchanges are explicit scheduled chain
all-to-alls (``parallel.collectives.torrent_all_to_all`` — the
ChainProgram IR's ``plan_all_to_all``), so the MoE token exchange is
OURS instead of a GSPMD resharding. Enabled by
``cfg.moe_ep_dispatch``: inside a Torrent ``shard_map`` region (e.g.
under ``torrent_grad_reduce``) it runs directly on the manual DP axes;
under GSPMD it opens its own nested subset ``shard_map`` over the DP
axes when a concrete mesh is reachable (``hints.concrete_mesh``), and
falls back to the flat path otherwise.

The aux load-balancing loss (switch-style E·Σ f_i·P_i) is returned to
the caller and folded into the training loss.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax import lax

from .config import ModelConfig
from .layers import cast, swiglu, swiglu_init
from repro.parallel.hints import BATCH, SEQ, TP, maybe_shard

_normal = lambda key, shape, scale: jax.random.normal(key, shape, jnp.float32) * scale


def moe_init(key: jax.Array, cfg: ModelConfig) -> dict:
    d, E, f = cfg.d_model, cfg.num_experts, cfg.moe_d_ff
    ks = jax.random.split(key, 5)
    p = {
        "router": _normal(ks[0], (d, E), d ** -0.5),
        "wg": _normal(ks[1], (E, d, f), d ** -0.5),
        "wu": _normal(ks[2], (E, d, f), d ** -0.5),
        "wd": _normal(ks[3], (E, f, d), f ** -0.5),
    }
    if cfg.num_shared_experts:
        p["shared"] = swiglu_init(ks[4], d, cfg.num_shared_experts * f)
    return p


def capacity(cfg: ModelConfig, tokens: int) -> int:
    c = int(math.ceil(tokens * cfg.moe_top_k / cfg.num_experts * cfg.capacity_factor))
    return max(8, -(-c // 8) * 8)  # round up to a multiple of 8


def moe_apply(
    params: dict, x: jax.Array, cfg: ModelConfig
) -> tuple[jax.Array, jax.Array]:
    """x: (B, S, d) -> (out, aux_loss)."""
    if cfg.moe_ep_dispatch:
        return _moe_apply_ep_auto(params, x, cfg)
    if cfg.moe_row_dispatch:
        return moe_apply_rowwise(params, x, cfg)
    return _moe_apply_flat(params, x, cfg)


def _moe_apply_flat(
    params: dict, x: jax.Array, cfg: ModelConfig
) -> tuple[jax.Array, jax.Array]:
    B, S, d = x.shape
    E, k = cfg.num_experts, cfg.moe_top_k
    T = B * S
    C = capacity(cfg, T)
    xf = x.reshape(T, d)

    # -- routing (f32) --------------------------------------------------
    logits = xf.astype(jnp.float32) @ params["router"]  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, k)  # (T, k)
    top_p = top_p / top_p.sum(-1, keepdims=True)  # deepseek renormalizes

    # aux load-balance loss: E * sum_i f_i * P_i
    P_i = probs.mean(0)
    f_i = jnp.zeros((E,), jnp.float32).at[top_e.reshape(-1)].add(1.0) / (T * k)
    aux = cfg.router_aux_loss_coef * E * jnp.sum(f_i * P_i)

    # -- position-in-expert (sort trick, no one-hot) ---------------------
    flat_e = top_e.reshape(-1)  # (T*k,)
    flat_p = top_p.reshape(-1)
    tok_id = jnp.arange(T * k) // k
    sort_idx = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[sort_idx]
    starts = jnp.searchsorted(sorted_e, jnp.arange(E), side="left")
    pos_sorted = jnp.arange(T * k) - starts[sorted_e]
    pos = jnp.zeros((T * k,), jnp.int32).at[sort_idx].set(pos_sorted.astype(jnp.int32))

    # -- dispatch: (E, C, d), capacity drop ------------------------------
    sel = xf[tok_id]  # (T*k, d) — the dispatch wire traffic
    # token-major (T*k) order aligns with xf's batch sharding; tell
    # GSPMD so it doesn't all-gather the token stream to every device.
    sel = maybe_shard(sel, BATCH, None)
    buf = jnp.zeros((E, C, d), x.dtype)
    buf = buf.at[flat_e, pos].set(sel, mode="drop")
    buf = maybe_shard(buf, TP, None, None)  # EP: experts over model axis

    # -- expert FFN (batched over E) -------------------------------------
    h = jax.nn.silu(
        jnp.einsum("ecd,edf->ecf", buf, cast(params["wg"]))
    ) * jnp.einsum("ecd,edf->ecf", buf, cast(params["wu"]))
    out_buf = jnp.einsum("ecf,efd->ecd", h, cast(params["wd"]))
    out_buf = maybe_shard(out_buf, TP, None, None)

    # -- combine ----------------------------------------------------------
    gathered = out_buf.at[flat_e, pos].get(
        mode="fill", fill_value=0
    )  # (T*k, d); dropped -> 0
    gathered = maybe_shard(gathered, BATCH, None)
    if cfg.moe_bf16_wire:
        # keep the (T*k, d) combine wire in bf16; f32 only in the
        # per-token top-k accumulation (same routing, half the traffic)
        out = jnp.einsum(
            "tkd,tk->td", gathered.reshape(T, k, d),
            top_p.astype(gathered.dtype),
            preferred_element_type=jnp.float32,
        )
    else:
        weighted = gathered.astype(jnp.float32) * flat_p[:, None]
        out = weighted.reshape(T, k, d).sum(1)

    if cfg.num_shared_experts:
        out = out + swiglu(params["shared"], xf).astype(jnp.float32)
    out = out.astype(x.dtype).reshape(B, S, d)
    out = maybe_shard(out, BATCH, None, None)
    return out, aux


def moe_apply_rowwise(
    params: dict, x: jax.Array, cfg: ModelConfig
) -> tuple[jax.Array, jax.Array]:
    """Row-wise (per-batch-row) dispatch — the shardable formulation.

    The flat dispatch computes global capacity positions, so GSPMD
    cannot shard the (E, C, d) buffer's capacity dim: every DP group
    redundantly runs the *global* expert batch (16× flops on the
    production mesh), and forcing the sharding turns the scatter into
    a collective storm (§Perf deepseek iterations 3–4, both refuted).

    Routing each batch row independently makes every scatter/gather
    index row-local, so the expert buffer (B, E, C_row, d) shards
    cleanly as (BATCH, TP/EP, —, —): expert flops divide over the DP
    axes AND experts, with no cross-row collectives beyond the einsum's
    own. Capacity is per-row (C_row = S·k/E · factor), a slightly
    stricter balance assumption than global capacity — same top-k
    routing, same aux loss.
    """
    B, S, d = x.shape
    E, k = cfg.num_experts, cfg.moe_top_k
    C = capacity(cfg, S)

    # -- routing (f32, all rows at once) --------------------------------
    logits = x.reshape(B * S, d).astype(jnp.float32) @ params["router"]
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, k)  # (B*S, k)
    top_p = top_p / top_p.sum(-1, keepdims=True)

    P_i = probs.mean(0)
    f_i = jnp.zeros((E,), jnp.float32).at[top_e.reshape(-1)].add(1.0) / (B * S * k)
    aux = cfg.router_aux_loss_coef * E * jnp.sum(f_i * P_i)

    # -- per-row position-in-expert (indices stay < S*k: row-local) -----
    flat_e = top_e.reshape(B, S * k)
    flat_p = top_p.reshape(B, S * k).astype(x.dtype)
    tok_id = jnp.arange(S * k) // k  # (S*k,) same for every row

    def row_pos(e_row):
        sort_idx = jnp.argsort(e_row, stable=True)
        sorted_e = e_row[sort_idx]
        starts = jnp.searchsorted(sorted_e, jnp.arange(E), side="left")
        pos_sorted = jnp.arange(S * k) - starts[sorted_e]
        return jnp.zeros((S * k,), jnp.int32).at[sort_idx].set(
            pos_sorted.astype(jnp.int32))

    pos = jax.vmap(row_pos)(flat_e)  # (B, S*k)

    # -- dispatch: (B, E, C, d) sharded (batch, experts, -, -) -----------
    xk = jnp.take_along_axis(
        x, jnp.broadcast_to(tok_id[None, :, None], (B, S * k, 1)), axis=1
    )  # (B, S*k, d)
    buf = jnp.zeros((B, E, C, d), x.dtype)
    buf = jax.vmap(lambda b, e, p, v: b.at[e, p].set(v, mode="drop"))(
        buf, flat_e, pos, xk)
    buf = maybe_shard(buf, BATCH, TP, None, None)

    # -- expert FFN: flops shard over DP (b) and EP (e) ------------------
    h = jax.nn.silu(
        jnp.einsum("becd,edf->becf", buf, cast(params["wg"]))
    ) * jnp.einsum("becd,edf->becf", buf, cast(params["wu"]))
    out_buf = jnp.einsum("becf,efd->becd", h, cast(params["wd"]))
    out_buf = maybe_shard(out_buf, BATCH, TP, None, None)

    # -- combine (row-local gather, bf16 wire, f32 top-k accumulation) --
    gathered = jax.vmap(
        lambda b, e, p: b.at[e, p].get(mode="fill", fill_value=0)
    )(out_buf, flat_e, pos)  # (B, S*k, d)
    gathered = maybe_shard(gathered, BATCH, None, None)
    out = jnp.einsum(
        "bskd,bsk->bsd", gathered.reshape(B, S, k, d),
        flat_p.reshape(B, S, k), preferred_element_type=jnp.float32,
    )

    if cfg.num_shared_experts:
        out = out + swiglu(params["shared"], x.reshape(B * S, d)).reshape(
            B, S, d).astype(jnp.float32)
    out = out.astype(x.dtype)
    out = maybe_shard(out, BATCH, None, None)
    return out, aux


# ---------------------------------------------------------------------------
# Torrent expert-parallel dispatch (chain all-to-all over the DP axes)
# ---------------------------------------------------------------------------


def _bucket_capacity(assignments: int, buckets: int, factor: float) -> int:
    """Static per-bucket capacity for ``assignments`` spread over
    ``buckets`` (same rounding policy as :func:`capacity`)."""
    c = int(math.ceil(assignments / buckets * factor))
    return max(8, -(-c // 8) * 8)


def moe_apply_ep(
    params: dict,
    x: jax.Array,
    cfg: ModelConfig,
    axis_name,
    *,
    num_chains: int = 1,
    scheduler: str = "tsp",
    wire_dtype: str | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Expert-parallel MoE — must run INSIDE ``shard_map`` over
    ``axis_name``: ``x`` is this shard's local ``(B_loc, S, d)`` tokens
    and the ``num_experts`` routed experts are partitioned contiguously
    over the axis (device ``i`` owns experts ``[i·E/n, (i+1)·E/n)``).

    Dispatch is two explicit Torrent chain all-to-alls
    (``parallel.collectives.torrent_all_to_all``; ``num_chains > 1``
    uses the K-ring schedule): tokens travel to their experts' owners,
    outputs travel back, and combine happens at the source with the
    router weights that never left. Capacity is enforced twice with the
    standard drop policy — per (source, destination) pair on the wire
    (``C_pair``) and per local expert at the receiver (``C_loc``) —
    both with ``cfg.capacity_factor`` headroom.

    ``wire_dtype="int8"`` ships the token payloads of BOTH exchanges
    (dispatch and return) quantized per hop — 4× fewer activation bytes
    on the wire; the ``send_e`` expert-id exchange is integer metadata
    and always travels exact.

    The aux loss is the *global* load-balance loss: the per-shard
    ``f_i``/``P_i`` statistics are ``pmean``-ed over the axis before
    the product, so it matches the single-device computation exactly
    (equal shard sizes).
    """
    from repro.core import chainwrite as cw
    from repro.parallel.collectives import torrent_all_to_all

    B, S, d = x.shape
    E, k = cfg.num_experts, cfg.moe_top_k
    n = cw._axis_size(axis_name)
    me = cw._axis_index(axis_name)
    if E % n:
        raise ValueError(f"num_experts={E} not divisible by EP group size {n}")
    E_loc = E // n
    T = B * S
    xf = x.reshape(T, d)

    # -- routing (f32, local tokens; global aux via pmean'd stats) ------
    logits = xf.astype(jnp.float32) @ params["router"]  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, k)  # (T, k)
    top_p = top_p / top_p.sum(-1, keepdims=True)

    P_i = jax.lax.pmean(probs.mean(0), axis_name)
    f_loc = jnp.zeros((E,), jnp.float32).at[top_e.reshape(-1)].add(1.0) / (T * k)
    f_i = jax.lax.pmean(f_loc, axis_name)
    aux = cfg.router_aux_loss_coef * E * jnp.sum(f_i * P_i)

    # -- dispatch: (n, C_pair, d) per-destination send buffers ----------
    flat_e = top_e.reshape(-1)  # (T*k,)
    flat_p = top_p.reshape(-1)
    tok_id = jnp.arange(T * k) // k
    dest = (flat_e // E_loc).astype(jnp.int32)  # owner device per assignment
    sort_idx = jnp.argsort(dest, stable=True)
    sorted_d = dest[sort_idx]
    starts = jnp.searchsorted(sorted_d, jnp.arange(n), side="left")
    pos_sorted = jnp.arange(T * k) - starts[sorted_d]
    pos = jnp.zeros((T * k,), jnp.int32).at[sort_idx].set(
        pos_sorted.astype(jnp.int32))

    C_pair = _bucket_capacity(T * k, n, cfg.capacity_factor)
    send = jnp.zeros((n, C_pair, d), x.dtype).at[dest, pos].set(
        xf[tok_id], mode="drop")
    send_e = jnp.full((n, C_pair), -1, jnp.int32).at[dest, pos].set(
        flat_e.astype(jnp.int32), mode="drop")

    # -- the wire: tokens (and their expert ids) to the expert owners --
    recv = torrent_all_to_all(
        send, axis_name, num_chains=num_chains, scheduler=scheduler,
        wire_dtype=wire_dtype)
    recv_e = torrent_all_to_all(
        send_e, axis_name, num_chains=num_chains, scheduler=scheduler)

    # -- receiver-side dispatch into the (E_loc, C_loc, d) buffer -------
    re = recv_e.reshape(-1)  # (n*C_pair,)
    le = re - me * E_loc  # local expert index
    valid = (re >= 0) & (le >= 0) & (le < E_loc)
    C_loc = _bucket_capacity(n * C_pair, E_loc, cfg.capacity_factor)
    key = jnp.where(valid, le, E_loc).astype(jnp.int32)
    sort2 = jnp.argsort(key, stable=True)
    sorted_k = key[sort2]
    starts2 = jnp.searchsorted(sorted_k, jnp.arange(E_loc), side="left")
    pos2_sorted = jnp.arange(n * C_pair) - starts2[
        jnp.clip(sorted_k, 0, E_loc - 1)]
    pos2 = jnp.zeros((n * C_pair,), jnp.int32).at[sort2].set(
        pos2_sorted.astype(jnp.int32))
    le_s = jnp.where(valid, le, E_loc).astype(jnp.int32)  # OOB -> dropped
    pos2 = jnp.where(valid, pos2, C_loc)

    rx = recv.reshape(n * C_pair, d)
    buf = jnp.zeros((E_loc, C_loc, d), x.dtype).at[le_s, pos2].set(
        rx, mode="drop")

    # -- local expert FFN (params replicated; slice my expert block) ----
    wg = lax.dynamic_slice_in_dim(cast(params["wg"]), me * E_loc, E_loc, 0)
    wu = lax.dynamic_slice_in_dim(cast(params["wu"]), me * E_loc, E_loc, 0)
    wd = lax.dynamic_slice_in_dim(cast(params["wd"]), me * E_loc, E_loc, 0)
    h = jax.nn.silu(
        jnp.einsum("ecd,edf->ecf", buf, wg)
    ) * jnp.einsum("ecd,edf->ecf", buf, wu)
    out_buf = jnp.einsum("ecf,efd->ecd", h, wd)

    # -- results back to the token owners, combine at the source --------
    back = out_buf.at[le_s, pos2].get(
        mode="fill", fill_value=0).reshape(n, C_pair, d)
    ret = torrent_all_to_all(
        back, axis_name, num_chains=num_chains, scheduler=scheduler,
        wire_dtype=wire_dtype)
    gathered = ret.at[dest, pos].get(mode="fill", fill_value=0)  # (T*k, d)
    weighted = gathered.astype(jnp.float32) * flat_p[:, None]
    out = weighted.reshape(T, k, d).sum(1)

    if cfg.num_shared_experts:
        out = out + swiglu(params["shared"], xf).astype(jnp.float32)
    out = out.astype(x.dtype).reshape(B, S, d)
    return out, aux


def _moe_apply_ep_auto(
    params: dict, x: jax.Array, cfg: ModelConfig
) -> tuple[jax.Array, jax.Array]:
    """Route ``cfg.moe_ep_dispatch`` to the right execution context:

    * DP axes already Manual (inside a Torrent subset ``shard_map``,
      e.g. under ``torrent_grad_reduce``): call :func:`moe_apply_ep`
      directly — ``x`` is already the local token shard;
    * DP axes Auto under GSPMD with a reachable concrete mesh: open a
      nested subset ``shard_map`` over the DP axes around
      :func:`moe_apply_ep`;
    * anything else (no mesh, no DP axes, indivisible experts/batch):
      fall back to the GSPMD-managed paths.
    """
    from jax.sharding import PartitionSpec as P

    from repro.parallel import hints

    def fallback():
        if cfg.moe_row_dispatch:
            return moe_apply_rowwise(params, x, cfg)
        return _moe_apply_flat(params, x, cfg)

    mesh = jax.sharding.get_abstract_mesh()
    if mesh is None or mesh.empty:
        return fallback()
    dp = hints.dp_axes(mesh.axis_names)
    if not dp:
        return fallback()
    axis = dp if len(dp) > 1 else dp[0]
    manual = set(hints.manual_axis_names())
    def ep_chains(group: int) -> int:
        # moe_ep_chains must divide the EP group; degrade to the
        # single ring rather than crash at trace time.
        k = cfg.moe_ep_chains
        return k if k > 1 and group % k == 0 else 1

    ep_wire = "int8" if cfg.moe_ep_int8_wire else None

    if all(a in manual for a in dp):
        group = 1
        for a in dp:
            group *= mesh.shape.get(a, 1)
        if cfg.num_experts % group:  # documented graceful fallback
            return fallback()
        return moe_apply_ep(
            params, x, cfg, axis, num_chains=ep_chains(group),
            wire_dtype=ep_wire)
    if any(a in manual for a in dp):
        return fallback()  # partially manual: no coherent EP axis

    concrete = hints.concrete_mesh()
    if concrete is None:
        return fallback()
    dp_size = 1
    for a in dp:
        dp_size *= concrete.shape[a]
    if cfg.num_experts % dp_size or x.shape[0] % dp_size:
        return fallback()

    def inner(p, xs):
        return moe_apply_ep(
            p, xs, cfg, axis, num_chains=ep_chains(dp_size),
            wire_dtype=ep_wire)

    xspec = P(dp if len(dp) > 1 else dp[0], None, None)
    return jax.shard_map(
        inner,
        mesh=concrete,
        in_specs=(P(), xspec),
        out_specs=(xspec, P()),
        axis_names=set(dp),
        check_vma=False,
    )(params, x)


def moe_ref(params: dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Oracle: dense per-token loop over top-k experts (no capacity)."""
    B, S, d = x.shape
    xf = x.reshape(-1, d)
    logits = xf.astype(jnp.float32) @ params["router"]
    probs = jax.nn.softmax(logits, -1)
    top_p, top_e = jax.lax.top_k(probs, cfg.moe_top_k)
    top_p = top_p / top_p.sum(-1, keepdims=True)
    out = jnp.zeros_like(xf, jnp.float32)
    for e in range(cfg.num_experts):
        he = jax.nn.silu(xf @ cast(params["wg"][e])) * (xf @ cast(params["wu"][e]))
        ye = (he @ cast(params["wd"][e])).astype(jnp.float32)
        w = jnp.where(top_e == e, top_p, 0.0).sum(-1)
        out = out + ye * w[:, None]
    if cfg.num_shared_experts:
        out = out + swiglu(params["shared"], xf).astype(jnp.float32)
    return out.astype(x.dtype).reshape(B, S, d)
