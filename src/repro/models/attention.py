"""Attention mixers: GQA (+RoPE/M-RoPE/SWA) and MLA (DeepSeek-V2).

Each mixer exposes ``*_init`` and ``*_apply``; apply handles both the
full-sequence path (training / prefill — optionally through the Pallas
flash kernel) and the single-token decode path (KV cache update). KV
caches for SWA archs are ring buffers of ``window`` slots, which is what
makes ``long_500k`` decode O(window) instead of O(S).

MLA decode is the paper's own FPGA workload (P3/D3 "KV_Matrix_MLA
Recovery"): the compressed KV (rank ``kv_lora + qk_rope``) is the only
thing cached; per-head K/V are *recovered* by up-projection at use —
under tensor parallelism the compressed cache is multicast to all
shards (Chainwrite) and every shard recovers only its heads' slice.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import apply_mrope, apply_rope, cast
from repro.kernels.flash_attention.chunked import attention_chunked
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.flash_attention.ops import flash_attention

NEG_INF = -1e30


def _full_attention(qt, kt, vt, cfg: ModelConfig, *, causal: bool):
    """Dispatch on cfg.attn_impl: 'reference' (materialized S² scores),
    'chunked' (online-softmax lax.scan — the lowerable flash twin), or
    'flash' (Pallas kernel; interpret mode off-TPU).

    With ``cfg.attn_seq_shard`` the query *sequence* is sharded over the
    TP axis instead of heads (K/V replicated) — the right layout when
    the head count doesn't divide TP (qwen2-vl: 28 heads on 16-way),
    where head sharding would silently all-gather full activations.
    """
    from repro.parallel.hints import BATCH, TP, maybe_shard

    if cfg.attn_seq_shard:
        qt = maybe_shard(qt, BATCH, None, TP, None)
        kt = maybe_shard(kt, BATCH, None, None, None)
        vt = maybe_shard(vt, BATCH, None, None, None)
    if cfg.attn_impl == "flash":
        out = flash_attention(qt, kt, vt, causal=causal, window=cfg.sliding_window)
    elif cfg.attn_impl == "chunked":
        out = attention_chunked(
            qt, kt, vt, causal=causal, window=cfg.sliding_window,
            chunk=cfg.attn_chunk,
        )
    else:
        out = attention_ref(qt, kt, vt, causal=causal, window=cfg.sliding_window)
    if cfg.attn_seq_shard:
        out = maybe_shard(out, BATCH, None, TP, None)
    return out


def _normal(key, shape, scale):
    return jax.random.normal(key, shape, jnp.float32) * scale


# ---------------------------------------------------------------------------
# GQA
# ---------------------------------------------------------------------------


def gqa_init(key: jax.Array, cfg: ModelConfig) -> dict:
    d, H, Hkv, Dh = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": _normal(ks[0], (d, H * Dh), d ** -0.5),
        "wk": _normal(ks[1], (d, Hkv * Dh), d ** -0.5),
        "wv": _normal(ks[2], (d, Hkv * Dh), d ** -0.5),
        "wo": _normal(ks[3], (H * Dh, d), (H * Dh) ** -0.5),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H * Dh,), jnp.float32)
        p["bk"] = jnp.zeros((Hkv * Dh,), jnp.float32)
        p["bv"] = jnp.zeros((Hkv * Dh,), jnp.float32)
    return p


def _project_qkv(params, x, cfg: ModelConfig):
    B, S, _ = x.shape
    H, Hkv, Dh = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    q = x @ cast(params["wq"])
    k = x @ cast(params["wk"])
    v = x @ cast(params["wv"])
    if cfg.qkv_bias:
        q = q + cast(params["bq"])
        k = k + cast(params["bk"])
        v = v + cast(params["bv"])
    return (
        q.reshape(B, S, H, Dh),
        k.reshape(B, S, Hkv, Dh),
        v.reshape(B, S, Hkv, Dh),
    )


def _rope_qk(q, k, positions, cfg: ModelConfig):
    if cfg.pos_scheme == "mrope":
        q = apply_mrope(q, positions, cfg.rope_theta, cfg.mrope_sections)
        k = apply_mrope(k, positions, cfg.rope_theta, cfg.mrope_sections)
    elif cfg.pos_scheme == "rope":
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    # 'learned' / 'none': positions handled at the embedding level.
    return q, k


def gqa_apply(
    params: dict,
    x: jax.Array,  # (B, S, d)
    positions: jax.Array,  # (B, S) or (3, B, S) for M-RoPE
    cfg: ModelConfig,
    *,
    causal: bool = True,
) -> jax.Array:
    """Full-sequence GQA (training / prefill), no cache."""
    q, k, v = _project_qkv(params, x, cfg)
    q, k = _rope_qk(q, k, positions, cfg)
    qt, kt, vt = (t.transpose(0, 2, 1, 3) for t in (q, k, v))  # (B,H,S,D)
    out = _full_attention(qt, kt, vt, cfg, causal=causal)
    B, S = x.shape[:2]
    out = out.transpose(0, 2, 1, 3).reshape(B, S, -1)
    return out @ cast(params["wo"])


def gqa_prefill(
    params: dict,
    x: jax.Array,
    positions: jax.Array,
    cfg: ModelConfig,
    max_seq: int,
) -> tuple[jax.Array, dict]:
    """Full-sequence attention that also emits the decode KV cache
    (ring-buffer layout for SWA archs)."""
    B, S, _ = x.shape
    q, k, v = _project_qkv(params, x, cfg)
    q, k = _rope_qk(q, k, positions, cfg)
    qt, kt, vt = (t.transpose(0, 2, 1, 3) for t in (q, k, v))
    out = _full_attention(qt, kt, vt, cfg, causal=True)
    out = out.transpose(0, 2, 1, 3).reshape(B, S, -1) @ cast(params["wo"])

    slots = min(max_seq, cfg.sliding_window) if cfg.sliding_window else max_seq
    cache = gqa_init_cache(cfg, B, max_seq)
    keep = jnp.arange(max(0, S - slots), S)  # last `slots` tokens
    slot_ids = keep % slots
    ck = cache["k"].at[:, slot_ids].set(k[:, keep].astype(jnp.bfloat16))
    cv = cache["v"].at[:, slot_ids].set(v[:, keep].astype(jnp.bfloat16))
    return out, {"k": ck, "v": cv}


def gqa_init_cache(cfg: ModelConfig, batch: int, max_seq: int) -> dict:
    slots = min(max_seq, cfg.sliding_window) if cfg.sliding_window else max_seq
    Hkv, Dh = cfg.num_kv_heads, cfg.resolved_head_dim
    shape = (batch, slots, Hkv, Dh)
    return {
        "k": jnp.zeros(shape, jnp.bfloat16),
        "v": jnp.zeros(shape, jnp.bfloat16),
    }


def gqa_decode(
    params: dict,
    x: jax.Array,  # (B, 1, d)
    pos: jax.Array,  # scalar int32 — or (B,) per-slot absolute positions
    cache: dict,
    cfg: ModelConfig,
) -> tuple[jax.Array, dict]:
    """Single-token decode with (ring-buffer for SWA) KV cache.

    ``pos`` is either a scalar (every row at the same absolute position
    — the packed-batch path) or a ``(B,)`` vector of per-slot positions
    (continuous batching: each slot advances independently, so an
    admission never disturbs an in-flight row)."""
    B = x.shape[0]
    H, Hkv, Dh = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    q, k, v = _project_qkv(params, x, cfg)  # (B,1,*,Dh)
    pos = jnp.asarray(pos, jnp.int32)
    per_slot = pos.ndim == 1
    if cfg.mrope_sections is not None:
        if per_slot:
            raise NotImplementedError("per-slot decode with M-RoPE")
        pos_b = jnp.broadcast_to(pos, (3, B, 1)).astype(jnp.int32)
    else:
        pos_b = pos[:, None] if per_slot else jnp.full((B, 1), pos, jnp.int32)
    q, k = _rope_qk(q, k, pos_b, cfg)

    slots = cache["k"].shape[1]
    if per_slot:
        slot_b = pos % slots  # (B,) ring-buffer slot per row
        ck = cache["k"].at[jnp.arange(B), slot_b].set(
            k[:, 0].astype(cache["k"].dtype)
        )
        cv = cache["v"].at[jnp.arange(B), slot_b].set(
            v[:, 0].astype(cache["v"].dtype)
        )
    else:
        slot = pos % slots  # ring buffer for SWA; identity when slots == max_seq
        ck = jax.lax.dynamic_update_slice(cache["k"], k, (0, slot, 0, 0))
        cv = jax.lax.dynamic_update_slice(cache["v"], v, (0, slot, 0, 0))

    group = H // Hkv
    qh = q[:, 0].reshape(B, Hkv, group, Dh)
    # bf16 reads with f32 accumulation — no f32 copy of the cache
    # (dtype hygiene: cuts decode HBM traffic ~3x vs materialized casts).
    scores = jnp.einsum(
        "bhgd,bshd->bhgs", qh.astype(ck.dtype), ck,
        preferred_element_type=jnp.float32,
    ) * (Dh ** -0.5)
    # Valid slots: written positions only (a ring buffer is fully valid
    # once wrapped; before wrapping, slots > pos are empty).
    slot_ids = jnp.arange(slots)
    if per_slot:
        valid = (pos_b >= slots) | (slot_ids[None, :] <= pos_b)  # (B, slots)
        scores = jnp.where(valid[:, None, None, :], scores, NEG_INF)
    else:
        valid = jnp.where(pos >= slots, jnp.ones((slots,), bool), slot_ids <= pos)
        scores = jnp.where(valid[None, None, None, :], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgs,bshd->bhgd", p.astype(cv.dtype), cv,
                     preferred_element_type=jnp.float32)
    out = out.reshape(B, 1, H * Dh).astype(x.dtype)
    return out @ cast(params["wo"]), {"k": ck, "v": cv}


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2 multi-head latent attention)
# ---------------------------------------------------------------------------


def mla_init(key: jax.Array, cfg: ModelConfig) -> dict:
    d, H = cfg.d_model, cfg.num_heads
    r = cfg.kv_lora_rank
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    ks = jax.random.split(key, 5)
    return {
        "wq": _normal(ks[0], (d, H * (dn + dr)), d ** -0.5),
        "w_dkv": _normal(ks[1], (d, r + dr), d ** -0.5),  # compress (+ shared rope key)
        "w_uk": _normal(ks[2], (r, H * dn), r ** -0.5),  # K recovery
        "w_uv": _normal(ks[3], (r, H * dv), r ** -0.5),  # V recovery
        "wo": _normal(ks[4], (H * dv, d), (H * dv) ** -0.5),
    }


def _mla_qkv(params, x, positions, cfg: ModelConfig):
    B, S, _ = x.shape
    H = cfg.num_heads
    r = cfg.kv_lora_rank
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    q = (x @ cast(params["wq"])).reshape(B, S, H, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    ckv = x @ cast(params["w_dkv"])  # (B, S, r + dr)
    c, k_rope = ckv[..., :r], ckv[..., r:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    k_rope = apply_rope(k_rope[:, :, None, :], positions, cfg.rope_theta)[:, :, 0]
    return q_nope, q_rope, c, k_rope


def _mla_attend(params, q_nope, q_rope, c, k_rope, cfg: ModelConfig,
                mask: jax.Array | None):
    """Attention over recovered K/V. c: (B,T,r); k_rope: (B,T,dr);
    q_*: (B,S,H,*). mask: (S,T) or per-row (B,S,T) boolean, or None (full)."""
    if cfg.attn_impl == "chunked" and mask is not None and mask.ndim == 2:
        return _mla_attend_chunked(params, q_nope, q_rope, c, k_rope, cfg)
    B, T = c.shape[:2]
    H = cfg.num_heads
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    # KV recovery (the paper's P3/D3 multicast workload under TP).
    k_nope = (c @ cast(params["w_uk"])).reshape(B, T, H, dn)
    v = (c @ cast(params["w_uv"])).reshape(B, T, H, dv)
    scale = (dn + dr) ** -0.5
    s = (
        jnp.einsum("bshd,bthd->bhst", q_nope.astype(jnp.float32), k_nope.astype(jnp.float32))
        + jnp.einsum("bshd,btd->bhst", q_rope.astype(jnp.float32), k_rope.astype(jnp.float32))
    ) * scale
    if mask is not None:
        m = mask[:, None] if mask.ndim == 3 else mask[None, None]
        s = jnp.where(m, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhst,bthd->bshd", p, v.astype(jnp.float32))
    return out.reshape(B, -1, H * dv).astype(q_nope.dtype) @ cast(params["wo"])


def _mla_attend_chunked(params, q_nope, q_rope, c, k_rope, cfg: ModelConfig):
    """Causal MLA attention, online-softmax over T chunks.

    Recovery ("the paper's multicast operand") happens per KV chunk
    inside the scan — same math and total FLOPs as :func:`_mla_attend`,
    but nothing quadratic (or proportional to T·H·dn) is materialized.
    Assumes S == T with a causal mask (training / prefill)."""
    B, T = c.shape[:2]
    S = q_nope.shape[1]
    assert S == T, (S, T)
    H = cfg.num_heads
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    scale = (dn + dr) ** -0.5
    C = min(cfg.attn_chunk, T)
    pad = (-T) % C
    Tp = T + pad
    if pad:
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0)))
        k_rope = jnp.pad(k_rope, ((0, 0), (0, pad), (0, 0)))
    nc = Tp // C
    cc = jnp.moveaxis(c.reshape(B, nc, C, -1), 1, 0)  # (nc,B,C,r)
    krc = jnp.moveaxis(k_rope.reshape(B, nc, C, dr), 1, 0)
    starts = jnp.arange(nc) * C
    rows = jnp.arange(S)[:, None]
    qn = q_nope.astype(jnp.float32) * scale  # (B,S,H,dn)
    qr = q_rope.astype(jnp.float32) * scale  # (B,S,H,dr)

    def body(carry, xs):
        m, l, acc = carry
        cb, krb, start = xs  # (B,C,r), (B,C,dr)
        k_nope = (cb @ cast(params["w_uk"])).reshape(B, C, H, dn)
        vb = (cb @ cast(params["w_uv"])).reshape(B, C, H, dv)
        s = (
            jnp.einsum("bshd,bthd->bhst", qn, k_nope.astype(jnp.float32))
            + jnp.einsum("bshd,btd->bhst", qr, krb.astype(jnp.float32))
        )  # (B,H,S,C)
        cols = start + jnp.arange(C)[None, :]
        mask = (cols < T) & (cols <= rows)
        s = jnp.where(mask[None, None], s, NEG_INF)
        m_cur = s.max(-1, keepdims=True)
        m_new = jnp.maximum(m, m_cur)
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m - m_new)
        l_new = alpha * l + p.sum(-1, keepdims=True)
        acc_new = acc * alpha + jnp.einsum(
            "bhst,bthd->bhsd", p, vb.astype(jnp.float32)
        )
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, H, S, 1), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, H, S, 1), jnp.float32)
    acc0 = jnp.zeros((B, H, S, dv), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, acc0), (cc, krc, starts))
    l = jnp.where(l == 0.0, 1.0, l)
    out = (acc / l).transpose(0, 2, 1, 3)  # (B,S,H,dv)
    return out.reshape(B, S, H * dv).astype(q_nope.dtype) @ cast(params["wo"])


def mla_apply(
    params: dict,
    x: jax.Array,
    positions: jax.Array,
    cfg: ModelConfig,
    *,
    causal: bool = True,
) -> jax.Array:
    S = x.shape[1]
    q_nope, q_rope, c, k_rope = _mla_qkv(params, x, positions, cfg)
    mask = None
    if causal:
        mask = jnp.tril(jnp.ones((S, S), bool))
    return _mla_attend(params, q_nope, q_rope, c, k_rope, cfg, mask)


def mla_prefill(
    params: dict,
    x: jax.Array,
    positions: jax.Array,
    cfg: ModelConfig,
    max_seq: int,
) -> tuple[jax.Array, dict]:
    B, S, _ = x.shape
    q_nope, q_rope, c, k_rope = _mla_qkv(params, x, positions, cfg)
    mask = jnp.tril(jnp.ones((S, S), bool))
    out = _mla_attend(params, q_nope, q_rope, c, k_rope, cfg, mask)
    cache = mla_init_cache(cfg, B, max_seq)
    ckv = cache["ckv"].at[:, :S].set(c.astype(jnp.bfloat16))
    krope = cache["krope"].at[:, :S].set(k_rope.astype(jnp.bfloat16))
    return out, {"ckv": ckv, "krope": krope}


def mla_init_cache(cfg: ModelConfig, batch: int, max_seq: int) -> dict:
    r, dr = cfg.kv_lora_rank, cfg.qk_rope_head_dim
    return {
        "ckv": jnp.zeros((batch, max_seq, r), jnp.bfloat16),
        "krope": jnp.zeros((batch, max_seq, dr), jnp.bfloat16),
    }


def mla_decode(
    params: dict,
    x: jax.Array,  # (B, 1, d)
    pos: jax.Array,
    cache: dict,
    cfg: ModelConfig,
) -> tuple[jax.Array, dict]:
    B = x.shape[0]
    pos = jnp.asarray(pos, jnp.int32)
    per_slot = pos.ndim == 1  # (B,) per-slot positions (continuous batching)
    pos_b = pos[:, None] if per_slot else jnp.full((B, 1), pos, jnp.int32)
    q_nope, q_rope, c, k_rope = _mla_qkv(params, x, pos_b, cfg)
    if per_slot:
        rows = jnp.arange(B)
        cckv = cache["ckv"].at[rows, pos].set(
            c[:, 0].astype(cache["ckv"].dtype))
        ckrope = cache["krope"].at[rows, pos].set(
            k_rope[:, 0].astype(cache["krope"].dtype))
    else:
        cckv = jax.lax.dynamic_update_slice(
            cache["ckv"], c.astype(cache["ckv"].dtype), (0, pos, 0))
        ckrope = jax.lax.dynamic_update_slice(
            cache["krope"], k_rope.astype(cache["krope"].dtype), (0, pos, 0))
    new_cache = {"ckv": cckv, "krope": ckrope}
    if cfg.mla_absorb:
        return _mla_decode_absorbed(
            params, q_nope, q_rope, cckv, ckrope, pos, cfg
        ), new_cache
    T = cckv.shape[1]
    if per_slot:
        mask = jnp.arange(T)[None, None, :] <= pos[:, None, None]  # (B, 1, T)
    else:
        mask = (jnp.arange(T) <= pos)[None, :]  # (1, T)
    out = _mla_attend(params, q_nope, q_rope, cckv, ckrope, cfg, mask)
    return out, new_cache


def _mla_decode_absorbed(params, q_nope, q_rope, cckv, ckrope, pos,
                         cfg: ModelConfig):
    """Weight-absorbed MLA decode (beyond-paper; exact same math).

    Instead of recovering per-head K/V for the whole cache
    (2·T·H·(dn+dv) values — the paper's P3/D3 recovery traffic), absorb
    W_uk into the query and W_uv into the output: attention runs
    directly against the compressed (r + dr)-wide cache, cutting decode
    HBM traffic by ~2·H·(dn+dv)/(r+dr) ≈ 7× for deepseek-v2-lite."""
    B = q_nope.shape[0]
    H = cfg.num_heads
    r = cfg.kv_lora_rank
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    T = cckv.shape[1]
    scale = (dn + dr) ** -0.5
    w_uk = cast(params["w_uk"]).reshape(r, H, dn)
    w_uv = cast(params["w_uv"]).reshape(r, H, dv)
    # q ⟵ q · W_uk  (B,H,r): per-step cost H·dn·r, independent of T
    q_c = jnp.einsum("bhd,rhd->bhr", q_nope[:, 0], w_uk,
                     preferred_element_type=jnp.float32)
    s = (
        jnp.einsum("bhr,btr->bht", q_c.astype(cckv.dtype), cckv,
                   preferred_element_type=jnp.float32)
        + jnp.einsum("bhd,btd->bht", q_rope[:, 0].astype(ckrope.dtype),
                     ckrope, preferred_element_type=jnp.float32)
    ) * scale
    pos_b = jnp.asarray(pos, jnp.int32).reshape(-1, 1)  # (B,1) or (1,1)
    mask = (jnp.arange(T)[None, :] <= pos_b)[:, None, :]
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o_c = jnp.einsum("bht,btr->bhr", p.astype(cckv.dtype), cckv,
                     preferred_element_type=jnp.float32)  # (B,H,r)
    out = jnp.einsum("bhr,rhd->bhd", o_c, w_uv.astype(jnp.float32))
    out = out.reshape(B, 1, H * dv).astype(q_nope.dtype)
    return out @ cast(params["wo"])


# ---------------------------------------------------------------------------
# Cross-attention (whisper decoder)
# ---------------------------------------------------------------------------


def cross_attn_init(key: jax.Array, cfg: ModelConfig) -> dict:
    d, H, Dh = cfg.d_model, cfg.num_heads, cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    return {
        "wq": _normal(ks[0], (d, H * Dh), d ** -0.5),
        "wk": _normal(ks[1], (d, H * Dh), d ** -0.5),
        "wv": _normal(ks[2], (d, H * Dh), d ** -0.5),
        "wo": _normal(ks[3], (H * Dh, d), (H * Dh) ** -0.5),
    }


def cross_attn_apply(
    params: dict,
    x: jax.Array,  # (B, S, d) decoder states
    enc: jax.Array,  # (B, T, d) encoder output
    cfg: ModelConfig,
) -> jax.Array:
    B, S, _ = x.shape
    T = enc.shape[1]
    H, Dh = cfg.num_heads, cfg.resolved_head_dim
    q = (x @ cast(params["wq"])).reshape(B, S, H, Dh)
    k = (enc @ cast(params["wk"])).reshape(B, T, H, Dh)
    v = (enc @ cast(params["wv"])).reshape(B, T, H, Dh)
    s = jnp.einsum(
        "bshd,bthd->bhst", q.astype(jnp.float32), k.astype(jnp.float32)
    ) * (Dh ** -0.5)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhst,bthd->bshd", p, v.astype(jnp.float32))
    return out.reshape(B, S, H * Dh).astype(x.dtype) @ cast(params["wo"])
