"""Unified model configuration covering all assigned architecture families.

One fat dataclass rather than a hierarchy: every assigned arch is a
decoder-LM-style backbone whose layers differ only in (a) the sequence
mixer (GQA / MLA / Mamba-2 SSD), (b) the FFN (dense SwiGLU / GeLU /
fine-grained MoE), and (c) the positional scheme (RoPE / M-RoPE /
learned). ``layer_groups`` compiles the per-layer pattern into
scan-friendly homogeneous groups (see models/transformer.py).
"""

from __future__ import annotations

import dataclasses
from typing import Literal

Family = Literal["dense", "moe", "hybrid", "ssm", "vlm", "audio"]
Mixer = Literal["gqa", "mla", "mamba"]
Ffn = Literal["dense", "moe", "none"]


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    mixer: Mixer
    ffn: Ffn
    cross_attention: bool = False  # whisper decoder layers


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: Family
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int | None = None  # default d_model // num_heads

    # positional / attention behaviour
    rope_theta: float = 10_000.0
    sliding_window: int | None = None  # SWA width (h2o-danube)
    mrope_sections: tuple[int, int, int] | None = None  # qwen2-vl M-RoPE
    qkv_bias: bool = False  # qwen2 family
    attention: Literal["gqa", "mla"] = "gqa"
    # 'rope' | 'mrope' | 'learned' (whisper) | 'none' (jamba attn layers)
    pos_scheme: Literal["rope", "mrope", "learned", "none"] = "rope"
    max_position_embeddings: int = 0  # learned-PE table size (audio)

    # MLA (deepseek-v2-lite)
    kv_lora_rank: int = 0
    q_lora_rank: int = 0  # 0 = full-rank Q (v2-lite)
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128

    # MoE
    num_experts: int = 0
    num_shared_experts: int = 0
    moe_top_k: int = 0
    moe_d_ff: int = 0
    moe_layer_stride: int = 1  # MoE every k-th layer (jamba: 2)
    first_layer_dense: bool = False  # deepseek family
    capacity_factor: float = 1.25
    router_aux_loss_coef: float = 0.001

    # hybrid (jamba): attention layer at i % attn_period == attn_offset
    attn_period: int = 0  # 0 = not hybrid
    attn_offset: int = 4

    # SSM (mamba2 / jamba mamba layers)
    ssm_state: int = 128
    ssm_headdim: int = 64
    ssm_expand: int = 2
    ssm_ngroups: int = 1
    ssm_conv: int = 4
    ssm_chunk: int = 128

    # encoder-decoder (whisper)
    encoder_layers: int = 0
    encoder_seq_len: int = 1500  # precomputed frame embeddings (stub frontend)

    # numerics
    norm_eps: float = 1e-5
    ffn_activation: Literal["swiglu", "gelu"] = "swiglu"
    tie_embeddings: bool = False
    # 'reference' materializes S² scores; 'chunked' is the lowerable
    # online-softmax flash twin; 'flash' is the Pallas TPU kernel.
    attn_impl: Literal["reference", "flash", "chunked"] = "reference"
    attn_chunk: int = 1024  # KV chunk for attn_impl='chunked'
    # MLA decode: score against the compressed cache directly (absorb
    # W_uk into Q / W_uv into the output) instead of recovering K/V —
    # beyond-paper optimization, exact same math.
    mla_absorb: bool = False
    # MoE dispatch/combine wire in bf16 with f32 accumulation only at
    # the per-token top-k sum (halves dispatch traffic; same routing).
    moe_bf16_wire: bool = False
    # norms: keep the (B,S,d) tensors bf16 (variance still f32) — the
    # production-framework trade; f32 everywhere is the faithful default.
    bf16_norm: bool = False
    # shard attention over the query-sequence dim instead of heads —
    # for archs whose head count doesn't divide the TP axis (qwen: 28).
    attn_seq_shard: bool = False
    # route/dispatch MoE per batch row: row-local scatter indices let
    # GSPMD shard expert flops over DP × EP (see moe_apply_rowwise).
    moe_row_dispatch: bool = False
    # expert-parallel MoE over the DP axes with Torrent chain
    # all-to-all dispatch/combine (see moe_apply_ep); requires the DP
    # group size to divide num_experts and the batch, else falls back
    # to the flat path.
    moe_ep_dispatch: bool = False
    # K sub-rings for the EP dispatch exchange (multi-chain all-to-all).
    moe_ep_chains: int = 1
    # ship the EP dispatch/return token payloads int8-quantized per hop
    # (wire_dtype="int8" through torrent_all_to_all); expert-id
    # metadata always travels exact.
    moe_ep_int8_wire: bool = False

    # --- derived -------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_nheads(self) -> int:
        return self.d_inner // self.ssm_headdim

    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    def layer_spec(self, i: int) -> LayerSpec:
        """The (mixer, ffn) of decoder layer ``i``."""
        if self.family == "ssm":
            return LayerSpec(mixer="mamba", ffn="none")
        if self.attn_period:  # hybrid (jamba)
            mixer: Mixer = (
                "gqa" if i % self.attn_period == self.attn_offset else "mamba"
            )
        else:
            mixer = self.attention
        ffn: Ffn = "dense"
        if self.num_experts:
            is_moe = i % self.moe_layer_stride == self.moe_layer_stride - 1 \
                if self.moe_layer_stride > 1 else True
            if self.first_layer_dense and i == 0:
                is_moe = False
            if is_moe:
                ffn = "moe"
        return LayerSpec(
            mixer=mixer, ffn=ffn, cross_attention=self.is_encdec
        )

    def layer_groups(self) -> list[tuple[tuple[LayerSpec, ...], int]]:
        """Compile per-layer specs into (pattern, repeat) groups so that
        heterogeneous stacks (hybrid/MoE-with-dense-first) scan with a
        small traced pattern. Greedy: find the shortest period that
        tiles the remaining layers."""
        specs = [self.layer_spec(i) for i in range(self.num_layers)]
        groups: list[tuple[tuple[LayerSpec, ...], int]] = []
        i = 0
        while i < len(specs):
            rest = specs[i:]
            best: tuple[tuple[LayerSpec, ...], int] | None = None
            # Prefer genuinely repeating patterns (reps >= 2, smallest
            # period on coverage ties) so the traced body stays small;
            # a pattern that never repeats is emitted layer-by-layer.
            for period in range(1, len(rest) // 2 + 1):
                pattern = tuple(rest[:period])
                reps = 1
                while (reps + 1) * period <= len(rest) and tuple(
                    rest[reps * period : (reps + 1) * period]
                ) == pattern:
                    reps += 1
                if reps >= 2 and (
                    best is None or reps * period > best[1] * len(best[0])
                ):
                    best = (pattern, reps)
            if best is None:
                best = ((rest[0],), 1)
            pattern, reps = best
            groups.append((pattern, reps))
            i += reps * len(pattern)
        return groups
