"""Model assembly: layer blocks, scan-compiled layer groups, forward,
chunked loss, prefill and decode.

Layer stacks are compiled into (pattern, repeat) groups
(``ModelConfig.layer_groups``): each group's params are stacked along a
leading ``repeat`` dim and the group runs as one ``lax.scan`` whose body
applies the (possibly heterogeneous) pattern once — so HLO size and
compile time are O(pattern), not O(num_layers), and activation remat is
applied per scan body. KV caches mirror the same (group, position,
stacked) structure.

Losses never materialize (B, S, V) logits: the cross-entropy is computed
in sequence chunks with vocab kept TP-sharded (`hints.maybe_shard`).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax import lax

from . import attention as attn
from . import mamba2 as mb
from . import moe as moe_mod
from .config import LayerSpec, ModelConfig
from .layers import (
    cast,
    embed,
    embedding_init,
    gelu_mlp,
    gelu_mlp_init,
    rmsnorm,
    rmsnorm_init,
    swiglu,
    swiglu_init,
)
from repro.parallel.hints import BATCH, TP, maybe_shard

Params = dict
PyTree = Any

REMAT_POLICIES: dict[str, Any] = {
    "none": None,
    "dots": jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims,
    "full": jax.checkpoint_policies.nothing_saveable,
}


# ---------------------------------------------------------------------------
# Single layer
# ---------------------------------------------------------------------------


def layer_init(key: jax.Array, spec: LayerSpec, cfg: ModelConfig) -> Params:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p: Params = {"norm1": rmsnorm_init(cfg.d_model)}
    if spec.mixer == "gqa":
        p["mixer"] = attn.gqa_init(k1, cfg)
    elif spec.mixer == "mla":
        p["mixer"] = attn.mla_init(k1, cfg)
    else:  # mamba
        p["mixer"] = mb.mamba2_init(k1, cfg)
    if spec.cross_attention:
        p["norm_ca"] = rmsnorm_init(cfg.d_model)
        p["cross"] = attn.cross_attn_init(k3, cfg)
    if spec.ffn != "none":
        p["norm2"] = rmsnorm_init(cfg.d_model)
        if spec.ffn == "dense":
            p["ffn"] = (
                swiglu_init(k2, cfg.d_model, cfg.d_ff)
                if cfg.ffn_activation == "swiglu"
                else gelu_mlp_init(k2, cfg.d_model, cfg.d_ff)
            )
        else:
            p["ffn"] = moe_mod.moe_init(k2, cfg)
    return p


def layer_apply(
    params: Params,
    spec: LayerSpec,
    cfg: ModelConfig,
    x: jax.Array,
    positions: jax.Array,
    *,
    enc: jax.Array | None = None,
    causal: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """Full-sequence layer. Returns (x, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    h = rmsnorm(params["norm1"], x, cfg.norm_eps, bf16=cfg.bf16_norm)
    if spec.mixer == "gqa":
        h = attn.gqa_apply(params["mixer"], h, positions, cfg, causal=causal)
    elif spec.mixer == "mla":
        h = attn.mla_apply(params["mixer"], h, positions, cfg, causal=causal)
    else:
        h = mb.mamba2_apply(params["mixer"], h, cfg)
    x = x + h
    x = maybe_shard(x, BATCH, None, None)
    if spec.cross_attention:
        assert enc is not None
        h = rmsnorm(params["norm_ca"], x, cfg.norm_eps, bf16=cfg.bf16_norm)
        x = x + attn.cross_attn_apply(params["cross"], h, enc, cfg)
    if spec.ffn != "none":
        h = rmsnorm(params["norm2"], x, cfg.norm_eps, bf16=cfg.bf16_norm)
        if spec.ffn == "dense":
            h = (
                swiglu(params["ffn"], h)
                if cfg.ffn_activation == "swiglu"
                else gelu_mlp(params["ffn"], h)
            )
        else:
            h, aux = moe_mod.moe_apply(params["ffn"], h, cfg)
        x = x + h
        x = maybe_shard(x, BATCH, None, None)
    return x, aux


def layer_init_cache(
    spec: LayerSpec, cfg: ModelConfig, batch: int, max_seq: int
) -> Params:
    if spec.mixer in ("gqa",):
        return attn.gqa_init_cache(cfg, batch, max_seq)
    if spec.mixer == "mla":
        return attn.mla_init_cache(cfg, batch, max_seq)
    return mb.mamba2_init_cache(cfg, batch)


def layer_decode(
    params: Params,
    spec: LayerSpec,
    cfg: ModelConfig,
    x: jax.Array,  # (B, 1, d)
    pos: jax.Array,
    cache: Params,
    *,
    enc: jax.Array | None = None,
) -> tuple[jax.Array, Params]:
    h = rmsnorm(params["norm1"], x, cfg.norm_eps, bf16=cfg.bf16_norm)
    if spec.mixer == "gqa":
        h, cache = attn.gqa_decode(params["mixer"], h, pos, cache, cfg)
    elif spec.mixer == "mla":
        h, cache = attn.mla_decode(params["mixer"], h, pos, cache, cfg)
    else:
        h, cache = mb.mamba2_decode(params["mixer"], h, cache, cfg)
    x = x + h
    if spec.cross_attention:
        assert enc is not None
        h = rmsnorm(params["norm_ca"], x, cfg.norm_eps, bf16=cfg.bf16_norm)
        x = x + attn.cross_attn_apply(params["cross"], h, enc, cfg)
    if spec.ffn != "none":
        h = rmsnorm(params["norm2"], x, cfg.norm_eps, bf16=cfg.bf16_norm)
        if spec.ffn == "dense":
            h = (
                swiglu(params["ffn"], h)
                if cfg.ffn_activation == "swiglu"
                else gelu_mlp(params["ffn"], h)
            )
        else:
            h, _ = moe_mod.moe_apply(params["ffn"], h, cfg)
        x = x + h
    return x, cache


# ---------------------------------------------------------------------------
# Groups (scan over stacked layers)
# ---------------------------------------------------------------------------


def _stack_trees(trees: list[PyTree]) -> PyTree:
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def groups_init(
    key: jax.Array, cfg: ModelConfig, groups=None
) -> list[list[Params]]:
    groups = cfg.layer_groups() if groups is None else groups
    out = []
    li = 0
    for pattern, reps in groups:
        per_pos: list[list[Params]] = [[] for _ in pattern]
        for r in range(reps):
            for pi, spec in enumerate(pattern):
                per_pos[pi].append(
                    layer_init(jax.random.fold_in(key, li), spec, cfg)
                )
                li += 1
        out.append([_stack_trees(ps) for ps in per_pos])
    return out


def groups_apply(
    gparams: list[list[Params]],
    cfg: ModelConfig,
    x: jax.Array,
    positions: jax.Array,
    *,
    enc: jax.Array | None = None,
    causal: bool = True,
    remat: str = "dots",
    groups=None,
) -> tuple[jax.Array, jax.Array]:
    groups = cfg.layer_groups() if groups is None else groups
    aux_total = jnp.zeros((), jnp.float32)
    policy = REMAT_POLICIES[remat]

    for (pattern, reps), stacked in zip(groups, gparams):

        def body(carry, layer_params, pattern=pattern):
            h, aux = carry
            for spec, p in zip(pattern, layer_params):
                h, a = layer_apply(
                    p, spec, cfg, h, positions, enc=enc, causal=causal
                )
                aux = aux + a
            return (h, aux), None

        if remat != "none":
            body = jax.checkpoint(body, policy=policy)
        if reps == 1:
            (x, aux_total), _ = body(
                (x, aux_total), [jax.tree.map(lambda t: t[0], s) for s in stacked]
            )
        else:
            (x, aux_total), _ = lax.scan(body, (x, aux_total), stacked)
    return x, aux_total


def groups_init_cache(
    cfg: ModelConfig, batch: int, max_seq: int, groups=None
) -> list[list[Params]]:
    groups = cfg.layer_groups() if groups is None else groups
    out = []
    for pattern, reps in groups:
        out.append(
            [
                _stack_trees(
                    [layer_init_cache(spec, cfg, batch, max_seq) for _ in range(reps)]
                )
                for spec in pattern
            ]
        )
    return out


def groups_decode(
    gparams: list[list[Params]],
    caches: list[list[Params]],
    cfg: ModelConfig,
    x: jax.Array,
    pos: jax.Array,
    *,
    enc: jax.Array | None = None,
    groups=None,
) -> tuple[jax.Array, list[list[Params]]]:
    groups = cfg.layer_groups() if groups is None else groups
    new_caches: list[list[Params]] = []
    for (pattern, reps), stacked, cstacked in zip(groups, gparams, caches):

        def body(h, xs, pattern=pattern):
            layer_params, layer_caches = xs
            new_lc = []
            for spec, p, c in zip(pattern, layer_params, layer_caches):
                h, c2 = layer_decode(p, spec, cfg, h, pos, c, enc=enc)
                new_lc.append(c2)
            return h, new_lc

        if reps == 1:
            p0 = [jax.tree.map(lambda t: t[0], s) for s in stacked]
            c0 = [jax.tree.map(lambda t: t[0], s) for s in cstacked]
            x, nc = body(x, (p0, c0))
            new_caches.append([jax.tree.map(lambda t: t[None], c) for c in nc])
        else:
            x, nc = lax.scan(body, x, (stacked, cstacked))
            new_caches.append(nc)
    return x, new_caches


# ---------------------------------------------------------------------------
# Full model: init / forward / loss / prefill / decode
# ---------------------------------------------------------------------------


def model_init(key: jax.Array, cfg: ModelConfig) -> Params:
    ks = jax.random.split(key, 6)
    p: Params = {
        "embed": embedding_init(ks[0], cfg.vocab_size, cfg.d_model),
        "final_norm": rmsnorm_init(cfg.d_model),
        "groups": groups_init(ks[1], cfg),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = embedding_init(ks[2], cfg.vocab_size, cfg.d_model)
    if cfg.pos_scheme == "learned":
        p["pos_emb"] = (
            jax.random.normal(ks[3], (cfg.max_position_embeddings, cfg.d_model))
            * 0.02
        )
    if cfg.is_encdec:
        enc_cfg = encoder_config(cfg)
        p["encoder"] = {
            "groups": groups_init(ks[4], enc_cfg, enc_cfg.layer_groups()),
            "final_norm": rmsnorm_init(cfg.d_model),
            "pos_emb": jax.random.normal(ks[5], (cfg.encoder_seq_len, cfg.d_model))
            * 0.02,
        }
    return p


def encoder_config(cfg: ModelConfig) -> ModelConfig:
    """Whisper encoder stack: bidirectional GQA + GeLU FFN, no MoE."""
    return dataclasses.replace(
        cfg,
        num_layers=cfg.encoder_layers,
        num_experts=0,
        attn_period=0,
        encoder_layers=0,  # the encoder itself is not enc-dec
        pos_scheme="learned",
    )


def encode(params: Params, cfg: ModelConfig, frames: jax.Array,
           remat: str = "dots") -> jax.Array:
    """Whisper encoder over precomputed frame embeddings (stub frontend)."""
    enc_cfg = encoder_config(cfg)
    T = frames.shape[1]
    x = cast(frames) + cast(params["encoder"]["pos_emb"][:T])
    pos = jnp.broadcast_to(jnp.arange(T), frames.shape[:2])
    x, _ = groups_apply(
        params["encoder"]["groups"], enc_cfg, x, pos,
        causal=False, remat=remat,
    )
    return rmsnorm(params["encoder"]["final_norm"], x, cfg.norm_eps, bf16=cfg.bf16_norm)


def forward_hidden(
    params: Params,
    cfg: ModelConfig,
    batch: dict,
    *,
    remat: str = "dots",
) -> tuple[jax.Array, jax.Array]:
    """Returns (hidden (B,S,d) after final norm, aux_loss)."""
    if "embeds" in batch:  # vlm: precomputed patch/token embeddings
        x = cast(batch["embeds"])
        positions = batch["positions"]  # (3, B, S) M-RoPE
        B, S = x.shape[:2]
    else:
        tokens = batch["tokens"]
        B, S = tokens.shape
        x = embed(params["embed"], tokens)
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    x = maybe_shard(x, BATCH, None, None)
    if cfg.pos_scheme == "learned":
        x = x + cast(params["pos_emb"][:S])
    enc = None
    if cfg.is_encdec:
        enc = encode(params, cfg, batch["enc_frames"], remat=remat)
    x, aux = groups_apply(
        params["groups"], cfg, x, positions, enc=enc, remat=remat
    )
    return rmsnorm(params["final_norm"], x, cfg.norm_eps, bf16=cfg.bf16_norm), aux


def _head_table(params: Params, cfg: ModelConfig) -> jax.Array:
    return (params["embed"] if cfg.tie_embeddings else params["lm_head"])["table"]


def loss_fn(
    params: Params,
    cfg: ModelConfig,
    batch: dict,
    *,
    remat: str = "dots",
    loss_chunks: int = 8,
    z_loss: float = 1e-4,
) -> tuple[jax.Array, dict]:
    """Next-token CE, computed in sequence chunks with TP-sharded vocab."""
    hidden, aux = forward_hidden(params, cfg, batch, remat=remat)
    labels = batch["labels"]
    B, S, d = hidden.shape
    chunks = loss_chunks
    while S % chunks:
        chunks -= 1
    hs = hidden.reshape(B, chunks, S // chunks, d).swapaxes(0, 1)
    ls = labels.reshape(B, chunks, S // chunks).swapaxes(0, 1)
    table = _head_table(params, cfg).astype(jnp.float32)

    def chunk_loss(carry, xs):
        h, lbl = xs  # (B, sc, d), (B, sc)
        logits = jnp.einsum("bsd,vd->bsv", h.astype(jnp.float32), table)
        logits = maybe_shard(logits, BATCH, None, TP)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lbl[..., None], axis=-1)[..., 0]
        ce = (lse - gold).sum()
        zl = (lse ** 2).sum() * z_loss
        return carry + ce + zl, None

    total, _ = lax.scan(chunk_loss, jnp.zeros((), jnp.float32), (hs, ls))
    ntok = B * S
    loss = total / ntok + aux
    return loss, {"loss": loss, "ce": total / ntok, "aux": aux}


# -- serving ---------------------------------------------------------------


def init_cache(cfg: ModelConfig, batch: int, max_seq: int) -> dict:
    cache: dict = {"layers": groups_init_cache(cfg, batch, max_seq)}
    if cfg.is_encdec:
        cache["enc"] = jnp.zeros(
            (batch, cfg.encoder_seq_len, cfg.d_model), jnp.bfloat16
        )
    return cache


def layer_prefill(
    params: Params,
    spec: LayerSpec,
    cfg: ModelConfig,
    x: jax.Array,
    positions: jax.Array,
    max_seq: int,
    *,
    enc: jax.Array | None = None,
) -> tuple[jax.Array, Params]:
    """Full-sequence layer that also emits its decode cache."""
    h = rmsnorm(params["norm1"], x, cfg.norm_eps, bf16=cfg.bf16_norm)
    if spec.mixer == "gqa":
        h, cache = attn.gqa_prefill(params["mixer"], h, positions, cfg, max_seq)
    elif spec.mixer == "mla":
        h, cache = attn.mla_prefill(params["mixer"], h, positions, cfg, max_seq)
    else:
        h, cache = mb.mamba2_prefill(params["mixer"], h, cfg)
    x = x + h
    x = maybe_shard(x, BATCH, None, None)
    if spec.cross_attention:
        assert enc is not None
        h = rmsnorm(params["norm_ca"], x, cfg.norm_eps, bf16=cfg.bf16_norm)
        x = x + attn.cross_attn_apply(params["cross"], h, enc, cfg)
    if spec.ffn != "none":
        h = rmsnorm(params["norm2"], x, cfg.norm_eps, bf16=cfg.bf16_norm)
        if spec.ffn == "dense":
            h = (
                swiglu(params["ffn"], h)
                if cfg.ffn_activation == "swiglu"
                else gelu_mlp(params["ffn"], h)
            )
        else:
            h, _ = moe_mod.moe_apply(params["ffn"], h, cfg)
        x = x + h
        x = maybe_shard(x, BATCH, None, None)
    return x, cache


def prefill(
    params: Params,
    cfg: ModelConfig,
    batch: dict,
    max_seq: int,
    *,
    remat: str = "dots",
) -> tuple[jax.Array, dict]:
    """Process the prompt, build the decode cache, return last-token
    logits. The cache is filled directly from the full-sequence
    projections (no second pass)."""
    if "embeds" in batch:
        x = cast(batch["embeds"])
        positions = batch["positions"]
        B, S = x.shape[:2]
    else:
        tokens = batch["tokens"]
        B, S = tokens.shape
        x = embed(params["embed"], tokens)
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    x = maybe_shard(x, BATCH, None, None)
    if cfg.pos_scheme == "learned":
        x = x + cast(params["pos_emb"][:S])
    enc = None
    if cfg.is_encdec:
        enc = encode(params, cfg, batch["enc_frames"], remat=remat)

    groups = cfg.layer_groups()
    caches: list[list[Params]] = []
    for (pattern, reps), stacked in zip(groups, params["groups"]):

        def body(h, layer_params, pattern=pattern):
            new_lc = []
            for spec, p in zip(pattern, layer_params):
                h, c = layer_prefill(
                    p, spec, cfg, h, positions, max_seq, enc=enc
                )
                new_lc.append(c)
            return h, new_lc

        if remat != "none":
            body = jax.checkpoint(body, policy=REMAT_POLICIES[remat])
        if reps == 1:
            x, lc = body(x, [jax.tree.map(lambda t: t[0], s) for s in stacked])
            caches.append([jax.tree.map(lambda t: t[None], c) for c in lc])
        else:
            x, lc = lax.scan(body, x, stacked)
            caches.append(lc)

    x = rmsnorm(params["final_norm"], x, cfg.norm_eps, bf16=cfg.bf16_norm)
    logits = jnp.einsum(
        "bd,vd->bv", x[:, -1].astype(jnp.float32),
        _head_table(params, cfg).astype(jnp.float32),
    )
    cache: dict = {"layers": caches}
    if cfg.is_encdec:
        cache["enc"] = enc.astype(jnp.bfloat16)
    return logits, cache


def decode_step(
    params: Params,
    cfg: ModelConfig,
    tokens: jax.Array,  # (B,) current token ids
    pos: jax.Array,  # scalar int32 — or (B,) per-slot absolute positions
    cache: dict,
) -> tuple[jax.Array, dict]:
    """One decode step: returns (logits (B, V), new cache).

    With a ``(B,)`` ``pos`` every batch row advances at its own absolute
    position (continuous batching); the scalar path is unchanged."""
    x = embed(params["embed"], tokens[:, None])  # (B, 1, d)
    if cfg.pos_scheme == "learned":
        pe = cast(params["pos_emb"][pos])
        x = x + (pe[:, None, :] if pe.ndim == 2 else pe[None, None, :])
    enc = cache.get("enc")
    x, new_layers = groups_decode(
        params["groups"], cache["layers"], cfg, x, pos, enc=enc
    )
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps, bf16=cfg.bf16_norm)
    logits = jnp.einsum(
        "bd,vd->bv", x[:, 0].astype(jnp.float32),
        _head_table(params, cfg).astype(jnp.float32),
    )
    logits = maybe_shard(logits, BATCH, TP)
    new_cache = dict(cache)
    new_cache["layers"] = new_layers
    return logits, new_cache
