"""Mamba-2 SSD (state-space duality) mixer — chunked scan + O(1) decode.

The SSD form computes, per head h with scalar decay ``a_h = -exp(A_log)``:

    state:  h_t = exp(dt_t a) h_{t-1} + dt_t * (B_t ⊗ x_t)
    out:    y_t = C_t · h_t + D x_t

Training uses the chunked algorithm (Mamba-2 paper §6): within a chunk
of Q tokens the recurrence is expanded into a masked "attention"
(quadratic in Q only); across chunks a single per-(batch, head) scalar
decay carries the (P×N) state, scanned sequentially over S/Q chunks.
Decode is the plain single-token recurrence — the whole point of the
``long_500k`` shape: cache is O(1) in context length (conv window +
(H, P, N) state).

All SSD arithmetic is f32; projections are bf16.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from .config import ModelConfig
from .layers import cast, gated_rmsnorm, rmsnorm_init

_normal = lambda key, shape, scale: jax.random.normal(key, shape, jnp.float32) * scale


def _dims(cfg: ModelConfig):
    d_in = cfg.d_inner
    H = cfg.ssm_nheads
    P = cfg.ssm_headdim
    G = cfg.ssm_ngroups
    N = cfg.ssm_state
    conv_dim = d_in + 2 * G * N
    return d_in, H, P, G, N, conv_dim


def mamba2_init(key: jax.Array, cfg: ModelConfig) -> dict:
    """Projections are kept separate (z/x vs B,C/dt, conv_x vs conv_BC)
    so tensor parallelism can shard the d_inner (head) dimension while
    replicating the small group/dt projections."""
    d = cfg.d_model
    d_in, H, P, G, N, conv_dim = _dims(cfg)
    ks = jax.random.split(key, 6)
    s = d ** -0.5
    W = cfg.ssm_conv
    return {
        "in_z": _normal(ks[0], (d, d_in), s),
        "in_x": _normal(ks[1], (d, d_in), s),
        "in_BC": _normal(ks[2], (d, 2 * G * N), s),
        "in_dt": _normal(ks[3], (d, H), s),
        "conv_x_w": _normal(ks[4], (W, d_in), W ** -0.5),
        "conv_x_b": jnp.zeros((d_in,), jnp.float32),
        "conv_BC_w": _normal(ks[5], (W, 2 * G * N), W ** -0.5),
        "conv_BC_b": jnp.zeros((2 * G * N,), jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "A_log": jnp.zeros((H,), jnp.float32),  # a = -exp(A_log) = -1
        "D": jnp.ones((H,), jnp.float32),
        "norm": rmsnorm_init(d_in),
        "out_proj": _normal(jax.random.fold_in(key, 7), (d_in, d), d_in ** -0.5),
    }


def _project(params: dict, xin: jax.Array, cfg: ModelConfig):
    """xin @ separate projections -> (z, x, BC, dt)."""
    z = xin @ cast(params["in_z"])
    x = xin @ cast(params["in_x"])
    BC = xin @ cast(params["in_BC"])
    dt = xin @ cast(params["in_dt"])
    return z, x, BC, dt


def _causal_conv(xBC: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv over (B, S, C)."""
    W, C = w.shape
    pad = jnp.pad(xBC, ((0, 0), (W - 1, 0), (0, 0)))
    out = lax.conv_general_dilated(
        pad.astype(jnp.float32),
        w[:, None, :].astype(jnp.float32),  # (W, 1, C)
        window_strides=(1,),
        padding="VALID",
        dimension_numbers=("NWC", "WIO", "NWC"),
        feature_group_count=C,
    )
    return (out + b).astype(xBC.dtype)


def mamba2_apply(params: dict, xin: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Full-sequence chunked SSD. xin: (B, S, d_model)."""
    y, _ = _ssd_forward(params, xin, cfg)
    return y


def mamba2_prefill(
    params: dict, xin: jax.Array, cfg: ModelConfig
) -> tuple[jax.Array, dict]:
    """Full-sequence forward that also returns the decode cache (final
    SSM state + conv window tail)."""
    return _ssd_forward(params, xin, cfg)


def _ssd_forward(
    params: dict, xin: jax.Array, cfg: ModelConfig
) -> tuple[jax.Array, dict]:
    B, S, _ = xin.shape
    d_in, H, P, G, N, conv_dim = _dims(cfg)
    Q = min(cfg.ssm_chunk, S)
    pad = (-S) % Q
    Sp = S + pad
    nc = Sp // Q

    z, x_raw, BC_raw, dt = _project(params, xin, cfg)
    W = cfg.ssm_conv
    xBC_raw = jnp.concatenate([x_raw, BC_raw], -1)  # cached for decode
    tail = xBC_raw[:, max(0, S - (W - 1)) :]
    if tail.shape[1] < W - 1:  # left-pad with zeros (conv's implicit state)
        tail = jnp.pad(tail, ((0, 0), (W - 1 - tail.shape[1], 0), (0, 0)))
    x = _causal_conv(x_raw, params["conv_x_w"], params["conv_x_b"])
    BC = _causal_conv(BC_raw, params["conv_BC_w"], params["conv_BC_b"])
    x = jax.nn.silu(x.astype(jnp.float32))
    BC = jax.nn.silu(BC.astype(jnp.float32))
    Bm, Cm = jnp.split(BC, [G * N], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])  # (B,S,H)
    if pad:
        # dt = 0 on padded positions makes the state update an exact
        # identity there (decay exp(0)=1, contribution dt·Bx = 0).
        zpad = lambda t: jnp.pad(t, ((0, 0), (0, pad), (0, 0)))
        x, Bm, Cm, dt = zpad(x), zpad(Bm), zpad(Cm), zpad(dt)

    # reshape to heads / groups (all f32 from here)
    x = x.reshape(B, nc, Q, H, P)
    Bm = Bm.reshape(B, nc, Q, G, N)
    Cm = Cm.reshape(B, nc, Q, G, N)
    rep = H // G
    Bh = jnp.repeat(Bm, rep, axis=3)  # (B,nc,Q,H,N)
    Ch = jnp.repeat(Cm, rep, axis=3)
    dt = dt.reshape(B, nc, Q, H)
    a = -jnp.exp(params["A_log"])  # (H,)
    dA = dt * a  # (B,nc,Q,H) negative
    lam = jnp.cumsum(dA, axis=2)  # Λ inclusive cumsum within chunk

    # ---- intra-chunk (masked attention form) -------------------------
    # att[i,j] = (C_i·B_j) exp(Λ_i - Λ_j) dt_j  for j <= i
    cb = jnp.einsum("bcqhn,bckhn->bchqk", Ch, Bh)  # (B,nc,H,Q,Q)
    decay = jnp.exp(lam[:, :, :, None, :] - lam[:, :, None, :, :])  # (B,nc,Q,Q,H)
    decay = jnp.moveaxis(decay, -1, 2)  # (B,nc,H,Q,Q)
    mask = jnp.tril(jnp.ones((Q, Q), bool))
    att = jnp.where(mask, cb * decay, 0.0) * jnp.moveaxis(dt, 2, 3)[:, :, :, None, :]
    y_intra = jnp.einsum("bchqk,bckhp->bcqhp", att, x)

    # ---- chunk states + sequential inter-chunk scan -------------------
    # state contributed by chunk c: S_c = sum_j exp(Λ_last - Λ_j) dt_j B_j ⊗ x_j
    seg = jnp.exp(lam[:, :, -1:, :] - lam) * dt  # (B,nc,Q,H)
    S_c = jnp.einsum("bcqh,bcqhn,bcqhp->bchnp", seg, Bh, x)  # (B,nc,H,N,P)
    gamma = jnp.exp(lam[:, :, -1, :])  # (B,nc,H) chunk total decay

    def scan_fn(h_prev, inp):
        g_c, s_c = inp  # (B,H), (B,H,N,P)
        h_new = g_c[..., None, None] * h_prev + s_c
        return h_new, h_prev  # emit state BEFORE this chunk

    h0 = jnp.zeros((B, H, N, P), jnp.float32)
    _, h_before = lax.scan(
        scan_fn, h0,
        (jnp.moveaxis(gamma, 1, 0), jnp.moveaxis(S_c, 1, 0)),
    )
    h_before = jnp.moveaxis(h_before, 0, 1)  # (B,nc,H,N,P)

    # y_inter[i] = C_i · exp(Λ_i) h_{c-1}
    y_inter = jnp.einsum(
        "bcqhn,bchnp->bcqhp", Ch * jnp.exp(lam)[..., None], h_before
    )

    y = y_intra + y_inter + x * params["D"][:, None]  # (B,nc,Q,H,P)
    y = y.reshape(B, Sp, d_in)[:, :S]
    y = gated_rmsnorm(params["norm"], y, z.astype(jnp.float32), cfg.norm_eps)
    out = cast(y) @ cast(params["out_proj"])

    # final state (for prefill -> decode handoff): one more scan step
    h_final = gamma[:, -1][..., None, None] * h_before[:, -1] + S_c[:, -1]
    cache = {"conv": tail.astype(jnp.bfloat16), "ssm": h_final}
    return out, cache


# ---------------------------------------------------------------------------
# Decode (O(1) state)
# ---------------------------------------------------------------------------


def mamba2_init_cache(cfg: ModelConfig, batch: int) -> dict:
    d_in, H, P, G, N, conv_dim = _dims(cfg)
    return {
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, conv_dim), jnp.bfloat16),
        "ssm": jnp.zeros((batch, H, N, P), jnp.float32),
    }


def mamba2_decode(
    params: dict,
    xin: jax.Array,  # (B, 1, d_model)
    cache: dict,
    cfg: ModelConfig,
) -> tuple[jax.Array, dict]:
    B = xin.shape[0]
    d_in, H, P, G, N, conv_dim = _dims(cfg)
    z, x_raw, BC_raw, dt = _project(params, xin[:, 0], cfg)

    xBC_t = jnp.concatenate([x_raw, BC_raw], -1)  # (B, conv_dim)
    window = jnp.concatenate([cache["conv"], xBC_t[:, None, :]], axis=1)  # (B,W,conv)
    conv_w = jnp.concatenate([params["conv_x_w"], params["conv_BC_w"]], -1)
    conv_b = jnp.concatenate([params["conv_x_b"], params["conv_BC_b"]], -1)
    conv_out = jnp.einsum(
        "bwc,wc->bc", window.astype(jnp.float32), conv_w
    ) + conv_b
    xBC = jax.nn.silu(conv_out)
    x, Bm, Cm = jnp.split(xBC, [d_in, d_in + G * N], axis=-1)
    x = x.reshape(B, H, P)
    Bm = Bm.reshape(B, G, N)
    Cm = Cm.reshape(B, G, N)
    rep = H // G
    Bh = jnp.repeat(Bm, rep, axis=1)  # (B,H,N)
    Ch = jnp.repeat(Cm, rep, axis=1)

    dtv = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])  # (B,H)
    decay = jnp.exp(dtv * -jnp.exp(params["A_log"]))  # (B,H)
    h = cache["ssm"] * decay[..., None, None] + jnp.einsum(
        "bh,bhn,bhp->bhnp", dtv, Bh, x
    )
    y = jnp.einsum("bhn,bhnp->bhp", Ch, h) + x * params["D"][:, None]
    y = y.reshape(B, 1, d_in)
    y = gated_rmsnorm(params["norm"], y, z[:, None, :].astype(jnp.float32), cfg.norm_eps)
    out = cast(y) @ cast(params["out_proj"])
    return out, {"conv": window[:, 1:].astype(jnp.bfloat16), "ssm": h}
