"""Pure-JAX model zoo (no flax): unified decoder-LM framework covering
dense GQA / MLA / fine-grained MoE / Mamba-2 SSD / hybrid / enc-dec /
VLM-backbone families. See transformer.py for assembly."""

from .config import LayerSpec, ModelConfig
from .transformer import (
    decode_step,
    forward_hidden,
    init_cache,
    loss_fn,
    model_init,
    prefill,
)

__all__ = [
    "LayerSpec",
    "ModelConfig",
    "decode_step",
    "forward_hidden",
    "init_cache",
    "loss_fn",
    "model_init",
    "prefill",
]
