"""jit-able step builders: train / prefill / serve, with sharding specs.

``build_cell`` is the single entry used by the dry-run, the trainer and
the benchmarks: given (arch config, shape, mesh) it returns the step
function plus fully-resolved in/out shardings and ShapeDtypeStruct
arguments — everything needed to ``jit(...).lower().compile()`` without
allocating a single parameter.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import configs as C
from repro.configs.shapes import Shape, input_specs
from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.optim import adamw
from repro.parallel import sharding as shd
from repro.parallel.collectives import torrent_grad_reduce

PyTree = Any


@dataclasses.dataclass
class Cell:
    """One (arch × shape × mesh) dry-run/benchmark cell."""

    cfg: ModelConfig
    shape: Shape
    mesh: jax.sharding.Mesh
    step_fn: Callable
    args: tuple  # ShapeDtypeStructs (or concrete arrays)
    in_shardings: tuple
    out_shardings: Any
    donate_argnums: tuple[int, ...] = ()
    num_chains: int | str = 1  # effective K after VARIANTS resolution ("auto" = model-picked)
    ar_algo: str = "rs_ag"  # multi-ring all-reduce schedule (rs_ag | rotation)
    compress_grads: bool = False  # int8 wire on the DP grad reduction
    bucket_bytes: int | None = None  # bucketed backward-overlapped reduce
    topology: str | None = None  # tiered link-graph spec for auto-K planning

    def lower(self):
        jitted = jax.jit(
            self.step_fn,
            in_shardings=self.in_shardings,
            out_shardings=self.out_shardings,
            donate_argnums=self.donate_argnums,
        )
        with jax.set_mesh(self.mesh):
            return jitted.lower(*self.args)


def _sanitize(spec: P | None, mesh) -> P:
    """Drop axes the mesh doesn't have (e.g. 'pod' on single-pod)."""
    if spec is None:
        return P()
    names = set(mesh.axis_names)
    out = []
    for el in spec:
        if el is None:
            out.append(None)
        elif isinstance(el, tuple):
            kept = tuple(a for a in el if a in names)
            # canonicalize: a 1-tuple equals its bare name on current
            # jax but not on the 0.4.x line — emit the bare name.
            out.append(kept[0] if len(kept) == 1 else (kept if kept else None))
        else:
            out.append(el if el in names else None)
    return P(*out)


def _named(mesh, specs):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, _sanitize(s, mesh)),
        specs,
        is_leaf=lambda x: isinstance(x, P) or x is None,
    )


def make_train_step(
    cfg: ModelConfig,
    opt_cfg: adamw.OptConfig,
    *,
    remat: str = "dots",
    collectives: str = "xla",
    num_chains: int | str = 1,
    ar_algo: str = "rs_ag",
    compress_grads: bool = False,
    error_feedback: bool = False,
    bucket_bytes: int | None = None,
    topology: str | None = None,
    mesh=None,
    batch_specs=None,
    loss_chunks: int = 8,
    microbatches: int = 1,
):
    """(params, opt_state, batch) -> (params, opt_state, metrics).

    ``microbatches > 1`` runs gradient accumulation: the global batch is
    split along dim 0 and scanned, dividing the activation working set
    by M at unchanged math (equal microbatches ⇒ mean-of-means == global
    mean) — the HBM-fit lever for the large training cells (§Perf).

    ``num_chains`` (with ``collectives="torrent"``) selects the
    multi-chain Chainwrite gradient reduction: K concurrent sub-rings
    per DP reduction (``parallel.collectives.torrent_grad_reduce``);
    ``"auto"`` picks K per gradient leaf from the calibrated
    ``all_reduce_latency`` model. ``ar_algo`` selects the multi-ring
    schedule (``"rs_ag"`` fused reduce-scatter/all-gather, the
    bandwidth-optimal default, or ``"rotation"``). Both are sweepable
    next to ``collectives=`` from the dry-run CLI (``--num-chains``,
    ``--ar-algo``) and via ``VARIANTS`` bundles.

    ``bucket_bytes`` (``collectives="torrent"`` only) switches the DP
    reduction to bucketed, backward-overlapped dispatch: gradient
    leaves group into size-targeted dtype-uniform buckets
    (``parallel.collectives.assign_buckets``) and each bucket issues
    ONE chain all-reduce in reverse-topological order — the first
    buckets' collectives are emitted before the fusions producing the
    remaining gradients, so XLA's scheduler can run them behind the
    rest of backward (evidence: ``launch.hlo_breakdown.overlap_stats``;
    modeled timeline: ``core.simulator.overlap_timeline``). Composes
    with ``num_chains``/``ar_algo``/``compress_grads``.

    ``compress_grads`` ships the DP gradient reduction int8-quantized
    per wire hop (``torrent_grad_reduce(wire_dtype="int8")``) — it
    composes with ``num_chains``/``ar_algo`` and requires
    ``collectives="torrent"``. ``error_feedback`` (requires
    ``compress_grads``) changes the signature to ``(params, opt_state,
    ef_state, batch) -> (params, opt_state, ef_state, metrics)``,
    carrying each DP rank's quantization residual across steps
    (EF-SGD; state from ``parallel.collectives.ef_residual_init``).

    ``topology`` (``collectives="torrent"`` only) is a
    ``core.topology`` spec string (e.g. ``"pods=4:interpod_bw=0.25"``)
    that models the DP ring as a tiered link graph for the
    ``num_chains="auto"`` selection — the hierarchical pod-aligned
    schedule then competes on modeled latency. Advisory: specs that do
    not fit the reduced axis degrade to the uniform ring.
    """
    if compress_grads and collectives != "torrent":
        raise ValueError(
            'compress_grads=True requires collectives="torrent" '
            "(the int8 wire is a property of the Chainwrite schedule; "
            "the XLA backend has no compressed all-reduce)"
        )
    if error_feedback and not compress_grads:
        raise ValueError(
            "error_feedback=True requires compress_grads=True: with an "
            "exact wire there is no quantization residual to feed back"
        )
    if error_feedback and microbatches > 1:
        raise ValueError(
            "error_feedback with microbatches > 1 is not supported: the "
            "residual is per wire reduction, not per accumulation step"
        )
    if bucket_bytes is not None and collectives != "torrent":
        raise ValueError(
            'bucket_bytes requires collectives="torrent" (bucketed '
            "dispatch is a property of the Chainwrite reduction; the "
            "XLA backend buckets internally)"
        )
    if topology is not None and collectives != "torrent":
        raise ValueError(
            'topology requires collectives="torrent" (the link-graph '
            "spec steers the Chainwrite ring planner; the XLA backend "
            "has no topology knob)"
        )
    wire_dtype = "int8" if compress_grads else None

    def grad_fn_local(params, batch):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: T.loss_fn(p, cfg, batch, remat=remat, loss_chunks=loss_chunks),
            has_aux=True,
        )(params)
        return grads, metrics

    def grad_fn(params, batch):
        if collectives == "torrent":
            return torrent_grad_reduce(
                grad_fn_local, mesh, batch_specs,
                num_chains=num_chains, algo=ar_algo,
                wire_dtype=wire_dtype, bucket_bytes=bucket_bytes,
                topology=topology,
            )(params, batch)
        return grad_fn_local(params, batch)

    if error_feedback:
        reduce_ef = torrent_grad_reduce(
            grad_fn_local, mesh, batch_specs,
            num_chains=num_chains, algo=ar_algo,
            wire_dtype=wire_dtype, error_feedback=True,
            bucket_bytes=bucket_bytes, topology=topology,
        )

        def train_step_ef(params, opt_state, ef_state, batch):
            grads, metrics, new_ef = reduce_ef(params, batch, ef_state)
            new_params, new_opt, om = adamw.update(
                opt_cfg, grads, opt_state, params
            )
            return new_params, new_opt, new_ef, {**metrics, **om}

        return train_step_ef

    def train_step(params, opt_state, batch):
        if microbatches > 1:
            M = microbatches
            split = jax.tree.map(
                lambda x: x.reshape((M, x.shape[0] // M) + x.shape[1:]), batch
            )

            def body(acc, mbatch):
                grads, metrics = grad_fn(params, mbatch)
                acc = jax.tree.map(jnp.add, acc, grads)
                return acc, metrics

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )
            acc, ms = jax.lax.scan(body, zeros, split)
            grads = jax.tree.map(lambda g: g / M, acc)
            metrics = jax.tree.map(lambda m: m.mean(0), ms)
        else:
            grads, metrics = grad_fn(params, batch)
        new_params, new_opt, om = adamw.update(opt_cfg, grads, opt_state, params)
        return new_params, new_opt, {**metrics, **om}

    return train_step


def make_prefill_step(cfg: ModelConfig, max_seq: int, *, remat: str = "dots"):
    def prefill_step(params, batch):
        return T.prefill(params, cfg, batch, max_seq, remat=remat)

    return prefill_step


def make_serve_step(cfg: ModelConfig):
    def serve_step(params, tokens, pos, cache):
        logits, new_cache = T.decode_step(params, cfg, tokens, pos, cache)
        next_tokens = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_tokens, new_cache

    return serve_step


def make_slot_prefill_step(cfg: ModelConfig, max_seq: int, *, remat: str = "dots"):
    """Per-slot prefill for continuous batching: one (1, S) prompt in,
    (first greedy token (1,), single-row cache) out.

    Unlike :func:`make_prefill_step` this never touches the other slots'
    state — the serve loop writes the returned cache row into the live
    batch cache with :func:`write_cache_slot`, so an admission cannot
    disturb in-flight requests."""

    def slot_prefill_step(params, tokens):
        logits, cache = T.prefill(
            params, cfg, {"tokens": tokens}, max_seq, remat=remat
        )
        return jnp.argmax(logits, axis=-1).astype(jnp.int32), cache

    return slot_prefill_step


def write_cache_slot(cache: PyTree, one_cache: PyTree, slot: int) -> PyTree:
    """Scatter a batch=1 cache (from ``make_slot_prefill_step``) into row
    ``slot`` of a live multi-slot cache. Leaves are (reps, B, ...)."""
    return jax.tree.map(
        lambda full, one: full.at[:, slot].set(one[:, 0].astype(full.dtype)),
        cache,
        one_cache,
    )


# ---------------------------------------------------------------------------
# Cell assembly (dry-run entry)
# ---------------------------------------------------------------------------


# Named optimization bundles for the §Perf hillclimb. "baseline" is the
# paper-faithful configuration; each variant is one recorded change.
# Entries are ModelConfig field overrides, except the step-builder
# knobs "num_chains" and "ar_algo" (popped by build_cell and routed to
# make_train_step) so the multi-chain Chainwrite reduction sweeps next
# to ``collectives=``.
VARIANTS: dict[str, dict] = {
    "baseline": {},
    # multi-chain Chainwrite DP reduction (K=2 concurrent sub-rings,
    # fused RS+AG schedule); only meaningful with collectives="torrent".
    "k2": {"num_chains": 2},
    # K=2 with PR 1's full-payload rotation schedule — the regression
    # twin that keeps the (S+K-2)-payload wire behaviour sweepable.
    "k2-rot": {"num_chains": 2, "ar_algo": "rotation"},
    # model-driven K: all_reduce_latency picks per gradient leaf.
    "k-auto": {"num_chains": "auto"},
    # chunked online-softmax attention (flash twin) — kills the S²
    # score materialization that dominates every memory term.
    "chunked": {"attn_impl": "chunked"},
    # + absorbed MLA decode + bf16 MoE wire + bf16 norms + row-wise
    # (DP×EP-shardable) MoE dispatch.
    "opt": {
        "attn_impl": "chunked", "mla_absorb": True,
        "moe_bf16_wire": True, "bf16_norm": True, "moe_row_dispatch": True,
    },
    # Torrent expert-parallel MoE: tokens stay DP-sharded, experts
    # partition over the DP axes, dispatch/combine run as explicit
    # scheduled chain all-to-alls (models.moe.moe_apply_ep via the
    # ChainProgram planner) instead of GSPMD reshardings. Sweepable
    # next to collectives=; falls back to the flat path when the DP
    # group doesn't divide experts/batch.
    "moe-ep": {"moe_ep_dispatch": True},
    # moe-ep with the K=2 multi-chain all-to-all exchange.
    "moe-ep-k2": {"moe_ep_dispatch": True, "moe_ep_chains": 2},
    # int8-compressed DP gradient reduction (wire_dtype="int8" through
    # torrent_grad_reduce — per-hop quantized frames + f32 scale
    # sideband, 4× fewer payload bytes); collectives="torrent" only.
    "int8-ar": {"compress_grads": True},
    # int8 wire on the K=2 multi-chain schedule — compression and
    # multi-chain compose since the wire became an IR dimension.
    "int8-ar-k2": {"compress_grads": True, "num_chains": 2},
    # Torrent EP MoE with int8-quantized token dispatch/return.
    "moe-ep-int8": {"moe_ep_dispatch": True, "moe_ep_int8_wire": True},
    # bucketed, backward-overlapped DP grad reduce: 4 MiB dtype-grouped
    # buckets dispatched in reverse-topological order, model-picked K
    # per bucket; collectives="torrent" only.
    "bucketed": {"bucket_bytes": 4 << 20, "num_chains": "auto"},
    # bucketed dispatch with the int8 wire — buckets, compression and
    # auto-K compose (each prices the compressed bucket bytes).
    "bucketed-int8": {
        "bucket_bytes": 4 << 20, "num_chains": "auto",
        "compress_grads": True,
    },
    # tiered link-graph planning: the DP ring is modeled as 2 pods with
    # 4× slower inter-pod links, so num_chains="auto" scores the
    # hierarchical pod-aligned schedule; collectives="torrent" only.
    # The relative pods=2 spec applies wherever 2 divides the DP axis
    # and degrades to the uniform ring elsewhere.
    "tiered": {
        "topology": "pods=2:interpod_bw=0.25", "num_chains": "auto",
    },
    # opt + query-sequence-sharded attention (heads ∤ TP archs).
    "opt-seq": {
        "attn_impl": "chunked", "mla_absorb": True,
        "moe_bf16_wire": True, "bf16_norm": True, "moe_row_dispatch": True,
        "attn_seq_shard": True,
    },
}


def build_cell(
    arch: str,
    shape_name: str,
    mesh: jax.sharding.Mesh,
    *,
    collectives: str = "xla",
    num_chains: int | str = 1,
    ar_algo: str = "rs_ag",
    compress_grads: bool = False,
    bucket_bytes: int | None = None,
    topology: str | None = None,
    remat: str = "dots",
    smoke: bool = False,
    variant: str = "baseline",
) -> Cell:
    cfg = C.get_smoke_config(arch) if smoke else C.get_config(arch)
    overrides = dict(VARIANTS.get(variant) or {})
    variant_k = overrides.pop("num_chains", None)
    if variant_k is not None:
        if num_chains not in (1, variant_k):
            raise ValueError(
                f"variant {variant!r} sets num_chains={variant_k} but "
                f"num_chains={num_chains} was passed explicitly"
            )
        num_chains = variant_k
    variant_algo = overrides.pop("ar_algo", None)
    if variant_algo is not None:
        if ar_algo not in ("rs_ag", variant_algo):
            raise ValueError(
                f"variant {variant!r} sets ar_algo={variant_algo!r} but "
                f"ar_algo={ar_algo!r} was passed explicitly"
            )
        ar_algo = variant_algo
    variant_cg = overrides.pop("compress_grads", None)
    if variant_cg is not None:
        if compress_grads not in (False, variant_cg):
            raise ValueError(
                f"variant {variant!r} sets compress_grads={variant_cg} but "
                f"compress_grads={compress_grads} was passed explicitly"
            )
        compress_grads = variant_cg
    variant_bb = overrides.pop("bucket_bytes", None)
    if variant_bb is not None:
        if bucket_bytes not in (None, variant_bb):
            raise ValueError(
                f"variant {variant!r} sets bucket_bytes={variant_bb} but "
                f"bucket_bytes={bucket_bytes} was passed explicitly"
            )
        bucket_bytes = variant_bb
    variant_topo = overrides.pop("topology", None)
    if variant_topo is not None:
        if topology not in (None, variant_topo):
            raise ValueError(
                f"variant {variant!r} sets topology={variant_topo!r} but "
                f"topology={topology!r} was passed explicitly"
            )
        topology = variant_topo
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    shape = C.SHAPES[shape_name]
    tp = mesh.shape.get("model", 1)
    dp = mesh.shape.get("data", 1) * mesh.shape.get("pod", 1)

    params_shape = jax.eval_shape(
        lambda: T.model_init(jax.random.PRNGKey(0), cfg)
    )
    pspecs = shd.param_pspecs(params_shape, cfg, tp=tp)
    specs = input_specs(cfg, shape)

    if shape.kind == "train":
        opt_cfg = adamw.OptConfig()
        opt_shape = jax.eval_shape(lambda: adamw.init(params_shape))
        ospecs = shd.opt_pspecs(pspecs, params_shape, data_size=mesh.shape.get("data", 1))
        bspecs = shd.batch_pspecs(cfg, shape)
        bspecs_clean = jax.tree.map(
            lambda s: _sanitize(s, mesh), bspecs,
            is_leaf=lambda x: isinstance(x, P) or x is None,
        )
        step = make_train_step(
            cfg, opt_cfg, remat=remat, collectives=collectives,
            num_chains=num_chains, ar_algo=ar_algo,
            compress_grads=compress_grads, bucket_bytes=bucket_bytes,
            topology=topology,
            mesh=mesh, batch_specs=bspecs_clean,
        )
        return Cell(
            cfg=cfg, shape=shape, mesh=mesh, step_fn=step,
            args=(params_shape, opt_shape, specs["batch"]),
            in_shardings=(
                _named(mesh, pspecs), _named(mesh, ospecs), _named(mesh, bspecs)
            ),
            out_shardings=(
                _named(mesh, pspecs), _named(mesh, ospecs), None
            ),
            donate_argnums=(0, 1),
            num_chains=num_chains,
            ar_algo=ar_algo,
            compress_grads=compress_grads,
            bucket_bytes=bucket_bytes,
            topology=topology,
        )

    if shape.kind == "prefill":
        bspecs = shd.batch_pspecs(cfg, shape)
        cache_shape = jax.eval_shape(
            lambda: T.init_cache(cfg, shape.global_batch, specs["max_seq"])
        )
        cspecs = shd.cache_pspecs(cache_shape, cfg, shape, tp=tp)
        step = make_prefill_step(cfg, specs["max_seq"], remat=remat)
        return Cell(
            cfg=cfg, shape=shape, mesh=mesh, step_fn=step,
            args=(params_shape, specs["batch"]),
            in_shardings=(_named(mesh, pspecs), _named(mesh, bspecs)),
            out_shardings=(
                NamedSharding(mesh, _sanitize(P(shd.BATCH_AXES, None), mesh)),
                _named(mesh, cspecs),
            ),
        )

    # decode
    cspecs = shd.cache_pspecs(specs["cache"], cfg, shape, tp=tp)
    long_ctx = shape.global_batch == 1
    tok_spec = P() if long_ctx else _sanitize(P(shd.BATCH_AXES), mesh)
    step = make_serve_step(cfg)
    return Cell(
        cfg=cfg, shape=shape, mesh=mesh, step_fn=step,
        args=(params_shape, specs["tokens"], specs["pos"], specs["cache"]),
        in_shardings=(
            _named(mesh, pspecs),
            NamedSharding(mesh, tok_spec),
            NamedSharding(mesh, P()),
            _named(mesh, cspecs),
        ),
        out_shardings=(
            NamedSharding(mesh, tok_spec),
            _named(mesh, cspecs),
        ),
        donate_argnums=(3,),
    )
