"""Trip-count-aware HLO cost model (FLOPs / HBM bytes / collective bytes).

``compiled.cost_analysis()`` visits every computation **once**: a
``lax.scan`` over 30 layers reports the cost of one layer, so a scanned
transformer's FLOPs are undercounted by ~num_layers× (we measured 19×
on starcoder2-3b).  XLA's post-optimization HLO, however, annotates
every while loop with ``backend_config={"known_trip_count":{"n":...}}``,
so the exact cost is recoverable from the HLO text.  This module parses
the compiled module and computes, with loop bodies multiplied by their
trip counts:

* ``flops``   — 2·M·N·K for every ``dot`` (+ convolutions, + elementwise
  arithmetic at 1 flop/element), matching HloCostAnalysis conventions;
* ``bytes``   — HBM traffic model: for every non-control-flow op at
  computation level, operand bytes + result bytes.  Fusions count their
  boundary (operands/results) only — internal values live in
  registers/VMEM; ``tuple``/``get-tuple-element``/``bitcast``/
  ``parameter``/``constant`` are free;
* ``collective_bytes`` — per-kind *wire* bytes of all-reduce/all-gather/
  reduce-scatter/all-to-all/collective-permute (operand bytes; derived
  from result shapes since post-opt HLO prints operands untyped).

The model is deliberately simple and documented — it is the source for
EXPERIMENTS.md §Roofline.  ``parse_module`` is pure text processing and
unit-tested against hand-built HLO in tests/test_hlo_cost.py.
"""

from __future__ import annotations

import dataclasses
import json
import re
from typing import Iterator

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3b11fnuz": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

# opcodes that read/write no HBM (metadata or aliasing only)
_FREE_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "token", "partition-id", "replica-id", "iota",
}
# control flow: recurse, don't count the op's own (tuple) operands
_CALL_OPS = {"while", "call", "conditional", "fusion", "async-start"}

# 1 flop per output element for these elementwise ops (XLA convention);
# transcendentals counted the same (good enough at matmul scales).
_ELEMENTWISE_FLOPS = {
    "add", "subtract", "multiply", "divide", "power", "maximum", "minimum",
    "exponential", "exponential-minus-one", "log", "log-plus-one", "tanh",
    "logistic", "rsqrt", "sqrt", "cbrt", "sine", "cosine", "negate", "abs",
    "atan2", "remainder", "erf",
}

COLLECTIVE_KINDS = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)

Shape = tuple[str, tuple[int, ...]]  # (dtype, dims)


def shape_bytes(shape: Shape) -> int:
    dtype, dims = shape
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    for d in dims:
        n *= d
    return n * _DTYPE_BYTES[dtype]


@dataclasses.dataclass
class Instr:
    name: str
    opcode: str
    shapes: list[Shape]  # result shapes (tuple types flattened)
    operands: list[str]
    attrs: str  # raw attribute text after the operand list

    @property
    def result_bytes(self) -> int:
        return sum(shape_bytes(s) for s in self.shapes)


@dataclasses.dataclass
class Computation:
    name: str
    instrs: list[Instr]
    by_name: dict[str, Instr]


# -- parsing ------------------------------------------------------------------

# Computation headers start at column 0: ``%name (params...) -> type {``
# (params may nest parentheses for tuple types — match greedily).
_COMP_HEADER = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->\s*.+\{\s*$")
_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")
_INSTR_RE = re.compile(
    r"^\s+(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(\(?[^)]*?\)?[a-z0-9\[\],\s/*{}_]*?)\s*"
    r"([a-z][a-z0-9\-]*)\((.*)$"
)
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"?(\d+)"?\}')
_CALLS_RE = re.compile(r"(?:calls|to_apply|body)=%([\w\.\-]+)")
_COND_RE = re.compile(r"condition=%([\w\.\-]+)")
_BODY_RE = re.compile(r"body=%([\w\.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_GROUPS_PAIR_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_BRACES_RE = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_DIM_LABELS_RE = re.compile(r"dim_labels=([0-9a-z]+)_([0-9a-z]+)->")


def _parse_shapes(type_text: str) -> list[Shape]:
    return [
        (dt, tuple(int(x) for x in dims.split(",")) if dims else ())
        for dt, dims in _SHAPE_RE.findall(type_text)
    ]


def _split_operands_attrs(rest: str) -> tuple[list[str], str]:
    """Split ``op(...)...attrs`` at the operand list's closing paren."""
    depth = 1
    for i, ch in enumerate(rest):
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                return _OPERAND_RE.findall(rest[:i]), rest[i + 1:]
    return _OPERAND_RE.findall(rest), ""


def parse_module(hlo_text: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur_name: str | None = None
    cur: list[Instr] = []
    for line in hlo_text.splitlines():
        if cur_name is None:
            m = _COMP_HEADER.match(line)
            if m:
                cur_name, cur = m.group(1), []
            continue
        if line.startswith("}") or line.strip() == "}":
            comps[cur_name] = Computation(
                cur_name, cur, {i.name: i for i in cur}
            )
            cur_name = None
            continue
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, type_text, opcode, rest = m.groups()
        operands, attrs = _split_operands_attrs(rest)
        cur.append(Instr(name, opcode, _parse_shapes(type_text), operands, attrs))
    return comps


# -- cost evaluation ----------------------------------------------------------


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0  # upper bound: operand+result at fusion boundaries
    bytes_lb: float = 0.0  # lower bound: dots/convs/copies/collectives only
    coll: dict[str, float] = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in COLLECTIVE_KINDS}
    )

    def __iadd__(self, other: "Cost") -> "Cost":
        self.flops += other.flops
        self.bytes += other.bytes
        self.bytes_lb += other.bytes_lb
        for k in self.coll:
            self.coll[k] += other.coll[k]
        return self

    def scaled(self, n: float) -> "Cost":
        return Cost(
            self.flops * n, self.bytes * n, self.bytes_lb * n,
            {k: v * n for k, v in self.coll.items()},
        )

    @property
    def coll_bytes(self) -> float:
        return sum(self.coll.values())


def _group_size(attrs: str) -> int:
    m = _GROUPS_PAIR_RE.search(attrs)
    if m:
        return max(1, int(m.group(2)))
    m = _GROUPS_BRACES_RE.search(attrs)
    if m:
        return max(1, len(m.group(1).split(",")))
    return 1


def _operand_bytes(instr: Instr, comp: Computation) -> int:
    total = 0
    for op in instr.operands:
        src = comp.by_name.get(op)
        if src is not None:
            total += src.result_bytes
    return total


def _dot_flops(instr: Instr, comp: Computation) -> float:
    out_elems = 1
    for s in instr.shapes:
        for d in s[1]:
            out_elems *= d
    m = _CONTRACT_RE.search(instr.attrs)
    contract = 1
    if m and instr.operands:
        lhs = comp.by_name.get(instr.operands[0])
        if lhs is not None and lhs.shapes:
            dims = lhs.shapes[0][1]
            for idx in (int(x) for x in m.group(1).split(",") if x):
                if idx < len(dims):
                    contract *= dims[idx]
    return 2.0 * out_elems * contract


def _conv_flops(instr: Instr, comp: Computation) -> float:
    out_elems = 1
    for s in instr.shapes:
        for d in s[1]:
            out_elems *= d
    kernel_elems, kernel_out = 1, 1
    if len(instr.operands) >= 2:
        k = comp.by_name.get(instr.operands[1])
        if k is not None and k.shapes:
            dims = k.shapes[0][1]
            for d in dims:
                kernel_elems *= d
            m = _DIM_LABELS_RE.search(instr.attrs)
            if m:
                o_pos = m.group(2).find("o")
                if 0 <= o_pos < len(dims):
                    kernel_out = dims[o_pos]
    return 2.0 * out_elems * kernel_elems / max(1, kernel_out)


def _collective_result_bytes(instr: Instr) -> int:
    """Wire bytes of one collective, derived from its result shape(s)."""
    shapes = instr.shapes
    if instr.opcode.endswith("-start") and len(shapes) > 1:
        # async start: result is (operand, result[, ...]) — take result
        shapes = shapes[1:2]
    return sum(shape_bytes(s) for s in shapes)


class ModuleCost:
    """Evaluates per-computation costs bottom-up with memoization.

    ``fused=True`` marks computations called from a ``fusion`` op:
    their internal elementwise/data-movement ops live in registers, so
    they contribute nothing to ``bytes_lb`` (dots/convs/collectives
    still do).
    """

    def __init__(self, comps: dict[str, Computation]):
        self.comps = comps
        self._memo: dict[tuple[str, bool], Cost] = {}

    def computation_cost(self, name: str, fused: bool = False) -> Cost:
        key = (name, fused)
        if key in self._memo:
            return self._memo[key]
        comp = self.comps.get(name)
        total = Cost()
        if comp is None:
            self._memo[key] = total
            return total
        self._memo[key] = total  # break cycles defensively
        for instr in comp.instrs:
            total += self.instr_cost(instr, comp, fused=fused)
        return total

    def instr_cost(self, instr: Instr, comp: Computation,
                   fused: bool = False) -> Cost:
        op = instr.opcode
        base = op[:-6] if op.endswith("-start") else op
        # -done of async collectives: counted at -start
        if op.endswith("-done"):
            return Cost()

        if base == "while":
            trip = 1
            m = _TRIP_RE.search(instr.attrs)
            if m:
                trip = int(m.group(1))
            body = _BODY_RE.search(instr.attrs)
            cond = _COND_RE.search(instr.attrs)
            c = Cost()
            if body:
                c += self.computation_cost(body.group(1))
            if cond:
                c += self.computation_cost(cond.group(1))
            return c.scaled(trip)

        if base == "conditional":
            m = _BRANCHES_RE.search(instr.attrs)
            names = _OPERAND_RE.findall(m.group(1)) if m else []
            costs = [self.computation_cost(n) for n in names]
            if not costs:
                return Cost()
            # worst-case branch
            return max(costs, key=lambda c: (c.flops, c.bytes))

        if base in ("call", "async-start"):
            m = _CALLS_RE.search(instr.attrs)
            return self.computation_cost(m.group(1)) if m else Cost()

        if base == "fusion":
            # ub: HBM traffic at the fusion boundary (operands+result —
            # every buffer double-counted as producer result + consumer
            # operand); lb: result written once, producers assumed fused.
            m = _CALLS_RE.search(instr.attrs)
            inner = self.computation_cost(m.group(1), fused=True) if m else Cost()
            return Cost(
                flops=inner.flops,
                bytes=_operand_bytes(instr, comp) + instr.result_bytes,
                bytes_lb=instr.result_bytes + inner.bytes_lb,
                coll=inner.coll,
            )

        c = Cost()
        if base in COLLECTIVE_KINDS:
            wire = _collective_result_bytes(instr)
            if base == "all-gather":
                wire //= _group_size(instr.attrs)
            elif base == "reduce-scatter":
                wire *= _group_size(instr.attrs)
            c.coll[base] += wire
            c.bytes += _operand_bytes(instr, comp) + instr.result_bytes
            c.bytes_lb = c.bytes
            return c

        if base in _FREE_OPS:
            return c

        if base == "dot":
            c.flops += _dot_flops(instr, comp)
        elif base == "convolution":
            c.flops += _conv_flops(instr, comp)
        elif base in _ELEMENTWISE_FLOPS:
            for s in instr.shapes:
                n = 1
                for d in s[1]:
                    n *= d
                c.flops += n
        c.bytes += _operand_bytes(instr, comp) + instr.result_bytes
        if base in ("dot", "convolution"):
            c.bytes_lb = c.bytes  # matmul operands are true HBM reads
        elif not fused:
            c.bytes_lb = instr.result_bytes
        return c

    def entry_cost(self, entry: str | None = None) -> Cost:
        if entry is None:
            entry = self._find_entry()
        return self.computation_cost(entry)

    def _find_entry(self) -> str:
        # entry computation = one that is not called by any other
        called: set[str] = set()
        for comp in self.comps.values():
            for instr in comp.instrs:
                for m in re.finditer(r"(?:calls|to_apply|body|condition)=%([\w\.\-]+)", instr.attrs):
                    called.add(m.group(1))
                m = _BRANCHES_RE.search(instr.attrs)
                if m:
                    called.update(_OPERAND_RE.findall(m.group(1)))
        candidates = [n for n in self.comps if n not in called]
        # prefer 'main'-ish names, else the biggest computation
        for n in candidates:
            if "main" in n:
                return n
        return max(
            candidates or list(self.comps),
            key=lambda n: len(self.comps[n].instrs),
        )


def analyze(hlo_text: str) -> Cost:
    """Full-module cost with while bodies multiplied by trip count."""
    return ModuleCost(parse_module(hlo_text)).entry_cost()


def constant_bytes(hlo_text: str) -> int:
    """Total bytes of literal ``constant`` instructions across every
    computation in the module — the embedded-table footprint. Symbolic
    shard addressing pins this to be ring-length-independent for
    ``chainwrite.execute_program`` (see BENCH_collectives.json
    ``plan_L*`` entries): addresses are computed in-kernel from the
    device index, not looked up in materialized L-sized tables."""
    return sum(
        instr.result_bytes
        for comp in parse_module(hlo_text).values()
        for instr in comp.instrs
        if instr.opcode == "constant"
    )
