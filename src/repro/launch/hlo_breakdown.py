"""Hot-spot breakdown of a compiled cell — the dry-run 'profiler'.

With no real TPU, the optimization loop's profile is the trip-count-
scaled HLO cost: this tool ranks instructions (and opcode classes) by
bytes / flops / collective bytes so each §Perf iteration can name the
op it is attacking and by how much.

    PYTHONPATH=src python -m repro.launch.hlo_breakdown \
        --arch deepseek-v2-lite-16b --shape prefill_32k --top 15
"""

from __future__ import annotations

import argparse
import os
from collections import defaultdict

from repro.launch import hlo_cost


def breakdown(hlo_text: str) -> tuple[list, dict, dict]:
    comps = hlo_cost.parse_module(hlo_text)
    mc = hlo_cost.ModuleCost(comps)
    items: list = []
    by_op_bytes: dict[str, float] = defaultdict(float)
    by_op_flops: dict[str, float] = defaultdict(float)

    def walk(name: str, scale: float):
        comp = comps.get(name)
        if comp is None:
            return
        for instr in comp.instrs:
            base = (instr.opcode[:-6] if instr.opcode.endswith("-start")
                    else instr.opcode)
            if base == "while":
                m = hlo_cost._TRIP_RE.search(instr.attrs)
                trip = int(m.group(1)) if m else 1
                b = hlo_cost._BODY_RE.search(instr.attrs)
                c = hlo_cost._COND_RE.search(instr.attrs)
                if b:
                    walk(b.group(1), scale * trip)
                if c:
                    walk(c.group(1), scale * trip)
                continue
            if base in ("call", "async-start"):
                m = hlo_cost._CALLS_RE.search(instr.attrs)
                if m:
                    walk(m.group(1), scale)
                continue
            cost = mc.instr_cost(instr, comp)
            if cost.bytes or cost.flops:
                meta = ""
                i = instr.attrs.find('op_name="')
                if i >= 0:
                    meta = instr.attrs[i + 9: instr.attrs.find('"', i + 9)]
                items.append((
                    cost.bytes * scale, cost.flops * scale, scale,
                    instr.name, instr.shapes[:1], meta,
                ))
                by_op_bytes[base] += cost.bytes * scale
                by_op_flops[base] += cost.flops * scale

    walk(mc._find_entry(), 1.0)
    return items, dict(by_op_bytes), dict(by_op_flops)


# opcodes that count as "real compute" between two collectives when
# measuring interleaving (fusions and contractions — the ops backward
# segments are made of after XLA fusion).
_COMPUTE_OPS = {"fusion", "dot", "convolution"}


def overlap_stats(hlo_text: str) -> dict[str, int]:
    """Scheduling-order overlap evidence from the compiled module — the
    HLO side of the bucketed-reduce overlap story (the modeled side is
    ``core.simulator.overlap_timeline``).

    Walks every computation's instruction list IN PROGRAM ORDER and
    counts:

    * ``async_start`` / ``async_done`` — async collective pair halves
      (``collective-permute-start`` etc.); a start that is not
      immediately followed by its done means XLA scheduled other work
      inside the collective's shadow;
    * ``max_in_flight`` — the deepest start-without-done nesting seen
      in one computation (> 1 = truly concurrent collectives);
    * ``collectives`` — collective ops total (``-done`` halves not
      double-counted);
    * ``interleavings`` — collective → compute (fusion/dot) → collective
      transitions: how many collective gaps have real compute scheduled
      inside them. Per-leaf serial reduction tails show ~0 compute
      between collectives; the bucketed dispatch order leaves backward
      fusions between bucket reduces.
    """
    comps = hlo_cost.parse_module(hlo_text)
    stats = {
        "async_start": 0, "async_done": 0, "collectives": 0,
        "interleavings": 0, "max_in_flight": 0,
    }
    for comp in comps.values():
        in_flight = 0
        seen_collective = False
        compute_since = False
        for instr in comp.instrs:
            op = instr.opcode
            base = op
            if base.endswith("-start"):
                base = base[:-6]
            elif base.endswith("-done"):
                base = base[:-5]
            if base in hlo_cost.COLLECTIVE_KINDS:
                if op.endswith("-start"):
                    stats["async_start"] += 1
                    in_flight += 1
                    stats["max_in_flight"] = max(
                        stats["max_in_flight"], in_flight
                    )
                elif op.endswith("-done"):
                    stats["async_done"] += 1
                    in_flight = max(0, in_flight - 1)
                    continue  # counted at -start
                if seen_collective and compute_since:
                    stats["interleavings"] += 1
                stats["collectives"] += 1
                seen_collective = True
                compute_since = False
            elif op in _COMPUTE_OPS:
                compute_since = True
    return stats


def main() -> None:
    # CLI-only: fake a 512-device host platform BEFORE the jax backend
    # initializes (set here, not at import, so importing this module for
    # overlap_stats/breakdown never changes the caller's device count)
    os.environ["XLA_FLAGS"] = (
        "--xla_force_host_platform_device_count=512 "
        + os.environ.get("XLA_FLAGS", "")
    )
    p = argparse.ArgumentParser()
    p.add_argument("--arch", required=True)
    p.add_argument("--shape", required=True)
    p.add_argument("--mesh", default="single")
    p.add_argument("--variant", default="baseline")
    p.add_argument("--collectives", default="xla")
    p.add_argument("--remat", default="dots")
    p.add_argument("--top", type=int, default=15)
    p.add_argument("--by", choices=("bytes", "flops"), default="bytes")
    args = p.parse_args()

    from repro.launch.mesh import make_production_mesh
    from repro.launch.steps import build_cell

    mesh = make_production_mesh(multi_pod=(args.mesh == "multi"))
    cell = build_cell(args.arch, args.shape, mesh, variant=args.variant,
                      collectives=args.collectives, remat=args.remat)
    compiled = cell.lower().compile()
    items, by_bytes, by_flops = breakdown(compiled.as_text())

    total_b = sum(by_bytes.values())
    total_f = sum(by_flops.values())
    print(f"== {args.arch} × {args.shape} ({args.variant}) ==")
    print(f"total bytes {total_b:.4g}   total flops {total_f:.4g}")
    print("\n-- by opcode (bytes) --")
    for op, b in sorted(by_bytes.items(), key=lambda kv: -kv[1])[:10]:
        print(f"  {op:25s} {b:10.4g}  ({100 * b / total_b:5.1f}%)")
    key = 0 if args.by == "bytes" else 1
    print(f"\n-- top instructions by {args.by} --")
    for it in sorted(items, key=lambda t: -t[key])[: args.top]:
        b, f, scale, name, shapes, meta = it
        print(f"  {b:10.4g}B {f:10.4g}F x{scale:4.0f} {name:38s} "
              f"{shapes} {meta[:60]}")


if __name__ == "__main__":
    main()
