"""Batched serving driver: continuous-batching decode loop with a
Torrent-orchestrated weight multicast between steps.

The serving runtime is where the paper's *dynamic* four-phase protocol
survives compilation (DESIGN.md §2): requests arrive asynchronously, and
host-side P2MP movement (broadcasting freshly-prefilled KV blocks or
refreshed weights to the replica set) is driven as Torrent chain tasks
with real predicted-cycle accounting.

Elastic serving: the server holds ONE persistent
``parallel.collectives.MultiChainPlan`` for the replica set.
``broadcast_weights`` streams the *entire* flattened parameter tree
(chunked, byte-exact — the logged byte count is asserted against the
params' true nbytes) down the plan's sub-chains, and
``Server.scale_down`` handles replica loss by *re-forming* that live
plan around the lost members (``runtime.elastic.scale_down_plan`` →
``MultiChainPlan.reform``) instead of rebuilding it — the Torrent
recovery machinery doing elastic scale-down.

    PYTHONPATH=src python -m repro.launch.serve --arch yi-6b --smoke \
        --requests 16 --max-new 32
"""

from __future__ import annotations

import argparse
import dataclasses
import logging
import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs as C
from repro.core.chaintask import MultiChainTask
from repro.core.topology import MeshTopology
from repro.launch.steps import make_prefill_step, make_serve_step
from repro.models import transformer as T
from repro.parallel.collectives import MultiChainPlan
from repro.runtime.elastic import scale_down_plan

log = logging.getLogger("repro.serve")


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray  # (S,) int32
    max_new: int
    out: list[int] = dataclasses.field(default_factory=list)
    done: bool = False


@dataclasses.dataclass
class ServeConfig:
    arch: str = "yi-6b"
    smoke: bool = True
    batch: int = 4  # decode slots
    prompt_len: int = 16
    max_seq: int = 128
    eos: int = -1  # -1: run to max_new
    replicas: int = 4  # model replicas for weight multicast demo
    seed: int = 0


class Server:
    """Slot-based continuous batching with greedy decode."""

    def __init__(self, sc: ServeConfig):
        self.sc = sc
        self.cfg = C.get_smoke_config(sc.arch) if sc.smoke else C.get_config(sc.arch)
        key = jax.random.PRNGKey(sc.seed)
        self.params = T.model_init(key, self.cfg)
        self.prefill = jax.jit(
            make_prefill_step(self.cfg, sc.max_seq), static_argnames=()
        )
        self.serve_step = jax.jit(make_serve_step(self.cfg))
        self.queue: list[Request] = []
        self.slots: list[Request | None] = [None] * sc.batch
        self.pos = 0
        self.cache = None
        self.steps = 0
        # weight-multicast bookkeeping (paper Fig. 4 host orchestration):
        # ONE persistent multi-chain plan for the replica set — elastic
        # scale-down re-forms it (endpoint-side) instead of rebuilding.
        self.replicas = sc.replicas
        self.topo = MeshTopology(max(2, sc.replicas), 1)
        self.plan = MultiChainPlan(
            self.topo, 0, list(range(1, sc.replicas)), scheduler="tsp"
        )
        self.multicast_log: list[dict] = []
        self.last_delivery: dict[int, np.ndarray] = {}

    # -- the paper's host-side P2MP: weight refresh to replicas ----------
    def broadcast_weights(self, chunk_bytes: int = 1 << 20) -> dict:
        """Multicast the FULL parameter tree to every surviving replica
        down the persistent plan's sub-chains, ``chunk_bytes`` at a
        time. The logged ``bytes`` is asserted against the params' true
        nbytes — the record describes a real weight refresh."""
        flat, _ = jax.tree_util.tree_flatten(self.params)
        true_nbytes = sum(int(np.asarray(x).nbytes) for x in flat)
        # dtype-agnostic byte stream: the wire moves bytes, not floats
        payload = (
            np.concatenate(
                [np.ascontiguousarray(x).reshape(-1).view(np.uint8) for x in flat]
            )
            if flat
            else np.zeros(0, np.uint8)
        )
        dests = self.plan.survivors
        cycles = unicast = chunks = 0
        parts: dict[int, list[np.ndarray]] = {d: [] for d in dests}
        for off in range(0, payload.size, max(1, int(chunk_bytes))):
            chunk = payload[off : off + max(1, int(chunk_bytes))]
            if not dests:
                break
            task = MultiChainTask(
                self.topo, 0, dests, chunk,
                chains=[list(c) for c in self.plan.chains],
            )
            bufs = task.run()
            for d, buf in bufs.items():
                parts[d].append(buf)
            cycles += task.cycle_ledger["total"]
            unicast += task.unicast_cycles()
            chunks += 1
        self.last_delivery = {
            d: np.concatenate(p) if p else np.zeros(0, np.uint8)
            for d, p in parts.items()
        }
        rec = {
            "bytes": int(payload.nbytes),
            "chunks": chunks,
            "replicas": len(dests) + 1,
            "cycles": cycles,
            "speedup_vs_unicast": unicast / cycles if cycles else 1.0,
        }
        if rec["bytes"] != true_nbytes:
            raise AssertionError(
                f"weight refresh logged {rec['bytes']} B but params hold "
                f"{true_nbytes} B"
            )
        self.multicast_log.append(rec)
        return rec

    # -- elastic scale-down: re-form the live plan, never rebuild it -----
    def scale_down(self, replicas: int) -> tuple[int, ...]:
        """Shrink the replica set to ``replicas`` (keeping replica 0,
        the plan head). The lost members are spliced out of the live
        ``MultiChainPlan`` as a concurrent failure set — surviving
        sub-chains keep their schedules verbatim and the next
        :meth:`broadcast_weights` still delivers full weights to every
        survivor. Returns the lost replica ids."""
        lost = scale_down_plan(self.plan, self.replicas, replicas)
        if lost:
            log.info("scale-down: lost replicas %s, plan re-formed", list(lost))
        self.replicas = int(replicas)
        return lost

    # -- request lifecycle -------------------------------------------------
    def submit(self, prompt: np.ndarray, max_new: int) -> Request:
        req = Request(rid=len(self.queue), prompt=np.asarray(prompt, np.int32),
                      max_new=max_new)
        self.queue.append(req)
        return req

    def _admit(self):
        """Fill free slots; (re)prefill the whole batch when it changes.

        A production server prefills per-slot into a paged cache; on one
        host we re-prefill the packed batch — same interface, simpler
        memory management.
        """
        waiting = [r for r in self.queue if not r.done and r not in self.slots]
        changed = False
        for i, slot in enumerate(self.slots):
            if (slot is None or slot.done) and waiting:
                self.slots[i] = waiting.pop(0)
                changed = True
        if changed:
            self._prefill_batch()

    def _prefill_batch(self):
        sc = self.sc
        prompts = np.zeros((sc.batch, sc.prompt_len), np.int32)
        for i, r in enumerate(self.slots):
            if r is not None:
                prompts[i, : len(r.prompt)] = r.prompt[: sc.prompt_len]
        logits, cache = self.prefill(self.params, {"tokens": jnp.asarray(prompts)})
        self.cache = cache
        self.pos = sc.prompt_len
        first = np.asarray(jnp.argmax(logits, -1), np.int32)
        for i, r in enumerate(self.slots):
            if r is not None and not r.done:
                r.out.append(int(first[i]))

    def step(self):
        """One decode step for every active slot."""
        if self.cache is None:
            return
        cur = np.array(
            [r.out[-1] if r and r.out else 0 for r in self.slots], np.int32
        )
        toks, self.cache = self.serve_step(
            self.params, jnp.asarray(cur), jnp.int32(self.pos), self.cache
        )
        self.pos += 1
        self.steps += 1
        nxt = np.asarray(toks)
        for i, r in enumerate(self.slots):
            if r is None or r.done:
                continue
            t = int(nxt[i])
            r.out.append(t)
            if len(r.out) >= r.max_new or t == self.sc.eos:
                r.done = True

    def run(self, requests: list[Request]) -> dict[str, Any]:
        t0 = time.time()
        self.broadcast_weights()  # weight multicast to the replica set
        while any(not r.done for r in requests):
            self._admit()
            if all(s is None or s.done for s in self.slots):
                break
            self.step()
            if self.pos >= self.sc.max_seq - 1:
                for r in self.slots:
                    if r is not None:
                        r.done = True
        wall = time.time() - t0
        toks = sum(len(r.out) for r in requests)
        return {
            "requests": len(requests),
            "generated_tokens": toks,
            "decode_steps": self.steps,
            "wall_s": wall,
            "tokens_per_s": toks / wall if wall else 0.0,
            "weight_multicast": self.multicast_log[-1] if self.multicast_log else None,
        }


def main(argv=None) -> dict:
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="yi-6b", choices=C.ARCHS)
    p.add_argument("--smoke", action="store_true", default=True)
    p.add_argument("--requests", type=int, default=8)
    p.add_argument("--max-new", type=int, default=16)
    p.add_argument("--batch", type=int, default=4)
    p.add_argument("--prompt-len", type=int, default=16)
    args = p.parse_args(argv)

    logging.basicConfig(level=logging.INFO, format="%(message)s")
    sc = ServeConfig(
        arch=args.arch, smoke=args.smoke, batch=args.batch,
        prompt_len=args.prompt_len,
        max_seq=args.prompt_len + args.max_new + 2,
    )
    server = Server(sc)
    rng = np.random.default_rng(0)
    reqs = [
        server.submit(
            rng.integers(0, server.cfg.vocab_size, size=sc.prompt_len),
            args.max_new,
        )
        for _ in range(args.requests)
    ]
    out = server.run(reqs)
    log.info(
        "served %d requests, %d tokens in %.2fs (%.1f tok/s); "
        "weight multicast %.1fx vs unicast",
        out["requests"], out["generated_tokens"], out["wall_s"],
        out["tokens_per_s"],
        (out["weight_multicast"] or {}).get("speedup_vs_unicast", 0.0),
    )
    return out


if __name__ == "__main__":
    main()
