"""Continuous-batching serving with Torrent P2MP weight AND KV multicast.

The serving runtime is where the paper's *dynamic* four-phase protocol
survives compilation (DESIGN.md §2): requests arrive asynchronously, and
host-side P2MP movement is driven as Torrent chain tasks with real
predicted-cycle accounting. Two payloads ride the replica plan:

* **Weight refresh** — ``broadcast_weights`` streams the *entire*
  flattened parameter tree (chunked, byte-exact; the logged byte count
  is asserted against the params' true nbytes) down the persistent
  ``parallel.collectives.MultiChainPlan``'s sub-chains.
* **KV-block multicast** — ``register_prefix`` prefilles a shared
  prompt prefix (system prompt / few-shot preamble) ONCE, flattens the
  per-position KV rows to a dense bf16 matrix
  (:mod:`repro.launch.paged_kv`), broadcasts the bytes to every replica
  as a ``core.program.plan_broadcast`` ChainProgram (priced by
  ``simulator.program_latency`` / ``program_wire_bytes``; delivered
  byte-exactly by ``MultiChainTask``), and each receiving replica runs
  the :mod:`repro.kernels.relayout` kernel to materialize its paged
  ``(page, F)`` block layout — pinned bit-exactly against the numpy
  oracle. Requests whose prompt starts with a registered prefix are
  admitted by *seeding* the cached rows instead of re-prefilling them.

The decode loop is slot-based continuous batching with **per-slot
positions**: every slot advances at its own absolute position
(``(B,)``-vector ``pos`` through ``models.transformer.decode_step``),
admission prefilles ONLY the admitted slot
(``launch.steps.make_slot_prefill_step`` + ``write_cache_slot``), and a
slot finishes only when *it* runs out of room — an admission or another
slot's exhaustion never perturbs an in-flight request's tokens.

Elastic serving: ``Server.scale_down`` handles replica loss by
*re-forming* the live plan around the lost members
(``runtime.elastic.scale_down_plan`` → ``MultiChainPlan.reform``)
instead of rebuilding it.

    PYTHONPATH=src python -m repro.launch.serve --arch yi-6b --smoke \
        --requests 16 --max-new 32
"""

from __future__ import annotations

import argparse
import dataclasses
import logging
import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs as C
from repro.core.chaintask import MultiChainTask
from repro.core.program import plan_broadcast, program_wire_bytes
from repro.core.simulator import program_latency, unicast_latency
from repro.core.topology import MeshTopology
from repro.launch.paged_kv import (
    PrefixCache,
    PrefixEntry,
    dense_from_bytes,
    extract_dense_kv,
    paged_ref,
    seed_cache_row,
    to_paged,
)
from repro.launch.steps import (
    make_serve_step,
    make_slot_prefill_step,
    write_cache_slot,
)
from repro.models import transformer as T
from repro.parallel.collectives import MultiChainPlan
from repro.runtime.elastic import scale_down_plan

log = logging.getLogger("repro.serve")


@dataclasses.dataclass(eq=False)
class Request:
    rid: int
    prompt: np.ndarray  # (S,) int32
    max_new: int
    arrival: int = 0  # decode tick the request becomes visible
    out: list[int] = dataclasses.field(default_factory=list)
    done: bool = False
    prefix_hit: bool = False  # admitted by seeding a registered prefix
    t_admit: int | None = None  # decode tick admitted to a slot
    t_done: int | None = None  # decode tick the last token was emitted


@dataclasses.dataclass
class ServeConfig:
    arch: str = "yi-6b"
    smoke: bool = True
    batch: int = 4  # decode slots
    prompt_len: int = 16  # admission window: longest accepted prompt
    max_seq: int = 128
    eos: int = -1  # -1: run to max_new
    replicas: int = 4  # model replicas for weight/KV multicast
    page_size: int = 8  # KV page height (positions per paged block)
    prefix_cache_bytes: int | None = None  # None = unbounded, else LRU
    seed: int = 0


class Server:
    """Slot-based continuous batching with greedy decode, per-slot
    positions, and a multicast-fed prefix cache."""

    def __init__(self, sc: ServeConfig):
        self.sc = sc
        self.cfg = C.get_smoke_config(sc.arch) if sc.smoke else C.get_config(sc.arch)
        key = jax.random.PRNGKey(sc.seed)
        self.params = T.model_init(key, self.cfg)
        self.slot_prefill = jax.jit(make_slot_prefill_step(self.cfg, sc.max_seq))
        self.serve_step = jax.jit(make_serve_step(self.cfg))
        self.queue: list[Request] = []
        self.slots: list[Request | None] = [None] * sc.batch
        self.cache = T.init_cache(self.cfg, sc.batch, sc.max_seq)
        self.clock = 0  # decode ticks (the traffic harness's time base)
        self.steps = 0
        # P2MP bookkeeping (paper Fig. 4 host orchestration): ONE
        # persistent multi-chain plan for the replica set — elastic
        # scale-down re-forms it (endpoint-side) instead of rebuilding.
        self.replicas = sc.replicas
        self.topo = MeshTopology(max(2, sc.replicas), 1)
        self.plan = MultiChainPlan(
            self.topo, 0, list(range(1, sc.replicas)), scheduler="tsp"
        )
        self.multicast_log: list[dict] = []
        self.last_delivery: dict[int, np.ndarray] = {}
        self.prefix_cache = PrefixCache(capacity_bytes=sc.prefix_cache_bytes)
        self.kv_multicast_log: list[dict] = []

    # -- the paper's host-side P2MP: weight refresh to replicas ----------
    def broadcast_weights(self, chunk_bytes: int = 1 << 20,
                          new_params=None) -> dict:
        """Multicast the FULL parameter tree to every surviving replica
        down the persistent plan's sub-chains, ``chunk_bytes`` at a
        time. The logged ``bytes`` is asserted against the params' true
        nbytes — the record describes a real weight refresh. With no
        surviving destinations (``replicas=1``) nothing moves and the
        record says so: a distinct no-op with 0 chunks / 0 delivered
        bytes, never a phantom full-payload claim.

        ``new_params`` replaces the served weights before streaming and
        version-invalidates the prefix cache (cached KV was prefilled
        under the old weights); re-broadcasting unchanged weights —
        e.g. the refresh at ``run()`` start — keeps entries valid.
        ``prefix_invalidated`` in the record counts what was dropped."""
        invalidated = 0
        if new_params is not None:
            self.params = new_params
            invalidated = self.prefix_cache.on_weights_update()
        dests = self.plan.survivors
        if not dests:
            rec = {
                "bytes": 0, "delivered_bytes": 0, "chunks": 0,
                "replicas": 1, "cycles": 0, "speedup_vs_unicast": 1.0,
                "noop": True, "prefix_invalidated": invalidated,
            }
            self.last_delivery = {}
            self.multicast_log.append(rec)
            return rec
        flat, _ = jax.tree_util.tree_flatten(self.params)
        true_nbytes = sum(int(np.asarray(x).nbytes) for x in flat)
        # dtype-agnostic byte stream: the wire moves bytes, not floats
        payload = (
            np.concatenate(
                [np.ascontiguousarray(x).reshape(-1).view(np.uint8) for x in flat]
            )
            if flat
            else np.zeros(0, np.uint8)
        )
        cycles = unicast = chunks = 0
        parts: dict[int, list[np.ndarray]] = {d: [] for d in dests}
        for off in range(0, payload.size, max(1, int(chunk_bytes))):
            chunk = payload[off : off + max(1, int(chunk_bytes))]
            task = MultiChainTask(
                self.topo, 0, dests, chunk,
                chains=[list(c) for c in self.plan.chains],
            )
            bufs = task.run()
            for d, buf in bufs.items():
                parts[d].append(buf)
            cycles += task.cycle_ledger["total"]
            unicast += task.unicast_cycles()
            chunks += 1
        self.last_delivery = {
            d: np.concatenate(p) if p else np.zeros(0, np.uint8)
            for d, p in parts.items()
        }
        rec = {
            "bytes": int(payload.nbytes),
            "delivered_bytes": sum(
                int(b.nbytes) for b in self.last_delivery.values()
            ),
            "chunks": chunks,
            "replicas": len(dests) + 1,
            "cycles": cycles,
            "speedup_vs_unicast": unicast / cycles if cycles else 1.0,
            "prefix_invalidated": invalidated,
        }
        if rec["bytes"] != true_nbytes:
            raise AssertionError(
                f"weight refresh logged {rec['bytes']} B but params hold "
                f"{true_nbytes} B"
            )
        self.multicast_log.append(rec)
        return rec

    # -- KV-block multicast: prefill a shared prefix once, chain it out --
    def register_prefix(self, tokens: np.ndarray) -> PrefixEntry:
        """Prefill a shared prompt prefix on the head replica, broadcast
        its KV rows to every survivor as a ``plan_broadcast``
        ChainProgram, and relayout them into paged blocks on receipt.

        Delivery is byte-exact and the modeled wire bytes
        (``program_wire_bytes``) are asserted against the bytes the
        chain task actually delivered; each replica's paged blocks are
        pinned bit-exactly against the ``relayout_ref`` numpy oracle."""
        sc = self.sc
        tokens = np.asarray(tokens, np.int32).reshape(-1)
        plen = int(tokens.size)
        if plen == 0 or plen % sc.page_size:
            raise ValueError(
                f"prefix length {plen} must be a positive multiple of "
                f"page_size={sc.page_size}"
            )
        if plen >= sc.max_seq:
            raise ValueError(f"prefix length {plen} >= max_seq {sc.max_seq}")
        # scratch B=1 prefill — the live slots are never touched
        _, one_cache = self.slot_prefill(self.params, jnp.asarray(tokens)[None])
        dense = extract_dense_kv(one_cache, 0, plen, sc.max_seq)
        paged = to_paged(dense, sc.page_size)
        oracle = paged_ref(dense, sc.page_size)
        np.testing.assert_array_equal(
            paged.view(np.uint8), oracle.view(np.uint8)
        )  # relayout kernel pinned against its numpy oracle
        entry = PrefixEntry(
            tokens=tokens, page=sc.page_size, dense=dense, paged=paged
        )
        entry.broadcast = self._broadcast_kv(entry)
        self.prefix_cache.add(entry)
        self.kv_multicast_log.append(entry.broadcast)
        return entry

    def _broadcast_kv(self, entry: PrefixEntry) -> dict:
        """Chain the dense KV rows to the surviving replicas and paged-
        relayout them on each receiver."""
        dests = self.plan.survivors
        payload = np.ascontiguousarray(entry.dense).reshape(-1).view(np.uint8)
        nbytes = int(payload.nbytes)
        if not dests:
            entry.replica_paged = {0: entry.paged}
            return {
                "prefix_len": entry.plen, "bytes": nbytes,
                "delivered_bytes": 0, "wire_bytes": 0, "replicas": 1,
                "cycles": 0, "modeled_cycles": 0,
                "speedup_vs_unicast": 1.0, "noop": True,
            }
        chains = tuple(tuple(c) for c in self.plan.chains)
        program = plan_broadcast(self.topo.num_nodes, 0, chains)
        modeled_wire = program_wire_bytes(program, nbytes)
        modeled_cc = int(program_latency(self.topo, 0, program, nbytes))
        uni_cc = int(unicast_latency(self.topo, 0, dests, nbytes))
        task = MultiChainTask(
            self.topo, 0, dests, payload, chains=[list(c) for c in chains]
        )
        bufs = task.run()
        delivered = 0
        replica_paged = {0: entry.paged}
        F = entry.dense.shape[1]
        for d, buf in bufs.items():
            rdense = dense_from_bytes(buf, entry.plen, F)
            np.testing.assert_array_equal(
                rdense.view(np.uint8), entry.dense.view(np.uint8)
            )  # byte-exact delivery vs the prefilling replica
            rpaged = to_paged(rdense, entry.page)
            np.testing.assert_array_equal(
                rpaged.view(np.uint8),
                paged_ref(entry.dense, entry.page).view(np.uint8),
            )  # receiver-side relayout pinned vs the numpy oracle
            replica_paged[d] = rpaged
            delivered += int(buf.nbytes)
        # Two byte books, each checked against its own invariant: the
        # task must deliver the FULL payload to every destination, and
        # the planned program's wire bytes are the fused-ppermute HLO
        # attribution — (steps + K - 1) payloads, which equals the
        # delivered bytes exactly when the plan is a single chain.
        if delivered != len(dests) * nbytes:
            raise AssertionError(
                f"KV broadcast delivered {delivered} B, expected "
                f"{len(dests)} x {nbytes} B"
            )
        if modeled_wire != (len(program.steps) + len(chains) - 1) * nbytes:
            raise AssertionError(
                f"planned program prices {modeled_wire} B, expected "
                f"{len(program.steps) + len(chains) - 1} x {nbytes} B"
            )
        entry.replica_paged = replica_paged
        return {
            "prefix_len": entry.plen,
            "bytes": nbytes,
            "delivered_bytes": delivered,
            "wire_bytes": modeled_wire,
            "replicas": len(dests) + 1,
            "cycles": int(task.cycle_ledger["total"]),
            "modeled_cycles": modeled_cc,
            "unicast_cycles": uni_cc,
            "speedup_vs_unicast": (
                uni_cc / modeled_cc if modeled_cc else 1.0
            ),
        }

    # -- elastic scale-down: re-form the live plan, never rebuild it -----
    def scale_down(self, replicas: int) -> tuple[int, ...]:
        """Shrink the replica set to ``replicas`` (keeping replica 0,
        the plan head). The lost members are spliced out of the live
        ``MultiChainPlan`` as a concurrent failure set — surviving
        sub-chains keep their schedules verbatim and the next
        :meth:`broadcast_weights` still delivers full weights to every
        survivor. Returns the lost replica ids."""
        lost = scale_down_plan(self.plan, self.replicas, replicas)
        if lost:
            log.info("scale-down: lost replicas %s, plan re-formed", list(lost))
        self.replicas = int(replicas)
        return lost

    # -- request lifecycle -------------------------------------------------
    def submit(self, prompt: np.ndarray, max_new: int, arrival: int = 0) -> Request:
        """Queue a request. Prompts longer than the admission window are
        rejected HERE — never silently truncated into a different
        prompt — as are empty ones."""
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if prompt.size == 0:
            raise ValueError("empty prompt")
        if prompt.size > self.sc.prompt_len:
            raise ValueError(
                f"prompt length {prompt.size} exceeds the admission window "
                f"prompt_len={self.sc.prompt_len}; refusing to truncate"
            )
        req = Request(
            rid=len(self.queue), prompt=prompt, max_new=max_new,
            arrival=int(arrival),
        )
        self.queue.append(req)
        return req

    def _admit(self):
        """Fill free slots with arrived requests, prefilling ONLY the
        admitted slot — in-flight rows are never rebuilt."""
        waiting = [
            r for r in self.queue
            if not r.done and r.t_admit is None and r.arrival <= self.clock
        ]
        for i, slot in enumerate(self.slots):
            if (slot is None or slot.done) and waiting:
                r = waiting.pop(0)
                self.slots[i] = r
                r.t_admit = self.clock
                self._prefill_slot(i)

    def _prefill_slot(self, i: int):
        """Fill slot ``i``'s cache row and emit its first token.

        Prefix-cache hit: seed the registered prefix's multicast KV rows
        straight into the row (bit-identical to prefilling them) and run
        only the prompt's suffix through single-row decode. Miss: exact-
        length full prefill of this row alone."""
        r = self.slots[i]
        prompt = r.prompt
        plen = int(prompt.size)
        entry = self.prefix_cache.lookup(prompt) if self.prefix_cache.entries else None
        if entry is not None:
            # keep at least one token to feed through decode so the
            # slot's first output falls out of the last suffix step
            seed = entry.plen if entry.plen < plen else plen - 1
            r.prefix_hit = True
            if seed:
                self.cache = seed_cache_row(self.cache, i, entry.dense, seed)
            one_cache = jax.tree.map(lambda t: t[:, i : i + 1], self.cache)
            tok = None
            for p in range(seed, plen):
                tok, one_cache = self.serve_step(
                    self.params,
                    jnp.asarray([int(prompt[p])], jnp.int32),
                    jnp.int32(p),
                    one_cache,
                )
            self.cache = write_cache_slot(self.cache, one_cache, i)
            first = int(np.asarray(tok)[0])
        else:
            first_tok, one_cache = self.slot_prefill(
                self.params, jnp.asarray(prompt)[None]
            )
            self.cache = write_cache_slot(self.cache, one_cache, i)
            first = int(np.asarray(first_tok)[0])
        r.out.append(first)
        self._maybe_finish(r)

    def _maybe_finish(self, r: Request):
        t = r.out[-1]
        if (
            len(r.out) >= r.max_new
            or t == self.sc.eos
            or len(r.prompt) + len(r.out) >= self.sc.max_seq
        ):
            r.done = True
            r.t_done = self.clock

    def _active(self) -> list[int]:
        return [
            i for i, r in enumerate(self.slots)
            if r is not None and not r.done and r.out
        ]

    def step(self):
        """One decode step: every active slot advances at its OWN
        absolute position (inactive rows are parked at position 0 and
        their tokens discarded — their rows are rewritten on the next
        admission)."""
        active = self._active()
        if not active:
            return
        B = self.sc.batch
        cur = np.zeros(B, np.int32)
        pos = np.zeros(B, np.int32)
        for i in active:
            r = self.slots[i]
            cur[i] = r.out[-1]
            pos[i] = len(r.prompt) + len(r.out) - 1
        toks, self.cache = self.serve_step(
            self.params, jnp.asarray(cur), jnp.asarray(pos), self.cache
        )
        self.clock += 1
        self.steps += 1
        nxt = np.asarray(toks)
        for i in active:
            r = self.slots[i]
            r.out.append(int(nxt[i]))
            self._maybe_finish(r)

    def run(self, requests: list[Request]) -> dict[str, Any]:
        t0 = time.time()
        self.broadcast_weights()  # weight multicast to the replica set
        while any(not r.done for r in requests):
            self._admit()
            if not self._active():
                future = [
                    r.arrival for r in self.queue
                    if not r.done and r.t_admit is None
                ]
                if not future:
                    break
                # idle until the next arrival
                self.clock = max(self.clock + 1, min(future))
                continue
            self.step()
        wall = time.time() - t0
        served = [r for r in requests if r.done]
        lat = [r.t_done - r.arrival for r in served if r.t_done is not None]
        toks = sum(len(r.out) for r in requests)
        return {
            "requests": len(requests),
            "served": len(served),
            "generated_tokens": toks,
            "decode_steps": self.steps,
            "wall_s": wall,
            "tokens_per_s": toks / wall if wall else 0.0,
            "prefix_hit_rate": self.prefix_cache.hit_rate,
            "prefix_entries": len(self.prefix_cache.entries),
            "prefix_bytes": self.prefix_cache.total_bytes,
            "prefix_evictions": self.prefix_cache.evictions,
            "prefix_invalidations": self.prefix_cache.invalidations,
            "latency_ticks_p50": float(np.percentile(lat, 50)) if lat else 0.0,
            "latency_ticks_p99": float(np.percentile(lat, 99)) if lat else 0.0,
            "weight_multicast": self.multicast_log[-1] if self.multicast_log else None,
            "kv_multicast": self.kv_multicast_log[-1] if self.kv_multicast_log else None,
        }


def main(argv=None) -> dict:
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="yi-6b", choices=C.ARCHS)
    p.add_argument("--smoke", action="store_true", default=True)
    p.add_argument("--requests", type=int, default=8)
    p.add_argument("--max-new", type=int, default=16)
    p.add_argument("--batch", type=int, default=4)
    p.add_argument("--prompt-len", type=int, default=16)
    args = p.parse_args(argv)

    logging.basicConfig(level=logging.INFO, format="%(message)s")
    sc = ServeConfig(
        arch=args.arch, smoke=args.smoke, batch=args.batch,
        prompt_len=args.prompt_len,
        max_seq=args.prompt_len + args.max_new + 2,
    )
    server = Server(sc)
    rng = np.random.default_rng(0)
    reqs = [
        server.submit(
            rng.integers(0, server.cfg.vocab_size, size=sc.prompt_len),
            args.max_new,
        )
        for _ in range(args.requests)
    ]
    out = server.run(reqs)
    log.info(
        "served %d requests, %d tokens in %.2fs (%.1f tok/s); "
        "weight multicast %.1fx vs unicast",
        out["requests"], out["generated_tokens"], out["wall_s"],
        out["tokens_per_s"],
        (out["weight_multicast"] or {}).get("speedup_vs_unicast", 0.0),
    )
    return out


if __name__ == "__main__":
    main()
