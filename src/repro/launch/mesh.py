"""Production mesh construction (single-pod 16×16, multi-pod 2×16×16).

Functions, not module-level constants — importing this module never
touches jax device state (device count locks on first use)."""

from __future__ import annotations

import jax


def _make(shape: tuple[int, ...], axes: tuple[str, ...]) -> jax.sharding.Mesh:
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
    )


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    """16×16 single pod (256 chips) or 2×16×16 two-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make(shape, axes)


def make_host_mesh(data: int | None = None, model: int = 1) -> jax.sharding.Mesh:
    """Small mesh over the actually-present devices (tests/examples)."""
    n = len(jax.devices())
    data = (n // model) if data is None else data
    return _make((data, model), ("data", "model"))
