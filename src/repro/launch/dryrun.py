"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this produces, WITHOUT allocating model memory
(ShapeDtypeStruct inputs only):

* proof the distribution config is coherent (`.lower().compile()`),
* ``memory_analysis()``  — per-device bytes (fits-in-HBM evidence),
* ``cost_analysis()``    — FLOPs / bytes for §Roofline,
* HLO collective-bytes breakdown (§Roofline collective term).

One cell per process (XLA leaks across big compiles on one core):
``python -m repro.launch.dryrun --arch llama3-8b --shape train_4k
--mesh single`` runs one cell; ``--all`` spawns subprocesses.

Results land in ``experiments/dryrun/<mesh>/<arch>__<shape>.json``.
"""

import argparse
import json
import os
import subprocess
import sys
import time
import traceback


def run_cell(arch: str, shape_name: str, mesh_kind: str, out_dir: str,
             collectives: str = "xla", remat: str = "dots",
             variant: str = "baseline", num_chains: int | str = 1,
             ar_algo: str = "rs_ag", compress_grads: bool = False,
             bucket_bytes: int | None = None,
             topology: str | None = None,
             src_read_bw: int | None = None,
             overlap: bool = False) -> dict:
    import jax

    from repro import configs as C
    from repro.launch import roofline as R
    from repro.launch.mesh import make_production_mesh
    from repro.launch.steps import build_cell

    ok, reason = C.applicable(arch, shape_name)
    rec: dict = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind,
        "collectives": collectives, "remat": remat, "variant": variant,
        "num_chains": num_chains, "ar_algo": ar_algo,
        "compress_grads": compress_grads, "bucket_bytes": bucket_bytes,
        "topology": topology, "src_read_bw": src_read_bw,
    }
    if not ok:
        rec.update(status="skipped", reason=reason)
        return rec

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    t0 = time.time()
    cell = build_cell(arch, shape_name, mesh, collectives=collectives,
                      num_chains=num_chains, ar_algo=ar_algo,
                      remat=remat, variant=variant,
                      compress_grads=compress_grads,
                      bucket_bytes=bucket_bytes,
                      topology=topology)
    rec["num_chains"] = cell.num_chains  # effective K (VARIANTS resolved)
    rec["ar_algo"] = cell.ar_algo
    rec["compress_grads"] = cell.compress_grads
    rec["bucket_bytes"] = cell.bucket_bytes
    rec["topology"] = cell.topology
    lowered = cell.lower()
    t1 = time.time()
    compiled = lowered.compile()
    t2 = time.time()

    mem = compiled.memory_analysis()
    roof = R.extract(compiled)
    cfg = cell.cfg
    n_active = _active_params(arch, cfg)
    tokens = (
        cell.shape.global_batch * cell.shape.seq_len
        if cell.shape.kind in ("train", "prefill")
        else cell.shape.global_batch
    )
    mf = R.model_flops(n_active, tokens, cell.shape.kind)
    chips = mesh.devices.size
    flops_global = roof.flops * chips

    rec.update(
        status="ok",
        lower_s=round(t1 - t0, 2),
        compile_s=round(t2 - t1, 2),
        chips=chips,
        memory_analysis=_mem_dict(mem),
        roofline=roof.as_dict(),
        model_flops_global=mf,
        hlo_flops_global=flops_global,
        useful_flops_ratio=(mf / flops_global) if flops_global else None,
    )
    if overlap:
        # Modeled bucketed-overlap timeline + HLO async/interleaving
        # evidence.  Prices the inner "data"-axis ring stage (the only
        # stage on single-pod meshes, where the model is exact).
        from repro.launch.hlo_breakdown import overlap_stats
        from repro.models import transformer as T

        leaves = jax.tree.leaves(
            jax.eval_shape(lambda: T.model_init(jax.random.PRNGKey(0), cfg))
        )
        bb = cell.bucket_bytes or (4 << 20)
        rec["overlap_model"] = R.modeled_train_overlap(
            leaves,
            int(mesh.shape["data"]),
            max(1, tokens // chips),
            bucket_bytes=bb,
            num_chains=cell.num_chains,
            algo=cell.ar_algo,
            wire_dtype="int8" if cell.compress_grads else None,
            topology=cell.topology,
            src_read_bw=src_read_bw,
        )
        rec["hlo_overlap"] = overlap_stats(compiled.as_text())
    return rec


def _active_params(arch: str, cfg) -> int:
    """Active (per-token) parameter count — MoE counts top-k+shared."""
    import jax

    from repro.models import transformer as T

    shapes = jax.eval_shape(lambda: T.model_init(jax.random.PRNGKey(0), cfg))
    total = sum(x.size for x in jax.tree.leaves(shapes))
    if not cfg.num_experts:
        return total
    # subtract routed-expert params not active per token
    moe_layers = sum(
        1 for i in range(cfg.num_layers) if cfg.layer_spec(i).ffn == "moe"
    )
    per_expert = 3 * cfg.d_model * cfg.moe_d_ff
    inactive = moe_layers * per_expert * (cfg.num_experts - cfg.moe_top_k)
    return total - inactive


def _mem_dict(mem) -> dict:
    out = {}
    for key in (
        "temp_size_in_bytes",
        "argument_size_in_bytes",
        "output_size_in_bytes",
        "alias_size_in_bytes",
        "generated_code_size_in_bytes",
        "peak_memory_in_bytes",
    ):
        val = getattr(mem, key, None)
        if val is not None:
            out[key] = int(val)
    if not out:
        out["repr"] = str(mem)
    return out


def main() -> None:
    # CLI-only: fake a 512-device host platform BEFORE the jax backend
    # initializes (set here, not at import, so importing this module for
    # _cell_suffix etc. never changes the caller's device count; --all
    # workers re-run main() in their own process and set it themselves)
    os.environ["XLA_FLAGS"] = (
        "--xla_force_host_platform_device_count=512 "
        + os.environ.get("XLA_FLAGS", "")
    )
    p = argparse.ArgumentParser()
    p.add_argument("--arch")
    p.add_argument("--shape")
    p.add_argument("--mesh", choices=("single", "multi"), default="single")
    p.add_argument("--collectives", choices=("xla", "torrent"), default="xla")
    p.add_argument("--remat", default="dots")
    p.add_argument("--variant", default="baseline",
                   help="optimization bundle from steps.VARIANTS")
    p.add_argument("--num-chains", type=_parse_num_chains, default=1,
                   help="multi-chain Chainwrite sub-rings per DP "
                        "reduction (with --collectives torrent), or "
                        "'auto' to pick K from the all_reduce_latency "
                        "model; sweepable next to --collectives")
    from repro.core.chainwrite_ref import ALL_REDUCE_ALGOS  # numpy-only

    p.add_argument("--ar-algo", choices=ALL_REDUCE_ALGOS,
                   default="rs_ag",
                   help="multi-ring all-reduce schedule: fused "
                        "reduce-scatter/all-gather (bandwidth-optimal "
                        "default) or full-payload rotation")
    p.add_argument("--compress-grads", action="store_true", default=False,
                   help="int8 wire for the DP gradient all-reduce "
                        "(requires --collectives torrent)")
    p.add_argument("--bucket-mb", type=float, default=None,
                   help="bucket size (MiB) for the bucketed, backward-"
                        "overlapped DP grad reduce (requires "
                        "--collectives torrent)")
    p.add_argument("--topology", default=None,
                   help="tiered link-graph spec for auto-K ring planning "
                        "(requires --collectives torrent), e.g. "
                        "'pods=4x(4x4):interpod_bw=0.25' or 'pods=2'; "
                        "parsed by core.topology.parse_topology_spec")
    p.add_argument("--src-read-bw", type=int, default=None,
                   help="source HBM read bandwidth (bytes/cc) for the "
                        "modeled overlap timeline; None = unconstrained "
                        "(link-bw-limited)")
    p.add_argument("--overlap", action="store_true", default=False,
                   help="emit the modeled bucketed-overlap timeline "
                        "(roofline.modeled_train_overlap) and HLO "
                        "async/interleaving counts in the record")
    p.add_argument("--out", default="experiments/dryrun")
    p.add_argument("--all", action="store_true")
    p.add_argument("--meshes", default="single,multi")
    p.add_argument("--timeout", type=int, default=3000)
    args = p.parse_args()

    if args.all:
        from repro import configs as C

        failures = []
        for mesh_kind in args.meshes.split(","):
            for arch in C.ARCHS:
                for shape in C.SHAPES:
                    rc = _run_subprocess(arch, shape, mesh_kind, args)
                    if rc != 0:
                        failures.append((mesh_kind, arch, shape))
        if failures:
            print("FAILED CELLS:", failures)
            sys.exit(1)
        print("ALL CELLS OK")
        return

    out_dir = os.path.join(args.out, args.mesh)
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(
        out_dir, f"{args.arch}__{args.shape}{_cell_suffix(args)}.json"
    )
    try:
        rec = run_cell(
            args.arch, args.shape, args.mesh, out_dir,
            collectives=args.collectives, remat=args.remat,
            variant=args.variant, num_chains=args.num_chains,
            ar_algo=args.ar_algo, compress_grads=args.compress_grads,
            bucket_bytes=(
                int(args.bucket_mb * (1 << 20)) if args.bucket_mb else None
            ),
            topology=args.topology,
            src_read_bw=args.src_read_bw,
            overlap=args.overlap,
        )
    except Exception:
        rec = {
            "arch": args.arch, "shape": args.shape, "mesh": args.mesh,
            "status": "error", "traceback": traceback.format_exc(),
        }
        with open(path, "w") as f:
            json.dump(rec, f, indent=2)
        print(rec["traceback"], file=sys.stderr)
        sys.exit(1)
    with open(path, "w") as f:
        json.dump(rec, f, indent=2)
    if rec["status"] == "ok":
        print(
            f"{args.arch} × {args.shape} × {args.mesh}: OK "
            f"compile={rec['compile_s']}s dominant={rec['roofline']['dominant']}"
        )
    else:
        print(f"{args.arch} × {args.shape} × {args.mesh}: {rec['status']} ({rec.get('reason','')})")


def _parse_num_chains(value: str):
    """CLI type for --num-chains: a positive int or the literal 'auto'."""
    if value == "auto":
        return "auto"
    k = int(value)
    if k < 1:
        raise argparse.ArgumentTypeError("num-chains must be >= 1 or 'auto'")
    return k


def _cell_suffix(args) -> str:
    """Output-file suffix encoding every non-default cell knob — shared
    by the single-cell writer and the --all cache check so sweeps over
    different knobs never collide on (or get skipped for) one path."""
    suffix = "" if args.collectives == "xla" else f"__{args.collectives}"
    if args.num_chains != 1:
        suffix += f"__k{args.num_chains}"
    if args.ar_algo != "rs_ag":
        suffix += f"__{args.ar_algo}"
    if args.compress_grads:
        suffix += "__int8"
    mb = getattr(args, "bucket_mb", 0)
    if mb:
        suffix += f"__b{int(mb) if mb == int(mb) else mb}MB"
    topo = getattr(args, "topology", None)
    if topo:
        # spec strings contain ':'/'('/')' — sanitize for filenames
        safe = "".join(c if c.isalnum() or c in "x=." else "-" for c in topo)
        suffix += f"__topo-{safe}"
    srbw = getattr(args, "src_read_bw", None)
    if srbw:
        suffix += f"__srbw{srbw}"
    if args.variant != "baseline":
        suffix += f"__{args.variant}"
    if args.remat != "dots":
        suffix += f"__remat-{args.remat}"
    return suffix


def _run_subprocess(arch: str, shape: str, mesh_kind: str, args) -> int:
    out_dir = os.path.join(args.out, mesh_kind)
    path = os.path.join(out_dir, f"{arch}__{shape}{_cell_suffix(args)}.json")
    if os.path.exists(path):
        with open(path) as f:
            if json.load(f).get("status") in ("ok", "skipped"):
                print(f"skip (cached): {path}")
                return 0
    cmd = [
        sys.executable, "-m", "repro.launch.dryrun",
        "--arch", arch, "--shape", shape, "--mesh", mesh_kind,
        "--collectives", args.collectives, "--remat", args.remat,
        "--num-chains", str(args.num_chains), "--ar-algo", args.ar_algo,
        "--variant", args.variant, "--out", args.out,
    ]
    if args.compress_grads:
        cmd.append("--compress-grads")
    if args.bucket_mb:
        cmd += ["--bucket-mb", str(args.bucket_mb)]
    if getattr(args, "topology", None):
        cmd += ["--topology", args.topology]
    if getattr(args, "src_read_bw", None):
        cmd += ["--src-read-bw", str(args.src_read_bw)]
    if args.overlap:
        cmd.append("--overlap")
    print("::", " ".join(cmd[3:]), flush=True)
    try:
        r = subprocess.run(cmd, timeout=args.timeout)
        return r.returncode
    except subprocess.TimeoutExpired:
        print(f"TIMEOUT: {arch} {shape} {mesh_kind}")
        return 124


if __name__ == "__main__":
    main()
