"""Paged KV blocks and the prefix cache behind KV-block multicast serving.

Millions of users share prompt prefixes (system prompts, few-shot
preambles). The serving tentpole prefilles such a prefix ONCE on one
replica, flattens the per-position KV rows into a dense ``(plen, F)``
bf16 matrix, broadcasts the raw bytes to the replica set down a
``core.program.plan_broadcast`` ChainProgram, and each receiving replica
runs the :mod:`repro.kernels.relayout` kernel to convert the dense rows
into its paged ``(page, F)``-blocked layout (the XDMA "layout-flexible
delivery" side of the paper's P2MP story). The numpy oracle
(:func:`paged_ref`) pins the kernel output bit-exactly.

Why this is exact (not an approximation): a position's KV row is that
token's projection (+RoPE at its absolute position) only — independent
of every other token — so a prefix's KV rows are identical for any
prompt sharing the prefix, and seeding them into a fresh slot
(:func:`seed_cache_row`) reproduces the full-prefill cache bit-for-bit.

Layout glossary (relayout kernel terms): the dense matrix is the
``(1, F)``-blocked layout (one row per block); the paged cache is the
``(page, F)``-blocked layout — each block is one KV page, contiguous in
memory, so a page is the unit a replica can place anywhere in its block
pool.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.relayout import relayout, relayout_ref

__all__ = [
    "BF16",
    "kv_feature_width",
    "extract_dense_kv",
    "seed_cache_row",
    "to_paged",
    "paged_ref",
    "dense_from_bytes",
    "PrefixEntry",
    "PrefixCache",
]

# numpy bf16 via ml_dtypes (jax's wire dtype for KV caches)
BF16 = np.dtype(jnp.bfloat16)


def _positional_leaves(cache: dict, max_seq: int) -> list:
    """The cache leaves carrying a per-position axis at dim 2.

    Decode caches stack layer groups as ``(reps, B, max_seq, *feat)``
    (gqa: k/v; mla: ckv/krope). Mixers without positional state (mamba)
    have no such axis — KV multicast is not defined for them."""
    leaves = jax.tree.leaves(cache["layers"])
    for leaf in leaves:
        if leaf.ndim < 3 or leaf.shape[2] != max_seq:
            raise ValueError(
                "KV multicast needs per-position cache leaves "
                f"(reps, B, {max_seq}, ...); got {leaf.shape} — "
                "non-attention mixers (mamba) are not supported"
            )
    return leaves


def kv_feature_width(cache: dict, max_seq: int) -> int:
    """F: bf16 values per cache position across all layers/leaves."""
    total = 0
    for leaf in _positional_leaves(cache, max_seq):
        reps = leaf.shape[0]
        feat = int(np.prod(leaf.shape[3:])) if leaf.ndim > 3 else 1
        total += reps * feat
    return total


def extract_dense_kv(cache: dict, row: int, plen: int, max_seq: int) -> np.ndarray:
    """Flatten cache positions ``[0, plen)`` of slot ``row`` into a
    dense ``(plen, F)`` bf16 matrix (position-major, leaves concatenated
    along F in tree order)."""
    mats = []
    for leaf in _positional_leaves(cache, max_seq):
        arr = np.asarray(jax.device_get(leaf)).astype(BF16)
        a = arr[:, row, :plen]  # (reps, plen, *feat)
        mats.append(np.moveaxis(a, 0, 1).reshape(plen, -1))
    return np.ascontiguousarray(np.concatenate(mats, axis=1))


def seed_cache_row(cache: dict, row: int, dense: np.ndarray, seed_len: int) -> dict:
    """Inverse of :func:`extract_dense_kv`: write ``dense[:seed_len]``
    into positions ``[0, seed_len)`` of slot ``row``. Exact — the seeded
    rows are bit-identical to a full prefill of the same tokens."""
    layers = cache["layers"]
    leaves, treedef = jax.tree.flatten(layers)
    max_seq = leaves[0].shape[2]
    _positional_leaves(cache, max_seq)  # validate
    off = 0
    out = []
    for leaf in leaves:
        reps = leaf.shape[0]
        feat_shape = tuple(leaf.shape[3:])
        width = reps * (int(np.prod(feat_shape)) if feat_shape else 1)
        seg = np.asarray(dense[:seed_len, off : off + width])
        off += width
        block = np.moveaxis(
            seg.reshape((seed_len, reps) + feat_shape), 1, 0
        )  # (reps, seed_len, *feat)
        out.append(leaf.at[:, row, :seed_len].set(jnp.asarray(block, leaf.dtype)))
    if off != dense.shape[1]:
        raise ValueError(f"dense width {dense.shape[1]} != cache width {off}")
    return {**cache, "layers": jax.tree.unflatten(treedef, out)}


def to_paged(dense: np.ndarray, page: int, *, interpret: bool | None = None) -> np.ndarray:
    """Dense ``(plen, F)`` rows -> paged ``(npages, page, F)`` blocks via
    the relayout kernel (``(1, F)``-blocked -> ``(page, F)``-blocked)."""
    plen, F = dense.shape
    if plen % page:
        raise ValueError(f"prefix length {plen} not a multiple of page {page}")
    src = jnp.asarray(dense).reshape(plen, 1, 1, F)  # (1,F)-blocked
    out = relayout(src, (plen, F), (1, F), (page, F), interpret=interpret)
    return np.asarray(jax.device_get(out))[:, 0]  # (npages, page, F)


def paged_ref(dense: np.ndarray, page: int) -> np.ndarray:
    """Numpy oracle twin of :func:`to_paged` through ``relayout_ref``."""
    plen, F = dense.shape
    src = jnp.asarray(dense).reshape(plen, 1, 1, F)
    out = relayout_ref(src, (plen, F), (1, F), (page, F))
    return np.asarray(jax.device_get(out))[:, 0]


def dense_from_bytes(buf: np.ndarray, plen: int, width: int) -> np.ndarray:
    """Reinterpret a delivered uint8 wire buffer as the ``(plen, F)``
    bf16 dense KV matrix (zero-copy view)."""
    return np.asarray(buf, np.uint8).view(BF16).reshape(plen, width)


@dataclasses.dataclass(eq=False)
class PrefixEntry:
    """One registered prefix: its tokens, the prefilling replica's dense
    KV rows, and the paged blocks each replica materialized on receipt."""

    tokens: np.ndarray  # (plen,) int32
    page: int
    dense: np.ndarray  # (plen, F) bf16 — source-replica KV rows
    paged: np.ndarray  # (npages, page, F) bf16 — source paged layout
    replica_paged: dict[int, np.ndarray] = dataclasses.field(default_factory=dict)
    broadcast: dict | None = None  # the plan_broadcast record (see serve)
    version: int = 0  # weights version the KV was prefilled under
    last_used: int = 0  # cache-recency tick (LRU bookkeeping)

    @property
    def plen(self) -> int:
        return int(self.tokens.size)

    @property
    def nbytes(self) -> int:
        """HBM the cached prefix pins: source dense + paged layouts plus
        every replica's paged copy."""
        return (
            int(self.dense.nbytes)
            + int(self.paged.nbytes)
            + sum(int(p.nbytes) for p in self.replica_paged.values())
        )


class PrefixCache:
    """Longest-prefix lookup over registered prompt prefixes, with a
    byte-capacity bound (LRU eviction) and version-tagged invalidation.

    * ``capacity_bytes=None`` (default) is unbounded — the pre-eviction
      behaviour. With a bound, :meth:`add` evicts least-recently-used
      entries (lookup hits refresh recency) until the cache fits; a
      single entry larger than the bound is itself rejected.
    * Entries are stamped with ``weights_version`` at :meth:`add`; a
      weight refresh (`serve.Server.broadcast_weights` with new params)
      calls :meth:`on_weights_update`, which bumps the version and drops
      every stale entry — cached KV prefilled under old weights would
      silently decode garbage.
    """

    def __init__(self, capacity_bytes: int | None = None) -> None:
        if capacity_bytes is not None and capacity_bytes <= 0:
            raise ValueError(f"capacity_bytes must be positive, got {capacity_bytes}")
        self.capacity_bytes = capacity_bytes
        self.entries: list[PrefixEntry] = []
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.invalidations = 0
        self.weights_version = 0
        self._tick = 0

    @property
    def total_bytes(self) -> int:
        return sum(e.nbytes for e in self.entries)

    def _touch(self, entry: PrefixEntry) -> None:
        self._tick += 1
        entry.last_used = self._tick

    def add(self, entry: PrefixEntry) -> None:
        entry.version = self.weights_version
        self._touch(entry)
        self.entries.append(entry)
        if self.capacity_bytes is not None:
            while self.total_bytes > self.capacity_bytes and self.entries:
                lru = min(self.entries, key=lambda e: e.last_used)
                self.entries.remove(lru)
                self.evictions += 1

    def lookup(self, prompt: np.ndarray) -> PrefixEntry | None:
        """Longest registered prefix that ``prompt`` starts with (counted
        as a hit/miss for the serving stats). A hit refreshes the
        entry's LRU recency."""
        prompt = np.asarray(prompt)
        best = None
        for e in self.entries:
            if e.version != self.weights_version:
                continue  # stale KV: never serve across a weight refresh
            if e.plen <= prompt.size and np.array_equal(prompt[: e.plen], e.tokens):
                if best is None or e.plen > best.plen:
                    best = e
        if best is None:
            self.misses += 1
        else:
            self.hits += 1
            self._touch(best)
        return best

    def on_weights_update(self) -> int:
        """New weights arrived: bump the version and invalidate every
        entry prefilled under an older one. Returns the count dropped."""
        self.weights_version += 1
        stale = [e for e in self.entries if e.version != self.weights_version]
        if stale:
            self.entries = [
                e for e in self.entries if e.version == self.weights_version
            ]
            self.invalidations += len(stale)
        return len(stale)

    @property
    def hit_rate(self) -> float:
        n = self.hits + self.misses
        return self.hits / n if n else 0.0
