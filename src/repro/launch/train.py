"""End-to-end fault-tolerant training driver.

Composes every substrate layer: data pipeline (Markov source), model
(any assigned arch), AdamW + ZeRO-1 specs, mesh + shardings, Torrent or
XLA collectives, async checkpointing with restart-on-failure, straggler
monitoring, and optional elastic rescale between runs.

CLI (see examples/train_lm.py for the library-level API):

    PYTHONPATH=src python -m repro.launch.train \
        --arch yi-6b --smoke --steps 200 --batch 8 --seq 128 \
        --collectives torrent --ckpt-dir /tmp/run0
"""

from __future__ import annotations

import argparse
import dataclasses
import logging
import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import configs as C
from repro.checkpoint.manager import CheckpointManager
from repro.data.pipeline import MarkovSource, Prefetcher
from repro.launch.mesh import make_host_mesh
from repro.launch.steps import _named, _sanitize, make_train_step
from repro.models import transformer as T
from repro.optim import adamw
from repro.parallel import ef_residual_init, ef_residual_specs
from repro.parallel import sharding as shd
from repro.runtime.failure import FaultInjector, resilient_loop
from repro.runtime.monitor import StepMonitor

log = logging.getLogger("repro.train")


@dataclasses.dataclass
class TrainConfig:
    arch: str = "yi-6b"
    smoke: bool = True
    steps: int = 100
    global_batch: int = 8
    seq_len: int = 128
    peak_lr: float = 1e-3
    warmup_steps: int = 20
    collectives: str = "xla"  # "xla" | "torrent"
    compress_grads: bool = False
    bucket_bytes: int | None = None  # bucketed backward-overlapped reduce
    topology: str | None = None  # tiered link-graph spec (torrent auto-K)
    remat: str = "dots"
    loss_chunks: int = 4
    microbatches: int = 1  # gradient accumulation (HBM-fit lever)
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_every: int = 50
    keep_last_k: int = 3
    tp: int = 1
    seed: int = 0
    log_every: int = 10
    fail_at: tuple[int, ...] = ()  # fault-injection (tests/demos)


class Trainer:
    """Owns mesh, sharded state, step function and the resilient loop."""

    def __init__(self, tc: TrainConfig):
        self.tc = tc
        self.cfg = (
            C.get_smoke_config(tc.arch) if tc.smoke else C.get_config(tc.arch)
        )
        self.mesh = make_host_mesh(model=tc.tp)
        self.opt_cfg = adamw.OptConfig(
            peak_lr=tc.peak_lr,
            warmup_steps=tc.warmup_steps,
            decay_steps=max(tc.steps, tc.warmup_steps + 1),
        )
        self.source = MarkovSource(
            vocab=self.cfg.vocab_size,
            seq_len=tc.seq_len,
            global_batch=tc.global_batch,
            seed=tc.seed + 1,
        )
        self.monitor = StepMonitor()
        self._build()

    # -- state / step ----------------------------------------------------
    def _build(self):
        tc, cfg, mesh = self.tc, self.cfg, self.mesh
        params_shape = jax.eval_shape(
            lambda: T.model_init(jax.random.PRNGKey(tc.seed), cfg)
        )
        pspecs = shd.param_pspecs(params_shape, cfg, tp=mesh.shape["model"])
        ospecs = shd.opt_pspecs(pspecs, params_shape, mesh.shape["data"])
        self.param_sh = _named(mesh, pspecs)
        self.opt_sh = _named(mesh, ospecs)
        self.batch_spec = P("data", None)
        self.batch_sh = NamedSharding(mesh, _sanitize(self.batch_spec, mesh))

        with jax.set_mesh(mesh):
            params = jax.jit(
                lambda: T.model_init(jax.random.PRNGKey(tc.seed), cfg),
                out_shardings=self.param_sh,
            )()
            opt = jax.jit(
                lambda: adamw.init(params), out_shardings=self.opt_sh
            )()
        self.state = {"params": params, "opt": opt}
        if tc.compress_grads:
            # Error-feedback residual rides in the training state so it
            # survives checkpoint/restart like the optimizer moments do.
            dp_size = int(np.prod(
                [mesh.shape[a] for a in mesh.axis_names if a != "model"]
            ))
            self.ef_sh = _named(mesh, ef_residual_specs(mesh, params_shape))
            with jax.set_mesh(mesh):
                self.state["ef"] = jax.jit(
                    lambda: ef_residual_init(params_shape, dp_size),
                    out_shardings=self.ef_sh,
                )()

        bspecs = {"tokens": self.batch_spec, "labels": self.batch_spec}
        step = make_train_step(
            cfg,
            self.opt_cfg,
            remat=tc.remat,
            collectives=tc.collectives,
            compress_grads=tc.compress_grads,
            error_feedback=tc.compress_grads,
            bucket_bytes=tc.bucket_bytes,
            topology=tc.topology,
            mesh=mesh,
            batch_specs={
                k: _sanitize(v, mesh) for k, v in bspecs.items()
            },
            loss_chunks=tc.loss_chunks,
            microbatches=tc.microbatches,
        )
        batch_sh = {"tokens": self.batch_sh, "labels": self.batch_sh}
        if tc.compress_grads:
            self.step_fn = jax.jit(
                step,
                in_shardings=(
                    self.param_sh, self.opt_sh, self.ef_sh, batch_sh
                ),
                out_shardings=(
                    self.param_sh, self.opt_sh, self.ef_sh, None
                ),
                donate_argnums=(0, 1, 2),
            )
        else:
            self.step_fn = jax.jit(
                step,
                in_shardings=(self.param_sh, self.opt_sh, batch_sh),
                out_shardings=(self.param_sh, self.opt_sh, None),
                donate_argnums=(0, 1),
            )

    def _device_batch(self, step: int) -> dict:
        host = self.source.batch(step)
        return {
            k: jax.device_put(v, self.batch_sh) for k, v in host.items()
        }

    # -- driver ------------------------------------------------------------
    def run(self) -> dict[str, Any]:
        tc = self.tc
        ckpt = CheckpointManager(tc.ckpt_dir, keep_last_k=tc.keep_last_k)
        injector = FaultInjector(tc.fail_at)
        losses: list[float] = []

        def one_step(state, i):
            injector.maybe_fail(i)
            self.monitor.start_step()
            batch = self._device_batch(i)
            with jax.set_mesh(self.mesh):
                if "ef" in state:
                    params, opt, ef, metrics = self.step_fn(
                        state["params"], state["opt"], state["ef"], batch
                    )
                else:
                    params, opt, metrics = self.step_fn(
                        state["params"], state["opt"], batch
                    )
                    ef = None
            loss = float(metrics["loss"])
            ev = self.monitor.end_step(i)
            if ev is not None:
                log.warning(
                    "straggler step %d: %.3fs (median %.3fs)",
                    ev.step, ev.duration_s, ev.median_s,
                )
            if i % tc.log_every == 0:
                log.info("step %5d loss %.4f lr %.2e", i, loss,
                         float(metrics["lr"]))
            losses.append(loss)
            new_state = {"params": params, "opt": opt}
            if ef is not None:
                new_state["ef"] = ef
            return new_state, {"loss": loss}

        t0 = time.time()
        state, result = resilient_loop(
            state=self.state,
            step_fn=one_step,
            num_steps=tc.steps,
            ckpt=ckpt,
            ckpt_every=tc.ckpt_every,
        )
        wall = time.time() - t0
        ckpt.close()
        self.state = state
        return {
            "final_step": result.final_step,
            "restarts": result.restarts,
            "losses": losses,
            "first_loss": losses[0] if losses else None,
            "last_loss": losses[-1] if losses else None,
            "wall_s": wall,
            "straggler_events": len(self.monitor.events),
            "tokens_per_s": (
                tc.steps * tc.global_batch * tc.seq_len / wall if wall else 0
            ),
        }


def main(argv=None) -> dict:
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="yi-6b", choices=C.ARCHS)
    p.add_argument("--smoke", action="store_true", default=False)
    p.add_argument("--full", dest="smoke", action="store_false")
    p.add_argument("--steps", type=int, default=100)
    p.add_argument("--batch", type=int, default=8)
    p.add_argument("--seq", type=int, default=128)
    p.add_argument("--lr", type=float, default=1e-3)
    p.add_argument("--collectives", choices=("xla", "torrent"), default="xla")
    p.add_argument("--compress-grads", action="store_true", default=False,
                   help="int8 wire for the DP gradient all-reduce with "
                        "error-feedback residuals (requires --collectives "
                        "torrent)")
    p.add_argument("--bucket-mb", type=float, default=None,
                   help="bucket size (MiB) for the bucketed, backward-"
                        "overlapped DP grad reduce (requires --collectives "
                        "torrent)")
    p.add_argument("--topology", default=None,
                   help="tiered link-graph spec for auto-K ring planning, "
                        "e.g. 'pods=2:interpod_bw=0.25' (requires "
                        "--collectives torrent)")
    p.add_argument("--tp", type=int, default=1)
    p.add_argument("--remat", default="dots")
    p.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    p.add_argument("--ckpt-every", type=int, default=50)
    p.add_argument("--fail-at", default="",
                   help="comma-separated steps for fault injection demo")
    args = p.parse_args(argv)

    logging.basicConfig(level=logging.INFO, format="%(message)s")
    tc = TrainConfig(
        arch=args.arch, smoke=args.smoke, steps=args.steps,
        global_batch=args.batch, seq_len=args.seq, peak_lr=args.lr,
        collectives=args.collectives, compress_grads=args.compress_grads,
        bucket_bytes=(
            int(args.bucket_mb * (1 << 20)) if args.bucket_mb else None
        ),
        topology=args.topology,
        tp=args.tp, remat=args.remat,
        ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
        fail_at=tuple(int(s) for s in args.fail_at.split(",") if s),
    )
    out = Trainer(tc).run()
    log.info(
        "done: %d steps (%d restarts)  loss %.4f -> %.4f  %.1f tok/s",
        out["final_step"], out["restarts"], out["first_loss"],
        out["last_loss"], out["tokens_per_s"],
    )
    return out


if __name__ == "__main__":
    main()
