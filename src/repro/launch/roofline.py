"""Roofline-term extraction from compiled dry-run artifacts.

Three terms per (arch × shape × mesh), in seconds (TPU v5e constants):

    compute    = HLO_FLOPs_per_device   / peak_flops_per_chip
    memory     = HLO_bytes_per_device   / hbm_bw_per_chip
    collective = collective_bytes_per_device / ici_link_bw

``cost_analysis()`` reports the *per-device* SPMD module, so dividing
by per-chip rates directly gives per-device seconds (algebraically
identical to global/(chips×rate)). Collective bytes are not in
cost_analysis — we parse the HLO text and sum operand bytes of every
all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute (async ``-start`` counted, ``-done`` skipped).
"""

from __future__ import annotations

import dataclasses
import re

# --- TPU v5e hardware constants (per chip) ---------------------------------
PEAK_FLOPS = 197e12  # bf16
HBM_BW = 819e9  # bytes/s
ICI_BW = 50e9  # bytes/s/link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"=\s*(.*?)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(-start|-done)?\("
)
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_BRACES_RE = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")


def _shape_bytes(dtype: str, dims: str) -> int:
    if dtype not in _DTYPE_BYTES:
        return 0  # token/opaque types
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def _group_size(line: str) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return max(1, int(m.group(2)))
    m = _GROUPS_BRACES_RE.search(line)
    if m:
        return max(1, len(m.group(1).split(",")))
    return 1


def collective_bytes(hlo_text: str) -> dict[str, int]:
    """Per-collective-kind *operand* bytes summed over the module.

    Post-optimization HLO prints operands untyped, so operand bytes are
    derived from the (typed) result shape: all-reduce / all-to-all /
    collective-permute results equal their operands; all-gather operand
    = result / group_size; reduce-scatter operand = result × group_size.
    """
    out = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        if m.group(3) == "-done":
            continue  # async completion — counted at -start
        op = m.group(2)
        result_bytes = sum(
            _shape_bytes(d, dims) for d, dims in _SHAPE_RE.findall(m.group(1))
        )
        if op == "all-gather":
            result_bytes //= _group_size(line)
        elif op == "reduce-scatter":
            result_bytes *= _group_size(line)
        out[op] += result_bytes
    return out


@dataclasses.dataclass
class Roofline:
    flops: float  # per-device (trip-count corrected)
    hbm_bytes: float  # per-device, upper bound (fusion-boundary model)
    coll_bytes: float  # per-device (wire)
    coll_breakdown: dict[str, int]
    xla_raw_flops: float = 0.0  # cost_analysis() (while bodies ×1)
    xla_raw_bytes: float = 0.0
    # lower bound: each buffer written once, elementwise fully fused —
    # the TPU-optimistic end of the memory-term bracket.
    hbm_bytes_lb: float = 0.0

    @property
    def compute_s(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def memory_s(self) -> float:
        return self.hbm_bytes / HBM_BW

    @property
    def memory_lb_s(self) -> float:
        return self.hbm_bytes_lb / HBM_BW

    @property
    def collective_s(self) -> float:
        return self.coll_bytes / ICI_BW

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    def as_dict(self) -> dict:
        return {
            "flops_per_device": self.flops,
            "hbm_bytes_per_device": self.hbm_bytes,
            "collective_bytes_per_device": self.coll_bytes,
            "collective_breakdown": self.coll_breakdown,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "memory_lb_s": self.memory_lb_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "xla_raw_flops": self.xla_raw_flops,
            "xla_raw_bytes": self.xla_raw_bytes,
        }


def extract(compiled) -> Roofline:
    """Roofline terms from the compiled module.

    Primary source is our trip-count-aware HLO cost model
    (:mod:`.hlo_cost`): XLA's ``cost_analysis()`` visits each ``while``
    body once, undercounting a scanned N-layer model by ~N×.  The raw
    XLA numbers are kept alongside for cross-checking.
    """
    from . import hlo_cost

    ca = compiled.cost_analysis()
    if isinstance(ca, list):  # some backends return [dict]
        ca = ca[0]
    xla_flops = float(ca.get("flops", 0.0))
    xla_bytes = float(ca.get("bytes accessed", 0.0))
    cost = hlo_cost.analyze(compiled.as_text())
    return Roofline(
        flops=cost.flops,
        hbm_bytes=cost.bytes,
        coll_bytes=cost.coll_bytes,
        coll_breakdown={k: int(v) for k, v in cost.coll.items()},
        xla_raw_flops=xla_flops,
        xla_raw_bytes=xla_bytes,
        hbm_bytes_lb=cost.bytes_lb,
    )


def model_flops(n_params_active: int, tokens: int, kind: str) -> float:
    """6·N·D training / 2·N·D inference forward (per step, global)."""
    mult = 6.0 if kind == "train" else 2.0
    return mult * n_params_active * tokens


# -- backward-segment compute availability (the overlap model's input) ------


def backward_flops(n_params: int, tokens: int) -> float:
    """Backward-pass FLOPs attributable to ``n_params`` parameters:
    4·N·D of the 6·N·D training total (2·N·D activation grads + 2·N·D
    weight grads; the forward 2·N·D happens before any gradient
    exists, so only the backward share gates bucket readiness)."""
    return 4.0 * n_params * tokens


def noc_cycles(seconds: float, link_bw: int = 64) -> int:
    """Seconds -> NoC cycles at the modeled clock. The simulator's
    cycle moves ``link_bw`` bytes per link (``SimParams.link_bw``) and
    the roofline's link moves ``ICI_BW`` bytes/s, so one cycle is
    ``link_bw / ICI_BW`` seconds — the bridge that lets roofline
    compute estimates and ``program_latency`` share one time base."""
    return int(round(seconds * ICI_BW / link_bw))


def bucket_ready_cc(
    bucket_params: "list[int]",
    tokens: int,
    *,
    peak_flops: float = PEAK_FLOPS,
    link_bw: int = 64,
) -> list[int]:
    """Per-bucket compute availability times, in NoC cycles, for
    ``core.simulator.overlap_timeline`` / ``choose_num_chains(buckets=)``.

    ``bucket_params[i]`` is the parameter count of bucket i in dispatch
    (reverse-topological) order. Backward produces the LAST parameters'
    gradients first, so bucket i is ready once the backward segments of
    buckets 0..i have run: ready[i] = cumulative
    ``backward_flops(segment) / peak_flops`` — nondecreasing by
    construction, as ``overlap_timeline`` requires. Per-device tokens
    should be passed when the comm latencies are per-device too."""
    out: list[int] = []
    acc = 0.0
    for n in bucket_params:
        acc += backward_flops(int(n), tokens) / peak_flops
        out.append(noc_cycles(acc, link_bw))
    return out


def modeled_train_overlap(
    leaves,
    axis_size: int,
    tokens: int,
    *,
    bucket_bytes: int,
    num_chains="auto",
    algo: str = "rs_ag",
    wire_dtype: "str | None" = None,
    scheduler: str = "tsp",
    max_chains: int = 4,
    topology: "str | None" = None,
    src_read_bw: "int | None" = None,
) -> dict:
    """End-to-end modeled step timeline of the bucketed,
    backward-overlapped DP gradient reduction — the composition of
    bucket assembly (``parallel.collectives.assign_buckets``), the
    backward-segment compute availability estimates
    (:func:`bucket_ready_cc`) and the chain all-reduce cost model
    (``core.simulator.all_reduce_latency``), fed through
    ``core.simulator.overlap_timeline``.

    ``leaves`` are the gradient leaves (arrays or ShapeDtypeStructs, in
    tree-flatten order); ``axis_size`` the DP ring size; ``tokens`` the
    per-device tokens per step (comm latencies are per-device too).
    Each bucket resolves its OWN (K, rings) from its bytes — the same
    ``resolve_ring_chains`` the executor uses — and is priced at its
    chunk-aligned padded payload (``bucket_shard_layout``), so the
    modeled wire bytes match the HLO parse of the bucketed step
    EXACTLY (asserted in benchmarks/bench_train.py).

    ``topology`` (a ``parse_topology_spec`` string) makes the auto-K
    ring planning and per-bucket latency pricing tier-aware; wire
    bytes are topology-independent so the exact HLO byte match is
    unaffected. ``src_read_bw`` caps the modeled source HBM read
    bandwidth (``SimParams.src_read_bw``); None = link-bw-limited.

    Returns ``{"buckets": [...], "timeline": overlap_timeline(...),
    "total_wire_bytes", "serial_cc", "overlap_cc", "efficiency"}``.
    """
    import math as _math

    import numpy as _np

    from repro.core import program as _prg
    from repro.core import simulator as _sim
    from repro.core.topology import MeshTopology as _Topo
    from repro.parallel import collectives as _col

    buckets = _col.assign_buckets(leaves, bucket_bytes)
    topo = (
        _col._ring_topology(axis_size, topology)
        if topology is not None
        else _Topo(axis_size, 1)
    )
    params = (
        _sim.SimParams(src_read_bw=src_read_bw)
        if src_read_bw is not None
        else _sim.DEFAULT_PARAMS
    )
    ready = bucket_ready_cc(
        [
            sum(_math.prod(leaves[i].shape) for i in b.indices)
            for b in buckets
        ],
        tokens,
    )
    recs, comms = [], []
    for b, r in zip(buckets, ready):
        k, rings = _col.resolve_ring_chains(
            axis_size, b.num_bytes, num_chains=num_chains,
            scheduler=scheduler, algo=algo, wire_dtype=wire_dtype,
            max_chains=max_chains, topology=topo,
        )
        shards = _col.all_reduce_shards(axis_size, k, algo)
        sizes = [_math.prod(leaves[i].shape) for i in b.indices]
        _, total_elems = _col.bucket_shard_layout(sizes, shards)
        padded_bytes = total_elems * _np.dtype(b.dtype).itemsize
        program = _prg.plan_all_reduce(
            axis_size, rings, algo, wire_dtype=wire_dtype
        )
        comm = _sim.program_latency(topo, 0, program, padded_bytes, params)
        wire = program.wire_bytes(padded_bytes)
        comms.append(int(comm))
        recs.append({
            "leaves": len(b.indices), "dtype": b.dtype,
            "bytes": b.num_bytes, "padded_bytes": int(padded_bytes),
            "num_chains": k, "shards": shards, "ready_cc": int(r),
            "comm_cc": int(comm), "wire_bytes": int(wire),
        })
    tl = _sim.overlap_timeline(ready, comms)
    return {
        "buckets": recs,
        "timeline": tl,
        "total_wire_bytes": sum(r["wire_bytes"] for r in recs),
        "serial_cc": tl["serial_cc"],
        "overlap_cc": tl["overlap_cc"],
        "efficiency": tl["efficiency"],
    }
