"""DeepSeek-MoE-16B — 2 shared + 64 routed top-6, fine-grained experts
[arXiv:2401.06066; hf]. kv=16 = num_heads (MHA). First layer dense."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-moe-16b", family="moe",
    num_layers=28, d_model=2048, num_heads=16, num_kv_heads=16,
    d_ff=10944, vocab_size=102400, head_dim=128,
    num_experts=64, num_shared_experts=2, moe_top_k=6, moe_d_ff=1408,
    first_layer_dense=True, rope_theta=1e4,
)

SMOKE_CONFIG = ModelConfig(
    name="deepseek-moe-16b-smoke", family="moe",
    num_layers=3, d_model=64, num_heads=4, num_kv_heads=4,
    d_ff=128, vocab_size=256, head_dim=16,
    num_experts=8, num_shared_experts=2, moe_top_k=2, moe_d_ff=32,
    first_layer_dense=True,
)
