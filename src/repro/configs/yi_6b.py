"""Yi-6B — llama-architecture dense GQA [arXiv:2403.04652; hf]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="yi-6b", family="dense",
    num_layers=32, d_model=4096, num_heads=32, num_kv_heads=4,
    d_ff=11008, vocab_size=64000, head_dim=128,
    rope_theta=5e6,
)

SMOKE_CONFIG = ModelConfig(
    name="yi-6b-smoke", family="dense",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
    d_ff=128, vocab_size=256, head_dim=16, rope_theta=5e6,
)
