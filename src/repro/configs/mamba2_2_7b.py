"""Mamba2-2.7B — attention-free SSD (state-space duality)
[arXiv:2405.21060; unverified]. d_inner = 2*2560 = 5120, 80 heads of 64,
state 128."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-2.7b", family="ssm",
    num_layers=64, d_model=2560, num_heads=0, num_kv_heads=0,
    d_ff=0, vocab_size=50280,
    ssm_state=128, ssm_headdim=64, ssm_expand=2, ssm_ngroups=1,
    ssm_conv=4, ssm_chunk=128,
)

SMOKE_CONFIG = ModelConfig(
    name="mamba2-2.7b-smoke", family="ssm",
    num_layers=2, d_model=64, num_heads=0, num_kv_heads=0,
    d_ff=0, vocab_size=256,
    ssm_state=8, ssm_headdim=8, ssm_expand=2, ssm_ngroups=1,
    ssm_conv=4, ssm_chunk=8,
)
