"""Assigned input shapes × applicability, and ShapeDtypeStruct specs.

Four shapes per architecture (40 cells):
  train_4k     seq 4096  × global_batch 256   -> train_step
  prefill_32k  seq 32768 × global_batch 32    -> prefill_step
  decode_32k   KV 32768  × global_batch 128   -> serve_step (1 new token)
  long_500k    KV 524288 × global_batch 1     -> serve_step (1 new token)

``long_500k`` requires a sub-quadratic *cache working set*: it runs for
SSM (mamba2: O(1) state), hybrid (jamba) and SWA (h2o-danube: ring
buffer = window) archs, and is skipped for pure full-attention archs
(see DESIGN.md §Shape skips).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class Shape:
    name: str
    kind: str  # 'train' | 'prefill' | 'decode'
    seq_len: int
    global_batch: int


SHAPES: dict[str, Shape] = {
    "train_4k": Shape("train_4k", "train", 4096, 256),
    "prefill_32k": Shape("prefill_32k", "prefill", 32768, 32),
    "decode_32k": Shape("decode_32k", "decode", 32768, 128),
    "long_500k": Shape("long_500k", "decode", 524288, 1),
}

# archs allowed to run long_500k (sub-quadratic cache working set)
LONG_CONTEXT_ARCHS = {"mamba2-2.7b", "jamba-v0.1-52b", "h2o-danube-1.8b"}


def applicable(arch: str, shape: str) -> tuple[bool, str]:
    """(runs?, reason-if-skipped)."""
    if shape == "long_500k" and arch not in LONG_CONTEXT_ARCHS:
        return False, "pure full-attention arch: 512k dense-KV decode skipped"
    return True, ""


def _f(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg: ModelConfig, shape: Shape) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of this cell
    (weak-type-correct, shardable, no device allocation)."""
    B, S = shape.global_batch, shape.seq_len
    i32, bf16 = jnp.int32, jnp.bfloat16
    if shape.kind == "train":
        batch: dict = {}
        if cfg.family == "vlm":  # stub frontend: precomputed embeddings
            batch["embeds"] = _f((B, S, cfg.d_model), bf16)
            batch["positions"] = _f((3, B, S), i32)
        else:
            batch["tokens"] = _f((B, S), i32)
        if cfg.is_encdec:  # stub conv frontend: precomputed frames
            batch["enc_frames"] = _f((B, cfg.encoder_seq_len, cfg.d_model), bf16)
        batch["labels"] = _f((B, S), i32)
        return {"batch": batch}
    if shape.kind == "prefill":
        batch = {}
        if cfg.family == "vlm":
            batch["embeds"] = _f((B, S, cfg.d_model), bf16)
            batch["positions"] = _f((3, B, S), i32)
        else:
            batch["tokens"] = _f((B, S), i32)
        if cfg.is_encdec:
            batch["enc_frames"] = _f((B, cfg.encoder_seq_len, cfg.d_model), bf16)
        return {"batch": batch, "max_seq": S}
    # decode: one new token against a seq_len-deep cache
    from repro.models import transformer as T

    cache = jax.eval_shape(lambda: T.init_cache(cfg, B, S))
    return {
        "tokens": _f((B,), i32),
        "pos": jax.ShapeDtypeStruct((), i32),
        "cache": cache,
    }
