"""DeepSeek-V2-Lite (16B) — MLA (kv_lora 512) + fine-grained MoE
[arXiv:2405.04434; hf].

Assignment config line: "MoE 64e top-6" (d_ff 1408); the descriptive
note mentions 160 routed experts — we follow the config line
(64 routed + 2 shared, top-6), recorded in DESIGN.md.
First layer uses a dense FFN (width 10944), as in the release.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b", family="moe",
    num_layers=27, d_model=2048, num_heads=16, num_kv_heads=16,
    d_ff=10944, vocab_size=102400,
    attention="mla", kv_lora_rank=512,
    qk_nope_head_dim=128, qk_rope_head_dim=64, v_head_dim=128,
    num_experts=64, num_shared_experts=2, moe_top_k=6, moe_d_ff=1408,
    first_layer_dense=True, rope_theta=1e4,
)

SMOKE_CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b-smoke", family="moe",
    num_layers=3, d_model=64, num_heads=4, num_kv_heads=4,
    d_ff=128, vocab_size=256,
    attention="mla", kv_lora_rank=32,
    qk_nope_head_dim=16, qk_rope_head_dim=8, v_head_dim=16,
    num_experts=8, num_shared_experts=2, moe_top_k=2, moe_d_ff=32,
    first_layer_dense=True,
)
