"""Assigned architecture configs (+ reduced smoke configs).

``get_config(arch_id)`` / ``get_smoke_config(arch_id)`` resolve by the
public architecture id (e.g. ``"llama3-8b"``).
"""

from __future__ import annotations

import importlib

from repro.models.config import ModelConfig

from .shapes import LONG_CONTEXT_ARCHS, SHAPES, Shape, applicable, input_specs

_MODULES: dict[str, str] = {
    "starcoder2-3b": "starcoder2_3b",
    "yi-6b": "yi_6b",
    "h2o-danube-1.8b": "h2o_danube_1_8b",
    "llama3-8b": "llama3_8b",
    "deepseek-v2-lite-16b": "deepseek_v2_lite_16b",
    "deepseek-moe-16b": "deepseek_moe_16b",
    "jamba-v0.1-52b": "jamba_v0_1_52b",
    "qwen2-vl-7b": "qwen2_vl_7b",
    "mamba2-2.7b": "mamba2_2_7b",
    "whisper-tiny": "whisper_tiny",
}

ARCHS: tuple[str, ...] = tuple(_MODULES)


def _load(arch: str):
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; have {sorted(_MODULES)}")
    return importlib.import_module(f"repro.configs.{_MODULES[arch]}")


def get_config(arch: str) -> ModelConfig:
    return _load(arch).CONFIG


def get_smoke_config(arch: str) -> ModelConfig:
    return _load(arch).SMOKE_CONFIG


__all__ = [
    "ARCHS",
    "LONG_CONTEXT_ARCHS",
    "SHAPES",
    "Shape",
    "applicable",
    "get_config",
    "get_smoke_config",
    "input_specs",
]
