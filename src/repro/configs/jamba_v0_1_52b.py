"""Jamba-v0.1 (52B) — Mamba+attention 1:7 interleave, MoE 16e top-2
every other layer [arXiv:2403.19887; hf]. No positional encoding
(the Mamba layers carry position)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="jamba-v0.1-52b", family="hybrid",
    num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8,
    d_ff=14336, vocab_size=65536, head_dim=128,
    num_experts=16, moe_top_k=2, moe_d_ff=14336, moe_layer_stride=2,
    attn_period=8, attn_offset=4, pos_scheme="none",
    ssm_state=16, ssm_headdim=64, ssm_expand=2, ssm_ngroups=8,
    ssm_conv=4, ssm_chunk=128,
)

SMOKE_CONFIG = ModelConfig(
    name="jamba-v0.1-52b-smoke", family="hybrid",
    num_layers=8, d_model=64, num_heads=4, num_kv_heads=2,
    d_ff=128, vocab_size=256, head_dim=16,
    num_experts=4, moe_top_k=2, moe_d_ff=64, moe_layer_stride=2,
    attn_period=8, attn_offset=4, pos_scheme="none",
    ssm_state=8, ssm_headdim=8, ssm_expand=2, ssm_ngroups=2,
    ssm_conv=4, ssm_chunk=8,
)
