"""Qwen2-VL-7B — M-RoPE, dynamic-resolution ViT frontend (STUB: the
backbone consumes precomputed patch/token embeddings)
[arXiv:2409.12191; hf]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-7b", family="vlm",
    num_layers=28, d_model=3584, num_heads=28, num_kv_heads=4,
    d_ff=18944, vocab_size=152064, head_dim=128,
    rope_theta=1e6, pos_scheme="mrope", mrope_sections=(16, 24, 24),
    qkv_bias=True,
)

SMOKE_CONFIG = ModelConfig(
    name="qwen2-vl-7b-smoke", family="vlm",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
    d_ff=128, vocab_size=256, head_dim=16,
    pos_scheme="mrope", mrope_sections=(2, 3, 3), qkv_bias=True,
)
