"""Whisper-tiny — encoder-decoder, conv frontend STUB (the encoder
consumes precomputed frame embeddings (B, 1500, 384))
[arXiv:2212.04356; unverified]. GeLU FFN, learned positions."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny", family="audio",
    num_layers=4, d_model=384, num_heads=6, num_kv_heads=6,
    d_ff=1536, vocab_size=51865, head_dim=64,
    encoder_layers=4, encoder_seq_len=1500,
    pos_scheme="learned", max_position_embeddings=32768,
    ffn_activation="gelu",
)

SMOKE_CONFIG = ModelConfig(
    name="whisper-tiny-smoke", family="audio",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
    d_ff=128, vocab_size=256, head_dim=16,
    encoder_layers=2, encoder_seq_len=24,
    pos_scheme="learned", max_position_embeddings=32768,
    ffn_activation="gelu",
)
