"""StarCoder2-3B — dense GQA + RoPE [arXiv:2402.19173; hf]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-3b", family="dense",
    num_layers=30, d_model=3072, num_heads=24, num_kv_heads=2,
    d_ff=12288, vocab_size=49152, head_dim=128,
    rope_theta=1e5,
)

SMOKE_CONFIG = ModelConfig(
    name="starcoder2-3b-smoke", family="dense",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
    d_ff=128, vocab_size=256, head_dim=16, rope_theta=1e5,
)
