"""H2O-Danube-1.8B — llama+mistral mix with sliding-window attention
[arXiv:2401.16818; hf]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="h2o-danube-1.8b", family="dense",
    num_layers=24, d_model=2560, num_heads=32, num_kv_heads=8,
    d_ff=6912, vocab_size=32000, head_dim=80,
    rope_theta=1e4, sliding_window=4096,
)

SMOKE_CONFIG = ModelConfig(
    name="h2o-danube-1.8b-smoke", family="dense",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
    d_ff=128, vocab_size=256, head_dim=16, sliding_window=8,
)
