"""Torrent distributed-DMA reproduction (jax).

Importing any ``repro`` module first installs the jax compatibility
shims (see :mod:`repro._jax_compat`) so the codebase's current-jax API
surface works on the older jax baked into the offline container.
"""

from . import _jax_compat

_jax_compat.install()
