"""Chainwrite collectives — the paper's P2MP mechanism on TPU ICI.

The paper moves data replication out of the NoC routers and into the DMA
endpoints: data traverses a *scheduled chain* of destinations, each hop
an ordinary P2P transfer. On TPU the only true P2P primitive is
``jax.lax.ppermute`` (collective-permute), so Chainwrite maps to chains
of ppermutes inside ``shard_map``:

* :func:`chain_broadcast` — P2MP multicast of a payload held by the
  chain head to an arbitrary *subset* of devices on an axis. Supports
  frame pipelining (``num_frames``): the payload is sliced into frames
  that stream through the chain (store-and-forward), so chain latency
  is (F + L - 2) frame-times rather than F·L — exactly the paper's
  §III-C stream duplicator behaviour.
* :func:`multi_chain_broadcast` — the multi-chain extension: K
  link-disjoint sub-chains (from ``scheduling.partition_schedule``)
  stream the same payload concurrently from one head. All chains live
  in one SPMD program; intra-chain hops across different chains fuse
  into a single ``ppermute`` per step (their sources/targets are
  disjoint), while the head's K same-step fan-out sends are emitted as
  K tiny ppermutes (XLA requires unique sources per permute). Supports
  the same per-chain frame pipelining as :func:`chain_broadcast`.
* :func:`chain_all_gather` / :func:`chain_reduce_scatter` /
  :func:`chain_all_reduce` — ring collectives over an explicitly
  *scheduled* ring order (from ``core.scheduling``), replacing XLA's
  built-in all-gather/all-reduce ("network-layer multicast" analogue).
* :func:`multi_chain_all_reduce` — all-reduce over K disjoint
  equal-size sub-rings; the generalization whose K=2 case is
  hierarchical (within-pod then cross-pod) all-reduce. Two schedules:
  ``algo="rs_ag"`` (default) runs a fused per-ring reduce-scatter,
  rotates the 1/S-payload *shards* across rings, then a fused per-ring
  all-gather — ≈ (2·(S-1)+(K-1))/S payloads of wire per device, the
  bandwidth-optimal family; ``algo="rotation"`` keeps the short
  (S+K-2)-step full-payload rotation schedule, latency-optimal for
  tiny payloads where per-step overhead dominates.
* :func:`chain_all_to_all` — MoE dispatch as a rotating chain.

All functions must be called inside ``shard_map`` with a manual axis.
``order`` is always a static tuple of device indices along the axis;
non-members of a partial chain participate in the SPMD program but
receive (and keep) zeros — the paper's "no change to the interconnect"
property: nothing outside the chain is touched.

Pure-jnp oracles for every collective live in :mod:`.chainwrite_ref`.
"""

from __future__ import annotations

import functools
from typing import Sequence

import jax
import jax.numpy as jnp
from jax import lax

from .chainwrite_ref import ALL_REDUCE_ALGOS  # canonical algo names

Axis = str | tuple[str, ...]

# When True, ring/chain scans are fully unrolled. The dry-run sets this
# so every ppermute appears as its own HLO op and the §Roofline
# collective-bytes parser counts true wire traffic (a rolled scan's
# body is counted once regardless of trip count).
_STATIC_UNROLL = False


def set_static_unroll(value: bool) -> None:
    global _STATIC_UNROLL
    _STATIC_UNROLL = bool(value)


def _scan(body, carry, xs):
    import numpy as _np

    length = int(xs.shape[0]) if hasattr(xs, "shape") else len(xs)
    return lax.scan(
        body, carry, xs, unroll=length if _STATIC_UNROLL else 1
    )


def _axis_index(axis_name: Axis) -> jax.Array:
    """Linearized index over one axis name or a tuple of axis names."""
    if isinstance(axis_name, (tuple, list)):
        idx = lax.axis_index(axis_name[0])
        for name in axis_name[1:]:
            idx = idx * lax.axis_size(name) + lax.axis_index(name)
        return idx
    return lax.axis_index(axis_name)


def chain_edges(order: Sequence[int], *, wrap: bool = False) -> list[tuple[int, int]]:
    """Directed ppermute pairs for a chain (optionally closed ring)."""
    edges = [(int(a), int(b)) for a, b in zip(order, order[1:])]
    if wrap and len(order) > 1:
        edges.append((int(order[-1]), int(order[0])))
    return edges


def _ppermute(x: jax.Array, axis_name: Axis, perm: list[tuple[int, int]]) -> jax.Array:
    return lax.ppermute(x, axis_name, perm)


# ---------------------------------------------------------------------------
# P2MP broadcast (the paper's core operation)
# ---------------------------------------------------------------------------


def chain_broadcast(
    x: jax.Array,
    axis_name: Axis,
    order: Sequence[int],
    *,
    num_frames: int = 1,
) -> jax.Array:
    """Multicast ``x`` from device ``order[0]`` to every device in
    ``order`` by store-and-forward chaining (paper §III-A/§III-C).

    ``x`` must be materialized on the chain head (other devices pass a
    same-shaped array whose value is ignored). Devices on the axis that
    are not in ``order`` return zeros. With ``num_frames > 1`` the
    payload's leading dimension is sliced into frames that pipeline
    through the chain — one scan step per frame-hop slot, F + L - 2
    steps total.
    """
    order = tuple(int(o) for o in order)
    if len(order) == 0:
        raise ValueError("empty chain")
    head = order[0]
    idx = _axis_index(axis_name)
    is_head = idx == head
    x = jnp.where(is_head, x, jnp.zeros_like(x))
    if len(order) == 1:
        return x
    edges = chain_edges(order, wrap=False)

    if num_frames <= 1:
        # Non-pipelined: the whole payload hops down the chain, one
        # sequential ppermute per edge; every member keeps a copy as the
        # payload passes through (store-and-forward of a single frame).
        out = x
        buf = x
        order_arr = jnp.asarray(order)
        for step in range(len(order) - 1):
            buf = _ppermute(buf, axis_name, [edges[step]])
            receiver = order_arr[step + 1]
            out = jnp.where(idx == receiver, buf, out)
        return out

    if x.shape[0] % num_frames != 0:
        raise ValueError(
            f"leading dim {x.shape[0]} not divisible by num_frames={num_frames}"
        )
    frames = x.reshape((num_frames, x.shape[0] // num_frames) + x.shape[1:])
    order_arr = jnp.asarray(order)
    # Ring position of this device in the chain; -1 (→ L, clamped out of
    # range) for non-members.
    member = (order_arr == idx).any()
    pos = jnp.argmax(order_arr == idx)  # 0 if non-member; masked below
    L = len(order)
    T = num_frames + L - 2  # scan steps

    def step(carry, t):
        buf, out = carry
        # Head injects frame t while frames remain; members forward the
        # frame they hold. (Head's "buf" is its injection register.)
        t_clamped = jnp.minimum(t, num_frames - 1)
        inject = lax.dynamic_index_in_dim(frames, t_clamped, axis=0, keepdims=False)
        buf = jnp.where(is_head & (t < num_frames), inject, buf)
        buf = _ppermute(buf, axis_name, edges)
        # After hop t, the device at chain position p holds frame t-(p-1).
        fidx = t - (pos - 1)
        valid = member & (pos > 0) & (fidx >= 0) & (fidx < num_frames)
        fidx_c = jnp.clip(fidx, 0, num_frames - 1)
        current = lax.dynamic_index_in_dim(out, fidx_c, axis=0, keepdims=False)
        new = jnp.where(valid, buf, current)
        out = lax.dynamic_update_index_in_dim(out, new, fidx_c, axis=0)
        return (buf, out), None

    buf0 = jnp.zeros_like(frames[0])
    out0 = jnp.where(is_head, frames, jnp.zeros_like(frames))
    (_, out), _ = _scan(step, (buf0, out0), jnp.arange(T))
    return out.reshape(x.shape)


def _validate_multi_chains(
    head: int, chains: Sequence[Sequence[int]]
) -> list[tuple[int, ...]]:
    clean = [tuple(int(d) for d in c) for c in chains if len(c)]
    if not clean:
        raise ValueError("empty chain set")
    seen: set[int] = set()
    for c in clean:
        for d in c:
            if d == head:
                raise ValueError("head cannot appear inside a chain")
            if d in seen:
                raise ValueError(f"destination {d} appears in two chains")
            seen.add(d)
    return clean


def multi_chain_broadcast(
    x: jax.Array,
    axis_name: Axis,
    head: int,
    chains: Sequence[Sequence[int]],
    *,
    num_frames: int = 1,
) -> jax.Array:
    """Multicast ``x`` from device ``head`` down K disjoint sub-chains
    concurrently (multi-chain Chainwrite; chains typically come from
    ``scheduling.partition_schedule``).

    ``chains`` are destination orders (head excluded, matching the
    scheduler convention); they must be pairwise disjoint. Devices on
    the axis in no chain return zeros, chain members (and the head)
    return the head's payload. ``num_frames > 1`` pipelines frames down
    every chain simultaneously; completion takes
    ``num_frames + max_chain_len - 1`` frame-hop slots instead of
    ``num_frames * max_chain_len``.

    K=1 computes exactly ``chain_broadcast(x, axis, (head, *chains[0]))``.
    """
    chains = _validate_multi_chains(int(head), chains)
    head = int(head)
    if len(chains) == 1:
        return chain_broadcast(
            x, axis_name, (head,) + chains[0], num_frames=num_frames
        )

    idx = _axis_index(axis_name)
    is_head = idx == head
    x = jnp.where(is_head, x, jnp.zeros_like(x))
    full = [(head,) + c for c in chains]  # per-chain node traversal
    max_len = max(len(f) for f in full)

    # Static per-device chain position: pos 0 = head, p >= 1 = p-th
    # member of its (unique) chain, L (out of range) = non-member.
    L_axis = _axis_size(axis_name)
    pos_np = [max_len] * L_axis
    pos_np[head] = 0
    for f in full:
        for p, d in enumerate(f[1:], start=1):
            pos_np[d] = p
    pos = jnp.asarray(pos_np)[idx]
    member = pos < max_len

    def fanout(buf: jax.Array, edges: list[tuple[int, int]]) -> jax.Array:
        """One hop of every chain. All intra-chain edges (plus the
        first head edge) have unique sources/targets -> one fused
        ppermute; the head's remaining same-step sends need their own
        ppermutes (unique-source rule)."""
        head_edges = [e for e in edges if e[0] == head]
        fused = [e for e in edges if e[0] != head] + head_edges[:1]
        new = _ppermute(buf, axis_name, fused) if fused else jnp.zeros_like(buf)
        for e in head_edges[1:]:
            r = _ppermute(buf, axis_name, [e])
            new = jnp.where(idx == e[1], r, new)
        return new

    if num_frames <= 1:
        out = x
        buf = x
        for step in range(max_len - 1):
            edges = [
                (f[step], f[step + 1]) for f in full if step + 1 < len(f)
            ]
            buf = fanout(buf, edges)
            receivers = jnp.asarray([e[1] for e in edges])
            out = jnp.where((idx == receivers).any(), buf, out)
        return out

    if x.shape[0] % num_frames != 0:
        raise ValueError(
            f"leading dim {x.shape[0]} not divisible by num_frames={num_frames}"
        )
    frames = x.reshape((num_frames, x.shape[0] // num_frames) + x.shape[1:])
    all_edges = [e for f in full for e in zip(f, f[1:])]
    T = num_frames + max_len - 2  # scan steps (longest chain's fill)

    def step(carry, t):
        buf, out = carry
        t_clamped = jnp.minimum(t, num_frames - 1)
        inject = lax.dynamic_index_in_dim(frames, t_clamped, axis=0, keepdims=False)
        buf = jnp.where(is_head & (t < num_frames), inject, buf)
        buf = fanout(buf, all_edges)
        # After hop t, the member at chain position p holds frame t-(p-1).
        fidx = t - (pos - 1)
        valid = member & (pos > 0) & (fidx >= 0) & (fidx < num_frames)
        fidx_c = jnp.clip(fidx, 0, num_frames - 1)
        current = lax.dynamic_index_in_dim(out, fidx_c, axis=0, keepdims=False)
        new = jnp.where(valid, buf, current)
        out = lax.dynamic_update_index_in_dim(out, new, fidx_c, axis=0)
        return (buf, out), None

    buf0 = jnp.zeros_like(frames[0])
    out0 = jnp.where(is_head, frames, jnp.zeros_like(frames))
    (_, out), _ = _scan(step, (buf0, out0), jnp.arange(T))
    return out.reshape(x.shape)


def degraded_chains(
    chains: Sequence[Sequence[int]], failed: int
) -> list[tuple[int, ...]]:
    """Splice ``failed`` out of its sub-chain (endpoint-only re-forming
    at the SPMD layer: no topology knowledge, relative order kept).

    Host-side callers that hold a :class:`~repro.core.topology.
    MeshTopology` should prefer ``scheduling.reform_chain`` per chain —
    it re-orders the orphaned suffix — and pass the result straight to
    :func:`multi_chain_broadcast`; this helper is the schedule-free
    fallback. Chains emptied by the splice are dropped.
    """
    failed = int(failed)
    found = False
    out: list[tuple[int, ...]] = []
    for c in chains:
        members = [int(d) for d in c]
        kept = tuple(d for d in members if d != failed)
        found = found or len(kept) != len(members)
        if kept:
            out.append(kept)
    if not found:
        raise ValueError(f"failed node {failed} is in no chain")
    return out


def degraded_multi_chain_broadcast(
    x: jax.Array,
    axis_name: Axis,
    head: int,
    chains: Sequence[Sequence[int]],
    failed: int,
    *,
    num_frames: int = 1,
) -> jax.Array:
    """:func:`multi_chain_broadcast` with chain member ``failed``
    dropped — the degraded collective a re-formed Chainwrite runs after
    a node failure.

    Every *surviving* chain member (and the head) still receives the
    head's payload; the failed device — like any non-member — returns
    zeros, so the paper's "nothing outside the chain is touched"
    property extends to dead nodes. K=1 with the failure in the middle
    of the single chain degrades to the spliced shorter chain.
    """
    head = int(head)
    if int(failed) == head:
        raise ValueError("the initiator (head) cannot be dropped")
    remaining = degraded_chains(chains, failed)
    if not remaining:  # every destination failed: head keeps its payload
        idx = _axis_index(axis_name)
        return jnp.where(idx == head, x, jnp.zeros_like(x))
    return multi_chain_broadcast(
        x, axis_name, head, remaining, num_frames=num_frames
    )


# ---------------------------------------------------------------------------
# Ring collectives over a scheduled order
# ---------------------------------------------------------------------------


def chain_all_gather(
    x: jax.Array,
    axis_name: Axis,
    order: Sequence[int] | None = None,
    *,
    tiled: bool = False,
) -> jax.Array:
    """Ring all-gather over a scheduled ring order.

    Every device contributes ``x``; returns the stacked (axis 0) —
    or, with ``tiled=True``, concatenated — shards indexed by *device
    id along the axis* (standard all_gather semantics, so this is a
    drop-in for ``lax.all_gather`` regardless of ring order).
    """
    L = _axis_size(axis_name)
    order = tuple(range(L)) if order is None else tuple(int(o) for o in order)
    if sorted(order) != list(range(L)):
        raise ValueError("ring order must be a permutation of the whole axis")
    idx = _axis_index(axis_name)
    order_arr = jnp.asarray(order)
    pos = jnp.argmax(order_arr == idx)
    edges = chain_edges(order, wrap=True)

    out = jnp.zeros((L,) + x.shape, x.dtype)
    out = lax.dynamic_update_index_in_dim(out, x, idx, axis=0)

    def step(carry, s):
        buf, out = carry
        buf = _ppermute(buf, axis_name, edges)
        src = order_arr[(pos - s) % L]  # origin device of the shard just received
        out = lax.dynamic_update_index_in_dim(out, buf, src, axis=0)
        return (buf, out), None

    (_, out), _ = _scan(step, (x, out), jnp.arange(1, L))
    if tiled:
        out = out.reshape((L * x.shape[0],) + x.shape[1:])
    return out


def chain_reduce_scatter(
    x: jax.Array,
    axis_name: Axis,
    order: Sequence[int] | None = None,
) -> jax.Array:
    """Ring reduce-scatter over a scheduled ring order.

    ``x`` has leading dim L (one chunk per device id along the axis);
    returns the fully-reduced chunk owned by this device
    (``sum_over_devices(x)[my_id]``).
    """
    L = _axis_size(axis_name)
    order = tuple(range(L)) if order is None else tuple(int(o) for o in order)
    if sorted(order) != list(range(L)):
        raise ValueError("ring order must be a permutation of the whole axis")
    if x.shape[0] != L:
        raise ValueError(f"leading dim {x.shape[0]} != axis size {L}")
    idx = _axis_index(axis_name)
    order_arr = jnp.asarray(order)
    pos = jnp.argmax(order_arr == idx)
    edges = chain_edges(order, wrap=True)

    # Chunks are addressed by ring position: the chunk that must end at
    # ring position p is the one for device order[p]. The partial for
    # position j starts at position j+1 (holding its local chunk) and
    # travels L-1 hops, accumulating every member's contribution.
    start_chunk = order_arr[(pos - 1) % L]
    buf = lax.dynamic_index_in_dim(x, start_chunk, axis=0, keepdims=False)

    def step(buf, s):
        buf = _ppermute(buf, axis_name, edges)
        j = order_arr[(pos - s - 1) % L]
        buf = buf + lax.dynamic_index_in_dim(x, j, axis=0, keepdims=False)
        return buf, None

    buf, _ = _scan(step, buf, jnp.arange(1, L))
    return buf


def chain_all_reduce(
    x: jax.Array,
    axis_name: Axis,
    order: Sequence[int] | None = None,
) -> jax.Array:
    """Ring all-reduce = reduce-scatter + all-gather on the scheduled
    ring (bandwidth-optimal: 2·(L-1)/L of the payload per link)."""
    L = _axis_size(axis_name)
    lead = x.shape[0]
    pad = (-lead) % L
    xp = jnp.pad(x, [(0, pad)] + [(0, 0)] * (x.ndim - 1)) if pad else x
    chunks = xp.reshape((L, xp.shape[0] // L) + x.shape[1:])
    own = chain_reduce_scatter(chunks, axis_name, order)
    full = chain_all_gather(own, axis_name, order, tiled=True)
    return full[:lead] if pad else full


def validate_ring_partition(
    axis_size: int, orders: Sequence[Sequence[int]]
) -> list[tuple[int, ...]]:
    """Clean + validate K disjoint equal-size sub-rings covering the
    whole axis. Pure host-side helper (no axis context needed) shared
    by :func:`multi_chain_all_reduce` and the property tests."""
    clean = [tuple(int(o) for o in c) for c in orders if len(c)]
    if not clean:
        raise ValueError("empty ring set")
    S = len(clean[0])
    if any(len(c) != S for c in clean):
        raise ValueError("sub-rings must have equal sizes")
    flat = [d for c in clean for d in c]
    if sorted(flat) != list(range(axis_size)):
        raise ValueError("sub-rings must partition the whole axis")
    return clean


def _cross_ring_edges(orders: Sequence[tuple[int, ...]]) -> list[tuple[int, int]]:
    """Rotation edges across rings: local position r of ring c -> local
    position r of ring (c+1) % K — one fused ppermute per step."""
    K, S = len(orders), len(orders[0])
    return [
        (orders[c][r], orders[(c + 1) % K][r])
        for c in range(K)
        for r in range(S)
    ]


def multi_chain_all_reduce(
    x: jax.Array,
    axis_name: Axis,
    orders: Sequence[Sequence[int]],
    *,
    algo: str = "rs_ag",
) -> jax.Array:
    """All-reduce over K disjoint equal-size sub-rings of the axis.

    ``algo="rs_ag"`` (default — bandwidth-optimal family): stage 1 is a
    fused per-ring reduce-scatter (S-1 steps; the K rings' edges are
    disjoint, so every step is ONE ppermute carrying 1/S-payload
    shards), stage 2 rotation-reduces the reduced *shards* across rings
    (K-1 steps, still 1/S payload: position r of ring c exchanges with
    position r of ring c+1), stage 3 is the fused per-ring all-gather
    (S-1 steps). Wire bytes per device ≈ (2·(S-1)+(K-1))/S · payload —
    at K=1 exactly ``chain_all_reduce``'s bandwidth-optimal
    2·(L-1)/L — while the per-ring chain length stays S, keeping the
    multi-chain latency win.

    ``algo="rotation"`` keeps PR 1's schedule: S-1 full-payload
    rotations within rings then K-1 across — fewer steps (S+K-2 vs
    2·(S-1)+(K-1)) but (S+K-2) full payloads of wire per device;
    preferable only when per-step overhead dominates (tiny payloads).
    ``core.simulator.all_reduce_latency`` models both and
    ``choose_num_chains(collective="all_reduce")`` picks K/algo-aware.

    Hierarchical (within-pod then cross-pod) all-reduce is exactly the
    K=#pods special case of either schedule on the flattened DP axis.

    ``orders``: K disjoint rings of equal size covering the whole axis
    (e.g. contiguous slices of ``ring_order_for_axis``). K=1 delegates
    to :func:`chain_all_reduce` (reduce-scatter + all-gather) for
    either ``algo``.
    """
    if algo not in ALL_REDUCE_ALGOS:
        raise ValueError(f"unknown algo {algo!r}; expected {ALL_REDUCE_ALGOS}")
    orders = validate_ring_partition(_axis_size(axis_name), orders)
    if len(orders) == 1:
        return chain_all_reduce(x, axis_name, orders[0])
    if algo == "rotation":
        return _multi_ring_rotation(x, axis_name, orders)
    return _multi_ring_rs_ag(x, axis_name, orders)


def _multi_ring_rotation(
    x: jax.Array, axis_name: Axis, orders: list[tuple[int, ...]]
) -> jax.Array:
    """PR 1 rotation schedule: full-payload rotations, S+K-2 steps."""
    K, S = len(orders), len(orders[0])

    # Stage 1 — within-ring rotation all-reduce (fused across rings).
    intra = [e for c in orders for e in chain_edges(c, wrap=True)]
    acc = x
    buf = x
    for _ in range(S - 1):
        buf = _ppermute(buf, axis_name, intra)
        acc = acc + buf

    # Stage 2 — across-ring rotation of the ring partials.
    cross = _cross_ring_edges(orders)
    buf = acc
    out = acc
    for _ in range(K - 1):
        buf = _ppermute(buf, axis_name, cross)
        out = out + buf
    return out


def _multi_ring_rs_ag(
    x: jax.Array, axis_name: Axis, orders: list[tuple[int, ...]]
) -> jax.Array:
    """Fused per-ring reduce-scatter -> cross-ring shard rotation ->
    fused per-ring all-gather. Shards are addressed by *ring position*
    (shard j of the payload ends, fully reduced, at local position j of
    every ring), so the cross-ring exchange at position r always pairs
    partials of the same shard."""
    K, S = len(orders), len(orders[0])
    idx = _axis_index(axis_name)

    # Static ring position of every device (each appears in exactly one
    # ring — validated by the caller).
    pos_np = [0] * (K * S)
    for c in orders:
        for p, d in enumerate(c):
            pos_np[d] = p
    pos = jnp.asarray(pos_np)[idx]

    lead = x.shape[0]
    pad = (-lead) % S
    xp = jnp.pad(x, [(0, pad)] + [(0, 0)] * (x.ndim - 1)) if pad else x
    shards = xp.reshape((S, xp.shape[0] // S) + x.shape[1:])

    intra = [e for c in orders for e in chain_edges(c, wrap=True)]

    # Stage 1 — fused per-ring reduce-scatter: the partial for position
    # j starts one hop downstream (position j+1, holding its local
    # shard) and travels S-1 hops, accumulating every ring member's
    # contribution; 1/S payload per step.
    buf = lax.dynamic_index_in_dim(shards, (pos - 1) % S, axis=0, keepdims=False)
    for s in range(1, S):
        buf = _ppermute(buf, axis_name, intra)
        j = (pos - s - 1) % S
        buf = buf + lax.dynamic_index_in_dim(shards, j, axis=0, keepdims=False)

    # Stage 2 — rotate the ring-reduced shards across rings (K-1 steps,
    # still 1/S payload — the bandwidth collapse vs full-payload
    # rotation). Each device forwards the partial it received while
    # accumulating: after K-1 steps position r holds the global sum of
    # shard r.
    cross = _cross_ring_edges(orders)
    acc = buf
    for _ in range(K - 1):
        buf = _ppermute(buf, axis_name, cross)
        acc = acc + buf

    # Stage 3 — fused per-ring all-gather of the S reduced shards.
    out = jnp.zeros_like(shards)
    out = lax.dynamic_update_index_in_dim(out, acc, pos, axis=0)
    buf = acc
    for s in range(1, S):
        buf = _ppermute(buf, axis_name, intra)
        src = (pos - s) % S
        out = lax.dynamic_update_index_in_dim(out, buf, src, axis=0)
    full = out.reshape((S * shards.shape[1],) + x.shape[1:])
    return full[:lead] if pad else full


def chain_all_to_all(
    x: jax.Array,
    axis_name: Axis,
    order: Sequence[int] | None = None,
) -> jax.Array:
    """Ring all-to-all (MoE dispatch): ``x`` has leading dim L, chunk
    ``x[d]`` is destined to device ``d``. Returns stacked chunks
    received from every device (``out[s]`` = chunk sent by device s).

    Implemented as L-1 rotations of the scheduled ring: at each step
    every device forwards the not-yet-delivered chunks one hop and
    keeps the chunk addressed to it — each chunk travels exactly its
    ring distance, the chain analogue of per-pair P2P transfers.
    """
    L = _axis_size(axis_name)
    order = tuple(range(L)) if order is None else tuple(int(o) for o in order)
    if sorted(order) != list(range(L)):
        raise ValueError("ring order must be a permutation of the whole axis")
    if x.shape[0] != L:
        raise ValueError(f"leading dim {x.shape[0]} != axis size {L}")
    idx = _axis_index(axis_name)
    order_arr = jnp.asarray(order)
    pos = jnp.argmax(order_arr == idx)
    edges = chain_edges(order, wrap=True)

    out = jnp.zeros_like(x)
    out = lax.dynamic_update_index_in_dim(
        out, lax.dynamic_index_in_dim(x, idx, axis=0, keepdims=False), idx, axis=0
    )

    def step(carry, s):
        buf, out = carry
        buf = _ppermute(buf, axis_name, edges)
        # After s hops, this device holds the chunk-train of the ring
        # predecessor at distance s: origin device order[(pos - s) % L].
        src = order_arr[(pos - s) % L]
        mine = lax.dynamic_index_in_dim(buf, idx, axis=0, keepdims=False)
        out = lax.dynamic_update_index_in_dim(out, mine, src, axis=0)
        return (buf, out), None

    (_, out), _ = _scan(step, (x, out), jnp.arange(1, L))
    return out


# ---------------------------------------------------------------------------
# XLA-native baselines (the "network-layer multicast" analogue)
# ---------------------------------------------------------------------------


def xla_broadcast(x: jax.Array, axis_name: Axis, root: int = 0) -> jax.Array:
    """Broadcast via the fabric's native reduction (baseline)."""
    idx = _axis_index(axis_name)
    return lax.psum(jnp.where(idx == root, x, jnp.zeros_like(x)), axis_name)


def _axis_size(axis_name: Axis) -> int:
    if isinstance(axis_name, (tuple, list)):
        return int(
            functools.reduce(lambda a, n: a * lax.axis_size(n), axis_name, 1)
        )
    return int(lax.axis_size(axis_name))
