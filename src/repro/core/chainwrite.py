"""Chainwrite collectives — the paper's P2MP mechanism on TPU ICI.

The paper moves data replication out of the NoC routers and into the DMA
endpoints: data traverses a *scheduled chain* of destinations, each hop
an ordinary P2P transfer. On TPU the only true P2P primitive is
``jax.lax.ppermute`` (collective-permute), so every Chainwrite pattern
maps to chains of ppermutes inside ``shard_map``.

Since the ChainProgram refactor there is exactly ONE interpreter here:
:func:`execute_program` runs any :class:`~repro.core.program.
ChainProgram` step by step (one fused ppermute per step; a pipeline
head's same-step fan-out gets per-edge permutes because XLA requires
unique permute sources). Every public collective is a thin
``plan_* -> execute_program`` wrapper whose signature is unchanged from
the pre-IR versions:

* :func:`chain_broadcast` / :func:`multi_chain_broadcast` /
  :func:`degraded_multi_chain_broadcast` — P2MP multicast down one or
  K link-disjoint sub-chains, with optional frame pipelining
  (``num_frames``: payload frames stream through the chains
  store-and-forward, F + L - 2 slots instead of F·L — the paper's
  §III-C stream duplicator).
* :func:`chain_all_gather` / :func:`chain_reduce_scatter` /
  :func:`chain_all_reduce` / :func:`chain_all_to_all` — ring
  collectives over an explicitly *scheduled* ring order.
* :func:`multi_chain_all_reduce` — K disjoint equal sub-rings;
  ``algo="rs_ag"`` (fused per-ring reduce-scatter → cross-ring shard
  rotation → fused per-ring all-gather, ≈ (2·(S-1)+(K-1))/S payloads
  of wire per device) or ``algo="rotation"`` (S+K-2 full-payload
  steps).
* :func:`multi_chain_reduce_scatter` / :func:`multi_chain_all_gather` /
  :func:`multi_chain_all_to_all` — the K-ring generalizations that
  fall straight out of the planner (same total wire as the single
  ring, ring-local/position-paired hops).

All functions must be called inside ``shard_map`` with a manual axis.
``order``/``orders``/``chains`` are static tuples of device indices
along the axis; non-members of a partial chain participate in the SPMD
program but receive (and keep) zeros — the paper's "no change to the
interconnect" property: nothing outside the chain is touched.

The numpy twin of :func:`execute_program` is
:func:`repro.core.chainwrite_ref.interpret_program`; both interpret the
same program, so they agree BIT-exactly (the IR fixes the fold order).
"""

from __future__ import annotations

import functools
from typing import Sequence

import jax
import jax.numpy as jnp
from jax import lax

from . import program as prg
from .program import ALL_REDUCE_ALGOS, ChainProgram, validate_ring_partition
from .scheduling import FailureSpec, normalize_failed

# Wire-dtype numerics (safe: repro.runtime never imports this module).
from repro.runtime.compression import dequantize, quantize

Axis = str | tuple[str, ...]

# When True, ring/chain scans are fully unrolled. The dry-run sets this
# so every ppermute appears as its own HLO op and the §Roofline
# collective-bytes parser counts true wire traffic (a rolled scan's
# body is counted once regardless of trip count). The stepped program
# interpreter is always unrolled (its addressing tables are per-step
# static); only the frame-pipelined broadcast scan consults this.
_STATIC_UNROLL = False


def set_static_unroll(value: bool) -> None:
    global _STATIC_UNROLL
    _STATIC_UNROLL = bool(value)


def _scan(body, carry, xs):
    length = int(xs.shape[0]) if hasattr(xs, "shape") else len(xs)
    return lax.scan(
        body, carry, xs, unroll=length if _STATIC_UNROLL else 1
    )


def _axis_index(axis_name: Axis) -> jax.Array:
    """Linearized index over one axis name or a tuple of axis names."""
    if isinstance(axis_name, (tuple, list)):
        idx = lax.axis_index(axis_name[0])
        for name in axis_name[1:]:
            idx = idx * lax.axis_size(name) + lax.axis_index(name)
        return idx
    return lax.axis_index(axis_name)


def chain_edges(order: Sequence[int], *, wrap: bool = False) -> list[tuple[int, int]]:
    """Directed ppermute pairs for a chain (optionally closed ring)."""
    edges = [(int(a), int(b)) for a, b in zip(order, order[1:])]
    if wrap and len(order) > 1:
        edges.append((int(order[-1]), int(order[0])))
    return edges


def _ppermute(x: jax.Array, axis_name: Axis, perm: list[tuple[int, int]]) -> jax.Array:
    return lax.ppermute(x, axis_name, perm)


# ---------------------------------------------------------------------------
# The generic SPMD program executor
# ---------------------------------------------------------------------------


def _fanout(
    buf: jax.Array, axis_name: Axis, edges: Sequence[tuple[int, int]], idx
) -> jax.Array:
    """One program step's hop. Unique-source edges fuse into a single
    ppermute; each repeated source (the pipeline head's same-step
    fan-out) costs its own permute (XLA's unique-source rule) — the
    split :meth:`Step.num_permutes` accounts for."""
    if not edges:
        return jnp.zeros_like(buf)
    seen: set[int] = set()
    fused: list[tuple[int, int]] = []
    extra: list[tuple[int, int]] = []
    for e in edges:
        if e[0] in seen:
            extra.append(e)
        else:
            seen.add(e[0])
            fused.append(e)
    new = _ppermute(buf, axis_name, fused)
    for e in extra:
        r = _ppermute(buf, axis_name, [e])
        new = jnp.where(idx == e[1], r, new)
    return new


class _AddrCtx:
    """Trace-time symbolic address evaluation: this device's ring
    position / ring index as traced scalars, built lazily on the first
    symbolic table. A *canonical* partition (``groups[j] == range(j·S,
    (j+1)·S)`` covering the axis) derives them arithmetically from
    ``idx`` — ZERO ring-length-sized HLO constants; an irregular
    partition gathers from (L,)-sized constant maps (still O(L) where
    dense tables were O(L²))."""

    def __init__(self, prog: ChainProgram, idx) -> None:
        self._prog = prog
        self._idx = idx
        self._ready = False

    def _build(self) -> None:
        if self._ready:
            return
        ctx = self._prog.ring_ctx()
        self.K, self.S = ctx.K, ctx.S
        idx = self._idx
        if ctx.canonical:
            self._pos = idx % ctx.S
            self._ring = idx // ctx.S
            self._mask = None
            self._flat = None
        else:
            L = self._prog.num_devices
            pos_np = [0] * L
            ring_np = [0] * L
            mem_np = [False] * L
            for d, p in ctx.pos.items():
                pos_np[d], mem_np[d] = p, True
            for d, r in ctx.ring_of.items():
                ring_np[d] = r
            self._pos = jnp.asarray(pos_np)[idx]
            self._ring = jnp.asarray(ring_np)[idx]
            self._mask = jnp.asarray(mem_np)[idx]
            self._flat = jnp.asarray(
                [ctx.orders[r][q] for r in range(ctx.K) for q in range(ctx.S)]
            )
        self._ready = True

    def dims(self) -> tuple[int, int]:
        self._build()
        return self.K, self.S

    def pos(self):
        self._build()
        return self._pos

    def ring(self):
        self._build()
        return self._ring

    def member(self, flat_idx):
        """Device id of ring ``flat_idx // S``, position ``% S``."""
        self._build()
        return flat_idx if self._flat is None else self._flat[flat_idx]

    def mask_row(self, row):
        """-1 out the row on devices outside every ring group."""
        self._build()
        if self._mask is None:
            return row
        return jnp.where(self._mask, row, -1)


def _row_ids(table, actx, idx):
    """Traced (width,) int32 slot/shard addresses of this device's row
    (-1 = none) — dense tables gather from the embedded constant, the
    symbolic forms compute from ``idx`` and the coefficients."""
    if isinstance(table, tuple):
        return jnp.asarray(table)[idx]
    if isinstance(table, prg.AtDevices):
        none = jnp.full((table.width,), -1, jnp.int32)
        if not table.devices:
            return none
        hit = jnp.any(jnp.asarray(sorted(set(table.devices))) == idx)
        return jnp.where(
            hit, jnp.full((table.width,), table.value, jnp.int32), none
        )
    if isinstance(table, prg.Diag):
        inner = _row_ids(table.inner, actx, idx)[0]
        return jnp.where(jnp.arange(table.width) == idx, inner, -1).astype(
            jnp.int32
        )
    if isinstance(table, prg.Affine):
        row = (
            table.a * actx.pos() + table.c * actx.ring()
            + table.e * jnp.arange(table.width) + table.b
        ) % table.m
        return actx.mask_row(row.astype(jnp.int32))
    if isinstance(table, prg.MemberLookup):
        K, S = actx.dims()
        cols = jnp.arange(table.width)
        r = (table.ar * actx.ring() + table.er * cols + table.br) % K
        q = (table.ap * actx.pos() + table.ep * cols + table.bp) % S
        return actx.mask_row(actx.member(r * S + q).astype(jnp.int32))
    raise TypeError(f"unknown table type {type(table).__name__}")


def _rows_from(table, idx, source, keep=None, actx=None):
    """Per-device row select: ``result[j] = source[table[self][j]]``,
    with ``-1`` giving ``keep[j]`` (same-width) or zeros."""
    t = _row_ids(table, actx, idx)  # (width,)
    safe = jnp.clip(t, 0, source.shape[0] - 1)
    rows = source[safe]
    mask = (t >= 0).reshape((-1,) + (1,) * (source.ndim - 1))
    if keep is not None and keep.shape[0] == prg.table_width(table):
        return jnp.where(mask, rows, keep)
    return jnp.where(mask, rows, jnp.zeros_like(rows))


def _hop(buf, axis_name, edges, idx, wire):
    """Ship ``buf`` over one step's edges. ``wire="int8"`` quantizes
    before the hop and dequantizes after: the int8 frame and its f32
    scale travel as two ppermutes (the scale is the 4-byte sideband
    :meth:`ChainProgram.step_bytes` prices); non-target devices receive
    zeros for both, so dequantize reproduces the uncompressed zeros."""
    if wire == "int8":
        q, scale = quantize(buf)
        q = _fanout(q, axis_name, edges, idx)
        scale = _fanout(scale, axis_name, edges, idx)
        # No contraction barrier needed: quantize() truncates the scale
        # mantissa so q * scale is exact in f32, which makes an FMA of
        # dequantize-mul + accumulate-add bitwise equal to separate
        # rounding — the oracle replay stays exact either way.
        return dequantize(q, scale)
    return _fanout(buf, axis_name, edges, idx)


def _one_step(buf, out, shards, axis_name, idx, step, wire=None, actx=None):
    """One program step (the machine model of :mod:`repro.core.program`
    verbatim): load -> hop -> combine -> write."""
    if step.load is not None:
        buf = _rows_from(step.load, idx, out, keep=buf, actx=actx)
    buf = _hop(buf, axis_name, step.edges, idx, wire)
    if step.combine == prg.ADD:
        src = shards if step.add_from == "input" else out
        buf = buf + _rows_from(step.add_src, idx, src, actx=actx)
    if step.write is not None:
        out = _write_step(buf, out, step.write, step.width,
                          step.write_op, actx, idx)
    return buf, out


def _write_step(buf, out, table, width, write_op, actx, idx):
    """Apply one step's write table: dense tables keep the historical
    sparse/width-loop paths; symbolic tables write through ONE indexed
    update (Diag / width-1) or one vector scatter (full-width)."""
    if isinstance(table, tuple):
        sparse = _sparse_write(table)
        if sparse is not None:
            rows_tbl, slots_tbl = sparse
            return _write_one(
                buf, out, jnp.asarray(rows_tbl)[idx],
                jnp.asarray(slots_tbl)[idx], write_op,
            )
        t = jnp.asarray(table)[idx]  # (width,)
        return _write_dense(buf, out, t, width, write_op)
    if isinstance(table, prg.Diag):
        slot = _row_ids(table.inner, actx, idx)[0]
        return _write_one(buf, out, idx, slot, write_op)
    rows = _row_ids(table, actx, idx)
    if prg.table_width(table) == 1:
        return _write_one(buf, out, jnp.int32(0), rows[0], write_op)
    return _write_rows(buf, out, rows, write_op)


def _sparse_write(table):
    """When every device writes at most ONE buf row per step (e.g. the
    all_to_all peel: width L, one live slot), the write collapses to a
    single indexed update instead of a width-long guarded loop —
    keeping HLO size O(L) rather than O(L^2) for the chunk train.
    Returns per-device (buf_row, out_slot) tables, or None when some
    device writes multiple slots."""
    rows: list[int] = []
    slots: list[int] = []
    for drow in table:
        live = [(j, s) for j, s in enumerate(drow) if s >= 0]
        if len(live) > 1:
            return None
        j, s = live[0] if live else (0, -1)
        rows.append(j)
        slots.append(s)
    return tuple(rows), tuple(slots)


def _write_one(buf, out, row_t, slot_t, write_op):
    """out[slot] (op)= buf[row] for this device; slot < 0 is a no-op."""
    valid = slot_t >= 0
    row_c = jnp.clip(row_t, 0, buf.shape[0] - 1)
    val = lax.dynamic_index_in_dim(buf, row_c, 0, keepdims=False)
    slot_c = jnp.clip(slot_t, 0, out.shape[0] - 1)
    cur = lax.dynamic_index_in_dim(out, slot_c, 0, keepdims=False)
    new = val if write_op == prg.COPY else cur + val
    new = jnp.where(valid, new, cur)
    return lax.dynamic_update_index_in_dim(out, new, slot_c, 0)


def _write_rows(buf, out, slots, write_op):
    """Vectorized full-width write ``out[slots[j]] (op)= buf[j]`` for a
    traced slot row: live slots are distinct (an IR invariant), so this
    is one scatter; -1 rows land on a dummy slot that is dropped."""
    dummy = jnp.zeros((1,) + out.shape[1:], out.dtype)
    ext = jnp.concatenate([out, dummy], axis=0)
    tgt = jnp.where(slots >= 0, slots, out.shape[0])
    if write_op == prg.COPY:
        ext = ext.at[tgt].set(buf)
    else:
        ext = ext.at[tgt].add(buf)
    return ext[:-1]


def _write_dense(buf, out, slots, width, write_op):
    for j in range(width):
        slot = slots[j]
        valid = slot >= 0
        slot_c = jnp.clip(slot, 0, out.shape[0] - 1)
        cur = lax.dynamic_index_in_dim(out, slot_c, 0, keepdims=False)
        new = buf[j] if write_op == prg.COPY else cur + buf[j]
        new = jnp.where(valid, new, cur)
        out = lax.dynamic_update_index_in_dim(out, new, slot_c, 0)
    return out


def _stack_key(table):
    """Scan-compatibility key of an addressing table: steps stack into
    one ``lax.scan`` when only their per-step *offsets* differ (dense
    rows ride in the xs; symbolic offsets — Affine ``b``, MemberLookup
    ``br``/``bp`` — become scalar xs decoded in the body)."""
    if table is None:
        return None
    if isinstance(table, tuple):
        return "dense"
    if isinstance(table, prg.Affine):
        return ("affine", table.width, table.a, table.c, table.e, table.m)
    if isinstance(table, prg.MemberLookup):
        return ("member", table.width, table.ar, table.er, table.ap, table.ep)
    if isinstance(table, prg.Diag):
        return ("diag", table.width, _stack_key(table.inner))
    return ("at", table)  # AtDevices: only identical tables stack


def _uniform_runs(steps, wires=None):
    """Group consecutive steps that share edges/width/combine/write
    structure AND wire dtype (differing only in their addressing
    offsets/rows) so the executor can roll each group into one
    ``lax.scan`` — keeping the compiled HLO ring-length-independent as
    the pre-IR collectives were. Steps with a ``load`` (phase
    boundaries) run standalone. Returns ``[(wire, [steps...]), ...]``."""
    if wires is None:
        wires = [None] * len(steps)
    runs: list[tuple] = []
    key_prev = None
    for s, w in zip(steps, wires):
        key = (s.edges, s.width, s.combine, s.add_from,
               _stack_key(s.add_src), _stack_key(s.write), s.write_op, w)
        if s.load is None and runs and key_prev == key:
            runs[-1][1].append(s)
        else:
            runs.append((w, [s]))
        key_prev = key if s.load is None else None
    return runs


def _offset_xs(vals):
    """Per-step symbolic offsets as scan xs WITHOUT an O(T) constant:
    a constant sequence broadcasts a scalar, an arithmetic progression
    rides an iota; anything else (no planner emits one) falls back to
    the materialized vector."""
    T = len(vals)
    v0 = vals[0]
    if all(v == v0 for v in vals):
        return jnp.full((T,), v0, jnp.int32)
    db = vals[1] - v0
    if all(vals[i] == v0 + i * db for i in range(T)):
        return (v0 + db * jnp.arange(T)).astype(jnp.int32)
    return jnp.asarray(vals, jnp.int32)


def _stacked_rows(tables, actx, idx):
    """(xs, row_fn) for a uniform run's same-structure tables: the scan
    body calls ``row_fn(x_t)`` to recover step t's (width,) address
    row. Dense tables pre-gather this device's rows into (T, width) xs;
    symbolic tables ship only their per-step offsets."""
    t0 = tables[0]
    if isinstance(t0, tuple):
        return jnp.asarray(tables)[:, idx], lambda x: x
    if isinstance(t0, prg.Affine):
        xs = _offset_xs([t.b for t in tables])

        def fn(x, t0=t0):
            row = (
                t0.a * actx.pos() + t0.c * actx.ring()
                + t0.e * jnp.arange(t0.width) + x
            ) % t0.m
            return actx.mask_row(row.astype(jnp.int32))

        return xs, fn
    if isinstance(t0, prg.MemberLookup):
        K, S = actx.dims()
        xs = jnp.stack(
            [_offset_xs([t.br for t in tables]),
             _offset_xs([t.bp for t in tables])], axis=1,
        )
        cols = jnp.arange(t0.width)

        def fn(x, t0=t0):
            r = (t0.ar * actx.ring() + t0.er * cols + x[0]) % K
            q = (t0.ap * actx.pos() + t0.ep * cols + x[1]) % S
            return actx.mask_row(actx.member(r * S + q).astype(jnp.int32))

        return xs, fn
    if isinstance(t0, prg.AtDevices):
        # Identical across the run (the uniform-run key pins the whole
        # table): evaluate once, constant through the scan.
        row = _row_ids(t0, actx, idx)
        return jnp.zeros((len(tables),), jnp.int32), lambda x: row
    raise TypeError(f"unstackable table type {type(t0).__name__}")


def _scan_run(buf, out, shards, axis_name, idx, run, wire=None, actx=None):
    """Rolled execution of a uniform step run: per-step addressing
    stacks into the scan's ``xs`` — dense tables as pre-gathered rows,
    symbolic tables as scalar offsets decoded in the body — so the
    compiled HLO (and on canonical rings, its constant footprint) is
    independent of the run length."""
    s0 = run[0]
    T = len(run)
    zeros_T = jnp.zeros((T,), jnp.int32)

    add_fn = None
    add_xs = zeros_T
    if s0.add_src is not None:
        add_xs, add_fn = _stacked_rows([s.add_src for s in run], actx, idx)

    # Write modes: "one" (single indexed update: sparse dense tables,
    # Diag, width-1 symbolic), "dense" (width loop), "rows" (vector
    # scatter), or None.
    write_mode = None
    write_xs = zeros_T
    write_fn = None
    if s0.write is not None:
        w0 = s0.write
        if isinstance(w0, tuple):
            sparse_all = [_sparse_write(s.write) for s in run]
            if all(sp is not None for sp in sparse_all):
                write_mode = "one"
                rows_xs = jnp.asarray([sp[0] for sp in sparse_all])[:, idx]
                slots_xs = jnp.asarray([sp[1] for sp in sparse_all])[:, idx]
                write_xs = jnp.stack([rows_xs, slots_xs], axis=1)
                write_fn = lambda x: (x[0], x[1])  # noqa: E731
            else:
                write_mode = "dense"
                write_xs = jnp.asarray([s.write for s in run])[:, idx]
        elif isinstance(w0, prg.Diag):
            write_mode = "one"
            write_xs, inner_fn = _stacked_rows(
                [s.write.inner for s in run], actx, idx
            )
            write_fn = lambda x: (idx, inner_fn(x)[0])  # noqa: E731
        else:
            xs, fn = _stacked_rows([s.write for s in run], actx, idx)
            write_xs = xs
            if prg.table_width(w0) == 1:
                write_mode = "one"
                write_fn = lambda x: (jnp.int32(0), fn(x)[0])  # noqa: E731
            else:
                write_mode = "rows"
                write_fn = fn

    def body(carry, xs):
        buf, out = carry
        add_t, write_t = xs
        buf = _hop(buf, axis_name, s0.edges, idx, wire)
        if s0.combine == prg.ADD:
            src = shards if s0.add_from == "input" else out
            row = add_fn(add_t)
            safe = jnp.clip(row, 0, src.shape[0] - 1)
            rows = src[safe]
            mask = (row >= 0).reshape((-1,) + (1,) * (src.ndim - 1))
            buf = buf + jnp.where(mask, rows, jnp.zeros_like(rows))
        if write_mode == "one":
            row_t, slot_t = write_fn(write_t)
            out = _write_one(buf, out, row_t, slot_t, s0.write_op)
        elif write_mode == "dense":
            out = _write_dense(buf, out, write_t, s0.width, s0.write_op)
        elif write_mode == "rows":
            out = _write_rows(buf, out, write_fn(write_t), s0.write_op)
        return (buf, out), None

    (buf, out), _ = lax.scan(body, (buf, out), (add_xs, write_xs))
    return buf, out


def _run_stepped(shards: jax.Array, axis_name: Axis, prog: ChainProgram) -> jax.Array:
    """Interpret a program over pre-blocked input ``shards``
    (``(addr_shards, m, ...)`` per device); returns the
    ``(out_slots, m, ...)`` output slots.

    Uniform step runs (same edges/structure, different tables — the
    RS/AG/rotation/cross phases of the ring collectives) execute as one
    rolled ``lax.scan`` each, so compiled HLO size stays independent of
    the ring length; ``set_static_unroll(True)`` (the dry-run's
    HLO-byte-parsing mode) unrolls every step into its own ppermute.
    """
    idx = _axis_index(axis_name)
    wires = [prog.step_wire_dtype(s) for s in prog.steps]
    orig_dtype = shards.dtype
    if any(w is not None for w in wires):
        # The compressed wire accumulates in f32 (quantize/dequantize
        # are f32 numerics); integer payloads cannot round-trip.
        if not jnp.issubdtype(shards.dtype, jnp.floating):
            raise ValueError(
                f"wire_dtype='int8' requires a floating payload, "
                f"got {shards.dtype}"
            )
        shards = shards.astype(jnp.float32)
    actx = _AddrCtx(prog, idx)
    buf = _rows_from(prog.buf_init, idx, shards, actx=actx)
    out = _rows_from(prog.out_init, idx, shards, actx=actx)
    for wire, run in _uniform_runs(prog.steps, wires):
        if len(run) == 1 or _STATIC_UNROLL:
            for step in run:
                buf, out = _one_step(
                    buf, out, shards, axis_name, idx, step, wire, actx
                )
        else:
            buf, out = _scan_run(
                buf, out, shards, axis_name, idx, run, wire, actx
            )
    return out.astype(orig_dtype)


def _execute_pipeline(
    x: jax.Array, axis_name: Axis, prog: ChainProgram, num_frames: int
) -> jax.Array:
    """Broadcast-kind programs: the stepped interpreter for a single
    frame, or the store-and-forward frame-pipelined scan (all chains'
    edges applied every slot; one scan step per frame-hop slot,
    F + L - 2 total)."""
    if num_frames <= 1 or not prog.steps:
        return _run_stepped(x[None], axis_name, prog)[0]

    if x.shape[0] % num_frames != 0:
        raise ValueError(
            f"leading dim {x.shape[0]} not divisible by num_frames={num_frames}"
        )
    head = int(prog.head)
    idx = _axis_index(axis_name)
    is_head = idx == head
    x = jnp.where(is_head, x, jnp.zeros_like(x))
    frames = x.reshape((num_frames, x.shape[0] // num_frames) + x.shape[1:])

    # Static per-device chain position: 0 = head, p >= 1 = receiver of
    # step p-1 (its chain depth), max_len = non-member (out of range).
    max_len = len(prog.steps) + 1
    pos_np = [max_len] * prog.num_devices
    pos_np[head] = 0
    for t, step in enumerate(prog.steps):
        for _, dst in step.edges:
            pos_np[dst] = t + 1
    pos = jnp.asarray(pos_np)[idx]
    member = pos < max_len
    all_edges = [e for step in prog.steps for e in step.edges]
    T = num_frames + max_len - 2  # scan steps (longest chain's fill)

    def step(carry, t):
        buf, out = carry
        t_clamped = jnp.minimum(t, num_frames - 1)
        inject = lax.dynamic_index_in_dim(frames, t_clamped, axis=0, keepdims=False)
        buf = jnp.where(is_head & (t < num_frames), inject, buf)
        buf = _fanout(buf, axis_name, all_edges, idx)
        # After hop t, the member at chain position p holds frame t-(p-1).
        fidx = t - (pos - 1)
        valid = member & (pos > 0) & (fidx >= 0) & (fidx < num_frames)
        fidx_c = jnp.clip(fidx, 0, num_frames - 1)
        current = lax.dynamic_index_in_dim(out, fidx_c, axis=0, keepdims=False)
        new = jnp.where(valid, buf, current)
        out = lax.dynamic_update_index_in_dim(out, new, fidx_c, axis=0)
        return (buf, out), None

    buf0 = jnp.zeros_like(frames[0])
    out0 = jnp.where(is_head, frames, jnp.zeros_like(frames))
    (_, out), _ = _scan(step, (buf0, out0), jnp.arange(T))
    return out.reshape(x.shape)


def execute_program(
    x: jax.Array,
    axis_name: Axis,
    prog: ChainProgram,
    *,
    num_frames: int = 1,
    tiled: bool = False,
) -> jax.Array:
    """Run a :class:`ChainProgram` inside ``shard_map``.

    Handles the per-collective input blocking / output assembly around
    the one generic interpreter: ``broadcast`` takes/returns the whole
    payload (``num_frames`` pipelines it); ``all_gather`` stacks (or,
    ``tiled``, concatenates) device-id-indexed shards;
    ``reduce_scatter``/``all_to_all`` take ``(L, ...)`` chunk trains;
    ``all_reduce`` zero-pads the leading dim to the program's shard
    count and unpads on the way out.
    """
    L = prog.num_devices
    if _axis_size(axis_name) != L:
        raise ValueError(
            f"program planned for {L} devices, axis has {_axis_size(axis_name)}"
        )
    c = prog.collective
    if c == "broadcast":
        return _execute_pipeline(x, axis_name, prog, num_frames)
    if c == "all_gather":
        out = _run_stepped(x[None], axis_name, prog)
        if tiled:
            out = out.reshape((L * x.shape[0],) + x.shape[1:])
        return out
    if c in ("reduce_scatter", "all_to_all"):
        if x.shape[0] != L:
            raise ValueError(f"leading dim {x.shape[0]} != axis size {L}")
        out = _run_stepped(x, axis_name, prog)
        return out[0] if c == "reduce_scatter" else out
    if c == "all_reduce":
        S = prog.addr_shards
        lead = x.shape[0]
        pad = (-lead) % S
        xp = jnp.pad(x, [(0, pad)] + [(0, 0)] * (x.ndim - 1)) if pad else x
        shards = xp.reshape((S, xp.shape[0] // S) + x.shape[1:])
        out = _run_stepped(shards, axis_name, prog)
        if prog.out_slots == 1:  # rotation: whole payload in one slot
            full = out[0]
        else:
            full = out.reshape((out.shape[0] * out.shape[1],) + x.shape[1:])
        return full[:lead] if pad else full
    raise ValueError(f"unknown collective {c!r}")


# ---------------------------------------------------------------------------
# P2MP broadcast (the paper's core operation)
# ---------------------------------------------------------------------------


def chain_broadcast(
    x: jax.Array,
    axis_name: Axis,
    order: Sequence[int],
    *,
    num_frames: int = 1,
) -> jax.Array:
    """Multicast ``x`` from device ``order[0]`` to every device in
    ``order`` by store-and-forward chaining (paper §III-A/§III-C).

    ``x`` must be materialized on the chain head (other devices pass a
    same-shaped array whose value is ignored). Devices on the axis that
    are not in ``order`` return zeros. With ``num_frames > 1`` the
    payload's leading dimension is sliced into frames that pipeline
    through the chain — one scan step per frame-hop slot, F + L - 2
    steps total.
    """
    order = tuple(int(o) for o in order)
    if len(order) == 0:
        raise ValueError("empty chain")
    prog = prg.plan_broadcast(
        _axis_size(axis_name), order[0], (order[1:],) if len(order) > 1 else ()
    )
    return execute_program(x, axis_name, prog, num_frames=num_frames)


def _validate_multi_chains(
    head: int, chains: Sequence[Sequence[int]]
) -> tuple[tuple[int, ...], ...]:
    clean = prg.validate_chains(head, chains)
    if not clean:
        raise ValueError("empty chain set")
    return clean


def multi_chain_broadcast(
    x: jax.Array,
    axis_name: Axis,
    head: int,
    chains: Sequence[Sequence[int]],
    *,
    num_frames: int = 1,
) -> jax.Array:
    """Multicast ``x`` from device ``head`` down K disjoint sub-chains
    concurrently (multi-chain Chainwrite; chains typically come from
    ``scheduling.partition_schedule``).

    ``chains`` are destination orders (head excluded, matching the
    scheduler convention); they must be pairwise disjoint. Devices on
    the axis in no chain return zeros, chain members (and the head)
    return the head's payload. ``num_frames > 1`` pipelines frames down
    every chain simultaneously; completion takes
    ``num_frames + max_chain_len - 1`` frame-hop slots instead of
    ``num_frames * max_chain_len``.

    K=1 computes exactly ``chain_broadcast(x, axis, (head, *chains[0]))``
    (they interpret the identical program).
    """
    chains = _validate_multi_chains(int(head), chains)
    prog = prg.plan_broadcast(_axis_size(axis_name), int(head), chains)
    return execute_program(x, axis_name, prog, num_frames=num_frames)


def degraded_chains(
    chains: Sequence[Sequence[int]], failed: FailureSpec
) -> list[tuple[int, ...]]:
    """Splice the ``failed`` member(s) out of their sub-chains
    (endpoint-only re-forming at the SPMD layer: no topology knowledge,
    relative order kept). ``failed`` is one node id or a set of
    concurrently dead members.

    Host-side callers that hold a :class:`~repro.core.topology.
    MeshTopology` should prefer ``scheduling.reform_chain`` per chain —
    it re-orders the orphaned suffix — and pass the result straight to
    :func:`multi_chain_broadcast`; this helper is the schedule-free
    fallback. Chains emptied by the splice are dropped.
    """
    dead = set(normalize_failed(failed))
    members = {int(d) for c in chains for d in c}
    missing = sorted(dead - members)
    if missing:
        raise ValueError(f"failed node(s) {missing} are in no chain")
    out: list[tuple[int, ...]] = []
    for c in chains:
        kept = tuple(int(d) for d in c if int(d) not in dead)
        if kept:
            out.append(kept)
    return out


def degraded_multi_chain_broadcast(
    x: jax.Array,
    axis_name: Axis,
    head: int,
    chains: Sequence[Sequence[int]],
    failed: FailureSpec,
    *,
    num_frames: int = 1,
) -> jax.Array:
    """:func:`multi_chain_broadcast` with the chain member(s) ``failed``
    (one node id or a set of concurrently dead members) dropped — the
    degraded collective a re-formed Chainwrite runs after node
    failures.

    Every *surviving* chain member (and the head) still receives the
    head's payload; the failed devices — like any non-member — return
    zeros, so the paper's "nothing outside the chain is touched"
    property extends to dead nodes. K=1 with the failure in the middle
    of the single chain degrades to the spliced shorter chain.
    """
    head = int(head)
    if head in set(normalize_failed(failed)):
        raise ValueError("the initiator (head) cannot be dropped")
    remaining = degraded_chains(chains, failed)
    if not remaining:  # every destination failed: head keeps its payload
        prog = prg.plan_broadcast(_axis_size(axis_name), head, ())
        return execute_program(x, axis_name, prog, num_frames=num_frames)
    return multi_chain_broadcast(
        x, axis_name, head, remaining, num_frames=num_frames
    )


# ---------------------------------------------------------------------------
# Ring collectives over a scheduled order
# ---------------------------------------------------------------------------


def _ring_args(
    axis_name: Axis, order: Sequence[int] | None
) -> tuple[int, tuple[int, ...]]:
    L = _axis_size(axis_name)
    order = tuple(range(L)) if order is None else tuple(int(o) for o in order)
    if sorted(order) != list(range(L)):
        raise ValueError("ring order must be a permutation of the whole axis")
    return L, order


def _ring_partition(
    axis_name: Axis, orders: Sequence[Sequence[int]]
) -> tuple[int, tuple[tuple[int, ...], ...]]:
    L = _axis_size(axis_name)
    return L, tuple(validate_ring_partition(L, orders))


def chain_all_gather(
    x: jax.Array,
    axis_name: Axis,
    order: Sequence[int] | None = None,
    *,
    tiled: bool = False,
) -> jax.Array:
    """Ring all-gather over a scheduled ring order.

    Every device contributes ``x``; returns the stacked (axis 0) —
    or, with ``tiled=True``, concatenated — shards indexed by *device
    id along the axis* (standard all_gather semantics, so this is a
    drop-in for ``lax.all_gather`` regardless of ring order).
    """
    L, order = _ring_args(axis_name, order)
    prog = prg.plan_all_gather(L, (order,))
    return execute_program(x, axis_name, prog, tiled=tiled)


def multi_chain_all_gather(
    x: jax.Array,
    axis_name: Axis,
    orders: Sequence[Sequence[int]],
    *,
    tiled: bool = False,
) -> jax.Array:
    """All-gather over K disjoint equal-size sub-rings: per-ring
    all-gather (S-1 fused 1-shard steps), then a cross-ring exchange of
    the gathered ring blocks (K-1 width-S steps) — (S-1) + (K-1)·S =
    L-1 shards of wire per device, exactly the single ring's, with
    every hop ring-local or position-paired. K=1 delegates to
    :func:`chain_all_gather`'s schedule."""
    L, orders = _ring_partition(axis_name, orders)
    prog = prg.plan_all_gather(L, orders)
    return execute_program(x, axis_name, prog, tiled=tiled)


def chain_reduce_scatter(
    x: jax.Array,
    axis_name: Axis,
    order: Sequence[int] | None = None,
) -> jax.Array:
    """Ring reduce-scatter over a scheduled ring order.

    ``x`` has leading dim L (one chunk per device id along the axis);
    returns the fully-reduced chunk owned by this device
    (``sum_over_devices(x)[my_id]``).
    """
    L, order = _ring_args(axis_name, order)
    prog = prg.plan_reduce_scatter(L, (order,))
    return execute_program(x, axis_name, prog)


def multi_chain_reduce_scatter(
    x: jax.Array,
    axis_name: Axis,
    orders: Sequence[Sequence[int]],
) -> jax.Array:
    """Reduce-scatter over K disjoint equal-size sub-rings: per-ring
    reduce-scatter of width-K chunk *groups* (S-1 steps), then a
    cross-ring reduce-scatter of each group (K-1 single-chunk steps) —
    (S-1)·K + (K-1) = L-1 chunks of wire per device, matching the
    single ring. K=1 delegates to :func:`chain_reduce_scatter`'s
    schedule."""
    L, orders = _ring_partition(axis_name, orders)
    prog = prg.plan_reduce_scatter(L, orders)
    return execute_program(x, axis_name, prog)


def chain_all_reduce(
    x: jax.Array,
    axis_name: Axis,
    order: Sequence[int] | None = None,
    *,
    wire_dtype: str | None = None,
) -> jax.Array:
    """Ring all-reduce = reduce-scatter + all-gather on the scheduled
    ring (bandwidth-optimal: 2·(L-1)/L of the payload per link).
    ``wire_dtype="int8"`` ships every hop quantized (per-hop int8 frame
    + f32 scale; f32 accumulation)."""
    L, order = _ring_args(axis_name, order)
    prog = prg.plan_all_reduce(L, (order,), wire_dtype=wire_dtype)
    return execute_program(x, axis_name, prog)


def multi_chain_all_reduce(
    x: jax.Array,
    axis_name: Axis,
    orders: Sequence[Sequence[int]],
    *,
    algo: str = "rs_ag",
    wire_dtype: str | None = None,
) -> jax.Array:
    """All-reduce over K disjoint equal-size sub-rings of the axis.

    ``algo="rs_ag"`` (default — bandwidth-optimal family): stage 1 is a
    fused per-ring reduce-scatter (S-1 steps; the K rings' edges are
    disjoint, so every step is ONE ppermute carrying 1/S-payload
    shards), stage 2 rotation-reduces the reduced *shards* across rings
    (K-1 steps, still 1/S payload: position r of ring c exchanges with
    position r of ring c+1), stage 3 is the fused per-ring all-gather
    (S-1 steps). Wire bytes per device ≈ (2·(S-1)+(K-1))/S · payload —
    at K=1 exactly ``chain_all_reduce``'s bandwidth-optimal
    2·(L-1)/L — while the per-ring chain length stays S, keeping the
    multi-chain latency win.

    ``algo="rotation"`` keeps PR 1's schedule: S-1 full-payload
    rotations within rings then K-1 across — fewer steps (S+K-2 vs
    2·(S-1)+(K-1)) but (S+K-2) full payloads of wire per device;
    preferable only when per-step overhead dominates (tiny payloads).
    ``core.simulator.all_reduce_latency`` models both and
    ``choose_num_chains(collective="all_reduce")`` picks K/algo-aware.

    Hierarchical (within-pod then cross-pod) all-reduce is exactly the
    K=#pods special case of either schedule on the flattened DP axis.

    ``orders``: K disjoint rings of equal size covering the whole axis
    (e.g. contiguous slices of ``ring_order_for_axis``). K=1 delegates
    to :func:`chain_all_reduce` (reduce-scatter + all-gather) for
    either ``algo``.
    """
    if algo not in ALL_REDUCE_ALGOS:
        raise ValueError(f"unknown algo {algo!r}; expected {ALL_REDUCE_ALGOS}")
    L, orders = _ring_partition(axis_name, orders)
    prog = prg.plan_all_reduce(L, orders, algo, wire_dtype=wire_dtype)
    return execute_program(x, axis_name, prog)


def chain_all_to_all(
    x: jax.Array,
    axis_name: Axis,
    order: Sequence[int] | None = None,
    *,
    wire_dtype: str | None = None,
) -> jax.Array:
    """Ring all-to-all (MoE dispatch): ``x`` has leading dim L, chunk
    ``x[d]`` is destined to device ``d``. Returns stacked chunks
    received from every device (``out[s]`` = chunk sent by device s).

    Implemented as L-1 rotations of the scheduled ring: at each step
    every device forwards the not-yet-delivered chunks one hop and
    keeps the chunk addressed to it — each chunk travels exactly its
    ring distance, the chain analogue of per-pair P2P transfers.
    """
    L, order = _ring_args(axis_name, order)
    prog = prg.plan_all_to_all(L, (order,), wire_dtype=wire_dtype)
    return execute_program(x, axis_name, prog)


def multi_chain_all_to_all(
    x: jax.Array,
    axis_name: Axis,
    orders: Sequence[Sequence[int]],
    *,
    wire_dtype: str | None = None,
) -> jax.Array:
    """All-to-all over K disjoint equal-size sub-rings: intra-ring
    rotations interleaved with cross-ring hops (K·(S-1) + (K-1) = L-1
    full-train steps — a chunk train cannot shrink, so the wire bytes
    match the single ring; every hop is ring-local or position-paired).
    K=1 delegates to :func:`chain_all_to_all`'s schedule."""
    L, orders = _ring_partition(axis_name, orders)
    prog = prg.plan_all_to_all(L, orders, wire_dtype=wire_dtype)
    return execute_program(x, axis_name, prog)


# ---------------------------------------------------------------------------
# XLA-native baselines (the "network-layer multicast" analogue)
# ---------------------------------------------------------------------------


def xla_broadcast(x: jax.Array, axis_name: Axis, root: int = 0) -> jax.Array:
    """Broadcast via the fabric's native reduction (baseline)."""
    idx = _axis_index(axis_name)
    return lax.psum(jnp.where(idx == root, x, jnp.zeros_like(x)), axis_name)


def _axis_size(axis_name: Axis) -> int:
    if isinstance(axis_name, (tuple, list)):
        return int(
            functools.reduce(lambda a, n: a * lax.axis_size(n), axis_name, 1)
        )
    return int(lax.axis_size(axis_name))
