"""Cycle-level NoC model for the paper's evaluation (Fig. 5, 7, 9/10).

The paper measures latency with RTL/FPGA hardware counters; we cannot
synthesize RTL here, so this module is an analytical cycle model of the
same three P2MP mechanisms on the same NoC (2-D mesh, XY routing,
64 B/cycle links):

* ``unicast_latency``   — iDMA-style software P2MP: N sequential P2P
  copies, each re-reading the source (η_P2MP ≤ 1 by construction).
* ``multicast_latency`` — ESP-style network-layer multicast: one stream,
  routers replicate at branch points; setup cost grows superlinearly
  with N_dst (the paper's observed behaviour).
* ``chainwrite_latency`` — Torrent: four-phase orchestration
  (cfg dispatch ∥, grant ⇠, pipelined frame store-and-forward data ⇢,
  finish ⇠).
* ``multi_chain_latency`` — K concurrent Chainwrite chains from one
  initiator (``scheduling.partition_schedule``): per-chain four-phase
  latency with all chains' cfg packets serialized through the single
  cfg-inject port; completion = max over chains. Reduces exactly to
  ``chainwrite_latency`` at K=1. ``choose_num_chains`` picks K by
  argmin of this model.

Calibration: the model's per-destination marginal overhead for a
1-hop-spaced chain is **82 cycles**, matching the paper's measured
Fig. 7 slope; the split across phases (cfg/grant/fill/finish) is a
modeling choice documented on :class:`SimParams`.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

from .scheduling import SCHEDULERS, chain_total_hops, partition_schedule
from .topology import MeshTopology


@dataclasses.dataclass(frozen=True)
class SimParams:
    """NoC and Torrent timing constants (defaults = paper's system).

    The per-destination Chainwrite overhead decomposes as
    ``3*router_cc + cfg_inject_cc + grant_fwd_cc + finish_fwd_cc +
    sf_fill_cc = 3 + 4 + 16 + 16 + 43 = 82`` cycles for adjacent
    (1-hop) chain members — the Fig. 7 slope. The split between phases
    is not observable in the paper; only the sum is calibrated.
    """

    link_bw: int = 64  # bytes / cycle / link (paper system AXI BW)
    router_cc: int = 1  # per-hop router+wire latency (head flit)
    dma_setup_cc: int = 12  # local DSE start-up (all mechanisms)
    # Chainwrite four-phase constants:
    cfg_inject_cc: int = 4  # initiator serializes one cfg per member
    cfg_proc_cc: int = 24  # cfg decode at a member (once, parallel)
    grant_fwd_cc: int = 16  # per-node grant forward latency
    finish_fwd_cc: int = 16  # per-node finish forward latency
    sf_fill_cc: int = 43  # per-hop store-and-forward pipeline fill
    # ESP-style multicast setup model (superlinear in N_dst):
    mcast_setup_base_cc: int = 40
    mcast_setup_per_dst_cc: int = 6
    mcast_setup_quad_cc: float = 4.7  # grows faster than Torrent's linear


DEFAULT_PARAMS = SimParams()


# ---------------------------------------------------------------------------
# Latency models
# ---------------------------------------------------------------------------


def p2p_latency(
    topo: MeshTopology,
    src: int,
    dst: int,
    size_bytes: int,
    p: SimParams = DEFAULT_PARAMS,
) -> int:
    """One wormhole-pipelined P2P copy."""
    hops = topo.distance(src, dst)
    return p.dma_setup_cc + hops * p.router_cc + _ceil_div(size_bytes, p.link_bw)


def unicast_latency(
    topo: MeshTopology,
    src: int,
    dsts: Sequence[int],
    size_bytes: int,
    p: SimParams = DEFAULT_PARAMS,
) -> int:
    """iDMA software P2MP: sequential P2P copies (paper baseline)."""
    return sum(p2p_latency(topo, src, d, size_bytes, p) for d in dsts)


def multicast_latency(
    topo: MeshTopology,
    src: int,
    dsts: Sequence[int],
    size_bytes: int,
    p: SimParams = DEFAULT_PARAMS,
) -> int:
    """ESP-style network-layer multicast.

    One stream; replication in routers, all branches progress in
    parallel → data phase is bounded by the farthest destination.
    Setup grows superlinearly with N_dst (multicast route tables and VC
    allocation across the destination set).
    """
    n = len(dsts)
    setup = (
        p.dma_setup_cc
        + p.mcast_setup_base_cc
        + p.mcast_setup_per_dst_cc * n
        + int(p.mcast_setup_quad_cc * n * n)
    )
    far = max(topo.distance(src, d) for d in dsts)
    return setup + far * p.router_cc + _ceil_div(size_bytes, p.link_bw)


def chainwrite_latency(
    topo: MeshTopology,
    src: int,
    order: Sequence[int],
    size_bytes: int,
    p: SimParams = DEFAULT_PARAMS,
) -> int:
    """Torrent Chainwrite: four-phase orchestration latency.

    ``order`` is the scheduled destination traversal order (chain =
    src -> order[0] -> ... -> order[-1]).
    """
    if not order:
        return 0
    n = len(order)
    chain_hops = chain_total_hops(topo, order, src)

    # Phase 1 — cfg dispatch: initiator serializes one cfg packet per
    # member (cfg_inject each); packets race to members in parallel;
    # the chain is ready when the farthest member has decoded its cfg.
    far = max(topo.distance(src, d) for d in order)
    cfg = p.dma_setup_cc + n * p.cfg_inject_cc + far * p.router_cc + p.cfg_proc_cc

    # Phase 2 — grant: tail -> head along the chain.
    grant = chain_hops * p.router_cc + n * p.grant_fwd_cc

    # Phase 3 — data: one pipelined stream through the chain. The tail
    # sees the first byte after the pipeline fill (per-hop
    # store-and-forward fill + wire), then streams at link_bw.
    data = chain_hops * (p.router_cc + 0) + n * p.sf_fill_cc + _ceil_div(
        size_bytes, p.link_bw
    )

    # Phase 4 — finish: tail -> head again.
    finish = chain_hops * p.router_cc + n * p.finish_fwd_cc
    return cfg + grant + data + finish


def multi_chain_latency(
    topo: MeshTopology,
    src: int,
    chains: Sequence[Sequence[int]],
    size_bytes: int,
    p: SimParams = DEFAULT_PARAMS,
    *,
    detail: bool = False,
) -> int | dict[str, object]:
    """K concurrent four-phase Chainwrites sharing one cfg-inject port.

    Contention model (the only coupling between chains): the initiator
    has a single cfg-inject port, so the cfg packets of **all** chains
    serialize through it in chain order — chain ``c`` can only become
    ready once the cfgs of chains ``0..c`` have been injected. Data,
    grant and finish phases run concurrently per chain (the partitioner
    prefers link-disjoint XY paths, and the paper's XDMA dispatches
    independent engines per chain), so completion is the max over
    chains of their four-phase latency with the staggered cfg start.

    ``multi_chain_latency(topo, src, [order], size)`` reduces *exactly*
    to ``chainwrite_latency(topo, src, order, size)`` — pinned by the
    tier-1 regression tests together with the 82 CC/destination Fig. 7
    slope.

    With ``detail=True`` returns ``{"total", "per_chain",
    "per_phase"}`` where ``per_phase`` holds each chain's
    ``(cfg, grant, data, finish)`` split.
    """
    chains = [list(c) for c in chains if len(c)]
    if not chains:
        return {"total": 0, "per_chain": [], "per_phase": []} if detail else 0

    per_chain: list[int] = []
    per_phase: list[tuple[int, int, int, int]] = []
    injected = 0  # cfg packets already serialized through the port
    for order in chains:
        n = len(order)
        injected += n
        chain_hops = chain_total_hops(topo, order, src)
        far = max(topo.distance(src, d) for d in order)
        cfg = (
            p.dma_setup_cc
            + injected * p.cfg_inject_cc
            + far * p.router_cc
            + p.cfg_proc_cc
        )
        grant = chain_hops * p.router_cc + n * p.grant_fwd_cc
        data = (
            chain_hops * p.router_cc
            + n * p.sf_fill_cc
            + _ceil_div(size_bytes, p.link_bw)
        )
        finish = chain_hops * p.router_cc + n * p.finish_fwd_cc
        per_phase.append((cfg, grant, data, finish))
        per_chain.append(cfg + grant + data + finish)

    total = max(per_chain)
    if detail:
        return {"total": total, "per_chain": per_chain, "per_phase": per_phase}
    return total


def choose_num_chains(
    topo: MeshTopology,
    src: int,
    dsts: Sequence[int],
    size_bytes: int,
    *,
    max_chains: int = 4,
    scheduler: str = "tsp",
    p: SimParams = DEFAULT_PARAMS,
) -> tuple[int, list[list[int]]]:
    """Pick K (1..max_chains) minimizing the calibrated multi-chain
    latency; ties go to fewer chains. Returns ``(k, chains)``.

    Because K=1 is always a candidate and ``partition_schedule`` with
    ``num_chains=1`` reproduces the single-chain schedule exactly, the
    returned partition's latency never exceeds the K=1 schedule's.
    """
    dsts = list(dict.fromkeys(dsts))
    if not dsts:
        return 1, []
    chains = partition_schedule(
        topo, dsts, src,
        scheduler=scheduler,
        max_chains=max_chains,
        cost_fn=lambda cs: multi_chain_latency(topo, src, cs, size_bytes, p),
    )
    return len(chains), chains


# ---------------------------------------------------------------------------
# η_P2MP (paper Eq. 1) and the Fig. 5 sweep
# ---------------------------------------------------------------------------


def eta_p2mp(n_dst: int, size_bytes: int, latency_cc: int, p: SimParams = DEFAULT_PARAMS) -> float:
    """η_P2MP = N_dst * (Size/BW_ideal) / lat  (paper Eq. 1)."""
    return n_dst * (size_bytes / p.link_bw) / latency_cc


def p2mp_efficiency_point(
    topo: MeshTopology,
    src: int,
    dsts: Sequence[int],
    size_bytes: int,
    scheduler: str = "greedy",
    p: SimParams = DEFAULT_PARAMS,
) -> dict[str, float]:
    """One (size, N_dst) test point of the Fig. 5 sweep — all three
    mechanisms' η_P2MP."""
    n = len(dsts)
    order = SCHEDULERS[scheduler](topo, list(dsts), src)
    lat_uni = unicast_latency(topo, src, dsts, size_bytes, p)
    lat_mc = multicast_latency(topo, src, dsts, size_bytes, p)
    lat_cw = chainwrite_latency(topo, src, order, size_bytes, p)
    return {
        "n_dst": n,
        "size_bytes": size_bytes,
        "eta_unicast": eta_p2mp(n, size_bytes, lat_uni, p),
        "eta_multicast": eta_p2mp(n, size_bytes, lat_mc, p),
        "eta_chainwrite": eta_p2mp(n, size_bytes, lat_cw, p),
        "lat_unicast_cc": lat_uni,
        "lat_multicast_cc": lat_mc,
        "lat_chainwrite_cc": lat_cw,
    }


def config_overhead_per_destination(
    topo: MeshTopology,
    src: int = 0,
    size_bytes: int = 64 * 1024,
    max_dsts: int = 8,
    p: SimParams = DEFAULT_PARAMS,
) -> dict[str, object]:
    """Fig. 7 experiment: 64 KB Chainwrite to 1..max_dsts adjacent
    destinations; returns per-destination latencies and the fitted
    linear slope (paper: 82 CC/destination)."""
    lats = []
    for n in range(1, max_dsts + 1):
        dsts = list(range(src + 1, src + 1 + n))  # a row of adjacent nodes
        order = SCHEDULERS["greedy"](topo, dsts, src)
        lats.append(chainwrite_latency(topo, src, order, size_bytes, p))
    # least-squares slope over n = 1..max_dsts
    ns = list(range(1, max_dsts + 1))
    mean_n = sum(ns) / len(ns)
    mean_l = sum(lats) / len(lats)
    slope = sum((n - mean_n) * (l - mean_l) for n, l in zip(ns, lats)) / sum(
        (n - mean_n) ** 2 for n in ns
    )
    return {"latencies_cc": lats, "slope_cc_per_dst": slope}


def _ceil_div(a: int, b: int) -> int:
    return -(-a // b)
