"""Cycle-level NoC model for the paper's evaluation (Fig. 5, 7, 9/10).

The paper measures latency with RTL/FPGA hardware counters; we cannot
synthesize RTL here, so this module is an analytical cycle model of the
same three P2MP mechanisms on the same NoC (2-D mesh, XY routing,
64 B/cycle links):

* ``unicast_latency``   — iDMA-style software P2MP: N sequential P2P
  copies, each re-reading the source (η_P2MP ≤ 1 by construction).
* ``multicast_latency`` — ESP-style network-layer multicast: one stream,
  routers replicate at branch points; setup cost grows superlinearly
  with N_dst (the paper's observed behaviour).
* ``chainwrite_latency`` — Torrent: four-phase orchestration
  (cfg dispatch ∥, grant ⇠, pipelined frame store-and-forward data ⇢,
  finish ⇠).
* ``program_latency`` / ``program_wire_bytes`` — the generic models
  over the :mod:`repro.core.program` schedule IR: any
  :class:`ChainProgram` gets the staggered-cfg/grant/finish machinery
  (all groups' cfg packets serialize through the initiator's single
  cfg-inject port) with a kind-aware data phase — one pipelined
  store-and-forward stream per chain for ``kind="pipeline"``, the sum
  of per-step (slowest-edge hops + fill + frame/BW) rounds for
  ``kind="stepped"``. Every concrete model below is a thin
  ``plan_* -> program_latency`` wrapper.
* ``multi_chain_latency`` — K concurrent Chainwrite chains from one
  initiator (``scheduling.partition_schedule``): ``program_latency``
  of ``plan_broadcast``. Reduces exactly to ``chainwrite_latency`` at
  K=1. ``choose_num_chains`` picks K by argmin of this model.
* ``all_reduce_latency`` — algo-aware model of the K-sub-ring
  all-reduce schedules (``multi_chain_all_reduce``):
  ``program_latency`` of ``plan_all_reduce`` — full payloads for
  ``rotation``, 1/S shards for ``rs_ag`` — so
  ``choose_num_chains(collective="all_reduce")`` picks K from modeled
  bytes *and* cycles. ``choose_num_chains`` extends the same
  byte/latency model to ``reduce_scatter`` / ``all_gather`` /
  ``all_to_all`` via their planners.
* ``chain_recovery_latency`` — failure/recovery extension: one *or
  several* chain members die concurrently, the initiator times out
  (``fail_timeout_cc``), re-forms each orphaned suffix
  (``scheduling.reform_chain``) and re-dispatches the cfgs through the
  same single cfg-inject port; the data is re-sent from the last
  surviving upstream member (store-and-forward banked the payload
  there). The whole recovery schedule is a ``program.plan_recovery``
  ChainProgram priced by ``program_latency`` — recovery bytes appear
  in ``program_wire_bytes`` like any other collective's. Isolation
  invariant: chains without a failed member complete at *exactly*
  their ``multi_chain_latency`` per-chain time. A dead *initiator* is
  unrecoverable: :class:`SourceFailedError`.

Calibration: the model's per-destination marginal overhead for a
1-hop-spaced chain is **82 cycles**, matching the paper's measured
Fig. 7 slope; the split across phases (cfg/grant/fill/finish) is a
modeling choice documented on :class:`SimParams`.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

from . import program as prg
from .program import ALL_REDUCE_ALGOS, ChainProgram, program_wire_bytes
from .scheduling import (
    SCHEDULERS,
    FailureSpec,
    chain_total_cost,
    normalize_failed,
    partition_schedule,
)
from .topology import MeshTopology


class SourceFailedError(ValueError):
    """The failed node is the chain *initiator* — total loss, not a
    recoverable member failure. Endpoint-side re-forming cannot help
    (nobody upstream banked the payload, and the cfg port died with the
    source); callers must fall back to checkpoint rollback
    (``runtime.failure.resilient_loop`` does exactly that)."""


@dataclasses.dataclass(frozen=True)
class SimParams:
    """NoC and Torrent timing constants (defaults = paper's system).

    The per-destination Chainwrite overhead decomposes as
    ``3*router_cc + cfg_inject_cc + grant_fwd_cc + finish_fwd_cc +
    sf_fill_cc = 3 + 4 + 16 + 16 + 43 = 82`` cycles for adjacent
    (1-hop) chain members — the Fig. 7 slope. The split between phases
    is not observable in the paper; only the sum is calibrated.
    """

    link_bw: int = 64  # bytes / cycle / link (paper system AXI BW)
    router_cc: int = 1  # per-hop router+wire latency (head flit)
    dma_setup_cc: int = 12  # local DSE start-up (all mechanisms)
    # Chainwrite four-phase constants:
    cfg_inject_cc: int = 4  # initiator serializes one cfg per member
    cfg_proc_cc: int = 24  # cfg decode at a member (once, parallel)
    grant_fwd_cc: int = 16  # per-node grant forward latency
    finish_fwd_cc: int = 16  # per-node finish forward latency
    sf_fill_cc: int = 43  # per-hop store-and-forward pipeline fill
    # ESP-style multicast setup model (superlinear in N_dst):
    mcast_setup_base_cc: int = 40
    mcast_setup_per_dst_cc: int = 6
    mcast_setup_quad_cc: float = 4.7  # grows faster than Torrent's linear
    # Failure recovery: cycles the initiator waits for a missing finish
    # before declaring a chain member dead and re-forming around it.
    fail_timeout_cc: int = 512
    # Initiator memory-read bandwidth shared by K concurrent data
    # streams (bytes/cycle). None = no contention (each stream reads at
    # full link_bw), which keeps every pinned latency unchanged.
    src_read_bw: int | None = None


DEFAULT_PARAMS = SimParams()


# ---------------------------------------------------------------------------
# Latency models
# ---------------------------------------------------------------------------


def p2p_latency(
    topo: MeshTopology,
    src: int,
    dst: int,
    size_bytes: int,
    p: SimParams = DEFAULT_PARAMS,
) -> int:
    """One wormhole-pipelined P2P copy."""
    hops = topo.weighted_distance(src, dst)
    bw = max(1, min(p.link_bw, int(p.link_bw * topo.path_min_bw(src, dst))))
    return p.dma_setup_cc + hops * p.router_cc + _ceil_div(size_bytes, bw)


def unicast_latency(
    topo: MeshTopology,
    src: int,
    dsts: Sequence[int],
    size_bytes: int,
    p: SimParams = DEFAULT_PARAMS,
) -> int:
    """iDMA software P2MP: sequential P2P copies (paper baseline)."""
    return sum(p2p_latency(topo, src, d, size_bytes, p) for d in dsts)


def multicast_latency(
    topo: MeshTopology,
    src: int,
    dsts: Sequence[int],
    size_bytes: int,
    p: SimParams = DEFAULT_PARAMS,
) -> int:
    """ESP-style network-layer multicast.

    One stream; replication in routers, all branches progress in
    parallel → data phase is bounded by the farthest destination.
    Setup grows superlinearly with N_dst (multicast route tables and VC
    allocation across the destination set).
    """
    n = len(dsts)
    setup = (
        p.dma_setup_cc
        + p.mcast_setup_base_cc
        + p.mcast_setup_per_dst_cc * n
        + int(p.mcast_setup_quad_cc * n * n)
    )
    far = max(topo.weighted_distance(src, d) for d in dsts)
    bw = max(
        1,
        min(
            p.link_bw,
            int(p.link_bw * min(topo.path_min_bw(src, d) for d in dsts)),
        ),
    )
    return setup + far * p.router_cc + _ceil_div(size_bytes, bw)


def _effective_bw(p: SimParams, streams: int) -> int:
    """Per-stream source-read bandwidth with ``streams`` concurrent
    data streams sharing the initiator's memory port
    (``src_read_bw=None`` = no contention -> full link_bw)."""
    if p.src_read_bw is None or streams <= 1:
        return p.link_bw if p.src_read_bw is None else min(
            p.link_bw, p.src_read_bw
        )
    return max(1, min(p.link_bw, p.src_read_bw // streams))


def _cfg_phase(
    topo: MeshTopology,
    src: int,
    order: Sequence[int],
    p: SimParams,
    injected: int,
) -> int:
    """Cfg-dispatch phase shared by every chain-shaped schedule: the
    initiator serializes ``injected`` cfg packets through its single
    cfg-inject port; packets race to members in parallel; the chain is
    ready when the farthest (by weighted route latency) member has
    decoded its cfg."""
    far = max(topo.weighted_distance(src, d) for d in order)
    return (
        p.dma_setup_cc
        + injected * p.cfg_inject_cc
        + far * p.router_cc
        + p.cfg_proc_cc
    )


def _chain_phases(
    topo: MeshTopology,
    src: int,
    head: int,
    order: Sequence[int],
    size_bytes: int,
    p: SimParams,
    *,
    injected: int,
    streams: int = 1,
) -> tuple[int, int, int, int]:
    """Four-phase (cfg, grant, data, finish) split of one chain.

    ``src`` is the cfg initiator (owner of the single cfg-inject port:
    ``injected`` counts every cfg packet serialized through it up to
    and including this chain's); ``head`` is where the data stream
    enters the chain (= ``src`` normally, the last surviving upstream
    member during recovery). ``streams`` is the number of concurrent
    data streams sharing ``src_read_bw``.

    * cfg — initiator serializes ``injected`` cfg packets; packets race
      to members in parallel; the chain is ready when the farthest
      member has decoded its cfg.
    * grant / finish — tail -> head along the chain.
    * data — one pipelined stream through the chain: per-hop
      store-and-forward fill, then streaming at the effective
      bandwidth, bottlenecked by the slowest link on the chain's routes
      (``path_min_bw``; a no-op on a uniform topology).

    Hop terms are weighted link latencies (``chain_total_cost``), so a
    uniform mesh prices CC-identically to the pre-tiering model while a
    tiered topology charges slow inter-pod links honestly.
    """
    n = len(order)
    chain_hops = chain_total_cost(topo, order, head)
    cfg = _cfg_phase(topo, src, order, p, injected)
    grant = chain_hops * p.router_cc + n * p.grant_fwd_cc
    bw = _effective_bw(p, streams)
    frac = _chain_min_bw(topo, order, head)
    if frac < 1.0:
        bw = max(1, min(bw, int(p.link_bw * frac)))
    data = (
        chain_hops * p.router_cc
        + n * p.sf_fill_cc
        + _ceil_div(size_bytes, bw)
    )
    finish = chain_hops * p.router_cc + n * p.finish_fwd_cc
    return cfg, grant, data, finish


def _chain_min_bw(
    topo: MeshTopology, order: Sequence[int], head: int
) -> float:
    """Bottleneck link bandwidth fraction over the chain's routes."""
    frac = topo.path_min_bw(head, order[0])
    for a, b in zip(order, order[1:]):
        f = topo.path_min_bw(a, b)
        if f < frac:
            frac = f
    return frac


def chainwrite_latency(
    topo: MeshTopology,
    src: int,
    order: Sequence[int],
    size_bytes: int,
    p: SimParams = DEFAULT_PARAMS,
) -> int:
    """Torrent Chainwrite: four-phase orchestration latency.

    ``order`` is the scheduled destination traversal order (chain =
    src -> order[0] -> ... -> order[-1]).
    """
    if not order:
        return 0
    return sum(
        _chain_phases(
            topo, src, src, order, size_bytes, p, injected=len(order)
        )
    )


def program_latency(
    topo: MeshTopology,
    src: int,
    program: ChainProgram,
    size_bytes: int,
    p: SimParams = DEFAULT_PARAMS,
    *,
    detail: bool = False,
) -> int | dict[str, object]:
    """Four-phase latency of any :class:`ChainProgram` — the generic
    model every per-collective wrapper is a thin planner around.

    Shared machinery (``program.groups`` = the chains/rings): the
    initiator serializes every group's cfg packets through its single
    cfg-inject port in group order (group ``c`` becomes ready only
    after groups ``0..c``'s cfgs), and each group pays its own
    tail->head grant and finish forwarding. The data phase is
    kind-aware:

    * ``kind="pipeline"`` — one wormhole-pipelined store-and-forward
      stream per chain, entering at the group's data head
      (``program.group_heads``, default: the initiator) — chain hops +
      per-member fill + payload at the per-stream effective bandwidth:
      streams sharing one data head (e.g. K broadcast chains all read
      from the initiator) share its ``src_read_bw``;
    * ``kind="stepped"``  — the schedule's rounds run lockstep: each
      step costs its slowest edge's router hops + one
      store-and-forward fill + frame bytes (``width/addr_shards`` of
      the payload) over the link bandwidth; every device drives one
      outgoing stream at a time (``streams=1``).

    Edge-free ``tag="detect"`` steps (the failure-detection window of a
    recovery program) each charge ``p.fail_timeout_cc``, added to every
    group's completion — they move no bytes.

    Completion = max over groups of the staggered-cfg four-phase sum.
    With ``detail=True`` returns ``{"total", "per_chain", "per_phase",
    "detect_cc"}`` (plus the program's modeled ``wire_bytes``).
    """
    heads = program.group_heads or (src,) * len(program.groups)
    pairs = [
        (list(c), int(h))
        for c, h in zip(program.groups, heads)
        if len(c)
    ]
    detect = p.fail_timeout_cc * sum(
        1 for s in program.steps if s.tag == "detect"
    )
    empty = {
        "total": detect, "per_chain": [], "per_phase": [],
        "detect_cc": detect, "wire_bytes": 0,
    }
    if not pairs:
        return dict(empty) if detail else detect

    per_chain: list[int] = []
    per_phase: list[tuple[int, int, int, int]] = []
    injected = 0  # cfg packets already serialized through the port

    if program.kind == "pipeline":
        streams_per_head: dict[int, int] = {}
        for _, h in pairs:
            streams_per_head[h] = streams_per_head.get(h, 0) + 1
        for order, head in pairs:
            injected += len(order)
            phases = _chain_phases(
                topo, src, head, order, size_bytes, p,
                injected=injected, streams=streams_per_head[head],
            )
            per_phase.append(phases)
            per_chain.append(sum(phases) + detect)
    else:  # stepped: lockstep rounds, shared by every ring
        bw = _effective_bw(p, 1)  # one outgoing stream per device
        # Steps share their edge tuples (one intra + one cross list per
        # program), so the O(edges) worst-edge scan memoizes by identity
        # — 1024-ring pricing stays O(L), not O(L²). Each step pays its
        # slowest edge's weighted hop cost and streams its frame at the
        # step's bottleneck link bandwidth (uniform: full link_bw).
        costs_memo: dict[int, tuple[int, float]] = {}
        data = 0
        for step in program.steps:
            ec = costs_memo.get(id(step.edges))
            if ec is None:
                ec = _edge_costs(topo, step.edges)
                costs_memo[id(step.edges)] = ec
            eh, frac = ec
            sbw = (
                bw if frac >= 1.0
                else max(1, min(bw, int(p.link_bw * frac)))
            )
            data += (
                eh * p.router_cc
                + p.sf_fill_cc
                + _ceil_div(program.step_bytes(step, size_bytes), sbw)
            )
        for order, _ in pairs:
            injected += len(order)
            cfg = _cfg_phase(topo, src, order, p, injected)
            hops = _ring_hops(topo, order)
            grant = hops * p.router_cc + len(order) * p.grant_fwd_cc
            finish = hops * p.router_cc + len(order) * p.finish_fwd_cc
            per_phase.append((cfg, grant, data, finish))
            per_chain.append(cfg + grant + data + finish + detect)

    total = max(per_chain)
    if detail:
        return {
            "total": total,
            "per_chain": per_chain,
            "per_phase": per_phase,
            "detect_cc": detect,
            "wire_bytes": program.wire_bytes(size_bytes),
        }
    return total


def multi_chain_latency(
    topo: MeshTopology,
    src: int,
    chains: Sequence[Sequence[int]],
    size_bytes: int,
    p: SimParams = DEFAULT_PARAMS,
    *,
    detail: bool = False,
) -> int | dict[str, object]:
    """K concurrent four-phase Chainwrites sharing one cfg-inject port —
    ``program_latency`` of the broadcast program.

    Contention model (the only coupling between chains): the initiator
    has a single cfg-inject port, so the cfg packets of **all** chains
    serialize through it in chain order — chain ``c`` can only become
    ready once the cfgs of chains ``0..c`` have been injected. Data,
    grant and finish phases run concurrently per chain (the partitioner
    prefers link-disjoint XY paths, and the paper's XDMA dispatches
    independent engines per chain), so completion is the max over
    chains of their four-phase latency with the staggered cfg start.

    ``multi_chain_latency(topo, src, [order], size)`` reduces *exactly*
    to ``chainwrite_latency(topo, src, order, size)`` — pinned by the
    tier-1 regression tests together with the 82 CC/destination Fig. 7
    slope.

    With ``detail=True`` returns ``{"total", "per_chain",
    "per_phase"}`` where ``per_phase`` holds each chain's
    ``(cfg, grant, data, finish)`` split.
    """
    clean = tuple(tuple(int(d) for d in c) for c in chains if len(c))
    if not clean:
        return (
            {"total": 0, "per_chain": [], "per_phase": [], "wire_bytes": 0}
            if detail
            else 0
        )
    program = prg.plan_broadcast(topo.num_nodes, int(src), clean)
    return program_latency(topo, src, program, size_bytes, p, detail=detail)


def chain_recovery_latency(
    topo: MeshTopology,
    src: int,
    chains: Sequence[Sequence[int]],
    failed: FailureSpec,
    size_bytes: int,
    p: SimParams = DEFAULT_PARAMS,
    *,
    scheduler: str = "tsp",
    detail: bool = False,
) -> int | dict[str, object]:
    """Multi-chain completion latency when chain member(s) ``failed``
    die — one node id or a set of concurrently dead members.

    Since the recovery-as-a-program refactor this is a thin wrapper:
    the whole recovery schedule is planned once by
    :func:`repro.core.program.plan_recovery` (detection window +
    re-formed suffix per affected chain, streaming from the member
    that banked the payload) and priced by the generic
    :func:`program_latency` — so recovery bytes also appear in
    ``program_wire_bytes`` like any other collective's. Composition
    (all endpoint-side — recovery is just a new cfg dispatch, the NoC
    is untouched):

    1. **Detection** — the failed chains run their original four phases
       but the finishes never arrive; the initiator times out
       ``fail_timeout_cc`` after the expected completion (one shared
       window: concurrent failures are detected together).
    2. **Re-cfg dispatch** — each orphaned suffix is re-formed
       (``scheduling.reform_chain``: splice + TSP re-order from the
       surviving tail, torus-aware) and its cfg packets are serialized
       through the same single cfg-inject port — independent per-chain
       recoveries contend only there, exactly like the original
       chains' cfgs in :func:`multi_chain_latency`.
    3. **Re-sent frames** — grant/data/finish per re-formed suffix,
       streamed from the last surviving upstream member (which banked
       the payload during store-and-forward), or from the initiator
       when the failure hit the chain head.

    Isolation invariant (pinned by tests): every chain *without* a
    failed member completes at exactly its ``multi_chain_latency``
    per-chain time — failures never perturb other sub-chains. The
    initiator itself cannot be recovered: ``failed`` containing ``src``
    raises :class:`SourceFailedError` (total loss — roll back to a
    checkpoint instead of re-forming).

    With ``detail=True`` returns the ``multi_chain_latency`` detail
    dict extended with ``failed`` (the sorted failure set),
    ``recovery_wire_bytes`` (the planned program's modeled bytes) and
    a ``recoveries`` list, one entry per affected chain: ``{"chain",
    "failed", "reformed", "resent", "head", "detect_cc", "cfg_cc",
    "grant_cc", "data_cc", "finish_cc", "recovery_cc"}``. When exactly
    one chain is affected the entry is also exposed as ``recovery``
    (the pre-refactor single-failure shape).
    """
    chains = [list(c) for c in chains if len(c)]
    dead = normalize_failed(failed)
    if src in dead:
        raise SourceFailedError(
            f"node {src} is the chain initiator: total loss, "
            "re-forming cannot recover the source"
        )
    members = {d for c in chains for d in c}
    missing = [f for f in dead if f not in members]
    if missing:
        raise ValueError(f"failed node(s) {missing} are in no chain")

    base = multi_chain_latency(topo, src, chains, size_bytes, p, detail=True)
    assert isinstance(base, dict)

    program = prg.plan_recovery(
        topo, src, [tuple(c) for c in chains], dead, scheduler=scheduler
    )
    rec = program_latency(topo, src, program, size_bytes, p, detail=True)
    assert isinstance(rec, dict)

    per_chain = list(base["per_chain"])
    recoveries: list[dict[str, object]] = []
    gi = 0  # index into the program's (non-empty resent) groups
    for ci, order in enumerate(chains):
        chain_dead = [d for d in order if d in dead]
        if not chain_dead:
            continue
        # The geometry comes straight from the planned program (the
        # prefix before the earliest failure is kept verbatim; the
        # program's group is the re-scheduled resent suffix) — the
        # exact-TSP re-schedule runs once, inside plan_recovery.
        first = order.index(chain_dead[0])
        prefix = order[:first]
        orphaned = any(d not in dead for d in order[first + 1 :])
        if orphaned:
            resent = list(program.groups[gi])
            head = program.group_heads[gi]
            cfg, grant, data, finish = rec["per_phase"][gi]
            recovery_cc = rec["per_chain"][gi]  # includes the detection
            gi += 1
        else:  # tail failure: nothing downstream to re-send
            resent = []
            head = prefix[-1] if prefix else src
            cfg = grant = data = finish = 0
            recovery_cc = p.fail_timeout_cc
        reformed = prefix + resent
        per_chain[ci] += recovery_cc
        recoveries.append({
            "chain": ci,
            "failed": chain_dead,
            "reformed": reformed,
            "resent": resent,
            "head": head,
            "detect_cc": p.fail_timeout_cc,
            "cfg_cc": cfg,
            "grant_cc": grant,
            "data_cc": data,
            "finish_cc": finish,
            "recovery_cc": recovery_cc,
        })
    total = max(per_chain)
    if detail:
        out: dict[str, object] = {
            "total": total,
            "per_chain": per_chain,
            "per_phase": list(base["per_phase"]),
            "failed": dead,
            "recoveries": recoveries,
            "recovery_wire_bytes": program.wire_bytes(size_bytes),
        }
        if len(recoveries) == 1:
            out["recovery"] = recoveries[0]
        return out
    return total


def _canonical_rings(ring_size: int, num_chains: int) -> tuple[tuple[int, ...], ...]:
    S, K = int(ring_size), int(num_chains)
    return tuple(
        tuple(range(c * S, (c + 1) * S)) for c in range(K)
    )


def all_reduce_wire_bytes(
    ring_size: int, num_chains: int, size_bytes: int, algo: str = "rs_ag",
    wire_dtype: str | None = None,
) -> int:
    """Per-device wire bytes of the K-sub-ring all-reduce schedules
    (``chainwrite.multi_chain_all_reduce``): S = ``ring_size`` members
    per ring, K = ``num_chains`` rings — ``program_wire_bytes`` of the
    planned schedule (ring membership does not change byte counts, so
    canonical contiguous rings stand in):

    * ``rs_ag``:    (2·(S-1) + (K-1)) shard-sized frames, shard =
      ceil(payload / S) — ≈ (2·(S-1)+(K-1))/S · payload, the
      bandwidth-optimal family (K=1 gives 2·(L-1)/L exactly).
    * ``rotation``: (S + K - 2) full payloads.

    K=1 always delegates to the single-ring reduce-scatter +
    all-gather, so the ``rs_ag`` formula applies for either ``algo``.
    ``wire_dtype="int8"`` prices quarter-size frames plus the per-frame
    f32 scale sideband.
    """
    if algo not in ALL_REDUCE_ALGOS:
        raise ValueError(f"unknown algo {algo!r}; expected {ALL_REDUCE_ALGOS}")
    S, K = int(ring_size), int(num_chains)
    if S < 1 or K < 1:
        raise ValueError("ring_size and num_chains must be >= 1")
    program = prg.plan_all_reduce(
        S * K, _canonical_rings(S, K), algo, wire_dtype=wire_dtype
    )
    return program.wire_bytes(size_bytes)


def _ring_hops(topo: MeshTopology, order: Sequence[int]) -> int:
    """Total weighted link cost around the closed ring (incl. the wrap
    link) — plain hop count on a uniform topology."""
    if len(order) <= 1:
        return 0
    loop = list(order) + [order[0]]
    return sum(topo.weighted_distance(a, b) for a, b in zip(loop, loop[1:]))


def _edge_costs(topo: MeshTopology, edges) -> tuple[int, float]:
    """Per-step cost of one fused rotation: (slowest edge's weighted
    route cost, bottleneck link bandwidth fraction across the edges) —
    the step completes when its slowest edge lands."""
    max_w = 0
    min_bw = 1.0
    for a, b in edges:
        w = topo.weighted_distance(a, b)
        if w > max_w:
            max_w = w
        f = topo.path_min_bw(a, b)
        if f < min_bw:
            min_bw = f
    return max_w, min_bw


def all_reduce_latency(
    topo: MeshTopology,
    src: int,
    orders: Sequence[Sequence[int]],
    size_bytes: int,
    p: SimParams = DEFAULT_PARAMS,
    *,
    algo: str = "rs_ag",
    wire_dtype: str | None = None,
    detail: bool = False,
) -> int | dict[str, object]:
    """Analytical latency of the K-sub-ring all-reduce schedules —
    ``program_latency`` of ``plan_all_reduce``.

    Same cfg-port serialization as ``multi_chain_latency`` (the
    initiator injects one cfg per ring member, later rings start after
    earlier rings' cfgs) and the same per-chain grant/finish
    forwarding, with the algo-aware data phase coming straight from the
    planned schedule's steps:

    * ``rotation``:  (S-1) intra + (K-1) cross steps, each a
      full-payload fused ppermute;
    * ``rs_ag``:     2·(S-1) intra + (K-1) cross steps at shard size
      ceil(payload/S) — more steps, S× fewer bytes per step.

    Every step costs its slowest edge's router hops + one
    store-and-forward fill + frame_bytes / effective bandwidth
    (``_effective_bw``; each device drives one outgoing stream at a
    time, so ``streams=1``). Completion = max over rings of the
    staggered-cfg four-phase sum. K=1 reduces — CC-exactly, for either
    ``algo`` — to the single-ring reduce-scatter + all-gather model,
    mirroring ``multi_chain_all_reduce``'s K=1 delegation.

    With ``detail=True`` returns ``{"total", "per_chain", "per_phase",
    "algo", "wire_bytes"}``.
    """
    if algo not in ALL_REDUCE_ALGOS:
        raise ValueError(f"unknown algo {algo!r}; expected {ALL_REDUCE_ALGOS}")
    clean = tuple(tuple(int(d) for d in c) for c in orders if len(c))
    if not clean:
        return (
            {"total": 0, "per_chain": [], "per_phase": [],
             "algo": algo, "wire_bytes": 0}
            if detail
            else 0
        )
    if len(clean) == 1:
        algo = "rs_ag"  # the K=1 delegation path: single-ring RS+AG
    program = prg.plan_all_reduce(
        topo.num_nodes, clean, algo, wire_dtype=wire_dtype
    )
    out = program_latency(topo, src, program, size_bytes, p, detail=detail)
    if detail:
        assert isinstance(out, dict)
        out["algo"] = algo
    return out


def overlap_timeline(
    ready_cc: Sequence[int], comm_cc: Sequence[int]
) -> dict[str, object]:
    """Modeled compute/communication timeline of a bucketed,
    backward-overlapped step (the simulator's first whole-step price —
    everything before this models a lone collective).

    ``ready_cc[i]`` is when bucket i's last gradient leaf exists (its
    compute availability, in NoC cycles — cumulative backward-segment
    estimates from ``launch.roofline.bucket_ready_cc``), nondecreasing
    in dispatch (reverse-topological) order; ``comm_cc[i]`` is that
    bucket's chain all-reduce latency (``program_latency`` /
    ``all_reduce_latency``). Buckets serialize on the NoC — one cfg
    port, one outgoing stream per device — so bucket i starts at
    ``max(ready[i], finish[i-1])``:

    * ``overlap_cc``  — finish of the last bucket (modeled overlapped
      step time: comm runs behind the remaining backward);
    * ``serial_cc``   — ``ready[-1] + sum(comm)`` (the per-leaf status
      quo: every reduction waits for the whole backward);
    * ``hidden_cc``   — serial − overlapped = comm hidden behind compute;
    * ``efficiency``  — hidden / total comm (1.0 = fully hidden; 0.0 =
      nothing overlapped, e.g. a single bucket).
    """
    ready = [int(r) for r in ready_cc]
    comm = [int(c) for c in comm_cc]
    if len(ready) != len(comm):
        raise ValueError(
            f"{len(ready)} ready times for {len(comm)} comm latencies"
        )
    if any(r < 0 for r in ready) or any(c < 0 for c in comm):
        raise ValueError("ready/comm cycles must be non-negative")
    if any(a > b for a, b in zip(ready, ready[1:])):
        raise ValueError(
            "ready_cc must be nondecreasing (dispatch order = "
            "reverse-topological bucket order)"
        )
    start, finish = [], []
    t = 0
    for r, c in zip(ready, comm):
        t = max(r, t)
        start.append(t)
        t += c
        finish.append(t)
    compute_cc = ready[-1] if ready else 0
    overlap = max(compute_cc, finish[-1] if finish else 0)
    total_comm = sum(comm)
    serial = compute_cc + total_comm
    hidden = serial - overlap
    return {
        "overlap_cc": overlap,
        "serial_cc": serial,
        "hidden_cc": hidden,
        "comm_cc": total_comm,
        "compute_cc": compute_cc,
        "efficiency": (hidden / total_comm) if total_comm else 0.0,
        "start_cc": start,
        "finish_cc": finish,
    }


def choose_num_chains(
    topo: MeshTopology,
    src: int,
    dsts: Sequence[int],
    size_bytes: int,
    *,
    max_chains: int = 4,
    scheduler: str = "tsp",
    p: SimParams = DEFAULT_PARAMS,
    collective: str = "broadcast",
    algo: str = "rs_ag",
    wire_dtype: str | None = None,
    buckets: Sequence[tuple[int, int]] | None = None,
    detail: bool = False,
) -> tuple[int, list[list[int]]] | dict[str, object]:
    """Pick K (1..max_chains) minimizing the calibrated model; ties go
    to fewer chains. Returns ``(k, chains)``; with ``detail=True``
    returns ``{"num_chains", "rings", "algo", "wire_dtype",
    "latency_cc"}`` instead (the extra selected dimensions).

    ``collective="broadcast"`` (default) partitions ``dsts`` into K
    sub-chains scored by ``multi_chain_latency`` (PR 1 behaviour;
    ``algo`` is ignored). Because K=1 is always a candidate and
    ``partition_schedule`` with ``num_chains=1`` reproduces the
    single-chain schedule exactly, the returned partition's latency
    never exceeds the K=1 schedule's.

    Every ring collective — ``"all_reduce"``, ``"reduce_scatter"``,
    ``"all_gather"``, ``"all_to_all"`` — goes through the unified
    program model: schedule the closed ring ``src -> dsts`` (the same
    snake construction as ``parallel.collectives.ring_order_for_axis``),
    split it into every K ≤ max_chains that divides the group size, and
    score the candidate sub-ring sets with ``program_latency`` of that
    collective's planner — so K is chosen from modeled *bytes and
    cycles*. Returns the winning ``(k, sub_rings)``; K=1 is always a
    candidate, so the result never models worse than the single ring.
    On a tiered topology (``topo.num_pods > 1``) the pod-aligned split
    — one sub-ring per pod — joins the candidate set (scored first, so
    it wins ties), which is how hierarchical all-reduce becomes a
    planning outcome rather than a hand-set K=#pods special case.

    The all-reduce selection is JOINT over (K, algo, wire_dtype):
    ``algo="auto"`` scores both :data:`ALL_REDUCE_ALGOS` and
    ``wire_dtype="auto"`` scores the payload dtype against the int8
    wire (whose fixed f32-scale sideband makes tiny payloads prefer
    uncompressed frames). A concrete ``algo``/``wire_dtype`` pins that
    dimension. Ties keep the earlier candidate: fewer chains, then
    ``rs_ag``, then the uncompressed wire.

    ``buckets`` (``collective="all_reduce"`` only) switches to the
    bucket-aware STEP-time mode: a sequence of ``(ready_cc,
    size_bytes)`` per bucket in dispatch order, and every (K, algo,
    wire_dtype) candidate is scored by :func:`overlap_timeline`'s
    ``overlap_cc`` — the modeled overlapped step time over ALL buckets
    — instead of one collective's latency (``size_bytes`` is then
    ignored). ``detail=True`` adds ``step_cc`` and the winning
    ``timeline``.
    """
    if buckets is not None and collective != "all_reduce":
        raise ValueError(
            f'buckets= requires collective="all_reduce", got {collective!r}'
        )
    dsts = list(dict.fromkeys(dsts))
    if collective == "broadcast":
        if not dsts:
            return 1, []
        chains = partition_schedule(
            topo, dsts, src,
            scheduler=scheduler,
            max_chains=max_chains,
            cost_fn=lambda cs: multi_chain_latency(topo, src, cs, size_bytes, p),
        )
        if detail:
            return {
                "num_chains": len(chains), "rings": chains, "algo": None,
                "wire_dtype": None,
                "latency_cc": multi_chain_latency(topo, src, chains, size_bytes, p),
            }
        return len(chains), chains
    if collective not in RING_COLLECTIVES:
        raise ValueError(f"unknown collective {collective!r}")
    if collective == "all_reduce":
        algos = ALL_REDUCE_ALGOS if algo == "auto" else (algo,)
        for a in algos:
            if a not in ALL_REDUCE_ALGOS:
                raise ValueError(
                    f"unknown algo {a!r}; expected {ALL_REDUCE_ALGOS}"
                )
    else:
        algos = (algo,)
    if wire_dtype == "auto":
        wire_opts: tuple[str | None, ...] = (None, "int8")
    else:
        wire_opts = (prg.normalize_wire_dtype(wire_dtype),)
    if any(w is not None for w in wire_opts) and collective not in (
        "all_reduce", "all_to_all"
    ):
        raise ValueError(
            f"wire_dtype is not supported for collective={collective!r}"
        )

    if not dsts:
        if detail:
            return {"num_chains": 1, "rings": [[int(src)]], "algo": None,
                    "wire_dtype": None, "latency_cc": 0}
        return 1, [[int(src)]]
    ring = [int(src)] + [int(d) for d in SCHEDULERS[scheduler](topo, dsts, src)]
    n = len(ring)
    # Candidate sub-ring sets. On a tiered topology the POD-ALIGNED
    # split (one sub-ring per pod, members in scheduled-ring order) is
    # scored first: its intra steps stay inside pods and only the K-1
    # cross-ring exchanges touch the slow inter-pod links — so the
    # hierarchical intra-pod RS -> one inter-pod exchange per shard ->
    # intra-pod AG schedule *emerges* from the same argmin that picks K
    # on a flat mesh (and wins ties over equally-priced flat splits).
    candidates: list[tuple[int, list[list[int]]]] = []
    if topo.num_pods > 1:
        by_pod: dict[int, list[int]] = {}
        for m in ring:
            by_pod.setdefault(topo.pod_of(m), []).append(m)
        pod_rings = [by_pod[pid] for pid in sorted(by_pod)]
        if (
            1 < len(pod_rings) <= max_chains
            and len({len(r) for r in pod_rings}) == 1
        ):
            candidates.append((len(pod_rings), pod_rings))
    for k in range(1, max_chains + 1):
        if n % k:
            continue
        size = n // k
        candidates.append(
            (k, [ring[i * size : (i + 1) * size] for i in range(k)])
        )
    if topo.num_pods > 1:
        # The tier-blind twin's ring splits are candidates too — the
        # weighted argmin then runs over a SUPERSET of what a tier-blind
        # planner could pick, so the tier-aware choice is never slower
        # than the blind plan priced on the same links (pinned in
        # benchmarks/bench_collectives._tiered_metrics).
        flat = MeshTopology(topo.nx, topo.ny, topo.torus)
        blind_ring = [int(src)] + [
            int(d) for d in SCHEDULERS[scheduler](flat, dsts, src)
        ]
        if blind_ring != ring:
            for k in range(1, max_chains + 1):
                if n % k:
                    continue
                size = n // k
                candidates.append(
                    (k, [blind_ring[i * size : (i + 1) * size]
                         for i in range(k)])
                )
    best: tuple | None = None
    for k, rings in candidates:
        for a in algos:
            # ONE planned program per (K, algo) candidate; the wire
            # variants are O(1) field replacements sharing its steps
            # (the planner caches hold only the wire-free base).
            base = plan_ring_collective(
                collective, topo.num_nodes, rings, algo=a
            )
            for w in wire_opts:
                program = (
                    base if w is None else base.with_wire_dtype(w)
                )
                if buckets is not None:
                    comms = [
                        program_latency(topo, src, program, sb, p)
                        for _, sb in buckets
                    ]
                    tl = overlap_timeline([r for r, _ in buckets], comms)
                    lat = int(tl["overlap_cc"])
                else:
                    tl = None
                    lat = program_latency(topo, src, program, size_bytes, p)
                assert isinstance(lat, int)
                if best is None or lat < best[0]:
                    best = (lat, k, rings, a, w, tl)
    assert best is not None  # k=1 always divides
    if detail:
        out: dict[str, object] = {
            "num_chains": best[1], "rings": best[2],
            "algo": best[3] if collective == "all_reduce" else None,
            "wire_dtype": best[4], "latency_cc": best[0],
        }
        if buckets is not None:
            out["step_cc"] = best[0]
            out["timeline"] = best[5]
        return out
    return best[1], best[2]


RING_COLLECTIVES = ("all_reduce", "reduce_scatter", "all_gather", "all_to_all")


def plan_ring_collective(
    collective: str,
    num_devices: int,
    orders: Sequence[Sequence[int]],
    *,
    algo: str = "rs_ag",
    wire_dtype: str | None = None,
) -> ChainProgram:
    """Planner dispatch for the ring collectives (the unified seam
    ``choose_num_chains`` and the benchmarks score through)."""
    rings = tuple(tuple(int(d) for d in c) for c in orders if len(c))
    if collective == "all_reduce":
        return prg.plan_all_reduce(num_devices, rings, algo, wire_dtype=wire_dtype)
    if collective == "reduce_scatter":
        if wire_dtype is not None:
            raise ValueError("wire_dtype is not supported for reduce_scatter")
        return prg.plan_reduce_scatter(num_devices, rings)
    if collective == "all_gather":
        if wire_dtype is not None:
            raise ValueError("wire_dtype is not supported for all_gather")
        return prg.plan_all_gather(num_devices, rings)
    if collective == "all_to_all":
        return prg.plan_all_to_all(num_devices, rings, wire_dtype=wire_dtype)
    raise ValueError(f"unknown collective {collective!r}")


# ---------------------------------------------------------------------------
# η_P2MP (paper Eq. 1) and the Fig. 5 sweep
# ---------------------------------------------------------------------------


def eta_p2mp(n_dst: int, size_bytes: int, latency_cc: int, p: SimParams = DEFAULT_PARAMS) -> float:
    """η_P2MP = N_dst * (Size/BW_ideal) / lat  (paper Eq. 1)."""
    return n_dst * (size_bytes / p.link_bw) / latency_cc


def p2mp_efficiency_point(
    topo: MeshTopology,
    src: int,
    dsts: Sequence[int],
    size_bytes: int,
    scheduler: str = "greedy",
    p: SimParams = DEFAULT_PARAMS,
) -> dict[str, float]:
    """One (size, N_dst) test point of the Fig. 5 sweep — all three
    mechanisms' η_P2MP."""
    n = len(dsts)
    order = SCHEDULERS[scheduler](topo, list(dsts), src)
    lat_uni = unicast_latency(topo, src, dsts, size_bytes, p)
    lat_mc = multicast_latency(topo, src, dsts, size_bytes, p)
    lat_cw = chainwrite_latency(topo, src, order, size_bytes, p)
    return {
        "n_dst": n,
        "size_bytes": size_bytes,
        "eta_unicast": eta_p2mp(n, size_bytes, lat_uni, p),
        "eta_multicast": eta_p2mp(n, size_bytes, lat_mc, p),
        "eta_chainwrite": eta_p2mp(n, size_bytes, lat_cw, p),
        "lat_unicast_cc": lat_uni,
        "lat_multicast_cc": lat_mc,
        "lat_chainwrite_cc": lat_cw,
    }


def config_overhead_per_destination(
    topo: MeshTopology,
    src: int = 0,
    size_bytes: int = 64 * 1024,
    max_dsts: int = 8,
    p: SimParams = DEFAULT_PARAMS,
) -> dict[str, object]:
    """Fig. 7 experiment: 64 KB Chainwrite to 1..max_dsts adjacent
    destinations; returns per-destination latencies and the fitted
    linear slope (paper: 82 CC/destination)."""
    lats = []
    for n in range(1, max_dsts + 1):
        dsts = list(range(src + 1, src + 1 + n))  # a row of adjacent nodes
        order = SCHEDULERS["greedy"](topo, dsts, src)
        lats.append(chainwrite_latency(topo, src, order, size_bytes, p))
    # least-squares slope over n = 1..max_dsts
    ns = list(range(1, max_dsts + 1))
    mean_n = sum(ns) / len(ns)
    mean_l = sum(lats) / len(lats)
    slope = sum((n - mean_n) * (l - mean_l) for n, l in zip(ns, lats)) / sum(
        (n - mean_n) ** 2 for n in ns
    )
    return {"latencies_cc": lats, "slope_cc_per_dst": slope}


def _ceil_div(a: int, b: int) -> int:
    return -(-a // b)
