"""Chainwrite sequence scheduling (paper §III-D).

Chainwrite exposes the destination traversal order; the total number of
link traversals ("hops") of a P2MP task is the sum of XY-route lengths
between consecutive chain members. Two schedulers from the paper:

* :func:`greedy_schedule` — Alg. 1: iteratively pick the next
  destination whose XY path does not overlap already-used links and is
  shortest; fall back to the nearest remaining destination when no
  link-disjoint candidate exists. O(N^2 * path) — just-in-time.

* :func:`tsp_schedule` — open-path TSP on the XY-distance matrix.
  The paper uses OR-Tools; OR-Tools is unavailable offline so we ship
  our own solver: exact Held–Karp DP for small instances, and
  nearest-neighbour + 2-opt + Or-opt local search beyond that. The
  exact solver is the oracle for the heuristic in tests.

Both return the destination visit order (the source C0 is the implicit
chain head and is not part of the returned list), matching Alg. 1.

Beyond the paper: :func:`partition_schedule` splits one destination set
into K link-disjoint-preferring sub-chains that stream **concurrently**
from the initiator (multi-chain Chainwrite — the distributed-DMA
analogue of partition-based NoC multicast). A single logical chain pays
latency linear in its length; K balanced sub-chains cut the data/grant/
finish critical path to the longest sub-chain while the cfg packets of
all chains still serialize through the initiator's one cfg-inject port
(modelled in :func:`repro.core.simulator.multi_chain_latency`).

Partition heuristic (documented invariants relied on by tests):

1. **Seeding** — K seeds via farthest-point sampling over the
   destination set (first seed = destination closest to the source, as
   in Alg. 1), spreading chains into different mesh regions so their
   XY paths tend to be link-disjoint.
2. **Balanced growth** — remaining destinations are absorbed one at a
   time by the (chain, destination) pair that (a) prefers an XY path
   overlapping no link used by *any* chain so far and (b) minimizes the
   resulting chain's total hops — LPT-style balancing, so per-chain hop
   totals stay within one mesh diameter of each other before ordering.
3. **Re-ordering** — each sub-chain is finally re-ordered by the
   requested scheduler (exact TSP for <= 13 members) and the better of
   (grown order, re-scheduled order) is kept, so a sub-chain never
   costs more hops than the growth order produced.

Balance bound: every chain's hop total is at most
``chain_total_hops(single_schedule)/K + 2*(nx + ny)`` — the slack is
one diameter from LPT imbalance plus one diameter for the extra
source->seed entry edge.

Tier-awareness: all scoring goes through the weighted link-graph
contract (``topo.weighted_distance`` / ``topo.path_tier_crossings``,
see :mod:`.topology`), so on a uniform :class:`MeshTopology` every
ordering and cost reduces exactly to the hop-based behaviour above,
while on a :class:`~.topology.TieredMeshTopology` the growth step
penalizes routes over slow tier>0 (inter-pod) links first and
:func:`partition_schedule` additionally considers the **pod-aligned**
partition (one sub-chain per pod, so each chain crosses the inter-pod
boundary in at most one route segment).
"""

from __future__ import annotations

import itertools
from typing import Callable, Iterable, Sequence

from .topology import Coord, Link, MeshTopology

# The failure-spec convention every fault-tolerance API shares: one
# node id, or any iterable of ids (see normalize_failed).
FailureSpec = int | Iterable[int]

# ---------------------------------------------------------------------------
# Paper Alg. 1 — greedy link-disjoint heuristic
# ---------------------------------------------------------------------------


def greedy_schedule(
    topo: MeshTopology,
    destinations: Sequence[int],
    source: int = 0,
) -> list[int]:
    """Greedy Chainwrite ordering (paper Algorithm 1).

    Starts from the destination closest to the source, then repeatedly
    selects the candidate whose XY path from the current chain tail
    (a) does not overlap any previously used link and (b) has the
    fewest hops; when no overlap-free candidate exists, falls back to
    the nearest remaining destination.
    """
    if not destinations:
        return []
    remaining = list(dict.fromkeys(destinations))  # dedupe, keep order
    # Start from the destination closest to the source (paper: min(D),
    # "dest closest to C0" — C0 is node 0 at the origin; we use the
    # weighted XY distance, which on a uniform mesh coincides with the
    # hop count and hence min-ID on their layout).
    start = min(
        remaining, key=lambda d: (topo.weighted_distance(source, d), d)
    )
    order = [start]
    remaining.remove(start)
    used_path: set[Link] = set(topo.xy_path(source, start))

    while remaining:
        best: int | None = None
        best_cost: int | None = None  # Alg. 1's bound, weighted
        best_path: list[Link] = []
        tail = order[-1]
        for cand in remaining:
            path = topo.xy_path(tail, cand)
            if set(path) & used_path:
                continue
            w = topo.weighted_distance(tail, cand)
            if best_cost is None or w < best_cost:
                best, best_cost, best_path = cand, w, path
        if best is None:  # fallback: shortest path regardless of overlap
            best = min(
                remaining,
                key=lambda c: (topo.weighted_distance(tail, c), c),
            )
            best_path = topo.xy_path(tail, best)
        order.append(best)
        used_path.update(best_path)
        remaining.remove(best)
    return order


# ---------------------------------------------------------------------------
# Open-path TSP scheduler
# ---------------------------------------------------------------------------


def _pairwise_dist(
    topo: MeshTopology, nodes: Sequence[int]
) -> list[list[int]]:
    return [[topo.weighted_distance(a, b) for b in nodes] for a in nodes]


def _held_karp_open_path(dist: list[list[int]]) -> list[int]:
    """Exact open-path TSP from node 0 (the source) via DP.

    dist is (n+1)x(n+1) with index 0 = source. Returns visiting order of
    indices 1..n (0-based into dist). O(2^n * n^2); used for n <= 13.
    """
    n = len(dist) - 1
    if n == 0:
        return []
    FULL = 1 << n
    INF = float("inf")
    # dp[mask][j] = best cost to start at source, visit set `mask`,
    # ending at destination j (0-based in 0..n-1 -> dist index j+1).
    dp = [[INF] * n for _ in range(FULL)]
    parent: list[list[int]] = [[-1] * n for _ in range(FULL)]
    for j in range(n):
        dp[1 << j][j] = dist[0][j + 1]
    for mask in range(FULL):
        row = dp[mask]
        for j in range(n):
            cj = row[j]
            if cj == INF or not (mask >> j) & 1:
                continue
            dj = dist[j + 1]
            for k in range(n):
                if (mask >> k) & 1:
                    continue
                nmask = mask | (1 << k)
                nc = cj + dj[k + 1]
                if nc < dp[nmask][k]:
                    dp[nmask][k] = nc
                    parent[nmask][k] = j
    last = min(range(n), key=lambda j: dp[FULL - 1][j])
    order_rev = []
    mask, j = FULL - 1, last
    while j != -1:
        order_rev.append(j)
        pj = parent[mask][j]
        mask ^= 1 << j
        j = pj
    return order_rev[::-1]


def _path_cost(dist: list[list[int]], order: list[int]) -> int:
    cost = dist[0][order[0] + 1]
    for a, b in zip(order, order[1:]):
        cost += dist[a + 1][b + 1]
    return cost


def _nearest_neighbour(dist: list[list[int]]) -> list[int]:
    n = len(dist) - 1
    unvisited = set(range(n))
    order: list[int] = []
    cur = 0  # dist index of source
    while unvisited:
        nxt = min(unvisited, key=lambda j: (dist[cur][j + 1], j))
        order.append(nxt)
        unvisited.remove(nxt)
        cur = nxt + 1
    return order


def _two_opt(dist: list[list[int]], order: list[int], max_rounds: int = 60) -> list[int]:
    """2-opt + Or-opt (segment relocation, len 1-3) for the open path.

    Moves are evaluated with O(1) endpoint deltas (node 0 of ``dist`` is
    the fixed source; the path end is open), so a full improvement round
    is O(n^2) rather than O(n^3).
    """
    n = len(order)
    if n < 2:
        return list(order)
    # tour[0] = source sentinel (dist index 0); tour[i>0] = dist index of
    # the (i-1)-th visited destination.
    tour = [0] + [i + 1 for i in order]
    m = len(tour)  # m = n + 1

    def d(i: int, j: int) -> int:
        return dist[tour[i]][tour[j]]

    for _ in range(max_rounds):
        improved = False
        # 2-opt: reverse tour[i..j] for 1 <= i <= j <= m-1. Open path:
        # delta = d(i-1, j) - d(i-1, i) + (d(j, j+1) after - before if
        # j is not the last node).
        for i in range(1, m - 1):
            for j in range(i + 1, m):
                delta = d(i - 1, j) - d(i - 1, i)
                if j < m - 1:
                    delta += d(i, j + 1) - d(j, j + 1)
                if delta < 0:
                    tour[i : j + 1] = tour[i : j + 1][::-1]
                    improved = True
        # Or-opt: relocate segment tour[i..i+seg-1] to after position k.
        for seg in (1, 2, 3):
            i = 1
            while i + seg <= m:
                a, b = i - 1, i + seg  # neighbours of the segment
                # cost removed by excising the segment:
                gain = d(a, i) + (d(i + seg - 1, b) if b < m else 0)
                bridge = dist[tour[a]][tour[b]] if b < m else 0
                best_k, best_delta = -1, -1e-9
                for k in range(1, m):
                    if i - 1 <= k <= i + seg - 1:
                        continue  # overlaps/adjacent-left of segment
                    # insert segment between tour[k] and tour[k+1]
                    add = dist[tour[k]][tour[i]]
                    if k + 1 < m:
                        add += dist[tour[i + seg - 1]][tour[k + 1]]
                        add -= dist[tour[k]][tour[k + 1]]
                    delta = bridge + add - gain
                    if delta < best_delta:
                        best_k, best_delta = k, delta
                if best_k >= 0:
                    segment = tour[i : i + seg]
                    del tour[i : i + seg]
                    k = best_k if best_k < i else best_k - seg
                    tour[k + 1 : k + 1] = segment
                    improved = True
                else:
                    i += 1
        if not improved:
            break
    return [t - 1 for t in tour[1:]]


def tsp_schedule(
    topo: MeshTopology,
    destinations: Sequence[int],
    source: int = 0,
    exact_threshold: int = 13,
) -> list[int]:
    """Open-path TSP Chainwrite ordering (paper §III-D strategy 2).

    Exact (Held–Karp) for ≤ ``exact_threshold`` destinations, otherwise
    nearest-neighbour + 2-opt/Or-opt local search.
    """
    dests = list(dict.fromkeys(destinations))
    if not dests:
        return []
    nodes = [source] + dests
    dist = _pairwise_dist(topo, nodes)
    if len(dests) <= exact_threshold:
        idx_order = _held_karp_open_path(dist)
    else:
        idx_order = _two_opt(dist, _nearest_neighbour(dist))
    return [dests[i] for i in idx_order]


def naive_schedule(
    topo: MeshTopology, destinations: Sequence[int], source: int = 0
) -> list[int]:
    """Naive ordering by cluster ID (the paper's baseline in Fig. 6)."""
    del topo, source
    return sorted(dict.fromkeys(destinations))


SCHEDULERS: dict[str, Callable[..., list[int]]] = {
    "naive": naive_schedule,
    "greedy": greedy_schedule,
    "tsp": tsp_schedule,
}


# ---------------------------------------------------------------------------
# Hop accounting (paper Fig. 6 metric)
# ---------------------------------------------------------------------------


def chain_total_hops(
    topo: MeshTopology, order: Sequence[int], source: int = 0
) -> int:
    """Total link traversals of a Chainwrite visiting ``order``."""
    if not order:
        return 0
    hops = topo.distance(source, order[0])
    for a, b in zip(order, order[1:]):
        hops += topo.distance(a, b)
    return hops


def chain_total_cost(
    topo: MeshTopology, order: Sequence[int], source: int = 0
) -> int:
    """Weighted link-latency total of a Chainwrite visiting ``order``
    (== :func:`chain_total_hops` on a uniform mesh)."""
    if not order:
        return 0
    cost = topo.weighted_distance(source, order[0])
    for a, b in zip(order, order[1:]):
        cost += topo.weighted_distance(a, b)
    return cost


def chain_slow_links(
    topo: MeshTopology, order: Sequence[int], source: int = 0
) -> int:
    """Total tier>0 (inter-pod) links the chain's routes traverse."""
    if not order:
        return 0
    n = topo.path_tier_crossings(source, order[0])
    for a, b in zip(order, order[1:]):
        n += topo.path_tier_crossings(a, b)
    return n


def chain_tier_crossings(
    topo: MeshTopology, order: Sequence[int], source: int = 0
) -> int:
    """Number of consecutive-member route *segments* that traverse at
    least one tier>0 link — a chain that enters a remote pod once and
    stays there counts 1 even when the XY route to a diagonal pod
    happens to cross two boundary links."""
    if not order:
        return 0
    n = 1 if topo.path_tier_crossings(source, order[0]) else 0
    for a, b in zip(order, order[1:]):
        if topo.path_tier_crossings(a, b):
            n += 1
    return n


def partition_tier_crossings(
    topo: MeshTopology, chains: Sequence[Sequence[int]], source: int = 0
) -> list[int]:
    """Per-chain segment-level tier crossings of a partition."""
    return [chain_tier_crossings(topo, c, source) for c in chains]


def unicast_total_hops(
    topo: MeshTopology, destinations: Sequence[int], source: int = 0
) -> int:
    """Total link traversals of N independent unicasts (iDMA model)."""
    return sum(topo.distance(source, d) for d in destinations)


def multicast_total_hops(
    topo: MeshTopology, destinations: Sequence[int], source: int = 0
) -> int:
    """Link traversals of XY network-layer multicast (shared prefixes)."""
    return len(topo.multicast_tree_links(source, list(destinations)))


def brute_force_schedule(
    topo: MeshTopology, destinations: Sequence[int], source: int = 0
) -> list[int]:
    """Exhaustive optimal order — test oracle only (n <= 8)."""
    dests = list(dict.fromkeys(destinations))
    best = None
    best_cost = None
    for perm in itertools.permutations(dests):
        c = chain_total_hops(topo, perm, source)
        if best_cost is None or c < best_cost:
            best, best_cost = list(perm), c
    return best or []


# ---------------------------------------------------------------------------
# Multi-chain partitioning (beyond the paper — see module docstring)
# ---------------------------------------------------------------------------


def partition_balance_slack(topo: MeshTopology) -> int:
    """Additive hop slack of the partition balance bound (two mesh
    diameters — see module docstring)."""
    return 2 * (topo.nx + topo.ny)


def _farthest_point_seeds(
    topo: MeshTopology, dests: list[int], source: int, k: int
) -> list[int]:
    """K spread-out seeds; the first is Alg. 1's closest-to-source."""
    first = min(dests, key=lambda d: (topo.weighted_distance(source, d), d))
    seeds = [first]
    while len(seeds) < k:
        nxt = max(
            (d for d in dests if d not in seeds),
            key=lambda d: (
                min(topo.weighted_distance(d, s) for s in seeds),
                -d,
            ),
        )
        seeds.append(nxt)
    return seeds


def hop_proxy_cost(
    topo: MeshTopology, source: int, per_member_hops: float = 2.4
) -> Callable[[list[list[int]]], float]:
    """Hop-level stand-in for the simulator's multi-chain latency.

    ``per_member_hops`` mirrors the calibrated 82 CC/destination
    overhead expressed in units of the ~34 CC a 1-hop link traversal
    adds to the critical path of a 64 B-granular stream — close enough
    to rank K choices without importing the cycle model (which would be
    a circular import; :mod:`.simulator` builds the calibrated version
    on top via ``choose_num_chains``).
    """

    def cost(chains: list[list[int]]) -> float:
        total_members = sum(len(c) for c in chains)
        worst = max(
            chain_total_cost(topo, c, source) + per_member_hops * len(c)
            for c in chains
        )
        # cfg packets for every member serialize through one port.
        return worst + 0.12 * per_member_hops * total_members

    return cost


def partition_schedule(
    topo: MeshTopology,
    destinations: Sequence[int],
    source: int = 0,
    *,
    num_chains: int | None = None,
    scheduler: str = "tsp",
    max_chains: int = 4,
    cost_fn: Callable[[list[list[int]]], float] | None = None,
) -> list[list[int]]:
    """Split ``destinations`` into K concurrent Chainwrite sub-chains.

    ``num_chains`` fixes K; ``num_chains=None`` auto-selects K in
    ``1..max_chains`` by minimizing ``cost_fn(chains)`` (ties -> fewer
    chains). The default ``cost_fn`` is :func:`hop_proxy_cost`; pass
    the calibrated cycle model through
    :func:`repro.core.simulator.choose_num_chains` instead when the
    topology/size point matters. K=1 returns
    ``[SCHEDULERS[scheduler](...)]`` exactly.

    Returns a list of K destination orders (source excluded, as in the
    single-chain schedulers). Every destination appears in exactly one
    sub-chain.
    """
    dests = list(dict.fromkeys(destinations))
    if not dests:
        return []
    if num_chains is not None:
        return _partition_fixed_k(topo, dests, source, int(num_chains), scheduler)
    if cost_fn is None:
        cost_fn = hop_proxy_cost(topo, source)
    best: list[list[int]] | None = None
    best_cost: float | None = None
    for k in range(1, min(max_chains, len(dests)) + 1):
        chains = _partition_fixed_k(topo, dests, source, k, scheduler)
        c = cost_fn(chains)
        if best_cost is None or c < best_cost:
            best, best_cost = chains, c
    assert best is not None
    return best


def _pod_partition(
    topo: MeshTopology, dests: list[int], source: int, scheduler: str
) -> list[list[int]]:
    """Pod-aligned partition: one sub-chain per pod touched, each
    ordered by the requested scheduler. Every chain enters its pod on
    one route segment and stays there, so it crosses the slow inter-pod
    boundary at most once (``chain_tier_crossings <= 1``)."""
    by_pod: dict[int, list[int]] = {}
    for d in dests:
        by_pod.setdefault(topo.pod_of(d), []).append(d)
    return [
        SCHEDULERS[scheduler](topo, members, source)
        for _, members in sorted(by_pod.items())
    ]


def _partition_fixed_k(
    topo: MeshTopology,
    dests: list[int],
    source: int,
    k: int,
    scheduler: str,
) -> list[list[int]]:
    k = max(1, min(k, len(dests)))
    if k == 1:
        return [SCHEDULERS[scheduler](topo, dests, source)]

    seeds = _farthest_point_seeds(topo, dests, source, k)
    chains: list[list[int]] = [[s] for s in seeds]
    hops = [topo.weighted_distance(source, s) for s in seeds]
    used: set[Link] = set()
    for s in seeds:
        used.update(topo.xy_path(source, s))

    remaining = [d for d in dests if d not in seeds]
    while remaining:
        # Pick the globally best (chain, destination) extension:
        # link-disjoint first (paper Alg. 1's preference), then fewest
        # slow tier>0 links on the extension route, then the smallest
        # resulting weighted chain cost (LPT balancing). On a uniform
        # mesh the slow term is a constant 0 and the weighted costs are
        # hop counts, so the pre-tiering ordering is preserved exactly.
        best_key: tuple | None = None
        best_ci = -1
        best_d = -1
        best_w = 0
        best_path: list[Link] = []
        for ci, chain in enumerate(chains):
            tail = chain[-1]
            for d in remaining:
                path = topo.xy_path(tail, d)
                overlap = bool(set(path) & used)
                w = topo.weighted_distance(tail, d)
                slow = topo.path_tier_crossings(tail, d)
                key = (overlap, slow, hops[ci] + w, w, ci, d)
                if best_key is None or key < best_key:
                    best_key, best_ci, best_d = key, ci, d
                    best_w, best_path = w, path
        chains[best_ci].append(best_d)
        hops[best_ci] += best_w
        used.update(best_path)
        remaining.remove(best_d)

    # Re-order each sub-chain; keep the better of grown vs re-scheduled.
    out: list[list[int]] = []
    for chain in chains:
        rescheduled = SCHEDULERS[scheduler](topo, chain, source)
        if chain_total_cost(topo, rescheduled, source) <= chain_total_cost(
            topo, chain, source
        ):
            out.append(rescheduled)
        else:
            out.append(chain)

    # On a tiered topology, when K matches the number of pods touched,
    # the pod-aligned split (<= 1 boundary crossing per chain) often
    # beats region growth; keep whichever the weighted proxy prefers.
    if topo.num_pods > 1:
        pod_chains = _pod_partition(topo, dests, source, scheduler)
        if len(pod_chains) == k:
            cost = hop_proxy_cost(topo, source)
            if cost(pod_chains) <= cost(out):
                return pod_chains
    return out


def partition_total_hops(
    topo: MeshTopology, chains: Sequence[Sequence[int]], source: int = 0
) -> int:
    """Sum of per-chain hop totals (wire-energy metric; the latency
    metric is the simulator's ``multi_chain_latency``)."""
    return sum(chain_total_hops(topo, c, source) for c in chains)


# ---------------------------------------------------------------------------
# Chain re-forming (fault tolerance — endpoint-only recovery)
# ---------------------------------------------------------------------------


def normalize_failed(failed: FailureSpec) -> list[int]:
    """Canonicalize a failure spec (one node id or an iterable of ids)
    into a sorted duplicate-free list — the failure-*set* convention
    shared by ``reform_chain``, ``simulator.chain_recovery_latency``,
    ``chainwrite.degraded_chains`` and ``MultiChainPlan.reform``."""
    if isinstance(failed, (str, bytes)):
        raise ValueError(f"failed must be a node id or a set of ids, got {failed!r}")
    try:
        it = iter(failed)
    except TypeError:  # a single node id (python or numpy integer)
        return [int(failed)]
    nodes = sorted({int(f) for f in it})
    if not nodes:
        raise ValueError("empty failure set")
    return nodes


def reform_chain(
    topo: MeshTopology,
    order: Sequence[int],
    failed: FailureSpec,
    source: int = 0,
    *,
    scheduler: str = "tsp",
) -> list[int]:
    """Splice the ``failed`` member(s) out of one sub-chain and
    re-order the orphaned suffix — the endpoint-side half of Chainwrite
    fault recovery. ``failed`` is one node id or a set of concurrently
    dead members of this chain.

    Store-and-forward means every member *upstream* of the earliest
    failure has already banked the payload, so that prefix is kept
    verbatim and only the downstream (orphaned) survivors are
    re-planned: they are re-scheduled by the requested scheduler (exact
    TSP for <= 13 members) starting from the surviving chain tail (the
    last prefix member, or the source when a failure hit the chain
    head). The better of the spliced original order and the
    re-scheduled suffix is kept, so re-forming never costs more hops
    than the naive splice.

    All scoring goes through the weighted link-graph contract
    (:meth:`MeshTopology.weighted_distance`), so wrap-around links are
    exploited when ``topo.torus`` — the recovery path on a torus is
    never longer than on the equivalent mesh — and slow inter-pod links
    are avoided when the topology is tiered.

    Like XDMA's distributed-DMA re-configuration, this is purely an
    endpoint operation: the result is just a new cfg schedule for the
    survivors; nothing in the NoC changes.
    """
    order = [int(d) for d in order]
    dead = set(normalize_failed(failed))
    missing = dead - set(order)
    if missing:
        raise ValueError(
            f"failed node(s) {sorted(missing)} are not chain members"
        )
    i = min(order.index(f) for f in dead)
    prefix = order[:i]
    suffix = [d for d in order[i + 1 :] if d not in dead]
    if not suffix:
        return prefix
    tail = prefix[-1] if prefix else source
    rescheduled = SCHEDULERS[scheduler](topo, suffix, tail)
    if chain_total_cost(topo, rescheduled, tail) <= chain_total_cost(
        topo, suffix, tail
    ):
        return prefix + rescheduled
    return prefix + suffix
