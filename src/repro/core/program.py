"""ChainProgram: the single schedule IR behind every Torrent collective.

The paper's core claim is that every P2MP pattern is *just a schedule*
of P2P hops over an unmodified NoC. This module makes that literal: a
:class:`ChainProgram` is an ordered list of :class:`Step`\\ s, each step
a set of ``(src, dst)`` edges plus per-device shard-addressing tables,
generated once by the ``plan_*`` functions from a chain/ring partition.
Three interchangeable backends consume the same program:

* the SPMD executor (``chainwrite.execute_program`` — fused ppermutes),
* the numpy interpreter (``chainwrite_ref.interpret_program`` — the
  bit-exactness oracle),
* the cycle/byte models (``simulator.program_latency`` /
  ``simulator.program_wire_bytes``).

Machine model (identical in every backend). Each device ``d`` holds:

* ``shards`` — its local input viewed as ``(addr_shards, m, ...)``
  (``addr_shards == 1`` means the whole payload is one frame);
* ``buf``   — the transit register: ``(width, m, ...)`` where ``width``
  is per-step (a step may carry a multi-shard block);
* ``out``   — ``(out_slots, m, ...)`` result/accumulator slots.

Per step, in order:

1. *load*    — ``buf[j] = out[load[d][j]]`` (``-1`` keeps the current
   row; required in full whenever the width changes);
2. *hop*     — ``buf = permute(buf, edges)``: ``dst`` receives ``src``'s
   buffer, devices no edge targets receive zeros;
3. *combine* — ``combine == "add"``: ``buf[j] += source[add_src[d][j]]``
   where ``source`` is the input shards (``add_from == "input"``) or the
   out slots (``add_from == "out"``); ``-1`` adds nothing;
4. *write*   — ``out[write[d][j]] (op)= buf[j]`` with ``write_op`` in
   ``{"copy", "add"}``; ``-1`` discards the row.

IR invariants (enforced by :meth:`ChainProgram.validate`, pinned by the
device-free golden-schedule tests):

* **edge-disjointness within a step** — a device receives at most one
  frame per step (unique destinations always; unique sources too for
  ``kind == "stepped"`` programs, so every step is ONE fused ppermute;
  ``kind == "pipeline"`` may repeat the head as a source — the
  executor splits the extra fan-out sends into their own permutes,
  which :func:`program_wire_bytes` accounts via
  :meth:`Step.num_permutes`);
* **shard-fraction accounting** — every step moves
  ``width / addr_shards`` of the payload per edge
  (:meth:`ChainProgram.step_bytes`); all addressing tables index within
  ``addr_shards`` / ``out_slots`` bounds, and a device's write rows
  target distinct slots;
* **combine-op semantics** — ``"copy"`` steps move data unchanged;
  ``"add"`` steps fold exactly one addressed local shard into each buf
  row *after* the hop (left-fold: ``buf + shard``), so replaying the
  program fixes the floating-point reduction order and any two
  backends agree BIT-exactly.

Symbolic addressing (the contract every backend shares). A "table" in
this IR is EITHER a dense ``tuple``-of-rows (``(num_devices, width)``,
``-1`` = none — the escape hatch for irregular schedules and hand-built
programs) OR one of four compact address *generators* evaluated per
device from its ring position — the IR analogue of XDMA's hardware
address generators:

* :class:`Affine`        — ``row[col] = (a·pos + c·ring + e·col + b)
  mod m`` for ring members, ``-1`` for non-members (constants, ring-
  position shards, iota rows);
* :class:`MemberLookup`  — ``row[col] = orders[(ar·ring + er·col + br)
  mod K][(ap·pos + ep·col + bp) mod S]`` (device-id addressing through
  the ring member map);
* :class:`Diag`          — ``row[d] = inner(d)`` on device ``d``'s own
  column, ``-1`` elsewhere (the all_to_all peel);
* :class:`AtDevices`     — ``row = [value]·width`` on a listed device
  set, ``-1`` elsewhere (chain heads and per-step chain writes).

Planning therefore builds O(1)-sized tables per step (O(L) per program
including the shared edge lists); ``validate()`` checks symbolic
tables structurally (coefficients and bounds, no materialization); the
numpy oracle materializes rows lazily via :func:`resolve_table` /
:func:`resolve_row`; and the SPMD executor evaluates the coefficients
in-kernel from ``lax.axis_index`` — on a *canonical* ring partition
(``groups[j] == range(j·S, (j+1)·S)`` covering the axis) its compiled
HLO carries NO ring-length-dependent constants.

Planners (``orders``/``chains`` are the scheduled partitions from
``core.scheduling``; ``num_devices`` is the SPMD axis size or the NoC
node count):

* :func:`plan_broadcast`       — P2MP multicast down K disjoint chains
  (``kind="pipeline"``: the data phase streams, frames optional);
* :func:`plan_recovery`        — the endpoint-side failure recovery of
  a multi-chain broadcast as a program: one detection-window step
  (``tag="detect"``, no edges) plus the re-formed orphaned suffix of
  every affected sub-chain as ordered chain steps, each suffix
  streaming from the surviving member that banked the payload
  (``group_heads``); concurrent failures in distinct sub-chains share
  the steps (and the initiator's cfg port, in the latency model);
* :func:`plan_all_gather`      — per-ring all-gather, then a cross-ring
  block exchange for K > 1;
* :func:`plan_reduce_scatter`  — per-ring reduce-scatter over K-chunk
  groups, then a cross-ring group reduce-scatter for K > 1;
* :func:`plan_all_reduce`      — ``algo="rs_ag"`` (fused per-ring RS →
  cross-ring shard rotation → fused per-ring AG, shards addressed by
  ring position) or ``algo="rotation"`` (full-payload rotations); K=1
  is the single-ring RS+AG with *device-id* chunk addressing (the
  historical ``chain_all_reduce`` schedule);
* :func:`plan_all_to_all`      — the rotating chunk train; K > 1
  interleaves intra-ring rotations with cross-ring hops (same total
  wire, shorter per-step distances).

Every :class:`Step` (and the program as a default) carries a
``wire_dtype``: ``None`` ships frames in the payload dtype; ``"int8"``
quantizes each hop's frame to int8 with one f32 scale riding alongside
(per-hop quantize → ship → dequantize → f32 combine). Compression is
therefore an ordinary IR dimension — the same executor, oracle replay,
byte/latency accounting and (K, algo, wire_dtype) selection apply.

This module is dependency-light (stdlib only) so the SPMD layer, the
numpy oracle, the simulator and the CLI all share ONE schedule source.
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import Iterable, Iterator, Sequence

# Canonical multi-ring all-reduce schedule names — the single tuple the
# SPMD layer, the simulator and the CLI validate against.
ALL_REDUCE_ALGOS = ("rs_ag", "rotation")

# Wire dtypes a step may ship. None = payload dtype unchanged; "int8" =
# per-hop symmetric quantization: an int8 frame plus one f32 scale.
WIRE_DTYPES = ("int8",)
_WIRE_SCALE_BYTES = 4  # the f32 scale shipped alongside each int8 frame


def normalize_wire_dtype(wire_dtype) -> str | None:
    """Canonical IR form of a wire dtype: ``None`` (ship the payload
    dtype) or a name from :data:`WIRE_DTYPES`. Accepts the string form
    or any numpy/jax dtype object whose name matches — keeping this
    module stdlib-only while letting callers pass ``jnp.int8``."""
    if wire_dtype is None:
        return None
    if isinstance(wire_dtype, str):
        name = wire_dtype
    else:
        name = (
            getattr(wire_dtype, "__name__", None)
            or getattr(wire_dtype, "name", None)
            or str(wire_dtype)
        )
    if name not in WIRE_DTYPES:
        raise ValueError(
            f"unknown wire dtype {wire_dtype!r}; "
            f"expected None or one of {WIRE_DTYPES}"
        )
    return name


Edge = tuple[int, int]
Table = tuple[tuple[int, ...], ...]  # (num_devices, width); -1 = none

COPY = "copy"
ADD = "add"


def _table(rows: Sequence[Sequence[int]]) -> Table:
    return tuple(tuple(int(v) for v in row) for row in rows)


# ---------------------------------------------------------------------------
# Symbolic addressing tables (see module docstring for the contract)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Affine:
    """``row[col] = (a·pos + c·ring + e·col + b) mod m`` for ring
    members; all ``-1`` for devices outside every group."""

    width: int
    a: int = 0  # coefficient on ring position
    c: int = 0  # coefficient on ring index
    e: int = 0  # coefficient on column
    b: int = 0  # offset
    m: int = 1  # modulus (values live in [0, m))


@dataclasses.dataclass(frozen=True)
class MemberLookup:
    """``row[col] = orders[(ar·ring + er·col + br) mod K]
    [(ap·pos + ep·col + bp) mod S]`` — device-id addressing through the
    ring member map; all ``-1`` for non-members."""

    width: int
    ar: int = 0
    er: int = 0
    br: int = 0
    ap: int = 0
    ep: int = 0
    bp: int = 0


@dataclasses.dataclass(frozen=True)
class Diag:
    """``row[d] = inner(d)`` on device ``d``'s own column (width must be
    ``num_devices``), ``-1`` elsewhere — the all_to_all peel/out_init
    shape. ``inner`` is a width-1 :class:`Affine` or
    :class:`MemberLookup` evaluated at column 0."""

    width: int
    inner: "Affine | MemberLookup"


@dataclasses.dataclass(frozen=True)
class AtDevices:
    """``row = (value,)·width`` on the listed devices, all ``-1``
    elsewhere — chain heads (inits/loads) and per-step chain writes.
    ``devices=()`` is the all-none table."""

    devices: tuple[int, ...]
    value: int = 0
    width: int = 1


# Any table position accepts the dense tuple form or a symbolic map.
TableRef = Table | Affine | MemberLookup | Diag | AtDevices


class _RingCtx:
    """Host-side ring-partition context for symbolic resolution: member
    orders, per-device position/ring index, and whether the partition
    is *canonical* (``orders[j] == range(j·S, (j+1)·S)`` covering the
    axis — the executor then derives pos/ring arithmetically from the
    device index, with zero L-sized HLO constants)."""

    __slots__ = ("orders", "K", "S", "pos", "ring_of", "canonical",
                 "max_member")

    def __init__(self, num_devices: int, orders) -> None:
        orders = tuple(tuple(int(d) for d in c) for c in orders)
        if not orders or not orders[0]:
            raise ValueError("symbolic table needs non-empty ring groups")
        S = len(orders[0])
        if any(len(c) != S for c in orders):
            raise ValueError("symbolic table needs equal-size ring groups")
        self.orders = orders
        self.K, self.S = len(orders), S
        self.pos: dict[int, int] = {}
        self.ring_of: dict[int, int] = {}
        for j, ring in enumerate(orders):
            for p, d in enumerate(ring):
                self.pos[d] = p
                self.ring_of[d] = j
        self.max_member = max(self.pos)
        self.canonical = self.K * S == num_devices and all(
            orders[j][p] == j * S + p
            for j in range(self.K)
            for p in range(S)
        )


def table_width(table) -> int:
    """Column count of a dense or symbolic table."""
    if isinstance(table, tuple):
        return len(table[0]) if table else 0
    return table.width


def _scalar_eval(inner, ctx: _RingCtx, d: int) -> int:
    """Column-0 value of a width-1 Affine/MemberLookup on device ``d``."""
    if d not in ctx.pos:
        return -1
    p, r = ctx.pos[d], ctx.ring_of[d]
    if isinstance(inner, Affine):
        return (inner.a * p + inner.c * r + inner.b) % inner.m
    return ctx.orders[(inner.ar * r + inner.br) % ctx.K][
        (inner.ap * p + inner.bp) % ctx.S
    ]


def resolve_row(program: "ChainProgram", table, d: int) -> tuple[int, ...]:
    """Materialize ONE device's row of a dense or symbolic table —
    O(width), so golden-schedule tests spot-check 1024-ring programs
    without building (L, L) tables."""
    if isinstance(table, tuple):
        return table[d]
    if isinstance(table, AtDevices):
        w = table.width
        return (table.value,) * w if d in table.devices else (-1,) * w
    ctx = program.ring_ctx()
    if isinstance(table, Diag):
        row = [-1] * table.width
        row[d] = _scalar_eval(table.inner, ctx, d)
        return tuple(row)
    if d not in ctx.pos:
        return (-1,) * table.width
    p, r = ctx.pos[d], ctx.ring_of[d]
    if isinstance(table, Affine):
        return tuple(
            (table.a * p + table.c * r + table.e * col + table.b) % table.m
            for col in range(table.width)
        )
    if isinstance(table, MemberLookup):
        return tuple(
            ctx.orders[(table.ar * r + table.er * col + table.br) % ctx.K][
                (table.ap * p + table.ep * col + table.bp) % ctx.S
            ]
            for col in range(table.width)
        )
    raise TypeError(f"unknown table type {type(table).__name__}")


def resolve_table(program: "ChainProgram", table) -> Table:
    """Materialize a dense or symbolic table to the dense tuple form —
    the numpy oracle's lazy path (dense tables pass through)."""
    if isinstance(table, tuple):
        return table
    return tuple(
        resolve_row(program, table, d) for d in range(program.num_devices)
    )


@dataclasses.dataclass(frozen=True)
class Step:
    """One schedule step: a set of concurrent P2P hops + addressing."""

    edges: tuple[Edge, ...]
    width: int = 1
    combine: str = COPY  # buf update after the hop: copy | add
    add_from: str = "input"  # add reads "input" shards or "out" slots
    add_src: TableRef | None = None
    load: TableRef | None = None  # out slots loaded into buf BEFORE the hop
    write: TableRef | None = None  # out slot written per buf row after combine
    write_op: str = COPY  # copy | add
    # Latency-model grouping: "intra" | "cross" (ring rounds), "chain"
    # (pipeline hop slots), "detect" (edge-free failure-timeout window —
    # priced as SimParams.fail_timeout_cc per occurrence, zero bytes).
    tag: str = "intra"
    # Per-step wire dtype override; None defers to the program default.
    wire_dtype: str | None = None

    def num_permutes(self) -> int:
        """ppermute ops the SPMD executor emits for this step: one fused
        permute for the unique-source edge set, plus one extra permute
        per repeated source (the pipeline head's same-step fan-out).
        Memoized per instance (fields are frozen) so 1024-ring byte
        accounting does not rescan the shared edge lists."""
        cached = self.__dict__.get("_num_permutes")
        if cached is not None:
            return cached
        if not self.edges:
            n = 0
        else:
            counts: dict[int, int] = {}
            for src, _ in self.edges:
                counts[src] = counts.get(src, 0) + 1
            n = 1 + sum(c - 1 for c in counts.values())
        object.__setattr__(self, "_num_permutes", n)
        return n

    def __getstate__(self):
        # Exclude memo attrs: pickled size must reflect the IR alone.
        return {f.name: getattr(self, f.name) for f in dataclasses.fields(self)}

    def __setstate__(self, state):
        for k, v in state.items():
            object.__setattr__(self, k, v)


@dataclasses.dataclass(frozen=True)
class ChainProgram:
    """A complete collective schedule (see module docstring)."""

    collective: str  # broadcast | all_gather | reduce_scatter | ...
    kind: str  # "pipeline" (streamed chains) | "stepped" (ring rounds)
    num_devices: int
    addr_shards: int  # input viewed as (addr_shards, m, ...)
    out_slots: int
    buf_init: Table  # (L, width0) input-shard indices; -1 = zeros
    out_init: Table  # (L, out_slots) input-shard indices; -1 = zeros
    steps: tuple[Step, ...]
    # Schedule metadata for the latency model: for kind="pipeline" the
    # per-chain destination orders (head excluded) + head; for
    # kind="stepped" the K sub-rings (full member orders).
    groups: tuple[tuple[int, ...], ...]
    head: int | None = None
    algo: str | None = None
    # Per-group data-entry nodes for kind="pipeline" programs whose
    # streams do NOT all start at the cfg initiator (recovery: each
    # re-formed suffix streams from the member that banked the payload).
    # None = every group streams from the initiator.
    group_heads: tuple[int, ...] | None = None
    # Program-default wire dtype (``Step.wire_dtype`` overrides per
    # step); None = frames ship in the payload dtype.
    wire_dtype: str | None = None

    # -- symbolic resolution ------------------------------------------
    def ring_ctx(self) -> _RingCtx:
        """The ring-partition context symbolic tables evaluate against
        (``groups`` interpreted as the K equal-size member orders).
        Cached per instance; never part of equality/pickling."""
        ctx = self.__dict__.get("_ring_ctx")
        if ctx is None:
            ctx = _RingCtx(self.num_devices, self.groups)
            object.__setattr__(self, "_ring_ctx", ctx)
        return ctx

    def with_wire_dtype(self, wire_dtype) -> "ChainProgram":
        """This program with a different default wire dtype — an O(1)
        field replacement (steps and tables are shared), so candidate
        scoring can derive every wire variant from ONE planned base."""
        wd = normalize_wire_dtype(wire_dtype)
        if wd == self.wire_dtype:
            return self
        if wd is not None and self.kind != "stepped":
            raise ValueError(
                "wire_dtype is only supported on stepped programs "
                "(the frame-pipelined executor ships payload-dtype frames)"
            )
        return dataclasses.replace(self, wire_dtype=wd)

    def __getstate__(self):
        # Exclude the cached _RingCtx: pickled size reflects the IR.
        return {f.name: getattr(self, f.name) for f in dataclasses.fields(self)}

    def __setstate__(self, state):
        for k, v in state.items():
            object.__setattr__(self, k, v)

    # -- accounting ---------------------------------------------------
    def step_wire_dtype(self, step: Step) -> str | None:
        """Resolved wire dtype of ``step``: its own override, else the
        program default; ``None`` = payload dtype."""
        return step.wire_dtype if step.wire_dtype is not None else self.wire_dtype

    def step_bytes(self, step: Step, size_bytes: int) -> int:
        """Frame bytes one edge of ``step`` carries, for a per-device
        input payload of ``size_bytes``. An int8-wire step ships a
        quarter-size frame (the byte model assumes a 4-byte payload
        dtype, matching the executor's f32 wire arithmetic) plus one
        f32 scale scalar per frame."""
        frame = step.width * _ceil_div(size_bytes, self.addr_shards)
        if self.step_wire_dtype(step) == "int8":
            return _ceil_div(frame, 4) + _WIRE_SCALE_BYTES
        return frame

    def wire_bytes(self, size_bytes: int) -> int:
        """Modeled collective wire bytes of the whole program — the
        trip-count-aware HLO ``collective-permute`` attribution: every
        emitted ppermute counts its (per-device) operand bytes. For
        ring ("stepped") programs every device sends each step, so this
        is also the per-device wire-byte total."""
        return sum(
            s.num_permutes() * self.step_bytes(s, size_bytes)
            for s in self.steps
        )

    @property
    def num_steps(self) -> int:
        return len(self.steps)

    def describe(self, size_bytes: int | None = None) -> Iterator[str]:
        """Human-readable step table (the examples/ demo)."""
        yield (
            f"{self.collective} [{self.kind}"
            + (f", algo={self.algo}" if self.algo else "")
            + (f", wire={self.wire_dtype}" if self.wire_dtype else "")
            + f"] devices={self.num_devices} shards=1/{self.addr_shards}"
            f" out_slots={self.out_slots} groups={list(self.groups)}"
        )
        for i, s in enumerate(self.steps):
            line = (
                f"  step {i:2d} [{s.tag:5s}] edges={len(s.edges)}"
                f" permutes={s.num_permutes()} frac={s.width}/{self.addr_shards}"
                f" combine={s.combine} {list(s.edges)}"
            )
            wd = self.step_wire_dtype(s)
            if wd is not None:
                line += f" wire={wd}"
            if size_bytes is not None:
                line += f" bytes/edge={self.step_bytes(s, size_bytes)}"
            yield line
        if size_bytes is not None:
            yield f"  total wire bytes: {self.wire_bytes(size_bytes)}"

    # -- validation ---------------------------------------------------
    def validate(self) -> "ChainProgram":
        L = self.num_devices
        if L < 1 or self.addr_shards < 1 or self.out_slots < 1:
            raise ValueError("degenerate program dimensions")
        if self.kind not in ("pipeline", "stepped"):
            raise ValueError(f"unknown program kind {self.kind!r}")
        if normalize_wire_dtype(self.wire_dtype) is not None and self.kind != "stepped":
            raise ValueError(
                "wire_dtype is only supported on stepped programs "
                "(the frame-pipelined executor ships payload-dtype frames)"
            )
        if self.group_heads is not None:
            if self.kind != "pipeline":
                raise ValueError("group_heads only applies to pipeline programs")
            if len(self.group_heads) != len(self.groups):
                raise ValueError(
                    f"group_heads has {len(self.group_heads)} entries, "
                    f"expected one per group ({len(self.groups)})"
                )
            for h in self.group_heads:
                if not 0 <= h < L:
                    raise ValueError(f"group head {h} out of range")
        self._check_table(self.buf_init, None, self.addr_shards, "buf_init")
        self._check_table(self.out_init, self.out_slots, self.addr_shards, "out_init")
        width = table_width(self.buf_init) or 1
        # Steps share their edge tuples (one intra + one cross list per
        # program), so the O(len(edges)) structural checks memoize by
        # object identity — validation stays O(L) for 1024-ring runs.
        edges_ok: set[int] = set()
        for i, s in enumerate(self.steps):
            if s.width < 1:
                raise ValueError(f"step {i}: width < 1")
            if normalize_wire_dtype(s.wire_dtype) is not None and self.kind != "stepped":
                raise ValueError(f"step {i}: wire_dtype on a {self.kind} program")
            if id(s.edges) not in edges_ok:
                dsts = [e[1] for e in s.edges]
                if len(set(dsts)) != len(dsts):
                    raise ValueError(f"step {i}: duplicate edge destinations")
                if self.kind == "stepped":
                    srcs = [e[0] for e in s.edges]
                    if len(set(srcs)) != len(srcs):
                        raise ValueError(f"step {i}: duplicate edge sources")
                for a, b in s.edges:
                    if not (0 <= a < L and 0 <= b < L):
                        raise ValueError(f"step {i}: edge ({a},{b}) out of range")
                edges_ok.add(id(s.edges))
            if s.width != width and s.load is None:
                raise ValueError(f"step {i}: width change without load")
            if s.load is not None:
                self._check_table(s.load, s.width, self.out_slots, f"step {i} load")
            if s.combine == ADD:
                bound = self.addr_shards if s.add_from == "input" else self.out_slots
                if s.add_src is None:
                    raise ValueError(f"step {i}: add without add_src")
                self._check_table(s.add_src, s.width, bound, f"step {i} add_src")
            elif s.combine != COPY:
                raise ValueError(f"step {i}: unknown combine {s.combine!r}")
            if s.write is not None:
                self._check_table(s.write, s.width, self.out_slots, f"step {i} write")
                self._check_write_distinct(s.write, i)
            width = s.width
        return self

    def _check_table(self, table, width, bound, name) -> None:
        if isinstance(table, tuple):
            if len(table) != self.num_devices:
                raise ValueError(f"{name}: table has {len(table)} rows, "
                                 f"expected {self.num_devices}")
            for row in table:
                if width is not None and len(row) != width:
                    raise ValueError(f"{name}: row width {len(row)} != {width}")
                for v in row:
                    if not (-1 <= v < bound):
                        raise ValueError(f"{name}: index {v} out of range {bound}")
            return
        # Symbolic tables: structural O(1) checks (the ring context is
        # built once per program, O(L)).
        if table.width < 1:
            raise ValueError(f"{name}: width < 1")
        if width is not None and table.width != width:
            raise ValueError(f"{name}: row width {table.width} != {width}")
        if isinstance(table, AtDevices):
            for dev in table.devices:
                if not 0 <= dev < self.num_devices:
                    raise ValueError(f"{name}: device {dev} out of range")
            if not -1 <= table.value < bound:
                raise ValueError(
                    f"{name}: index {table.value} out of range {bound}"
                )
            return
        if isinstance(table, Diag):
            if table.width != self.num_devices:
                raise ValueError(
                    f"{name}: Diag width {table.width} != num_devices"
                )
            self._check_table(table.inner, 1, bound, f"{name} inner")
            return
        if isinstance(table, Affine):
            if not 1 <= table.m <= bound:
                raise ValueError(
                    f"{name}: modulus {table.m} outside [1, {bound}]"
                )
            return
        if isinstance(table, MemberLookup):
            if self.ring_ctx().max_member >= bound:
                raise ValueError(
                    f"{name}: ring member {self.ring_ctx().max_member} "
                    f"out of range {bound}"
                )
            return
        raise TypeError(f"{name}: unknown table type {type(table).__name__}")

    def _check_write_distinct(self, table, i: int) -> None:
        """A device's write rows must target distinct out slots. Dense
        tables are checked row by row; symbolic ones structurally (the
        property test pins the materialized equivalence)."""
        if isinstance(table, tuple):
            for d, row in enumerate(table):
                live = [v for v in row if v >= 0]
                if len(set(live)) != len(live):
                    raise ValueError(
                        f"step {i}: device {d} writes one slot twice"
                    )
            return
        if isinstance(table, Diag) or table.width == 1:
            return  # at most one live slot per row
        if isinstance(table, AtDevices):
            if table.devices and table.value >= 0:
                raise ValueError(
                    f"step {i}: AtDevices write repeats slot {table.value}"
                )
            return
        if isinstance(table, Affine):
            if math.gcd(table.e, table.m) == 1 and table.width <= table.m:
                return
        elif isinstance(table, MemberLookup):
            K, S = self.ring_ctx().K, self.ring_ctx().S
            if table.ep % S == 0 and math.gcd(table.er, K) == 1 \
                    and table.width <= K:
                return  # distinct rings -> distinct members
            if table.er % K == 0 and math.gcd(table.ep, S) == 1 \
                    and table.width <= S:
                return  # one ring, distinct positions
        raise ValueError(
            f"step {i}: cannot prove distinct write slots for "
            f"{type(table).__name__}"
        )


def program_wire_bytes(program: ChainProgram, size_bytes: int) -> int:
    """Functional alias of :meth:`ChainProgram.wire_bytes`."""
    return program.wire_bytes(size_bytes)


def tier_crossing_stats(
    program: ChainProgram, topo, src: int = 0
) -> dict[str, object]:
    """Tier-crossing accounting of a planned program on a weighted
    topology (``topo`` is any object honouring the link-graph contract
    of :mod:`repro.core.topology` — duck-typed so this module stays
    stdlib-only).

    Returns ``{"per_group", "per_step", "crossing_steps", "total"}``:

    * ``per_group`` — for each chain/ring, how many consecutive-member
      route *segments* traverse at least one tier>0 (inter-pod) link
      (pipeline chains walk head -> members; stepped rings close the
      loop). The tier-aware partitioner targets ≤ 1 per chain.
    * ``per_step`` — for each stepped round, how many of its fused
      edges cross a pod boundary (pipeline programs have data-free
      steps here: ``0`` per step).
    * ``crossing_steps`` — number of steps with ≥ 1 crossing edge (the
      "one inter-pod exchange per shard" count of a hierarchical
      schedule).
    * ``total`` — summed tier>0 link traversals over the group routes
      (link granularity, wire-energy flavoured; the step edges are
      derived from the same routes, so they are not double-counted).
    """
    heads = program.group_heads or (src,) * len(program.groups)
    per_group: list[int] = []
    total = 0
    for order, head in zip(program.groups, heads):
        if not order:
            per_group.append(0)
            continue
        walk = [int(head)] + [int(d) for d in order]
        if program.kind != "pipeline" and len(order) > 1:
            walk = [int(d) for d in order] + [int(order[0])]  # closed ring
        segs = 0
        for a, b in zip(walk, walk[1:]):
            c = topo.path_tier_crossings(a, b)
            total += c
            if c:
                segs += 1
        per_group.append(segs)
    per_step: list[int] = []
    crossing_steps = 0
    for step in program.steps:
        n = sum(
            1
            for a, b in step.edges
            if topo.path_tier_crossings(int(a), int(b))
        )
        per_step.append(n)
        if n:
            crossing_steps += 1
    return {
        "per_group": per_group,
        "per_step": per_step,
        "crossing_steps": crossing_steps,
        "total": total,
    }


def pipelined_wire_bytes(
    program: ChainProgram, size_bytes: int, num_frames: int = 1
) -> int:
    """Wire bytes of the frame-pipelined execution of a ``pipeline``
    program: the store-and-forward scan applies EVERY chain edge on
    each of its F + L - 2 slots at 1/F-payload frame granularity
    (idle edge slots still ship a frame-sized buffer — the modeled HLO
    attribution of the scanned executor). ``num_frames <= 1`` is the
    stepped execution, i.e. :func:`program_wire_bytes`."""
    if program.kind != "pipeline" or num_frames <= 1 or not program.steps:
        return program.wire_bytes(size_bytes)
    counts: dict[int, int] = {}
    for s in program.steps:
        for src, _ in s.edges:
            counts[src] = counts.get(src, 0) + 1
    permutes = 1 + sum(c - 1 for c in counts.values())
    slots = num_frames + len(program.steps) - 1
    return slots * permutes * _ceil_div(size_bytes, num_frames)


# ---------------------------------------------------------------------------
# Partition validation helpers
# ---------------------------------------------------------------------------


def validate_chains(
    head: int, chains: Sequence[Sequence[int]]
) -> tuple[tuple[int, ...], ...]:
    """Clean + validate K disjoint broadcast sub-chains (head excluded
    from every chain; empty chains dropped). An empty *result* is
    allowed here (a head-only broadcast); ``multi_chain_broadcast``
    rejects it at its own layer."""
    head = int(head)
    clean = [tuple(int(d) for d in c) for c in chains if len(c)]
    seen: set[int] = set()
    for c in clean:
        for d in c:
            if d == head:
                raise ValueError("head cannot appear inside a chain")
            if d in seen:
                raise ValueError(f"destination {d} appears in two chains")
            seen.add(d)
    return tuple(clean)


def validate_ring_partition(
    axis_size: int, orders: Sequence[Sequence[int]]
) -> list[tuple[int, ...]]:
    """Clean + validate K disjoint equal-size sub-rings covering the
    whole axis. Pure host-side helper shared by the SPMD ring
    collectives, the planners and the property tests."""
    clean = [tuple(int(o) for o in c) for c in orders if len(c)]
    if not clean:
        raise ValueError("empty ring set")
    S = len(clean[0])
    if any(len(c) != S for c in clean):
        raise ValueError("sub-rings must have equal sizes")
    flat = [d for c in clean for d in c]
    if sorted(flat) != list(range(axis_size)):
        raise ValueError("sub-rings must partition the whole axis")
    return clean


def _check_rings(
    num_devices: int, orders: Sequence[Sequence[int]]
) -> tuple[tuple[int, ...], ...]:
    """Planner-level ring validation: disjoint, equal sizes, members in
    range. (Unlike :func:`validate_ring_partition` the rings need not
    cover every device — the simulator models rings over node subsets
    of a larger NoC.)"""
    clean = [tuple(int(o) for o in c) for c in orders if len(c)]
    if not clean:
        raise ValueError("empty ring set")
    S = len(clean[0])
    if any(len(c) != S for c in clean):
        raise ValueError("sub-rings must have equal sizes")
    flat = [d for c in clean for d in c]
    if len(set(flat)) != len(flat):
        raise ValueError("sub-rings must be disjoint")
    if any(not 0 <= d < num_devices for d in flat):
        raise ValueError("ring member out of device range")
    return tuple(clean)


def _ring_maps(orders: tuple[tuple[int, ...], ...]):
    """(intra_edges, cross_edges, pos, ring_of) for K equal-size rings."""
    K, S = len(orders), len(orders[0])
    intra = tuple(
        (c[p], c[(p + 1) % S]) for c in orders for p in range(S)
    ) if S > 1 else ()
    cross = tuple(
        (orders[j][r], orders[(j + 1) % K][r])
        for j in range(K)
        for r in range(S)
    ) if K > 1 else ()
    pos: dict[int, int] = {}
    ring_of: dict[int, int] = {}
    for j, ring in enumerate(orders):
        for p, d in enumerate(ring):
            pos[d] = p
            ring_of[d] = j
    return intra, cross, pos, ring_of


def _rows(num_devices: int, width: int) -> list[list[int]]:
    return [[-1] * width for _ in range(num_devices)]


# ---------------------------------------------------------------------------
# Planners
# ---------------------------------------------------------------------------

# Every planner memoizes on its full argument tuple, but BOUNDED: a
# large-L sweep must not pin every planned program in memory forever.
# LRU keeps the working set (one training/serving loop re-plans the
# same few programs); see planner_cache_stats() for hit rates.
_PLANNER_CACHE_MAXSIZE = 128


@functools.lru_cache(maxsize=_PLANNER_CACHE_MAXSIZE)
def plan_broadcast(
    num_devices: int, head: int, chains: tuple[tuple[int, ...], ...]
) -> ChainProgram:
    """P2MP multicast from ``head`` down K disjoint sub-chains.

    ``kind="pipeline"``: step ``t`` holds every chain's depth-``t``
    edge, so the steps double as the per-frame hop slots of the
    streamed (frame-pipelined) execution.
    """
    head = int(head)
    chains = validate_chains(head, chains)
    L = int(num_devices)
    full = [(head,) + c for c in chains]
    at_head = AtDevices((head,), 0)
    steps = []
    max_len = max((len(f) for f in full), default=1)
    for t in range(max_len - 1):
        edges = tuple((f[t], f[t + 1]) for f in full if t + 1 < len(f))
        steps.append(Step(
            edges=edges, width=1, tag="chain",
            write=AtDevices(tuple(dst for _, dst in edges), 0),
        ))
    return ChainProgram(
        collective="broadcast", kind="pipeline", num_devices=L,
        addr_shards=1, out_slots=1,
        buf_init=at_head, out_init=at_head,
        steps=tuple(steps), groups=chains, head=head,
    ).validate()


def plan_recovery(
    topo,
    src: int,
    chains: Sequence[Sequence[int]],
    failed: "int | Iterable[int]",
    *,
    scheduler: str = "tsp",
) -> ChainProgram:
    """Failure recovery of a multi-chain broadcast as a ChainProgram.

    ``chains`` is the (failure-free) partition the broadcast ran with;
    ``failed`` is one dead member or a set of concurrently dead members
    (each must belong to some chain; the initiator ``src`` cannot be
    recovered — raise before calling for that case). Per affected
    sub-chain the orphaned suffix is re-formed by
    ``scheduling.reform_chain`` (upstream prefix kept verbatim — the
    payload is banked there by store-and-forward) and emitted as
    ordered chain steps; the suffix streams from the last surviving
    prefix member (``group_heads``), or from ``src`` when the failure
    hit the chain head. Step 0 is the shared detection window
    (``tag="detect"``, no edges — the initiator's finish timeout fires
    once for every concurrent failure).

    Sub-chains with no failed member do not appear: recovery never
    perturbs them (the isolation invariant). A chain whose survivors
    all sit upstream of its failures contributes no steps either —
    nothing downstream is orphaned, only the detection window is paid
    (priced by ``simulator.chain_recovery_latency``).

    The returned program is consumed by ``simulator.program_latency`` /
    ``program_wire_bytes`` (recovery priced through the same machinery
    as every other schedule) and replays under
    ``chainwrite_ref.interpret_program`` — seed the banked heads with
    the payload and every re-sent survivor receives it.
    """
    chains_t = tuple(
        tuple(int(d) for d in c) for c in chains if len(c)
    )
    from .scheduling import normalize_failed  # host-side only

    return _plan_recovery_cached(
        topo, int(src), chains_t, tuple(normalize_failed(failed)), scheduler
    )


@functools.lru_cache(maxsize=_PLANNER_CACHE_MAXSIZE)
def _plan_recovery_cached(
    topo,
    src: int,
    chains: tuple[tuple[int, ...], ...],
    failed: tuple[int, ...],
    scheduler: str,
) -> ChainProgram:
    from .scheduling import reform_chain  # host-side only

    dead = set(failed)
    members = {d for c in chains for d in c}
    missing = dead - members
    if missing:
        raise ValueError(f"failed node(s) {sorted(missing)} are in no chain")
    L = int(topo.num_nodes)

    groups: list[tuple[int, ...]] = []
    heads: list[int] = []
    for chain in chains:
        chain_dead = [f for f in chain if f in dead]
        if not chain_dead:
            continue
        first = min(chain.index(f) for f in chain_dead)
        reformed = reform_chain(topo, chain, chain_dead, src, scheduler=scheduler)
        prefix, resent = reformed[:first], reformed[first:]
        if not resent:
            continue  # tail failure: nothing downstream to re-send
        groups.append(tuple(resent))
        heads.append(prefix[-1] if prefix else src)

    at_heads = AtDevices(tuple(dict.fromkeys(heads)), 0)
    steps: list[Step] = [Step(edges=(), tag="detect")]
    full = [(h,) + g for h, g in zip(heads, groups)]
    max_len = max((len(f) for f in full), default=1)
    for t in range(max_len - 1):
        edges = tuple((f[t], f[t + 1]) for f in full if t + 1 < len(f))
        # At t == 0 the banked members re-read the payload from local
        # memory (the detection window cleared the transit registers).
        steps.append(Step(
            edges=edges, width=1, tag="chain",
            load=at_heads if t == 0 else None,
            write=AtDevices(tuple(dst for _, dst in edges), 0),
        ))
    return ChainProgram(
        collective="recovery", kind="pipeline", num_devices=L,
        addr_shards=1, out_slots=1,
        buf_init=at_heads, out_init=at_heads,
        steps=tuple(steps), groups=tuple(groups), head=src,
        group_heads=tuple(heads),
    ).validate()


@functools.lru_cache(maxsize=_PLANNER_CACHE_MAXSIZE)
def plan_all_gather(
    num_devices: int, orders: tuple[tuple[int, ...], ...]
) -> ChainProgram:
    """Per-ring all-gather; K > 1 adds a cross-ring exchange of the
    gathered ring *blocks* (width-S steps). Output slots are device-id
    addressed — standard all_gather semantics for any ring order."""
    L = int(num_devices)
    orders = _check_rings(L, orders)
    K, S = len(orders), len(orders[0])
    intra, cross, _pos, _ring_of = _ring_maps(orders)

    steps: list[Step] = []
    for s in range(1, S):
        # write[d][0] = orders[ring][(pos - s) % S]
        steps.append(Step(
            edges=intra, width=1, tag="intra",
            write=MemberLookup(1, ar=1, ap=1, bp=-s),
        ))
    for c in range(1, K):
        # load (c==1): this ring's members; write: ring (ring - c)'s.
        steps.append(Step(
            edges=cross, width=S, tag="cross",
            load=MemberLookup(S, ar=1, ep=1) if c == 1 else None,
            write=MemberLookup(S, ar=1, br=-c, ep=1),
        ))
    return ChainProgram(
        collective="all_gather", kind="stepped", num_devices=L,
        addr_shards=1, out_slots=L,
        buf_init=Affine(1),  # members hold shard 0; non-members none
        out_init=Diag(L, Affine(1)),  # own slot seeded from own shard
        steps=tuple(steps), groups=orders,
    ).validate()


@functools.lru_cache(maxsize=_PLANNER_CACHE_MAXSIZE)
def plan_reduce_scatter(
    num_devices: int, orders: tuple[tuple[int, ...], ...]
) -> ChainProgram:
    """Reduce-scatter over K sub-rings: the input is ``num_devices``
    device-id-addressed chunks; device ``d`` ends with the fully
    reduced chunk ``d`` in out slot 0.

    K=1 is the classic ring schedule (1/L frames, L-1 steps). K > 1
    first reduce-scatters width-K chunk *groups* within each ring
    (group ``p`` = the chunks of every ring's position-``p`` member),
    then reduce-scatters each group across the rings at single-chunk
    width — same total wire as the single ring, shorter rounds.
    """
    L = int(num_devices)
    orders = _check_rings(L, orders)
    K, S = len(orders), len(orders[0])
    intra, cross, _pos, _ring_of = _ring_maps(orders)
    steps: list[Step] = []

    if K == 1:
        ring = orders[0]
        for s in range(1, S):
            # add[d][0] = ring[(pos - s - 1) % S]
            steps.append(Step(
                edges=intra, width=1, tag="intra", combine=ADD,
                add_src=MemberLookup(1, ar=1, ap=1, bp=-s - 1),
                write=Affine(1) if s == S - 1 else None,
            ))
        return ChainProgram(
            collective="reduce_scatter", kind="stepped", num_devices=L,
            addr_shards=L, out_slots=1,
            buf_init=MemberLookup(1, ar=1, ap=1, bp=-1),
            out_init=(
                AtDevices((ring[0],), ring[0]) if S == 1
                else AtDevices((), width=1)
            ),
            steps=tuple(steps), groups=orders,
        ).validate()

    out_slots = K
    if S == 1:
        # No intra phase: seed the group slots straight from the input.
        buf_init = AtDevices((), width=K)
        out_init = MemberLookup(K, er=1)  # out_init[d][j] = orders[j][0]
    else:
        # buf_init[d][j] = orders[j][(pos - 1) % S]
        buf_init = MemberLookup(K, er=1, ap=1, bp=-1)
        out_init = AtDevices((), width=K)
        for s in range(1, S):
            steps.append(Step(
                edges=intra, width=K, tag="intra", combine=ADD,
                add_src=MemberLookup(K, er=1, ap=1, bp=-s - 1),
                write=Affine(K, e=1, m=K) if s == S - 1 else None,
            ))
    for c in range(1, K):
        steps.append(Step(
            edges=cross, width=1, tag="cross", combine=ADD,
            add_from="out",
            add_src=Affine(1, c=1, b=-c - 1, m=K),
            load=Affine(1, c=1, b=-1, m=K) if c == 1 else None,
            write=Affine(1) if c == K - 1 else None,
        ))
    return ChainProgram(
        collective="reduce_scatter", kind="stepped", num_devices=L,
        addr_shards=L, out_slots=out_slots,
        buf_init=buf_init, out_init=out_init,
        steps=tuple(steps), groups=orders,
    ).validate()


def plan_all_reduce(
    num_devices: int,
    orders: tuple[tuple[int, ...], ...],
    algo: str = "rs_ag",
    wire_dtype: str | None = None,
) -> ChainProgram:
    """All-reduce over K sub-rings (see module docstring for the two
    schedules). K=1 is the single-ring reduce-scatter + all-gather
    with *device-id* chunk addressing for either ``algo`` — the
    historical ``chain_all_reduce`` schedule, kept so its fold order
    (and therefore every bit-exactness pin) is unchanged.
    ``wire_dtype="int8"`` ships every hop quantized (per-hop int8 frame
    + f32 scale); it composes with any (K, algo). The wire variants
    share ONE cached plan (:meth:`ChainProgram.with_wire_dtype`)."""
    return _plan_all_reduce(num_devices, orders, algo).with_wire_dtype(
        wire_dtype
    )


@functools.lru_cache(maxsize=_PLANNER_CACHE_MAXSIZE)
def _plan_all_reduce(
    num_devices: int,
    orders: tuple[tuple[int, ...], ...],
    algo: str,
) -> ChainProgram:
    if algo not in ALL_REDUCE_ALGOS:
        raise ValueError(f"unknown algo {algo!r}; expected {ALL_REDUCE_ALGOS}")
    L = int(num_devices)
    orders = _check_rings(L, orders)
    K, S = len(orders), len(orders[0])
    intra, cross, _pos, _ring_of = _ring_maps(orders)
    steps: list[Step] = []

    if K == 1 and S == L:
        # The full-axis single ring keeps the historical device-id
        # addressing (chunk i = device i's chunk). A *subset* ring —
        # simulator-only, the SPMD layer requires a full partition —
        # falls through to the position-addressed schedules below, so
        # its shard size is payload/S, not payload/num_devices.
        own = MemberLookup(1, ar=1, ap=1)  # slot = device id
        for s in range(1, S):  # reduce-scatter (device-id chunks)
            steps.append(Step(
                edges=intra, width=1, tag="intra", combine=ADD,
                add_src=MemberLookup(1, ar=1, ap=1, bp=-s - 1),
                write=own if s == S - 1 else None,
            ))
        for s in range(1, S):  # all-gather
            steps.append(Step(
                edges=intra, width=1, tag="intra",
                write=MemberLookup(1, ar=1, ap=1, bp=-s),
            ))
        return ChainProgram(
            collective="all_reduce", kind="stepped", num_devices=L,
            addr_shards=L, out_slots=L,
            buf_init=MemberLookup(1, ar=1, ap=1, bp=-1),
            out_init=Diag(L, own) if S == 1 else AtDevices((), width=L),
            steps=tuple(steps), groups=orders, algo=algo,
        ).validate()

    if algo == "rotation" or S == 1:
        # Full-payload rotations (S=1 rs_ag degenerates to the same
        # cross-only schedule: there is nothing to shard over).
        acc = Affine(1)  # members address frame/slot 0
        for _s in range(1, S):
            steps.append(Step(
                edges=intra, width=1, tag="intra",
                write=acc, write_op=ADD,
            ))
        for c in range(1, K):
            steps.append(Step(
                edges=cross, width=1, tag="cross",
                load=acc if c == 1 else None, write=acc, write_op=ADD,
            ))
        return ChainProgram(
            collective="all_reduce", kind="stepped", num_devices=L,
            addr_shards=1, out_slots=1,
            buf_init=acc, out_init=acc,
            steps=tuple(steps), groups=orders, algo=algo,
        ).validate()

    # rs_ag, K > 1, S > 1: shards addressed by ring position.
    pos_write = Affine(1, a=1, m=S)  # slot = own ring position
    for s in range(1, S):  # fused per-ring reduce-scatter
        steps.append(Step(
            edges=intra, width=1, tag="intra", combine=ADD,
            add_src=Affine(1, a=1, b=-s - 1, m=S),
            write=pos_write if s == S - 1 else None,
        ))
    for _c in range(1, K):  # cross-ring shard rotation (accumulating)
        steps.append(Step(
            edges=cross, width=1, tag="cross",
            write=pos_write, write_op=ADD,
        ))
    for s in range(1, S):  # fused per-ring all-gather
        steps.append(Step(
            edges=intra, width=1, tag="intra",
            load=pos_write if s == 1 else None,
            write=Affine(1, a=1, b=-s, m=S),
        ))
    return ChainProgram(
        collective="all_reduce", kind="stepped", num_devices=L,
        addr_shards=S, out_slots=S,
        buf_init=Affine(1, a=1, b=-1, m=S),
        out_init=AtDevices((), width=S),
        steps=tuple(steps), groups=orders, algo=algo,
    ).validate()


def plan_all_to_all(
    num_devices: int,
    orders: tuple[tuple[int, ...], ...],
    wire_dtype: str | None = None,
) -> ChainProgram:
    """All-to-all (MoE dispatch): chunk ``j`` of each device's train is
    destined to device ``j``. The train rotates whole; each device
    peels the chunk addressed to it every step. K > 1 interleaves
    intra-ring rotations with cross-ring hops — (K·(S-1) + (K-1)) =
    L-1 steps either way (a chunk train cannot shrink), but every hop
    stays ring-local/position-paired. ``wire_dtype="int8"`` ships the
    rotating train quantized (per-hop int8 frame + f32 scale). The
    wire variants share ONE cached plan
    (:meth:`ChainProgram.with_wire_dtype`)."""
    return _plan_all_to_all(num_devices, orders).with_wire_dtype(wire_dtype)


@functools.lru_cache(maxsize=_PLANNER_CACHE_MAXSIZE)
def _plan_all_to_all(
    num_devices: int,
    orders: tuple[tuple[int, ...], ...],
) -> ChainProgram:
    L = int(num_devices)
    orders = _check_rings(L, orders)
    K, S = len(orders), len(orders[0])
    intra, cross, _pos, _ring_of = _ring_maps(orders)

    def peel(j: int, t: int) -> Diag:
        # write[d][d] = orders[(ring - j) % K][(pos - t) % S]: the train
        # at (ring, pos) originated j cross hops / t intra hops back.
        return Diag(L, MemberLookup(1, ar=1, br=-j, ap=1, bp=-t))

    steps: list[Step] = []
    for j in range(K):
        # After j cross hops and t intra hops the train at device (c, p)
        # originated at ring (c - j), position (p - t) — the intra
        # offset accumulates across stages.
        if j > 0:
            steps.append(Step(
                edges=cross, width=L, tag="cross",
                write=peel(j, j * (S - 1)),
            ))
        for s in range(1, S):
            steps.append(Step(
                edges=intra, width=L, tag="intra",
                write=peel(j, j * (S - 1) + s),
            ))
    return ChainProgram(
        collective="all_to_all", kind="stepped", num_devices=L,
        addr_shards=L, out_slots=L,
        buf_init=Affine(L, e=1, m=L),  # chunk train: iota row
        out_init=Diag(L, MemberLookup(1, ar=1, ap=1)),  # own chunk
        steps=tuple(steps), groups=orders,
    ).validate()


# ---------------------------------------------------------------------------
# Planner cache instrumentation
# ---------------------------------------------------------------------------

# The memoized planner entry points (public name -> cached callable).
# Keys must stay COMPLETE: every argument that changes the emitted
# program is part of the cache key (regression-tested).
PLANNER_CACHES = {
    "plan_broadcast": plan_broadcast,
    "plan_recovery": _plan_recovery_cached,
    "plan_all_gather": plan_all_gather,
    "plan_reduce_scatter": plan_reduce_scatter,
    "plan_all_reduce": _plan_all_reduce,
    "plan_all_to_all": _plan_all_to_all,
}


def planner_cache_stats() -> dict[str, dict[str, int]]:
    """Per-planner ``lru_cache`` statistics (hits/misses/maxsize/
    currsize) — the observability hook for cache sizing."""
    return {
        name: fn.cache_info()._asdict()
        for name, fn in PLANNER_CACHES.items()
    }


def clear_planner_caches() -> None:
    """Drop every memoized plan (benchmarks time cold planning)."""
    for fn in PLANNER_CACHES.values():
        fn.cache_clear()


def _ceil_div(a: int, b: int) -> int:
    return -(-a // b)
