"""Pure-numpy oracles for the Chainwrite collectives.

Two layers of oracle live here:

* **Semantic oracles** (``broadcast_ref``, ``all_gather_ref``,
  ``reduce_scatter_ref``, ``all_reduce_ref``, ``all_to_all_ref``, ...)
  state what each collective must *compute*, independent of any
  schedule — the ground truth the planners are checked against.

* **The program interpreter** (:func:`interpret_program` /
  :func:`run_program_ref`) replays any
  :class:`~repro.core.program.ChainProgram` step for step on the
  global ``(L, ...)`` view — the numpy twin of
  ``chainwrite.execute_program``. Because both backends interpret the
  SAME program (same permutes, same left-folded additions), the SPMD
  collectives are pinned BIT-exactly against it: float addition is not
  associative, so value equality up to reassociation would hide
  scheduling bugs. This one interpreter replaces the hand-written
  per-collective replays that previously lived here.

Each function takes the *global* view — ``xs[d]`` is device ``d``'s
input along the axis — and returns the global stacked outputs.
Used by tests/test_chainwrite_collectives.py and friends.
"""

from __future__ import annotations

from typing import Sequence

import numpy as np

from . import program as prg

# Canonical multi-ring all-reduce schedule names (re-exported from the
# schedule IR so the SPMD layer, the simulator and the CLI keep
# validating against ONE tuple).
ALL_REDUCE_ALGOS = prg.ALL_REDUCE_ALGOS


def broadcast_ref(
    xs: np.ndarray, order: Sequence[int]
) -> np.ndarray:
    """xs: (L, ...) per-device inputs. Devices in ``order`` end with the
    head's payload; everyone else ends with zeros."""
    out = np.zeros_like(xs)
    head = order[0]
    for d in order:
        out[d] = xs[head]
    return out


def multi_broadcast_ref(
    xs: np.ndarray, head: int, chains: Sequence[Sequence[int]]
) -> np.ndarray:
    """Oracle for ``multi_chain_broadcast``: the head and every member
    of any sub-chain end with the head's payload; everyone else ends
    with zeros. Chain structure/frames affect latency, not values."""
    out = np.zeros_like(xs)
    out[head] = xs[head]
    for chain in chains:
        for d in chain:
            out[d] = xs[head]
    return out


def degraded_multi_broadcast_ref(
    xs: np.ndarray, head: int, chains: Sequence[Sequence[int]], failed
) -> np.ndarray:
    """Oracle for ``degraded_multi_chain_broadcast``: the head and every
    *surviving* chain member end with the head's payload; the failed
    node(s) — like any non-member — end with zeros. ``failed`` is one
    node id or a set of concurrently dead members."""
    dead = (
        {int(failed)}
        if isinstance(failed, (int, np.integer))
        else {int(f) for f in failed}
    )
    out = np.zeros_like(xs)
    out[head] = xs[head]
    for chain in chains:
        for d in chain:
            if d not in dead:
                out[d] = xs[head]
    return out


def all_gather_ref(xs: np.ndarray, tiled: bool = False) -> np.ndarray:
    """Every device ends with the full stack (device-id indexed) —
    independent of ring order."""
    L = xs.shape[0]
    full = xs if not tiled else xs.reshape((L * xs.shape[1],) + xs.shape[2:])
    return np.stack([full] * L)


def reduce_scatter_ref(xs: np.ndarray) -> np.ndarray:
    """xs: (L, L, chunk...) — xs[d][j] is device d's contribution to
    chunk j. Device d ends with sum_d' xs[d'][d]."""
    L = xs.shape[0]
    total = xs.sum(axis=0)  # (L, chunk...)
    return np.stack([total[d] for d in range(L)])


def all_reduce_ref(xs: np.ndarray) -> np.ndarray:
    """Every device ends with the elementwise sum."""
    total = xs.sum(axis=0)
    return np.stack([total] * xs.shape[0])


def all_to_all_ref(xs: np.ndarray) -> np.ndarray:
    """xs: (L, L, chunk...) — xs[s][d] is the chunk device s sends to
    device d. Device d ends with out[s] = xs[s][d] (transpose)."""
    return np.swapaxes(xs, 0, 1)


# ---------------------------------------------------------------------------
# The numpy program interpreter
# ---------------------------------------------------------------------------


def _is_float_dtype(dt) -> bool:
    """True for numpy floats AND the ml_dtypes extension floats
    (bfloat16, float8_*) that ``np.issubdtype`` does not classify."""
    dt = np.dtype(dt)
    if np.issubdtype(dt, np.floating):
        return True
    try:
        import ml_dtypes

        ml_dtypes.finfo(dt)
        return True
    except (ImportError, ValueError):
        return False


def _quantize_ref(x: np.ndarray) -> tuple[np.ndarray, np.float32]:
    """Numpy twin of ``repro.runtime.compression.quantize``: identical
    f32 arithmetic (f32 max, power-of-two divisor, round-half-to-even,
    17-bit scale mantissa), so the wire replay is bit-exact against the
    SPMD executor: the /128 divisor makes XLA's divide-by-constant →
    multiply-by-reciprocal rewrite exact, and the truncated scale makes
    every dequantize product exact in f32, which neutralises FMA
    contraction of dequantize-mul + accumulate-add."""
    x = np.asarray(x, np.float32)
    scale = np.float32(
        np.max(np.abs(x)) / np.float32(128.0) + np.float32(1e-12)
    )
    scale = np.float32(
        (np.asarray(scale, np.float32).view(np.uint32) & np.uint32(0xFFFFFF80))
        .view(np.float32)
    )
    q = np.clip(np.round(x / scale), -127.0, 127.0).astype(np.int8)
    return q, scale


def _dequantize_ref(q: np.ndarray, scale: np.float32) -> np.ndarray:
    return q.astype(np.float32) * scale


def interpret_program(shards: np.ndarray, prog: prg.ChainProgram) -> np.ndarray:
    """Replay ``prog`` on the global pre-blocked view ``shards``
    (``(L, addr_shards, m, ...)``); returns the global out slots
    ``(L, out_slots, m, ...)``. Implements the machine model documented
    in :mod:`repro.core.program` verbatim — the numpy twin of
    ``chainwrite.execute_program``."""
    L = prog.num_devices
    if shards.shape[0] != L or shards.shape[1] != prog.addr_shards:
        raise ValueError(
            f"shards {shards.shape} incompatible with program "
            f"(L={L}, addr_shards={prog.addr_shards})"
        )
    inner = shards.shape[2:]
    wires = [prog.step_wire_dtype(s) for s in prog.steps]
    orig_dtype = shards.dtype
    if any(w is not None for w in wires):
        # Mirror the executor: the compressed wire computes in f32.
        if not _is_float_dtype(shards.dtype):
            raise ValueError(
                f"wire_dtype='int8' requires a floating payload, "
                f"got {shards.dtype}"
            )
        shards = shards.astype(np.float32)

    def rows(table, source, keep=None):
        # Symbolic tables materialize lazily — the replay (and thus
        # every bit-exactness pin) is identical to the dense form.
        table = prg.resolve_table(prog, table)
        width = len(table[0])
        out = np.zeros((L, width) + inner, shards.dtype)
        for d in range(L):
            for j in range(width):
                v = table[d][j]
                if v >= 0:
                    out[d, j] = source[d, v]
                elif keep is not None and keep.shape[1] == width:
                    out[d, j] = keep[d, j]
        return out

    buf = rows(prog.buf_init, shards)
    out = rows(prog.out_init, shards)
    for step, wire in zip(prog.steps, wires):
        if step.load is not None:
            buf = rows(step.load, out, keep=buf)
        new = np.zeros((L, step.width) + inner, shards.dtype)
        if wire == "int8":
            # Per-hop quantized wire: every device quantizes its whole
            # buf with one f32 scale; the destination dequantizes.
            # Non-targets keep zeros — dequantize(0, 0) = 0 in SPMD.
            qs = [_quantize_ref(buf[d]) for d in range(L)]
            for src, dst in step.edges:
                new[dst] = _dequantize_ref(*qs[src])
        else:
            for src, dst in step.edges:
                new[dst] = buf[src]
        buf = new
        if step.combine == prg.ADD:
            source = shards if step.add_from == "input" else out
            buf = buf + rows(step.add_src, source)
        if step.write is not None:
            write_tbl = prg.resolve_table(prog, step.write)
            for d in range(L):
                for j in range(step.width):
                    slot = write_tbl[d][j]
                    if slot >= 0:
                        if step.write_op == prg.COPY:
                            out[d, slot] = buf[d, j]
                        else:
                            out[d, slot] = out[d, slot] + buf[d, j]
    return out.astype(orig_dtype)


def run_program_ref(
    xs: np.ndarray, prog: prg.ChainProgram, *, tiled: bool = False
) -> np.ndarray:
    """:func:`interpret_program` plus the same per-collective input
    blocking / output assembly as ``chainwrite.execute_program`` —
    global in, global out."""
    L = prog.num_devices
    if xs.shape[0] != L:
        raise ValueError(f"global view has {xs.shape[0]} rows, expected {L}")
    c = prog.collective
    if c in ("broadcast", "all_gather"):
        out = interpret_program(xs[:, None], prog)
        if c == "broadcast":
            return out[:, 0]
        if tiled:
            return out.reshape((L, L * xs.shape[1]) + xs.shape[2:])
        return out
    if c in ("reduce_scatter", "all_to_all"):
        if xs.shape[1] != L:
            raise ValueError(f"leading dim {xs.shape[1]} != axis size {L}")
        out = interpret_program(xs, prog)
        return out[:, 0] if c == "reduce_scatter" else out
    if c == "all_reduce":
        S = prog.addr_shards
        lead = xs.shape[1]
        pad = (-lead) % S
        xp = (
            np.pad(xs, [(0, 0), (0, pad)] + [(0, 0)] * (xs.ndim - 2))
            if pad
            else xs
        )
        shards = xp.reshape((L, S, xp.shape[1] // S) + xs.shape[2:])
        out = interpret_program(shards, prog)
        if prog.out_slots == 1:  # rotation: whole payload in one slot
            full = out[:, 0]
        else:
            full = out.reshape((L, out.shape[1] * out.shape[2]) + xs.shape[2:])
        return full[:, :lead] if pad else full
    raise ValueError(f"unknown collective {c!r}")


def multi_all_reduce_ref(
    xs: np.ndarray, orders, algo: str = "rs_ag",
    wire_dtype: str | None = None,
) -> np.ndarray:
    """Oracle for ``multi_chain_all_reduce``: plans the same
    :class:`ChainProgram` the SPMD collective executes and replays it
    with :func:`run_program_ref`, so the result matches bit-exactly —
    including every per-hop quantization when ``wire_dtype="int8"``.
    ``xs`` is the (L, n, ...) global view. K=1 is — like the SPMD
    implementation — the single-ring reduce-scatter + all-gather with
    device-id chunk addressing, for either ``algo``.
    """
    orders = tuple(tuple(int(d) for d in c) for c in orders if len(c))
    if not orders:
        raise ValueError("empty ring set")
    if algo not in ALL_REDUCE_ALGOS:
        raise ValueError(f"unknown algo {algo!r}; expected {ALL_REDUCE_ALGOS}")
    prog = prg.plan_all_reduce(xs.shape[0], orders, algo, wire_dtype=wire_dtype)
    return run_program_ref(xs, prog)


def multi_reduce_scatter_ref(xs: np.ndarray, orders) -> np.ndarray:
    """Schedule-replaying oracle for ``multi_chain_reduce_scatter``."""
    orders = tuple(tuple(int(d) for d in c) for c in orders if len(c))
    prog = prg.plan_reduce_scatter(xs.shape[0], orders)
    return run_program_ref(xs, prog)


def multi_all_gather_ref(
    xs: np.ndarray, orders, tiled: bool = False
) -> np.ndarray:
    """Schedule-replaying oracle for ``multi_chain_all_gather``."""
    orders = tuple(tuple(int(d) for d in c) for c in orders if len(c))
    prog = prg.plan_all_gather(xs.shape[0], orders)
    return run_program_ref(xs, prog, tiled=tiled)


def multi_all_to_all_ref(
    xs: np.ndarray, orders, wire_dtype: str | None = None
) -> np.ndarray:
    """Schedule-replaying oracle for ``multi_chain_all_to_all``."""
    orders = tuple(tuple(int(d) for d in c) for c in orders if len(c))
    prog = prg.plan_all_to_all(xs.shape[0], orders, wire_dtype=wire_dtype)
    return run_program_ref(xs, prog)
