"""Pure-numpy oracles for the Chainwrite collectives.

Each function takes the *global* view — ``xs[d]`` is device ``d``'s
input along the axis — and returns the global stacked outputs, defining
the semantics :mod:`.chainwrite` must match for any scheduled order.
Used by tests/test_chainwrite_collectives.py.
"""

from __future__ import annotations

from typing import Sequence

import numpy as np

# Canonical multi-ring all-reduce schedule names. Defined here (the
# dependency-light numpy module) so the SPMD layer, the simulator and
# the CLI all validate against ONE tuple.
ALL_REDUCE_ALGOS = ("rs_ag", "rotation")


def broadcast_ref(
    xs: np.ndarray, order: Sequence[int]
) -> np.ndarray:
    """xs: (L, ...) per-device inputs. Devices in ``order`` end with the
    head's payload; everyone else ends with zeros."""
    out = np.zeros_like(xs)
    head = order[0]
    for d in order:
        out[d] = xs[head]
    return out


def multi_broadcast_ref(
    xs: np.ndarray, head: int, chains: Sequence[Sequence[int]]
) -> np.ndarray:
    """Oracle for ``multi_chain_broadcast``: the head and every member
    of any sub-chain end with the head's payload; everyone else ends
    with zeros. Chain structure/frames affect latency, not values."""
    out = np.zeros_like(xs)
    out[head] = xs[head]
    for chain in chains:
        for d in chain:
            out[d] = xs[head]
    return out


def degraded_multi_broadcast_ref(
    xs: np.ndarray, head: int, chains: Sequence[Sequence[int]], failed: int
) -> np.ndarray:
    """Oracle for ``degraded_multi_chain_broadcast``: the head and every
    *surviving* chain member end with the head's payload; the failed
    node — like any non-member — ends with zeros."""
    out = np.zeros_like(xs)
    out[head] = xs[head]
    for chain in chains:
        for d in chain:
            if d != failed:
                out[d] = xs[head]
    return out


def all_gather_ref(xs: np.ndarray, tiled: bool = False) -> np.ndarray:
    """Every device ends with the full stack (device-id indexed) —
    independent of ring order."""
    L = xs.shape[0]
    full = xs if not tiled else xs.reshape((L * xs.shape[1],) + xs.shape[2:])
    return np.stack([full] * L)


def reduce_scatter_ref(xs: np.ndarray) -> np.ndarray:
    """xs: (L, L, chunk...) — xs[d][j] is device d's contribution to
    chunk j. Device d ends with sum_d' xs[d'][d]."""
    L = xs.shape[0]
    total = xs.sum(axis=0)  # (L, chunk...)
    return np.stack([total[d] for d in range(L)])


def all_reduce_ref(xs: np.ndarray) -> np.ndarray:
    """Every device ends with the elementwise sum."""
    total = xs.sum(axis=0)
    return np.stack([total] * xs.shape[0])


def all_to_all_ref(xs: np.ndarray) -> np.ndarray:
    """xs: (L, L, chunk...) — xs[s][d] is the chunk device s sends to
    device d. Device d ends with out[s] = xs[s][d] (transpose)."""
    return np.swapaxes(xs, 0, 1)


# ---------------------------------------------------------------------------
# Schedule-simulating multi-ring all-reduce oracles
# ---------------------------------------------------------------------------
#
# ``all_reduce_ref`` defines the *semantics* (sum everywhere); the
# oracles below additionally replay the exact per-step permute/add
# order of ``chainwrite.multi_chain_all_reduce``'s two schedules, so
# tests can pin the SPMD collectives BIT-exactly (float addition is not
# associative — value equality up to reassociation would hide
# scheduling bugs).


def _permute(bufs: np.ndarray, edges) -> np.ndarray:
    """Numpy twin of ``lax.ppermute``: dst receives src's buffer;
    devices no edge targets receive zeros."""
    out = np.zeros_like(bufs)
    for src, dst in edges:
        out[dst] = bufs[src]
    return out


def _ring_maps(orders):
    """(intra_edges, cross_edges, pos) for K equal-size rings."""
    orders = [tuple(int(d) for d in c) for c in orders]
    K, S = len(orders), len(orders[0])
    L = K * S
    intra = [
        (c[p], c[(p + 1) % S]) for c in orders for p in range(S)
    ] if S > 1 else []
    cross = [
        (orders[c][r], orders[(c + 1) % K][r])
        for c in range(K)
        for r in range(S)
    ]
    pos = np.zeros(L, dtype=int)
    for c in orders:
        for p, d in enumerate(c):
            pos[d] = p
    return intra, cross, pos


def multi_all_reduce_ref(
    xs: np.ndarray, orders, algo: str = "rs_ag"
) -> np.ndarray:
    """Oracle for ``multi_chain_all_reduce``: replays the schedule
    step-for-step (same permutes, same left-folded additions) so the
    SPMD result matches bit-exactly. ``xs`` is the (L, n, ...) global
    view. K=1 delegates — like the SPMD implementation — to the
    single-ring reduce-scatter + all-gather for either ``algo``.
    """
    orders = [tuple(int(d) for d in c) for c in orders if len(c)]
    if not orders:
        raise ValueError("empty ring set")
    if algo not in ALL_REDUCE_ALGOS:
        raise ValueError(f"unknown algo {algo!r}; expected {ALL_REDUCE_ALGOS}")
    if len(orders) == 1:
        return _chain_rs_ag_ref(xs, orders[0])
    if algo == "rotation":
        return _multi_rotation_ref(xs, orders)
    return _multi_rs_ag_ref(xs, orders)


def _chain_rs_ag_ref(xs: np.ndarray, order) -> np.ndarray:
    """Replays ``chain_all_reduce`` (single-ring reduce-scatter +
    all-gather) exactly: chunks are addressed by *device id* — the K=1
    delegation path of ``multi_chain_all_reduce`` — which for scheduled
    (non-identity) ring orders folds each chunk's additions along a
    different ring segment than position addressing would."""
    order = tuple(int(d) for d in order)
    L = xs.shape[0]
    lead = xs.shape[1]
    padw = (-lead) % L
    xp = (
        np.pad(xs, [(0, 0), (0, padw)] + [(0, 0)] * (xs.ndim - 2))
        if padw
        else xs
    )
    m = xp.shape[1] // L
    chunks = xp.reshape((L, L, m) + xs.shape[2:])
    pos = np.zeros(L, dtype=int)
    for p, d in enumerate(order):
        pos[d] = p
    edges = list(zip(order, order[1:])) + (
        [(order[-1], order[0])] if L > 1 else []
    )

    buf = np.stack([chunks[d][order[(pos[d] - 1) % L]] for d in range(L)])
    for s in range(1, L):
        buf = _permute(buf, edges)
        buf = buf + np.stack(
            [chunks[d][order[(pos[d] - s - 1) % L]] for d in range(L)]
        )

    out = np.zeros_like(chunks)
    for d in range(L):
        out[d][d] = buf[d]
    gbuf = buf.copy()
    for s in range(1, L):
        gbuf = _permute(gbuf, edges)
        for d in range(L):
            out[d][order[(pos[d] - s) % L]] = gbuf[d]
    full = out.reshape((L, L * m) + xs.shape[2:])
    return full[:, :lead] if padw else full


def _multi_rotation_ref(xs: np.ndarray, orders) -> np.ndarray:
    K, S = len(orders), len(orders[0])
    intra, cross, _ = _ring_maps(orders)
    acc = xs.copy()
    buf = xs.copy()
    for _ in range(S - 1):
        buf = _permute(buf, intra)
        acc = acc + buf
    out = acc.copy()
    buf = acc.copy()
    for _ in range(K - 1):
        buf = _permute(buf, cross)
        out = out + buf
    return out


def _multi_rs_ag_ref(xs: np.ndarray, orders) -> np.ndarray:
    """RS -> cross-ring shard rotation -> AG, shards addressed by ring
    position. With K=1 this replays ``chain_all_reduce``'s single-ring
    reduce-scatter + all-gather add order exactly (the K=1 delegation
    path), since both accumulate each shard along the ring traversal."""
    L = xs.shape[0]
    K, S = len(orders), len(orders[0])
    intra, cross, pos = _ring_maps(orders)
    lead = xs.shape[1]
    padw = (-lead) % S
    xp = (
        np.pad(xs, [(0, 0), (0, padw)] + [(0, 0)] * (xs.ndim - 2))
        if padw
        else xs
    )
    m = xp.shape[1] // S
    shards = xp.reshape((L, S, m) + xs.shape[2:])

    buf = np.stack([shards[d][(pos[d] - 1) % S] for d in range(L)])
    for s in range(1, S):
        buf = _permute(buf, intra)
        buf = buf + np.stack(
            [shards[d][(pos[d] - s - 1) % S] for d in range(L)]
        )
    acc = buf.copy()
    for _ in range(K - 1):
        buf = _permute(buf, cross)
        acc = acc + buf

    out = np.zeros_like(shards)
    for d in range(L):
        out[d][pos[d]] = acc[d]
    buf = acc.copy()
    for s in range(1, S):
        buf = _permute(buf, intra)
        for d in range(L):
            out[d][(pos[d] - s) % S] = buf[d]
    full = out.reshape((L, S * m) + xs.shape[2:])
    return full[:, :lead] if padw else full
