"""Pure-numpy oracles for the Chainwrite collectives.

Each function takes the *global* view — ``xs[d]`` is device ``d``'s
input along the axis — and returns the global stacked outputs, defining
the semantics :mod:`.chainwrite` must match for any scheduled order.
Used by tests/test_chainwrite_collectives.py.
"""

from __future__ import annotations

from typing import Sequence

import numpy as np


def broadcast_ref(
    xs: np.ndarray, order: Sequence[int]
) -> np.ndarray:
    """xs: (L, ...) per-device inputs. Devices in ``order`` end with the
    head's payload; everyone else ends with zeros."""
    out = np.zeros_like(xs)
    head = order[0]
    for d in order:
        out[d] = xs[head]
    return out


def multi_broadcast_ref(
    xs: np.ndarray, head: int, chains: Sequence[Sequence[int]]
) -> np.ndarray:
    """Oracle for ``multi_chain_broadcast``: the head and every member
    of any sub-chain end with the head's payload; everyone else ends
    with zeros. Chain structure/frames affect latency, not values."""
    out = np.zeros_like(xs)
    out[head] = xs[head]
    for chain in chains:
        for d in chain:
            out[d] = xs[head]
    return out


def degraded_multi_broadcast_ref(
    xs: np.ndarray, head: int, chains: Sequence[Sequence[int]], failed: int
) -> np.ndarray:
    """Oracle for ``degraded_multi_chain_broadcast``: the head and every
    *surviving* chain member end with the head's payload; the failed
    node — like any non-member — ends with zeros."""
    out = np.zeros_like(xs)
    out[head] = xs[head]
    for chain in chains:
        for d in chain:
            if d != failed:
                out[d] = xs[head]
    return out


def all_gather_ref(xs: np.ndarray, tiled: bool = False) -> np.ndarray:
    """Every device ends with the full stack (device-id indexed) —
    independent of ring order."""
    L = xs.shape[0]
    full = xs if not tiled else xs.reshape((L * xs.shape[1],) + xs.shape[2:])
    return np.stack([full] * L)


def reduce_scatter_ref(xs: np.ndarray) -> np.ndarray:
    """xs: (L, L, chunk...) — xs[d][j] is device d's contribution to
    chunk j. Device d ends with sum_d' xs[d'][d]."""
    L = xs.shape[0]
    total = xs.sum(axis=0)  # (L, chunk...)
    return np.stack([total[d] for d in range(L)])


def all_reduce_ref(xs: np.ndarray) -> np.ndarray:
    """Every device ends with the elementwise sum."""
    total = xs.sum(axis=0)
    return np.stack([total] * xs.shape[0])


def all_to_all_ref(xs: np.ndarray) -> np.ndarray:
    """xs: (L, L, chunk...) — xs[s][d] is the chunk device s sends to
    device d. Device d ends with out[s] = xs[s][d] (transpose)."""
    return np.swapaxes(xs, 0, 1)
