"""Torrent core — the paper's contribution in JAX.

* :mod:`.topology`   — 2-D mesh/torus + XY routing (SoC NoC and ICI).
* :mod:`.scheduling` — Chainwrite sequence schedulers (Alg. 1 greedy,
  open-path TSP) and hop accounting.
* :mod:`.simulator`  — cycle-level NoC model (Fig. 5/6/7 reproduction).
* :mod:`.chainwrite` — Chainwrite collectives on TPU ICI
  (scheduled ppermute chains inside shard_map).
* :mod:`.chaintask`  — host-side four-phase orchestration (Fig. 4).
"""

from .chainwrite import (
    ALL_REDUCE_ALGOS,
    chain_all_gather,
    chain_all_reduce,
    chain_all_to_all,
    chain_broadcast,
    chain_edges,
    chain_reduce_scatter,
    multi_chain_all_reduce,
    multi_chain_broadcast,
    validate_ring_partition,
    xla_broadcast,
)
from .chaintask import (
    AffinePattern,
    ChainConfig,
    ChainTask,
    MultiChainTask,
    Phase,
)
from .scheduling import (
    SCHEDULERS,
    brute_force_schedule,
    chain_total_hops,
    greedy_schedule,
    multicast_total_hops,
    naive_schedule,
    partition_balance_slack,
    partition_schedule,
    partition_total_hops,
    tsp_schedule,
    unicast_total_hops,
)
from .simulator import (
    DEFAULT_PARAMS,
    SimParams,
    all_reduce_latency,
    all_reduce_wire_bytes,
    chainwrite_latency,
    choose_num_chains,
    config_overhead_per_destination,
    eta_p2mp,
    multi_chain_latency,
    multicast_latency,
    p2mp_efficiency_point,
    p2p_latency,
    unicast_latency,
)
from .topology import MeshTopology

__all__ = [
    "ALL_REDUCE_ALGOS",
    "AffinePattern",
    "ChainConfig",
    "ChainTask",
    "DEFAULT_PARAMS",
    "MeshTopology",
    "Phase",
    "SCHEDULERS",
    "SimParams",
    "all_reduce_latency",
    "all_reduce_wire_bytes",
    "brute_force_schedule",
    "chain_all_gather",
    "chain_all_reduce",
    "chain_all_to_all",
    "chain_broadcast",
    "chain_edges",
    "chain_reduce_scatter",
    "chain_total_hops",
    "chainwrite_latency",
    "config_overhead_per_destination",
    "eta_p2mp",
    "choose_num_chains",
    "greedy_schedule",
    "multi_chain_all_reduce",
    "multi_chain_broadcast",
    "multi_chain_latency",
    "MultiChainTask",
    "multicast_latency",
    "multicast_total_hops",
    "naive_schedule",
    "p2mp_efficiency_point",
    "p2p_latency",
    "partition_balance_slack",
    "partition_schedule",
    "partition_total_hops",
    "tsp_schedule",
    "unicast_latency",
    "unicast_total_hops",
    "validate_ring_partition",
    "xla_broadcast",
]
