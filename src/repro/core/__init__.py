"""Torrent core — the paper's contribution in JAX.

* :mod:`.topology`   — 2-D mesh/torus + XY routing (SoC NoC and ICI).
* :mod:`.scheduling` — Chainwrite sequence schedulers (Alg. 1 greedy,
  open-path TSP) and hop accounting.
* :mod:`.program`    — the ChainProgram schedule IR + ``plan_*``
  planners: every collective described ONCE, consumed by three
  interchangeable backends.
* :mod:`.simulator`  — cycle-level NoC model (Fig. 5/6/7 reproduction)
  — drives the IR via ``program_latency``/``program_wire_bytes``.
* :mod:`.chainwrite` — Chainwrite collectives on TPU ICI
  (the generic SPMD program executor inside shard_map).
* :mod:`.chaintask`  — host-side four-phase orchestration (Fig. 4).
"""

from .chainwrite import (
    ALL_REDUCE_ALGOS,
    chain_all_gather,
    chain_all_reduce,
    chain_all_to_all,
    chain_broadcast,
    chain_edges,
    chain_reduce_scatter,
    execute_program,
    multi_chain_all_gather,
    multi_chain_all_reduce,
    multi_chain_all_to_all,
    multi_chain_broadcast,
    multi_chain_reduce_scatter,
    validate_ring_partition,
    xla_broadcast,
)
from .program import (
    ChainProgram,
    Step,
    plan_all_gather,
    plan_all_reduce,
    plan_all_to_all,
    plan_broadcast,
    plan_reduce_scatter,
    program_wire_bytes,
)
from .chaintask import (
    AffinePattern,
    ChainConfig,
    ChainTask,
    MultiChainTask,
    Phase,
)
from .scheduling import (
    SCHEDULERS,
    brute_force_schedule,
    chain_total_hops,
    greedy_schedule,
    multicast_total_hops,
    naive_schedule,
    partition_balance_slack,
    partition_schedule,
    partition_total_hops,
    tsp_schedule,
    unicast_total_hops,
)
from .simulator import (
    DEFAULT_PARAMS,
    SimParams,
    all_reduce_latency,
    all_reduce_wire_bytes,
    chainwrite_latency,
    choose_num_chains,
    plan_ring_collective,
    program_latency,
    config_overhead_per_destination,
    eta_p2mp,
    multi_chain_latency,
    multicast_latency,
    p2mp_efficiency_point,
    p2p_latency,
    unicast_latency,
)
from .topology import MeshTopology

__all__ = [
    "ALL_REDUCE_ALGOS",
    "AffinePattern",
    "ChainConfig",
    "ChainTask",
    "DEFAULT_PARAMS",
    "MeshTopology",
    "Phase",
    "SCHEDULERS",
    "SimParams",
    "ChainProgram",
    "Step",
    "all_reduce_latency",
    "all_reduce_wire_bytes",
    "brute_force_schedule",
    "chain_all_gather",
    "chain_all_reduce",
    "chain_all_to_all",
    "chain_broadcast",
    "chain_edges",
    "chain_reduce_scatter",
    "chain_total_hops",
    "chainwrite_latency",
    "config_overhead_per_destination",
    "eta_p2mp",
    "choose_num_chains",
    "execute_program",
    "greedy_schedule",
    "multi_chain_all_gather",
    "multi_chain_all_reduce",
    "multi_chain_all_to_all",
    "multi_chain_broadcast",
    "multi_chain_latency",
    "multi_chain_reduce_scatter",
    "MultiChainTask",
    "multicast_latency",
    "multicast_total_hops",
    "naive_schedule",
    "p2mp_efficiency_point",
    "p2p_latency",
    "partition_balance_slack",
    "partition_schedule",
    "partition_total_hops",
    "plan_all_gather",
    "plan_ring_collective",
    "plan_all_reduce",
    "plan_all_to_all",
    "plan_broadcast",
    "plan_reduce_scatter",
    "program_latency",
    "program_wire_bytes",
    "tsp_schedule",
    "unicast_latency",
    "unicast_total_hops",
    "validate_ring_partition",
    "xla_broadcast",
]
