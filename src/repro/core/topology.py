"""2-D mesh / torus topology with XY (dimension-ordered) routing.

This is the substrate both for the paper-faithful NoC model (SoC mesh,
Fig. 1/6) and for scheduling chain orders on the TPU ICI torus: a TPU
pod slice is a 2-D (or 3-D) torus of chips, and dimension-ordered
routing is the standard ICI route, so the same path/hop machinery
serves both.

Coordinates are ``(x, y)`` with ``node_id = y * nx + x`` (row-major by
rows of ``nx``), matching the paper's cluster numbering (C0 at origin).
Links are directed edges between adjacent nodes.
"""

from __future__ import annotations

import dataclasses
from typing import Iterator, Sequence

Coord = tuple[int, int]
Link = tuple[Coord, Coord]  # directed (src, dst), adjacent nodes


@dataclasses.dataclass(frozen=True)
class MeshTopology:
    """A 2-D mesh (optionally wrap-around torus) with XY routing."""

    nx: int
    ny: int
    torus: bool = False

    # -- node helpers -------------------------------------------------
    @property
    def num_nodes(self) -> int:
        return self.nx * self.ny

    def coord(self, node_id: int) -> Coord:
        if not 0 <= node_id < self.num_nodes:
            raise ValueError(f"node {node_id} outside {self.nx}x{self.ny} mesh")
        return (node_id % self.nx, node_id // self.nx)

    def node_id(self, coord: Coord) -> int:
        x, y = coord
        if not (0 <= x < self.nx and 0 <= y < self.ny):
            raise ValueError(f"coord {coord} outside {self.nx}x{self.ny} mesh")
        return y * self.nx + x

    def nodes(self) -> Iterator[int]:
        return iter(range(self.num_nodes))

    # -- distance / routing -------------------------------------------
    def _axis_steps(self, a: int, b: int, n: int) -> list[int]:
        """Unit steps along one axis from a to b (shortest direction)."""
        if a == b:
            return []
        if not self.torus:
            step = 1 if b > a else -1
            return [step] * abs(b - a)
        fwd = (b - a) % n
        bwd = (a - b) % n
        if fwd <= bwd:
            return [1] * fwd
        return [-1] * bwd

    def distance(self, a: Coord | int, b: Coord | int) -> int:
        """Hop count of the XY route (Manhattan / torus-Manhattan)."""
        ca = self.coord(a) if isinstance(a, int) else a
        cb = self.coord(b) if isinstance(b, int) else b
        return len(self._axis_steps(ca[0], cb[0], self.nx)) + len(
            self._axis_steps(ca[1], cb[1], self.ny)
        )

    def xy_path(self, src: Coord | int, dst: Coord | int) -> list[Link]:
        """Directed links of the XY (X-first, then Y) route src -> dst."""
        cur = self.coord(src) if isinstance(src, int) else src
        dst_c = self.coord(dst) if isinstance(dst, int) else dst
        links: list[Link] = []
        for sx in self._axis_steps(cur[0], dst_c[0], self.nx):
            nxt = ((cur[0] + sx) % self.nx, cur[1])
            links.append((cur, nxt))
            cur = nxt
        for sy in self._axis_steps(cur[1], dst_c[1], self.ny):
            nxt = (cur[0], (cur[1] + sy) % self.ny)
            links.append((cur, nxt))
            cur = nxt
        return links

    def path_nodes(self, src: Coord | int, dst: Coord | int) -> list[Coord]:
        """Nodes visited on the XY route, inclusive of both endpoints."""
        src_c = self.coord(src) if isinstance(src, int) else src
        links = self.xy_path(src_c, dst)
        return [src_c] + [l[1] for l in links]

    # -- multicast tree (network-layer baseline) ----------------------
    def multicast_tree_links(
        self, src: Coord | int, dsts: Sequence[Coord | int]
    ) -> set[Link]:
        """Links used by XY-routed network-layer multicast.

        Models the ESP-style router behaviour: one packet follows
        XY routes to every destination; branches that share a prefix
        share the links (the router replicates at divergence points).
        The link set is therefore the union of the per-destination XY
        paths.
        """
        links: set[Link] = set()
        for d in dsts:
            links.update(self.xy_path(src, d))
        return links

    def snake_order(self) -> list[int]:
        """Boustrophedon (snake) node order — a Hamiltonian path on the
        mesh where every hop is 1 physical link. The natural 'perfect'
        chain order when the destination set is the whole mesh."""
        order: list[int] = []
        for y in range(self.ny):
            xs = range(self.nx) if y % 2 == 0 else range(self.nx - 1, -1, -1)
            order.extend(self.node_id((x, y)) for x in xs)
        return order
