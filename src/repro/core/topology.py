"""Weighted link-graph topology with XY (dimension-ordered) routing.

The planning/pricing stack sees a topology as a **weighted link graph**:
nodes joined by directed links, each link carrying
:class:`LinkAttrs` — ``{bandwidth, latency, tier}``. The link-graph
contract every consumer (``core.scheduling``, ``core.simulator``,
``core.program.tier_crossing_stats``) programs against is:

* ``link_attrs(link)``          — the attributes of one directed link;
* ``weighted_distance(a, b)``   — summed link *latency* along the route
  (the weighted hop cost schedulers minimize);
* ``path_min_bw(a, b)``         — the bottleneck link *bandwidth
  fraction* along the route (scales the data-phase bytes/cycle);
* ``path_tier_crossings(a, b)`` — how many tier>0 (slow, inter-pod)
  links the route traverses;
* ``pod_of(node)`` / ``num_pods`` — the tier-0 island a node belongs to.

:class:`MeshTopology` is the **uniform-weight constructor** of that
contract: a 2-D mesh (optionally wrap-around torus) where every link is
``LinkAttrs(bandwidth=1.0, latency=1, tier=0)``, so ``weighted_distance
== distance`` (Manhattan / torus-Manhattan), ``path_min_bw == 1.0`` and
``path_tier_crossings == 0`` — by construction, every pre-existing call
site and CC-exact pin (82 CC/destination Fig. 7 slope, collective
latency pins) is preserved unchanged.

:class:`TieredMeshTopology` is the 2-tier refinement: the same global
``nx × ny`` mesh tiled into ``pods_x × pods_y`` equal pods, with every
link that crosses a pod boundary priced at ``interpod_bw`` (fraction of
the intra-pod link bandwidth) and ``interpod_latency`` (router-latency
multiplier), ``tier=1``. This is the off-chip/on-chip split of real
deployments (fast NoC inside a pod, slow chip-to-chip between pods);
scheduling on it makes hierarchical collectives a *planning outcome*
(see ``core.simulator.choose_num_chains``).

:class:`LinkGraph` is the fully explicit form — arbitrary nodes, an
arbitrary weighted link set, Dijkstra shortest routes — used by the
property tests as the model the mesh classes must agree with
(``to_link_graph()`` exports any mesh into it).

Coordinates are ``(x, y)`` with ``node_id = y * nx + x`` (row-major by
rows of ``nx``), matching the paper's cluster numbering (C0 at origin).
Links are directed edges between adjacent nodes.

``parse_topology_spec`` / ``.spec()`` round-trip the CLI grammar shared
by ``launch.dryrun --topology``, ``launch.train`` and the benchmarks:
``"8x8"``, ``"8x8:torus"``, ``"pods=4x(4x4):interpod_bw=0.25"``,
``"16x1:pods=4x1:interpod_lat=4"`` and — relative to a known axis size
— ``"pods=4:interpod_bw=0.25"``.
"""

from __future__ import annotations

import dataclasses
import functools
import heapq
import math
from typing import Iterator, Sequence

Coord = tuple[int, int]
Link = tuple[Coord, Coord]  # directed (src, dst), adjacent nodes


@dataclasses.dataclass(frozen=True)
class LinkAttrs:
    """Per-link weights of the link graph.

    ``bandwidth`` is a fraction of the NoC link bandwidth
    (``SimParams.link_bw``); ``latency`` multiplies the per-hop router
    latency (``SimParams.router_cc``); ``tier`` labels the link's level
    (0 = intra-pod NoC, >0 = slower inter-pod fabric). The defaults are
    the uniform link every :class:`MeshTopology` edge carries.
    """

    bandwidth: float = 1.0
    latency: int = 1
    tier: int = 0


UNIFORM_LINK = LinkAttrs()


@dataclasses.dataclass(frozen=True)
class MeshTopology:
    """A 2-D mesh (optionally wrap-around torus) with XY routing —
    the uniform-weight link graph (every link = :data:`UNIFORM_LINK`)."""

    nx: int
    ny: int
    torus: bool = False

    # -- node helpers -------------------------------------------------
    @property
    def num_nodes(self) -> int:
        return self.nx * self.ny

    def coord(self, node_id: int) -> Coord:
        if not 0 <= node_id < self.num_nodes:
            raise ValueError(f"node {node_id} outside {self.nx}x{self.ny} mesh")
        return (node_id % self.nx, node_id // self.nx)

    def node_id(self, coord: Coord) -> int:
        x, y = coord
        if not (0 <= x < self.nx and 0 <= y < self.ny):
            raise ValueError(f"coord {coord} outside {self.nx}x{self.ny} mesh")
        return y * self.nx + x

    def nodes(self) -> Iterator[int]:
        return iter(range(self.num_nodes))

    # -- distance / routing -------------------------------------------
    def _axis_steps(self, a: int, b: int, n: int) -> list[int]:
        """Unit steps along one axis from a to b (shortest direction)."""
        if a == b:
            return []
        if not self.torus:
            step = 1 if b > a else -1
            return [step] * abs(b - a)
        fwd = (b - a) % n
        bwd = (a - b) % n
        if fwd <= bwd:
            return [1] * fwd
        return [-1] * bwd

    def distance(self, a: Coord | int, b: Coord | int) -> int:
        """Hop count of the XY route (Manhattan / torus-Manhattan)."""
        ca = self.coord(a) if isinstance(a, int) else a
        cb = self.coord(b) if isinstance(b, int) else b
        return len(self._axis_steps(ca[0], cb[0], self.nx)) + len(
            self._axis_steps(ca[1], cb[1], self.ny)
        )

    def xy_path(self, src: Coord | int, dst: Coord | int) -> list[Link]:
        """Directed links of the XY (X-first, then Y) route src -> dst."""
        cur = self.coord(src) if isinstance(src, int) else src
        dst_c = self.coord(dst) if isinstance(dst, int) else dst
        links: list[Link] = []
        for sx in self._axis_steps(cur[0], dst_c[0], self.nx):
            nxt = ((cur[0] + sx) % self.nx, cur[1])
            links.append((cur, nxt))
            cur = nxt
        for sy in self._axis_steps(cur[1], dst_c[1], self.ny):
            nxt = (cur[0], (cur[1] + sy) % self.ny)
            links.append((cur, nxt))
            cur = nxt
        return links

    def path_nodes(self, src: Coord | int, dst: Coord | int) -> list[Coord]:
        """Nodes visited on the XY route, inclusive of both endpoints."""
        src_c = self.coord(src) if isinstance(src, int) else src
        links = self.xy_path(src_c, dst)
        return [src_c] + [l[1] for l in links]

    # -- weighted link-graph contract ---------------------------------
    def link_attrs(self, link: Link) -> LinkAttrs:
        """Attributes of one directed link (uniform mesh: every link is
        :data:`UNIFORM_LINK`). Subclasses override this one hook; the
        path aggregates below derive from it."""
        del link
        return UNIFORM_LINK

    @property
    def num_pods(self) -> int:
        return 1

    def pod_of(self, node: Coord | int) -> int:
        """Tier-0 island (pod) a node belongs to. One pod here."""
        del node
        return 0

    def weighted_distance(self, a: Coord | int, b: Coord | int) -> int:
        """Summed link latency of the XY route — the weighted hop cost
        schedulers minimize. Uniform mesh: identical to ``distance``
        (every link latency is 1), so every pre-refactor ordering and
        cycle pin is reproduced by construction."""
        return self.distance(a, b)

    def path_min_bw(self, a: Coord | int, b: Coord | int) -> float:
        """Bottleneck link bandwidth fraction along the XY route
        (1.0 when ``a == b`` — no link to bottleneck on)."""
        del a, b
        return 1.0

    def path_tier_crossings(self, a: Coord | int, b: Coord | int) -> int:
        """Number of tier>0 links the XY route traverses."""
        del a, b
        return 0

    def to_link_graph(self) -> "LinkGraph":
        """Export as the explicit :class:`LinkGraph` (node-id links with
        this topology's ``link_attrs``) — the property-test oracle."""
        links: dict[tuple[int, int], LinkAttrs] = {}
        for n in self.nodes():
            c = self.coord(n)
            for dx, dy in ((1, 0), (-1, 0), (0, 1), (0, -1)):
                x, y = c[0] + dx, c[1] + dy
                if self.torus:
                    x, y = x % self.nx, y % self.ny
                elif not (0 <= x < self.nx and 0 <= y < self.ny):
                    continue
                if (x, y) == c:  # degenerate wrap on a length-1 axis
                    continue
                m = self.node_id((x, y))
                links[(n, m)] = self.link_attrs((c, (x, y)))
        return LinkGraph(
            self.num_nodes,
            tuple((a, b, attrs) for (a, b), attrs in sorted(links.items())),
        )

    def spec(self) -> str:
        """Canonical spec string (inverse of :func:`parse_topology_spec`)."""
        return f"{self.nx}x{self.ny}" + (":torus" if self.torus else "")

    # -- multicast tree (network-layer baseline) ----------------------
    def multicast_tree_links(
        self, src: Coord | int, dsts: Sequence[Coord | int]
    ) -> set[Link]:
        """Links used by XY-routed network-layer multicast.

        Models the ESP-style router behaviour: one packet follows
        XY routes to every destination; branches that share a prefix
        share the links (the router replicates at divergence points).
        The link set is therefore the union of the per-destination XY
        paths.
        """
        links: set[Link] = set()
        for d in dsts:
            links.update(self.xy_path(src, d))
        return links

    def snake_order(self) -> list[int]:
        """Boustrophedon (snake) node order — a Hamiltonian path on the
        mesh where every hop is 1 physical link. The natural 'perfect'
        chain order when the destination set is the whole mesh."""
        order: list[int] = []
        for y in range(self.ny):
            xs = range(self.nx) if y % 2 == 0 else range(self.nx - 1, -1, -1)
            order.extend(self.node_id((x, y)) for x in xs)
        return order


@dataclasses.dataclass(frozen=True)
class TieredMeshTopology(MeshTopology):
    """Two-tier weighted mesh: the global ``nx × ny`` mesh tiled into
    ``pods_x × pods_y`` equal pods. Links inside a pod are uniform
    (:data:`UNIFORM_LINK`); links crossing a pod boundary carry
    ``LinkAttrs(interpod_bw, interpod_latency, tier=1)`` — the slow
    chip-to-chip/inter-pod fabric. A neutral tiering (``interpod_bw=1.0,
    interpod_latency=1``) weighs exactly like the uniform mesh (pinned),
    though it still *labels* boundary links tier 1 for crossing counts.
    """

    pods_x: int = 1
    pods_y: int = 1
    interpod_bw: float = 0.25
    interpod_latency: int = 4

    def __post_init__(self) -> None:
        if self.pods_x < 1 or self.pods_y < 1:
            raise ValueError(
                f"pods must be >= 1, got {self.pods_x}x{self.pods_y}"
            )
        if self.nx % self.pods_x or self.ny % self.pods_y:
            raise ValueError(
                f"pods {self.pods_x}x{self.pods_y} must tile the "
                f"{self.nx}x{self.ny} mesh evenly"
            )
        if not 0.0 < self.interpod_bw <= 1.0:
            raise ValueError(
                f"interpod_bw must be in (0, 1], got {self.interpod_bw}"
            )
        if self.interpod_latency < 1:
            raise ValueError(
                f"interpod_latency must be >= 1, got {self.interpod_latency}"
            )

    @classmethod
    def from_pods(
        cls,
        num_pods: int,
        pod_nx: int,
        pod_ny: int,
        *,
        torus: bool = False,
        interpod_bw: float = 0.25,
        interpod_latency: int = 4,
    ) -> "TieredMeshTopology":
        """``num_pods`` pods of ``pod_nx × pod_ny`` each, arranged in a
        near-square pod grid (4 pods of 4x4 -> an 8x8 global mesh)."""
        if num_pods < 1:
            raise ValueError(f"num_pods must be >= 1, got {num_pods}")
        py = max(1, math.isqrt(num_pods))
        while num_pods % py:
            py -= 1
        px = num_pods // py
        return cls(
            nx=px * pod_nx, ny=py * pod_ny, torus=torus,
            pods_x=px, pods_y=py,
            interpod_bw=interpod_bw, interpod_latency=interpod_latency,
        )

    # -- pod helpers --------------------------------------------------
    @property
    def pod_nx(self) -> int:
        return self.nx // self.pods_x

    @property
    def pod_ny(self) -> int:
        return self.ny // self.pods_y

    @property
    def num_pods(self) -> int:
        return self.pods_x * self.pods_y

    def pod_of(self, node: Coord | int) -> int:
        x, y = self.coord(node) if isinstance(node, int) else node
        return (y // self.pod_ny) * self.pods_x + (x // self.pod_nx)

    def pod_members(self, pod: int) -> list[int]:
        """Node ids of one pod, in row-major order."""
        if not 0 <= pod < self.num_pods:
            raise ValueError(f"pod {pod} outside {self.pods_x}x{self.pods_y}")
        px, py = pod % self.pods_x, pod // self.pods_x
        return [
            self.node_id((x, y))
            for y in range(py * self.pod_ny, (py + 1) * self.pod_ny)
            for x in range(px * self.pod_nx, (px + 1) * self.pod_nx)
        ]

    # -- weighted link-graph contract ---------------------------------
    @functools.cached_property
    def _interpod_attrs(self) -> LinkAttrs:
        return LinkAttrs(
            bandwidth=self.interpod_bw,
            latency=self.interpod_latency,
            tier=1,
        )

    def link_attrs(self, link: Link) -> LinkAttrs:
        (ax, ay), (bx, by) = link
        if ax // self.pod_nx != bx // self.pod_nx or (
            ay // self.pod_ny != by // self.pod_ny
        ):
            return self._interpod_attrs
        return UNIFORM_LINK

    def weighted_distance(self, a: Coord | int, b: Coord | int) -> int:
        return sum(self.link_attrs(l).latency for l in self.xy_path(a, b))

    def path_min_bw(self, a: Coord | int, b: Coord | int) -> float:
        return min(
            (self.link_attrs(l).bandwidth for l in self.xy_path(a, b)),
            default=1.0,
        )

    def path_tier_crossings(self, a: Coord | int, b: Coord | int) -> int:
        return sum(
            1 for l in self.xy_path(a, b) if self.link_attrs(l).tier > 0
        )

    def spec(self) -> str:
        return (
            f"{self.nx}x{self.ny}:pods={self.pods_x}x{self.pods_y}"
            f":interpod_bw={self.interpod_bw:g}"
            f":interpod_lat={self.interpod_latency}"
            + (":torus" if self.torus else "")
        )


@dataclasses.dataclass(frozen=True)
class LinkGraph:
    """Fully explicit weighted link graph: ``num_nodes`` nodes and a
    tuple of directed ``(src, dst, LinkAttrs)`` links. Routes are
    latency-weighted Dijkstra shortest paths (deterministic: ties break
    toward smaller node ids) — the general model the mesh classes'
    XY-routed aggregates are property-tested against, and the substrate
    for topologies the 2-D constructors cannot express."""

    num_nodes: int
    links: tuple[tuple[int, int, LinkAttrs], ...]

    def __post_init__(self) -> None:
        if self.num_nodes < 1:
            raise ValueError(f"num_nodes must be >= 1, got {self.num_nodes}")
        for a, b, attrs in self.links:
            if not (0 <= a < self.num_nodes and 0 <= b < self.num_nodes):
                raise ValueError(f"link ({a},{b}) out of range")
            if a == b:
                raise ValueError(f"self-link on node {a}")
            if attrs.latency < 1 or not 0.0 < attrs.bandwidth:
                raise ValueError(f"bad link attrs on ({a},{b}): {attrs}")

    @functools.cached_property
    def _adj(self) -> dict[int, tuple[tuple[int, LinkAttrs], ...]]:
        adj: dict[int, list[tuple[int, LinkAttrs]]] = {
            n: [] for n in range(self.num_nodes)
        }
        for a, b, attrs in self.links:
            adj[a].append((b, attrs))
        return {n: tuple(sorted(nbrs)) for n, nbrs in adj.items()}

    def link_attrs(self, a: int, b: int) -> LinkAttrs:
        for m, attrs in self._adj[a]:
            if m == b:
                return attrs
        raise ValueError(f"no link ({a},{b}) in graph")

    def shortest_path(self, a: int, b: int) -> list[tuple[int, int]]:
        """Latency-minimal route a -> b as a list of (src, dst) node-id
        links (empty when ``a == b``); raises when unreachable."""
        if not (0 <= a < self.num_nodes and 0 <= b < self.num_nodes):
            raise ValueError(f"nodes ({a},{b}) out of range")
        if a == b:
            return []
        dist: dict[int, int] = {a: 0}
        prev: dict[int, int] = {}
        heap: list[tuple[int, int]] = [(0, a)]
        while heap:
            d, n = heapq.heappop(heap)
            if n == b:
                break
            if d > dist.get(n, d):
                continue
            for m, attrs in self._adj[n]:
                nd = d + attrs.latency
                if nd < dist.get(m, nd + 1):
                    dist[m] = nd
                    prev[m] = n
                    heapq.heappush(heap, (nd, m))
        if b not in dist:
            raise ValueError(f"node {b} unreachable from {a}")
        path: list[tuple[int, int]] = []
        cur = b
        while cur != a:
            path.append((prev[cur], cur))
            cur = prev[cur]
        return path[::-1]

    def path_cost(self, path: Sequence[tuple[int, int]]) -> int:
        """Summed link latency of an explicit route."""
        return sum(self.link_attrs(a, b).latency for a, b in path)

    def weighted_distance(self, a: int, b: int) -> int:
        return self.path_cost(self.shortest_path(a, b))

    def path_min_bw(self, a: int, b: int) -> float:
        return min(
            (self.link_attrs(s, d).bandwidth
             for s, d in self.shortest_path(a, b)),
            default=1.0,
        )

    def path_tier_crossings(self, a: int, b: int) -> int:
        return sum(
            1 for s, d in self.shortest_path(a, b)
            if self.link_attrs(s, d).tier > 0
        )


def parse_topology_spec(
    spec: str, num_nodes: int | None = None
) -> MeshTopology:
    """Parse the CLI topology grammar (shared by ``dryrun --topology``,
    ``train --topology`` and ``benchmarks/bench_collectives.py``).

    Colon-separated clauses, order-insensitive after the first:

    * ``"8x8"``                       — uniform mesh;
    * ``"8x8:torus"``                 — uniform torus;
    * ``"pods=4x(4x4)"``              — 4 pods of 4x4 each, near-square
      pod grid (:meth:`TieredMeshTopology.from_pods`);
    * ``"16x1:pods=4x1"``             — explicit global mesh + pod grid;
    * ``"pods=4"``                    — *relative* form: tile a known
      1-D ring (``num_nodes`` required) into 4 equal pods;
    * ``":interpod_bw=0.25"`` / ``":interpod_lat=4"`` — tier-1 link
      weights (defaults 0.25 / 4).

    Round-trips ``topo.spec()`` for every topology class here.
    """
    if not spec or not spec.strip():
        raise ValueError("empty topology spec")
    shape: tuple[int, int] | None = None
    pods: tuple[int, int] | None = None
    pod_shape: tuple[int, int] | None = None
    num_pods: int | None = None
    torus = False
    bw = 0.25
    lat = 4
    tiered = False

    def _pair(text: str, what: str) -> tuple[int, int]:
        parts = text.split("x")
        if len(parts) != 2:
            raise ValueError(f"bad {what} {text!r} in topology spec {spec!r}")
        try:
            a, b = int(parts[0]), int(parts[1])
        except ValueError:
            raise ValueError(
                f"bad {what} {text!r} in topology spec {spec!r}"
            ) from None
        if a < 1 or b < 1:
            raise ValueError(f"{what} must be positive, got {text!r}")
        return a, b

    for clause in spec.strip().split(":"):
        clause = clause.strip()
        if not clause:
            raise ValueError(f"empty clause in topology spec {spec!r}")
        if clause == "torus":
            torus = True
        elif clause.startswith("pods="):
            if pods is not None or num_pods is not None:
                raise ValueError(
                    f"duplicate pods clause in topology spec {spec!r}"
                )
            tiered = True
            val = clause[len("pods="):]
            if "(" in val:  # pods=Px(AxB)
                if not val.endswith(")"):
                    raise ValueError(f"bad pods clause {clause!r}")
                count, inner = val[:-1].split("x(", 1)
                try:
                    num_pods = int(count)
                except ValueError:
                    raise ValueError(f"bad pods clause {clause!r}") from None
                pod_shape = _pair(inner, "pod shape")
            elif "x" in val:  # pods=PXxPY (with an explicit global shape)
                pods = _pair(val, "pod grid")
            else:  # pods=P (relative to a known axis size)
                try:
                    num_pods = int(val)
                except ValueError:
                    raise ValueError(f"bad pods clause {clause!r}") from None
        elif clause.startswith("interpod_bw="):
            tiered = True
            bw = float(clause[len("interpod_bw="):])
        elif clause.startswith("interpod_lat="):
            tiered = True
            lat = int(clause[len("interpod_lat="):])
        elif "x" in clause and shape is None:
            shape = _pair(clause, "mesh shape")
        else:
            raise ValueError(f"unknown clause {clause!r} in topology spec {spec!r}")

    if not tiered:
        if shape is None:
            raise ValueError(f"topology spec {spec!r} has no mesh shape")
        return MeshTopology(shape[0], shape[1], torus=torus)
    if pod_shape is not None:  # pods=Px(AxB)
        if num_pods is None or shape is not None or pods is not None:
            raise ValueError(f"ambiguous pod clauses in {spec!r}")
        return TieredMeshTopology.from_pods(
            num_pods, pod_shape[0], pod_shape[1], torus=torus,
            interpod_bw=bw, interpod_latency=lat,
        )
    if pods is not None:  # NxM:pods=PXxPY
        if shape is None:
            raise ValueError(
                f"pod grid without a global mesh shape in {spec!r}"
            )
        return TieredMeshTopology(
            shape[0], shape[1], torus=torus,
            pods_x=pods[0], pods_y=pods[1],
            interpod_bw=bw, interpod_latency=lat,
        )
    if num_pods is not None:  # pods=P, relative to the axis size
        if shape is not None:
            raise ValueError(
                f"use pods=PXxPY with an explicit mesh shape ({spec!r})"
            )
        if num_nodes is None:
            raise ValueError(
                f"relative spec {spec!r} needs a known axis size"
            )
        if num_nodes % num_pods:
            raise ValueError(
                f"pods={num_pods} must divide the axis size {num_nodes}"
            )
        return TieredMeshTopology(
            num_nodes, 1, torus=torus, pods_x=num_pods, pods_y=1,
            interpod_bw=bw, interpod_latency=lat,
        )
    # only interpod_* clauses given: weights without a pod structure
    if shape is None:
        raise ValueError(f"topology spec {spec!r} has no mesh shape")
    raise ValueError(
        f"interpod weights without a pods= clause in {spec!r}"
    )
